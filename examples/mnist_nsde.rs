//! MNIST Neural SDE classification (paper §4.2.2, Table 4 + Figure 6):
//! drift/diffusion per Eq. 18-21, 10-trajectory mean-logit prediction,
//! ERNSDE gives the paper's headline 1.34x train / 2.1x predict speedup.
//!
//! ```bash
//! cargo run --release --example mnist_nsde [epochs]
//! ```

use regnde::coordinator::experiments::{run_by_name, TrainOpts};
use regnde::coordinator::recorder::Recorder;
use regnde::coordinator::Method;
use regnde::runtime::Engine;

fn main() -> anyhow::Result<()> {
    let epochs: usize = std::env::args()
        .nth(1)
        .map_or(3, |s| s.parse().unwrap_or(3));
    let engine = Engine::new(regnde::default_artifacts_dir())?;
    let recorder = Recorder::new(regnde::default_runs_dir())?;
    let opts = TrainOpts {
        epochs,
        iters_per_epoch: 10,
        seed: 0,
        verbose: true,
    };

    let mut results = Vec::new();
    for method in ["vanilla", "srnsde", "ernsde"] {
        println!("--- {method} ---");
        let r = run_by_name(&engine, "mnist-nsde", Method::parse(method)?, opts)?;
        recorder.save(&r)?;
        results.push(r);
    }

    println!("\n============ MNIST NSDE summary (Table 4) ============");
    println!(
        "{:<14} {:>9} {:>10} {:>9} {:>10}",
        "method", "train s", "predict s", "NFE", "test acc"
    );
    for r in &results {
        println!(
            "{:<14} {:>9.1} {:>10.4} {:>9.1} {:>10.4}",
            r.method, r.train_time_s, r.predict_time_s, r.predict_nfe, r.final_test_metric
        );
    }
    let v = &results[0];
    let er = &results[2];
    println!(
        "\nERNSDE vs vanilla: train {:.2}x, predict {:.2}x, NFE {:.2}x \
         (paper: 1.51x / 2.08x / 2.23x)",
        v.train_time_s / er.train_time_s.max(1e-9),
        v.predict_time_s / er.predict_time_s.max(1e-9),
        v.predict_nfe / er.predict_nfe.max(1.0),
    );
    Ok(())
}
