//! End-to-end driver (the repository's E2E validation run): train the
//! MNIST Neural ODE for a few hundred optimizer steps with the ERNODE
//! regularizer, logging the loss curve, NFE trajectory and budget-ladder
//! routing — then compare training/prediction cost against a vanilla
//! baseline.
//!
//! ```bash
//! cargo run --release --example mnist_node [epochs] [iters_per_epoch]
//! ```
//!
//! The reference run is recorded in EXPERIMENTS.md §E2E.

use regnde::coordinator::experiments::{run_by_name, TrainOpts};
use regnde::coordinator::recorder::Recorder;
use regnde::coordinator::Method;
use regnde::runtime::Engine;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let epochs: usize = args.get(1).map_or(10, |s| s.parse().unwrap_or(10));
    let iters: usize = args.get(2).map_or(30, |s| s.parse().unwrap_or(30));

    let engine = Engine::new(regnde::default_artifacts_dir())?;
    let recorder = Recorder::new(regnde::default_runs_dir())?;
    let opts = TrainOpts {
        epochs,
        iters_per_epoch: iters,
        seed: 0,
        verbose: true,
    };
    println!(
        "=== MNIST Neural ODE e2e: {} optimizer steps (ERNODE vs vanilla) ===\n",
        epochs * iters
    );

    println!("--- ERNODE (error-estimate regularized, coef annealed 100->10) ---");
    let er = run_by_name(&engine, "mnist-node", Method::parse("ernode")?, opts)?;
    recorder.save(&er)?;

    println!("\n--- Vanilla NODE baseline ---");
    let vanilla = run_by_name(&engine, "mnist-node", Method::VANILLA, opts)?;
    recorder.save(&vanilla)?;

    println!("\n===================== e2e summary =====================");
    println!("loss curve (ERNODE):");
    for e in &er.epochs {
        println!(
            "  epoch {:>3}: loss {:>8.4}  acc {:>6.3}  nfe {:>6.1}  rung {}  ({:.1}s)",
            e.epoch, e.loss, e.metric, e.nfe, e.rung, e.wall_s
        );
    }
    for r in [&vanilla, &er] {
        println!(
            "{:<14} train {:>7.1}s | predict {:>7.4}s | pred NFE {:>6.1} | \
             test acc {:.4} | escalations {} descents {}",
            r.method,
            r.train_time_s,
            r.predict_time_s,
            r.predict_nfe,
            r.final_test_metric,
            r.escalations,
            r.descents
        );
    }
    println!(
        "\ntrain speedup {:.2}x | predict speedup {:.2}x (paper Table 1: 1.20x / 1.57x)",
        vanilla.train_time_s / er.train_time_s.max(1e-9),
        vanilla.predict_time_s / er.predict_time_s.max(1e-9),
    );
    Ok(())
}
