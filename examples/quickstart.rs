//! Quickstart: train one regularized vs one unregularized spiral Neural
//! ODE on the **native backend** — pure Rust, no artifacts, no XLA — and
//! print the white-boxed solver statistics the paper is built on.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! (Set `REGNDE_BACKEND=pjrt` with `--features pjrt` + compiled artifacts
//! to run the same comparison through the AOT engine.)

use regnde::coordinator::experiments::{run_by_name, TrainOpts};
use regnde::coordinator::Method;
use regnde::runtime::{backend_from_env, Backend};

fn main() -> anyhow::Result<()> {
    let backend = backend_from_env(&regnde::default_artifacts_dir())?;
    println!("backend: {}", backend.name());
    let info = backend.model("spiral_node")?;
    println!(
        "spiral_node: {} params, {} opt-state floats ({})\n",
        info.params_size, info.opt_state_size, info.optimizer
    );

    let opts = TrainOpts {
        epochs: 3,
        iters_per_epoch: 20,
        seed: 0,
        verbose: true,
    };

    println!("--- Vanilla Neural ODE (spiral, Fig. 2 setting) ---");
    let vanilla = run_by_name(backend.as_ref(), "spiral-node", Method::VANILLA, opts)?;

    println!("\n--- ERNODE + SRNODE (error + stiffness regularized) ---");
    let reg = run_by_name(
        backend.as_ref(),
        "spiral-node",
        Method::parse("srnode+ernode")?,
        opts,
    )?;

    println!("\n================= summary =================");
    for r in [&vanilla, &reg] {
        println!(
            "{:<18} train {:>6.2}s | predict {:>7.4}s | NFE {:>6.1} | MSE {:.5}",
            r.method, r.train_time_s, r.predict_time_s, r.predict_nfe, r.final_test_loss
        );
    }
    let speedup = vanilla.predict_nfe / reg.predict_nfe.max(1.0);
    println!(
        "\nprediction NFE ratio (vanilla/regularized): {speedup:.2}x \
         — the paper's Figure 2 effect"
    );
    Ok(())
}
