//! Quickstart: load the artifact manifest, run one regularized vs one
//! unregularized training run on the spiral Neural ODE, and print the
//! white-boxed solver statistics the paper is built on.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use regnde::coordinator::experiments::{run_by_name, TrainOpts};
use regnde::coordinator::Method;
use regnde::runtime::Engine;

fn main() -> anyhow::Result<()> {
    let engine = Engine::new(regnde::default_artifacts_dir())?;
    println!("PJRT platform: {}", engine.platform());
    println!(
        "manifest: {} artifacts, {} models\n",
        engine.manifest.artifacts.len(),
        engine.manifest.models.len()
    );

    let opts = TrainOpts {
        epochs: 3,
        iters_per_epoch: 20,
        seed: 0,
        verbose: true,
    };

    println!("--- Vanilla Neural ODE (spiral, Fig. 2 setting) ---");
    let vanilla = run_by_name(&engine, "spiral-node", Method::VANILLA, opts)?;

    println!("\n--- ERNODE + SRNODE (error + stiffness regularized) ---");
    let reg = run_by_name(
        &engine,
        "spiral-node",
        Method::parse("srnode+ernode")?,
        opts,
    )?;

    println!("\n================= summary =================");
    for r in [&vanilla, &reg] {
        println!(
            "{:<18} train {:>6.2}s | predict {:>7.4}s | NFE {:>6.1} | MSE {:.5}",
            r.method, r.train_time_s, r.predict_time_s, r.predict_nfe, r.final_test_loss
        );
    }
    let speedup = vanilla.predict_nfe / reg.predict_nfe.max(1.0);
    println!(
        "\nprediction NFE ratio (vanilla/regularized): {speedup:.2}x \
         — the paper's Figure 2 effect"
    );
    Ok(())
}
