//! Neural SDE fit of the spiral diagonal-noise SDE (paper §4.2.1, Table 3
//! + Figure 5): ground-truth moments from the native Rust SDE ensemble,
//! GMM moment-matching training, ERNSDE/SRNSDE regularization.
//!
//! ```bash
//! cargo run --release --example spiral_sde [iterations]
//! ```

use regnde::coordinator::experiments::spiral_nsde;
use regnde::coordinator::experiments::{run_by_name, TrainOpts};
use regnde::coordinator::Method;
use regnde::runtime::Engine;

fn main() -> anyhow::Result<()> {
    let iters: usize = std::env::args()
        .nth(1)
        .map_or(25, |s| s.parse().unwrap_or(25));
    let engine = Engine::new(regnde::default_artifacts_dir())?;
    let opts = TrainOpts {
        epochs: 1,
        iters_per_epoch: iters,
        seed: 0,
        verbose: true,
    };

    // Show the data substrate at work: moments from the Rust SDE ensemble.
    let (_, mu, var, _) = spiral_nsde::ground_truth(0);
    println!("ground-truth moments (native Rust SDE ensemble, Eq. 15):");
    for k in [0, 10, 20, 29] {
        println!(
            "  t[{k:>2}]  mu = ({:>7.4}, {:>7.4})   var = ({:.4}, {:.4})",
            mu[k * 2],
            mu[k * 2 + 1],
            var[k * 2],
            var[k * 2 + 1]
        );
    }
    println!();

    let mut results = Vec::new();
    for method in ["vanilla", "srnsde", "ernsde"] {
        println!("--- {method} ({iters} GMM iterations) ---");
        let r = run_by_name(&engine, "spiral-nsde", Method::parse(method)?, opts)?;
        results.push(r);
    }

    println!("\n=============== Spiral SDE summary (Table 3) ===============");
    println!(
        "{:<14} {:>10} {:>9} {:>10} {:>9}",
        "method", "GMM loss", "train s", "predict s", "NFE"
    );
    for r in &results {
        println!(
            "{:<14} {:>10.4} {:>9.1} {:>10.4} {:>9.1}",
            r.method, r.final_test_loss, r.train_time_s, r.predict_time_s, r.predict_nfe
        );
    }
    Ok(())
}
