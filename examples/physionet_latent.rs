//! Latent ODE on synthetic Physionet-like vitals (paper §4.1.2 scenario):
//! the workload the paper's Table 2 measures — SRNODE is the paper's best
//! method here (0.87h vs 1.75h train, 0.20s vs 0.53s predict).
//!
//! ```bash
//! cargo run --release --example physionet_latent [epochs]
//! ```

use regnde::coordinator::experiments::{run_by_name, TrainOpts};
use regnde::coordinator::recorder::Recorder;
use regnde::coordinator::Method;
use regnde::runtime::Engine;

fn main() -> anyhow::Result<()> {
    let epochs: usize = std::env::args()
        .nth(1)
        .map_or(4, |s| s.parse().unwrap_or(4));
    let engine = Engine::new(regnde::default_artifacts_dir())?;
    let recorder = Recorder::new(regnde::default_runs_dir())?;
    let opts = TrainOpts {
        epochs,
        iters_per_epoch: 10,
        seed: 0,
        verbose: true,
    };

    let mut results = Vec::new();
    for method in ["vanilla", "srnode", "ernode"] {
        println!("--- {method} ---");
        let r = run_by_name(&engine, "latent-ode", Method::parse(method)?, opts)?;
        recorder.save(&r)?;
        results.push(r);
    }

    println!("\n========== Physionet interpolation summary ==========");
    println!(
        "{:<16} {:>9} {:>10} {:>9} {:>12}",
        "method", "train s", "predict s", "NFE", "test MSE"
    );
    for r in &results {
        println!(
            "{:<16} {:>9.1} {:>10.4} {:>9.1} {:>12.5}",
            r.method, r.train_time_s, r.predict_time_s, r.predict_nfe, r.final_test_metric
        );
    }
    println!(
        "\npaper Table 2 shape: regularized variants cut NFE ~700 -> ~280 \
         and train time by 36-50% at ~equal loss"
    );
    Ok(())
}
