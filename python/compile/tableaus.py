"""Butcher tableaus for the explicit embedded Runge-Kutta pairs used by regnde.

Each tableau is an explicit RK method with an embedded lower-order solution
used for the local error estimate (paper Eq. 3-5).  We store the *difference*
coefficients ``btilde = b - bhat`` so the error estimate is simply

    E = h * sum_i btilde_i * k_i

exactly as OrdinaryDiffEq.jl computes it.  The stiffness estimate (paper
Eq. 8, Shampine 1977) needs two stages with equal ``c``; for every tableau we
record the index pair ``(stiff_x, stiff_y)`` with ``c[x] == c[y]``.

These constants are mirrored bit-for-bit in ``rust/src/solvers/tableau.rs`` —
the native Rust solver suite cross-validates the JAX solver trajectory-for-
trajectory (see rust/tests/cross_validate.rs).
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import numpy as np


class Tableau(NamedTuple):
    """An explicit embedded Runge-Kutta tableau.

    Attributes:
      name:   human-readable method name.
      a:      (s, s) strictly lower-triangular stage coefficient matrix.
      b:      (s,) higher-order solution weights.
      btilde: (s,) ``b - bhat`` difference weights for the error estimate.
      c:      (s,) stage abscissae.
      order:  order of the propagated (higher-order) solution.
      fsal:   whether the last stage equals f at the accepted step end
              (First-Same-As-Last: k[-1] becomes k[0] of the next step).
      stiff_pair: indices (x, y) with c[x] == c[y] used for the Shampine
              stiffness ratio (paper Eq. 8).
    """

    name: str
    a: np.ndarray
    b: np.ndarray
    btilde: np.ndarray
    c: np.ndarray
    order: int
    fsal: bool
    stiff_pair: Tuple[int, int]

    @property
    def stages(self) -> int:
        return len(self.b)

    @property
    def nfe_per_attempt(self) -> int:
        """f-evaluations consumed by one step *attempt* (FSAL reuses k1)."""
        return self.stages - 1 if self.fsal else self.stages


def _lower(rows) -> np.ndarray:
    s = len(rows) + 1
    a = np.zeros((s, s), dtype=np.float64)
    for i, row in enumerate(rows, start=1):
        a[i, : len(row)] = row
    return a


def tsit5() -> Tableau:
    """Tsitouras 5(4) (Tsitouras 2011) — the paper's Neural-ODE solver."""
    a = _lower(
        [
            [0.161],
            [-0.008480655492356989, 0.335480655492357],
            [2.8971530571054935, -6.359448489975075, 4.3622954328695815],
            [
                5.325864828439257,
                -11.748883564062828,
                7.4955393428898365,
                -0.09249506636175525,
            ],
            [
                5.86145544294642,
                -12.92096931784711,
                8.159367898576159,
                -0.071584973281401,
                -0.028269050394068383,
            ],
            [
                0.09646076681806523,
                0.01,
                0.4798896504144996,
                1.379008574103742,
                -3.290069515436081,
                2.324710524099774,
            ],
        ]
    )
    b = np.array(
        [
            0.09646076681806523,
            0.01,
            0.4798896504144996,
            1.379008574103742,
            -3.290069515436081,
            2.324710524099774,
            0.0,
        ]
    )
    btilde = np.array(
        [
            -0.00178001105222577714,
            -0.0008164344596567469,
            0.007880878010261995,
            -0.1447110071732629,
            0.5823571654525552,
            -0.45808210592918697,
            0.015151515151515152,
        ]
    )
    c = np.array([0.0, 0.161, 0.327, 0.9, 0.9800255409045097, 1.0, 1.0])
    return Tableau("tsit5", a, b, btilde, c, order=5, fsal=True, stiff_pair=(5, 6))


def dopri5() -> Tableau:
    """Dormand-Prince 5(4) — the classic `dopri` pair (ablation alternative)."""
    a = _lower(
        [
            [1 / 5],
            [3 / 40, 9 / 40],
            [44 / 45, -56 / 15, 32 / 9],
            [19372 / 6561, -25360 / 2187, 64448 / 6561, -212 / 729],
            [9017 / 3168, -355 / 33, 46732 / 5247, 49 / 176, -5103 / 18656],
            [35 / 384, 0.0, 500 / 1113, 125 / 192, -2187 / 6784, 11 / 84],
        ]
    )
    b = np.array([35 / 384, 0.0, 500 / 1113, 125 / 192, -2187 / 6784, 11 / 84, 0.0])
    bhat = np.array(
        [
            5179 / 57600,
            0.0,
            7571 / 16695,
            393 / 640,
            -92097 / 339200,
            187 / 2100,
            1 / 40,
        ]
    )
    c = np.array([0.0, 1 / 5, 3 / 10, 4 / 5, 8 / 9, 1.0, 1.0])
    return Tableau(
        "dopri5", a, b, b - bhat, c, order=5, fsal=True, stiff_pair=(5, 6)
    )


def bs3() -> Tableau:
    """Bogacki-Shampine 3(2) — cheap low-order pair (ablation alternative).

    BS3 has no two distinct stages with equal ``c``, so there is no valid
    Shampine pair; the degenerate ``(3, 3)`` makes the stiffness estimate
    read ~0 ("not stiff") instead of comparing stages at different times
    (kept bit-for-bit in sync with rust/src/solvers/tableau.rs).
    """
    a = _lower([[1 / 2], [0.0, 3 / 4], [2 / 9, 1 / 3, 4 / 9]])
    b = np.array([2 / 9, 1 / 3, 4 / 9, 0.0])
    bhat = np.array([7 / 24, 1 / 4, 1 / 3, 1 / 8])
    c = np.array([0.0, 1 / 2, 3 / 4, 1.0])
    return Tableau("bs3", a, b, b - bhat, c, order=3, fsal=True, stiff_pair=(3, 3))


_REGISTRY = {"tsit5": tsit5, "dopri5": dopri5, "bs3": bs3}


def get(name: str) -> Tableau:
    """Look up a tableau by name (``tsit5``, ``dopri5``, ``bs3``)."""
    try:
        return _REGISTRY[name]()
    except KeyError:
        raise KeyError(f"unknown tableau {name!r}; have {sorted(_REGISTRY)}")
