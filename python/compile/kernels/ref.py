"""Pure-jnp oracles for the Pallas kernels.

Every Pallas kernel in this package has a reference implementation here; the
pytest suite (python/tests/test_kernels.py) sweeps shapes with hypothesis and
asserts allclose between kernel and oracle.  The oracles are also what the
tiny "spiral" models use directly (kernel launch overhead dominates at
state dim = 2).
"""
from __future__ import annotations

import jax.numpy as jnp


def dense_act(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray, act: str = "tanh"):
    """``act(x @ w + b)`` — the fused dynamics-MLP layer (paper Eq. 12-13)."""
    y = x @ w + b
    if act == "tanh":
        return jnp.tanh(y)
    if act == "linear":
        return y
    if act == "sigmoid":
        return 1.0 / (1.0 + jnp.exp(-y))
    raise ValueError(f"unknown act {act!r}")


def rk_combine(ks: jnp.ndarray, z: jnp.ndarray, h: jnp.ndarray, b, btilde):
    """Stage combination + embedded error estimate (paper Eq. 3 + Eq. 9 input).

    Args:
      ks:     (S, ..., D) stacked RK stages.
      z:      (..., D) current state.
      h:      scalar step size.
      b:      (S,) solution weights.
      btilde: (S,) embedded-difference weights.

    Returns:
      ``(z_new, err)`` where ``z_new = z + h * sum_i b_i k_i`` and
      ``err = h * sum_i btilde_i k_i`` is the local error estimate vector
      whose scaled norm is the paper's Eq. 5 ratio.
    """
    b = jnp.asarray(b, dtype=z.dtype).reshape((-1,) + (1,) * z.ndim)
    bt = jnp.asarray(btilde, dtype=z.dtype).reshape((-1,) + (1,) * z.ndim)
    z_new = z + h * jnp.sum(b * ks, axis=0)
    err = h * jnp.sum(bt * ks, axis=0)
    return z_new, err
