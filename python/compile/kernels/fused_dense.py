"""Fused dense + activation Pallas kernel (the dynamics-MLP hot-spot).

The paper's dynamics networks (Eq. 12-13, 16, 18-21) are chains of
``act(x @ W + b)`` layers evaluated once per RK/SDE stage — by far the
dominant FLOP cost of every experiment.  This module provides

  * ``dense_act(x, w, b, act=...)`` — a Pallas kernel computing the fused
    matmul + bias + activation in one pass over VMEM-resident tiles, wrapped
    in ``jax.custom_vjp`` so reverse-mode AD (the discrete adjoint of paper
    §3.2) works; the backward pass reuses the same Pallas matmul kernel.

TPU mapping (DESIGN.md §Hardware-Adaptation): the grid tiles rows of ``x``
and columns of ``w`` into MXU-aligned ``(TILE_M, K) x (K, TILE_N)`` blocks
held in VMEM; the activation is applied by the VPU on the accumulator before
it is written back to HBM, so the nonlinearity is free.  On this image the
kernel runs under ``interpret=True`` (CPU PJRT cannot execute Mosaic
custom-calls); the BlockSpec structure is what the §Perf VMEM/MXU estimates
in EXPERIMENTS.md are computed from.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# MXU-friendly tile sizes.  TILE_M multiples of 8 (sublane), TILE_N multiples
# of 128 (lane) keep the systolic array fully fed on a real TPU; in interpret
# mode they just bound the working set.
TILE_M = 128
TILE_N = 128


def _cdiv(a: int, b: int) -> int:
    return -(-a // b)


def _tile(n: int, cap: int) -> int:
    """Adaptive tile edge: cap for large dims, 8-aligned cover for small.

    §Perf finding (EXPERIMENTS.md): fixed 128-tiles pad small problem dims
    (e.g. the Latent ODE's 20-50-wide matmuls) by up to 10x in FLOPs.  On a
    real TPU the lane dimension would stay at 128; under interpret=True the
    padding is pure waste, so small dims get a single 8-aligned tile.  The
    BlockSpec structure (and hence the TPU VMEM/MXU estimate) is unchanged
    for MXU-scale operands.
    """
    return cap if n >= cap else _cdiv(n, 8) * 8


def _apply_act(y, act: str):
    if act == "tanh":
        return jnp.tanh(y)
    if act == "linear":
        return y
    if act == "sigmoid":
        return 1.0 / (1.0 + jnp.exp(-y))
    raise ValueError(f"unknown act {act!r}")


def _dense_act_kernel(x_ref, w_ref, b_ref, o_ref, *, act: str):
    """One (TILE_M, TILE_N) output tile: act(x_tile @ w_tile + b_tile)."""
    y = jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)
    y = y + b_ref[...]
    o_ref[...] = _apply_act(y, act)


def _matmul_kernel(a_ref, b_ref, o_ref):
    """Plain (TILE_M, TILE_N) matmul tile — used by the backward pass."""
    o_ref[...] = jnp.dot(a_ref[...], b_ref[...], preferred_element_type=jnp.float32)


def _pad_to(x: jnp.ndarray, axis: int, mult: int) -> jnp.ndarray:
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _dense_act_fwd_impl(x, w, b, act: str):
    """Launch the fused kernel over a (M/tm, N/tn) grid."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    tm, tn = _tile(m, TILE_M), _tile(n, TILE_N)
    xp = _pad_to(x, 0, tm)
    wp = _pad_to(w, 1, tn)
    bp = _pad_to(b.reshape(1, -1), 1, tn)
    mp, np_ = xp.shape[0], wp.shape[1]
    grid = (mp // tm, np_ // tn)
    out = pl.pallas_call(
        functools.partial(_dense_act_kernel, act=act),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, tn), lambda i, j: (0, j)),
            pl.BlockSpec((1, tn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((tm, tn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), x.dtype),
        interpret=True,
    )(xp, wp, bp)
    return out[:m, :n]


def matmul(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Tiled Pallas matmul (no bias/activation) — backward-pass workhorse."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    tm, tn = _tile(m, TILE_M), _tile(n, TILE_N)
    ap = _pad_to(a, 0, tm)
    bp = _pad_to(b, 1, tn)
    mp, np_ = ap.shape[0], bp.shape[1]
    grid = (mp // tm, np_ // tn)
    out = pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, tn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((tm, tn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), a.dtype),
        interpret=True,
    )(ap, bp)
    return out[:m, :n]


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def dense_act(x, w, b, act: str = "tanh"):
    """Fused ``act(x @ w + b)`` with a hand-written VJP.

    Args:
      x: (M, K) activations.
      w: (K, N) weights.
      b: (N,) bias.
      act: "tanh" | "sigmoid" | "linear".

    The custom VJP exists because ``pallas_call`` has no general reverse rule;
    writing it by hand also lets the backward matmuls reuse the same tiled
    kernel (see ``matmul``), keeping the whole train-step HLO kernel-pure.
    """
    return _dense_act_fwd_impl(x, w, b, act)


def _dense_act_fwd(x, w, b, act: str):
    out = _dense_act_fwd_impl(x, w, b, act)
    return out, (x, w, out)


def _dense_act_bwd(act: str, res, g):
    x, w, out = res
    if act == "tanh":
        gpre = g * (1.0 - out * out)
    elif act == "sigmoid":
        gpre = g * out * (1.0 - out)
    else:
        gpre = g
    dx = matmul(gpre, w.T)
    dw = matmul(x.T, gpre)
    db = jnp.sum(gpre, axis=0)
    return dx, dw, db


dense_act.defvjp(_dense_act_fwd, _dense_act_bwd)


def mlp(x: jnp.ndarray, layers: Tuple[Tuple[jnp.ndarray, jnp.ndarray, str], ...]):
    """Chain of fused dense_act layers: ``layers = ((w, b, act), ...)``."""
    for w, b, act in layers:
        x = dense_act(x, w, b, act)
    return x
