"""Pallas kernel for the RK stage combination + embedded error estimate.

Per step *attempt* the solver must form (paper Eq. 3 + the input to Eq. 5):

    z_new = z + h * sum_i b_i      * k_i
    err   =     h * sum_i btilde_i * k_i

Naively this is 2S reads of the state-sized stage arrays; fusing both
reductions into one kernel streams the stacked stages HBM->VMEM exactly once
and emits both outputs from the same accumulator pass (a pure VPU kernel —
DESIGN.md §Hardware-Adaptation).  The tableau weights are compile-time
constants baked into the kernel, so no weight traffic at all.

The operation is linear in ``(ks, z, h)``; the hand-written VJP below is the
exact transpose and deliberately keeps ``h`` differentiable — the paper's
regularizer R_E = sum_j E_j*|h_j| (Eq. 9) needs d(loss)/dh_j.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_B = 128


def _pad_rows(x: jnp.ndarray, axis: int, mult: int) -> jnp.ndarray:
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _combine_kernel(ks_ref, z_ref, h_ref, znew_ref, err_ref, *, b, btilde):
    """One (TILE_B, D) tile: both weighted stage reductions in one pass."""
    h = h_ref[0, 0]
    z = z_ref[...]
    acc_b = jnp.zeros_like(z)
    acc_bt = jnp.zeros_like(z)
    # S is a small static constant (4 or 7): unrolled python loop, each stage
    # slab is read from VMEM exactly once and feeds both accumulators.
    for i in range(len(b)):
        k = ks_ref[i, :, :]
        if b[i] != 0.0:
            acc_b = acc_b + b[i] * k
        if btilde[i] != 0.0:
            acc_bt = acc_bt + btilde[i] * k
    znew_ref[...] = z + h * acc_b
    err_ref[...] = h * acc_bt


def _combine_impl(ks, z, h, b: Tuple[float, ...], btilde: Tuple[float, ...]):
    s, m, d = ks.shape
    # Adaptive batch tile (see fused_dense._tile / EXPERIMENTS.md §Perf):
    # fixed 128-row tiles quadruple the work for the B=32 testbed batches.
    tb = TILE_B if m >= TILE_B else -(-m // 8) * 8
    ksp = _pad_rows(ks, 1, tb)
    zp = _pad_rows(z, 0, tb)
    mp = zp.shape[0]
    h2 = jnp.asarray(h, dtype=z.dtype).reshape(1, 1)
    grid = (mp // tb,)
    znew, err = pl.pallas_call(
        functools.partial(_combine_kernel, b=b, btilde=btilde),
        grid=grid,
        in_specs=[
            pl.BlockSpec((s, tb, d), lambda i: (0, i, 0)),
            pl.BlockSpec((tb, d), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((tb, d), lambda i: (i, 0)),
            pl.BlockSpec((tb, d), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((mp, d), z.dtype),
            jax.ShapeDtypeStruct((mp, d), z.dtype),
        ],
        interpret=True,
    )(ksp, zp, h2)
    return znew[:m], err[:m]


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def rk_combine(ks, z, h, b: Tuple[float, ...], btilde: Tuple[float, ...]):
    """Fused ``(z + h*sum b_i k_i, h*sum btilde_i k_i)``.

    Args:
      ks: (S, B, D) stacked stages.
      z:  (B, D) current state.
      h:  scalar step size (differentiable).
      b / btilde: static tableau weight tuples (baked into the kernel).
    """
    return _combine_impl(ks, z, h, b, btilde)


def _combine_fwd(ks, z, h, b, btilde):
    out = _combine_impl(ks, z, h, b, btilde)
    return out, (ks, h)


def _combine_bwd(b, btilde, res, g):
    ks, h = res
    g_znew, g_err = g
    bv = jnp.asarray(b, dtype=ks.dtype).reshape(-1, 1, 1)
    btv = jnp.asarray(btilde, dtype=ks.dtype).reshape(-1, 1, 1)
    # Exact transpose of the linear map.
    d_ks = h * (bv * g_znew[None] + btv * g_err[None])
    d_z = g_znew
    d_h = jnp.sum(jnp.sum(bv * ks, axis=0) * g_znew) + jnp.sum(
        jnp.sum(btv * ks, axis=0) * g_err
    )
    return d_ks, d_z, jnp.asarray(d_h, dtype=h.dtype if hasattr(h, "dtype") else jnp.float32)


rk_combine.defvjp(_combine_fwd, _combine_bwd)
