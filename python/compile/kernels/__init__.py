"""Layer-1 Pallas kernels for regnde.

The compute hot-spot of every experiment in the paper is (a) the dynamics-MLP
evaluated once per RK stage and (b) the stage linear-combination + embedded
error estimate evaluated once per step attempt.  Both are implemented as
Pallas kernels (``interpret=True`` on this CPU image — real-TPU lowering
emits Mosaic custom-calls the CPU PJRT plugin cannot execute) and wrapped in
``jax.custom_vjp`` so the discrete adjoint (paper §3.2) flows through them.

``ref.py`` holds the pure-jnp oracles used by the pytest/hypothesis sweeps.
"""
from .fused_dense import dense_act
from .rk_combine import rk_combine
from . import ref

__all__ = ["dense_act", "rk_combine", "ref"]
