"""In-graph optimizers over flat parameter vectors.

The paper trains with Momentum (MNIST NODE), Adamax (Physionet Latent ODE),
AdaBelief (spiral NSDE) and Adam (MNIST NSDE), each with an inverse learning
rate decay applied per iteration.  We implement all four *inside* the lowered
HLO so a single artifact execution performs forward + backward + update and
the Rust coordinator only shuttles flat f32 state vectors.

State layout (manifest-visible): ``state = concat(slot_0, ..., slot_{k-1},
[step])`` where each slot has the size of the parameter vector and ``step``
is a single f32 iteration counter.  ``state_size(P) = slots * P + 1``.

The learning rate is an artifact *input*: the inverse decay
``lr_t = lr0 / (1 + decay * iter)`` (Flux.jl's ``InvDecay``) is applied by
the Rust coordinator (rust/src/coordinator/schedule.rs), keeping schedule
policy at L3 where the paper's annealing logic lives.
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Tuple

import jax.numpy as jnp

Array = jnp.ndarray


class Optimizer(NamedTuple):
    """A flat-vector optimizer: ``update(params, grad, state, lr)``."""

    name: str
    slots: int
    update: Callable[[Array, Array, Array, Array], Tuple[Array, Array]]

    def state_size(self, n_params: int) -> int:
        return self.slots * n_params + 1

    def init_state(self, n_params: int) -> Array:
        return jnp.zeros((self.state_size(n_params),), jnp.float32)


def _split(state: Array, n: int, slots: int):
    parts = [state[i * n : (i + 1) * n] for i in range(slots)]
    step = state[slots * n]
    return parts, step


def _join(parts, step) -> Array:
    return jnp.concatenate([jnp.concatenate(parts), jnp.reshape(step, (1,))])


def sgd_momentum(mass: float = 0.9) -> Optimizer:
    """Flux.jl `Momentum`: v <- mass*v + lr*g ; p <- p - v (paper §4.1.1)."""

    def update(p, g, state, lr):
        (v,), step = _split(state, p.shape[0], 1)
        v = mass * v + lr * g
        return p - v, _join([v], step + 1.0)

    return Optimizer("momentum", 1, update)


def adam(b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8) -> Optimizer:
    """Adam (Kingma & Ba 2014) — paper §4.2.2 (MNIST NSDE)."""

    def update(p, g, state, lr):
        (m, v), step = _split(state, p.shape[0], 2)
        step = step + 1.0
        m = b1 * m + (1.0 - b1) * g
        v = b2 * v + (1.0 - b2) * g * g
        mhat = m / (1.0 - b1**step)
        vhat = v / (1.0 - b2**step)
        return p - lr * mhat / (jnp.sqrt(vhat) + eps), _join([m, v], step)

    return Optimizer("adam", 2, update)


def adamax(b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8) -> Optimizer:
    """Adamax (infinity-norm Adam) — paper §4.1.2 (Physionet Latent ODE)."""

    def update(p, g, state, lr):
        (m, u), step = _split(state, p.shape[0], 2)
        step = step + 1.0
        m = b1 * m + (1.0 - b1) * g
        u = jnp.maximum(b2 * u, jnp.abs(g))
        return p - lr / (1.0 - b1**step) * m / (u + eps), _join([m, u], step)

    return Optimizer("adamax", 2, update)


def adabelief(b1: float = 0.9, b2: float = 0.999, eps: float = 1e-16) -> Optimizer:
    """AdaBelief (Zhuang et al. 2020) — paper §4.2.1 (spiral NSDE)."""

    def update(p, g, state, lr):
        (m, s), step = _split(state, p.shape[0], 2)
        step = step + 1.0
        m = b1 * m + (1.0 - b1) * g
        diff = g - m
        s = b2 * s + (1.0 - b2) * diff * diff + eps
        mhat = m / (1.0 - b1**step)
        shat = s / (1.0 - b2**step)
        return p - lr * mhat / (jnp.sqrt(shat) + eps), _join([m, s], step)

    return Optimizer("adabelief", 2, update)


_REGISTRY = {
    "momentum": sgd_momentum,
    "adam": adam,
    "adamax": adamax,
    "adabelief": adabelief,
}


def get(name: str, **kwargs) -> Optimizer:
    """Look up an optimizer factory by name."""
    try:
        return _REGISTRY[name](**kwargs)
    except KeyError:
        raise KeyError(f"unknown optimizer {name!r}; have {sorted(_REGISTRY)}")
