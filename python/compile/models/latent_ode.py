"""Latent ODE for irregular time-series interpolation — paper §4.1.2
(Table 2, Figure 4; Physionet 2012).

Encoder-decoder as in Rubanova et al. (2019): a GRU recognition network runs
*backwards* over the (value, mask) sequence to produce q(z0 | x) = N(mu,
sigma); a latent trajectory is decoded from a sampled z0 by the adaptive
Tsit5 solve saving at every observation time; a linear decoder maps latent
states to observation space.  Loss = masked Gaussian NLL + KL-annealed
KL(q || N(0, I)) + the white-boxed solver regularizers.

Dimensions follow the paper: 20-d latent state, 40-d recognition hidden
state, dynamics = 4-layer MLP with 50 tanh units.  The observation grid
``ts`` is an artifact input: the Rust data pipeline places each batch on a
shared union grid with per-sample masks (physionet_synth.rs), and the STEER
baseline perturbs interior grid points at L3 (paper §4.1.2 baseline).
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from .. import optimizers, solver, tableaus
from ..kernels import dense_act
from ..packing import ParamSpec
from ..regularizers import taylor_reg_fn
from .common import metrics_vector, prng_from_seed

CHANNELS = 8
LATENT = 20
REC_HIDDEN = 40
DYN_HIDDEN = 50
OBS_SIGMA = 0.05  # fixed observation noise for the Gaussian likelihood

_IN = 2 * CHANNELS  # (value, mask) per channel

SPEC = ParamSpec(
    [
        # GRU recognition cell (input = [x, mask], hidden = REC_HIDDEN)
        ("Wz", (_IN + REC_HIDDEN, REC_HIDDEN)),
        ("bz", (REC_HIDDEN,)),
        ("Wr", (_IN + REC_HIDDEN, REC_HIDDEN)),
        ("br", (REC_HIDDEN,)),
        ("Wh", (_IN + REC_HIDDEN, REC_HIDDEN)),
        ("bh", (REC_HIDDEN,)),
        # hidden -> (mu, logvar)
        ("Wq", (REC_HIDDEN, 2 * LATENT)),
        ("bq", (2 * LATENT,)),
        # latent dynamics: 4-layer tanh MLP, 50 units (paper §4.1.2)
        ("D1", (LATENT, DYN_HIDDEN)),
        ("d1", (DYN_HIDDEN,)),
        ("D2", (DYN_HIDDEN, DYN_HIDDEN)),
        ("d2", (DYN_HIDDEN,)),
        ("D3", (DYN_HIDDEN, DYN_HIDDEN)),
        ("d3", (DYN_HIDDEN,)),
        ("D4", (DYN_HIDDEN, LATENT)),
        ("d4", (LATENT,)),
        # linear decoder latent -> observation space
        ("Wd", (LATENT, CHANNELS)),
        ("bd", (CHANNELS,)),
    ]
)

OPT = optimizers.adamax()


class Config(NamedTuple):
    batch: int = 64
    t_points: int = 16
    rtol: float = 1e-4
    atol: float = 1e-4
    steps_per_segment: int = 6
    tableau: str = "tsit5"
    use_kernels: bool = True
    taylor_order: int = 0  # 2 = the paper's TayNODE baseline for this task


def init_fn(seed):
    return SPEC.init(jax.random.PRNGKey(seed))


def _gru_encode(p, x, mask):
    """Run the GRU backwards over time; returns (mu, logvar) of q(z0)."""
    b = x.shape[0]
    inputs = jnp.concatenate([x, mask], axis=-1)  # (B, T, 2D)
    inputs = jnp.flip(inputs, axis=1)  # reverse time

    def cell(h, u):
        hu = jnp.concatenate([u, h], axis=-1)
        zg = jax.nn.sigmoid(hu @ p["Wz"] + p["bz"])
        rg = jax.nn.sigmoid(hu @ p["Wr"] + p["br"])
        hru = jnp.concatenate([u, rg * h], axis=-1)
        cand = jnp.tanh(hru @ p["Wh"] + p["bh"])
        return (1.0 - zg) * h + zg * cand, None

    h0 = jnp.zeros((b, REC_HIDDEN), x.dtype)
    hT, _ = jax.lax.scan(cell, h0, jnp.swapaxes(inputs, 0, 1))
    q = hT @ p["Wq"] + p["bq"]
    return q[:, :LATENT], q[:, LATENT:]


def dynamics(p, use_kernels: bool) -> Callable:
    """4-layer tanh MLP latent dynamics (autonomous)."""

    def f(z, t):
        del t
        if use_kernels:
            h = dense_act(z, p["D1"], p["d1"], "tanh")
            h = dense_act(h, p["D2"], p["d2"], "tanh")
            h = dense_act(h, p["D3"], p["d3"], "tanh")
            return dense_act(h, p["D4"], p["d4"], "linear")
        h = jnp.tanh(z @ p["D1"] + p["d1"])
        h = jnp.tanh(h @ p["D2"] + p["d2"])
        h = jnp.tanh(h @ p["D3"] + p["d3"])
        return h @ p["D4"] + p["d4"]

    return f


def _decode(p, zs):
    return zs @ p["Wd"] + p["bd"]  # (T, B, D)


def _forward(params, x, mask, ts, seed, cfg: Config, predict: bool):
    p = SPEC.unpack(params)
    mu, logvar = _gru_encode(p, x, mask)
    key = prng_from_seed(seed)
    eps = jax.random.normal(key, mu.shape, mu.dtype)
    z0 = mu + jnp.exp(0.5 * logvar) * eps
    f = dynamics(p, cfg.use_kernels)
    tab = tableaus.get(cfg.tableau)
    aux_fn = None
    if cfg.taylor_order >= 2 and not predict:
        # jet cannot trace custom_vjp (Pallas) calls — use the jnp dynamics.
        aux_fn = taylor_reg_fn(dynamics(p, False), cfg.taylor_order)
    if predict:
        zs, stats = solver.odeint_save_while(
            f, z0, ts, tab=tab, rtol=cfg.rtol, atol=cfg.atol,
            use_kernels=cfg.use_kernels,
        )
    else:
        zs, stats = solver.odeint_save_scan(
            f, z0, ts, tab=tab, rtol=cfg.rtol, atol=cfg.atol,
            steps_per_segment=cfg.steps_per_segment,
            use_kernels=cfg.use_kernels, aux_fn=aux_fn,
        )
    xhat = _decode(p, zs)  # (T, B, D)
    xhat = jnp.swapaxes(xhat, 0, 1)  # (B, T, D)
    return xhat, mu, logvar, stats


def _nll_kl_mse(x, mask, xhat, mu, logvar):
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    se = mask * jnp.square(x - xhat)
    mse = jnp.sum(se) / denom
    nll = 0.5 * jnp.sum(se / (OBS_SIGMA**2)) / denom
    kl = -0.5 * jnp.mean(
        jnp.sum(1.0 + logvar - jnp.square(mu) - jnp.exp(logvar), axis=-1)
    )
    return nll, kl, mse


def make_train_step(cfg: Config):
    """(params, opt_state, x, mask, ts, lr, coef_e, coef_s, coef_aux,
    kl_coef, seed) -> (params', opt_state', metrics[9]); metric = masked MSE."""

    def loss_fn(params, x, mask, ts, coef_e, coef_s, coef_aux, kl_coef, seed):
        xhat, mu, logvar, stats = _forward(
            params, x, mask, ts, seed, cfg, predict=False
        )
        nll, kl, mse = _nll_kl_mse(x, mask, xhat, mu, logvar)
        reg = coef_e * stats.r_e + coef_s * stats.r_s + coef_aux * stats.r_aux
        return nll + kl_coef * kl + reg, (nll + kl_coef * kl, mse, stats)

    def step(params, opt_state, x, mask, ts, lr, coef_e, coef_s, coef_aux,
             kl_coef, seed):
        (_, (task, mse, stats)), grads = jax.value_and_grad(
            loss_fn, has_aux=True
        )(params, x, mask, ts, coef_e, coef_s, coef_aux, kl_coef, seed)
        new_params, new_state = OPT.update(params, grads, opt_state, lr)
        return new_params, new_state, metrics_vector(task, mse, stats)

    return step


def make_predict(cfg: Config):
    """(params, x, mask, ts, seed) -> (xhat, metrics[9]); metric = MSE."""

    def predict(params, x, mask, ts, seed):
        xhat, mu, logvar, stats = _forward(
            params, x, mask, ts, seed, cfg, predict=True
        )
        nll, kl, mse = _nll_kl_mse(x, mask, xhat, mu, logvar)
        return xhat, metrics_vector(nll + kl, mse, stats)

    return predict
