"""Shared pieces for model train/predict step builders."""
from __future__ import annotations

import jax
import jax.numpy as jnp

METRICS_LAYOUT = [
    "loss",
    "metric",
    "nfe",
    "naccept",
    "nreject",
    "success",
    "r_e",
    "r_s",
    "r_aux",
]


def metrics_vector(loss, metric, stats) -> jnp.ndarray:
    """Assemble the standard 9-element metric vector (see METRICS_LAYOUT)."""
    return jnp.stack(
        [
            jnp.asarray(loss, jnp.float32),
            jnp.asarray(metric, jnp.float32),
            stats.nfe,
            stats.naccept,
            stats.nreject,
            stats.success,
            stats.r_e,
            stats.r_s,
            stats.r_aux,
        ]
    )


def softmax_xent(logits: jnp.ndarray, y_onehot: jnp.ndarray) -> jnp.ndarray:
    """Mean softmax cross-entropy (numerically stable)."""
    logz = jax.nn.logsumexp(logits, axis=-1, keepdims=True)
    return -jnp.mean(jnp.sum(y_onehot * (logits - logz), axis=-1))


def accuracy(logits: jnp.ndarray, y_onehot: jnp.ndarray) -> jnp.ndarray:
    return jnp.mean(
        (jnp.argmax(logits, -1) == jnp.argmax(y_onehot, -1)).astype(jnp.float32)
    )


def prng_from_seed(seed: jnp.ndarray) -> jnp.ndarray:
    """Build a PRNG key from a u32 scalar artifact input."""
    return jax.random.PRNGKey(seed)
