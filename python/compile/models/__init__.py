"""Layer-2 model definitions — one module per paper experiment.

Every model module exposes:

  * ``SPEC`` — the ParamSpec of its flat parameter vector,
  * ``OPT`` — the paper's optimizer for that experiment,
  * ``Config`` — static lowering configuration (batch, tolerances, budgets),
  * ``init_fn(seed)`` — parameter initialization (lowered to an HLO artifact
    so the Rust coordinator can initialize any replica seed on-device),
  * ``make_train_step(cfg)`` — full fwd+bwd+optimizer-update step,
  * ``make_predict(cfg)`` — early-exiting inference path.

Standard metric vector returned by every step: see ``common.METRICS_LAYOUT``.
"""
from .common import METRICS_LAYOUT, metrics_vector
from . import mnist_node, latent_ode, spiral_node, spiral_nsde, mnist_nsde

__all__ = [
    "METRICS_LAYOUT",
    "metrics_vector",
    "mnist_node",
    "latent_ode",
    "spiral_node",
    "spiral_nsde",
    "mnist_nsde",
]
