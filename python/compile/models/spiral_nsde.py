"""Spiral Neural SDE — paper §4.2.1 (Table 3, Figure 5).

Fit a neural SDE to trajectories of the spiral diagonal-noise SDE
(paper Eq. 15):

    du1 = -a u1^3 dt + b u2^3 dt + c u1 dW
    du2 = -b u1^3 dt - a u2^3 dt + c u2 dW      a=0.1, b=2.0, c=0.2

Drift/diffusion parameterization (paper Eq. 16):

    f(x) = W2 tanh(W1 x^3 + B1) + B2     (2 -> 50 -> 2)
    g(x) = W3 x + B3                     (2 -> 2, diagonal noise)

Training uses the generalized method of moments loss (paper Eq. 17): the L2
distance between per-save-point mean/variance of the predicted trajectory
ensemble and the data ensemble.  Ground-truth moments are produced by the
native Rust SDE solver over 10k trajectories (rust/src/data/spiral.rs).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .. import optimizers, sde_solver
from ..packing import ParamSpec
from .common import metrics_vector, prng_from_seed

DIM = 2
HIDDEN = 50

SPEC = ParamSpec(
    [
        ("W1", (DIM, HIDDEN)),
        ("B1", (HIDDEN,)),
        ("W2", (HIDDEN, DIM)),
        ("B2", (DIM,)),
        ("W3", (DIM, DIM)),
        ("B3", (DIM,)),
    ]
)

OPT = optimizers.adabelief()


class Config(NamedTuple):
    n_traj: int = 64  # predicted ensemble size per iteration (paper: 100)
    t_points: int = 30
    rtol: float = 1e-2
    atol: float = 1e-2
    steps_per_segment: int = 6


def init_fn(seed):
    return SPEC.init(jax.random.PRNGKey(seed))


def drift_diffusion(p):
    def f(z, t):
        del t
        return jnp.tanh(jnp.power(z, 3) @ p["W1"] + p["B1"]) @ p["W2"] + p["B2"]

    def g(z, t):
        del t
        return z @ p["W3"] + p["B3"]

    return f, g


def _forward(params, u0, ts, seed, cfg: Config, predict: bool):
    p = SPEC.unpack(params)
    f, g = drift_diffusion(p)
    key = prng_from_seed(seed)
    if predict:
        zs, stats = sde_solver.sdeint_save_while(
            f, g, u0, ts, key, rtol=cfg.rtol, atol=cfg.atol
        )
    else:
        zs, stats = sde_solver.sdeint_save_scan(
            f, g, u0, ts, key, rtol=cfg.rtol, atol=cfg.atol,
            steps_per_segment=cfg.steps_per_segment,
        )
    return zs, stats  # (T, N, 2)


def _gmm_loss(zs, data_mu, data_var):
    """Paper Eq. 17 — match ensemble mean and variance per save point."""
    mu = jnp.mean(zs, axis=1)
    var = jnp.var(zs, axis=1)
    return jnp.sum(jnp.square(mu - data_mu) + jnp.square(var - data_var))


def make_train_step(cfg: Config):
    """(params, opt_state, u0, data_mu, data_var, ts, lr, coef_e, coef_s,
    seed) -> (params', opt_state', metrics[9]); metric = GMM loss."""

    def loss_fn(params, u0, data_mu, data_var, ts, coef_e, coef_s, seed):
        zs, stats = _forward(params, u0, ts, seed, cfg, predict=False)
        gmm = _gmm_loss(zs, data_mu, data_var)
        return gmm + coef_e * stats.r_e + coef_s * stats.r_s, (gmm, stats)

    def step(params, opt_state, u0, data_mu, data_var, ts, lr, coef_e,
             coef_s, seed):
        (_, (gmm, stats)), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, u0, data_mu, data_var, ts, coef_e, coef_s, seed
        )
        new_params, new_state = OPT.update(params, grads, opt_state, lr)
        return new_params, new_state, metrics_vector(gmm, gmm, stats)

    return step


def make_predict(cfg: Config):
    """(params, u0, data_mu, data_var, ts, seed) -> (zs, metrics[9])."""

    def predict(params, u0, data_mu, data_var, ts, seed):
        zs, stats = _forward(params, u0, ts, seed, cfg, predict=True)
        gmm = _gmm_loss(zs, data_mu, data_var)
        return zs, metrics_vector(gmm, gmm, stats)

    return predict
