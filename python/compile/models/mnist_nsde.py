"""MNIST Neural SDE classifier — paper §4.2.2 (Table 4, Figure 6).

Architecture (paper Eq. 18-21; shapes follow the text — the drift is the
*linear* map and the diffusion the two-layer MLP, as §4.2.2 states):

    a(x)  = W1 x + B1            784 -> 32   (input embedding)
    f(x)  = W3 tanh(W2 x + B2)+B3  32 -> 64 -> 32   (diffusion MLP)
    g(x)  = W4 x + B4            32 -> 32   (drift, linear)
    b(x)  = W5 x + B5            32 -> 10   (logit readout)

    dz = g(z) dt + 0.1 * f(z) ∘ dW   over t in [0, 1]

(The extra 0.1 diffusion scale keeps glorot-initialized noise from swamping
the drift at init — DESIGN.md §4 records this as a substitution detail.)
Prediction averages logits over ``predict_traj`` sampled trajectories
(paper: 10).  The diffusion MLP runs on the fused Pallas kernel.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .. import optimizers, sde_solver
from ..kernels import dense_act
from ..packing import ParamSpec
from .common import accuracy, metrics_vector, prng_from_seed, softmax_xent

DIM = 784
STATE = 32
DHID = 64
CLASSES = 10
DIFF_SCALE = 0.1

SPEC = ParamSpec(
    [
        ("W1", (DIM, STATE)),
        ("B1", (STATE,)),
        ("W2", (STATE, DHID)),
        ("B2", (DHID,)),
        ("W3", (DHID, STATE)),
        ("B3", (STATE,)),
        ("W4", (STATE, STATE)),
        ("B4", (STATE,)),
        ("W5", (STATE, CLASSES)),
        ("B5", (CLASSES,)),
    ]
)

OPT = optimizers.adam()


class Config(NamedTuple):
    batch: int = 128
    rtol: float = 1e-3
    atol: float = 1e-3
    max_steps: int = 48
    use_kernels: bool = True
    predict_traj: int = 10


def init_fn(seed):
    return SPEC.init(jax.random.PRNGKey(seed))


def drift_diffusion(p, use_kernels: bool):
    def drift(z, t):
        del t
        return z @ p["W4"] + p["B4"]

    def diffusion(z, t):
        del t
        if use_kernels:
            h = dense_act(z, p["W2"], p["B2"], "tanh")
            return DIFF_SCALE * dense_act(h, p["W3"], p["B3"], "linear")
        h = jnp.tanh(z @ p["W2"] + p["B2"])
        return DIFF_SCALE * (h @ p["W3"] + p["B3"])

    return drift, diffusion


def _embed(p, x):
    return x @ p["W1"] + p["B1"]


def _readout(p, z):
    return z @ p["W5"] + p["B5"]


def make_train_step(cfg: Config):
    """(params, opt_state, x, y, lr, coef_e, coef_s, seed)
    -> (params', opt_state', metrics[9]); metric = accuracy."""

    def loss_fn(params, x, y, coef_e, coef_s, seed):
        p = SPEC.unpack(params)
        f, g = drift_diffusion(p, cfg.use_kernels)
        z0 = _embed(p, x)
        key = prng_from_seed(seed)
        z1, stats = sde_solver.sdeint_scan(
            g, f, z0, 0.0, 1.0, key, rtol=cfg.rtol, atol=cfg.atol,
            max_steps=cfg.max_steps,
        )
        logits = _readout(p, z1)
        task = softmax_xent(logits, y)
        reg = coef_e * stats.r_e + coef_s * stats.r_s
        return task + reg, (task, accuracy(logits, y), stats)

    def step(params, opt_state, x, y, lr, coef_e, coef_s, seed):
        (_, (task, acc, stats)), grads = jax.value_and_grad(
            loss_fn, has_aux=True
        )(params, x, y, coef_e, coef_s, seed)
        new_params, new_state = OPT.update(params, grads, opt_state, lr)
        return new_params, new_state, metrics_vector(task, acc, stats)

    return step


def make_predict(cfg: Config):
    """(params, x, y, seed) -> (logits, metrics[9]).

    Averages logits over ``cfg.predict_traj`` independent driving paths
    (paper: mean logits across 10 trajectories).
    """

    def predict(params, x, y, seed):
        p = SPEC.unpack(params)
        f, g = drift_diffusion(p, cfg.use_kernels)
        z0 = _embed(p, x)
        keys = jax.random.split(prng_from_seed(seed), cfg.predict_traj)

        def one(key):
            z1, stats = sde_solver.sdeint_while(
                g, f, z0, 0.0, 1.0, key, rtol=cfg.rtol, atol=cfg.atol
            )
            return _readout(p, z1), stats

        # scan (not vmap) over trajectories: each solve early-exits on its
        # own NFE, and the stats sum matches the paper's per-prediction NFE.
        def body(carry, key):
            logits_sum, st_acc = carry
            logits, st = one(key)
            return (logits_sum + logits, st_acc.merge(st)), None

        from ..solver import SolveStats

        (logits_sum, stats), _ = jax.lax.scan(
            body, (jnp.zeros((x.shape[0], CLASSES)), SolveStats.zeros()), keys
        )
        logits = logits_sum / float(cfg.predict_traj)
        return logits, metrics_vector(
            softmax_xent(logits, y), accuracy(logits, y), stats
        )

    return predict
