"""MNIST Neural ODE classifier — paper §4.1.1 (Table 1, Figure 3).

Architecture (paper Eq. 12-14), dimension-identical to Kelly et al. (2020):

    z(x, t)   = tanh(W1 [x; t] + B1)          785 -> 100
    f(x, t)   = tanh(W2 [z; t] + B2)          101 -> 784   (ODE dynamics)
    g(x)      = W3 x + B3                     784 -> 10    (linear classifier)

The image is the ODE initial condition; the logits are read off the state at
t = 1.  The dynamics MLP runs on the fused Pallas ``dense_act`` kernel; the
RK stage combination runs on the ``rk_combine`` kernel; both sit inside the
masked-scan adaptive Tsit5 solve, so one lowered train step = forward solve
(+ white-boxed R_E/R_S accumulation) + discrete adjoint + Momentum update.

Train-step inputs expose everything the paper's method grid needs:
``t1`` (STEER samples it around 1.0), ``coef_e``/``coef_s`` (ERNODE/SRNODE,
zero disables), and the TayNODE variant adds the jet-based R_K (Eq. 10).
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from .. import optimizers, solver, tableaus
from ..kernels import dense_act
from ..packing import ParamSpec
from ..regularizers import taylor_reg_fn
from .common import accuracy, metrics_vector, softmax_xent

DIM = 784
HIDDEN = 100
CLASSES = 10

SPEC = ParamSpec(
    [
        ("W1", (DIM + 1, HIDDEN)),
        ("B1", (HIDDEN,)),
        ("W2", (HIDDEN + 1, DIM)),
        ("B2", (DIM,)),
        ("W3", (DIM, CLASSES)),
        ("B3", (CLASSES,)),
    ]
)

OPT = optimizers.sgd_momentum(mass=0.9)


class Config(NamedTuple):
    batch: int = 128
    rtol: float = 1e-4
    atol: float = 1e-4
    max_steps: int = 32
    tableau: str = "tsit5"
    use_kernels: bool = True
    taylor_order: int = 0  # 0 = off; 3 = the paper's TayNODE baseline


def dynamics(p, use_kernels: bool) -> Callable:
    """Paper Eq. 12-13 as a closure over unpacked parameters."""

    def f(z, t):
        b = z.shape[0]
        tcol = jnp.full((b, 1), 1.0, z.dtype) * t
        xt = jnp.concatenate([z, tcol], axis=1)
        if use_kernels:
            h = dense_act(xt, p["W1"], p["B1"], "tanh")
            ht = jnp.concatenate([h, tcol], axis=1)
            return dense_act(ht, p["W2"], p["B2"], "tanh")
        h = jnp.tanh(xt @ p["W1"] + p["B1"])
        ht = jnp.concatenate([h, tcol], axis=1)
        return jnp.tanh(ht @ p["W2"] + p["B2"])

    return f


def init_fn(seed):
    return SPEC.init(jax.random.PRNGKey(seed))


def _forward(params, x, t1, cfg: Config, predict: bool):
    p = SPEC.unpack(params)
    f = dynamics(p, cfg.use_kernels)
    tab = tableaus.get(cfg.tableau)
    aux_fn = None
    if cfg.taylor_order >= 2 and not predict:
        # jet (Taylor-mode AD) has no rule for custom_vjp primitives, so the
        # TayNODE regularizer differentiates the pure-jnp dynamics — same
        # math, and faithful to the reference TayNODE implementation.
        aux_fn = taylor_reg_fn(dynamics(p, False), cfg.taylor_order)
    if predict:
        z1, stats = solver.odeint_while(
            f, x, 0.0, t1, tab=tab, rtol=cfg.rtol, atol=cfg.atol,
            use_kernels=cfg.use_kernels,
        )
    else:
        z1, stats = solver.odeint_scan(
            f, x, 0.0, t1, tab=tab, rtol=cfg.rtol, atol=cfg.atol,
            max_steps=cfg.max_steps, use_kernels=cfg.use_kernels, aux_fn=aux_fn,
        )
    logits = z1 @ p["W3"] + p["B3"]
    return logits, stats


def make_train_step(cfg: Config):
    """(params, opt_state, x, y, lr, coef_e, coef_s, coef_aux, t1)
    -> (params', opt_state', metrics[9])."""

    def loss_fn(params, x, y, coef_e, coef_s, coef_aux, t1):
        logits, stats = _forward(params, x, t1, cfg, predict=False)
        task = softmax_xent(logits, y)
        reg = coef_e * stats.r_e + coef_s * stats.r_s + coef_aux * stats.r_aux
        return task + reg, (task, accuracy(logits, y), stats)

    def step(params, opt_state, x, y, lr, coef_e, coef_s, coef_aux, t1):
        (_, (task, acc, stats)), grads = jax.value_and_grad(
            loss_fn, has_aux=True
        )(params, x, y, coef_e, coef_s, coef_aux, t1)
        new_params, new_state = OPT.update(params, grads, opt_state, lr)
        return new_params, new_state, metrics_vector(task, acc, stats)

    return step


def make_predict(cfg: Config):
    """(params, x, y) -> (logits, metrics[9]); metric = accuracy."""

    def predict(params, x, y):
        logits, stats = _forward(params, x, jnp.float32(1.0), cfg, predict=True)
        return logits, metrics_vector(
            softmax_xent(logits, y), accuracy(logits, y), stats
        )

    return predict
