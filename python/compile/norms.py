"""Error norms and step-size controllers shared by the ODE and SDE solvers.

Implements the tolerance-scaled error ratio of paper Eq. 5 and the PI
step-size controller of paper Eq. 6 (Wanner & Hairer 1996, §IV.2).
"""
from __future__ import annotations

import jax.numpy as jnp

# Controller constants (OrdinaryDiffEq.jl defaults for explicit RK).
SAFETY = 0.9
MIN_FACTOR = 0.2
MAX_FACTOR = 10.0
# PI gains: q^alpha uses the current error ratio, q_{n-1}^beta the previous
# one (paper Eq. 6).  beta > 0 damps oscillation of h.
PI_BETA = 0.04


def hairer_norm(x: jnp.ndarray) -> jnp.ndarray:
    """RMS norm over all elements — the norm used for adaptivity in Hairer.

    The tiny epsilon inside the sqrt keeps the reverse-mode derivative finite
    at ``x == 0``: masked-out (``done``) solver iterations still trace this
    computation with zero-sized errors, and ``d sqrt(0)`` would poison the
    whole discrete adjoint with NaNs even though the forward value is masked.
    """
    return jnp.sqrt(jnp.mean(jnp.square(x)) + 1e-30)


def error_ratio(e: jnp.ndarray, z0: jnp.ndarray, z1: jnp.ndarray, rtol, atol):
    """Paper Eq. 5: scaled error ratio q; the step is accepted iff q <= 1."""
    scale = atol + jnp.maximum(jnp.abs(z0), jnp.abs(z1)) * rtol
    return hairer_norm(e / scale)


def pi_step_factor(q: jnp.ndarray, q_prev: jnp.ndarray, order: int) -> jnp.ndarray:
    """PI controller growth factor for the next step size (paper Eq. 6).

    ``h_new = h * clip(safety * q^-alpha * q_prev^beta)`` with
    ``alpha = 1/order - 0.75*beta`` (Hairer's recommended gain split).
    """
    alpha = 1.0 / order - 0.75 * PI_BETA
    qc = jnp.maximum(q, 1e-10)
    qp = jnp.maximum(q_prev, 1e-10)
    factor = SAFETY * qc ** (-alpha) * qp ** PI_BETA
    return jnp.clip(factor, MIN_FACTOR, MAX_FACTOR)


def reject_step_factor(q: jnp.ndarray, order: int) -> jnp.ndarray:
    """Shrink factor after a rejected step (plain P-control, no growth)."""
    alpha = 1.0 / order
    factor = SAFETY * jnp.maximum(q, 1e-10) ** (-alpha)
    return jnp.clip(factor, MIN_FACTOR, 1.0)


def initial_step_size(f0: jnp.ndarray, z0: jnp.ndarray, t_span: float, rtol, atol):
    """Cheap h0 heuristic: a small fraction of the span scaled by |f0|.

    A full Hairer h0 selector costs two extra NFE; since train-time solves
    re-run thousands of times with similar dynamics we use the conservative
    `0.01 * span / max(1, |f0|_rms)` rule and let the PI controller adapt.
    """
    del rtol, atol
    fnorm = hairer_norm(f0)
    return 0.01 * t_span / jnp.maximum(1.0, fnorm)
