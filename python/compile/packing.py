"""Flat parameter-vector packing.

The Rust coordinator treats model parameters and optimizer state as opaque
``f32[P]`` vectors (runtime/state.rs); this module defines the layout.  Each
model declares an ordered ``ParamSpec`` of named tensors; ``pack``/``unpack``
convert between the flat vector and a name->tensor dict.  The layout (name,
offset, shape) is exported into ``artifacts/manifest.json`` so external tools
can introspect checkpoints.
"""
from __future__ import annotations

from typing import Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Array = jnp.ndarray


class ParamSpec:
    """Ordered collection of named parameter tensors with a flat layout."""

    def __init__(self, entries: Sequence[Tuple[str, Tuple[int, ...]]]):
        self.entries: List[Tuple[str, Tuple[int, ...]]] = [
            (name, tuple(shape)) for name, shape in entries
        ]
        self.offsets: Dict[str, int] = {}
        off = 0
        for name, shape in self.entries:
            self.offsets[name] = off
            off += int(np.prod(shape))
        self.size = off

    def unpack(self, flat: Array) -> Dict[str, Array]:
        out = {}
        for name, shape in self.entries:
            off = self.offsets[name]
            n = int(np.prod(shape))
            out[name] = jnp.reshape(flat[off : off + n], shape)
        return out

    def pack(self, tensors: Dict[str, Array]) -> Array:
        parts = []
        for name, shape in self.entries:
            t = tensors[name]
            assert tuple(t.shape) == shape, (name, t.shape, shape)
            parts.append(jnp.ravel(t))
        return jnp.concatenate(parts)

    def init(self, key: Array) -> Array:
        """Glorot-uniform weights / zero biases (Flux.jl Dense defaults).

        A tensor is treated as a bias iff it is 1-D.
        """
        parts = []
        for name, shape in self.entries:
            key, sub = jax.random.split(key)
            if len(shape) == 1:
                parts.append(jnp.zeros(shape, jnp.float32).ravel())
            else:
                fan_in, fan_out = shape[0], shape[-1]
                lim = jnp.sqrt(6.0 / (fan_in + fan_out))
                w = jax.random.uniform(
                    sub, shape, jnp.float32, minval=-lim, maxval=lim
                )
                parts.append(w.ravel())
        return jnp.concatenate(parts)

    def manifest_layout(self) -> List[dict]:
        return [
            {"name": n, "shape": list(s), "offset": self.offsets[n]}
            for n, s in self.entries
        ]
