"""Differentiable adaptive Runge-Kutta solvers with white-boxed heuristics.

This module is the paper's core mechanism.  The adaptive RK loop is written
so that the *internal* solver heuristics — the embedded local error estimate
``E_j`` (paper Eq. 3-5) and the Shampine stiffness estimate ``S_j`` (paper
Eq. 7-8) — are accumulated into regularization terms

    R_E  = sum_j E_j * |h_j|        (paper Eq. 9)
    R_E2 = sum_j E_j^2              (paper §4.1.2 variant)
    R_S  = sum_j S_j                (paper Eq. 11)

as free by-products of the forward solve, and the whole loop is reverse-mode
differentiable: gradients of these terms are the paper's *discrete adjoint*
(§3.2) — automatic differentiation *of the solver*, seeing every stage k_i.

Two execution modes:

  * ``odeint_scan`` / ``odeint_save_scan`` — a **bounded masked scan**: a
    fixed budget of step attempts; a ``done`` mask freezes the carry once
    ``t >= t1``.  Reverse-mode AD works through ``lax.scan``, so this is the
    train-time path.  The fixed budget means train wall-clock does not track
    NFE inside one artifact; the L3 coordinator therefore compiles a *ladder*
    of budgets and routes each batch to the smallest executable whose budget
    covers the recent NFE (rust/src/coordinator/budget.rs) — that is how the
    paper's training-time speedups (Tables 1-2) materialize end-to-end.
  * ``odeint_while`` / ``odeint_save_while`` — a genuine ``lax.while_loop``
    that early-exits; used by the predict artifacts where no gradient is
    needed, so prediction wall-clock directly tracks NFE (Tables 1-4).

The solver state is a flat ``(B, D)`` array: a batch is treated as one large
ODE system with a shared step size, exactly like DiffEqFlux batching.
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax import lax

from . import norms
from .tableaus import Tableau
from .kernels import rk_combine as rk_combine_kernel
from .kernels import ref as kref

Array = jnp.ndarray
EPS = 1e-12


class SolveStats(NamedTuple):
    """White-boxed solver statistics (all f32 scalars, all differentiable
    where meaningful).

    r_e:     paper Eq. 9   regularizer  sum_j E_j |h_j|   (accepted steps)
    r_e2:    paper variant              sum_j E_j^2
    r_s:     paper Eq. 11  regularizer  sum_j S_j
    nfe:     number of dynamics evaluations (DiffEqFlux-style accounting)
    naccept: accepted steps
    nreject: rejected step attempts
    success: 1.0 iff the integration reached t1 within the attempt budget
             (always 1.0 for the while variants)
    r_aux:   optional auxiliary per-step regularizer accumulator — used for
             the TayNODE baseline: sum_j aux(z_j, t_j) * |h_j|, a quadrature
             of Kelly et al.'s R_K = ∫ ||d^K z/dt^K||^2 dt (paper Eq. 10)
    """

    r_e: Array
    r_e2: Array
    r_s: Array
    nfe: Array
    naccept: Array
    nreject: Array
    success: Array
    r_aux: Array

    @staticmethod
    def zeros() -> "SolveStats":
        z = jnp.float32(0.0)
        # success starts at 1.0: segmented solves multiply per-segment
        # completion flags into it.
        return SolveStats(z, z, z, z, z, z, jnp.float32(1.0), z)

    def merge(self, other: "SolveStats") -> "SolveStats":
        return SolveStats(
            self.r_e + other.r_e,
            self.r_e2 + other.r_e2,
            self.r_s + other.r_s,
            self.nfe + other.nfe,
            self.naccept + other.naccept,
            self.nreject + other.nreject,
            self.success * other.success,
            self.r_aux + other.r_aux,
        )


class _Carry(NamedTuple):
    t: Array
    z: Array
    h: Array
    k1: Array  # FSAL stage carried across steps
    q_prev: Array
    done: Array
    stats: SolveStats


def _attempt(f, tab: Tableau, z: Array, t: Array, h: Array, k1: Array, rtol, atol,
             use_kernels: bool):
    """One full stage cascade + error/stiffness estimates for step size h.

    Returns (z_new, k_last, q, e_norm, stiff).
    """
    s = tab.stages
    a = tab.a
    c = tab.c
    ks = [k1]
    g_x = g_y = None
    for i in range(1, s):
        zi = z
        for j in range(i):
            aij = float(a[i, j])
            if aij != 0.0:
                zi = zi + (h * aij) * ks[j]
        if i == tab.stiff_pair[0]:
            g_x = zi
        if i == tab.stiff_pair[1]:
            g_y = zi
        ks.append(f(zi, t + float(c[i]) * h))
    ks_arr = jnp.stack(ks)
    b = tuple(float(v) for v in tab.b)
    btilde = tuple(float(v) for v in tab.btilde)
    combine = rk_combine_kernel if use_kernels else kref.rk_combine
    z_new, err = combine(ks_arr, z, h, b, btilde)

    # Paper Eq. 5 — tolerance-scaled error ratio (accept iff q <= 1).
    q = norms.error_ratio(err, z, z_new, rtol, atol)
    # Unscaled local error magnitude for R_E (paper Eq. 9).
    e_norm = norms.hairer_norm(err)
    # Paper Eq. 8 — Shampine stiffness ratio from the equal-c stage pair.
    ix, iy = tab.stiff_pair
    if ix == 0:
        g_x = z  # stage 0 input is z itself (only taken for an equal-c
        #          pair with ix == 0; bs3 has no equal-c pair, so its
        #          degenerate (3, 3) pair makes the estimate read ~0)
    num = norms.hairer_norm(ks[iy] - ks[ix])
    den = norms.hairer_norm(g_y - g_x) + EPS
    stiff = num / den
    return z_new, ks[-1], q, e_norm, stiff


def _step_once(f, tab, rtol, atol, t1, use_kernels, carry: _Carry,
               aux_fn=None) -> _Carry:
    """One masked accept/reject step attempt (shared by scan and while)."""
    t, z, h, k1, q_prev, done, st = carry
    span_left = t1 - t
    h_eff = jnp.minimum(h, span_left)
    h_eff = jnp.maximum(h_eff, EPS)

    z_new, k_last, q, e_norm, stiff = _attempt(
        f, tab, z, t, h_eff, k1, rtol, atol, use_kernels
    )

    accept = q <= 1.0
    t_acc = t + h_eff
    reached = t_acc >= t1 - 1e-7 * jnp.abs(t1)

    h_grow = h_eff * norms.pi_step_factor(q, q_prev, tab.order)
    h_shrink = h_eff * norms.reject_step_factor(q, tab.order)
    h_next = jnp.where(accept, h_grow, h_shrink)

    step = lambda new, old: jnp.where(done, old, jnp.where(accept, new, old))
    live = (~done).astype(jnp.float32)
    acc_f = live * accept.astype(jnp.float32)
    rej_f = live * (1.0 - accept.astype(jnp.float32))

    r_aux = st.r_aux
    if aux_fn is not None:
        # Quadrature of the auxiliary (TayNODE) regularizer along the
        # accepted trajectory: aux(z_{n+1}, t_{n+1}) * |h| on accept.
        r_aux = r_aux + acc_f * aux_fn(z_new, t_acc) * jnp.abs(h_eff)
    new_stats = SolveStats(
        r_e=st.r_e + acc_f * e_norm * jnp.abs(h_eff),
        r_e2=st.r_e2 + acc_f * e_norm * e_norm,
        r_s=st.r_s + acc_f * stiff,
        nfe=st.nfe + live * float(tab.nfe_per_attempt),
        naccept=st.naccept + acc_f,
        nreject=st.nreject + rej_f,
        success=st.success,
        r_aux=r_aux,
    )
    return _Carry(
        t=step(t_acc, t),
        z=step(z_new, z),
        h=jnp.where(done, h, h_next),
        k1=step(k_last, k1),
        q_prev=step(jnp.maximum(q, 1e-4), q_prev),
        done=done | (accept & reached),
        stats=new_stats,
    )


def _init_carry(f, z0: Array, t0, t1, dt0: Optional[Array]) -> _Carry:
    t0 = jnp.asarray(t0, jnp.float32)
    t1 = jnp.asarray(t1, jnp.float32)
    k1 = f(z0, t0)
    h0 = dt0 if dt0 is not None else norms.initial_step_size(
        k1, z0, t1 - t0, None, None
    )
    st = SolveStats.zeros()
    st = st._replace(nfe=jnp.float32(1.0))  # the initial FSAL k1 evaluation
    return _Carry(
        t=t0,
        z=z0,
        h=jnp.asarray(h0, jnp.float32),
        k1=k1,
        q_prev=jnp.float32(1.0),
        done=jnp.asarray(False),
        stats=st,
    )


def odeint_scan(
    f: Callable[[Array, Array], Array],
    z0: Array,
    t0,
    t1,
    *,
    tab: Tableau,
    rtol: float,
    atol: float,
    max_steps: int,
    dt0: Optional[Array] = None,
    use_kernels: bool = True,
    unroll: int = 1,
    aux_fn=None,
):
    """Differentiable adaptive solve over [t0, t1] with a bounded masked scan.

    Returns ``(z1, stats)``.  ``stats.success`` is 0.0 if the budget of
    ``max_steps`` attempts was exhausted before reaching t1 — the L3
    coordinator watches this output and re-routes the batch to a larger
    budget artifact (budget-ladder routing, DESIGN.md §6).

    ``aux_fn(z, t) -> scalar`` (optional) is accumulated as
    ``stats.r_aux = sum_j aux_fn(z_j, t_j) |h_j|`` — the TayNODE hook.
    """
    t1 = jnp.asarray(t1, jnp.float32)
    carry0 = _init_carry(f, z0, t0, t1, dt0)

    def body(carry, _):
        return _step_once(f, tab, rtol, atol, t1, use_kernels, carry, aux_fn), None

    carry, _ = lax.scan(body, carry0, None, length=max_steps, unroll=unroll)
    stats = carry.stats._replace(success=carry.done.astype(jnp.float32))
    return carry.z, stats


def odeint_while(
    f: Callable[[Array, Array], Array],
    z0: Array,
    t0,
    t1,
    *,
    tab: Tableau,
    rtol: float,
    atol: float,
    max_steps: int = 10_000,
    dt0: Optional[Array] = None,
    use_kernels: bool = True,
):
    """Early-exiting adaptive solve (prediction path; not differentiable).

    Wall-clock genuinely tracks NFE here, which is what the paper's
    prediction-time columns measure.
    """
    t1 = jnp.asarray(t1, jnp.float32)
    carry0 = _init_carry(f, z0, t0, t1, dt0)

    def cond(state):
        carry, i = state
        return (~carry.done) & (i < max_steps)

    def body(state):
        carry, i = state
        return _step_once(f, tab, rtol, atol, t1, use_kernels, carry), i + 1

    carry, _ = lax.while_loop(cond, body, (carry0, jnp.int32(0)))
    stats = carry.stats._replace(success=carry.done.astype(jnp.float32))
    return carry.z, stats


def odeint_save_scan(
    f: Callable[[Array, Array], Array],
    z0: Array,
    ts: Array,
    *,
    tab: Tableau,
    rtol: float,
    atol: float,
    steps_per_segment: int,
    dt0: Optional[Array] = None,
    use_kernels: bool = True,
    aux_fn=None,
):
    """Differentiable solve saving the state at each time in ``ts``.

    ``ts`` is a (T,) strictly-increasing array; the solve is segmented over
    consecutive pairs with the FSAL stage, step size and PI history carried
    across segment boundaries (matching `saveat` semantics of
    OrdinaryDiffEq.jl: hitting save points exactly by step clamping).
    Returns ``(zs, stats)`` with ``zs`` of shape (T, *z0.shape) — note
    ``zs[0] == z0``.
    """
    carry0 = _init_carry(f, z0, ts[0], ts[-1], dt0)

    def segment(carry: _Carry, t_pair):
        t_lo, t_hi = t_pair
        seg = carry._replace(t=t_lo, done=jnp.asarray(False))

        def body(c, _):
            return _step_once(f, tab, rtol, atol, t_hi, use_kernels, c, aux_fn), None

        seg, _ = lax.scan(body, seg, None, length=steps_per_segment)
        seg_stats = seg.stats._replace(
            success=seg.stats.success * seg.done.astype(jnp.float32)
        )
        out = seg._replace(stats=seg_stats)
        return out, seg.z

    carry_f, z_rest = lax.scan(segment, carry0, (ts[:-1], ts[1:]))
    zs = jnp.concatenate([z0[None], z_rest], axis=0)
    stats = carry_f.stats._replace(
        success=(carry_f.stats.success > 0).astype(jnp.float32)
    )
    return zs, stats


def odeint_save_while(
    f: Callable[[Array, Array], Array],
    z0: Array,
    ts: Array,
    *,
    tab: Tableau,
    rtol: float,
    atol: float,
    max_steps_per_segment: int = 10_000,
    dt0: Optional[Array] = None,
    use_kernels: bool = True,
):
    """Early-exiting saveat solve (prediction path for Latent ODE / NSDE)."""
    carry0 = _init_carry(f, z0, ts[0], ts[-1], dt0)

    def segment(carry: _Carry, t_pair):
        t_lo, t_hi = t_pair
        seg0 = carry._replace(t=t_lo, done=jnp.asarray(False))

        def cond(state):
            c, i = state
            return (~c.done) & (i < max_steps_per_segment)

        def body(state):
            c, i = state
            return _step_once(f, tab, rtol, atol, t_hi, use_kernels, c), i + 1

        seg, _ = lax.while_loop(cond, body, (seg0, jnp.int32(0)))
        return seg, seg.z

    carry_f, z_rest = lax.scan(segment, carry0, (ts[:-1], ts[1:]))
    zs = jnp.concatenate([z0[None], z_rest], axis=0)
    stats = carry_f.stats._replace(success=carry_f.done.astype(jnp.float32))
    return zs, stats
