"""Regularization strategies compared in the paper (§2.3, §3, §4 baselines).

* **ERNODE / ERNSDE** — paper Eq. 9: ``R_E = sum_j E_j |h_j|`` from the
  solver's embedded local error estimate.  Free: accumulated by the solver
  itself (solver.py / sde_solver.py); this module only scales it.
* **SRNODE / SRNSDE** — paper Eq. 11: ``R_S = sum_j S_j`` from the Shampine
  stiffness ratio.  Also free.
* **TayNODE** (Kelly et al. 2020) — paper Eq. 10:
  ``R_K = ∫ ||d^K z/dt^K||^2 dt`` computed with Taylor-mode automatic
  differentiation (``jax.experimental.jet``) and quadratured along the
  accepted trajectory via the solver's ``aux_fn`` hook.  Deliberately
  expensive — it is the baseline whose training-time blow-up (7-10x on
  Physionet, Table 2) motivates the paper.
* **STEER** (Behl et al. 2020) — stochastic end time: not a loss term at all;
  the train artifacts expose ``t1`` as an input and the Rust coordinator
  samples ``t1 ~ U(T-b, T+b)`` per iteration (coordinator/steer.rs).
"""
from __future__ import annotations

from typing import Callable

import jax.numpy as jnp
from jax.experimental.jet import jet

Array = jnp.ndarray


def taylor_derivative_coeffs(f, z: Array, t: Array, order: int):
    """Taylor coefficients of the ODE solution through ``(z, t)``.

    Follows Kelly et al.'s `sol_recursive`: time is appended to the state so
    the dynamics become autonomous, then ``jet`` is applied recursively —
    each pass extends the known truncated Taylor series of z(t) by one term.
    Returns the list of series coefficients ``[y1, ..., y_order]`` of the
    *flattened augmented* state (coefficient k is proportional to the
    (k+1)-th time derivative of the solution).
    """
    shape = z.shape
    z_t = jnp.concatenate([jnp.ravel(z), jnp.reshape(t, (1,)).astype(z.dtype)])

    def g(zt):
        zz = jnp.reshape(zt[:-1], shape)
        tt = zt[-1]
        dz = jnp.ravel(f(zz, tt))
        return jnp.concatenate([dz, jnp.ones((1,), zt.dtype)])

    (y0, _) = jet(g, (z_t,), ((jnp.ones_like(z_t),),))
    coeffs = [y0]
    # Each jet pass extends the *valid* prefix of the series by one term
    # (the list grows faster, but trailing entries are not yet converged),
    # so `order` valid coefficients need exactly `order - 1` passes.
    for _ in range(order - 1):
        (y0, yns) = jet(g, (z_t,), (coeffs + [jnp.zeros_like(z_t)],))
        coeffs = [y0] + yns
    return coeffs[:order]


def taylor_reg_fn(f, order: int) -> Callable[[Array, Array], Array]:
    """Build the TayNODE ``aux_fn`` for the solver: z, t -> ||d^K z/dt^K||^2.

    The squared norm of the highest Taylor coefficient (time component
    stripped) approximates the integrand of paper Eq. 10 up to the constant
    ``(K!)^2`` — absorbed into the regularization coefficient, as in the
    reference implementation.
    """
    if order < 2:
        raise ValueError("taylor_reg_fn needs order >= 2")

    def aux(z, t):
        coeffs = taylor_derivative_coeffs(f, z, t, order)
        top = coeffs[order - 1][:-1]  # strip the appended time component
        return jnp.mean(jnp.square(top))

    return aux


def compose_regularization(
    stats, coef_e: Array, coef_s: Array, coef_aux: Array = None,
    error_variant: str = "eh",
) -> Array:
    """Total regularization term added to the task loss.

    ``error_variant``: ``"eh"`` uses R_E = sum E_j |h_j| (paper Eq. 9);
    ``"e2"`` uses the squared variant sum E_j^2 the paper reports as working
    equally well on Physionet with a constant coefficient (§4.1.2).
    """
    r_e = stats.r_e if error_variant == "eh" else stats.r_e2
    total = coef_e * r_e + coef_s * stats.r_s
    if coef_aux is not None:
        total = total + coef_aux * stats.r_aux
    return total
