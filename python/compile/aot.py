"""AOT lowering: JAX train/predict graphs -> HLO text artifacts + manifest.

This is the single entry point of the build-time Python layer:

    cd python && python -m compile.aot --out ../artifacts

For every model it emits
  * ``<model>_init``      — parameter initialization from a u32 seed,
  * ``<model>_train_*``   — fwd + discrete adjoint + optimizer update, one
                            artifact per step-budget rung (the L3 coordinator
                            routes batches across the ladder, DESIGN.md §6),
  * ``<model>_tay_*``     — the TayNODE baseline variant (jet-based R_K),
  * ``<model>_predict``   — early-exiting inference,
plus ``spiral_ode_solve`` (fixed ground-truth dynamics) used by the Rust
test-suite to cross-validate the JAX solver against the native Rust solver.

Interchange format is **HLO text**, not serialized HloModuleProto: jax >= 0.5
emits 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
parser reassigns ids (see /opt/xla-example/README.md).

``manifest.json`` records for each artifact the exact input/output specs
(name, shape, dtype) plus per-model metadata (flat param layout, optimizer
state size, metric vector layout, paper hyper-parameters) — everything the
Rust runtime needs; nothing else crosses the language boundary.
"""
from __future__ import annotations

import argparse
import hashlib
import json
import os
import time
from typing import Callable, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import solver, tableaus
from .models import METRICS_LAYOUT, latent_ode, mnist_node, mnist_nsde, \
    spiral_node, spiral_nsde
from .models import common as model_common

F32 = jnp.float32
U32 = jnp.uint32


def spec(shape, dtype=F32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _dtype_tag(d) -> str:
    return {np.dtype(np.float32): "f32", np.dtype(np.uint32): "u32"}[np.dtype(d)]


class Emitter:
    def __init__(self, out_dir: str, only: Sequence[str]):
        self.out_dir = out_dir
        self.only = list(only)
        self.manifest = {
            "version": 1,
            "metrics_layout": METRICS_LAYOUT,
            "models": {},
            "artifacts": {},
        }
        os.makedirs(out_dir, exist_ok=True)

    def want(self, name: str) -> bool:
        return not self.only or any(o in name for o in self.only)

    def emit(
        self,
        name: str,
        fn: Callable,
        in_specs: List[Tuple[str, jax.ShapeDtypeStruct]],
        *,
        model: str,
        kind: str,
        meta: dict = None,
    ):
        if not self.want(name):
            return
        t0 = time.time()
        lowered = jax.jit(fn, keep_unused=True).lower(*[s for _, s in in_specs])
        out_shapes = jax.eval_shape(fn, *[s for _, s in in_specs])
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(self.out_dir, fname), "w") as f:
            f.write(text)
        leaves = jax.tree_util.tree_leaves(out_shapes)
        self.manifest["artifacts"][name] = {
            "file": fname,
            "model": model,
            "kind": kind,
            "inputs": [
                {"name": n, "shape": list(s.shape), "dtype": _dtype_tag(s.dtype)}
                for n, s in in_specs
            ],
            "outputs": [
                {"shape": list(l.shape), "dtype": _dtype_tag(l.dtype)}
                for l in leaves
            ],
            "meta": meta or {},
            "sha256": hashlib.sha256(text.encode()).hexdigest()[:16],
        }
        print(f"  {name}: {len(text)/1e6:.2f} MB HLO, {time.time()-t0:.1f}s")

    def add_model(self, name: str, module, opt, cfg, hyper: dict):
        self.manifest["models"][name] = {
            "params_size": module.SPEC.size,
            "opt_state_size": opt.state_size(module.SPEC.size),
            "optimizer": opt.name,
            "layout": module.SPEC.manifest_layout(),
            "config": {
                k: (v if not isinstance(v, (np.generic,)) else v.item())
                for k, v in cfg._asdict().items()
            },
            "paper_hyperparams": hyper,
        }

    def save(self):
        path = os.path.join(self.out_dir, "manifest.json")
        with open(path, "w") as f:
            json.dump(self.manifest, f, indent=1, sort_keys=True)
        print(f"wrote {path} ({len(self.manifest['artifacts'])} artifacts)")


# ---------------------------------------------------------------------------
# Per-model emission.  Batch sizes / budgets are scaled for the CPU-PJRT
# testbed (DESIGN.md §4 tolerance/batch substitutions).
# ---------------------------------------------------------------------------

def emit_mnist_node(em: Emitter):
    B = 32
    cfg = mnist_node.Config(batch=B, rtol=1e-6, atol=1e-6, use_kernels=True)
    em.add_model(
        "mnist_node", mnist_node, mnist_node.OPT, cfg,
        # Paper §4.1.1: Momentum(0.1, 0.9), inv-decay 1e-5, 75 epochs, B=512;
        # coef_e annealed 100 -> 10; coef_s = 0.0285; TayNODE K=3.
        {
            "lr": 0.1, "inv_decay": 1e-5, "coef_e_start": 100.0,
            "coef_e_end": 10.0, "coef_s": 0.0285, "taylor_order": 3,
            "taylor_coef": 3.02e-3, "steer_b": 0.5, "t1": 1.0,
        },
    )
    P = mnist_node.SPEC.size
    S = mnist_node.OPT.state_size(P)
    train_ins = [
        ("params", spec([P])), ("opt_state", spec([S])),
        ("x", spec([B, 784])), ("y", spec([B, 10])),
        ("lr", spec([])), ("coef_e", spec([])), ("coef_s", spec([])),
        ("coef_aux", spec([])), ("t1", spec([])),
    ]
    em.emit(
        "mnist_node_init", lambda seed: mnist_node.init_fn(seed),
        [("seed", spec([], U32))], model="mnist_node", kind="init",
    )
    for budget in (16, 32, 64):
        c = cfg._replace(max_steps=budget)
        em.emit(
            f"mnist_node_train_b{budget}", mnist_node.make_train_step(c),
            train_ins, model="mnist_node", kind="train",
            meta={"budget": budget},
        )
    em.emit(
        "mnist_node_tay_train_b32",
        mnist_node.make_train_step(cfg._replace(max_steps=32, taylor_order=3)),
        train_ins, model="mnist_node", kind="tay_train", meta={"budget": 32},
    )
    em.emit(
        "mnist_node_predict", mnist_node.make_predict(cfg),
        [("params", spec([P])), ("x", spec([B, 784])), ("y", spec([B, 10]))],
        model="mnist_node", kind="predict",
    )


def emit_latent_ode(em: Emitter):
    B, T, D = 32, 16, latent_ode.CHANNELS
    cfg = latent_ode.Config(batch=B, t_points=T, rtol=1e-4, atol=1e-4,
                            use_kernels=True)
    em.add_model(
        "latent_ode", latent_ode, latent_ode.OPT, cfg,
        # Paper §4.1.2: Adamax(0.01), inv-decay 1e-5, 300 epochs, B=512;
        # coef_e annealed 1000 -> 100; coef_s = 0.285; KL anneal 0.99;
        # TayNODE K=2, coef 0.01.
        {
            "lr": 0.01, "inv_decay": 1e-5, "coef_e_start": 1000.0,
            "coef_e_end": 100.0, "coef_s": 0.285, "kl_anneal": 0.99,
            "taylor_order": 2, "taylor_coef": 0.01,
        },
    )
    P = latent_ode.SPEC.size
    S = latent_ode.OPT.state_size(P)
    train_ins = [
        ("params", spec([P])), ("opt_state", spec([S])),
        ("x", spec([B, T, D])), ("mask", spec([B, T, D])), ("ts", spec([T])),
        ("lr", spec([])), ("coef_e", spec([])), ("coef_s", spec([])),
        ("coef_aux", spec([])), ("kl_coef", spec([])), ("seed", spec([], U32)),
    ]
    em.emit(
        "latent_ode_init", lambda seed: latent_ode.init_fn(seed),
        [("seed", spec([], U32))], model="latent_ode", kind="init",
    )
    for budget in (4, 8):
        c = cfg._replace(steps_per_segment=budget)
        em.emit(
            f"latent_ode_train_s{budget}", latent_ode.make_train_step(c),
            train_ins, model="latent_ode", kind="train",
            meta={"budget": budget},
        )
    em.emit(
        "latent_ode_tay_train_s4",
        latent_ode.make_train_step(
            cfg._replace(steps_per_segment=4, taylor_order=2)
        ),
        train_ins, model="latent_ode", kind="tay_train", meta={"budget": 4},
    )
    em.emit(
        "latent_ode_predict", latent_ode.make_predict(cfg),
        [
            ("params", spec([P])), ("x", spec([B, T, D])),
            ("mask", spec([B, T, D])), ("ts", spec([T])),
            ("seed", spec([], U32)),
        ],
        model="latent_ode", kind="predict",
    )


def emit_spiral_node(em: Emitter):
    T = 30
    cfg = spiral_node.Config(t_points=T, rtol=1e-6, atol=1e-6)
    em.add_model(
        "spiral_node", spiral_node, spiral_node.OPT, cfg,
        {"lr": 0.01, "coef_e": 0.1, "coef_s": 0.0285, "t_span": 1.5},
    )
    P = spiral_node.SPEC.size
    S = spiral_node.OPT.state_size(P)
    train_ins = [
        ("params", spec([P])), ("opt_state", spec([S])),
        ("data", spec([T, 2])), ("ts", spec([T])),
        ("lr", spec([])), ("coef_e", spec([])), ("coef_s", spec([])),
    ]
    em.emit(
        "spiral_node_init", lambda seed: spiral_node.init_fn(seed),
        [("seed", spec([], U32))], model="spiral_node", kind="init",
    )
    for budget in (6, 12):
        c = cfg._replace(steps_per_segment=budget)
        em.emit(
            f"spiral_node_train_s{budget}", spiral_node.make_train_step(c),
            train_ins, model="spiral_node", kind="train",
            meta={"budget": budget},
        )
    em.emit(
        "spiral_node_predict", spiral_node.make_predict(cfg),
        [("params", spec([P])), ("data", spec([T, 2])), ("ts", spec([T]))],
        model="spiral_node", kind="predict",
    )


def emit_spiral_nsde(em: Emitter):
    N, T = 64, 30
    cfg = spiral_nsde.Config(n_traj=N, t_points=T, rtol=1e-2, atol=1e-2)
    em.add_model(
        "spiral_nsde", spiral_nsde, spiral_nsde.OPT, cfg,
        # Paper §4.2.1: AdaBelief(0.01), 250 iters, 100 traj/iter;
        # ERNSDE coef 1.0 (table 3 scale), SRNSDE coef 0.01 — the paper does
        # not list these; chosen so reg magnitudes match the GMM loss scale.
        {"lr": 0.01, "coef_e": 1.0, "coef_s": 0.01, "t_span": 1.0},
    )
    P = spiral_nsde.SPEC.size
    S = spiral_nsde.OPT.state_size(P)
    train_ins = [
        ("params", spec([P])), ("opt_state", spec([S])),
        ("u0", spec([N, 2])), ("data_mu", spec([T, 2])),
        ("data_var", spec([T, 2])), ("ts", spec([T])),
        ("lr", spec([])), ("coef_e", spec([])), ("coef_s", spec([])),
        ("seed", spec([], U32)),
    ]
    em.emit(
        "spiral_nsde_init", lambda seed: spiral_nsde.init_fn(seed),
        [("seed", spec([], U32))], model="spiral_nsde", kind="init",
    )
    for budget in (6, 12):
        c = cfg._replace(steps_per_segment=budget)
        em.emit(
            f"spiral_nsde_train_s{budget}", spiral_nsde.make_train_step(c),
            train_ins, model="spiral_nsde", kind="train",
            meta={"budget": budget},
        )
    em.emit(
        "spiral_nsde_predict", spiral_nsde.make_predict(cfg),
        [
            ("params", spec([P])), ("u0", spec([N, 2])),
            ("data_mu", spec([T, 2])), ("data_var", spec([T, 2])),
            ("ts", spec([T])), ("seed", spec([], U32)),
        ],
        model="spiral_nsde", kind="predict",
    )


def emit_mnist_nsde(em: Emitter):
    B = 32
    cfg = mnist_nsde.Config(batch=B, rtol=1e-2, atol=1e-2, use_kernels=True)
    em.add_model(
        "mnist_nsde", mnist_nsde, mnist_nsde.OPT, cfg,
        # Paper §4.2.2: Adam(0.01), inv-decay 1e-5, 40 epochs, B=512;
        # coef_e = 10.0, coef_s = 0.1; predict = mean of 10 trajectories.
        {"lr": 0.01, "inv_decay": 1e-5, "coef_e": 10.0, "coef_s": 0.1},
    )
    P = mnist_nsde.SPEC.size
    S = mnist_nsde.OPT.state_size(P)
    train_ins = [
        ("params", spec([P])), ("opt_state", spec([S])),
        ("x", spec([B, 784])), ("y", spec([B, 10])),
        ("lr", spec([])), ("coef_e", spec([])), ("coef_s", spec([])),
        ("seed", spec([], U32)),
    ]
    em.emit(
        "mnist_nsde_init", lambda seed: mnist_nsde.init_fn(seed),
        [("seed", spec([], U32))], model="mnist_nsde", kind="init",
    )
    for budget in (48, 96):
        c = cfg._replace(max_steps=budget)
        em.emit(
            f"mnist_nsde_train_b{budget}", mnist_nsde.make_train_step(c),
            train_ins, model="mnist_nsde", kind="train",
            meta={"budget": budget},
        )
    em.emit(
        "mnist_nsde_predict", mnist_nsde.make_predict(cfg),
        [
            ("params", spec([P])), ("x", spec([B, 784])),
            ("y", spec([B, 10])), ("seed", spec([], U32)),
        ],
        model="mnist_nsde", kind="predict",
    )


def emit_cross_validation(em: Emitter):
    """Fixed spiral ODE solved by the JAX adaptive Tsit5 — compared
    trajectory-for-trajectory against rust/src/solvers in rust tests."""
    T = 30

    def solve(u0, ts):
        a_mat = jnp.array([[-0.1, 2.0], [-2.0, -0.1]], jnp.float32)

        def f(z, t):
            del t
            return jnp.power(z, 3) @ a_mat.T

        zs, stats = solver.odeint_save_scan(
            f, u0, ts, tab=tableaus.get("tsit5"), rtol=1e-6, atol=1e-6,
            steps_per_segment=16, use_kernels=False,
        )
        return zs[:, 0, :], model_common.metrics_vector(0.0, 0.0, stats)

    em.emit(
        "spiral_ode_solve", solve,
        [("u0", spec([1, 2])), ("ts", spec([T]))],
        model="spiral_ode", kind="solve",
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--only", nargs="*", default=[],
                    help="substring filter on artifact names")
    args = ap.parse_args()
    em = Emitter(args.out, args.only)
    t0 = time.time()
    emit_mnist_node(em)
    emit_latent_ode(em)
    emit_spiral_node(em)
    emit_spiral_nsde(em)
    emit_mnist_nsde(em)
    emit_cross_validation(em)
    em.save()
    print(f"total {time.time()-t0:.0f}s")


if __name__ == "__main__":
    main()
