"""Differentiable adaptive SDE solver with white-boxed heuristics.

Neural SDEs with *diagonal multiplicative noise* (paper §2.2, §4.2):

    dz = f(z, t) dt + g(z, t) ∘ dW          (∘ = elementwise)

The paper uses SOSRI/SOSRI2 (Rackauckas & Nie 2020) — stability-optimized
stochastic Runge-Kutta pairs with embedded error estimates and rejection
sampling with memory (RSwM).  We substitute a scan-compatible **adaptive
stochastic Heun 1.0/0.5 embedded pair** (DESIGN.md §4): the propagated
solution is the Heun (stochastic improved-Euler) value, the embedded
lower-order value is plain Euler-Maruyama, and their difference is the local
error estimate.  That is all the paper's regularizers need — *an* embedded
local error E_j and a drift stiffness ratio S_j accumulated per step:

    R_E = sum_j E_j |h_j|     R_S = sum_j S_j       (paper Eq. 9/11)

Brownian-path handling under rejection is RSwM-lite: the carry holds one
pending increment ``(h_pend, w_pend)`` for the interval ahead.  A step of
size h < h_pend takes the Brownian-bridge conditional sample for the front
sub-interval; on rejection the pending increment is *refined* to the bridged
front sample (so retries stay on the same path); on acceptance any unused
tail increment is discarded (fresh noise ahead).  This keeps the driving
path self-consistent across all retries of a single step while remaining a
fixed-shape scan carry (a full RSwM stack is not scan-compatible).

Like the ODE module this provides a differentiable bounded-scan variant for
training and an early-exiting while variant for prediction.
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from . import norms
from .solver import SolveStats

Array = jnp.ndarray
EPS = 1e-12
ORDER = 1  # weak/strong order of the propagated Heun solution used for PI control


class _SdeCarry(NamedTuple):
    t: Array
    z: Array
    h: Array
    h_pend: Array  # length of the pending Brownian interval
    w_pend: Array  # pending Brownian increment over [t, t + h_pend]
    q_prev: Array
    done: Array
    key: Array
    stats: SolveStats


def _bridge_split(key, w_pend: Array, h_pend: Array, h: Array):
    """Brownian bridge: sample W(h) | W(h_pend) = w_pend for 0 < h <= h_pend."""
    frac = h / jnp.maximum(h_pend, EPS)
    mean = frac * w_pend
    var = jnp.maximum(h * (h_pend - h) / jnp.maximum(h_pend, EPS), 0.0)
    eps = jax.random.normal(key, w_pend.shape, dtype=w_pend.dtype)
    # +1e-30 keeps d(sqrt) finite at var == 0 (masked branch, see norms.py).
    return mean + jnp.sqrt(var + 1e-30) * eps


def _extend(key, w_pend: Array, h_pend: Array, h: Array):
    """Extend the pending increment from h_pend to h > h_pend with fresh noise."""
    extra = jnp.maximum(h - h_pend, 0.0)
    eps = jax.random.normal(key, w_pend.shape, dtype=w_pend.dtype)
    return w_pend + jnp.sqrt(extra + 1e-30) * eps


def _heun_attempt(f, g, z, t, h, dw, rtol, atol):
    """Adaptive stochastic Heun pair: one attempt, returns estimates.

    Embedded pair:
      z_em   = z + h f1 + g1 ∘ dW                       (order 0.5 strong)
      z_heun = z + h/2 (f1+f2) + dW/2 ∘ (g1+g2)         (order 1.0 for diag)
      E      = z_heun - z_em
    Stiffness (Shampine-style on the drift, paper Eq. 8 analog):
      S = |f2 - f1| / |z_em - z|
    """
    f1 = f(z, t)
    g1 = g(z, t)
    z_em = z + h * f1 + g1 * dw
    f2 = f(z_em, t + h)
    g2 = g(z_em, t + h)
    z_heun = z + 0.5 * h * (f1 + f2) + 0.5 * dw * (g1 + g2)
    err = z_heun - z_em
    q = norms.error_ratio(err, z, z_heun, rtol, atol)
    e_norm = norms.hairer_norm(err)
    stiff = norms.hairer_norm(f2 - f1) / (norms.hairer_norm(z_em - z) + EPS)
    return z_heun, q, e_norm, stiff


def _sde_step_once(f, g, rtol, atol, t1, carry: _SdeCarry) -> _SdeCarry:
    t, z, h, h_pend, w_pend, q_prev, done, key, st = carry
    key, k_noise = jax.random.split(key)

    span_left = t1 - t
    h_eff = jnp.maximum(jnp.minimum(h, span_left), EPS)

    # Brownian increment for [t, t+h_eff]: bridge into the pending interval
    # or extend it, whichever applies (both branches computed, one selected —
    # scan-compatible).
    shrink = h_eff < h_pend
    w_bridge = _bridge_split(k_noise, w_pend, h_pend, h_eff)
    w_extend = _extend(k_noise, w_pend, h_pend, h_eff)
    dw = jnp.where(shrink, w_bridge, w_extend)

    z_new, q, e_norm, stiff = _heun_attempt(f, g, z, t, h_eff, dw, rtol, atol)

    accept = q <= 1.0
    t_acc = t + h_eff
    reached = t_acc >= t1 - 1e-7 * jnp.abs(t1)

    h_grow = h_eff * norms.pi_step_factor(q, q_prev, ORDER)
    h_shrink = h_eff * norms.reject_step_factor(q, ORDER)
    h_next = jnp.where(accept, h_grow, h_shrink)

    # RSwM pending-increment update.  Invariant: the *total* pending
    # increment is drawn before any accept/reject decision that depends on
    # it, so acceptance (which conditions on |dW|) can never truncate the
    # increment distribution:
    #  accept, h < h_pend -> the unconsumed tail (w_pend - dw) stays pending;
    #  accept, h >= h_pend -> pending fully consumed, reset to zero;
    #  reject, h >= h_pend -> the extended increment becomes the pending
    #                         total for the retry;
    #  reject, h < h_pend -> pending unchanged (retry re-bridges into it).
    acc_shrink = accept & shrink
    h_pend_new = jnp.where(
        acc_shrink, h_pend - h_eff,
        jnp.where(accept, 0.0, jnp.maximum(h_pend, h_eff)),
    )
    w_pend_new = jnp.where(
        acc_shrink, w_pend - dw,
        jnp.where(accept, jnp.zeros_like(w_pend),
                  jnp.where(shrink, w_pend, dw)),
    )

    step = lambda new, old: jnp.where(done, old, jnp.where(accept, new, old))
    live = (~done).astype(jnp.float32)
    acc_f = live * accept.astype(jnp.float32)
    rej_f = live * (1.0 - accept.astype(jnp.float32))

    new_stats = SolveStats(
        r_e=st.r_e + acc_f * e_norm * jnp.abs(h_eff),
        r_e2=st.r_e2 + acc_f * e_norm * e_norm,
        r_s=st.r_s + acc_f * stiff,
        # 2 drift + 2 diffusion evaluations per attempt.
        nfe=st.nfe + live * 4.0,
        naccept=st.naccept + acc_f,
        nreject=st.nreject + rej_f,
        success=st.success,
        r_aux=st.r_aux,
    )
    return _SdeCarry(
        t=step(t_acc, t),
        z=step(z_new, z),
        h=jnp.where(done, h, h_next),
        h_pend=jnp.where(done, h_pend, h_pend_new),
        w_pend=jnp.where(done, w_pend, w_pend_new),
        q_prev=step(jnp.maximum(q, 1e-4), q_prev),
        done=done | (accept & reached),
        key=key,
        stats=new_stats,
    )


def _sde_init(z0: Array, t0, t1, key, dt0: Optional[Array]) -> _SdeCarry:
    t0 = jnp.asarray(t0, jnp.float32)
    t1 = jnp.asarray(t1, jnp.float32)
    h0 = jnp.asarray(
        dt0 if dt0 is not None else 0.01 * (t1 - t0), jnp.float32
    )
    return _SdeCarry(
        t=t0,
        z=z0,
        h=h0,
        h_pend=jnp.float32(0.0),
        w_pend=jnp.zeros_like(z0),
        q_prev=jnp.float32(1.0),
        done=jnp.asarray(False),
        key=key,
        stats=SolveStats.zeros(),
    )


def sdeint_scan(
    f: Callable[[Array, Array], Array],
    g: Callable[[Array, Array], Array],
    z0: Array,
    t0,
    t1,
    key: Array,
    *,
    rtol: float,
    atol: float,
    max_steps: int,
    dt0: Optional[Array] = None,
):
    """Differentiable adaptive SDE solve over [t0, t1] (bounded masked scan).

    Gradients flow through drift, diffusion, the Brownian increments (treated
    as reparameterized noise) and the accumulated R_E/R_S — the discrete
    adjoint of the stochastic solver, as in the paper's Neural SDE runs.
    """
    t1 = jnp.asarray(t1, jnp.float32)
    carry0 = _sde_init(z0, t0, t1, key, dt0)

    def body(c, _):
        return _sde_step_once(f, g, rtol, atol, t1, c), None

    carry, _ = lax.scan(body, carry0, None, length=max_steps)
    stats = carry.stats._replace(success=carry.done.astype(jnp.float32))
    return carry.z, stats


def sdeint_while(
    f, g, z0: Array, t0, t1, key: Array, *, rtol: float, atol: float,
    max_steps: int = 100_000, dt0: Optional[Array] = None,
):
    """Early-exiting adaptive SDE solve (prediction path)."""
    t1 = jnp.asarray(t1, jnp.float32)
    carry0 = _sde_init(z0, t0, t1, key, dt0)

    def cond(state):
        c, i = state
        return (~c.done) & (i < max_steps)

    def body(state):
        c, i = state
        return _sde_step_once(f, g, rtol, atol, t1, c), i + 1

    carry, _ = lax.while_loop(cond, body, (carry0, jnp.int32(0)))
    stats = carry.stats._replace(success=carry.done.astype(jnp.float32))
    return carry.z, stats


def sdeint_save_scan(
    f, g, z0: Array, ts: Array, key: Array, *, rtol: float, atol: float,
    steps_per_segment: int, dt0: Optional[Array] = None,
):
    """Differentiable saveat SDE solve — states at each time in ``ts``.

    Used by the spiral NSDE (paper Eq. 15-17): the GMM loss needs the state
    at 30 uniformly spaced save points.
    """
    carry0 = _sde_init(z0, ts[0], ts[-1], key, dt0)

    def segment(carry: _SdeCarry, t_pair):
        t_lo, t_hi = t_pair
        seg0 = carry._replace(t=t_lo, done=jnp.asarray(False))

        def body(c, _):
            return _sde_step_once(f, g, rtol, atol, t_hi, c), None

        seg, _ = lax.scan(body, seg0, None, length=steps_per_segment)
        seg_stats = seg.stats._replace(
            success=seg.stats.success * seg.done.astype(jnp.float32)
        )
        return seg._replace(stats=seg_stats), seg.z

    carry_f, z_rest = lax.scan(segment, carry0, (ts[:-1], ts[1:]))
    zs = jnp.concatenate([z0[None], z_rest], axis=0)
    stats = carry_f.stats._replace(
        success=(carry_f.stats.success > 0).astype(jnp.float32)
    )
    return zs, stats


def sdeint_save_while(
    f, g, z0: Array, ts: Array, key: Array, *, rtol: float, atol: float,
    max_steps_per_segment: int = 100_000, dt0: Optional[Array] = None,
):
    """Early-exiting saveat SDE solve (prediction path)."""
    carry0 = _sde_init(z0, ts[0], ts[-1], key, dt0)

    def segment(carry: _SdeCarry, t_pair):
        t_lo, t_hi = t_pair
        seg0 = carry._replace(t=t_lo, done=jnp.asarray(False))

        def cond(state):
            c, i = state
            return (~c.done) & (i < max_steps_per_segment)

        def body(state):
            c, i = state
            return _sde_step_once(f, g, rtol, atol, t_hi, c), i + 1

        seg, _ = lax.while_loop(cond, body, (seg0, jnp.int32(0)))
        return seg, seg.z

    carry_f, z_rest = lax.scan(segment, carry0, (ts[:-1], ts[1:]))
    zs = jnp.concatenate([z0[None], z_rest], axis=0)
    stats = carry_f.stats._replace(success=carry_f.done.astype(jnp.float32))
    return zs, stats
