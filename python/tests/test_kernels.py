"""L1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes; every property asserts allclose between the
kernel and ref.py, for both forward values and the hand-written VJPs —
these kernels sit inside the discrete adjoint (paper §3.2), so gradient
correctness is the core signal.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import dense_act, rk_combine, ref
from compile.kernels.fused_dense import matmul

settings.register_profile("ci", max_examples=20, deadline=None)
settings.load_profile("ci")


def rand(key, *shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


class TestDenseAct:
    @given(
        m=st.integers(1, 200),
        k=st.integers(1, 300),
        n=st.integers(1, 200),
        act=st.sampled_from(["tanh", "linear", "sigmoid"]),
    )
    def test_forward_matches_ref(self, m, k, n, act):
        x, w = rand(0, m, k), 0.1 * rand(1, k, n)
        b = 0.1 * rand(2, n)
        got = dense_act(x, w, b, act)
        want = ref.dense_act(x, w, b, act)
        np.testing.assert_allclose(got, want, atol=1e-5, rtol=1e-5)

    @given(
        m=st.integers(1, 80),
        k=st.integers(1, 120),
        n=st.integers(1, 80),
        act=st.sampled_from(["tanh", "linear", "sigmoid"]),
    )
    def test_vjp_matches_ref(self, m, k, n, act):
        x, w = rand(3, m, k), 0.1 * rand(4, k, n)
        b = 0.1 * rand(5, n)
        f = lambda x, w, b: jnp.sum(jnp.sin(dense_act(x, w, b, act)))
        fr = lambda x, w, b: jnp.sum(jnp.sin(ref.dense_act(x, w, b, act)))
        g = jax.grad(f, argnums=(0, 1, 2))(x, w, b)
        gr = jax.grad(fr, argnums=(0, 1, 2))(x, w, b)
        for a, bb in zip(g, gr):
            np.testing.assert_allclose(a, bb, atol=1e-4, rtol=1e-4)

    def test_exact_tile_boundary(self):
        # shapes exactly on the 128-tile boundary exercise the no-pad path
        x, w, b = rand(6, 128, 785), 0.05 * rand(7, 785, 128), jnp.zeros(128)
        np.testing.assert_allclose(
            dense_act(x, w, b, "tanh"),
            ref.dense_act(x, w, b, "tanh"),
            atol=1e-5,
        )

    def test_bad_act_raises(self):
        with pytest.raises(ValueError):
            ref.dense_act(rand(0, 2, 2), rand(1, 2, 2), jnp.zeros(2), "relu6")

    def test_jit_compatible(self):
        f = jax.jit(lambda x, w, b: dense_act(x, w, b, "tanh"))
        x, w, b = rand(8, 37, 19), rand(9, 19, 11), jnp.zeros(11)
        np.testing.assert_allclose(
            f(x, w, b), ref.dense_act(x, w, b, "tanh"), atol=1e-5
        )


class TestMatmul:
    @given(m=st.integers(1, 150), k=st.integers(1, 200), n=st.integers(1, 150))
    def test_matches_jnp(self, m, k, n):
        a, b = rand(10, m, k), rand(11, k, n)
        np.testing.assert_allclose(matmul(a, b), a @ b, atol=1e-4, rtol=1e-4)


class TestRkCombine:
    @given(
        s=st.integers(2, 7),
        b_=st.integers(1, 100),
        d=st.integers(1, 50),
    )
    def test_forward_matches_ref(self, s, b_, d):
        ks = rand(12, s, b_, d)
        z = rand(13, b_, d)
        h = jnp.float32(0.037)
        rng = np.random.default_rng(s)
        bcoef = tuple(rng.normal(size=s))
        btilde = tuple(rng.normal(size=s))
        zn, err = rk_combine(ks, z, h, bcoef, btilde)
        zn_r, err_r = ref.rk_combine(ks, z, h, bcoef, btilde)
        np.testing.assert_allclose(zn, zn_r, atol=1e-5, rtol=1e-5)
        np.testing.assert_allclose(err, err_r, atol=1e-5, rtol=1e-5)

    @given(s=st.integers(2, 7), b_=st.integers(1, 40), d=st.integers(1, 20))
    def test_vjp_matches_ref_incl_h(self, s, b_, d):
        ks = rand(14, s, b_, d)
        z = rand(15, b_, d)
        h = jnp.float32(0.05)
        rng = np.random.default_rng(s + 100)
        bcoef = tuple(rng.normal(size=s))
        btilde = tuple(rng.normal(size=s))

        def loss(kernel):
            def f(ks, z, h):
                zn, err = kernel(ks, z, h, bcoef, btilde)
                return jnp.sum(zn**2) + jnp.sum(jnp.abs(err))
            return f

        g = jax.grad(loss(rk_combine), argnums=(0, 1, 2))(ks, z, h)
        gr = jax.grad(loss(ref.rk_combine), argnums=(0, 1, 2))(ks, z, h)
        for a, bb in zip(g, gr):
            np.testing.assert_allclose(a, bb, atol=1e-4, rtol=1e-4)

    def test_zero_h_gives_identity(self):
        ks = rand(16, 4, 8, 3)
        z = rand(17, 8, 3)
        zn, err = rk_combine(ks, z, jnp.float32(0.0), (0.1,) * 4, (0.2,) * 4)
        np.testing.assert_allclose(zn, z, atol=1e-7)
        np.testing.assert_allclose(err, jnp.zeros_like(z), atol=1e-7)
