"""L2 solver correctness: adaptive Tsit5/Dopri5/BS3 vs analytic solutions,
white-boxed statistics semantics, and the discrete adjoint."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import norms, solver, tableaus

TAB = tableaus.tsit5()
DECAY = lambda z, t: -z


class TestTableaus:
    @pytest.mark.parametrize("name", ["tsit5", "dopri5", "bs3"])
    def test_consistency_conditions(self, name):
        tab = tableaus.get(name)
        assert abs(tab.b.sum() - 1.0) < 1e-12
        assert abs(tab.btilde.sum()) < 1e-12
        for i in range(tab.stages):
            assert abs(tab.a[i, :].sum() - tab.c[i]) < 1e-9

    def test_unknown_raises(self):
        with pytest.raises(KeyError):
            tableaus.get("rk4")

    def test_fsal_structure(self):
        for name in ("tsit5", "dopri5"):
            tab = tableaus.get(name)
            np.testing.assert_allclose(tab.a[-1, :-1], tab.b[:-1], atol=1e-15)


class TestOdeint:
    def test_exponential_accuracy(self):
        z0 = jnp.ones((4, 3))
        z1, st = solver.odeint_scan(
            DECAY, z0, 0.0, 1.0, tab=TAB, rtol=1e-7, atol=1e-7,
            max_steps=128, use_kernels=False,
        )
        np.testing.assert_allclose(z1, np.exp(-1.0), rtol=1e-6)
        assert float(st.success) == 1.0

    @pytest.mark.parametrize("name", ["tsit5", "dopri5", "bs3"])
    def test_all_tableaus_converge(self, name):
        tab = tableaus.get(name)
        z1, st = solver.odeint_scan(
            DECAY, jnp.ones((2, 2)), 0.0, 1.0, tab=tab, rtol=1e-6,
            atol=1e-6, max_steps=256, use_kernels=False,
        )
        np.testing.assert_allclose(z1, np.exp(-1.0), rtol=1e-4)

    def test_while_matches_scan(self):
        z0 = jnp.ones((3, 2)) * 0.7
        f = lambda z, t: jnp.sin(z) - 0.3 * z
        z_s, st_s = solver.odeint_scan(
            f, z0, 0.0, 2.0, tab=TAB, rtol=1e-5, atol=1e-5, max_steps=128,
            use_kernels=False,
        )
        z_w, st_w = solver.odeint_while(
            f, z0, 0.0, 2.0, tab=TAB, rtol=1e-5, atol=1e-5, use_kernels=False
        )
        np.testing.assert_allclose(z_s, z_w, atol=1e-6)
        assert float(st_s.nfe) == float(st_w.nfe)
        assert float(st_s.r_e) == pytest.approx(float(st_w.r_e), rel=1e-5)

    def test_kernel_path_matches_ref_path(self):
        z0 = jnp.ones((16, 8)) * 0.3
        for use_kernels in (False, True):
            out = solver.odeint_scan(
                DECAY, z0, 0.0, 1.0, tab=TAB, rtol=1e-5, atol=1e-5,
                max_steps=64, use_kernels=use_kernels,
            )
            if use_kernels:
                np.testing.assert_allclose(out[0], ref_out[0], atol=1e-6)
                assert float(out[1].nfe) == float(ref_out[1].nfe)
            else:
                ref_out = out

    def test_nfe_accounting(self):
        _, st = solver.odeint_scan(
            DECAY, jnp.ones((2, 2)), 0.0, 1.0, tab=TAB, rtol=1e-6,
            atol=1e-6, max_steps=64, use_kernels=False,
        )
        # 1 initial eval + 6 per attempt (FSAL Tsit5)
        attempts = float(st.naccept) + float(st.nreject)
        assert float(st.nfe) == 1.0 + 6.0 * attempts

    def test_budget_exhaustion_flags_failure(self):
        _, st = solver.odeint_scan(
            DECAY, jnp.ones((2, 2)), 0.0, 1.0, tab=TAB, rtol=1e-12,
            atol=1e-12, max_steps=4, use_kernels=False,
        )
        assert float(st.success) == 0.0

    def test_stiffness_estimate_tracks_lambda(self):
        lam = 40.0
        _, st = solver.odeint_scan(
            lambda z, t: -lam * z, jnp.ones((2, 2)), 0.0, 1.0, tab=TAB,
            rtol=1e-6, atol=1e-6, max_steps=256, use_kernels=False,
        )
        s_per_step = float(st.r_s) / float(st.naccept)
        assert abs(s_per_step - lam) / lam < 0.25

    def test_r_e_decreases_with_tolerance(self):
        res = []
        for tol in (1e-3, 1e-6):
            _, st = solver.odeint_scan(
                DECAY, jnp.ones((2, 2)), 0.0, 1.0, tab=TAB, rtol=tol,
                atol=tol, max_steps=256, use_kernels=False,
            )
            res.append(float(st.r_e))
        assert res[1] < res[0]

    def test_saveat_matches_analytic(self):
        ts = jnp.linspace(0.0, 1.0, 7)
        zs, st = solver.odeint_save_scan(
            DECAY, jnp.ones((2, 1)), ts, tab=TAB, rtol=1e-7, atol=1e-7,
            steps_per_segment=16, use_kernels=False,
        )
        np.testing.assert_allclose(
            zs[:, 0, 0], np.exp(-np.asarray(ts)), rtol=1e-5
        )
        assert float(st.success) == 1.0

    def test_saveat_while_matches_scan(self):
        ts = jnp.linspace(0.0, 1.0, 5)
        a = solver.odeint_save_scan(
            DECAY, jnp.ones((2, 2)), ts, tab=TAB, rtol=1e-5, atol=1e-5,
            steps_per_segment=12, use_kernels=False,
        )
        b = solver.odeint_save_while(
            DECAY, jnp.ones((2, 2)), ts, tab=TAB, rtol=1e-5, atol=1e-5,
            use_kernels=False,
        )
        np.testing.assert_allclose(a[0], b[0], atol=1e-6)


class TestDiscreteAdjoint:
    def test_grad_matches_analytic(self):
        # d/da [z0 * exp(-a)] = -z0 exp(-a) at a=1
        def loss(a):
            z1, _ = solver.odeint_scan(
                lambda z, t: -a * z, jnp.ones((1, 1)), 0.0, 1.0, tab=TAB,
                rtol=1e-7, atol=1e-7, max_steps=128, use_kernels=False,
            )
            return z1[0, 0]

        g = jax.grad(loss)(jnp.float32(1.0))
        assert abs(float(g) - (-np.exp(-1.0))) < 1e-4

    def test_reg_terms_differentiable(self):
        def loss(a):
            _, st = solver.odeint_scan(
                lambda z, t: -a * z, jnp.ones((2, 2)), 0.0, 1.0, tab=TAB,
                rtol=1e-4, atol=1e-4, max_steps=64, use_kernels=False,
            )
            return st.r_e + 0.1 * st.r_s + st.r_e2

        g = jax.grad(loss)(jnp.float32(1.0))
        assert np.isfinite(float(g))
        assert float(g) != 0.0

    def test_grad_finite_difference(self):
        def loss(a):
            z1, st = solver.odeint_scan(
                lambda z, t: -a * z * z, jnp.ones((1, 2)), 0.0, 1.0,
                tab=TAB, rtol=1e-5, atol=1e-5, max_steps=64,
                use_kernels=False,
            )
            return jnp.sum(z1) + 0.01 * st.r_e

        a0 = jnp.float32(0.8)
        g = float(jax.grad(loss)(a0))
        eps = 1e-3
        fd = (float(loss(a0 + eps)) - float(loss(a0 - eps))) / (2 * eps)
        assert abs(g - fd) < 5e-2 * max(1.0, abs(fd))


class TestNorms:
    def test_hairer_norm_safe_at_zero(self):
        g = jax.grad(lambda x: norms.hairer_norm(x))(jnp.zeros(4))
        assert np.isfinite(np.asarray(g)).all()

    def test_error_ratio_accept_boundary(self):
        e = jnp.full((4,), 1e-6)
        z = jnp.ones((4,))
        q = norms.error_ratio(e, z, z, 1e-6, 1e-6)
        assert float(q) < 1.0  # scale = atol + |z| rtol = 2e-6 > |e|

    def test_pi_factor_clamps(self):
        assert float(norms.pi_step_factor(jnp.float32(1e-8), jnp.float32(1.0), 5)) <= 10.0
        assert float(norms.pi_step_factor(jnp.float32(1e8), jnp.float32(1.0), 5)) >= 0.2
