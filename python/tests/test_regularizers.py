"""TayNODE (jet) regularizer and regularization composition."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import regularizers, solver, tableaus


class TestTaylorCoeffs:
    def test_exponential_derivatives(self):
        # f = -z  =>  z^{(k)} alternates sign with |.| = |z|
        f = lambda z, t: -z
        coeffs = regularizers.taylor_derivative_coeffs(
            f, jnp.ones(3), jnp.float32(0.0), 4
        )
        vals = [float(c[0]) for c in coeffs]
        assert vals == pytest.approx([-1.0, 1.0, -1.0, 1.0], abs=1e-5)

    def test_time_dependent_dynamics(self):
        # z' = t  =>  z'' = 1, z''' = 0
        f = lambda z, t: jnp.full_like(z, t)
        coeffs = regularizers.taylor_derivative_coeffs(
            f, jnp.zeros(1), jnp.float32(2.0), 3
        )
        assert float(coeffs[0][0]) == pytest.approx(2.0)
        assert float(coeffs[1][0]) == pytest.approx(1.0, abs=1e-5)
        assert float(coeffs[2][0]) == pytest.approx(0.0, abs=1e-5)

    def test_reg_fn_positive(self):
        aux = regularizers.taylor_reg_fn(lambda z, t: -z, 3)
        assert float(aux(jnp.ones((4,)), jnp.float32(0.0))) > 0.0

    def test_order_validation(self):
        with pytest.raises(ValueError):
            regularizers.taylor_reg_fn(lambda z, t: -z, 1)


class TestSolverIntegration:
    def test_r_aux_accumulates_and_differentiates(self):
        tab = tableaus.tsit5()

        def loss(a):
            f = lambda z, t: -a * z
            _, st = solver.odeint_scan(
                f, jnp.ones((2, 3)), 0.0, 1.0, tab=tab, rtol=1e-4,
                atol=1e-4, max_steps=32, use_kernels=False,
                aux_fn=regularizers.taylor_reg_fn(f, 3),
            )
            return st.r_aux

        v = float(loss(jnp.float32(1.0)))
        assert v > 0.0
        g = float(jax.grad(loss)(jnp.float32(1.0)))
        assert np.isfinite(g) and g != 0.0

    def test_higher_curvature_higher_r_aux(self):
        tab = tableaus.tsit5()

        def r_aux(a):
            f = lambda z, t: -a * z
            _, st = solver.odeint_scan(
                f, jnp.ones((1, 2)), 0.0, 1.0, tab=tab, rtol=1e-4,
                atol=1e-4, max_steps=64, use_kernels=False,
                aux_fn=regularizers.taylor_reg_fn(f, 2),
            )
            return float(st.r_aux)

        assert r_aux(jnp.float32(3.0)) > r_aux(jnp.float32(0.5))


class TestCompose:
    def test_variants(self):
        class FakeStats:
            r_e, r_e2, r_s, r_aux = (
                jnp.float32(2.0),
                jnp.float32(4.0),
                jnp.float32(3.0),
                jnp.float32(5.0),
            )

        st = FakeStats()
        eh = regularizers.compose_regularization(
            st, jnp.float32(1.0), jnp.float32(0.5)
        )
        assert float(eh) == pytest.approx(2.0 + 1.5)
        e2 = regularizers.compose_regularization(
            st, jnp.float32(1.0), jnp.float32(0.0), error_variant="e2"
        )
        assert float(e2) == pytest.approx(4.0)
        full = regularizers.compose_regularization(
            st, jnp.float32(0.0), jnp.float32(0.0), coef_aux=jnp.float32(2.0)
        )
        assert float(full) == pytest.approx(10.0)
