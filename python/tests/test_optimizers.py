"""In-graph optimizer semantics on flat vectors."""
import jax.numpy as jnp
import numpy as np
import pytest

from compile import optimizers


QUAD_OPT = {  # reasonable lr per optimizer for the quadratic descent test
    "momentum": 0.1,
    "adam": 0.1,
    "adamax": 0.1,
    "adabelief": 0.1,
}


@pytest.mark.parametrize("name", ["momentum", "adam", "adamax", "adabelief"])
class TestOptimizer:
    def test_state_layout(self, name):
        opt = optimizers.get(name)
        assert opt.state_size(10) == opt.slots * 10 + 1
        s = opt.init_state(10)
        assert s.shape == (opt.state_size(10),)
        assert float(s[-1]) == 0.0

    def test_step_counter_increments(self, name):
        opt = optimizers.get(name)
        p = jnp.ones(5)
        s = opt.init_state(5)
        for i in range(3):
            p, s = opt.update(p, jnp.ones(5) * 0.1, s, jnp.float32(0.01))
            assert float(s[-1]) == i + 1

    def test_descends_quadratic(self, name):
        # minimize 0.5 * ||p||^2, grad = p
        opt = optimizers.get(name)
        p = jnp.ones(8) * 2.0
        s = opt.init_state(8)
        lr = jnp.float32(QUAD_OPT[name])
        for _ in range(200):
            p, s = opt.update(p, p, s, lr)
        assert float(jnp.sum(p**2)) < 0.05, name

    def test_zero_grad_keeps_params_close(self, name):
        opt = optimizers.get(name)
        p0 = jnp.ones(4)
        s = opt.init_state(4)
        p, _ = opt.update(p0, jnp.zeros(4), s, jnp.float32(0.1))
        np.testing.assert_allclose(p, p0, atol=1e-5)


def test_momentum_matches_flux_semantics():
    # v = rho v + lr g; p -= v
    opt = optimizers.sgd_momentum(mass=0.9)
    p = jnp.zeros(1)
    s = opt.init_state(1)
    g = jnp.ones(1)
    p, s = opt.update(p, g, s, jnp.float32(0.1))
    assert float(p[0]) == pytest.approx(-0.1)
    p, s = opt.update(p, g, s, jnp.float32(0.1))
    # v = 0.9*0.1 + 0.1 = 0.19; p = -0.1 - 0.19 = -0.29
    assert float(p[0]) == pytest.approx(-0.29, rel=1e-6)


def test_adam_bias_correction_first_step():
    opt = optimizers.adam()
    p = jnp.zeros(1)
    s = opt.init_state(1)
    p, _ = opt.update(p, jnp.ones(1) * 0.5, s, jnp.float32(0.01))
    # first Adam step is ~ -lr * sign(g)
    assert float(p[0]) == pytest.approx(-0.01, rel=1e-3)


def test_unknown_optimizer_raises():
    with pytest.raises(KeyError):
        optimizers.get("lion")
