"""Model-level smoke + semantics tests (small shapes for speed)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.models import (
    latent_ode,
    mnist_node,
    mnist_nsde,
    spiral_node,
    spiral_nsde,
)
from compile.models.common import METRICS_LAYOUT, accuracy, softmax_xent


def onehot(labels, n=10):
    return np.eye(n, dtype=np.float32)[labels]


class TestCommon:
    def test_metrics_layout_stable(self):
        # the Rust runtime hard-codes this 9-element contract
        assert METRICS_LAYOUT == [
            "loss", "metric", "nfe", "naccept", "nreject", "success",
            "r_e", "r_s", "r_aux",
        ]

    def test_xent_uniform(self):
        logits = jnp.zeros((8, 10))
        y = jnp.asarray(onehot(np.arange(8) % 10))
        assert float(softmax_xent(logits, y)) == pytest.approx(np.log(10), rel=1e-5)

    def test_accuracy(self):
        logits = jnp.asarray(onehot(np.array([1, 2, 3]), 10) * 5.0)
        y = jnp.asarray(onehot(np.array([1, 2, 0]), 10))
        assert float(accuracy(logits, y)) == pytest.approx(2 / 3)


class TestMnistNode:
    CFG = mnist_node.Config(batch=4, max_steps=12, rtol=1e-3, atol=1e-3,
                            use_kernels=False)

    def test_param_count_matches_paper_architecture(self):
        # W1(785x100)+B1(100)+W2(101x784)+B2(784)+W3(784x10)+B3(10)
        assert mnist_node.SPEC.size == 785 * 100 + 100 + 101 * 784 + 784 + 784 * 10 + 10

    def test_init_deterministic_per_seed(self):
        a, b = mnist_node.init_fn(3), mnist_node.init_fn(3)
        np.testing.assert_array_equal(a, b)
        assert not np.allclose(a, mnist_node.init_fn(4))

    def test_train_step_reduces_loss_eventually(self):
        step = jax.jit(mnist_node.make_train_step(self.CFG))
        rng = np.random.default_rng(0)
        x = rng.random((4, 784), dtype=np.float32)
        y = onehot(np.array([0, 1, 2, 3]))
        p = mnist_node.init_fn(0)
        s = mnist_node.OPT.init_state(mnist_node.SPEC.size)
        losses = []
        for _ in range(8):
            p, s, m = step(p, s, x, y, 0.1, 0.0, 0.0, 0.0, 1.0)
            losses.append(float(m[0]))
        assert losses[-1] < losses[0]

    def test_er_coefficient_changes_gradient(self):
        step = jax.jit(mnist_node.make_train_step(self.CFG))
        rng = np.random.default_rng(0)
        x = rng.random((4, 784), dtype=np.float32)
        y = onehot(np.array([0, 1, 2, 3]))
        p = mnist_node.init_fn(0)
        s = mnist_node.OPT.init_state(mnist_node.SPEC.size)
        p_a, _, _ = step(p, s, x, y, 0.1, 0.0, 0.0, 0.0, 1.0)
        p_b, _, _ = step(p, s, x, y, 0.1, 100.0, 0.0, 0.0, 1.0)
        assert not np.allclose(np.asarray(p_a), np.asarray(p_b))

    def test_steer_t1_input_respected(self):
        pred = mnist_node.make_train_step(self.CFG)
        rng = np.random.default_rng(0)
        x = rng.random((4, 784), dtype=np.float32)
        y = onehot(np.array([0, 1, 2, 3]))
        p = mnist_node.init_fn(0)
        s = mnist_node.OPT.init_state(mnist_node.SPEC.size)
        _, _, m_short = pred(p, s, x, y, 0.1, 0.0, 0.0, 0.0, 0.5)
        _, _, m_long = pred(p, s, x, y, 0.1, 0.0, 0.0, 0.0, 1.5)
        assert float(m_long[2]) >= float(m_short[2])  # longer span >= NFE


class TestLatentOde:
    CFG = latent_ode.Config(batch=3, t_points=6, steps_per_segment=4,
                            rtol=1e-3, atol=1e-3, use_kernels=False)

    def test_shapes_and_finiteness(self):
        step = jax.jit(latent_ode.make_train_step(self.CFG))
        rng = np.random.default_rng(1)
        x = rng.normal(size=(3, 6, 8)).astype(np.float32)
        mask = (rng.random((3, 6, 8)) > 0.5).astype(np.float32)
        ts = np.linspace(0, 1, 6).astype(np.float32)
        p = latent_ode.init_fn(0)
        s = latent_ode.OPT.init_state(latent_ode.SPEC.size)
        p2, s2, m = step(p, s, x, mask, ts, 0.01, 0.0, 0.0, 0.0, 0.5,
                         np.uint32(7))
        assert np.isfinite(np.asarray(m)).all()
        assert p2.shape == p.shape

    def test_mask_zero_channels_ignored(self):
        # fully masked-out entries must not change the loss value
        pred = jax.jit(latent_ode.make_predict(self.CFG))
        rng = np.random.default_rng(2)
        x = rng.normal(size=(3, 6, 8)).astype(np.float32)
        mask = np.ones((3, 6, 8), np.float32)
        mask[:, :, 4:] = 0.0
        x2 = x.copy()
        x2[:, :, 4:] = 99.0  # garbage in masked-out channels
        x_masked = x * mask
        x2_masked = x2 * mask
        p = latent_ode.init_fn(0)
        _, m_a = pred(p, x_masked, mask, np.linspace(0, 1, 6).astype(np.float32),
                      np.uint32(5))
        _, m_b = pred(p, x2_masked, mask, np.linspace(0, 1, 6).astype(np.float32),
                      np.uint32(5))
        assert float(m_a[0]) == pytest.approx(float(m_b[0]), rel=1e-6)


class TestSpiralModels:
    def test_spiral_node_fits_line(self):
        cfg = spiral_node.Config(t_points=8, steps_per_segment=8,
                                 rtol=1e-4, atol=1e-4)
        step = jax.jit(spiral_node.make_train_step(cfg))
        ts = np.linspace(0, 1, 8).astype(np.float32)
        data = np.stack([2 - ts, 0.5 * ts], 1).astype(np.float32)
        p = spiral_node.init_fn(0)
        s = spiral_node.OPT.init_state(spiral_node.SPEC.size)
        first = None
        for i in range(30):
            p, s, m = step(p, s, data, ts, 0.05, 0.0, 0.0)
            if first is None:
                first = float(m[0])
        assert float(m[0]) < first

    def test_spiral_nsde_gmm_loss_finite(self):
        cfg = spiral_nsde.Config(n_traj=8, t_points=6, steps_per_segment=6)
        step = jax.jit(spiral_nsde.make_train_step(cfg))
        ts = np.linspace(0, 1, 6).astype(np.float32)
        u0 = np.ones((8, 2), np.float32)
        mu = np.ones((6, 2), np.float32)
        var = 0.1 * np.ones((6, 2), np.float32)
        p = spiral_nsde.init_fn(0)
        s = spiral_nsde.OPT.init_state(spiral_nsde.SPEC.size)
        p, s, m = step(p, s, u0, mu, var, ts, 0.01, 0.0, 0.0, np.uint32(3))
        assert np.isfinite(np.asarray(m)).all()


class TestMnistNsde:
    CFG = mnist_nsde.Config(batch=4, max_steps=32, rtol=1e-2, atol=1e-2,
                            use_kernels=False, predict_traj=3)

    def test_train_and_predict(self):
        step = jax.jit(mnist_nsde.make_train_step(self.CFG))
        pred = jax.jit(mnist_nsde.make_predict(self.CFG))
        rng = np.random.default_rng(3)
        x = rng.random((4, 784), dtype=np.float32)
        y = onehot(np.array([1, 2, 3, 4]))
        p = mnist_nsde.init_fn(0)
        s = mnist_nsde.OPT.init_state(mnist_nsde.SPEC.size)
        p, s, m = step(p, s, x, y, 0.01, 0.0, 0.0, np.uint32(5))
        assert np.isfinite(np.asarray(m)).all()
        logits, mp = pred(p, x, y, np.uint32(9))
        assert logits.shape == (4, 10)
        # predict runs predict_traj solves: NFE should reflect that
        assert float(mp[2]) > float(m[2]) / 2
