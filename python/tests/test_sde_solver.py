"""L2 SDE solver: moments, deterministic limit, RSwM invariants, adjoint."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import sde_solver

KEY = jax.random.PRNGKey(0)
F = lambda z, t: -z
G01 = lambda z, t: 0.1 * jnp.ones_like(z)
GZERO = lambda z, t: jnp.zeros_like(z)


class TestSdeint:
    def test_deterministic_limit_matches_ode(self):
        z1, st = sde_solver.sdeint_scan(
            F, GZERO, jnp.ones((4, 2)), 0.0, 1.0, KEY, rtol=1e-5,
            atol=1e-5, max_steps=512,
        )
        np.testing.assert_allclose(z1, np.exp(-1.0), atol=1e-3)
        assert float(st.success) == 1.0

    def test_while_matches_scan(self):
        args = (F, G01, jnp.ones((4, 2)), 0.0, 1.0, KEY)
        kw = dict(rtol=1e-3, atol=1e-3)
        z_s, st_s = sde_solver.sdeint_scan(*args, max_steps=256, **kw)
        z_w, st_w = sde_solver.sdeint_while(*args, **kw)
        np.testing.assert_allclose(z_s, z_w, atol=1e-6)
        assert float(st_s.nfe) == float(st_w.nfe)

    def test_gbm_stratonovich_mean(self):
        mu, sig = 0.5, 0.3
        z0 = jnp.ones((4000, 1))
        z1, _ = sde_solver.sdeint_scan(
            lambda z, t: mu * z, lambda z, t: sig * z, z0, 0.0, 1.0, KEY,
            rtol=1e-3, atol=1e-3, max_steps=512,
        )
        expect = np.exp(mu + 0.5 * sig**2)
        assert abs(float(jnp.mean(z1)) - expect) / expect < 0.05

    def test_ou_variance(self):
        sig = 0.5
        z0 = jnp.zeros((4000, 1))
        z1, _ = sde_solver.sdeint_scan(
            F, lambda z, t: sig * jnp.ones_like(z), z0, 0.0, 4.0, KEY,
            rtol=1e-3, atol=1e-3, max_steps=1024,
        )
        var = float(jnp.var(z1))
        expect = sig**2 / 2
        assert abs(var - expect) / expect < 0.15, var

    def test_nfe_four_per_attempt(self):
        _, st = sde_solver.sdeint_scan(
            F, G01, jnp.ones((2, 2)), 0.0, 1.0, KEY, rtol=1e-3,
            atol=1e-3, max_steps=256,
        )
        attempts = float(st.naccept) + float(st.nreject)
        assert float(st.nfe) == 4.0 * attempts

    def test_different_keys_different_paths(self):
        z0 = jnp.ones((2, 2))
        z_a, _ = sde_solver.sdeint_scan(
            F, G01, z0, 0.0, 1.0, jax.random.PRNGKey(1), rtol=1e-3,
            atol=1e-3, max_steps=128,
        )
        z_b, _ = sde_solver.sdeint_scan(
            F, G01, z0, 0.0, 1.0, jax.random.PRNGKey(2), rtol=1e-3,
            atol=1e-3, max_steps=128,
        )
        assert not np.allclose(z_a, z_b)

    def test_same_key_reproducible(self):
        z0 = jnp.ones((2, 2))
        runs = [
            sde_solver.sdeint_scan(
                F, G01, z0, 0.0, 1.0, KEY, rtol=1e-3, atol=1e-3,
                max_steps=128,
            )[0]
            for _ in range(2)
        ]
        np.testing.assert_array_equal(runs[0], runs[1])

    def test_saveat_shapes_and_success(self):
        ts = jnp.linspace(0.0, 1.0, 30)
        zs, st = sde_solver.sdeint_save_scan(
            F, G01, jnp.ones((8, 2)), ts, KEY, rtol=1e-2, atol=1e-2,
            steps_per_segment=8,
        )
        assert zs.shape == (30, 8, 2)
        np.testing.assert_allclose(zs[0], 1.0)
        assert float(st.success) == 1.0

    def test_saveat_while_statistically_matches_scan(self):
        # NOTE: scan and while variants consume PRNG keys differently (the
        # masked scan splits a key on *every* bounded iteration, the while
        # loop only on live ones), so individual paths differ; the solved
        # *distribution* must agree.  Deterministic-path equality is covered
        # by test_while_matches_scan on the single-span API, where budget
        # and live iterations coincide for these tolerances.
        ts = jnp.linspace(0.0, 1.0, 10)
        z0 = jnp.ones((256, 2))
        a = sde_solver.sdeint_save_scan(
            F, G01, z0, ts, KEY, rtol=1e-2, atol=1e-2, steps_per_segment=12
        )
        b = sde_solver.sdeint_save_while(
            F, G01, z0, ts, jax.random.PRNGKey(5), rtol=1e-2, atol=1e-2
        )
        np.testing.assert_allclose(a[0][0], b[0][0], atol=1e-7)  # z0 row
        np.testing.assert_allclose(
            jnp.mean(a[0][-1]), jnp.mean(b[0][-1]), atol=0.02
        )
        np.testing.assert_allclose(
            jnp.std(a[0][-1]), jnp.std(b[0][-1]), atol=0.02
        )


class TestSdeAdjoint:
    def test_grad_finite_and_nonzero(self):
        def loss(a):
            z1, st = sde_solver.sdeint_scan(
                lambda z, t: -a * z, G01, jnp.ones((8, 2)), 0.0, 1.0, KEY,
                rtol=1e-3, atol=1e-3, max_steps=256,
            )
            return jnp.mean(z1**2) + 0.1 * st.r_e + 0.01 * st.r_s

        g = float(jax.grad(loss)(jnp.float32(1.0)))
        assert np.isfinite(g) and g != 0.0

    def test_grad_sign_matches_decay(self):
        # increasing decay rate must decrease E[z^2]
        def loss(a):
            z1, _ = sde_solver.sdeint_scan(
                lambda z, t: -a * z, GZERO, jnp.ones((4, 1)), 0.0, 1.0,
                KEY, rtol=1e-4, atol=1e-4, max_steps=256,
            )
            return jnp.mean(z1**2)

        assert float(jax.grad(loss)(jnp.float32(1.0))) < 0.0

    def test_diffusion_grad_flows(self):
        def loss(s):
            z1, _ = sde_solver.sdeint_scan(
                F, lambda z, t: s * jnp.ones_like(z), jnp.ones((64, 2)),
                0.0, 1.0, KEY, rtol=1e-2, atol=1e-2, max_steps=128,
            )
            return jnp.var(z1)

        g = float(jax.grad(loss)(jnp.float32(0.3)))
        assert np.isfinite(g) and g > 0.0  # more noise -> more variance
