"""AOT pipeline: HLO text emission + manifest integrity.

Full-artifact emission is exercised by `make artifacts`; here we lower one
small artifact end-to-end and check the manifest contract the Rust runtime
relies on (names, shapes, dtypes, budget metadata).
"""
import json
import os

import jax
import jax.numpy as jnp
import pytest

from compile import aot

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


class TestToHloText:
    def test_simple_function(self):
        lowered = jax.jit(lambda x: (x * 2.0,)).lower(
            jax.ShapeDtypeStruct((4,), jnp.float32)
        )
        text = aot.to_hlo_text(lowered)
        assert "HloModule" in text
        assert "f32[4]" in text

    def test_no_mosaic_custom_calls(self):
        # interpret=True pallas must lower to plain HLO (no custom-call the
        # CPU PJRT client can't run)
        from compile.kernels import dense_act

        lowered = jax.jit(
            lambda x, w, b: (dense_act(x, w, b, "tanh"),),
            keep_unused=True,
        ).lower(
            jax.ShapeDtypeStruct((8, 16), jnp.float32),
            jax.ShapeDtypeStruct((16, 4), jnp.float32),
            jax.ShapeDtypeStruct((4,), jnp.float32),
        )
        text = aot.to_hlo_text(lowered)
        assert "custom-call" not in text


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ART_DIR, "manifest.json")),
    reason="artifacts not built (run `make artifacts`)",
)
class TestManifest:
    @pytest.fixture(scope="class")
    def manifest(self):
        with open(os.path.join(ART_DIR, "manifest.json")) as f:
            return json.load(f)

    def test_metrics_layout(self, manifest):
        assert manifest["metrics_layout"] == [
            "loss", "metric", "nfe", "naccept", "nreject", "success",
            "r_e", "r_s", "r_aux",
        ]

    def test_every_artifact_file_exists(self, manifest):
        for name, a in manifest["artifacts"].items():
            path = os.path.join(ART_DIR, a["file"])
            assert os.path.exists(path), name
            assert os.path.getsize(path) > 100, name

    def test_models_have_ladders(self, manifest):
        for model in ("mnist_node", "latent_ode", "spiral_node",
                      "spiral_nsde", "mnist_nsde"):
            rungs = [
                a for a in manifest["artifacts"].values()
                if a["model"] == model and a["kind"] == "train"
            ]
            assert len(rungs) >= 2, f"{model} needs a budget ladder"
            budgets = sorted(r["meta"]["budget"] for r in rungs)
            assert budgets == sorted(set(budgets))

    def test_param_sizes_consistent(self, manifest):
        for name, a in manifest["artifacts"].items():
            if a["kind"] in ("train", "tay_train"):
                p = manifest["models"][a["model"]]["params_size"]
                s = manifest["models"][a["model"]]["opt_state_size"]
                ins = {i["name"]: i for i in a["inputs"]}
                assert ins["params"]["shape"] == [p], name
                assert ins["opt_state"]["shape"] == [s], name
                # outputs: params, opt_state, metrics[9]
                assert a["outputs"][0]["shape"] == [p], name
                assert a["outputs"][1]["shape"] == [s], name
                assert a["outputs"][2]["shape"] == [9], name

    def test_init_artifacts_take_u32_seed(self, manifest):
        for name, a in manifest["artifacts"].items():
            if a["kind"] == "init":
                assert len(a["inputs"]) == 1, name
                assert a["inputs"][0]["dtype"] == "u32", name

    def test_hyperparams_match_paper(self, manifest):
        h1 = manifest["models"]["mnist_node"]["paper_hyperparams"]
        assert h1["coef_e_start"] == 100.0 and h1["coef_e_end"] == 10.0
        assert h1["coef_s"] == 0.0285
        h2 = manifest["models"]["latent_ode"]["paper_hyperparams"]
        assert h2["coef_e_start"] == 1000.0 and h2["coef_s"] == 0.285
        h4 = manifest["models"]["mnist_nsde"]["paper_hyperparams"]
        assert h4["coef_e"] == 10.0 and h4["coef_s"] == 0.1
