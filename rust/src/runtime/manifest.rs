//! Typed view of `artifacts/manifest.json` (produced by compile/aot.py).
//!
//! The manifest is the single contract between the build-time Python layer
//! and the Rust runtime: artifact file names, exact input/output tensor
//! specs, per-model parameter/optimizer-state sizes and the paper's
//! hyper-parameters.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// One tensor in an artifact signature.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String, // "f32" | "u32"
}

impl TensorSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One lowered HLO artifact.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: PathBuf,
    pub model: String,
    pub kind: String, // init | train | tay_train | predict | solve
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    /// Step budget for train artifacts (the budget-ladder rung).
    pub budget: Option<usize>,
}

/// Per-model metadata.
#[derive(Clone, Debug)]
pub struct ModelSpec {
    pub name: String,
    pub params_size: usize,
    pub opt_state_size: usize,
    pub optimizer: String,
    /// Paper hyper-parameters (lr, regularization coefficients, ...).
    pub hyper: BTreeMap<String, f64>,
}

/// The parsed manifest.
#[derive(Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub metrics_layout: Vec<String>,
    pub models: BTreeMap<String, ModelSpec>,
    pub artifacts: BTreeMap<String, ArtifactSpec>,
}

fn tensor_list(j: &Json, with_names: bool) -> Result<Vec<TensorSpec>> {
    j.as_arr()?
        .iter()
        .enumerate()
        .map(|(i, t)| {
            Ok(TensorSpec {
                name: if with_names {
                    t.get("name")?.as_str()?.to_string()
                } else {
                    format!("out{i}")
                },
                shape: t
                    .get("shape")?
                    .as_arr()?
                    .iter()
                    .map(|d| d.as_usize())
                    .collect::<Result<_>>()?,
                dtype: t.get("dtype")?.as_str()?.to_string(),
            })
        })
        .collect()
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let root = Json::parse(&text).context("parsing manifest.json")?;

        let metrics_layout = root
            .get("metrics_layout")?
            .as_arr()?
            .iter()
            .map(|v| Ok(v.as_str()?.to_string()))
            .collect::<Result<_>>()?;

        let mut models = BTreeMap::new();
        for (name, m) in root.get("models")?.as_obj()? {
            let mut hyper = BTreeMap::new();
            if let Some(h) = m.opt("paper_hyperparams") {
                for (k, v) in h.as_obj()? {
                    if let Json::Num(x) = v {
                        hyper.insert(k.clone(), *x);
                    }
                }
            }
            models.insert(
                name.clone(),
                ModelSpec {
                    name: name.clone(),
                    params_size: m.get("params_size")?.as_usize()?,
                    opt_state_size: m.get("opt_state_size")?.as_usize()?,
                    optimizer: m.get("optimizer")?.as_str()?.to_string(),
                    hyper,
                },
            );
        }

        let mut artifacts = BTreeMap::new();
        for (name, a) in root.get("artifacts")?.as_obj()? {
            let budget = a
                .opt("meta")
                .and_then(|m| m.opt("budget"))
                .and_then(|b| b.as_f64().ok())
                .map(|b| b as usize);
            artifacts.insert(
                name.clone(),
                ArtifactSpec {
                    name: name.clone(),
                    file: dir.join(a.get("file")?.as_str()?),
                    model: a.get("model")?.as_str()?.to_string(),
                    kind: a.get("kind")?.as_str()?.to_string(),
                    inputs: tensor_list(a.get("inputs")?, true)?,
                    outputs: tensor_list(a.get("outputs")?, false)?,
                    budget,
                },
            );
        }

        Ok(Manifest {
            dir,
            metrics_layout,
            models,
            artifacts,
        })
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactSpec> {
        match self.artifacts.get(name) {
            Some(a) => Ok(a),
            None => bail!(
                "artifact {name:?} not in manifest (have: {:?})",
                self.artifacts.keys().take(8).collect::<Vec<_>>()
            ),
        }
    }

    pub fn model(&self, name: &str) -> Result<&ModelSpec> {
        match self.models.get(name) {
            Some(m) => Ok(m),
            None => bail!("model {name:?} not in manifest"),
        }
    }

    /// Train-artifact budget ladder for a model, ascending by budget.
    /// `tay` selects the TayNODE variants instead of the plain ones.
    pub fn train_ladder(&self, model: &str, tay: bool) -> Vec<&ArtifactSpec> {
        let kind = if tay { "tay_train" } else { "train" };
        let mut rungs: Vec<&ArtifactSpec> = self
            .artifacts
            .values()
            .filter(|a| a.model == model && a.kind == kind)
            .collect();
        rungs.sort_by_key(|a| a.budget.unwrap_or(usize::MAX));
        rungs
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn manifest_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    #[test]
    fn loads_real_manifest() {
        let m = Manifest::load(manifest_dir()).unwrap();
        assert_eq!(m.metrics_layout.len(), 9);
        assert!(m.models.contains_key("mnist_node"));
        let a = m.artifact("mnist_node_train_b32").unwrap();
        assert_eq!(a.kind, "train");
        assert_eq!(a.budget, Some(32));
        assert_eq!(a.inputs[0].name, "params");
        assert_eq!(
            a.inputs[0].numel(),
            m.model("mnist_node").unwrap().params_size
        );
    }

    #[test]
    fn ladder_sorted_ascending() {
        let m = Manifest::load(manifest_dir()).unwrap();
        let ladder = m.train_ladder("mnist_node", false);
        assert!(ladder.len() >= 2);
        let budgets: Vec<usize> = ladder.iter().map(|a| a.budget.unwrap()).collect();
        let mut sorted = budgets.clone();
        sorted.sort_unstable();
        assert_eq!(budgets, sorted);
        // tay ladder is separate
        let tay = m.train_ladder("mnist_node", true);
        assert!(tay.iter().all(|a| a.kind == "tay_train"));
    }

    #[test]
    fn unknown_artifact_errors() {
        let m = Manifest::load(manifest_dir()).unwrap();
        assert!(m.artifact("nope").is_err());
    }
}
