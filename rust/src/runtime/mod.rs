//! PJRT runtime: load AOT artifacts, execute them on the hot path.
//!
//! Wraps the `xla` crate (PJRT C API, CPU plugin):
//! `HloModuleProto::from_text_file` -> `XlaComputation::from_proto` ->
//! `client.compile` -> `execute`.  HLO *text* is the interchange format
//! (see python/compile/aot.py for why).  Python never runs here.
//!
//! Structure:
//!  * `manifest` — typed view of artifacts/manifest.json,
//!  * `engine`   — client + lazily-compiled executable cache + typed
//!                 input/output marshalling,
//!  * `state`    — flat parameter/optimizer vectors and the standard
//!                 9-element metric block shared by all artifacts.

pub mod engine;
pub mod manifest;
pub mod state;

pub use engine::{Engine, Input};
pub use manifest::{ArtifactSpec, Manifest, ModelSpec, TensorSpec};
pub use state::{Metrics, TrainState};
