//! Training runtimes behind the [`Backend`] seam.
//!
//! * `backend` — the trait every experiment driver is generic over, plus
//!   the shared payload types (`TrainData`, `StepCoefs`, `StepOutput`,
//!   `ModelInfo`, `Input`),
//! * `native`  — pure-Rust differentiable training (flat-parameter MLPs,
//!   discrete adjoints through the native adaptive solvers, Adam).  The
//!   default: no artifacts, no XLA, runs in tier-1 CI,
//! * `state`   — flat parameter/optimizer vectors and the standard
//!   9-element metric block shared by both backends,
//! * `engine` / `manifest` (feature `pjrt`) — the AOT path: typed view of
//!   `artifacts/manifest.json`, PJRT client + compiled-executable cache +
//!   typed input/output marshalling.  HLO *text* is the interchange
//!   format (see python/compile/aot.py); Python never runs here.

pub mod backend;
pub mod native;
pub mod state;

#[cfg(feature = "pjrt")]
pub mod engine;
#[cfg(feature = "pjrt")]
pub mod manifest;

pub use backend::{
    Backend, ExportedState, GradOutput, Input, ModelInfo, StepCoefs, StepOutput, TrainData,
};
pub use native::NativeBackend;
pub use state::{Metrics, TrainState};

#[cfg(feature = "pjrt")]
pub use engine::Engine;
#[cfg(feature = "pjrt")]
pub use manifest::{ArtifactSpec, Manifest, ModelSpec, TensorSpec};

/// Construct a backend by name.
///
/// * `"native"` — always available; `solver` selects its RK tableau by
///   name (`Tableau::parse`, case-insensitive; default `tsit5`).
/// * `"pjrt"`   — requires the `pjrt` cargo feature *and* compiled
///   artifacts under `artifacts_dir`; its solver is baked into the
///   lowered artifacts, so `solver` must be `None`.
pub fn make_backend(
    name: &str,
    artifacts_dir: &std::path::Path,
    solver: Option<&str>,
) -> anyhow::Result<Box<dyn Backend>> {
    match name {
        "native" => {
            let be = NativeBackend::new();
            let be = match solver {
                Some(s) => be.with_solver(s)?,
                None => be,
            };
            Ok(Box::new(be))
        }
        #[cfg(feature = "pjrt")]
        "pjrt" => {
            anyhow::ensure!(
                solver.is_none(),
                "--solver is native-only: the PJRT artifacts bake their tableau in at lowering"
            );
            Ok(Box::new(Engine::new(artifacts_dir)?))
        }
        #[cfg(not(feature = "pjrt"))]
        "pjrt" => {
            let _ = (artifacts_dir, solver);
            anyhow::bail!(
                "this build has no PJRT support — rebuild with `--features pjrt` \
                 (and real xla-rs bindings in place of the vendored stub)"
            )
        }
        other => anyhow::bail!("unknown backend {other:?} (native|pjrt)"),
    }
}

/// Backend selected by the `REGNDE_BACKEND` env var (default `"native"`).
pub fn backend_from_env(artifacts_dir: &std::path::Path) -> anyhow::Result<Box<dyn Backend>> {
    let name = std::env::var("REGNDE_BACKEND").unwrap_or_else(|_| "native".to_string());
    make_backend(&name, artifacts_dir, None)
}
