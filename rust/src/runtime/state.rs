//! Training state and the standard metric block.
//!
//! Parameters and optimizer state are opaque flat f32 vectors whose sizes
//! come from the manifest; `Metrics` decodes the standard 9-element vector
//! every artifact returns (python/compile/models/common.py METRICS_LAYOUT).

use anyhow::{bail, Result};

use crate::solvers::error::SolveErrorKind;

/// Decoded standard metric vector.
#[derive(Clone, Copy, Debug, Default)]
pub struct Metrics {
    pub loss: f64,
    /// Task metric: accuracy (classification) or MSE (regression).
    pub metric: f64,
    pub nfe: f64,
    pub naccept: f64,
    pub nreject: f64,
    pub success: bool,
    /// Typed failure class when `success` is false (native backend; the
    /// 9-element artifact vector only carries a boolean, decoded as
    /// `BudgetExhausted` — the only failure the PJRT lowering can hit).
    /// The budget router keys its skip/escalate policy off this.
    pub error: Option<SolveErrorKind>,
    pub r_e: f64,
    /// `Σ E_j²` — the unsquared-mean R_E variant (§4.1.2 note), the
    /// natural diagnostic for tolerance sweeps.  Native backend only; the
    /// 9-element artifact vector does not carry it (decoded as 0).
    pub r_e2: f64,
    pub r_s: f64,
    /// Sampled-step local regularizer value `R_L = E_ĵ |h_ĵ|`
    /// (LRNODE/LRNSDE).  Native backend only; the 9-element artifact
    /// vector does not carry it (decoded as 0).
    pub r_l: f64,
    pub r_aux: f64,
}

impl Metrics {
    pub fn decode(v: &[f32]) -> Result<Metrics> {
        if v.len() != 9 {
            bail!("metric vector has {} elements, expected 9", v.len());
        }
        let success = v[5] > 0.5;
        Ok(Metrics {
            loss: v[0] as f64,
            metric: v[1] as f64,
            nfe: v[2] as f64,
            naccept: v[3] as f64,
            nreject: v[4] as f64,
            success,
            error: if success {
                None
            } else {
                Some(SolveErrorKind::BudgetExhausted)
            },
            r_e: v[6] as f64,
            r_e2: 0.0,
            r_s: v[7] as f64,
            r_l: 0.0,
            r_aux: v[8] as f64,
        })
    }
}

/// Flat parameter + optimizer-state vectors for one model replica.
#[derive(Clone, Debug)]
pub struct TrainState {
    pub params: Vec<f32>,
    pub opt_state: Vec<f32>,
    /// Completed optimizer iterations (drives lr inverse decay at L3).
    pub iter: u64,
}

impl TrainState {
    pub fn new(params: Vec<f32>, opt_state_size: usize) -> TrainState {
        TrainState {
            params,
            opt_state: vec![0.0; opt_state_size],
            iter: 0,
        }
    }

    /// Install the outputs of a train artifact (new params + opt state).
    pub fn update(&mut self, params: Vec<f32>, opt_state: Vec<f32>) -> Result<()> {
        if params.len() != self.params.len() || opt_state.len() != self.opt_state.len() {
            bail!(
                "state size changed: params {} -> {}, opt {} -> {}",
                self.params.len(),
                params.len(),
                self.opt_state.len(),
                opt_state.len()
            );
        }
        self.params = params;
        self.opt_state = opt_state;
        self.iter += 1;
        Ok(())
    }

    /// L2 norm of the parameters — cheap NaN/blow-up tripwire.
    pub fn param_norm(&self) -> f64 {
        self.params
            .iter()
            .map(|&p| (p as f64) * (p as f64))
            .sum::<f64>()
            .sqrt()
    }

    pub fn is_finite(&self) -> bool {
        self.params.iter().all(|p| p.is_finite())
            && self.opt_state.iter().all(|p| p.is_finite())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn metrics_decode() {
        let v = [1.5, 0.9, 253.0, 42.0, 3.0, 1.0, 0.01, 2.5, 0.0];
        let m = Metrics::decode(&v).unwrap();
        assert_eq!(m.loss, 1.5);
        assert_eq!(m.nfe, 253.0);
        assert!(m.success);
        assert_eq!(m.error, None);
        assert!(Metrics::decode(&v[..5]).is_err());

        let mut failed = v;
        failed[5] = 0.0;
        let m = Metrics::decode(&failed).unwrap();
        assert!(!m.success);
        assert_eq!(m.error, Some(SolveErrorKind::BudgetExhausted));
    }

    #[test]
    fn state_update_checks_sizes() {
        let mut s = TrainState::new(vec![0.0; 4], 5);
        assert!(s.update(vec![1.0; 4], vec![1.0; 5]).is_ok());
        assert_eq!(s.iter, 1);
        assert!(s.update(vec![1.0; 3], vec![1.0; 5]).is_err());
    }

    #[test]
    fn finiteness_tripwire() {
        let mut s = TrainState::new(vec![1.0; 3], 2);
        assert!(s.is_finite());
        s.params[1] = f32::NAN;
        assert!(!s.is_finite());
        assert!(s.param_norm().is_nan());
    }
}
