//! Executable engine: compile cache + typed execution.
//!
//! One `Engine` owns the PJRT CPU client and a cache of compiled
//! executables keyed by artifact name.  Inputs are validated against the
//! manifest specs before execution (shape mismatches fail fast with the
//! tensor name, not an opaque XLA error).

use std::collections::HashMap;
use std::sync::Mutex;

use anyhow::{bail, Context, Result};

use super::manifest::{ArtifactSpec, Manifest, TensorSpec};

/// A typed runtime input.
#[derive(Clone, Debug)]
pub enum Input<'a> {
    /// Dense f32 tensor (row-major); shape checked against the spec.
    F32(&'a [f32]),
    /// f32 scalar.
    Scalar(f32),
    /// u32 scalar (RNG seeds).
    SeedU32(u32),
}

pub struct Engine {
    pub manifest: Manifest,
    client: xla::PjRtClient,
    cache: Mutex<HashMap<String, std::sync::Arc<xla::PjRtLoadedExecutable>>>,
}

impl Engine {
    /// Create the PJRT CPU client and load the manifest from `dir`.
    pub fn new(dir: impl AsRef<std::path::Path>) -> Result<Engine> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Engine {
            manifest,
            client,
            cache: Mutex::new(HashMap::new()),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch from cache) an artifact's executable.
    pub fn load(&self, name: &str) -> Result<std::sync::Arc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.lock().unwrap().get(name) {
            return Ok(exe.clone());
        }
        let spec = self.manifest.artifact(name)?;
        let proto = xla::HloModuleProto::from_text_file(
            spec.file
                .to_str()
                .context("artifact path not valid UTF-8")?,
        )
        .with_context(|| format!("parsing HLO text for {name}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling artifact {name}"))?;
        let exe = std::sync::Arc::new(exe);
        self.cache
            .lock()
            .unwrap()
            .insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Pre-compile a set of artifacts (amortizes JIT cost up front).
    pub fn warm(&self, names: &[&str]) -> Result<()> {
        for n in names {
            self.load(n)?;
        }
        Ok(())
    }

    fn literal(&self, spec: &TensorSpec, input: &Input) -> Result<xla::Literal> {
        let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
        match (input, spec.dtype.as_str()) {
            (Input::F32(data), "f32") => {
                if data.len() != spec.numel() {
                    bail!(
                        "input {:?}: got {} elements, spec {:?} wants {}",
                        spec.name,
                        data.len(),
                        spec.shape,
                        spec.numel()
                    );
                }
                Ok(xla::Literal::vec1(data).reshape(&dims)?)
            }
            (Input::Scalar(x), "f32") => {
                if !spec.shape.is_empty() {
                    bail!("input {:?} is not a scalar: {:?}", spec.name, spec.shape);
                }
                Ok(xla::Literal::scalar(*x))
            }
            (Input::SeedU32(x), "u32") => Ok(xla::Literal::scalar(*x)),
            (i, d) => bail!("input {:?}: dtype mismatch {i:?} vs {d}", spec.name),
        }
    }

    /// Execute an artifact; returns the output tuple as f32 vectors.
    ///
    /// Every artifact is lowered with `return_tuple=True`, so the single
    /// result buffer is a tuple literal; elements are decoded per the
    /// manifest output specs.
    pub fn run(&self, name: &str, inputs: &[Input]) -> Result<Vec<Vec<f32>>> {
        let spec = self.manifest.artifact(name)?.clone();
        self.run_spec(&spec, inputs)
    }

    /// Like [`run`] but with a pre-fetched spec (hot path: no map lookups).
    pub fn run_spec(&self, spec: &ArtifactSpec, inputs: &[Input]) -> Result<Vec<Vec<f32>>> {
        if inputs.len() != spec.inputs.len() {
            bail!(
                "artifact {}: got {} inputs, expected {} ({:?})",
                spec.name,
                inputs.len(),
                spec.inputs.len(),
                spec.inputs.iter().map(|t| &t.name).collect::<Vec<_>>()
            );
        }
        let exe = self.load(&spec.name)?;
        let lits: Vec<xla::Literal> = spec
            .inputs
            .iter()
            .zip(inputs)
            .map(|(t, i)| self.literal(t, i))
            .collect::<Result<_>>()?;
        let result = exe
            .execute::<xla::Literal>(&lits)
            .with_context(|| format!("executing {}", spec.name))?;
        let tuple = result[0][0]
            .to_literal_sync()?
            .to_tuple()
            .with_context(|| format!("untupling outputs of {}", spec.name))?;
        if tuple.len() != spec.outputs.len() {
            bail!(
                "artifact {}: {} outputs returned, manifest says {}",
                spec.name,
                tuple.len(),
                spec.outputs.len()
            );
        }
        tuple
            .into_iter()
            .zip(&spec.outputs)
            .map(|(lit, ospec)| {
                let v = lit
                    .to_vec::<f32>()
                    .with_context(|| format!("decoding output {:?}", ospec.name))?;
                if v.len() != ospec.numel() {
                    bail!(
                        "output {:?}: got {} elements, expected {}",
                        ospec.name,
                        v.len(),
                        ospec.numel()
                    );
                }
                Ok(v)
            })
            .collect()
    }

    /// Initialize model parameters on-device from a seed.
    pub fn init_params(&self, model: &str, seed: u32) -> Result<Vec<f32>> {
        let mut out = self.run(&format!("{model}_init"), &[Input::SeedU32(seed)])?;
        Ok(out.remove(0))
    }
}
