//! Executable engine: compile cache + typed execution.
//!
//! One `Engine` owns the PJRT CPU client and a cache of compiled
//! executables keyed by artifact name.  Inputs are validated against the
//! manifest specs before execution (shape mismatches fail fast with the
//! tensor name, not an opaque XLA error).

use std::collections::HashMap;
use std::sync::Mutex;

use anyhow::{bail, Context, Result};

use super::backend::{Backend, ModelInfo, StepCoefs, StepOutput, TrainData};
use super::manifest::{ArtifactSpec, Manifest, TensorSpec};
use super::state::{Metrics, TrainState};

pub use super::backend::Input;

pub struct Engine {
    pub manifest: Manifest,
    client: xla::PjRtClient,
    cache: Mutex<HashMap<String, std::sync::Arc<xla::PjRtLoadedExecutable>>>,
}

impl Engine {
    /// Create the PJRT CPU client and load the manifest from `dir`.
    pub fn new(dir: impl AsRef<std::path::Path>) -> Result<Engine> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Engine {
            manifest,
            client,
            cache: Mutex::new(HashMap::new()),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch from cache) an artifact's executable.
    pub fn load(&self, name: &str) -> Result<std::sync::Arc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.lock().unwrap().get(name) {
            return Ok(exe.clone());
        }
        let spec = self.manifest.artifact(name)?;
        let proto = xla::HloModuleProto::from_text_file(
            spec.file
                .to_str()
                .context("artifact path not valid UTF-8")?,
        )
        .with_context(|| format!("parsing HLO text for {name}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling artifact {name}"))?;
        let exe = std::sync::Arc::new(exe);
        self.cache
            .lock()
            .unwrap()
            .insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Pre-compile a set of artifacts (amortizes JIT cost up front).
    pub fn warm(&self, names: &[&str]) -> Result<()> {
        for n in names {
            self.load(n)?;
        }
        Ok(())
    }

    fn literal(&self, spec: &TensorSpec, input: &Input) -> Result<xla::Literal> {
        let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
        match (input, spec.dtype.as_str()) {
            (Input::F32(data), "f32") => {
                if data.len() != spec.numel() {
                    bail!(
                        "input {:?}: got {} elements, spec {:?} wants {}",
                        spec.name,
                        data.len(),
                        spec.shape,
                        spec.numel()
                    );
                }
                Ok(xla::Literal::vec1(data).reshape(&dims)?)
            }
            (Input::Scalar(x), "f32") => {
                if !spec.shape.is_empty() {
                    bail!("input {:?} is not a scalar: {:?}", spec.name, spec.shape);
                }
                Ok(xla::Literal::scalar(*x))
            }
            (Input::SeedU32(x), "u32") => Ok(xla::Literal::scalar(*x)),
            (i, d) => bail!("input {:?}: dtype mismatch {i:?} vs {d}", spec.name),
        }
    }

    /// Execute an artifact; returns the output tuple as f32 vectors.
    ///
    /// Every artifact is lowered with `return_tuple=True`, so the single
    /// result buffer is a tuple literal; elements are decoded per the
    /// manifest output specs.
    pub fn run(&self, name: &str, inputs: &[Input]) -> Result<Vec<Vec<f32>>> {
        let spec = self.manifest.artifact(name)?.clone();
        self.run_spec(&spec, inputs)
    }

    /// Like [`run`] but with a pre-fetched spec (hot path: no map lookups).
    pub fn run_spec(&self, spec: &ArtifactSpec, inputs: &[Input]) -> Result<Vec<Vec<f32>>> {
        if inputs.len() != spec.inputs.len() {
            bail!(
                "artifact {}: got {} inputs, expected {} ({:?})",
                spec.name,
                inputs.len(),
                spec.inputs.len(),
                spec.inputs.iter().map(|t| &t.name).collect::<Vec<_>>()
            );
        }
        let exe = self.load(&spec.name)?;
        let lits: Vec<xla::Literal> = spec
            .inputs
            .iter()
            .zip(inputs)
            .map(|(t, i)| self.literal(t, i))
            .collect::<Result<_>>()?;
        let result = exe
            .execute::<xla::Literal>(&lits)
            .with_context(|| format!("executing {}", spec.name))?;
        let tuple = result[0][0]
            .to_literal_sync()?
            .to_tuple()
            .with_context(|| format!("untupling outputs of {}", spec.name))?;
        if tuple.len() != spec.outputs.len() {
            bail!(
                "artifact {}: {} outputs returned, manifest says {}",
                spec.name,
                tuple.len(),
                spec.outputs.len()
            );
        }
        tuple
            .into_iter()
            .zip(&spec.outputs)
            .map(|(lit, ospec)| {
                let v = lit
                    .to_vec::<f32>()
                    .with_context(|| format!("decoding output {:?}", ospec.name))?;
                if v.len() != ospec.numel() {
                    bail!(
                        "output {:?}: got {} elements, expected {}",
                        ospec.name,
                        v.len(),
                        ospec.numel()
                    );
                }
                Ok(v)
            })
            .collect()
    }

    /// Initialize model parameters on-device from a seed.
    pub fn init_params(&self, model: &str, seed: u32) -> Result<Vec<f32>> {
        let mut out = self.run(&format!("{model}_init"), &[Input::SeedU32(seed)])?;
        Ok(out.remove(0))
    }

    /// Ladder artifact for `(model, tay)` at `rung` (borrowed — the train
    /// hot path must not clone tensor specs per step).
    fn train_artifact(&self, model: &str, tay: bool, rung: usize) -> Result<&ArtifactSpec> {
        let ladder = self.manifest.train_ladder(model, tay);
        match ladder.get(rung) {
            Some(a) => Ok(*a),
            None => bail!(
                "rung {rung} out of ladder for {model} (len {})",
                ladder.len()
            ),
        }
    }
}

/// The AOT path behind the backend seam: artifact input lists are
/// assembled per model in the exact positional order the lowering
/// declares (python/compile/aot.py).
impl Backend for Engine {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn models(&self) -> Vec<String> {
        self.manifest.models.keys().cloned().collect()
    }

    fn model(&self, model: &str) -> Result<ModelInfo> {
        let m = self.manifest.model(model)?;
        Ok(ModelInfo {
            name: m.name.clone(),
            params_size: m.params_size,
            opt_state_size: m.opt_state_size,
            optimizer: m.optimizer.clone(),
            hyper: m.hyper.clone(),
        })
    }

    fn ladder(&self, model: &str, tay: bool) -> Result<Vec<usize>> {
        let rungs: Vec<usize> = self
            .manifest
            .train_ladder(model, tay)
            .iter()
            .map(|a| a.budget.unwrap_or(usize::MAX))
            .collect();
        if rungs.is_empty() {
            bail!("no train artifacts for {model}");
        }
        Ok(rungs)
    }

    fn init_params(&self, model: &str, seed: u32) -> Result<Vec<f32>> {
        Engine::init_params(self, model, seed)
    }

    fn warm(&self, model: &str, tay: bool) -> Result<()> {
        for art in self.manifest.train_ladder(model, tay) {
            self.load(&art.name)?;
        }
        self.load(&format!("{model}_predict"))?;
        Ok(())
    }

    fn train_step(
        &self,
        model: &str,
        tay: bool,
        rung: usize,
        state: &TrainState,
        data: &TrainData,
        coefs: &StepCoefs,
    ) -> Result<StepOutput> {
        let art = self.train_artifact(model, tay, rung)?;
        let lr = Input::Scalar(coefs.lr);
        let ce = Input::Scalar(coefs.coef_e);
        let cs = Input::Scalar(coefs.coef_s);
        let mut inputs = vec![Input::F32(&state.params), Input::F32(&state.opt_state)];
        match (model, *data) {
            ("spiral_node", TrainData::Trajectory { data, ts }) => {
                inputs.extend([Input::F32(data), Input::F32(ts), lr, ce, cs]);
            }
            ("spiral_nsde", TrainData::Moments { u0, mu, var, ts }) => {
                inputs.extend([
                    Input::F32(u0),
                    Input::F32(mu),
                    Input::F32(var),
                    Input::F32(ts),
                    lr,
                    ce,
                    cs,
                    Input::SeedU32(coefs.seed),
                ]);
            }
            ("mnist_node", TrainData::Classify { x, y }) => {
                inputs.extend([
                    Input::F32(x),
                    Input::F32(y),
                    lr,
                    ce,
                    cs,
                    Input::Scalar(coefs.coef_aux),
                    Input::Scalar(coefs.t1),
                ]);
            }
            ("mnist_nsde", TrainData::Classify { x, y }) => {
                inputs.extend([
                    Input::F32(x),
                    Input::F32(y),
                    lr,
                    ce,
                    cs,
                    Input::SeedU32(coefs.seed),
                ]);
            }
            ("latent_ode", TrainData::Series { x, mask, ts }) => {
                inputs.extend([
                    Input::F32(x),
                    Input::F32(mask),
                    Input::F32(ts),
                    lr,
                    ce,
                    cs,
                    Input::Scalar(coefs.coef_aux),
                    Input::Scalar(coefs.kl),
                    Input::SeedU32(coefs.seed),
                ]);
            }
            (m, d) => bail!("engine: model {m} cannot train on {:?} data", d.kind()),
        }
        let out = self
            .run_spec(art, &inputs)
            .with_context(|| format!("train step on {}", art.name))?;
        let [params, opt_state, metrics]: [Vec<f32>; 3] =
            out.try_into().ok().context("train step arity")?;
        let metrics = Metrics::decode(&metrics)?;
        Ok(StepOutput {
            params,
            opt_state,
            metrics,
        })
    }

    fn predict(
        &self,
        model: &str,
        params: &[f32],
        data: &TrainData,
        seed: u32,
    ) -> Result<(Vec<f32>, Metrics)> {
        let mut inputs = vec![Input::F32(params)];
        match (model, *data) {
            ("spiral_node", TrainData::Trajectory { data, ts }) => {
                inputs.extend([Input::F32(data), Input::F32(ts)]);
            }
            ("spiral_nsde", TrainData::Moments { u0, mu, var, ts }) => {
                inputs.extend([
                    Input::F32(u0),
                    Input::F32(mu),
                    Input::F32(var),
                    Input::F32(ts),
                    Input::SeedU32(seed),
                ]);
            }
            ("mnist_node", TrainData::Classify { x, y }) => {
                inputs.extend([Input::F32(x), Input::F32(y)]);
            }
            ("mnist_nsde", TrainData::Classify { x, y }) => {
                inputs.extend([Input::F32(x), Input::F32(y), Input::SeedU32(seed)]);
            }
            ("latent_ode", TrainData::Series { x, mask, ts }) => {
                inputs.extend([
                    Input::F32(x),
                    Input::F32(mask),
                    Input::F32(ts),
                    Input::SeedU32(seed),
                ]);
            }
            (m, d) => bail!("engine: model {m} cannot predict on {:?} data", d.kind()),
        }
        let mut out = self.run(&format!("{model}_predict"), &inputs)?;
        anyhow::ensure!(out.len() >= 2, "predict artifact must return [out, metrics]");
        let metrics = Metrics::decode(&out[1])?;
        Ok((out.remove(0), metrics))
    }
}
