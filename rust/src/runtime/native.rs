//! Native differentiable training backend — the paper's method with no
//! Python, no XLA, no artifacts.
//!
//! Each of the five experiment models is a composition of flat-parameter
//! MLPs (`models::mlp`) around the native adaptive solvers, packaged as
//! solver [`System`]s (`MlpOde` / `MlpSde`: row-batched dynamics + VJP
//! hooks on the vectorized `models::kernels` entry points
//! [`Mlp::forward_batch`] / [`Mlp::vjp_batch`] — one kernel pass per
//! layer over the whole batch, scratch sized at construction so solver
//! attempts stay allocation-free; DESIGN.md §Perf) and integrated
//! through the unified driver (`solvers::driver`):
//! the forward drive records a discrete-adjoint tape of the accepted
//! steps and feeds every step to a [`LocalReg`] observer, the backward
//! walk (`solvers::adjoint`) pulls the data loss *and* the white-boxed
//! regularizers — `R_E = Σ E_j |h_j|` (Eq. 9), the Shampine stiffness
//! ratio `R_S = Σ S_j` (Eq. 8/11), and the sampled-step local term
//! `R_L = E_ĵ |h_ĵ|` (LRNODE/LRNSDE, Pal et al. 2023) — back through
//! those steps, and Adam updates the same flat `TrainState` vectors the
//! PJRT artifacts use.  The update therefore sees exactly the objective
//! the metrics report:
//! `∇(data_loss + coef_e·R_E + coef_s·R_S + coef_l·R_L)`.
//!
//! The stiffness adjoint needs no extra tape storage: the ODE tape's
//! per-step record `[z_start | k_0 … k_{s-1}]` lets the backward pass
//! reconstruct the equal-`c` stage states `g_x`/`g_y` entering `S_j`
//! (`g_i = z + h Σ_j a_ij k_j`), and the SDE tape's `[z_start | ΔW]`
//! record lets it recompute the Heun internals behind the drift-based
//! surrogate.  The accepted step sequence (and the Brownian increments)
//! stay frozen exactly as for `R_E` — `ode_replay`/`sde_replay` re-run
//! that frozen program and return both accumulators so
//! `tests/adjoint_gradcheck.rs` can finite-difference the full SRNODE
//! objective.  TayNODE's high-order terms remain PJRT-only: the native
//! `tay` ladder aliases the plain one with `r_aux = 0` (avoiding exactly
//! the K-th-order AD the paper positions itself against).
//!
//! Parameter layouts (flat, in order):
//!
//! | model        | layout                                   |
//! |--------------|------------------------------------------|
//! | `spiral_node`| `[dyn]` cubed-MLP `[2,16,2]`             |
//! | `spiral_nsde`| `[drift | diffusion]`                    |
//! | `mnist_node` | `[enc | dyn | clf]`                      |
//! | `mnist_nsde` | `[enc | drift | diffusion | clf]`        |
//! | `latent_ode` | `[enc | dyn | dec]`                      |
//!
//! Budget-ladder semantics: each rung is a **total** step-attempt budget
//! for the train-time solve (summed over save segments, and over the
//! ensemble for `spiral_nsde`); exhausting it surfaces as a typed
//! [`SolveErrorKind::BudgetExhausted`] in [`Metrics::error`]
//! (`success = false`) so the coordinator's router escalates and retries
//! the batch.  Other failure classes (`NonFiniteState`,
//! `StepSizeUnderflow` — a diverging vector field, not an undersized
//! budget) are reported the same way but make the router *skip* the
//! batch instead of burning rungs on it (DESIGN.md §Robustness).

#![allow(clippy::too_many_arguments)]

use std::collections::BTreeMap;

use anyhow::{bail, ensure, Result};

use super::backend::{
    Backend, ExportedState, GradOutput, ModelInfo, StepCoefs, StepOutput, TrainData,
};
use super::state::{Metrics, TrainState};
use crate::models::{Adam, Mlp, MlpBatchScratch};
use crate::solvers::adjoint::{ode_backward_sys, sde_backward_sys, OdeTape, RegCoefs, SdeTape};
use crate::solvers::driver::{Saveat, SolveOptions, StepBudget};
use crate::solvers::error::{SolveErrorKind, SolveResultExt};
use crate::solvers::observer::{LocalReg, StepObserver};
use crate::solvers::ode::{self, Stats};
use crate::solvers::sde;
use crate::solvers::system::System;
use crate::solvers::tableau::Tableau;
use crate::util::rng::Rng;

/// Latent width shared by the MNIST models (encoder output / ODE state).
const MNIST_LATENT: usize = 16;
/// Latent width of the Latent ODE.
const LATENT_DIM: usize = 8;
/// Channels of the Physionet stand-in (mirrors `data::physionet_synth`).
const SERIES_CHANNELS: usize = 8;
/// MNIST classes / input dim (mirrors `data::mnist_synth`).
const CLASSES: usize = 10;
const IMG_DIM: usize = 784;
/// Driving paths averaged for NSDE prediction (paper uses 10; testbed 4).
const PREDICT_PATHS: usize = 4;

/// Architecture of one native model.
#[derive(Clone, Debug)]
enum Arch {
    SpiralNode {
        dynamics: Mlp,
    },
    SpiralNsde {
        drift: Mlp,
        diffusion: Mlp,
    },
    MnistNode {
        enc: Mlp,
        dynamics: Mlp,
        clf: Mlp,
    },
    MnistNsde {
        enc: Mlp,
        drift: Mlp,
        diffusion: Mlp,
        clf: Mlp,
    },
    LatentOde {
        enc: Mlp,
        dynamics: Mlp,
        dec: Mlp,
    },
}

impl Arch {
    fn parts(&self) -> Vec<&Mlp> {
        match self {
            Arch::SpiralNode { dynamics } => vec![dynamics],
            Arch::SpiralNsde { drift, diffusion } => vec![drift, diffusion],
            Arch::MnistNode { enc, dynamics, clf } => vec![enc, dynamics, clf],
            Arch::MnistNsde {
                enc,
                drift,
                diffusion,
                clf,
            } => vec![enc, drift, diffusion, clf],
            Arch::LatentOde { enc, dynamics, dec } => vec![enc, dynamics, dec],
        }
    }

    fn n_params(&self) -> usize {
        self.parts().iter().map(|m| m.n_params()).sum()
    }

    /// Flat-vector range of part `i` (parts in declaration order).
    fn range(&self, i: usize) -> std::ops::Range<usize> {
        let parts = self.parts();
        let start: usize = parts[..i].iter().map(|m| m.n_params()).sum();
        start..start + parts[i].n_params()
    }
}

#[derive(Clone, Debug)]
struct NativeModel {
    arch: Arch,
    ladder: Vec<usize>,
    hyper: BTreeMap<String, f64>,
    /// Train-time solver tolerance (rtol = atol).
    train_tol: f64,
    /// Inference tolerance (the "early-exiting predict" setting).
    predict_tol: f64,
}

/// Pure-Rust [`Backend`] over the five paper models.
pub struct NativeBackend {
    models: BTreeMap<String, NativeModel>,
    /// RK tableau of every ODE solve (train + predict); the SDE models'
    /// stochastic Heun scheme is fixed and ignores it.  Selected at the
    /// CLI boundary via `--solver` / [`NativeBackend::with_solver`].
    tableau: Tableau,
}

impl Default for NativeBackend {
    fn default() -> Self {
        Self::new()
    }
}

fn hyper(pairs: &[(&str, f64)]) -> BTreeMap<String, f64> {
    pairs.iter().map(|&(k, v)| (k.to_string(), v)).collect()
}

impl NativeBackend {
    pub fn new() -> NativeBackend {
        let mut models = BTreeMap::new();
        models.insert(
            "spiral_node".to_string(),
            NativeModel {
                arch: Arch::SpiralNode {
                    dynamics: Mlp::cubed(&[2, 16, 2]),
                },
                ladder: vec![512, 2048, 8192],
                hyper: hyper(&[
                    ("lr", 0.02),
                    ("coef_e", 100.0),
                    ("coef_s", 0.02),
                    ("coef_l", 100.0),
                    ("t1", 1.0),
                ]),
                train_tol: 1e-4,
                predict_tol: 1e-6,
            },
        );
        models.insert(
            "spiral_nsde".to_string(),
            NativeModel {
                arch: Arch::SpiralNsde {
                    drift: Mlp::cubed(&[2, 16, 2]),
                    diffusion: Mlp::new(&[2, 8, 2]),
                },
                ladder: vec![8192, 32768, 131072],
                hyper: hyper(&[
                    ("lr", 0.02),
                    ("coef_e", 1.0),
                    ("coef_s", 0.01),
                    ("coef_l", 1.0),
                ]),
                train_tol: 1e-2,
                predict_tol: 1e-2,
            },
        );
        models.insert(
            "mnist_node".to_string(),
            NativeModel {
                arch: Arch::MnistNode {
                    enc: Mlp::tanh_out(&[IMG_DIM, MNIST_LATENT]),
                    dynamics: Mlp::new(&[MNIST_LATENT, 32, MNIST_LATENT]),
                    clf: Mlp::new(&[MNIST_LATENT, CLASSES]),
                },
                ladder: vec![128, 512, 2048],
                hyper: hyper(&[
                    ("lr", 0.01),
                    ("inv_decay", 1e-5),
                    ("coef_e_start", 100.0),
                    ("coef_e_end", 10.0),
                    ("coef_s", 0.0285),
                    ("coef_l", 100.0),
                    ("taylor_coef", 3.02e-3),
                    ("t1", 1.0),
                    ("steer_b", 0.5),
                ]),
                train_tol: 1e-3,
                predict_tol: 1e-3,
            },
        );
        models.insert(
            "mnist_nsde".to_string(),
            NativeModel {
                arch: Arch::MnistNsde {
                    enc: Mlp::tanh_out(&[IMG_DIM, MNIST_LATENT]),
                    drift: Mlp::new(&[MNIST_LATENT, 32, MNIST_LATENT]),
                    diffusion: Mlp::new(&[MNIST_LATENT, 32, MNIST_LATENT]),
                    clf: Mlp::new(&[MNIST_LATENT, CLASSES]),
                },
                ladder: vec![128, 512, 2048],
                hyper: hyper(&[
                    ("lr", 0.01),
                    ("inv_decay", 1e-5),
                    ("coef_e", 10.0),
                    ("coef_s", 0.1),
                    ("coef_l", 10.0),
                ]),
                train_tol: 1e-2,
                predict_tol: 1e-2,
            },
        );
        models.insert(
            "latent_ode".to_string(),
            NativeModel {
                arch: Arch::LatentOde {
                    enc: Mlp::tanh_out(&[2 * SERIES_CHANNELS, LATENT_DIM]),
                    dynamics: Mlp::new(&[LATENT_DIM, 32, LATENT_DIM]),
                    dec: Mlp::new(&[LATENT_DIM, SERIES_CHANNELS]),
                },
                ladder: vec![256, 1024, 4096],
                hyper: hyper(&[
                    ("lr", 0.01),
                    ("inv_decay", 1e-5),
                    ("coef_e_start", 1000.0),
                    ("coef_e_end", 100.0),
                    ("coef_s", 0.285),
                    ("coef_l", 1000.0),
                    ("taylor_coef", 0.01),
                    ("kl_anneal", 0.99),
                ]),
                train_tol: 1e-3,
                predict_tol: 1e-3,
            },
        );
        NativeBackend {
            models,
            tableau: Tableau::tsit5(),
        }
    }

    /// Test hook: replace a model's budget ladder (e.g. with tiny rungs
    /// to force router escalations in integration tests).
    pub fn with_ladder(mut self, model: &str, ladder: Vec<usize>) -> NativeBackend {
        if let Some(m) = self.models.get_mut(model) {
            m.ladder = ladder;
        }
        self
    }

    /// Select the RK tableau of every ODE solve by name
    /// (case-insensitive; the CLI's `--solver` flag).  Unknown names get
    /// the registry-listing error of [`Tableau::parse`].
    pub fn with_solver(mut self, name: &str) -> Result<NativeBackend> {
        self.tableau = Tableau::parse(name).map_err(anyhow::Error::msg)?;
        Ok(self)
    }

    /// The active RK tableau (what `--solver` selected; default `tsit5`).
    pub fn solver(&self) -> &Tableau {
        &self.tableau
    }

    fn get(&self, model: &str) -> Result<&NativeModel> {
        match self.models.get(model) {
            Some(m) => Ok(m),
            None => bail!(
                "model {model:?} not in native backend (have: {:?})",
                self.models.keys().collect::<Vec<_>>()
            ),
        }
    }

    /// Options of the ODE predict paths: backend tableau, the model's
    /// predict tolerance, default per-segment budget (the early-exiting
    /// inference setting — no budget ladder at serve time).
    fn ode_predict_opts(&self, tol: f64) -> SolveOptions {
        SolveOptions::new()
            .with_tableau(self.tableau.clone())
            .with_tolerance(tol)
    }

    /// Unified options of an ODE train solve: backend tableau, paper
    /// tolerance, **total** attempt budget (the budget-ladder rung).
    fn ode_train_opts(&self, tol: f64, budget: u64) -> SolveOptions {
        SolveOptions::new()
            .with_tableau(self.tableau.clone())
            .with_tolerance(tol)
            .with_budget(StepBudget::Total(budget))
    }

    /// Unified options of an SDE train solve (Heun scheme is fixed, so
    /// no tableau choice).
    fn sde_train_opts(tol: f64, budget: u64) -> SolveOptions {
        SolveOptions::new()
            .with_tolerance(tol)
            .with_budget(StepBudget::Total(budget))
    }

    /// Options of the SDE predict paths (Heun scheme is fixed; the
    /// generous per-segment budget matches the historical prediction
    /// setting).
    fn sde_predict_opts(tol: f64) -> SolveOptions {
        SolveOptions::new()
            .with_tolerance(tol)
            .with_budget(StepBudget::PerSegment(1_000_000))
    }

    /// State dimension of a model's single-trajectory serving path
    /// (`serve::batcher` coalesces requests of this width).  Only models
    /// whose inference is "integrate one state vector over a grid" are
    /// row-batchable this way.
    pub fn traj_state_dim(&self, model: &str) -> Result<usize> {
        match &self.get(model)?.arch {
            Arch::SpiralNode { dynamics } => Ok(dynamics.in_dim()),
            _ => bail!(
                "model {model:?} has no single-trajectory serving path \
                 (only trajectory-output models are row-batchable)"
            ),
        }
    }

    /// Row-batched trajectory inference — the serving hot path: integrate
    /// `B` initial states (`u0s`, row-major `[B, d]`) through **one**
    /// `drive()` over the shared save grid `ts`, so concurrent predict
    /// requests share every solver step.  Returns one `[T * d]`
    /// trajectory per request plus the batch solve's [`Stats`] (the NFE
    /// every rider pays once, jointly) and the solve's typed failure
    /// class (`None` on success) — the batcher forwards the kind to
    /// every rider of a poisoned window.
    ///
    /// `budget: Some(b)` bounds the whole batch solve
    /// ([`StepBudget::Total`], the serving admission unit); `None` keeps
    /// the default per-segment predict budget.  A batch of one takes
    /// exactly the steps of [`Backend::predict`] on the same input, so
    /// an unbatched served response is bit-identical to the in-process
    /// prediction.
    pub fn predict_traj_batch(
        &self,
        model: &str,
        params: &[f32],
        u0s: &[f32],
        ts: &[f32],
        budget: Option<u64>,
    ) -> Result<(Vec<Vec<f32>>, Stats, Option<SolveErrorKind>)> {
        let m = self.get(model)?;
        let dynamics = match &m.arch {
            Arch::SpiralNode { dynamics } => dynamics,
            _ => bail!("model {model:?} has no single-trajectory serving path"),
        };
        let d = dynamics.in_dim();
        ensure!(
            params.len() == m.arch.n_params(),
            "params size {} != {}",
            params.len(),
            m.arch.n_params()
        );
        ensure!(ts.len() >= 2, "need at least two save points");
        ensure!(
            !u0s.is_empty() && u0s.len() % d == 0,
            "u0 batch must be rows of {d} floats (got {})",
            u0s.len()
        );
        let b = u0s.len() / d;
        let theta = to_f64(params);
        let ts64: Vec<f64> = ts.iter().map(|&t| t as f64).collect();
        let z0: Vec<f64> = u0s.iter().map(|&v| v as f64).collect();

        let mut opts = self.ode_predict_opts(m.predict_tol);
        if let Some(total) = budget {
            opts = opts.with_budget(StepBudget::Total(total));
        }
        let mut sys = MlpOde::new(dynamics, &theta, b, 0..0);
        let (zs, out) = ode::drive(&mut sys, &z0, Saveat::Grid(&ts64), &opts, None, &mut []);

        let mut trajs: Vec<Vec<f32>> =
            (0..b).map(|_| Vec::with_capacity(ts.len() * d)).collect();
        for z in &zs {
            for (i, traj) in trajs.iter_mut().enumerate() {
                for k in 0..d {
                    traj.push(z[i * d + k] as f32);
                }
            }
        }
        Ok((trajs, out.stats(), out.error_kind()))
    }
}

// ---------------------------------------------------------------------------
// Shared numeric helpers
// ---------------------------------------------------------------------------

fn to_f64(v: &[f32]) -> Vec<f64> {
    v.iter().map(|&x| x as f64).collect()
}

/// Mean softmax cross-entropy + accuracy over a `[b, c]` logit block;
/// writes `d(loss)/d(logits)` into `dlogits`.
fn softmax_ce(
    logits: &[f64],
    onehot: &[f32],
    b: usize,
    c: usize,
    dlogits: &mut [f64],
) -> (f64, f64) {
    let mut loss = 0.0;
    let mut correct = 0usize;
    for r in 0..b {
        let lrow = &logits[r * c..(r + 1) * c];
        let yrow = &onehot[r * c..(r + 1) * c];
        let max = lrow.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let sum: f64 = lrow.iter().map(|&l| (l - max).exp()).sum();
        let lse = max + sum.ln();
        let mut y_logit = 0.0;
        let mut argmax_l = 0;
        let mut argmax_y = 0;
        for k in 0..c {
            y_logit += yrow[k] as f64 * lrow[k];
            if lrow[k] > lrow[argmax_l] {
                argmax_l = k;
            }
            if yrow[k] > yrow[argmax_y] {
                argmax_y = k;
            }
        }
        loss += lse - y_logit;
        if argmax_l == argmax_y {
            correct += 1;
        }
        for k in 0..c {
            let p = (lrow[k] - lse).exp();
            dlogits[r * c + k] = (p - yrow[k] as f64) / b as f64;
        }
    }
    (loss / b as f64, correct as f64 / b as f64)
}

/// Build the standard metric block from solver stats plus the solve's
/// typed failure class (`None` on success).
fn metrics(loss: f64, metric: f64, stats: &Stats, error: Option<SolveErrorKind>) -> Metrics {
    Metrics {
        loss,
        metric,
        nfe: stats.nfe as f64,
        naccept: stats.naccept as f64,
        nreject: stats.nreject as f64,
        success: error.is_none(),
        error,
        r_e: stats.r_e,
        r_e2: stats.r_e2,
        r_s: stats.r_s,
        r_l: 0.0,
        r_aux: 0.0,
    }
}

// ---------------------------------------------------------------------------
// Model systems: the native models as solver `System`s
// ---------------------------------------------------------------------------

/// Row-batched MLP dynamics over a flat `[rows, l]` state — every native
/// ODE model's dynamics block as one [`System`].  Drift and VJP go
/// through the batched kernel entry points ([`Mlp::forward_batch`] /
/// [`Mlp::vjp_batch`]): one vectorized pass per layer over the whole
/// batch, scratch sized at construction (allocation-free per solver
/// attempt).  The VJP accumulates its parameter cotangent into
/// `gp[grad_range]` (the dynamics part's slice of the full flat
/// gradient).
struct MlpOde<'a> {
    mlp: &'a Mlp,
    /// This part's parameter slice (already cut out of the flat vector).
    theta: &'a [f64],
    grad_range: std::ops::Range<usize>,
    fwd: MlpBatchScratch,
    bwd: MlpBatchScratch,
}

impl<'a> MlpOde<'a> {
    fn new(
        mlp: &'a Mlp,
        theta: &'a [f64],
        rows: usize,
        grad_range: std::ops::Range<usize>,
    ) -> MlpOde<'a> {
        MlpOde {
            mlp,
            theta,
            grad_range,
            fwd: mlp.batch_scratch(rows),
            bwd: mlp.batch_scratch(rows),
        }
    }
}

impl System for MlpOde<'_> {
    fn drift(&mut self, z: &[f64], _t: f64, dz: &mut [f64]) {
        self.mlp.forward_batch(self.theta, z, dz, &mut self.fwd);
    }

    fn drift_vjp(&mut self, z: &[f64], _t: f64, w: &[f64], gz: &mut [f64], gp: &mut [f64]) {
        let g = &mut gp[self.grad_range.clone()];
        self.mlp.vjp_batch(self.theta, z, w, gz, g, &mut self.bwd);
    }
}

/// Row-batched drift + diagonal-diffusion MLP pair — every native NSDE
/// model's dynamics block as one diffusive [`System`], on the same
/// batched kernel entry points as [`MlpOde`].
struct MlpSde<'a> {
    drift: &'a Mlp,
    th_drift: &'a [f64],
    drift_range: std::ops::Range<usize>,
    diffusion: &'a Mlp,
    th_diff: &'a [f64],
    diff_range: std::ops::Range<usize>,
    dfwd: MlpBatchScratch,
    dbwd: MlpBatchScratch,
    gfwd: MlpBatchScratch,
    gbwd: MlpBatchScratch,
}

impl<'a> MlpSde<'a> {
    fn new(
        drift: &'a Mlp,
        th_drift: &'a [f64],
        drift_range: std::ops::Range<usize>,
        diffusion: &'a Mlp,
        th_diff: &'a [f64],
        diff_range: std::ops::Range<usize>,
        rows: usize,
    ) -> MlpSde<'a> {
        MlpSde {
            drift,
            th_drift,
            drift_range,
            diffusion,
            th_diff,
            diff_range,
            dfwd: drift.batch_scratch(rows),
            dbwd: drift.batch_scratch(rows),
            gfwd: diffusion.batch_scratch(rows),
            gbwd: diffusion.batch_scratch(rows),
        }
    }
}

impl System for MlpSde<'_> {
    fn drift(&mut self, z: &[f64], _t: f64, dz: &mut [f64]) {
        self.drift.forward_batch(self.th_drift, z, dz, &mut self.dfwd);
    }

    fn has_diffusion(&self) -> bool {
        true
    }

    fn diffusion(&mut self, z: &[f64], _t: f64, dg: &mut [f64]) {
        self.diffusion.forward_batch(self.th_diff, z, dg, &mut self.gfwd);
    }

    fn drift_vjp(&mut self, z: &[f64], _t: f64, w: &[f64], gz: &mut [f64], gp: &mut [f64]) {
        let g = &mut gp[self.drift_range.clone()];
        self.drift.vjp_batch(self.th_drift, z, w, gz, g, &mut self.dbwd);
    }

    fn diffusion_vjp(&mut self, z: &[f64], _t: f64, w: &[f64], gz: &mut [f64], gp: &mut [f64]) {
        let g = &mut gp[self.diff_range.clone()];
        self.diffusion.vjp_batch(self.th_diff, z, w, gz, g, &mut self.gbwd);
    }
}

/// LocalReg sampling seed of one train-step solve (`traj` distinguishes
/// ensemble members so they sample independent steps).
fn local_seed(seed: u32, traj: usize) -> u64 {
    (seed as u64 ^ 0x10CA_1B0B)
        .wrapping_add((traj as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15))
}

/// The sampled-step observer of one training solve: live and seeded for
/// lr methods (`coef_l != 0`), inert otherwise — non-lr methods must
/// not pay a per-accepted-step sampling draw they discard.
fn local_sampler(coef_l: f64, seed: u32, traj: usize) -> LocalReg {
    if coef_l != 0.0 {
        LocalReg::new(local_seed(seed, traj))
    } else {
        LocalReg::disabled()
    }
}

/// Resolve a [`LocalReg`] observation into backward weights: the
/// regularizer coefficients (global + sampled-step local term) and the
/// reported `R_L` value.  With `coef_l = 0` the observation is ignored.
fn resolve_local(reg: RegCoefs, local: &LocalReg, coef_l: f64) -> (RegCoefs, f64) {
    if coef_l == 0.0 {
        return (reg, 0.0);
    }
    match local.sampled_step() {
        Some(step) => (reg.with_local(step, coef_l), local.value()),
        None => (reg, 0.0),
    }
}

/// Per-trajectory RNG stream — the ensemble layer's derivation, so native
/// NSDE paths and `solvers::ensemble` draw from the same stream family.
fn traj_rng(seed: u64, i: usize) -> Rng {
    crate::solvers::ensemble::trajectory_rng(seed, i)
}

/// Seed salt per model name so different models draw different init
/// streams from the same replica seed.
fn name_salt(name: &str) -> u64 {
    name.bytes()
        .fold(0xCBF2_9CE4_8422_2325u64, |h, b| {
            (h ^ b as u64).wrapping_mul(0x0000_0100_0000_01B3)
        })
}

/// Mask-aware pooled features of one series sample: per channel the mean
/// of observed values and the observed fraction (`2 * channels` long).
fn series_features(
    x: &[f32],
    mask: &[f32],
    t_points: usize,
    channels: usize,
    out: &mut [f64],
) {
    debug_assert_eq!(out.len(), 2 * channels);
    for c in 0..channels {
        let mut sum = 0.0;
        let mut cnt = 0.0;
        for t in 0..t_points {
            let m = mask[t * channels + c] as f64;
            sum += m * x[t * channels + c] as f64;
            cnt += m;
        }
        out[c] = sum / cnt.max(1.0);
        out[channels + c] = cnt / t_points as f64;
    }
}

// ---------------------------------------------------------------------------
// Backend impl
// ---------------------------------------------------------------------------

impl Backend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn models(&self) -> Vec<String> {
        self.models.keys().cloned().collect()
    }

    fn model(&self, model: &str) -> Result<ModelInfo> {
        let m = self.get(model)?;
        let n = m.arch.n_params();
        Ok(ModelInfo {
            name: model.to_string(),
            params_size: n,
            opt_state_size: Adam::opt_state_size(n),
            optimizer: "adam".to_string(),
            hyper: m.hyper.clone(),
        })
    }

    fn ladder(&self, model: &str, _tay: bool) -> Result<Vec<usize>> {
        // The native path has no separate TayNODE lowering: same rungs.
        Ok(self.get(model)?.ladder.clone())
    }

    fn init_params(&self, model: &str, seed: u32) -> Result<Vec<f32>> {
        let m = self.get(model)?;
        let mut rng = Rng::new(seed as u64 ^ name_salt(model));
        let mut params = vec![0.0f32; m.arch.n_params()];
        for (i, part) in m.arch.parts().iter().enumerate() {
            let r = m.arch.range(i);
            part.init(&mut rng, &mut params[r]);
        }
        Ok(params)
    }

    fn train_step(
        &self,
        model: &str,
        tay: bool,
        rung: usize,
        state: &TrainState,
        data: &TrainData,
        coefs: &StepCoefs,
    ) -> Result<StepOutput> {
        // Layered on the distributed seam: evaluate the gradient, then
        // apply the Adam update locally.  `to_f64` widening is bit-exact,
        // so the f32 gradient seam costs one rounding — the same rounding
        // every shard and the single-process path share.
        let t0 = std::time::Instant::now();
        let out = self.grad_step(model, tay, rung, state, data, coefs)?;
        let grad = to_f64(&out.grad);
        let mut params = state.params.clone();
        let mut opt_state = state.opt_state.clone();
        {
            crate::span!("optimizer", "train");
            Adam::default().step(
                &mut params,
                &mut opt_state,
                &grad,
                coefs.lr as f64,
                state.iter,
            );
        }
        // Observability taps are pure reads — nothing below feeds back
        // into the update, so bit-equivalence suites pass untouched.
        let mut grad_sq = 0.0f64;
        for g in &grad {
            grad_sq += g * g;
        }
        crate::obs::metrics::note_train_step(
            model,
            out.metrics.loss,
            out.metrics.r_e,
            out.metrics.r_s,
            grad_sq.sqrt(),
            t0.elapsed().as_secs_f64(),
        );
        Ok(StepOutput {
            params,
            opt_state,
            metrics: out.metrics,
        })
    }

    fn grad_step(
        &self,
        model: &str,
        _tay: bool,
        rung: usize,
        state: &TrainState,
        data: &TrainData,
        coefs: &StepCoefs,
    ) -> Result<GradOutput> {
        let m = self.get(model)?;
        ensure!(rung < m.ladder.len(), "rung {rung} out of ladder");
        ensure!(
            state.params.len() == m.arch.n_params(),
            "params size {} != {}",
            state.params.len(),
            m.arch.n_params()
        );
        let budget = m.ladder[rung] as u64;
        let theta = to_f64(&state.params);
        let mut grad = vec![0.0; theta.len()];
        let coef_e = coefs.coef_e as f64;
        let coef_s = coefs.coef_s as f64;
        let coef_l = coefs.coef_l as f64;

        let (data_loss, metric, stats, solve_err, r_l) = match (&m.arch, data) {
            (Arch::SpiralNode { dynamics }, TrainData::Trajectory { data, ts }) => {
                spiral_node_pass(
                    dynamics,
                    &theta,
                    data,
                    ts,
                    &self.ode_train_opts(m.train_tol, budget),
                    coef_e,
                    coef_s,
                    coef_l,
                    coefs.seed,
                    &mut grad,
                )?
            }
            (Arch::SpiralNsde { drift, diffusion }, TrainData::Moments { u0, mu, var, ts }) => {
                spiral_nsde_pass(
                    drift,
                    diffusion,
                    &m.arch,
                    &theta,
                    u0,
                    mu,
                    var,
                    ts,
                    m.train_tol,
                    budget,
                    coef_e,
                    coef_s,
                    coef_l,
                    coefs.seed,
                    &mut grad,
                )?
            }
            (Arch::MnistNode { enc, dynamics, clf }, TrainData::Classify { x, y }) => {
                mnist_node_pass(
                    enc,
                    dynamics,
                    clf,
                    &m.arch,
                    &theta,
                    x,
                    y,
                    coefs.t1 as f64,
                    &self.ode_train_opts(m.train_tol, budget),
                    coef_e,
                    coef_s,
                    coef_l,
                    coefs.seed,
                    &mut grad,
                )?
            }
            (
                Arch::MnistNsde {
                    enc,
                    drift,
                    diffusion,
                    clf,
                },
                TrainData::Classify { x, y },
            ) => mnist_nsde_pass(
                enc,
                drift,
                diffusion,
                clf,
                &m.arch,
                &theta,
                x,
                y,
                &Self::sde_train_opts(m.train_tol, budget),
                coef_e,
                coef_s,
                coef_l,
                coefs.seed,
                &mut grad,
            )?,
            (Arch::LatentOde { enc, dynamics, dec }, TrainData::Series { x, mask, ts }) => {
                latent_ode_pass(
                    enc,
                    dynamics,
                    dec,
                    &m.arch,
                    &theta,
                    x,
                    mask,
                    ts,
                    coefs.kl as f64,
                    &self.ode_train_opts(m.train_tol, budget),
                    coef_e,
                    coef_s,
                    coef_l,
                    coefs.seed,
                    &mut grad,
                )?
            }
            (_, d) => bail!("model {model} cannot train on {:?} data", d.kind()),
        };

        // The reported loss and the gradient compose identically: both
        // are data_loss + coef_e·R_E + coef_s·R_S + coef_l·R_L (the
        // sampled-step local term).
        let loss = data_loss + coef_e * stats.r_e + coef_s * stats.r_s + coef_l * r_l;

        let mut step_metrics = metrics(loss, metric, &stats, solve_err);
        step_metrics.r_l = r_l;
        Ok(GradOutput {
            grad: grad.iter().map(|&g| g as f32).collect(),
            metrics: step_metrics,
        })
    }

    fn shard_items(&self, model: &str, data: &TrainData) -> Result<usize> {
        let m = self.get(model)?;
        Ok(match (&m.arch, data) {
            // Whole-trajectory / whole-ensemble fits are one item: their
            // loss is not a mean over independent rows.
            (Arch::SpiralNode { .. }, TrainData::Trajectory { .. })
            | (Arch::SpiralNsde { .. }, TrainData::Moments { .. }) => 1,
            (Arch::MnistNode { .. } | Arch::MnistNsde { .. }, TrainData::Classify { x, .. }) => {
                ensure!(!x.is_empty() && x.len() % IMG_DIM == 0, "image batch shape");
                x.len() / IMG_DIM
            }
            (Arch::LatentOde { .. }, TrainData::Series { x, ts, .. }) => {
                let row = ts.len() * SERIES_CHANNELS;
                ensure!(row > 0 && !x.is_empty() && x.len() % row == 0, "series batch shape");
                x.len() / row
            }
            (_, d) => bail!("model {model} cannot shard {:?} data", d.kind()),
        })
    }

    fn predict(
        &self,
        model: &str,
        params: &[f32],
        data: &TrainData,
        seed: u32,
    ) -> Result<(Vec<f32>, Metrics)> {
        let m = self.get(model)?;
        ensure!(
            params.len() == m.arch.n_params(),
            "params size {} != {}",
            params.len(),
            m.arch.n_params()
        );
        let theta = to_f64(params);
        match (&m.arch, data) {
            (Arch::SpiralNode { dynamics }, TrainData::Trajectory { data, ts }) => {
                let (pred, loss, stats, err) = spiral_node_predict(
                    dynamics,
                    &theta,
                    data,
                    ts,
                    &self.ode_predict_opts(m.predict_tol),
                )?;
                Ok((pred, metrics(loss, loss, &stats, err)))
            }
            (Arch::SpiralNsde { drift, diffusion }, TrainData::Moments { u0, mu, var, ts }) => {
                spiral_nsde_predict(
                    drift,
                    diffusion,
                    &m.arch,
                    &theta,
                    u0,
                    mu,
                    var,
                    ts,
                    &Self::sde_predict_opts(m.predict_tol),
                    seed,
                )
            }
            (Arch::MnistNode { enc, dynamics, clf }, TrainData::Classify { x, y }) => {
                let (logits, loss, acc, stats, err) = mnist_node_predict(
                    enc,
                    dynamics,
                    clf,
                    &m.arch,
                    &theta,
                    x,
                    y,
                    &self.ode_predict_opts(m.predict_tol),
                )?;
                Ok((logits, metrics(loss, acc, &stats, err)))
            }
            (
                Arch::MnistNsde {
                    enc,
                    drift,
                    diffusion,
                    clf,
                },
                TrainData::Classify { x, y },
            ) => mnist_nsde_predict(
                enc,
                drift,
                diffusion,
                clf,
                &m.arch,
                &theta,
                x,
                y,
                &Self::sde_predict_opts(m.predict_tol),
                seed,
            ),
            (Arch::LatentOde { enc, dynamics, dec }, TrainData::Series { x, mask, ts }) => {
                latent_ode_predict(
                    enc,
                    dynamics,
                    dec,
                    &m.arch,
                    &theta,
                    x,
                    mask,
                    ts,
                    &self.ode_predict_opts(m.predict_tol),
                )
            }
            (_, d) => bail!("model {model} cannot predict on {:?} data", d.kind()),
        }
    }

    fn export_state(&self, model: &str, params: &[f32]) -> Result<ExportedState> {
        let m = self.get(model)?;
        ensure!(
            params.len() == m.arch.n_params(),
            "params size {} != {} for model {model:?}",
            params.len(),
            m.arch.n_params()
        );
        ensure!(
            params.iter().all(|p| p.is_finite()),
            "refusing to export non-finite parameters for model {model:?}"
        );
        Ok(ExportedState {
            model: model.to_string(),
            params: params.to_vec(),
            solver: self.tableau.name.to_string(),
            train_tol: m.train_tol,
            predict_tol: m.predict_tol,
            step_budget: m.ladder.last().copied().unwrap_or(100_000) as u64,
            hyper: m.hyper.clone(),
        })
    }

    fn import_state(&self, state: &ExportedState) -> Result<Vec<f32>> {
        let m = self.get(&state.model)?;
        ensure!(
            state.params.len() == m.arch.n_params(),
            "checkpoint carries {} parameters but model {:?} has {}",
            state.params.len(),
            state.model,
            m.arch.n_params()
        );
        ensure!(
            state.params.iter().all(|p| p.is_finite()),
            "checkpoint for model {:?} carries non-finite parameters",
            state.model
        );
        // The solver name must be resolvable so a serving backend can be
        // reconstructed with `with_solver` (unknown names list the
        // registry).
        Tableau::parse(&state.solver).map_err(anyhow::Error::msg)?;
        Ok(state.params.clone())
    }
}

// ---------------------------------------------------------------------------
// spiral_node: single-trajectory fit (Fig. 2)
// ---------------------------------------------------------------------------

fn spiral_node_pass(
    dynamics: &Mlp,
    theta: &[f64],
    data: &[f32],
    ts: &[f32],
    opts: &SolveOptions,
    coef_e: f64,
    coef_s: f64,
    coef_l: f64,
    seed: u32,
    grad: &mut [f64],
) -> Result<(f64, f64, Stats, Option<SolveErrorKind>, f64)> {
    let d = dynamics.in_dim();
    ensure!(ts.len() >= 2, "need at least two save points");
    ensure!(data.len() == ts.len() * d, "trajectory shape mismatch");
    let ts64: Vec<f64> = ts.iter().map(|&t| t as f64).collect();
    let z0: Vec<f64> = data[..d].iter().map(|&v| v as f64).collect();

    let mut sys = MlpOde::new(dynamics, theta, 1, 0..grad.len());
    let mut tape = OdeTape::new();
    let mut local = local_sampler(coef_l, seed, 0);
    let (zs, out) = ode::drive(
        &mut sys,
        &z0,
        Saveat::Grid(&ts64),
        opts,
        Some(&mut tape),
        &mut [&mut local],
    );

    let denom = (ts.len() * d) as f64;
    let mut mse = 0.0;
    let mut save_grads = vec![vec![0.0; d]; ts.len()];
    for (t, z) in zs.iter().enumerate() {
        for k in 0..d {
            let diff = z[k] - data[t * d + k] as f64;
            mse += diff * diff / denom;
            save_grads[t][k] = 2.0 * diff / denom;
        }
    }

    let (reg, r_l) = resolve_local(RegCoefs::global(coef_e, coef_s), &local, coef_l);
    ode_backward_sys(&tape, &opts.tableau, &save_grads, &reg, grad, &mut sys);
    Ok((mse, mse, out.stats(), out.error_kind(), r_l))
}

fn spiral_node_predict(
    dynamics: &Mlp,
    theta: &[f64],
    data: &[f32],
    ts: &[f32],
    opts: &SolveOptions,
) -> Result<(Vec<f32>, f64, Stats, Option<SolveErrorKind>)> {
    let d = dynamics.in_dim();
    ensure!(data.len() == ts.len() * d, "trajectory shape mismatch");
    let ts64: Vec<f64> = ts.iter().map(|&t| t as f64).collect();
    let z0: Vec<f64> = data[..d].iter().map(|&v| v as f64).collect();
    let mut sys = MlpOde::new(dynamics, theta, 1, 0..0);
    let (zs, out) = ode::drive(&mut sys, &z0, Saveat::Grid(&ts64), opts, None, &mut []);
    let denom = (ts.len() * d) as f64;
    let mut mse = 0.0;
    let mut pred = Vec::with_capacity(ts.len() * d);
    for (t, z) in zs.iter().enumerate() {
        for k in 0..d {
            let diff = z[k] - data[t * d + k] as f64;
            mse += diff * diff / denom;
            pred.push(z[k] as f32);
        }
    }
    Ok((pred, mse, out.stats(), out.error_kind()))
}

// ---------------------------------------------------------------------------
// spiral_nsde: ensemble moment matching (Table 3)
// ---------------------------------------------------------------------------

/// Ensemble GMM moment loss + per-(trajectory, save, dim) cotangents.
/// `states[i][t][k]`, `mu`/`var` row-major `[T, d]`.
fn moment_loss(
    states: &[Vec<Vec<f64>>],
    mu: &[f32],
    var: &[f32],
    t_pts: usize,
    d: usize,
) -> (f64, Vec<f64>, Vec<f64>) {
    let n = states.len();
    let mut mu_p = vec![0.0; t_pts * d];
    let mut var_p = vec![0.0; t_pts * d];
    for zs in states {
        for t in 0..t_pts {
            for k in 0..d {
                mu_p[t * d + k] += zs[t][k] / n as f64;
            }
        }
    }
    for zs in states {
        for t in 0..t_pts {
            for k in 0..d {
                let diff = zs[t][k] - mu_p[t * d + k];
                var_p[t * d + k] += diff * diff / n as f64;
            }
        }
    }
    let denom = (t_pts * d) as f64;
    let mut loss = 0.0;
    for j in 0..t_pts * d {
        let dm = mu_p[j] - mu[j] as f64;
        let dv = var_p[j] - var[j] as f64;
        loss += (dm * dm + dv * dv) / denom;
    }
    (loss, mu_p, var_p)
}

fn spiral_nsde_pass(
    drift: &Mlp,
    diffusion: &Mlp,
    arch: &Arch,
    theta: &[f64],
    u0: &[f32],
    mu: &[f32],
    var: &[f32],
    ts: &[f32],
    tol: f64,
    budget: u64,
    coef_e: f64,
    coef_s: f64,
    coef_l: f64,
    seed: u32,
    grad: &mut [f64],
) -> Result<(f64, f64, Stats, Option<SolveErrorKind>, f64)> {
    let d = drift.in_dim();
    let t_pts = ts.len();
    ensure!(t_pts >= 2, "need at least two save points");
    ensure!(!u0.is_empty() && u0.len() % d == 0, "u0 shape mismatch");
    ensure!(mu.len() == t_pts * d && var.len() == t_pts * d, "moment shape");
    let n_traj = u0.len() / d;
    let ts64: Vec<f64> = ts.iter().map(|&t| t as f64).collect();
    let th_drift = &theta[arch.range(0)];
    let th_diff = &theta[arch.range(1)];

    let mut sys = MlpSde::new(
        drift,
        th_drift,
        arch.range(0),
        diffusion,
        th_diff,
        arch.range(1),
        1,
    );
    let mut stats = Stats::default();
    // First (lowest-index) trajectory failure, matching the ensemble
    // layer's deterministic pick; later trajectories still run so the
    // tape set stays complete and the gradient deterministic.
    let mut solve_err: Option<SolveErrorKind> = None;
    let mut tapes: Vec<SdeTape> = Vec::with_capacity(n_traj);
    let mut states: Vec<Vec<Vec<f64>>> = Vec::with_capacity(n_traj);
    // Per-trajectory backward weights (LRNSDE samples one step per
    // trajectory's solve); R_L sums the sampled terms.
    let mut regs: Vec<RegCoefs> = Vec::with_capacity(n_traj);
    let mut r_l = 0.0;
    for i in 0..n_traj {
        let z0: Vec<f64> = u0[i * d..(i + 1) * d].iter().map(|&v| v as f64).collect();
        let mut rng = traj_rng(seed as u64 ^ 0x51DE, i);
        let remaining = budget.saturating_sub(stats.attempts());
        let opts = NativeBackend::sde_train_opts(tol, remaining);
        let mut tape = SdeTape::new();
        let mut local = local_sampler(coef_l, seed, i);
        let (zs, out) = sde::drive(
            &mut sys,
            &z0,
            Saveat::Grid(&ts64),
            &mut rng,
            &opts,
            Some(&mut tape),
            &mut [&mut local],
        );
        stats.merge(&out.stats());
        if solve_err.is_none() {
            solve_err = out.error_kind();
        }
        tapes.push(tape);
        states.push(zs);
        let (reg, value) = resolve_local(RegCoefs::global(coef_e, coef_s), &local, coef_l);
        r_l += value;
        regs.push(reg);
    }

    let (gmm, mu_p, var_p) = moment_loss(&states, mu, var, t_pts, d);

    {
        let denom = (t_pts * d) as f64;
        let mut sg = vec![vec![0.0; d]; t_pts];
        for i in 0..n_traj {
            for t in 0..t_pts {
                for k in 0..d {
                    let j = t * d + k;
                    let dmu = 2.0 * (mu_p[j] - mu[j] as f64) / denom;
                    let dvar = 2.0 * (var_p[j] - var[j] as f64) / denom;
                    sg[t][k] = dmu / n_traj as f64
                        + dvar * 2.0 * (states[i][t][k] - mu_p[j]) / n_traj as f64;
                }
            }
            // u0 is data: the returned z0 cotangent is discarded.
            sde_backward_sys(&tapes[i], &sg, &regs[i], grad, &mut sys);
        }
    }
    Ok((gmm, gmm, stats, solve_err, r_l))
}

fn spiral_nsde_predict(
    drift: &Mlp,
    diffusion: &Mlp,
    arch: &Arch,
    theta: &[f64],
    u0: &[f32],
    mu: &[f32],
    var: &[f32],
    ts: &[f32],
    opts: &SolveOptions,
    seed: u32,
) -> Result<(Vec<f32>, Metrics)> {
    let d = drift.in_dim();
    let t_pts = ts.len();
    ensure!(!u0.is_empty() && u0.len() % d == 0, "u0 shape mismatch");
    ensure!(mu.len() == t_pts * d && var.len() == t_pts * d, "moment shape");
    let n_traj = u0.len() / d;
    let ts64: Vec<f64> = ts.iter().map(|&t| t as f64).collect();
    let th_drift = &theta[arch.range(0)];
    let th_diff = &theta[arch.range(1)];
    let mut sys = MlpSde::new(drift, th_drift, 0..0, diffusion, th_diff, 0..0, 1);
    let mut stats = Stats::default();
    let mut solve_err: Option<SolveErrorKind> = None;
    let mut states: Vec<Vec<Vec<f64>>> = Vec::with_capacity(n_traj);
    for i in 0..n_traj {
        let z0: Vec<f64> = u0[i * d..(i + 1) * d].iter().map(|&v| v as f64).collect();
        let mut rng = traj_rng(seed as u64 ^ 0x9E9D_1C7, i);
        let (zs, out) =
            sde::drive(&mut sys, &z0, Saveat::Grid(&ts64), &mut rng, opts, None, &mut []);
        stats.merge(&out.stats());
        if solve_err.is_none() {
            solve_err = out.error_kind();
        }
        states.push(zs);
    }
    let (gmm, _, _) = moment_loss(&states, mu, var, t_pts, d);
    // Ensemble output in the artifact layout [T, n_traj, d].
    let mut out = vec![0.0f32; t_pts * n_traj * d];
    for (i, zs) in states.iter().enumerate() {
        for t in 0..t_pts {
            for k in 0..d {
                out[t * n_traj * d + i * d + k] = zs[t][k] as f32;
            }
        }
    }
    Ok((out, metrics(gmm, gmm, &stats, solve_err)))
}

// ---------------------------------------------------------------------------
// mnist_node: encode -> NODE -> classify (Table 1)
// ---------------------------------------------------------------------------

/// Encode a `[b, IMG_DIM]` batch into the flat latent state `[b * l]`
/// — one batched kernel pass per encoder layer.
fn encode_batch(
    enc: &Mlp,
    th_enc: &[f64],
    x: &[f32],
    b: usize,
    scratch: &mut MlpBatchScratch,
) -> Vec<f64> {
    let in_dim = enc.in_dim();
    let xin: Vec<f64> = x[..b * in_dim].iter().map(|&v| v as f64).collect();
    let mut z0 = vec![0.0; b * enc.out_dim()];
    enc.forward_batch(th_enc, &xin, &mut z0, scratch);
    z0
}

/// Pull classifier + encoder gradients around a solved latent batch:
/// returns (ce_loss, accuracy, dzT, logits) and accumulates clf grads.
fn classify_batch(
    clf: &Mlp,
    th_clf: &[f64],
    zt: &[f64],
    y: &[f32],
    b: usize,
    gclf: Option<&mut [f64]>,
) -> (f64, f64, Vec<f64>, Vec<f64>) {
    let l = clf.in_dim();
    let c = clf.out_dim();
    let mut sc = clf.batch_scratch(b);
    let mut logits = vec![0.0; b * c];
    clf.forward_batch(th_clf, zt, &mut logits, &mut sc);
    let mut dlogits = vec![0.0; b * c];
    let (loss, acc) = softmax_ce(&logits, y, b, c, &mut dlogits);
    let mut dzt = vec![0.0; b * l];
    if let Some(gclf) = gclf {
        clf.vjp_batch(th_clf, zt, &dlogits, &mut dzt, gclf, &mut sc);
    }
    (loss, acc, dzt, logits)
}

/// Backprop `dz0` through the encoder, accumulating encoder grads.
fn encoder_backward(
    enc: &Mlp,
    th_enc: &[f64],
    x: &[f32],
    dz0: &[f64],
    b: usize,
    genc: &mut [f64],
    scratch: &mut MlpBatchScratch,
) {
    let in_dim = enc.in_dim();
    let xin: Vec<f64> = x[..b * in_dim].iter().map(|&v| v as f64).collect();
    // Inputs are data — their cotangent is discarded (but a buffer is
    // still required by the accumulating VJP signature).
    let mut gx = vec![0.0; b * in_dim];
    enc.vjp_batch(th_enc, &xin, dz0, &mut gx, genc, scratch);
}

fn mnist_node_pass(
    enc: &Mlp,
    dynamics: &Mlp,
    clf: &Mlp,
    arch: &Arch,
    theta: &[f64],
    x: &[f32],
    y: &[f32],
    t1: f64,
    opts: &SolveOptions,
    coef_e: f64,
    coef_s: f64,
    coef_l: f64,
    seed: u32,
    grad: &mut [f64],
) -> Result<(f64, f64, Stats, Option<SolveErrorKind>, f64)> {
    ensure!(!x.is_empty() && x.len() % IMG_DIM == 0, "image batch shape");
    let b = x.len() / IMG_DIM;
    ensure!(y.len() == b * CLASSES, "one-hot batch shape");
    let l = dynamics.in_dim();
    let t_end = t1.max(0.1);
    let th_enc = &theta[arch.range(0)];
    let th_dyn = &theta[arch.range(1)];
    let th_clf = &theta[arch.range(2)];

    let mut se = enc.batch_scratch(b);
    let z0 = encode_batch(enc, th_enc, x, b, &mut se);

    let mut sys = MlpOde::new(dynamics, th_dyn, b, arch.range(1));
    let mut tape = OdeTape::new();
    let mut local = local_sampler(coef_l, seed, 0);
    let (zs, out) = ode::drive(
        &mut sys,
        &z0,
        Saveat::Grid(&[0.0, t_end]),
        opts,
        Some(&mut tape),
        &mut [&mut local],
    );

    let (ce_loss, acc, dzt, _) =
        classify_batch(clf, th_clf, &zs[1], y, b, Some(&mut grad[arch.range(2)]));

    let save_grads = vec![vec![0.0; b * l], dzt];
    let (reg, r_l) = resolve_local(RegCoefs::global(coef_e, coef_s), &local, coef_l);
    let dz0 = ode_backward_sys(&tape, &opts.tableau, &save_grads, &reg, grad, &mut sys);
    encoder_backward(enc, th_enc, x, &dz0, b, &mut grad[arch.range(0)], &mut se);
    Ok((ce_loss, acc, out.stats(), out.error_kind(), r_l))
}

fn mnist_node_predict(
    enc: &Mlp,
    dynamics: &Mlp,
    clf: &Mlp,
    arch: &Arch,
    theta: &[f64],
    x: &[f32],
    y: &[f32],
    opts: &SolveOptions,
) -> Result<(Vec<f32>, f64, f64, Stats, Option<SolveErrorKind>)> {
    ensure!(!x.is_empty() && x.len() % IMG_DIM == 0, "image batch shape");
    let b = x.len() / IMG_DIM;
    ensure!(y.len() == b * CLASSES, "one-hot batch shape");
    let th_enc = &theta[arch.range(0)];
    let th_dyn = &theta[arch.range(1)];
    let th_clf = &theta[arch.range(2)];
    let mut se = enc.batch_scratch(b);
    let z0 = encode_batch(enc, th_enc, x, b, &mut se);
    let mut sys = MlpOde::new(dynamics, th_dyn, b, 0..0);
    let (zs, out) = ode::drive(&mut sys, &z0, Saveat::Grid(&[0.0, 1.0]), opts, None, &mut []);
    let (loss, acc, _, logits) = classify_batch(clf, th_clf, &zs[1], y, b, None);
    let logits: Vec<f32> = logits.iter().map(|&v| v as f32).collect();
    Ok((logits, loss, acc, out.stats(), out.error_kind()))
}

// ---------------------------------------------------------------------------
// mnist_nsde: encode -> NSDE -> classify (Table 4)
// ---------------------------------------------------------------------------

fn mnist_nsde_pass(
    enc: &Mlp,
    drift: &Mlp,
    diffusion: &Mlp,
    clf: &Mlp,
    arch: &Arch,
    theta: &[f64],
    x: &[f32],
    y: &[f32],
    opts: &SolveOptions,
    coef_e: f64,
    coef_s: f64,
    coef_l: f64,
    seed: u32,
    grad: &mut [f64],
) -> Result<(f64, f64, Stats, Option<SolveErrorKind>, f64)> {
    ensure!(!x.is_empty() && x.len() % IMG_DIM == 0, "image batch shape");
    let b = x.len() / IMG_DIM;
    ensure!(y.len() == b * CLASSES, "one-hot batch shape");
    let l = drift.in_dim();
    let th_enc = &theta[arch.range(0)];
    let th_drift = &theta[arch.range(1)];
    let th_diff = &theta[arch.range(2)];
    let th_clf = &theta[arch.range(3)];

    let mut se = enc.batch_scratch(b);
    let z0 = encode_batch(enc, th_enc, x, b, &mut se);

    let mut sys = MlpSde::new(
        drift,
        th_drift,
        arch.range(1),
        diffusion,
        th_diff,
        arch.range(2),
        b,
    );
    let mut rng = Rng::new(seed as u64 ^ 0x51DE);
    let mut tape = SdeTape::new();
    let mut local = local_sampler(coef_l, seed, 0);
    let (zs, out) = sde::drive(
        &mut sys,
        &z0,
        Saveat::Grid(&[0.0, 1.0]),
        &mut rng,
        opts,
        Some(&mut tape),
        &mut [&mut local],
    );

    let (ce_loss, acc, dzt, _) =
        classify_batch(clf, th_clf, &zs[1], y, b, Some(&mut grad[arch.range(3)]));

    let save_grads = vec![vec![0.0; b * l], dzt];
    let (reg, r_l) = resolve_local(RegCoefs::global(coef_e, coef_s), &local, coef_l);
    let dz0 = sde_backward_sys(&tape, &save_grads, &reg, grad, &mut sys);
    encoder_backward(enc, th_enc, x, &dz0, b, &mut grad[arch.range(0)], &mut se);
    Ok((ce_loss, acc, out.stats(), out.error_kind(), r_l))
}

fn mnist_nsde_predict(
    enc: &Mlp,
    drift: &Mlp,
    diffusion: &Mlp,
    clf: &Mlp,
    arch: &Arch,
    theta: &[f64],
    x: &[f32],
    y: &[f32],
    opts: &SolveOptions,
    seed: u32,
) -> Result<(Vec<f32>, Metrics)> {
    ensure!(!x.is_empty() && x.len() % IMG_DIM == 0, "image batch shape");
    let b = x.len() / IMG_DIM;
    ensure!(y.len() == b * CLASSES, "one-hot batch shape");
    let th_enc = &theta[arch.range(0)];
    let th_drift = &theta[arch.range(1)];
    let th_diff = &theta[arch.range(2)];
    let th_clf = &theta[arch.range(3)];
    let mut se = enc.batch_scratch(b);
    let z0 = encode_batch(enc, th_enc, x, b, &mut se);

    // Paper-style prediction: mean logits over several driving paths.
    let mut stats = Stats::default();
    let mut solve_err: Option<SolveErrorKind> = None;
    let mut mean_logits = vec![0.0f64; b * CLASSES];
    let mut sys = MlpSde::new(drift, th_drift, 0..0, diffusion, th_diff, 0..0, b);
    let mut sc = clf.batch_scratch(b);
    let mut logits = vec![0.0f64; b * CLASSES];
    for path in 0..PREDICT_PATHS {
        let mut rng = traj_rng(seed as u64 ^ 0x9E9D_1C7, path);
        let (zs, out) = sde::drive(
            &mut sys,
            &z0,
            Saveat::Grid(&[0.0, 1.0]),
            &mut rng,
            opts,
            None,
            &mut [],
        );
        stats.merge(&out.stats());
        if solve_err.is_none() {
            solve_err = out.error_kind();
        }
        clf.forward_batch(th_clf, &zs[1], &mut logits, &mut sc);
        for (m, &v) in mean_logits.iter_mut().zip(&logits) {
            *m += v / PREDICT_PATHS as f64;
        }
    }
    let mut dlogits = vec![0.0; b * CLASSES];
    let (loss, acc) = softmax_ce(&mean_logits, y, b, CLASSES, &mut dlogits);
    let out: Vec<f32> = mean_logits.iter().map(|&v| v as f32).collect();
    Ok((out, metrics(loss, acc, &stats, solve_err)))
}

// ---------------------------------------------------------------------------
// latent_ode: pooled encoder -> latent NODE -> decoder (Table 2)
// ---------------------------------------------------------------------------

fn latent_ode_pass(
    enc: &Mlp,
    dynamics: &Mlp,
    dec: &Mlp,
    arch: &Arch,
    theta: &[f64],
    x: &[f32],
    mask: &[f32],
    ts: &[f32],
    kl_coef: f64,
    opts: &SolveOptions,
    coef_e: f64,
    coef_s: f64,
    coef_l: f64,
    seed: u32,
    grad: &mut [f64],
) -> Result<(f64, f64, Stats, Option<SolveErrorKind>, f64)> {
    let c = dec.out_dim();
    let t_pts = ts.len();
    ensure!(t_pts >= 2, "need at least two save points");
    ensure!(
        !x.is_empty() && x.len() % (t_pts * c) == 0 && mask.len() == x.len(),
        "series batch shape"
    );
    let b = x.len() / (t_pts * c);
    let l = dynamics.in_dim();
    let th_enc = &theta[arch.range(0)];
    let th_dyn = &theta[arch.range(1)];
    let th_dec = &theta[arch.range(2)];
    let ts64: Vec<f64> = ts.iter().map(|&t| t as f64).collect();

    // Mask-aware pooled encoding.
    let mut se = enc.batch_scratch(b);
    let mut feats = vec![0.0; b * 2 * c];
    let mut z0 = vec![0.0; b * l];
    for r in 0..b {
        let sz = t_pts * c;
        series_features(
            &x[r * sz..(r + 1) * sz],
            &mask[r * sz..(r + 1) * sz],
            t_pts,
            c,
            &mut feats[r * 2 * c..(r + 1) * 2 * c],
        );
    }
    enc.forward_batch(th_enc, &feats, &mut z0, &mut se);

    let mut sys = MlpOde::new(dynamics, th_dyn, b, arch.range(1));
    let mut tape = OdeTape::new();
    let mut local = local_sampler(coef_l, seed, 0);
    let (zs, out) = ode::drive(
        &mut sys,
        &z0,
        Saveat::Grid(&ts64),
        opts,
        Some(&mut tape),
        &mut [&mut local],
    );

    // Masked reconstruction MSE + decoder backward per save point.
    let observed: f64 = mask.iter().map(|&m| m as f64).sum();
    let denom = observed.max(1.0);
    let mut sd = dec.batch_scratch(b);
    let mut pred = vec![0.0; b * c];
    let mut wblk = vec![0.0; b * c];
    let mut mse = 0.0;
    let mut save_grads = vec![vec![0.0; b * l]; t_pts];
    {
        let gdec = &mut grad[arch.range(2)];
        for t in 0..t_pts {
            dec.forward_batch(th_dec, &zs[t], &mut pred, &mut sd);
            for r in 0..b {
                let base = r * t_pts * c + t * c;
                for k in 0..c {
                    let m = mask[base + k] as f64;
                    let diff = pred[r * c + k] - x[base + k] as f64;
                    mse += m * diff * diff / denom;
                    wblk[r * c + k] = 2.0 * m * diff / denom;
                }
            }
            dec.vjp_batch(th_dec, &zs[t], &wblk, &mut save_grads[t], gdec, &mut sd);
        }
    }

    // KL-annealed latent prior term: kl · ½ mean(z0²).
    let kl_term = kl_coef * 0.5 * z0.iter().map(|z| z * z).sum::<f64>() / (b * l) as f64;

    let (reg, r_l) = resolve_local(RegCoefs::global(coef_e, coef_s), &local, coef_l);
    let mut dz0 = ode_backward_sys(&tape, &opts.tableau, &save_grads, &reg, grad, &mut sys);
    for (g, z) in dz0.iter_mut().zip(&z0) {
        *g += kl_coef * z / (b * l) as f64;
    }

    // Encoder backward over the pooled features (input cotangent is
    // discarded — the features are data).
    {
        let genc = &mut grad[arch.range(0)];
        let mut gx = vec![0.0; b * 2 * c];
        enc.vjp_batch(th_enc, &feats, &dz0, &mut gx, genc, &mut se);
    }
    Ok((mse + kl_term, mse, out.stats(), out.error_kind(), r_l))
}

fn latent_ode_predict(
    enc: &Mlp,
    dynamics: &Mlp,
    dec: &Mlp,
    arch: &Arch,
    theta: &[f64],
    x: &[f32],
    mask: &[f32],
    ts: &[f32],
    opts: &SolveOptions,
) -> Result<(Vec<f32>, Metrics)> {
    let c = dec.out_dim();
    let t_pts = ts.len();
    ensure!(
        !x.is_empty() && x.len() % (t_pts * c) == 0 && mask.len() == x.len(),
        "series batch shape"
    );
    let b = x.len() / (t_pts * c);
    let l = dynamics.in_dim();
    let th_enc = &theta[arch.range(0)];
    let th_dyn = &theta[arch.range(1)];
    let th_dec = &theta[arch.range(2)];
    let ts64: Vec<f64> = ts.iter().map(|&t| t as f64).collect();

    let mut se = enc.batch_scratch(b);
    let mut feats = vec![0.0; b * 2 * c];
    let mut z0 = vec![0.0; b * l];
    for r in 0..b {
        let sz = t_pts * c;
        let (xs, ms) = (&x[r * sz..(r + 1) * sz], &mask[r * sz..(r + 1) * sz]);
        series_features(xs, ms, t_pts, c, &mut feats[r * 2 * c..(r + 1) * 2 * c]);
    }
    enc.forward_batch(th_enc, &feats, &mut z0, &mut se);
    let mut sys = MlpOde::new(dynamics, th_dyn, b, 0..0);
    let (zs, out) = ode::drive(&mut sys, &z0, Saveat::Grid(&ts64), opts, None, &mut []);
    let observed: f64 = mask.iter().map(|&m| m as f64).sum();
    let denom = observed.max(1.0);
    let mut sd = dec.batch_scratch(b);
    let mut pred = vec![0.0; b * c];
    let mut mse = 0.0;
    let mut preds = vec![0.0f32; b * t_pts * c];
    for (t, zt) in zs.iter().enumerate() {
        dec.forward_batch(th_dec, zt, &mut pred, &mut sd);
        for r in 0..b {
            let base = r * t_pts * c + t * c;
            for k in 0..c {
                let m = mask[base + k] as f64;
                let diff = pred[r * c + k] - x[base + k] as f64;
                mse += m * diff * diff / denom;
                preds[base + k] = pred[r * c + k] as f32;
            }
        }
    }
    Ok((preds, metrics(mse, mse, &out.stats(), out.error_kind())))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::spiral;

    fn spiral_fixture(t_pts: usize) -> (Vec<f32>, Vec<f32>) {
        let ts = spiral::uniform_grid(t_pts, 1.0);
        let traj = spiral::spiral_ode_trajectory([2.0, 0.0], &ts);
        (traj, ts.iter().map(|&t| t as f32).collect())
    }

    #[test]
    fn init_params_seeded_and_sized() {
        let be = NativeBackend::new();
        for model in ["spiral_node", "spiral_nsde", "mnist_node", "mnist_nsde", "latent_ode"] {
            let info = be.model(model).unwrap();
            let a = be.init_params(model, 3).unwrap();
            assert_eq!(a.len(), info.params_size, "{model}");
            assert_eq!(info.opt_state_size, 2 * info.params_size, "{model}");
            assert!(a.iter().all(|v| v.is_finite()), "{model}");
            assert!(a.iter().any(|&v| v != 0.0), "{model}");
            assert_eq!(a, be.init_params(model, 3).unwrap(), "{model}");
            assert_ne!(a, be.init_params(model, 4).unwrap(), "{model}");
        }
        assert!(be.model("nope").is_err());
    }

    #[test]
    fn ladders_ascend() {
        let be = NativeBackend::new();
        for model in ["spiral_node", "spiral_nsde", "mnist_node", "mnist_nsde", "latent_ode"] {
            let ladder = be.ladder(model, false).unwrap();
            assert!(ladder.windows(2).all(|w| w[0] < w[1]), "{model}: {ladder:?}");
            assert_eq!(ladder, be.ladder(model, true).unwrap(), "tay aliases plain");
        }
    }

    #[test]
    fn spiral_node_training_decreases_loss_and_accumulates_r_e() {
        let (traj, ts) = spiral_fixture(16);
        let be = NativeBackend::new();
        let info = be.model("spiral_node").unwrap();
        let mut state = TrainState::new(
            be.init_params("spiral_node", 0).unwrap(),
            info.opt_state_size,
        );
        let data = TrainData::Trajectory { data: &traj, ts: &ts };
        let coefs = StepCoefs {
            lr: 0.02,
            coef_e: 100.0,
            ..Default::default()
        };
        let mut first = f64::NAN;
        let mut last = f64::NAN;
        for it in 0..25 {
            let out = be
                .train_step("spiral_node", false, 0, &state, &data, &coefs)
                .unwrap();
            assert!(out.metrics.loss.is_finite());
            assert!(out.metrics.r_e > 0.0, "white-boxed R_E must accumulate");
            assert!(out.metrics.nfe > 0.0);
            if it == 0 {
                first = out.metrics.loss;
            }
            last = out.metrics.loss;
            state.update(out.params, out.opt_state).unwrap();
        }
        assert!(state.is_finite());
        assert!(
            last < first,
            "25 Adam steps must reduce the loss ({first} -> {last})"
        );
    }

    /// Run a few committed train steps and return the final parameters.
    /// Several steps, not one: Adam's bias-corrected first update is
    /// `≈ lr · sign(g)`, so a small gradient perturbation only becomes
    /// visible in f32 parameters once `m`/`v` carry history.
    fn train_params(
        be: &NativeBackend,
        model: &str,
        data: &TrainData,
        coefs: &StepCoefs,
        steps: usize,
    ) -> (Vec<f32>, Metrics) {
        let info = be.model(model).unwrap();
        let mut state =
            TrainState::new(be.init_params(model, 0).unwrap(), info.opt_state_size);
        let mut last = Metrics::default();
        for _ in 0..steps {
            let out = be.train_step(model, false, 0, &state, data, coefs).unwrap();
            last = out.metrics;
            state.update(out.params, out.opt_state).unwrap();
        }
        (state.params, last)
    }

    #[test]
    fn coef_s_gradient_path_is_live() {
        // Same init, same data: toggling coef_s must change the trained
        // parameters — the stiffness regularizer is differentiated through
        // the tape, not just added to the reported loss value.
        let (traj, ts) = spiral_fixture(16);
        let be = NativeBackend::new();
        let data = TrainData::Trajectory { data: &traj, ts: &ts };
        let with_sr = StepCoefs {
            coef_e: 100.0,
            coef_s: 0.02,
            ..Default::default()
        };
        let without = StepCoefs {
            coef_e: 100.0,
            coef_s: 0.0,
            ..Default::default()
        };
        let (pa, ma) = train_params(&be, "spiral_node", &data, &with_sr, 3);
        let (pb, _) = train_params(&be, "spiral_node", &data, &without, 3);
        assert!(ma.r_s > 0.0, "R_S must accumulate");
        assert_ne!(
            pa, pb,
            "coef_s must alter the ODE gradient, not just the loss value"
        );

        // SDE path: same check on the spiral NSDE moment objective.
        let ts_sde = spiral::uniform_grid(8, 0.5);
        let ts_f32: Vec<f32> = ts_sde.iter().map(|&t| t as f32).collect();
        let (mu, var) = spiral::spiral_sde_moments([1.0, 1.0], &ts_sde, 64, 1);
        let u0: Vec<f32> = (0..8).flat_map(|_| [1.0f32, 1.0]).collect();
        let data = TrainData::Moments { u0: &u0, mu: &mu, var: &var, ts: &ts_f32 };
        let with_sr = StepCoefs {
            coef_s: 0.01,
            seed: 7,
            ..Default::default()
        };
        let without = StepCoefs {
            coef_s: 0.0,
            seed: 7,
            ..Default::default()
        };
        let (pa, ma) = train_params(&be, "spiral_nsde", &data, &with_sr, 3);
        let (pb, _) = train_params(&be, "spiral_nsde", &data, &without, 3);
        assert!(ma.r_s > 0.0);
        assert_ne!(
            pa, pb,
            "coef_s must alter the SDE gradient, not just the loss value"
        );
    }

    #[test]
    fn coef_l_gradient_path_is_live() {
        // Same init, same data, same seed: toggling coef_l must change
        // the trained parameters — the sampled-step local regularizer is
        // differentiated through the tape at the sampled step, not just
        // added to the reported loss value.
        let (traj, ts) = spiral_fixture(16);
        let be = NativeBackend::new();
        let data = TrainData::Trajectory { data: &traj, ts: &ts };
        let with_lr = StepCoefs {
            coef_l: 100.0,
            seed: 5,
            ..Default::default()
        };
        let without = StepCoefs {
            coef_l: 0.0,
            seed: 5,
            ..Default::default()
        };
        let (pa, ma) = train_params(&be, "spiral_node", &data, &with_lr, 3);
        let (pb, mb) = train_params(&be, "spiral_node", &data, &without, 3);
        assert!(ma.r_l > 0.0, "sampled R_L must be reported");
        assert!(
            ma.r_l <= ma.r_e,
            "one step's error term cannot exceed the R_E sum"
        );
        assert_eq!(mb.r_l, 0.0, "R_L reads 0 when the method is off");
        assert_ne!(
            pa, pb,
            "coef_l must alter the gradient, not just the loss value"
        );

        // SDE path: same check on the spiral NSDE moment objective.
        let ts_sde = spiral::uniform_grid(8, 0.5);
        let ts_f32: Vec<f32> = ts_sde.iter().map(|&t| t as f32).collect();
        let (mu, var) = spiral::spiral_sde_moments([1.0, 1.0], &ts_sde, 64, 1);
        let u0: Vec<f32> = (0..8).flat_map(|_| [1.0f32, 1.0]).collect();
        let data = TrainData::Moments { u0: &u0, mu: &mu, var: &var, ts: &ts_f32 };
        let with_lr = StepCoefs {
            coef_l: 1.0,
            seed: 7,
            ..Default::default()
        };
        let without = StepCoefs {
            coef_l: 0.0,
            seed: 7,
            ..Default::default()
        };
        let (pa, ma) = train_params(&be, "spiral_nsde", &data, &with_lr, 3);
        let (pb, _) = train_params(&be, "spiral_nsde", &data, &without, 3);
        assert!(ma.r_l > 0.0, "ensemble R_L sums the per-trajectory samples");
        assert_ne!(
            pa, pb,
            "coef_l must alter the SDE gradient, not just the loss value"
        );
    }

    #[test]
    fn with_solver_switches_the_ode_tableau() {
        let (traj, ts) = spiral_fixture(16);
        let data = TrainData::Trajectory { data: &traj, ts: &ts };
        let coefs = StepCoefs {
            coef_e: 100.0,
            ..Default::default()
        };
        let tsit = NativeBackend::new();
        assert_eq!(tsit.solver().name, "tsit5");
        let dopri = NativeBackend::new().with_solver("DoPri5").unwrap();
        assert_eq!(dopri.solver().name, "dopri5");
        assert!(NativeBackend::new().with_solver("rk4").is_err());

        let (pa, ma) = train_params(&tsit, "spiral_node", &data, &coefs, 2);
        let (pb, mb) = train_params(&dopri, "spiral_node", &data, &coefs, 2);
        assert!(ma.loss.is_finite() && mb.loss.is_finite());
        assert!(pb.iter().all(|p| p.is_finite()));
        assert_ne!(
            (ma.nfe, pa.first().copied()),
            (mb.nfe, pb.first().copied()),
            "a different tableau must change the realized solve"
        );
    }

    #[test]
    fn budget_exhaustion_reports_failure_for_escalation() {
        let (traj, ts) = spiral_fixture(16);
        let be = NativeBackend::new().with_ladder("spiral_node", vec![2, 4, 4096]);
        let info = be.model("spiral_node").unwrap();
        let state = TrainState::new(
            be.init_params("spiral_node", 0).unwrap(),
            info.opt_state_size,
        );
        let data = TrainData::Trajectory { data: &traj, ts: &ts };
        let out = be
            .train_step("spiral_node", false, 0, &state, &data, &StepCoefs::default())
            .unwrap();
        assert!(!out.metrics.success, "2 attempts cannot cover 15 segments");
        assert_eq!(
            out.metrics.error,
            Some(SolveErrorKind::BudgetExhausted),
            "the router keys escalation off the typed kind"
        );
        let out = be
            .train_step("spiral_node", false, 2, &state, &data, &StepCoefs::default())
            .unwrap();
        assert!(out.metrics.success, "top rung must succeed");
        assert_eq!(out.metrics.error, None);
    }

    #[test]
    fn non_finite_params_surface_as_typed_error_not_a_panic() {
        // A blown-up parameter vector makes the first drift evaluation
        // NaN: train_step must return Ok with a NonFiniteState metric
        // block (the router skips the batch), and the backward walk over
        // the failed solve's short tape must stay panic-free.
        let (traj, ts) = spiral_fixture(16);
        let be = NativeBackend::new();
        let info = be.model("spiral_node").unwrap();
        let mut params = be.init_params("spiral_node", 0).unwrap();
        params[0] = f32::NAN;
        let state = TrainState::new(params.clone(), info.opt_state_size);
        let data = TrainData::Trajectory { data: &traj, ts: &ts };
        let out = be
            .train_step("spiral_node", false, 0, &state, &data, &StepCoefs::default())
            .unwrap();
        assert!(!out.metrics.success);
        assert_eq!(out.metrics.error, Some(SolveErrorKind::NonFiniteState));
        // Predict path contains the same failure.
        let (_, m) = be.predict("spiral_node", &params, &data, 0).unwrap();
        assert_eq!(m.error, Some(SolveErrorKind::NonFiniteState));
    }

    #[test]
    fn data_kind_mismatch_is_rejected() {
        let (traj, ts) = spiral_fixture(8);
        let be = NativeBackend::new();
        let info = be.model("mnist_node").unwrap();
        let state = TrainState::new(
            be.init_params("mnist_node", 0).unwrap(),
            info.opt_state_size,
        );
        let data = TrainData::Trajectory { data: &traj, ts: &ts };
        assert!(be
            .train_step("mnist_node", false, 0, &state, &data, &StepCoefs::default())
            .is_err());
        assert!(be.predict("mnist_node", &state.params, &data, 0).is_err());
    }

    #[test]
    fn mnist_node_step_and_predict_are_finite() {
        let be = NativeBackend::new();
        let info = be.model("mnist_node").unwrap();
        let mut state = TrainState::new(
            be.init_params("mnist_node", 1).unwrap(),
            info.opt_state_size,
        );
        let b = 4;
        let mut rng = Rng::new(9);
        let x: Vec<f32> = (0..b * IMG_DIM).map(|_| rng.range(0.0, 1.0) as f32).collect();
        let mut y = vec![0.0f32; b * CLASSES];
        for r in 0..b {
            y[r * CLASSES + r % CLASSES] = 1.0;
        }
        let data = TrainData::Classify { x: &x, y: &y };
        let coefs = StepCoefs {
            coef_e: 10.0,
            ..Default::default()
        };
        let before = state.params.clone();
        let out = be
            .train_step("mnist_node", false, 0, &state, &data, &coefs)
            .unwrap();
        assert!(out.metrics.loss.is_finite());
        assert!(out.metrics.r_e > 0.0);
        state.update(out.params, out.opt_state).unwrap();
        assert_ne!(before, state.params, "gradients must move every block");
        let (logits, m) = be.predict("mnist_node", &state.params, &data, 0).unwrap();
        assert_eq!(logits.len(), b * CLASSES);
        assert!(m.loss.is_finite() && (0.0..=1.0).contains(&m.metric));
    }

    #[test]
    fn mnist_nsde_counts_four_nfe_per_attempt() {
        let be = NativeBackend::new();
        let info = be.model("mnist_nsde").unwrap();
        let state = TrainState::new(
            be.init_params("mnist_nsde", 1).unwrap(),
            info.opt_state_size,
        );
        let b = 4;
        let mut rng = Rng::new(10);
        let x: Vec<f32> = (0..b * IMG_DIM).map(|_| rng.range(0.0, 1.0) as f32).collect();
        let mut y = vec![0.0f32; b * CLASSES];
        for r in 0..b {
            y[r * CLASSES + r % CLASSES] = 1.0;
        }
        let data = TrainData::Classify { x: &x, y: &y };
        let out = be
            .train_step("mnist_nsde", false, 0, &state, &data, &StepCoefs::default())
            .unwrap();
        let m = out.metrics;
        assert!(m.loss.is_finite());
        assert!((m.nfe - 4.0 * (m.naccept + m.nreject)).abs() < 1e-9);
    }

    #[test]
    fn latent_ode_step_wires_kl_and_masks() {
        let be = NativeBackend::new();
        let info = be.model("latent_ode").unwrap();
        let mut state = TrainState::new(
            be.init_params("latent_ode", 2).unwrap(),
            info.opt_state_size,
        );
        let (b, t_pts, c) = (3, 6, SERIES_CHANNELS);
        let mut rng = Rng::new(11);
        let x: Vec<f32> = (0..b * t_pts * c).map(|_| rng.range(-1.0, 1.0) as f32).collect();
        let mask: Vec<f32> = (0..b * t_pts * c)
            .map(|_| if rng.uniform() < 0.5 { 1.0 } else { 0.0 })
            .collect();
        let ts: Vec<f32> = (0..t_pts).map(|i| i as f32 / (t_pts - 1) as f32).collect();
        let data = TrainData::Series { x: &x, mask: &mask, ts: &ts };
        let coefs = StepCoefs {
            kl: 0.5,
            coef_e: 10.0,
            ..Default::default()
        };
        let out = be
            .train_step("latent_ode", false, 0, &state, &data, &coefs)
            .unwrap();
        assert!(out.metrics.loss.is_finite());
        assert!(out.metrics.loss >= out.metrics.metric, "loss includes KL + R terms");
        state.update(out.params, out.opt_state).unwrap();
        let (preds, m) = be.predict("latent_ode", &state.params, &data, 0).unwrap();
        assert_eq!(preds.len(), b * t_pts * c);
        assert!(m.loss.is_finite());
    }

    #[test]
    fn spiral_nsde_step_trains_on_moments() {
        let ts = spiral::uniform_grid(8, 0.5);
        let ts_f32: Vec<f32> = ts.iter().map(|&t| t as f32).collect();
        let (mu, var) = spiral::spiral_sde_moments([1.0, 1.0], &ts, 64, 1);
        let n_traj = 8;
        let u0: Vec<f32> = (0..n_traj).flat_map(|_| [1.0f32, 1.0]).collect();
        let be = NativeBackend::new();
        let info = be.model("spiral_nsde").unwrap();
        let mut state = TrainState::new(
            be.init_params("spiral_nsde", 0).unwrap(),
            info.opt_state_size,
        );
        let data = TrainData::Moments { u0: &u0, mu: &mu, var: &var, ts: &ts_f32 };
        let coefs = StepCoefs {
            coef_e: 1.0,
            seed: 77,
            ..Default::default()
        };
        let out = be
            .train_step("spiral_nsde", false, 0, &state, &data, &coefs)
            .unwrap();
        assert!(out.metrics.loss.is_finite());
        assert!(out.metrics.r_e > 0.0);
        state.update(out.params, out.opt_state).unwrap();
        assert!(state.is_finite());
        let (ens, m) = be.predict("spiral_nsde", &state.params, &data, 5).unwrap();
        assert_eq!(ens.len(), ts.len() * n_traj * 2);
        assert!(m.nfe >= (ts.len() as f64 - 1.0) * 4.0);
    }
}
