//! The backend seam: one trait, two training runtimes.
//!
//! Every experiment driver in `coordinator::experiments` is generic over
//! `&dyn Backend`, which owns the model-facing half of a training step:
//!
//!  * **`NativeBackend`** (`runtime::native`, always built) — pure-Rust
//!    flat-parameter models integrated by the native adaptive solvers,
//!    trained via discrete adjoints through the accepted steps
//!    (`solvers::adjoint`).  This is what tier-1 CI exercises end-to-end.
//!  * **`Engine`** (`runtime::engine`, behind the `pjrt` cargo feature) —
//!    the AOT path: lowered HLO artifacts executed through PJRT.
//!
//! The contract mirrors the artifact signatures: flat `f32` parameter /
//! optimizer-state vectors, experiment data handed over as a typed
//! [`TrainData`] payload, scalar coefficients in [`StepCoefs`], and the
//! standard 9-element [`Metrics`] block back.  `train_step` returns the
//! *candidate* next state in a [`StepOutput`] without committing it —
//! the budget-ladder router decides whether a truncated step is retried
//! on a bigger rung or accepted (see `coordinator::budget`).

use std::collections::BTreeMap;

use anyhow::{bail, Result};

use super::state::{Metrics, TrainState};

/// A typed runtime input tensor (shared by both backends' marshalling).
#[derive(Clone, Debug)]
pub enum Input<'a> {
    /// Dense f32 tensor (row-major); shape checked against the spec.
    F32(&'a [f32]),
    /// f32 scalar.
    Scalar(f32),
    /// u32 scalar (RNG seeds).
    SeedU32(u32),
}

/// Experiment data for one train/predict call, in the shape the paper's
/// five experiments use.  Borrowed — the coordinator owns the dataset.
#[derive(Clone, Copy, Debug)]
pub enum TrainData<'a> {
    /// Ground-truth trajectory fit (spiral NODE, Fig. 2):
    /// `data` is row-major `[T, d]`, `ts` the save grid.
    Trajectory { data: &'a [f32], ts: &'a [f32] },
    /// Ensemble moment matching (spiral NSDE, Table 3): `u0` row-major
    /// `[n_traj, d]`, `mu`/`var` row-major `[T, d]`, `ts` the save grid.
    Moments {
        u0: &'a [f32],
        mu: &'a [f32],
        var: &'a [f32],
        ts: &'a [f32],
    },
    /// Batched classification (MNIST NODE/NSDE): `x` `[B, D]`, one-hot
    /// `y` `[B, C]`.
    Classify { x: &'a [f32], y: &'a [f32] },
    /// Masked time series (Physionet Latent ODE): `x`/`mask` row-major
    /// `[B, T, C]`, `ts` the shared grid.
    Series {
        x: &'a [f32],
        mask: &'a [f32],
        ts: &'a [f32],
    },
}

impl TrainData<'_> {
    pub fn kind(&self) -> &'static str {
        match self {
            TrainData::Trajectory { .. } => "trajectory",
            TrainData::Moments { .. } => "moments",
            TrainData::Classify { .. } => "classify",
            TrainData::Series { .. } => "series",
        }
    }
}

/// Scalar coefficients of one train step (the coordinator owns every
/// schedule; backends just consume the values).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct StepCoefs {
    pub lr: f32,
    /// `R_E` coefficient (ERNODE/ERNSDE), 0 disables.
    pub coef_e: f32,
    /// `R_S` coefficient (SRNODE/SRNSDE), 0 disables.
    pub coef_s: f32,
    /// Sampled-step local error coefficient (LRNODE/LRNSDE), 0 disables.
    /// Native backend only: the forward solve reservoir-samples one
    /// accepted step (seeded by [`StepCoefs::seed`]) and the discrete
    /// adjoint differentiates exactly that step's `E_ĵ |h_ĵ|` term.
    pub coef_l: f32,
    /// TayNODE auxiliary coefficient (PJRT `tay_train` artifacts only).
    pub coef_aux: f32,
    /// KL-annealing coefficient (Latent ODE).
    pub kl: f32,
    /// Integration end time (STEER samples this per iteration).
    pub t1: f32,
    /// Per-step RNG seed (SDE driving noise, encoder sampling).
    pub seed: u32,
}

impl Default for StepCoefs {
    fn default() -> Self {
        StepCoefs {
            lr: 0.01,
            coef_e: 0.0,
            coef_s: 0.0,
            coef_l: 0.0,
            coef_aux: 0.0,
            kl: 0.0,
            t1: 1.0,
            seed: 0,
        }
    }
}

/// Uncommitted result of one train step: the candidate next state plus
/// the step's metric block.  The caller commits via
/// [`TrainState::update`] once the budget router accepts the step.
#[derive(Clone, Debug)]
pub struct StepOutput {
    pub params: Vec<f32>,
    pub opt_state: Vec<f32>,
    pub metrics: Metrics,
}

/// Result of one *gradient* evaluation ([`Backend::grad_step`]): the flat
/// objective gradient at the current parameters plus the step's metric
/// block, with **no optimizer update applied**.  This is the unit of work
/// the distributed layer (`dist`) ships to workers: the coordinator owns
/// the Adam state and applies the update once after reducing shard
/// gradients (DESIGN.md §Distributed).
#[derive(Clone, Debug)]
pub struct GradOutput {
    /// Flat `d(loss)/d(params)` — same length/layout as
    /// [`TrainState::params`].  `f32` on the seam (the wire dtype);
    /// reducers widen to f64 before combining.
    pub grad: Vec<f32>,
    pub metrics: Metrics,
}

/// Per-model metadata (the backend-agnostic slice of the PJRT manifest's
/// `ModelSpec`).
#[derive(Clone, Debug)]
pub struct ModelInfo {
    pub name: String,
    pub params_size: usize,
    pub opt_state_size: usize,
    pub optimizer: String,
    /// Paper hyper-parameters (lr, regularization coefficients, ...).
    pub hyper: BTreeMap<String, f64>,
}

/// Everything a backend needs to reconstruct a trained model for
/// inference — the backend-owned half of a serving checkpoint
/// (`serve::checkpoint` adds the coordinator-owned half: experiment id,
/// method label, serving grid).  Produced by [`Backend::export_state`],
/// validated back into a usable parameter vector by
/// [`Backend::import_state`].
#[derive(Clone, Debug, PartialEq)]
pub struct ExportedState {
    /// Backend model name (`spiral_node`, ...).
    pub model: String,
    /// Flat trained parameters (bit-exact; the checkpoint codec must
    /// round-trip these without loss).
    pub params: Vec<f32>,
    /// Solver identifier (`Tableau` name for the native backend).
    pub solver: String,
    /// Train-time solver tolerance (rtol = atol).
    pub train_tol: f64,
    /// Inference tolerance (the early-exiting predict setting).
    pub predict_tol: f64,
    /// Default total step-attempt budget for a served solve (the top
    /// budget-ladder rung).
    pub step_budget: u64,
    /// Paper hyper-parameters (lr, regularization coefficients, ...).
    pub hyper: BTreeMap<String, f64>,
}

/// A training/inference runtime for the paper's model zoo.
pub trait Backend {
    /// Short runtime name ("native" / "pjrt").
    fn name(&self) -> &'static str;

    /// Names of the models this backend can run (stable order).
    fn models(&self) -> Vec<String>;

    /// Metadata for `model` (errors on unknown models).
    fn model(&self, model: &str) -> Result<ModelInfo>;

    /// Ascending step-attempt budgets — the budget-ladder rungs the
    /// router escalates/descends over.  `tay` selects the TayNODE ladder
    /// where the backend distinguishes it.
    fn ladder(&self, model: &str, tay: bool) -> Result<Vec<usize>>;

    /// Seeded parameter initialization (flat vector of
    /// `ModelInfo::params_size`).
    fn init_params(&self, model: &str, seed: u32) -> Result<Vec<f32>>;

    /// Amortize compile/setup cost for every rung + the predict path
    /// (PJRT JIT warm-up; native no-op).
    fn warm(&self, model: &str, tay: bool) -> Result<()> {
        let _ = (model, tay);
        Ok(())
    }

    /// One optimizer step on ladder rung `rung`.  Does **not** commit:
    /// returns the candidate state + metrics for the router to judge.
    fn train_step(
        &self,
        model: &str,
        tay: bool,
        rung: usize,
        state: &TrainState,
        data: &TrainData,
        coefs: &StepCoefs,
    ) -> Result<StepOutput>;

    /// Evaluate the objective gradient at `state.params` on ladder rung
    /// `rung` **without** applying the optimizer update — the distributed
    /// seam.  `state.opt_state` is ignored (workers ship an empty one).
    /// **Unsupported by default**: only backends that expose a raw
    /// gradient (the native path; `train_step` is layered on top of it
    /// there) override this.
    fn grad_step(
        &self,
        model: &str,
        tay: bool,
        rung: usize,
        state: &TrainState,
        data: &TrainData,
        coefs: &StepCoefs,
    ) -> Result<GradOutput> {
        let _ = (model, tay, rung, state, data, coefs);
        bail!(
            "backend {:?} does not support grad_step (distributed \
             training is native-backend only)",
            self.name()
        )
    }

    /// Number of independently shardable items in `data` for `model` —
    /// the unit the data-parallel sharder splits over (batch rows for
    /// classification, series for Latent ODE, 1 for whole-trajectory
    /// fits).  Defaults to 1 (unsplittable).
    fn shard_items(&self, model: &str, data: &TrainData) -> Result<usize> {
        let _ = (model, data);
        Ok(1)
    }

    /// Inference with the early-exiting (fully adaptive) solver.
    /// Returns the primary output tensor (trajectory / logits / ...) and
    /// the standard metric block.
    fn predict(
        &self,
        model: &str,
        params: &[f32],
        data: &TrainData,
        seed: u32,
    ) -> Result<(Vec<f32>, Metrics)>;

    /// Package trained parameters into an [`ExportedState`] carrying
    /// everything this backend needs to serve the model later
    /// (`serve::checkpoint` persists it).  **Unsupported by default**:
    /// the PJRT engine's solver/tolerances are baked into its lowered
    /// artifacts, so it cannot emit a self-describing state — only the
    /// native backend overrides this pair.
    fn export_state(&self, model: &str, params: &[f32]) -> Result<ExportedState> {
        let _ = (model, params);
        bail!(
            "backend {:?} does not support state export (serving \
             checkpoints are native-backend only)",
            self.name()
        )
    }

    /// Validate an [`ExportedState`] against this backend's model zoo and
    /// return the parameter vector ready for [`Backend::predict`].
    /// Unsupported by default (see [`Backend::export_state`]).
    fn import_state(&self, state: &ExportedState) -> Result<Vec<f32>> {
        let _ = state;
        bail!(
            "backend {:?} does not support state import (serving \
             checkpoints are native-backend only)",
            self.name()
        )
    }
}
