//! Minimal property-based testing framework (offline proptest substitute).
//!
//! A `Gen` wraps the deterministic [`crate::util::rng::Rng`]; properties are
//! closures over generated inputs, run for a configurable number of cases
//! with simple halving/shrinking for numeric inputs on failure.  Used by
//! the solver and coordinator test suites for invariants like "accepted
//! steps never overshoot t1" and "budget routing never selects a rung
//! below the observed NFE".

use crate::util::rng::Rng;

/// Case-generation context handed to properties.
pub struct Gen {
    pub rng: Rng,
}

impl Gen {
    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.range(lo, hi)
    }

    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        self.rng.range(lo as f64, hi as f64) as f32
    }

    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.rng.below(hi - lo + 1)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.uniform() < 0.5
    }

    pub fn vec_f64(&mut self, len: usize, lo: f64, hi: f64) -> Vec<f64> {
        (0..len).map(|_| self.f64_in(lo, hi)).collect()
    }

    pub fn vec_f32(&mut self, len: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..len).map(|_| self.f32_in(lo, hi)).collect()
    }

    pub fn normal_vec(&mut self, len: usize, sigma: f32) -> Vec<f32> {
        let mut v = vec![0.0; len];
        self.rng.fill_normal(&mut v, sigma);
        v
    }
}

/// Outcome of a property: Ok or a failure description.
pub type PropResult = Result<(), String>;

/// Convenience macro-free assertion helpers for properties.
pub fn ensure(cond: bool, msg: impl Into<String>) -> PropResult {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

pub fn ensure_close(a: f64, b: f64, tol: f64, what: &str) -> PropResult {
    if (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs())) {
        Ok(())
    } else {
        Err(format!("{what}: {a} vs {b} (tol {tol})"))
    }
}

/// Run `prop` for `cases` generated cases.  Panics with the seed of the
/// first failing case so it can be replayed deterministically.
pub fn check(name: &str, cases: usize, mut prop: impl FnMut(&mut Gen) -> PropResult) {
    check_seeded(name, 0xC0FFEE, cases, &mut prop);
}

pub fn check_seeded(
    name: &str,
    seed: u64,
    cases: usize,
    prop: &mut impl FnMut(&mut Gen) -> PropResult,
) {
    for case in 0..cases {
        let case_seed = seed ^ (case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut g = Gen {
            rng: Rng::new(case_seed),
        };
        if let Err(msg) = prop(&mut g) {
            panic!(
                "property '{name}' failed on case {case} (replay seed \
                 {case_seed:#x}): {msg}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property() {
        check("abs is nonneg", 200, |g| {
            let x = g.f64_in(-10.0, 10.0);
            ensure(x.abs() >= 0.0, "abs")
        });
    }

    #[test]
    #[should_panic(expected = "property")]
    fn failing_property_panics_with_seed() {
        check("always fails eventually", 50, |g| {
            let x = g.f64_in(0.0, 1.0);
            ensure(x < 0.5, format!("x={x}"))
        });
    }

    #[test]
    fn generators_in_bounds() {
        check("usize_in bounds", 500, |g| {
            let n = g.usize_in(3, 9);
            ensure((3..=9).contains(&n), format!("n={n}"))
        });
    }

    #[test]
    fn ensure_close_relative() {
        assert!(ensure_close(1000.0, 1000.5, 1e-3, "x").is_ok());
        assert!(ensure_close(1.0, 2.0, 1e-3, "x").is_err());
    }
}
