//! Minimal JSON parser + writer (offline substitute for serde_json).
//!
//! Supports the full JSON grammar (RFC 8259) minus exotic number forms;
//! used for `artifacts/manifest.json`, experiment configs and run records.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

/// A JSON value.  Objects use BTreeMap for deterministic serialization.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            bail!("trailing content at byte {}", p.pos);
        }
        Ok(v)
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => Err(anyhow!("expected object, got {self:?}")),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(a) => Ok(a),
            _ => Err(anyhow!("expected array, got {self:?}")),
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => Err(anyhow!("expected string, got {self:?}")),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => Err(anyhow!("expected number, got {self:?}")),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => Err(anyhow!("expected bool, got {self:?}")),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        Ok(self.as_f64()? as usize)
    }

    /// Field access with a useful error message.
    pub fn get(&self, key: &str) -> Result<&Json> {
        self.as_obj()?
            .get(key)
            .ok_or_else(|| anyhow!("missing key {key:?}"))
    }

    /// Optional field access.
    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0, true);
        s
    }

    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0, false);
        s
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        let pad = |out: &mut String, n: usize| {
            if pretty {
                out.push('\n');
                for _ in 0..n {
                    out.push(' ');
                }
            }
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                // -0.0 must not take the i64 path (`-0.0 as i64` is 0,
                // which would drop the sign bit on the wire).
                if n.fract() == 0.0 && n.abs() < 1e15 && !(*n == 0.0 && n.is_sign_negative()) {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    v.write(out, indent + 1, pretty);
                }
                if !a.is_empty() {
                    pad(out, indent);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    v.write(out, indent + 1, pretty);
                }
                if !m.is_empty() {
                    pad(out, indent);
                }
                out.push('}');
            }
        }
    }
}

impl From<f64> for Json {
    fn from(v: f64) -> Self {
        Json::Num(v)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Self {
        Json::Num(v as f64)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Self {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Self {
        Json::Str(v)
    }
}
impl From<bool> for Json {
    fn from(v: bool) -> Self {
        Json::Bool(v)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Self {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

/// Convenience builder for objects: `obj([("k", 1.0.into())])`.
pub fn obj<I: IntoIterator<Item = (&'static str, Json)>>(pairs: I) -> Json {
    Json::Obj(
        pairs
            .into_iter()
            .map(|(k, v)| (k.to_string(), v))
            .collect(),
    )
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            bail!(
                "expected {:?} at byte {} (found {:?})",
                b as char,
                self.pos,
                self.peek().map(|c| c as char)
            )
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.pos)
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => bail!("unexpected {:?} at byte {}", other, self.pos),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                other => bail!("expected , or }} got {other:?} at {}", self.pos),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut arr = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(arr));
        }
        loop {
            arr.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(arr));
                }
                other => bail!("expected , or ] got {other:?} at {}", self.pos),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => bail!("unterminated string"),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .bytes
                                .get(self.pos + 1..self.pos + 5)
                                .ok_or_else(|| anyhow!("bad \\u escape"))?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex)?,
                                16,
                            )?;
                            s.push(
                                char::from_u32(code)
                                    .ok_or_else(|| anyhow!("bad codepoint"))?,
                            );
                            self.pos += 4;
                        }
                        other => bail!("bad escape {other:?}"),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 encoded char.
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Json::Num(text.parse::<f64>()?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for t in ["null", "true", "false", "42", "-1.5", "\"hi\""] {
            let v = Json::parse(t).unwrap();
            assert_eq!(Json::parse(&v.to_string_compact()).unwrap(), v);
        }
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x\ny");
    }

    #[test]
    fn roundtrip_pretty() {
        let v = obj([
            ("x", Json::from(1.25)),
            ("y", Json::from(vec![1.0f64, 2.0])),
            ("s", Json::from("quote\"inside")),
        ]);
        let pretty = v.to_string_pretty();
        assert_eq!(Json::parse(&pretty).unwrap(), v);
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(Json::parse("{} x").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn unicode_escapes() {
        let v = Json::parse(r#""Aé""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "Aé");
    }

    #[test]
    fn numbers_scientific() {
        assert_eq!(Json::parse("1e3").unwrap().as_f64().unwrap(), 1000.0);
        assert_eq!(Json::parse("-2.5E-2").unwrap().as_f64().unwrap(), -0.025);
    }

    #[test]
    fn negative_zero_keeps_its_sign_bit() {
        let wire = Json::Num(-0.0).to_string_compact();
        let back = Json::parse(&wire).unwrap().as_f64().unwrap();
        assert!(back == 0.0 && back.is_sign_negative(), "wire {wire:?} -> {back}");
        assert_eq!(Json::Num(0.0).to_string_compact(), "0");
        assert_eq!(Json::Num(-5.0).to_string_compact(), "-5");
    }
}
