//! Summary statistics for metric series and benchmark timings.

/// Mean of a slice (0.0 for empty input).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation (n-1 denominator; 0.0 for n < 2).
pub fn std(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64)
        .sqrt()
}

/// Percentile via linear interpolation on the sorted data (p in [0, 100]).
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut sorted = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let w = rank - lo as f64;
        sorted[lo] * (1.0 - w) + sorted[hi] * w
    }
}

pub fn min(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::INFINITY, f64::min)
}

pub fn max(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::NEG_INFINITY, f64::max)
}

/// `mean ± std` summary of a set of replicate measurements.
#[derive(Clone, Copy, Debug, Default)]
pub struct Summary {
    pub mean: f64,
    pub std: f64,
    pub n: usize,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Self {
        Self {
            mean: mean(xs),
            std: std(xs),
            n: xs.len(),
        }
    }
}

impl std::fmt::Display for Summary {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        if self.n > 1 {
            write!(f, "{:.4} ± {:.4}", self.mean, self.std)
        } else {
            write!(f, "{:.4}", self.mean)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std_basic() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((std(&xs) - 2.13809).abs() < 1e-4);
    }

    #[test]
    fn empty_and_singleton() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(std(&[]), 0.0);
        assert_eq!(std(&[3.0]), 0.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
    }

    #[test]
    fn percentiles() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 50.0), 3.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
        assert_eq!(percentile(&xs, 25.0), 2.0);
    }

    #[test]
    fn minmax() {
        let xs = [3.0, -1.0, 7.5];
        assert_eq!(min(&xs), -1.0);
        assert_eq!(max(&xs), 7.5);
    }
}
