//! Self-contained utility substrates.
//!
//! The build environment is fully offline with only the `xla` crate's
//! dependency closure available, so the conveniences a service would
//! normally pull from crates.io (serde/clap/criterion/proptest/tokio) are
//! implemented here from scratch: a JSON parser/writer, a deterministic
//! PRNG suite, a CLI argument parser, a scoped thread pool, a
//! property-based testing mini-framework, summary statistics, and a
//! paper-style table renderer.

pub mod cli;
pub mod json;
pub mod propcheck;
pub mod rng;
pub mod stats;
pub mod tablefmt;
pub mod threadpool;
pub mod timer;
