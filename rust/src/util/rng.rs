//! Deterministic pseudo-random number generation.
//!
//! SplitMix64 for seeding, xoshiro256++ as the main generator (Blackman &
//! Vigna), Box-Muller for normals.  Every stochastic component of the
//! coordinator (data synthesis, batch shuffling, STEER end-time sampling,
//! replica seeds, property-test case generation) draws from this module so
//! entire experiment runs are reproducible from a single u64 seed.

/// SplitMix64 — used to expand one seed into independent stream seeds.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256++ — fast, high-quality 64-bit generator.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second Box-Muller normal.
    spare_normal: Option<f64>,
}

impl Rng {
    /// Seed via SplitMix64 (the reference seeding procedure).
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
            spare_normal: None,
        }
    }

    /// Derive an independent child stream (stable under call order).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = (self.s[0].wrapping_add(self.s[3]))
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.uniform() * (hi - lo)
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        ((self.uniform() * n as f64) as usize).min(n - 1)
    }

    /// Standard normal via Box-Muller (pair cached).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        loop {
            let u1 = self.uniform();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.uniform();
            let r = (-2.0 * u1.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * u2;
            self.spare_normal = Some(r * theta.sin());
            return r * theta.cos();
        }
    }

    pub fn normal_f32(&mut self) -> f32 {
        self.normal() as f32
    }

    /// Fill a slice with N(0, sigma^2) samples.
    pub fn fill_normal(&mut self, out: &mut [f32], sigma: f32) {
        for v in out.iter_mut() {
            *v = self.normal_f32() * sigma;
        }
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn uniform_in_range() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn uniform_mean_is_half() {
        let mut r = Rng::new(11);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.uniform()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(13);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.01, "mean={mean}");
        assert!((var - 1.0).abs() < 0.02, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(3);
        for n in [1usize, 2, 7, 100] {
            for _ in 0..1000 {
                assert!(r.below(n) < n);
            }
        }
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Rng::new(9);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
