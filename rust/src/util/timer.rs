//! Wall-clock timing helpers for the training loop and bench harness.

use std::time::{Duration, Instant};

/// Accumulating stopwatch: many start/stop intervals, one total.
#[derive(Debug)]
pub struct Stopwatch {
    total: Duration,
    started: Option<Instant>,
}

impl Default for Stopwatch {
    fn default() -> Self {
        Self::new()
    }
}

impl Stopwatch {
    pub fn new() -> Self {
        Self {
            total: Duration::ZERO,
            started: None,
        }
    }

    /// Begin (or continue) an interval.  Calling `start` on an already
    /// running stopwatch **saturates**: the running interval keeps
    /// accumulating and the call is a no-op, so no elapsed time is ever
    /// silently discarded (the pre-fix behavior reset the interval in
    /// release builds and asserted in debug).
    pub fn start(&mut self) {
        if self.started.is_none() {
            self.started = Some(Instant::now());
        }
    }

    pub fn stop(&mut self) {
        if let Some(t0) = self.started.take() {
            self.total += t0.elapsed();
        }
    }

    pub fn total_secs(&self) -> f64 {
        let mut t = self.total;
        if let Some(t0) = self.started {
            t += t0.elapsed();
        }
        t.as_secs_f64()
    }
}

/// Time a closure, returning (result, seconds).
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stopwatch_accumulates() {
        let mut sw = Stopwatch::new();
        sw.start();
        std::thread::sleep(Duration::from_millis(5));
        sw.stop();
        let t1 = sw.total_secs();
        assert!(t1 >= 0.004, "t1={t1}");
        sw.start();
        std::thread::sleep(Duration::from_millis(5));
        sw.stop();
        assert!(sw.total_secs() > t1);
    }

    #[test]
    fn double_start_saturates_instead_of_discarding() {
        let mut sw = Stopwatch::new();
        sw.start();
        std::thread::sleep(Duration::from_millis(5));
        // A second start while running must keep the original interval.
        sw.start();
        std::thread::sleep(Duration::from_millis(1));
        sw.stop();
        assert!(
            sw.total_secs() >= 0.005,
            "double-start discarded the running interval: {}",
            sw.total_secs()
        );
    }

    #[test]
    fn timed_returns_value() {
        let (v, secs) = timed(|| 42);
        assert_eq!(v, 42);
        assert!(secs >= 0.0);
    }
}
