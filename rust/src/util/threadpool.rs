//! Minimal scoped thread pool (offline substitute for rayon/tokio).
//!
//! Used by the coordinator to run independent replica trainings (different
//! seeds / methods) in parallel and by the data pipeline to overlap batch
//! synthesis with device execution.

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Fixed-size worker pool executing boxed jobs.
pub struct ThreadPool {
    tx: Option<mpsc::Sender<Job>>,
    workers: Vec<thread::JoinHandle<()>>,
}

impl ThreadPool {
    pub fn new(n: usize) -> Self {
        let n = n.max(1);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..n)
            .map(|i| {
                let rx = Arc::clone(&rx);
                thread::Builder::new()
                    .name(format!("regnde-worker-{i}"))
                    .spawn(move || loop {
                        let job = { rx.lock().unwrap().recv() };
                        match job {
                            Ok(job) => job(),
                            Err(_) => break, // channel closed: shut down
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        Self {
            tx: Some(tx),
            workers,
        }
    }

    pub fn execute(&self, job: impl FnOnce() + Send + 'static) {
        self.tx
            .as_ref()
            .expect("pool shut down")
            .send(Box::new(job))
            .expect("workers alive");
    }

    /// Run a closure over each item in parallel and collect results in
    /// input order.  Panics in jobs are propagated.
    pub fn map<T, R>(&self, items: Vec<T>, f: impl Fn(T) -> R + Send + Sync) -> Vec<R>
    where
        T: Send,
        R: Send,
    {
        thread::scope(|scope| {
            let f = &f;
            let handles: Vec<_> = items
                .into_iter()
                .map(|item| scope.spawn(move || f(item)))
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        })
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Number of worker threads to use by default (leaves a core for PJRT).
pub fn default_workers() -> usize {
    thread::available_parallelism()
        .map(|n| n.get().saturating_sub(1).max(1))
        .unwrap_or(2)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // join
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn map_preserves_order() {
        let pool = ThreadPool::new(3);
        let out = pool.map((0..20).collect(), |i: usize| i * i);
        assert_eq!(out, (0..20).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn zero_workers_clamps_to_one() {
        let pool = ThreadPool::new(0);
        let out = pool.map(vec![1, 2, 3], |x| x + 1);
        assert_eq!(out, vec![2, 3, 4]);
    }
}
