//! Minimal scoped thread pool (offline substitute for rayon/tokio).
//!
//! Used by the coordinator to run independent replica trainings (different
//! seeds / methods) in parallel, by the data pipeline to overlap batch
//! synthesis with device execution, and by the solver ensemble layer
//! (`solvers::ensemble`) to integrate many trajectories concurrently.

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Fixed-size worker pool executing boxed jobs.
pub struct ThreadPool {
    tx: Option<mpsc::Sender<Job>>,
    workers: Vec<thread::JoinHandle<()>>,
}

impl ThreadPool {
    pub fn new(n: usize) -> Self {
        let n = n.max(1);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..n)
            .map(|i| {
                let rx = Arc::clone(&rx);
                thread::Builder::new()
                    .name(format!("regnde-worker-{i}"))
                    .spawn(move || loop {
                        // analyze: allow(held) -- the receiver mutex IS the work handoff: exactly one idle worker blocks in recv() and the guard drops before the job runs
                        let job = { rx.lock().unwrap().recv() };
                        match job {
                            Ok(job) => job(),
                            Err(_) => break, // channel closed: shut down
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        Self {
            tx: Some(tx),
            workers,
        }
    }

    /// Configured parallelism (the bound honored by [`ThreadPool::map`]).
    pub fn size(&self) -> usize {
        self.workers.len()
    }

    pub fn execute(&self, job: impl FnOnce() + Send + 'static) {
        self.tx
            .as_ref()
            .expect("pool shut down")
            .send(Box::new(job))
            .expect("workers alive");
    }

    /// Run a closure over each item with bounded parallelism and collect
    /// results in input order.  Panics in jobs are propagated to the
    /// caller.
    ///
    /// At most [`ThreadPool::size`] items are in flight at any instant —
    /// mapping 10k items on a 4-worker pool uses 4 concurrent jobs, not
    /// 10k threads.  Because `items` and `f` may borrow from the caller's
    /// stack, the work cannot be shipped to the resident workers (their
    /// job queue requires `'static`); instead `map` runs scoped helper
    /// threads that drain a shared queue (see [`map_bounded`]), which
    /// gives the same bounded parallelism with a plain borrowed closure.
    pub fn map<T, R>(&self, items: Vec<T>, f: impl Fn(T) -> R + Send + Sync) -> Vec<R>
    where
        T: Send,
        R: Send,
    {
        map_bounded(self.size(), items, f)
    }
}

/// Free-function form of [`ThreadPool::map`] for callers that don't hold a
/// long-lived pool: run `f` over each item with at most `parallelism`
/// concurrent jobs, preserving input order and propagating panics.
pub fn map_bounded<T, R>(
    parallelism: usize,
    items: Vec<T>,
    f: impl Fn(T) -> R + Send + Sync,
) -> Vec<R>
where
    T: Send,
    R: Send,
{
    let n_items = items.len();
    let helpers = parallelism.min(n_items);
    if helpers <= 1 {
        return items.into_iter().map(f).collect();
    }
    // Shared pull-queue: each helper claims the next unprocessed item,
    // so a slow item never stalls the rest of its "chunk".
    let queue = Mutex::new(items.into_iter().enumerate());
    let (tx, rx) = mpsc::channel::<(usize, R)>();
    thread::scope(|scope| {
        let (f, queue) = (&f, &queue);
        let handles: Vec<_> = (0..helpers)
            .map(|_| {
                let tx = tx.clone();
                scope.spawn(move || loop {
                    // Lock released before running f (guard is a temp).
                    let next = queue.lock().unwrap().next();
                    match next {
                        Some((i, item)) => {
                            if tx.send((i, f(item))).is_err() {
                                break;
                            }
                        }
                        None => break,
                    }
                })
            })
            .collect();
        drop(tx);
        for h in handles {
            if let Err(panic) = h.join() {
                std::panic::resume_unwind(panic);
            }
        }
    });
    let mut results: Vec<Option<R>> = (0..n_items).map(|_| None).collect();
    for (i, r) in rx.try_iter() {
        results[i] = Some(r);
    }
    results
        .into_iter()
        .map(|r| r.expect("all jobs completed"))
        .collect()
}

/// Deterministically split `0..n` into `chunk`-sized index ranges (the
/// last may be short).  Shared by every chunked-map call site so stitch
/// order never depends on the parallelism level.
pub fn chunk_ranges(n: usize, chunk: usize) -> Vec<std::ops::Range<usize>> {
    let c = chunk.max(1);
    (0..n.div_ceil(c))
        .map(|k| k * c..((k + 1) * c).min(n))
        .collect()
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Number of worker threads to use by default (leaves a core for PJRT).
pub fn default_workers() -> usize {
    thread::available_parallelism()
        .map(|n| n.get().saturating_sub(1).max(1))
        .unwrap_or(2)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // join
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn map_preserves_order() {
        let pool = ThreadPool::new(3);
        let out = pool.map((0..20).collect(), |i: usize| i * i);
        assert_eq!(out, (0..20).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn zero_workers_clamps_to_one() {
        let pool = ThreadPool::new(0);
        let out = pool.map(vec![1, 2, 3], |x| x + 1);
        assert_eq!(out, vec![2, 3, 4]);
    }

    #[test]
    fn map_concurrency_never_exceeds_pool_size() {
        let pool = ThreadPool::new(4);
        let in_flight = AtomicUsize::new(0);
        let peak = AtomicUsize::new(0);
        let out = pool.map((0..1000).collect(), |i: usize| {
            let now = in_flight.fetch_add(1, Ordering::SeqCst) + 1;
            peak.fetch_max(now, Ordering::SeqCst);
            std::thread::yield_now();
            in_flight.fetch_sub(1, Ordering::SeqCst);
            i
        });
        assert_eq!(out.len(), 1000);
        let peak = peak.load(Ordering::SeqCst);
        assert!(peak <= 4, "peak concurrency {peak} exceeds pool size 4");
        assert!(peak >= 2, "expected some parallelism, saw {peak}");
    }

    #[test]
    fn map_preserves_order_under_jitter() {
        // Items finish out of order; results must still be in input order.
        let pool = ThreadPool::new(4);
        let out = pool.map((0..64).collect(), |i: usize| {
            if i % 7 == 0 {
                std::thread::sleep(std::time::Duration::from_millis(1));
            }
            i * 3
        });
        assert_eq!(out, (0..64).map(|i| i * 3).collect::<Vec<_>>());
    }

    #[test]
    #[should_panic(expected = "boom")]
    fn map_propagates_panics() {
        let pool = ThreadPool::new(2);
        let _ = pool.map((0..16).collect(), |i: usize| {
            if i == 9 {
                panic!("boom");
            }
            i
        });
    }

    #[test]
    fn map_with_more_workers_than_items() {
        let pool = ThreadPool::new(8);
        let out = pool.map(vec![10, 20], |x| x / 10);
        assert_eq!(out, vec![1, 2]);
    }

    #[test]
    fn map_bounded_without_a_pool() {
        let out = map_bounded(3, (0..50).collect(), |i: usize| i + 1);
        assert_eq!(out, (1..51).collect::<Vec<_>>());
        // parallelism 0/1 degrade to the serial path
        assert_eq!(map_bounded(0, vec![5], |x: i32| x * 2), vec![10]);
    }

    #[test]
    fn chunk_ranges_cover_and_order() {
        let chunks = chunk_ranges(23, 7);
        assert_eq!(chunks.len(), 4);
        assert_eq!(chunks[0], 0..7);
        assert_eq!(chunks[3], 21..23);
        assert_eq!(chunks.iter().map(|c| c.len()).sum::<usize>(), 23);
        assert!(chunk_ranges(0, 7).is_empty());
        assert_eq!(chunk_ranges(3, 0), vec![0..1, 1..2, 2..3]); // clamps to 1
    }
}
