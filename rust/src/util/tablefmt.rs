//! Paper-style ASCII table rendering for the bench harness.
//!
//! Renders `mean ± std` cells with aligned columns, matching the layout of
//! the paper's Tables 1-4 so bench output can be compared side by side.

/// A simple column-aligned table.
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len(), "column count mismatch");
        self.rows.push(cells);
    }

    /// Format a `mean ± std` cell.
    pub fn pm(mean: f64, std: f64, decimals: usize) -> String {
        format!("{mean:.decimals$} ± {std:.decimals$}")
    }

    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths = vec![0usize; ncol];
        for (i, h) in self.header.iter().enumerate() {
            widths[i] = widths[i].max(h.chars().count());
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.chars().count());
            }
        }
        let sep: String = widths
            .iter()
            .map(|w| format!("+{}", "-".repeat(w + 2)))
            .collect::<String>()
            + "+";
        let fmt_row = |cells: &[String]| -> String {
            let mut s = String::new();
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!("| {:<width$} ", c, width = widths[i]));
            }
            s.push('|');
            s
        };
        let mut out = String::new();
        out.push_str(&format!("{}\n", self.title));
        out.push_str(&sep);
        out.push('\n');
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out.push_str(&sep);
        out.push('\n');
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("Demo", &["Method", "NFE"]);
        t.row(vec!["Vanilla".into(), Table::pm(253.0, 3.46, 1)]);
        t.row(vec!["ERNODE".into(), Table::pm(177.0, 0.0, 1)]);
        let s = t.render();
        assert!(s.contains("Vanilla"));
        assert!(s.contains("253.0 ± 3.5") || s.contains("253.0 ± 3.46"));
        // all data lines share the same width
        let lines: Vec<&str> = s.lines().filter(|l| l.starts_with('|')).collect();
        // char count, not byte count: "±" is multibyte.
        assert!(lines
            .windows(2)
            .all(|w| w[0].chars().count() == w[1].chars().count()));
    }

    #[test]
    #[should_panic(expected = "column count")]
    fn wrong_arity_panics() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }
}
