//! Tiny CLI argument parser (offline substitute for clap), plus the
//! shared environment scale-knob reader the bench binaries use.
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional arguments,
//! with typed accessors and a generated usage string.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Context, Result};

/// Read a `usize` scale knob from the environment (`REGNDE_BENCH_*`
/// style), falling back to `default` when unset or unparseable.  Shared
/// by `bench::BenchConfig` and the standalone bench binaries so the knob
/// semantics cannot drift between them.
pub fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

/// Parsed command line: positionals + key/value options + boolean flags.
#[derive(Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
    /// Option keys that take a value (everything else with `--` is a flag).
    valued: Vec<&'static str>,
}

impl Args {
    /// Parse `argv[1..]`.  `valued` lists the option names (without `--`)
    /// that consume a following value.
    pub fn parse(argv: impl Iterator<Item = String>, valued: &[&'static str]) -> Result<Args> {
        let mut out = Args {
            valued: valued.to_vec(),
            ..Default::default()
        };
        let mut it = argv.peekable();
        while let Some(arg) = it.next() {
            if let Some(body) = arg.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if out.valued.contains(&body) {
                    let v = it
                        .next()
                        .ok_or_else(|| anyhow!("option --{body} needs a value"))?;
                    out.options.insert(body.to_string(), v);
                } else {
                    out.flags.push(body.to_string());
                }
            } else {
                out.positional.push(arg);
            }
        }
        Ok(out)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(s) => s
                .parse::<f64>()
                .with_context(|| format!("--{name} expects a number, got {s:?}")),
        }
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(s) => s
                .parse::<usize>()
                .with_context(|| format!("--{name} expects an integer, got {s:?}")),
        }
    }

    pub fn get_u64(&self, name: &str, default: u64) -> Result<u64> {
        match self.get(name) {
            None => Ok(default),
            Some(s) => s
                .parse::<u64>()
                .with_context(|| format!("--{name} expects an integer, got {s:?}")),
        }
    }

    /// Error if unknown option names were passed (catches typos).
    pub fn check_known(&self, known: &[&str]) -> Result<()> {
        for k in self.options.keys().chain(self.flags.iter()) {
            if !known.contains(&k.as_str()) {
                bail!("unknown option --{k}; known: {known:?}");
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> impl Iterator<Item = String> + '_ {
        s.split_whitespace().map(|x| x.to_string())
    }

    #[test]
    fn parses_mixed() {
        let a = Args::parse(argv("train --model mnist --steps=10 --verbose extra"), &["model"])
            .unwrap();
        assert_eq!(a.positional, vec!["train", "extra"]);
        assert_eq!(a.get("model"), Some("mnist"));
        assert_eq!(a.get("steps"), Some("10"));
        assert!(a.flag("verbose"));
    }

    #[test]
    fn typed_accessors() {
        let a = Args::parse(argv("--lr 0.5 --n 3"), &["lr", "n"]).unwrap();
        assert_eq!(a.get_f64("lr", 0.0).unwrap(), 0.5);
        assert_eq!(a.get_usize("n", 0).unwrap(), 3);
        assert_eq!(a.get_usize("missing", 7).unwrap(), 7);
        assert!(a.get_f64("n", 0.0).is_ok());
    }

    #[test]
    fn missing_value_errors() {
        assert!(Args::parse(argv("--model"), &["model"]).is_err());
    }

    #[test]
    fn bad_number_errors() {
        let a = Args::parse(argv("--lr abc"), &["lr"]).unwrap();
        assert!(a.get_f64("lr", 0.0).is_err());
    }

    #[test]
    fn check_known_catches_typo() {
        let a = Args::parse(argv("--sedes 1"), &["seeds"]).unwrap();
        assert!(a.check_known(&["seeds"]).is_err());
    }
}
