//! Epoch-based mini-batch iteration with deterministic shuffling.
//!
//! Yields index slices; dataset-specific gather functions assemble the
//! actual f32 buffers (see the coordinator's experiment drivers).  Partial
//! trailing batches are dropped (lowered artifacts have a static batch
//! dimension).

use crate::util::rng::Rng;

/// Deterministic shuffling batch iterator over `n` samples.
pub struct Batcher {
    order: Vec<usize>,
    batch: usize,
    cursor: usize,
    rng: Rng,
}

impl Batcher {
    pub fn new(n: usize, batch: usize, seed: u64) -> Self {
        assert!(batch > 0 && batch <= n, "batch {batch} vs n {n}");
        let mut b = Self {
            order: (0..n).collect(),
            batch,
            cursor: 0,
            rng: Rng::new(seed ^ 0xBA7C4),
        };
        b.reshuffle();
        b
    }

    fn reshuffle(&mut self) {
        self.rng.shuffle(&mut self.order);
        self.cursor = 0;
    }

    /// Number of full batches per epoch.
    pub fn batches_per_epoch(&self) -> usize {
        self.order.len() / self.batch
    }

    /// Next batch of indices; reshuffles at epoch end.  Returns the epoch
    /// number the batch belongs to alongside the indices.
    pub fn next_batch(&mut self) -> &[usize] {
        if self.cursor + self.batch > self.order.len() {
            self.reshuffle();
        }
        let s = &self.order[self.cursor..self.cursor + self.batch];
        self.cursor += self.batch;
        s
    }

    /// Gather rows of a row-major [n, dim] buffer into a batch buffer.
    pub fn gather(src: &[f32], dim: usize, idx: &[usize], dst: &mut Vec<f32>) {
        dst.clear();
        dst.reserve(idx.len() * dim);
        for &i in idx {
            dst.extend_from_slice(&src[i * dim..(i + 1) * dim]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batches_cover_epoch_without_repeat() {
        let mut b = Batcher::new(100, 10, 1);
        let mut seen = vec![false; 100];
        for _ in 0..b.batches_per_epoch() {
            for &i in b.next_batch().to_vec().iter() {
                assert!(!seen[i], "index {i} repeated within epoch");
                seen[i] = true;
            }
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn reshuffles_between_epochs() {
        let mut b = Batcher::new(64, 8, 2);
        let first: Vec<usize> = (0..8).flat_map(|_| b.next_batch().to_vec()).collect();
        let second: Vec<usize> = (0..8).flat_map(|_| b.next_batch().to_vec()).collect();
        assert_ne!(first, second);
    }

    #[test]
    fn partial_batches_dropped() {
        let mut b = Batcher::new(25, 10, 3);
        assert_eq!(b.batches_per_epoch(), 2);
        // Three calls must still produce full batches (epoch wraps).
        for _ in 0..3 {
            assert_eq!(b.next_batch().len(), 10);
        }
    }

    #[test]
    fn gather_assembles_rows() {
        let src: Vec<f32> = (0..12).map(|x| x as f32).collect(); // 4 rows of 3
        let mut dst = Vec::new();
        Batcher::gather(&src, 3, &[2, 0], &mut dst);
        assert_eq!(dst, vec![6.0, 7.0, 8.0, 0.0, 1.0, 2.0]);
    }

    #[test]
    #[should_panic(expected = "batch")]
    fn batch_larger_than_n_panics() {
        let _ = Batcher::new(5, 10, 0);
    }
}
