//! Ground-truth spiral data from the native Rust solvers.
//!
//! * `spiral_ode_trajectory` — the Figure-2 fixture: one trajectory of
//!   du/dt = A u^3 at the save grid, solved at tight tolerance.
//! * `spiral_sde_moments` — the Table-3 fixture: per-save-point mean and
//!   variance over an ensemble of spiral DSDE trajectories (paper Eq. 15;
//!   the paper uses 10k trajectories, configurable here).  The ensemble is
//!   integrated through `solvers::ensemble` — chunked across the thread
//!   pool with per-trajectory RNG streams, so the fixture is bit-identical
//!   at any worker count (and on a single-core runner).

use crate::solvers::ensemble::{sde_ensemble_moments, EnsembleOptions};
use crate::solvers::problems;
use crate::solvers::{solve, OdeSystem, Saveat, SolveOptions, Taping};

/// One spiral ODE trajectory at the given save times (row-major [T, 2]).
pub fn spiral_ode_trajectory(u0: [f64; 2], ts: &[f64]) -> Vec<f32> {
    let mut sys = OdeSystem(problems::spiral_ode);
    let (zs, out) = solve(
        &mut sys,
        &u0,
        Saveat::Grid(ts),
        &SolveOptions::new().with_tolerance(1e-9),
        None,
        Taping::Off,
        &mut [],
    );
    out.expect("ground-truth spiral solve failed");
    zs.iter().flat_map(|z| z.iter().map(|&v| v as f32)).collect()
}

/// Moments of the spiral DSDE ensemble: (mu, var), each row-major [T, 2].
pub fn spiral_sde_moments(
    u0: [f64; 2],
    ts: &[f64],
    n_traj: usize,
    seed: u64,
) -> (Vec<f32>, Vec<f32>) {
    let opts = SolveOptions::new().with_tolerance(1e-3);
    let m = sde_ensemble_moments(
        &problems::spiral_sde_drift,
        &problems::spiral_sde_diffusion,
        &u0,
        ts,
        n_traj,
        seed ^ 0x5350_4952_414C, // "SPIRAL"
        &opts,
        &EnsembleOptions::default(),
    );
    assert!(m.success(), "ground-truth spiral SDE ensemble failed");
    (
        m.mu.iter().map(|&v| v as f32).collect(),
        m.var.iter().map(|&v| v as f32).collect(),
    )
}

/// The paper's save grid: `t_points` uniform times over [0, span].
pub fn uniform_grid(t_points: usize, span: f64) -> Vec<f64> {
    (0..t_points)
        .map(|i| span * i as f64 / (t_points - 1) as f64)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trajectory_starts_at_u0() {
        let ts = uniform_grid(30, 1.5);
        let traj = spiral_ode_trajectory([2.0, 0.0], &ts);
        assert_eq!(traj.len(), 60);
        assert!((traj[0] - 2.0).abs() < 1e-6);
        assert!(traj[1].abs() < 1e-6);
    }

    #[test]
    fn trajectory_spirals() {
        let ts = uniform_grid(30, 1.5);
        let traj = spiral_ode_trajectory([2.0, 0.0], &ts);
        // u2 must move away from 0 (rotation) and radius must shrink.
        let r_first = (traj[0].powi(2) + traj[1].powi(2)).sqrt();
        let last = &traj[58..];
        let r_last = (last[0].powi(2) + last[1].powi(2)).sqrt();
        assert!(r_last < r_first);
        assert!(traj[3].abs() > 1e-3, "no rotation seen");
    }

    #[test]
    fn moments_deterministic_and_sane() {
        let ts = uniform_grid(10, 1.0);
        let (mu1, var1) = spiral_sde_moments([1.0, 1.0], &ts, 200, 1);
        let (mu2, var2) = spiral_sde_moments([1.0, 1.0], &ts, 200, 1);
        assert_eq!(mu1, mu2);
        assert_eq!(var1, var2);
        // At t=0 mean is exactly u0 with zero variance.
        assert!((mu1[0] - 1.0).abs() < 1e-6);
        assert!(var1[0] < 1e-8);
        // Variance grows from zero.
        assert!(var1[18] > var1[0]);
        assert!(mu1.iter().all(|m| m.is_finite()));
    }

    #[test]
    fn moments_independent_of_worker_count() {
        // The fixture contract: pooled generation reproduces serial bits.
        let ts = uniform_grid(6, 1.0);
        let opts = SolveOptions::new().with_tolerance(1e-3);
        let mk = |workers: usize| {
            sde_ensemble_moments(
                &problems::spiral_sde_drift,
                &problems::spiral_sde_diffusion,
                &[1.0, 1.0],
                &ts,
                100,
                1 ^ 0x5350_4952_414C,
                &opts,
                &EnsembleOptions {
                    workers,
                    ..Default::default()
                },
            )
        };
        let a = mk(1);
        let b = mk(3);
        assert_eq!(a.mu, b.mu);
        assert_eq!(a.var, b.var);
    }
}
