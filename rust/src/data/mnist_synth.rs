//! Procedural MNIST stand-in: 10 visually distinct 28x28 class prototypes
//! with per-sample elastic deformation, stroke jitter and pixel noise.
//!
//! Design goals (matching what the paper's experiment actually needs):
//!  * 784-dim inputs in [0, 1] with MNIST-like sparsity,
//!  * 10 classes, easy enough that the Eq. 12-14 architecture reaches high
//!    accuracy, hard enough that accuracy is not trivially 100% at init,
//!  * fully deterministic from a seed.
//!
//! Prototypes are simple stroke drawings of the digits on a 28x28 canvas;
//! each sample shifts, scales and perturbs its class prototype.

use crate::util::rng::Rng;

pub const SIDE: usize = 28;
pub const DIM: usize = SIDE * SIDE;
pub const CLASSES: usize = 10;

/// An in-memory labelled image dataset (flattened f32 pixels).
#[derive(Clone)]
pub struct Dataset {
    pub images: Vec<f32>, // n * DIM
    pub labels: Vec<u8>,  // n
    pub n: usize,
}

impl Dataset {
    pub fn image(&self, i: usize) -> &[f32] {
        &self.images[i * DIM..(i + 1) * DIM]
    }
}

/// Stroke segments (x0, y0, x1, y1) in [0,1]^2 per digit class.
fn strokes(class: u8) -> &'static [(f32, f32, f32, f32)] {
    match class {
        0 => &[
            (0.3, 0.2, 0.7, 0.2),
            (0.7, 0.2, 0.7, 0.8),
            (0.7, 0.8, 0.3, 0.8),
            (0.3, 0.8, 0.3, 0.2),
        ],
        1 => &[(0.5, 0.15, 0.5, 0.85), (0.35, 0.3, 0.5, 0.15)],
        2 => &[
            (0.3, 0.25, 0.7, 0.25),
            (0.7, 0.25, 0.7, 0.5),
            (0.7, 0.5, 0.3, 0.8),
            (0.3, 0.8, 0.7, 0.8),
        ],
        3 => &[
            (0.3, 0.2, 0.7, 0.2),
            (0.7, 0.2, 0.7, 0.5),
            (0.45, 0.5, 0.7, 0.5),
            (0.7, 0.5, 0.7, 0.8),
            (0.7, 0.8, 0.3, 0.8),
        ],
        4 => &[
            (0.35, 0.2, 0.35, 0.55),
            (0.35, 0.55, 0.7, 0.55),
            (0.65, 0.2, 0.65, 0.85),
        ],
        5 => &[
            (0.7, 0.2, 0.3, 0.2),
            (0.3, 0.2, 0.3, 0.5),
            (0.3, 0.5, 0.7, 0.5),
            (0.7, 0.5, 0.7, 0.8),
            (0.7, 0.8, 0.3, 0.8),
        ],
        6 => &[
            (0.65, 0.2, 0.35, 0.4),
            (0.35, 0.4, 0.35, 0.8),
            (0.35, 0.8, 0.7, 0.8),
            (0.7, 0.8, 0.7, 0.55),
            (0.7, 0.55, 0.35, 0.55),
        ],
        7 => &[(0.3, 0.2, 0.7, 0.2), (0.7, 0.2, 0.45, 0.85)],
        8 => &[
            (0.35, 0.2, 0.65, 0.2),
            (0.65, 0.2, 0.65, 0.5),
            (0.65, 0.5, 0.35, 0.5),
            (0.35, 0.5, 0.35, 0.2),
            (0.35, 0.5, 0.35, 0.8),
            (0.35, 0.8, 0.65, 0.8),
            (0.65, 0.8, 0.65, 0.5),
        ],
        9 => &[
            (0.65, 0.45, 0.35, 0.45),
            (0.35, 0.45, 0.35, 0.2),
            (0.35, 0.2, 0.65, 0.2),
            (0.65, 0.2, 0.65, 0.8),
        ],
        _ => unreachable!(),
    }
}

/// Draw a blurred stroke segment onto the canvas.
fn draw_stroke(img: &mut [f32], seg: (f32, f32, f32, f32), width: f32, intensity: f32) {
    let (x0, y0, x1, y1) = seg;
    let steps = 40;
    for k in 0..=steps {
        let t = k as f32 / steps as f32;
        let cx = (x0 + t * (x1 - x0)) * SIDE as f32;
        let cy = (y0 + t * (y1 - y0)) * SIDE as f32;
        let r = (width * SIDE as f32).ceil() as i32;
        let (cxi, cyi) = (cx as i32, cy as i32);
        for dy in -r..=r {
            for dx in -r..=r {
                let px = cxi + dx;
                let py = cyi + dy;
                if px < 0 || py < 0 || px >= SIDE as i32 || py >= SIDE as i32 {
                    continue;
                }
                let d2 = ((px as f32 - cx).powi(2) + (py as f32 - cy).powi(2))
                    / (width * SIDE as f32).powi(2);
                let v = intensity * (-2.0 * d2).exp();
                let idx = py as usize * SIDE + px as usize;
                img[idx] = (img[idx] + v).min(1.0);
            }
        }
    }
}

/// Generate `n` samples (round-robin over classes) from `seed`.
pub fn generate(n: usize, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed ^ 0x4D4E_4953_5421); // "MNIST!"
    let mut images = vec![0.0f32; n * DIM];
    let mut labels = vec![0u8; n];
    for i in 0..n {
        let class = (i % CLASSES) as u8;
        labels[i] = class;
        let img = &mut images[i * DIM..(i + 1) * DIM];
        // Per-sample geometric jitter.
        let ox = rng.range(-0.06, 0.06) as f32;
        let oy = rng.range(-0.06, 0.06) as f32;
        let scale = rng.range(0.85, 1.15) as f32;
        let width = rng.range(0.035, 0.06) as f32;
        for &(x0, y0, x1, y1) in strokes(class) {
            let tx = |x: f32| 0.5 + (x - 0.5) * scale + ox;
            let ty = |y: f32| 0.5 + (y - 0.5) * scale + oy;
            // stroke endpoint jitter (elastic-ish deformation)
            let j = 0.02;
            let seg = (
                tx(x0) + rng.range(-j, j) as f32,
                ty(y0) + rng.range(-j, j) as f32,
                tx(x1) + rng.range(-j, j) as f32,
                ty(y1) + rng.range(-j, j) as f32,
            );
            draw_stroke(img, seg, width, 0.9);
        }
        // Pixel noise.
        for p in img.iter_mut() {
            let noise = rng.normal_f32() * 0.02;
            *p = (*p + noise).clamp(0.0, 1.0);
        }
    }
    // Shuffle sample order (labels stay attached).
    let mut order: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut order);
    let mut s_images = vec![0.0f32; n * DIM];
    let mut s_labels = vec![0u8; n];
    for (dst, &src) in order.iter().enumerate() {
        s_images[dst * DIM..(dst + 1) * DIM]
            .copy_from_slice(&images[src * DIM..(src + 1) * DIM]);
        s_labels[dst] = labels[src];
    }
    Dataset {
        images: s_images,
        labels: s_labels,
        n,
    }
}

/// One-hot encode labels into a f32 buffer of shape [n, CLASSES].
pub fn one_hot(labels: &[u8]) -> Vec<f32> {
    let mut out = vec![0.0f32; labels.len() * CLASSES];
    for (i, &l) in labels.iter().enumerate() {
        out[i * CLASSES + l as usize] = 1.0;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_generation() {
        let a = generate(50, 7);
        let b = generate(50, 7);
        assert_eq!(a.images, b.images);
        assert_eq!(a.labels, b.labels);
    }

    #[test]
    fn seeds_change_data() {
        let a = generate(50, 1);
        let b = generate(50, 2);
        assert_ne!(a.images, b.images);
    }

    #[test]
    fn pixel_range_and_sparsity() {
        let d = generate(100, 3);
        assert!(d.images.iter().all(|&p| (0.0..=1.0).contains(&p)));
        // MNIST-like: most pixels near zero, some ink.
        let ink = d.images.iter().filter(|&&p| p > 0.5).count() as f64
            / d.images.len() as f64;
        assert!(ink > 0.02 && ink < 0.4, "ink fraction {ink}");
    }

    #[test]
    fn classes_balanced() {
        let d = generate(200, 5);
        let mut counts = [0usize; CLASSES];
        for &l in &d.labels {
            counts[l as usize] += 1;
        }
        assert!(counts.iter().all(|&c| c == 20), "{counts:?}");
    }

    #[test]
    fn classes_are_distinguishable() {
        // Nearest-prototype classification on clean means should beat 90%:
        // the dataset must be learnable by construction.
        let train = generate(400, 11);
        let test = generate(100, 12);
        let mut means = vec![vec![0.0f32; DIM]; CLASSES];
        let mut counts = [0usize; CLASSES];
        for i in 0..train.n {
            let c = train.labels[i] as usize;
            counts[c] += 1;
            for (m, &p) in means[c].iter_mut().zip(train.image(i)) {
                *m += p;
            }
        }
        for c in 0..CLASSES {
            for m in means[c].iter_mut() {
                *m /= counts[c] as f32;
            }
        }
        let mut correct = 0;
        for i in 0..test.n {
            let img = test.image(i);
            let best = (0..CLASSES)
                .min_by(|&a, &b| {
                    let da: f32 = means[a]
                        .iter()
                        .zip(img)
                        .map(|(m, p)| (m - p) * (m - p))
                        .sum();
                    let db: f32 = means[b]
                        .iter()
                        .zip(img)
                        .map(|(m, p)| (m - p) * (m - p))
                        .sum();
                    da.partial_cmp(&db).unwrap()
                })
                .unwrap();
            if best == test.labels[i] as usize {
                correct += 1;
            }
        }
        assert!(correct >= 85, "nearest-prototype acc {correct}/100");
    }

    #[test]
    fn one_hot_shape() {
        let oh = one_hot(&[0, 3, 9]);
        assert_eq!(oh.len(), 30);
        assert_eq!(oh[0], 1.0);
        assert_eq!(oh[13], 1.0);
        assert_eq!(oh[29], 1.0);
        assert_eq!(oh.iter().sum::<f32>(), 3.0);
    }
}
