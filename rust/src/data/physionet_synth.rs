//! Physionet-2012 stand-in: irregularly-sampled multichannel vitals-like
//! time series with per-channel observation masks.
//!
//! Each patient is simulated from a small latent dynamical system (two
//! coupled oscillating "physiological" modes + patient-specific drift and
//! noise), observed through 8 channels with random per-channel sampling
//! (~50% missingness, like the union-grid preprocessing of Rubanova et al.)
//! The Latent-ODE pipeline — mask-aware GRU encoding, KL-annealed NLL on a
//! shared grid, interpolation at unobserved points — is exercised exactly
//! as with the real dataset (DESIGN.md §4 substitution).
//!
//! Patients are independent given their seed, so synthesis is chunked
//! across the thread pool: each patient draws from its own RNG stream
//! derived from `(seed, patient index)` up front, making the dataset
//! bit-identical at any worker count.

use crate::util::rng::Rng;
use crate::util::threadpool::{chunk_ranges, default_workers, map_bounded};

pub const CHANNELS: usize = 8;

/// Patients per work item (fixed so chunk stitch order never varies).
const PATIENT_CHUNK: usize = 16;

/// A batch-ready time-series dataset on a shared time grid.
#[derive(Clone)]
pub struct Dataset {
    /// values, shape [n, t_points, CHANNELS] (0 where unobserved)
    pub values: Vec<f32>,
    /// observation masks, same shape, in {0, 1}
    pub masks: Vec<f32>,
    /// shared (union) time grid in [0, 1], length t_points
    pub ts: Vec<f32>,
    pub n: usize,
    pub t_points: usize,
}

impl Dataset {
    pub fn sample(&self, i: usize) -> (&[f32], &[f32]) {
        let sz = self.t_points * CHANNELS;
        (&self.values[i * sz..(i + 1) * sz], &self.masks[i * sz..(i + 1) * sz])
    }
}

/// Synthesize one patient's [t_points, CHANNELS] block from its stream.
fn synth_patient(rng: &mut Rng, ts: &[f32], values: &mut [f32], masks: &mut [f32]) {
    let t_points = ts.len();
    // Patient-specific latent parameters.
    let freq1 = rng.range(2.0, 6.0);
    let freq2 = rng.range(6.0, 14.0);
    let phase1 = rng.range(0.0, std::f64::consts::TAU);
    let phase2 = rng.range(0.0, std::f64::consts::TAU);
    let drift = rng.range(-0.5, 0.5);
    let amp1 = rng.range(0.4, 1.0);
    let amp2 = rng.range(0.1, 0.4);
    // Channel mixing of the two latent modes + offset.
    let mix: Vec<(f64, f64, f64)> = (0..CHANNELS)
        .map(|_| {
            (
                rng.range(-1.0, 1.0),
                rng.range(-1.0, 1.0),
                rng.range(-0.3, 0.3),
            )
        })
        .collect();
    for (k, &t) in ts.iter().enumerate() {
        let td = t as f64;
        let m1 = amp1 * (freq1 * td + phase1).sin();
        let m2 = amp2 * (freq2 * td + phase2).sin();
        let trend = drift * td;
        for c in 0..CHANNELS {
            let (w1, w2, off) = mix[c];
            let clean = w1 * m1 + w2 * m2 + off + trend;
            let noisy = clean + rng.normal() * 0.03;
            let observed = rng.uniform() < 0.5; // ~50% missingness
            let idx = k * CHANNELS + c;
            if observed {
                values[idx] = noisy as f32;
                masks[idx] = 1.0;
            }
        }
    }
    // Guarantee at least one observation per time point (union grid
    // semantics: every grid time was observed by someone/some channel).
    for k in 0..t_points {
        let any = (0..CHANNELS).any(|c| masks[k * CHANNELS + c] > 0.0);
        if !any {
            let c = rng.below(CHANNELS);
            let idx = k * CHANNELS + c;
            masks[idx] = 1.0;
            values[idx] = 0.0;
        }
    }
}

/// Generate `n` synthetic patients on a `t_points` grid.
pub fn generate(n: usize, t_points: usize, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed ^ 0x5048_5953_494F); // "PHYSIO"
    // Slightly irregular shared grid (sorted uniform jitter around linspace).
    let mut ts: Vec<f32> = (0..t_points)
        .map(|i| {
            let base = i as f64 / (t_points - 1) as f64;
            let jitter = if i == 0 || i == t_points - 1 {
                0.0
            } else {
                rng.range(-0.3, 0.3) / t_points as f64
            };
            (base + jitter) as f32
        })
        .collect();
    ts.sort_by(|a, b| a.partial_cmp(b).unwrap());

    // Per-patient streams derived up front (schedule-independent).
    let seeds: Vec<u64> = (0..n).map(|_| rng.next_u64()).collect();
    let sz = t_points * CHANNELS;

    // Chunk patients across the bounded pool map; each job owns its
    // output block, stitched back in chunk order.
    let blocks: Vec<(Vec<f32>, Vec<f32>)> = map_bounded(
        default_workers(),
        chunk_ranges(n, PATIENT_CHUNK),
        |range: std::ops::Range<usize>| {
            let mut values = vec![0.0f32; range.len() * sz];
            let mut masks = vec![0.0f32; range.len() * sz];
            for (local, p) in range.enumerate() {
                let mut prng = Rng::new(seeds[p]);
                synth_patient(
                    &mut prng,
                    &ts,
                    &mut values[local * sz..(local + 1) * sz],
                    &mut masks[local * sz..(local + 1) * sz],
                );
            }
            (values, masks)
        },
    );

    let mut values = Vec::with_capacity(n * sz);
    let mut masks = Vec::with_capacity(n * sz);
    for (v, m) in blocks {
        values.extend_from_slice(&v);
        masks.extend_from_slice(&m);
    }
    Dataset {
        values,
        masks,
        ts,
        n,
        t_points,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let a = generate(10, 16, 42);
        let b = generate(10, 16, 42);
        assert_eq!(a.values, b.values);
        assert_eq!(a.masks, b.masks);
        assert_eq!(a.ts, b.ts);
    }

    #[test]
    fn deterministic_across_chunk_boundaries() {
        // A dataset spanning several chunks must agree patient-by-patient
        // with a smaller dataset generated from the same seed (per-patient
        // streams depend on (seed, index) only, not on n or scheduling).
        let small = generate(3, 16, 42);
        let large = generate(3 * PATIENT_CHUNK, 16, 42);
        for p in 0..3 {
            assert_eq!(small.sample(p).0, large.sample(p).0, "patient {p} values");
            assert_eq!(small.sample(p).1, large.sample(p).1, "patient {p} masks");
        }
    }

    #[test]
    fn grid_sorted_in_unit_interval() {
        let d = generate(5, 16, 1);
        assert_eq!(d.ts.len(), 16);
        assert_eq!(d.ts[0], 0.0);
        assert!((d.ts[15] - 1.0).abs() < 1e-6);
        assert!(d.ts.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn masks_are_binary_and_partial() {
        let d = generate(20, 16, 2);
        assert!(d.masks.iter().all(|&m| m == 0.0 || m == 1.0));
        let frac = d.masks.iter().sum::<f32>() as f64 / d.masks.len() as f64;
        assert!(frac > 0.3 && frac < 0.7, "observed fraction {frac}");
    }

    #[test]
    fn unobserved_values_are_zeroed() {
        let d = generate(20, 16, 3);
        for (v, m) in d.values.iter().zip(&d.masks) {
            if *m == 0.0 {
                assert_eq!(*v, 0.0);
            }
        }
    }

    #[test]
    fn every_time_point_observed_somewhere() {
        let d = generate(10, 16, 4);
        let sz = d.t_points * CHANNELS;
        for p in 0..d.n {
            for k in 0..d.t_points {
                let any = (0..CHANNELS)
                    .any(|c| d.masks[p * sz + k * CHANNELS + c] > 0.0);
                assert!(any, "patient {p} time {k} fully unobserved");
            }
        }
    }

    #[test]
    fn values_bounded() {
        let d = generate(50, 16, 5);
        assert!(d.values.iter().all(|v| v.abs() < 5.0));
    }
}
