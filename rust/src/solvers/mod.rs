//! Native Rust adaptive differential-equation solver suite.
//!
//! A faithful mirror of the Layer-2 JAX solvers (python/compile/solver.py /
//! sde_solver.py): the same Butcher tableaus (bit-for-bit constants), the
//! same tolerance-scaled error ratio (paper Eq. 5), PI controller (Eq. 6),
//! Shampine stiffness ratio (Eq. 8) and white-boxed statistics (R_E, R_S,
//! NFE).  Three roles:
//!
//!  1. **Data generation** — ground-truth spiral ODE/SDE trajectories and
//!     the latent generators behind the synthetic datasets (rust/src/data).
//!  2. **Cross-validation** — rust/tests/cross_validate.rs solves the same
//!     IVP through this suite and through the lowered `spiral_ode_solve`
//!     artifact and asserts trajectory agreement, pinning down the semantic
//!     equivalence of the two implementations.
//!  3. **Reference analytics** — stiffness estimation and NFE accounting
//!     used by unit/property tests of the coordinator's heuristics.
//!
//! Structure (DESIGN.md §Perf): [`controller`] holds the step-size
//! heuristics shared by the ODE and SDE steppers; [`ode`] / [`sde`] are
//! the allocation-free single-trajectory cores; [`ensemble`] scales them
//! to many trajectories across a thread pool with deterministic
//! per-trajectory RNG streams.

pub mod adjoint;
pub mod controller;
pub mod ensemble;
pub mod ode;
pub mod problems;
pub mod sde;
pub mod tableau;

pub use adjoint::{ode_backward, ode_replay, sde_backward, sde_replay, OdeTape, SdeTape};
pub use ensemble::{
    sde_ensemble_moments, sde_solve_ensemble, solve_ensemble, EnsembleOptions, SdeMoments,
    SdeTrajectory,
};
pub use ode::{solve, solve_saveat, solve_saveat_taped, OdeOptions, SolveOutcome, Stats};
pub use sde::{sde_solve_saveat, sde_solve_saveat_taped, SdeOptions};
pub use tableau::Tableau;
