//! Native Rust adaptive differential-equation solvers with a **white-box
//! `solve()` API**: the internal heuristics the paper regularizes (local
//! error `E_j`, stiffness `S_j`) are a first-class, pluggable observation
//! surface, not private accumulators.
//!
//! ## The unified API (DESIGN.md §Solver API)
//!
//! One call shape serves every integration in the suite:
//!
//! ```text
//! solve(&mut system, z0, saveat, &options, rng, taping, observers)
//! ```
//!
//! * [`System`] (in [`system`]) — the dynamics: drift, optional diagonal
//!   diffusion, optional VJP hooks.  [`OdeSystem`] / [`SdeSystem`] lift
//!   plain closures.  [`System::has_diffusion`] routes the call to the
//!   adaptive RK stack ([`ode::drive`]) or the stochastic Heun stack
//!   ([`sde::drive`]) — one generic driver loop per stack.
//! * [`SolveOptions`] (in [`driver`]) — tableau, tolerances, initial
//!   step, and an **explicit** [`StepBudget`]: `PerSegment` (each save
//!   interval gets the full attempt budget — the data-generation
//!   semantics) or `Total` (one budget bounds the whole solve — the
//!   budget-ladder training contract).
//! * [`Saveat`] — a `Span { t0, t1 }` or a non-decreasing `Grid`.
//! * [`Taping`] — discrete-adjoint recording as configuration: `Off`,
//!   or an [`OdeTape`] / [`SdeTape`] matching the stack.
//! * [`StepObserver`]s (in [`observer`]) — invoked once per *accepted*
//!   step with a [`StepView`] `(index, t, h, E_j, S_j, state, error
//!   vector)`.  The paper's regularizers are themselves observers:
//!   [`ErrorIntegral`] (`R_E`), [`ErrorSquared`] (`Σ E_j²`),
//!   [`StiffnessSum`] (`R_S`) — the driver always installs these three,
//!   bit-identical to the seed's hard-wired `Stats` fields — and
//!   [`LocalReg`], the sampled-step local regularizer behind the
//!   `lrnode`/`lrnsde` methods (Pal et al. 2023).
//!
//! The RK stepper's stage combination + embedded error estimate are
//! fused into one pass over the stage arena
//! (`models::kernels::rk_combine`), dims chunked 8 lanes wide with the
//! tableau's stage order preserved per dim — bit-identical to the seed
//! two-pass loop by construction (DESIGN.md §Perf), so the
//! `tests/solver_equivalence.rs` pin is unaffected.
//!
//! Gradients flow through [`adjoint`]: taped solves record the accepted
//! steps, [`ode_backward_sys`] / [`sde_backward_sys`] walk them in
//! reverse under [`RegCoefs`] (global `coef_e`/`coef_s` plus the
//! optional sampled-step local term), and the replay functions re-run
//! the frozen program for finite-difference checks.
//!
//! ## Failure containment (DESIGN.md §Robustness)
//!
//! Every drive returns `Result<SolveOutcome, SolveError>` ([`error`]):
//! no panic is reachable from user input and nothing fails silently.
//! [`SolveErrorKind`] names the failure class — `NonFiniteState` (a
//! learned vector field blew up mid-attempt), `StepSizeUnderflow` (a
//! rejection drove the step below the EPS floor), `BudgetExhausted`
//! (the [`StepBudget`] died first), `BadSpan` (malformed span/grid),
//! `TapeMismatch` / `MissingRng` (misconfiguration) — and the
//! [`SolveError`] carries the last committed state plus realized
//! [`Stats`] so callers can retry, escalate or shed without re-deriving
//! work.  Failed drives stay grid-shaped (remaining save points repeat
//! the last committed state) and fail fast: the first failed segment
//! ends the integration.  [`chaos::ChaosSystem`] wraps any [`System`]
//! with configurable fault injection (NaN drift, slow evaluations,
//! forced rejects) to prove these paths in `tests/fault_injection.rs`.
//!
//! The closure-based legacy entry points of the pre-unification release
//! (`ode::solve`, `solve_saveat`, `solve_saveat_taped`,
//! `sde_solve_saveat`, `sde_solve_saveat_taped` and their
//! `OdeOptions`/`SdeOptions` bundles) are **retired**: every caller goes
//! through [`solve`] or the per-stack drivers, and
//! `tests/solver_equivalence.rs` pins the unified API bit-for-bit
//! against a transcription of the seed stepper.
//!
//! ## Roles
//!
//!  1. **Training** — the native backend (`runtime::native`) trains all
//!     five paper models through taped drives + discrete adjoints.
//!  2. **Data generation** — ground-truth spiral ODE/SDE trajectories
//!     and the synthetic-dataset generators (`rust/src/data`), scaled to
//!     ensembles by [`ensemble`] across a thread pool with deterministic
//!     per-trajectory RNG streams.
//!  3. **Cross-validation / reference analytics** — the same Butcher
//!     tableaus bit-for-bit as python/compile/tableaus.py ([`tableau`],
//!     with [`Tableau::parse`] at CLI boundaries), shared controller
//!     heuristics ([`controller`]), canonical problems ([`problems`]).
//!
//! ## Enforced invariants (DESIGN.md §Static Analysis)
//!
//! This module is in the `regnde-analyze` lint perimeter: the
//! step-attempt loops are `// analyze: hot-path` (allocation-free),
//! panics are unreachable outside `#[cfg(test)]` (errors flow through
//! typed [`SolveError`]s), [`SolveErrorKind`] wire strings are pinned
//! by the committed wire registry, and FP accumulation avoids
//! hash-order and untyped-`.sum()` nondeterminism.  CI runs the lints
//! (`cargo run -p regnde-analyze -- --deny-all`) and Miri over these
//! unit tests on every PR.

pub mod adjoint;
pub mod chaos;
pub mod controller;
pub mod driver;
pub mod ensemble;
pub mod error;
pub mod observer;
pub mod ode;
pub mod problems;
pub mod sde;
pub mod system;
pub mod tableau;

pub use adjoint::{
    ode_backward, ode_backward_sys, ode_replay, ode_replay_errors, sde_backward,
    sde_backward_sys, sde_replay, sde_replay_errors, OdeTape, RegCoefs, SdeTape,
};
pub use chaos::{ChaosConfig, ChaosSystem};
pub use driver::{solve, Saveat, SolveOptions, StepBudget, Taping};
pub use error::{SolveError, SolveErrorKind, SolveResult, SolveResultExt};
pub use ensemble::{
    sde_ensemble_moments, sde_solve_ensemble, solve_ensemble, EnsembleOptions, SdeMoments,
    SdeTrajectory,
};
pub use observer::{
    ErrorIntegral, ErrorSquared, LocalReg, StepObserver, StepView, StiffnessSum,
};
pub use ode::{SolveOutcome, Stats};
pub use system::{OdeSystem, OdeSystemVjp, SdeSystem, SdeSystemVjp, System};
pub use tableau::Tableau;
