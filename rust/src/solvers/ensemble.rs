//! Parallel ensemble integration: many independent trajectories, each with
//! its own adaptive stepper, chunked across a [`ThreadPool`].
//!
//! This is the throughput layer behind the paper's ensemble workloads —
//! the 10k-trajectory spiral DSDE moment fixtures (Eq. 15, Table 3) and
//! multi-initial-condition solver benches — which the seed integrated
//! strictly serially.  Three guarantees:
//!
//!  1. **Per-trajectory equivalence** — each trajectory runs the exact
//!     single-trajectory driver ([`ode::drive`] / [`sde::drive`]
//!     semantics) with independent adaptive steps; an ensemble of N copies
//!     is bit-identical to N independent solve calls.
//!  2. **Schedule independence** — results do not depend on worker count
//!     or thread timing: SDE trajectories draw from per-trajectory RNG
//!     streams derived from `(seed, index)` up front, work is split into
//!     fixed-size chunks, and chunk partials are merged in index order.
//!     `workers = 1` and `workers = 8` produce identical bits.
//!  3. **Bounded parallelism** — dispatch goes through the thread pool's
//!     bounded map ([`map_bounded`]), so at most `workers` chunks are in
//!     flight (10k trajectories never means 10k threads).

use super::driver::{Saveat, SolveOptions};
use super::error::{SolveError, SolveResult, SolveResultExt};
use super::ode::{self, Stats};
use super::sde;
use super::system::{OdeSystem, SdeSystem};
use crate::dist::ShardPlan;
use crate::util::rng::Rng;
use crate::util::threadpool::{default_workers, map_bounded};

/// How an ensemble is scheduled (orthogonal to solver tolerances).
#[derive(Clone, Debug)]
pub struct EnsembleOptions {
    /// Worker threads; `1` integrates serially on the calling thread.
    pub workers: usize,
    /// Trajectories per work item.  Fixed (not derived from `workers`) so
    /// the chunk partial-merge order — and therefore every output bit —
    /// is identical at any parallelism level.
    pub chunk: usize,
}

impl Default for EnsembleOptions {
    fn default() -> Self {
        Self {
            workers: default_workers(),
            chunk: 32,
        }
    }
}

impl EnsembleOptions {
    /// Serial schedule (reference semantics / baseline for benches).
    pub fn serial() -> Self {
        Self {
            workers: 1,
            ..Default::default()
        }
    }

    /// Run `job` over every chunk of `0..n`, merging results in chunk
    /// order regardless of how (or whether) chunks ran in parallel.  The
    /// partition comes from the shared deterministic sharder
    /// ([`ShardPlan::by_chunk`]) so ensemble sweeps and the distributed
    /// training coordinator split work identically (DESIGN.md
    /// §Distributed).
    fn run_chunks<R: Send>(
        &self,
        n: usize,
        job: impl Fn(std::ops::Range<usize>) -> R + Send + Sync,
    ) -> Vec<R> {
        let plan = ShardPlan::by_chunk(n, self.chunk);
        map_bounded(self.workers, plan.ranges().to_vec(), job)
    }
}

/// Integrate one ODE from many initial conditions over `[t0, t1]`.
///
/// Results are in input order; trajectory `i` is exactly
/// `ode::drive(&mut sys, &z0s[i], Saveat::Span { t0, t1 }, opts, ..)`.
/// Failure containment is per trajectory: a trajectory that fails
/// carries its own typed [`SolveError`] (fail-fast for that trajectory)
/// and leaves every other trajectory unaffected.
pub fn solve_ensemble<F>(
    f: &F,
    z0s: &[Vec<f64>],
    t0: f64,
    t1: f64,
    opts: &SolveOptions,
    eopts: &EnsembleOptions,
) -> Vec<SolveResult>
where
    F: Fn(&[f64], f64, &mut [f64]) + Sync,
{
    let per_chunk = eopts.run_chunks(z0s.len(), |range| {
        range
            .map(|i| {
                let mut sys = OdeSystem(|z: &[f64], t: f64, dz: &mut [f64]| f(z, t, dz));
                let (_, out) =
                    ode::drive(&mut sys, &z0s[i], Saveat::Span { t0, t1 }, opts, None, &mut []);
                out
            })
            .collect::<Vec<_>>()
    });
    per_chunk.into_iter().flatten().collect()
}

/// One SDE trajectory of an ensemble solve.  Failure containment is
/// per trajectory: `error` carries this trajectory's typed failure (if
/// any) and says nothing about its siblings.
#[derive(Clone, Debug)]
pub struct SdeTrajectory {
    /// Saved states at each `ts` entry (`[T][n]`; grid-shaped even on
    /// failure, repeating the last committed state).
    pub states: Vec<Vec<f64>>,
    pub stats: Stats,
    pub error: Option<SolveError>,
}

impl SdeTrajectory {
    /// The seed's `success` flag: no typed failure.
    pub fn success(&self) -> bool {
        self.error.is_none()
    }
}

/// Derive the RNG for trajectory `i`: a function of `(seed, i)` only, so
/// streams are independent of scheduling and of each other.  Shared with
/// the native backend's NSDE ensembles (`runtime::native`) so both draw
/// from the same stream family.
pub(crate) fn trajectory_rng(seed: u64, i: usize) -> Rng {
    Rng::new(seed ^ (i as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15))
}

/// Integrate `n_traj` trajectories of a diagonal-noise SDE from a shared
/// initial state, saving at each time in `ts`.
///
/// Trajectory `i` draws from its own deterministic stream (see
/// [`trajectory_rng`] derivation); the result is identical for any
/// `eopts.workers`.
#[allow(clippy::too_many_arguments)]
pub fn sde_solve_ensemble<F, G>(
    drift: &F,
    diffusion: &G,
    z0: &[f64],
    ts: &[f64],
    n_traj: usize,
    seed: u64,
    opts: &SolveOptions,
    eopts: &EnsembleOptions,
) -> Vec<SdeTrajectory>
where
    F: Fn(&[f64], f64, &mut [f64]) + Sync,
    G: Fn(&[f64], f64, &mut [f64]) + Sync,
{
    let per_chunk = eopts.run_chunks(n_traj, |range| {
        range
            .map(|i| {
                let mut rng = trajectory_rng(seed, i);
                let mut sys = SdeSystem {
                    drift: |z: &[f64], t: f64, dz: &mut [f64]| drift(z, t, dz),
                    diffusion: |z: &[f64], t: f64, dg: &mut [f64]| diffusion(z, t, dg),
                };
                let (states, out) =
                    sde::drive(&mut sys, z0, Saveat::Grid(ts), &mut rng, opts, None, &mut []);
                SdeTrajectory {
                    states,
                    stats: out.stats(),
                    error: out.err(),
                }
            })
            .collect::<Vec<_>>()
    });
    per_chunk.into_iter().flatten().collect()
}

/// Streaming per-save-point first and second moments of an SDE ensemble.
#[derive(Clone, Debug)]
pub struct SdeMoments {
    /// Mean, row-major `[T, n]`.
    pub mu: Vec<f64>,
    /// Population variance, row-major `[T, n]`.
    pub var: Vec<f64>,
    /// Merged solver statistics over the whole ensemble.
    pub stats: Stats,
    /// First (lowest trajectory index) typed failure, if any trajectory
    /// failed; deterministic because chunk partials merge in index order.
    pub error: Option<SolveError>,
}

impl SdeMoments {
    /// The seed's `success` flag: every trajectory solved cleanly.
    pub fn success(&self) -> bool {
        self.error.is_none()
    }
}

/// Like [`sde_solve_ensemble`] but folds each chunk into running
/// sum / sum-of-squares accumulators instead of materializing every
/// trajectory — O(T·n) memory for a 10k-trajectory ensemble.
///
/// Chunk partials are merged in chunk order, so the moments are
/// bit-identical at any `eopts.workers`.
#[allow(clippy::too_many_arguments)]
pub fn sde_ensemble_moments<F, G>(
    drift: &F,
    diffusion: &G,
    z0: &[f64],
    ts: &[f64],
    n_traj: usize,
    seed: u64,
    opts: &SolveOptions,
    eopts: &EnsembleOptions,
) -> SdeMoments
where
    F: Fn(&[f64], f64, &mut [f64]) + Sync,
    G: Fn(&[f64], f64, &mut [f64]) + Sync,
{
    assert!(n_traj > 0, "need at least one trajectory");
    let n = z0.len();
    let t = ts.len();
    let per_chunk = eopts.run_chunks(n_traj, |range| {
        let mut sum = vec![0.0f64; t * n];
        let mut sumsq = vec![0.0f64; t * n];
        let mut stats = Stats::default();
        let mut first_err: Option<SolveError> = None;
        for i in range {
            let mut rng = trajectory_rng(seed, i);
            let mut sys = SdeSystem {
                drift: |z: &[f64], t: f64, dz: &mut [f64]| drift(z, t, dz),
                diffusion: |z: &[f64], t: f64, dg: &mut [f64]| diffusion(z, t, dg),
            };
            let (states, out) =
                sde::drive(&mut sys, z0, Saveat::Grid(ts), &mut rng, opts, None, &mut []);
            stats.merge(&out.stats());
            if first_err.is_none() {
                first_err = out.err();
            }
            for (k, zk) in states.iter().enumerate() {
                for d in 0..n {
                    sum[k * n + d] += zk[d];
                    sumsq[k * n + d] += zk[d] * zk[d];
                }
            }
        }
        (sum, sumsq, stats, first_err)
    });

    let mut sum = vec![0.0f64; t * n];
    let mut sumsq = vec![0.0f64; t * n];
    let mut stats = Stats::default();
    let mut error = None;
    for (s, sq, st, chunk_err) in per_chunk {
        for i in 0..t * n {
            sum[i] += s[i];
            sumsq[i] += sq[i];
        }
        stats.merge(&st);
        if error.is_none() {
            error = chunk_err;
        }
    }
    let inv = 1.0 / n_traj as f64;
    let mu: Vec<f64> = sum.iter().map(|s| s * inv).collect();
    let var: Vec<f64> = sumsq
        .iter()
        .zip(&sum)
        .map(|(sq, s)| ((sq * inv) - (s * inv) * (s * inv)).max(0.0))
        .collect();
    SdeMoments {
        mu,
        var,
        stats,
        error,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solvers::problems;

    fn exp_decay(z: &[f64], _t: f64, dz: &mut [f64]) {
        for i in 0..z.len() {
            dz[i] = -z[i];
        }
    }

    #[test]
    // Statistical / many-trajectory: minutes under the Miri
    // interpreter for no extra UB coverage (DESIGN.md §Static
    // Analysis).
    #[cfg_attr(miri, ignore)]
    fn ode_ensemble_matches_independent_solves() {
        let opts = SolveOptions::new().with_tolerance(1e-8);
        let z0s: Vec<Vec<f64>> = (0..37)
            .map(|i| vec![1.0 + 0.1 * i as f64, -0.5 * i as f64])
            .collect();
        let eopts = EnsembleOptions {
            workers: 3,
            chunk: 4,
        };
        let ensemble = solve_ensemble(&exp_decay, &z0s, 0.0, 1.0, &opts, &eopts);
        assert_eq!(ensemble.len(), z0s.len());
        for (i, out) in ensemble.iter().enumerate() {
            let mut sys = OdeSystem(exp_decay);
            let (_, solo) = ode::drive(
                &mut sys,
                &z0s[i],
                Saveat::Span { t0: 0.0, t1: 1.0 },
                &opts,
                None,
                &mut [],
            );
            let out = out.as_ref().expect("trajectory failed");
            let solo = solo.unwrap();
            assert_eq!(out.z, solo.z, "trajectory {i} state drifted");
            assert_eq!(out.stats.nfe, solo.stats.nfe);
            assert_eq!(out.stats.naccept, solo.stats.naccept);
            assert_eq!(out.stats.nreject, solo.stats.nreject);
        }
    }

    #[test]
    // Statistical / many-trajectory: minutes under the Miri
    // interpreter for no extra UB coverage (DESIGN.md §Static
    // Analysis).
    #[cfg_attr(miri, ignore)]
    fn sde_ensemble_is_schedule_independent() {
        let ts = [0.0, 0.5, 1.0];
        let opts = SolveOptions::new().with_tolerance(1e-2);
        let serial = sde_solve_ensemble(
            &problems::spiral_sde_drift,
            &problems::spiral_sde_diffusion,
            &[1.0, 1.0],
            &ts,
            50,
            7,
            &opts,
            &EnsembleOptions {
                workers: 1,
                chunk: 8,
            },
        );
        let pooled = sde_solve_ensemble(
            &problems::spiral_sde_drift,
            &problems::spiral_sde_diffusion,
            &[1.0, 1.0],
            &ts,
            50,
            7,
            &opts,
            &EnsembleOptions {
                workers: 4,
                chunk: 8,
            },
        );
        assert_eq!(serial.len(), pooled.len());
        for (a, b) in serial.iter().zip(&pooled) {
            assert_eq!(a.states, b.states);
            assert_eq!(a.stats.nfe, b.stats.nfe);
        }
    }

    #[test]
    // Statistical / many-trajectory: minutes under the Miri
    // interpreter for no extra UB coverage (DESIGN.md §Static
    // Analysis).
    #[cfg_attr(miri, ignore)]
    fn sde_trajectories_differ_from_each_other() {
        let ts = [0.0, 1.0];
        let ens = sde_solve_ensemble(
            &problems::spiral_sde_drift,
            &problems::spiral_sde_diffusion,
            &[1.0, 1.0],
            &ts,
            4,
            3,
            &SolveOptions::new().with_tolerance(1e-2),
            &EnsembleOptions::serial(),
        );
        assert_ne!(ens[0].states[1], ens[1].states[1], "streams not independent");
    }

    #[test]
    // Statistical / many-trajectory: minutes under the Miri
    // interpreter for no extra UB coverage (DESIGN.md §Static
    // Analysis).
    #[cfg_attr(miri, ignore)]
    fn moments_match_materialized_ensemble() {
        let ts = [0.0, 0.5, 1.0];
        let opts = SolveOptions::new().with_tolerance(1e-2);
        let eopts = EnsembleOptions {
            workers: 2,
            chunk: 16,
        };
        let n_traj = 64;
        let full = sde_solve_ensemble(
            &problems::spiral_sde_drift,
            &problems::spiral_sde_diffusion,
            &[1.0, 1.0],
            &ts,
            n_traj,
            11,
            &opts,
            &eopts,
        );
        let m = sde_ensemble_moments(
            &problems::spiral_sde_drift,
            &problems::spiral_sde_diffusion,
            &[1.0, 1.0],
            &ts,
            n_traj,
            11,
            &opts,
            &eopts,
        );
        assert!(m.success());
        for k in 0..ts.len() {
            for d in 0..2 {
                let mean = full.iter().map(|tr| tr.states[k][d]).sum::<f64>()
                    / n_traj as f64;
                assert!(
                    (m.mu[k * 2 + d] - mean).abs() < 1e-9,
                    "mu mismatch at ({k},{d}): {} vs {mean}",
                    m.mu[k * 2 + d]
                );
            }
        }
        // t=0: mean exactly z0, zero variance.
        assert!((m.mu[0] - 1.0).abs() < 1e-12);
        assert!(m.var[0] < 1e-12);
        assert!(m.var[4] > m.var[0], "variance must grow from zero");
        // Stats aggregate over all trajectories.
        assert_eq!(
            m.stats.nfe,
            full.iter().map(|tr| tr.stats.nfe).sum::<u64>()
        );
    }

    #[test]
    // Statistical / many-trajectory: minutes under the Miri
    // interpreter for no extra UB coverage (DESIGN.md §Static
    // Analysis).
    #[cfg_attr(miri, ignore)]
    fn moments_schedule_independent_bits() {
        let ts = [0.0, 0.4, 0.8];
        let mk = |workers| {
            sde_ensemble_moments(
                &problems::spiral_sde_drift,
                &problems::spiral_sde_diffusion,
                &[1.0, 1.0],
                &ts,
                48,
                21,
                &SolveOptions::new().with_tolerance(1e-2),
                &EnsembleOptions { workers, chunk: 8 },
            )
        };
        let a = mk(1);
        let b = mk(5);
        assert_eq!(a.mu, b.mu);
        assert_eq!(a.var, b.var);
        assert_eq!(a.stats.nfe, b.stats.nfe);
    }

    #[test]
    fn empty_ensemble_is_empty() {
        let outs = solve_ensemble(
            &exp_decay,
            &[],
            0.0,
            1.0,
            &SolveOptions::default(),
            &EnsembleOptions::default(),
        );
        assert!(outs.is_empty());
    }
}
