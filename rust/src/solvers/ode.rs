//! Native adaptive explicit Runge-Kutta integrator with white-boxed
//! heuristics — the Rust mirror of python/compile/solver.py.
//!
//! Semantics match the JAX solver: Hairer RMS error norm, paper Eq. 5
//! accept test, PI controller (Eq. 6) with the same gains, FSAL stage
//! reuse, `R_E = sum E_j |h_j|`, `R_S = sum S_j` (Eq. 9/11) and
//! DiffEqFlux-style NFE accounting.  f64 state (data generation wants the
//! extra precision; the JAX side is f32 — cross-validation tolerances
//! account for that).

use super::tableau::Tableau;

/// Controller constants — keep in sync with python/compile/norms.py.
const SAFETY: f64 = 0.9;
const MIN_FACTOR: f64 = 0.2;
const MAX_FACTOR: f64 = 10.0;
const PI_BETA: f64 = 0.04;
const EPS: f64 = 1e-12;

/// White-boxed solver statistics (paper Eq. 9/11 accumulators + counters).
#[derive(Clone, Copy, Debug, Default)]
pub struct Stats {
    pub r_e: f64,
    pub r_e2: f64,
    pub r_s: f64,
    pub nfe: u64,
    pub naccept: u64,
    pub nreject: u64,
}

impl Stats {
    pub fn merge(&mut self, o: &Stats) {
        self.r_e += o.r_e;
        self.r_e2 += o.r_e2;
        self.r_s += o.r_s;
        self.nfe += o.nfe;
        self.naccept += o.naccept;
        self.nreject += o.nreject;
    }
}

#[derive(Clone, Debug)]
pub struct OdeOptions {
    pub tableau: Tableau,
    pub rtol: f64,
    pub atol: f64,
    pub max_steps: u64,
    pub dt0: Option<f64>,
}

impl Default for OdeOptions {
    fn default() -> Self {
        Self {
            tableau: Tableau::tsit5(),
            rtol: 1e-6,
            atol: 1e-6,
            max_steps: 100_000,
            dt0: None,
        }
    }
}

/// Final state + statistics of one integration.
#[derive(Clone, Debug)]
pub struct SolveOutcome {
    pub z: Vec<f64>,
    pub t: f64,
    pub stats: Stats,
    pub success: bool,
}

fn rms(v: &[f64]) -> f64 {
    (v.iter().map(|x| x * x).sum::<f64>() / v.len() as f64 + 1e-300).sqrt()
}

fn error_ratio(e: &[f64], z0: &[f64], z1: &[f64], rtol: f64, atol: f64) -> f64 {
    let mut acc = 0.0;
    for i in 0..e.len() {
        let scale = atol + z0[i].abs().max(z1[i].abs()) * rtol;
        let r = e[i] / scale;
        acc += r * r;
    }
    (acc / e.len() as f64 + 1e-300).sqrt()
}

fn pi_factor(q: f64, q_prev: f64, order: usize) -> f64 {
    let alpha = 1.0 / order as f64 - 0.75 * PI_BETA;
    let f = SAFETY * q.max(1e-10).powf(-alpha) * q_prev.max(1e-10).powf(PI_BETA);
    f.clamp(MIN_FACTOR, MAX_FACTOR)
}

fn reject_factor(q: f64, order: usize) -> f64 {
    let alpha = 1.0 / order as f64;
    (SAFETY * q.max(1e-10).powf(-alpha)).clamp(MIN_FACTOR, 1.0)
}

/// Internal stepping state threaded across segments in saveat solves.
struct Stepper<'a, F: FnMut(&[f64], f64, &mut [f64])> {
    f: F,
    tab: &'a Tableau,
    opts: &'a OdeOptions,
    /// FSAL stage (f at the current (t, z)).
    k1: Vec<f64>,
    h: f64,
    q_prev: f64,
    stats: Stats,
    // scratch
    ks: Vec<Vec<f64>>,
    zi: Vec<f64>,
    znew: Vec<f64>,
    err: Vec<f64>,
}

impl<'a, F: FnMut(&[f64], f64, &mut [f64])> Stepper<'a, F> {
    fn new(mut f: F, tab: &'a Tableau, opts: &'a OdeOptions, z0: &[f64], t0: f64, span: f64) -> Self {
        let n = z0.len();
        let mut k1 = vec![0.0; n];
        f(z0, t0, &mut k1);
        let h0 = opts
            .dt0
            .unwrap_or_else(|| 0.01 * span / rms(&k1).max(1.0));
        Self {
            f,
            tab,
            opts,
            k1,
            h: h0,
            q_prev: 1.0,
            stats: Stats {
                nfe: 1,
                ..Default::default()
            },
            ks: vec![vec![0.0; n]; tab.stages()],
            zi: vec![0.0; n],
            znew: vec![0.0; n],
            err: vec![0.0; n],
        }
    }

    /// Integrate from (t, z) to t1 in place.  Returns success.
    fn advance(&mut self, z: &mut Vec<f64>, t: &mut f64, t1: f64, budget: u64) -> bool {
        let s = self.tab.stages();
        let n = z.len();
        let mut attempts = 0;
        while *t < t1 - 1e-12 * t1.abs().max(1.0) {
            if attempts >= budget {
                return false;
            }
            attempts += 1;
            let h = self.h.min(t1 - *t).max(EPS);

            // Stage cascade (k1 via FSAL).
            self.ks[0].copy_from_slice(&self.k1);
            let (sx, sy) = self.tab.stiff_pair;
            let mut g_x = vec![0.0; if sx == 0 { n } else { 0 }];
            if sx == 0 {
                g_x.copy_from_slice(z);
            }
            let mut g_y = vec![0.0; n];
            for i in 1..s {
                self.zi.copy_from_slice(z);
                for (j, &aij) in self.tab.a[i].iter().enumerate() {
                    if aij != 0.0 {
                        for d in 0..n {
                            self.zi[d] += h * aij * self.ks[j][d];
                        }
                    }
                }
                if i == sx {
                    g_x = self.zi.clone();
                }
                if i == sy {
                    g_y.copy_from_slice(&self.zi);
                }
                let ti = *t + self.tab.c[i] * h;
                let (before, after) = self.ks.split_at_mut(i);
                let _ = before;
                (self.f)(&self.zi, ti, &mut after[0]);
            }
            self.stats.nfe += self.tab.nfe_per_attempt() as u64;

            // Combination + embedded error (paper Eq. 3).
            for d in 0..n {
                let mut acc_b = 0.0;
                let mut acc_bt = 0.0;
                for i in 0..s {
                    acc_b += self.tab.b[i] * self.ks[i][d];
                    acc_bt += self.tab.btilde[i] * self.ks[i][d];
                }
                self.znew[d] = z[d] + h * acc_b;
                self.err[d] = h * acc_bt;
            }

            let q = error_ratio(&self.err, z, &self.znew, self.opts.rtol, self.opts.atol);
            let e_norm = rms(&self.err);

            if q <= 1.0 {
                // Shampine stiffness ratio (paper Eq. 8).
                let mut dnum = vec![0.0; n];
                let mut dden = vec![0.0; n];
                for d in 0..n {
                    dnum[d] = self.ks[sy][d] - self.ks[sx][d];
                    dden[d] = g_y[d] - g_x[d];
                }
                let stiff = rms(&dnum) / (rms(&dden) + EPS);

                self.stats.r_e += e_norm * h.abs();
                self.stats.r_e2 += e_norm * e_norm;
                self.stats.r_s += stiff;
                self.stats.naccept += 1;
                *t += h;
                std::mem::swap(z, &mut self.znew);
                // FSAL: last stage is f at the accepted point.
                self.k1.copy_from_slice(&self.ks[s - 1]);
                self.h = h * pi_factor(q, self.q_prev, self.tab.order);
                self.q_prev = q.max(1e-4);
            } else {
                self.stats.nreject += 1;
                self.h = h * reject_factor(q, self.tab.order);
            }
        }
        true
    }
}

/// Adaptive solve over [t0, t1].  `f(z, t, dz)` writes the derivative.
pub fn solve<F: FnMut(&[f64], f64, &mut [f64])>(
    f: F,
    z0: &[f64],
    t0: f64,
    t1: f64,
    opts: &OdeOptions,
) -> SolveOutcome {
    let tab = opts.tableau.clone();
    let mut stepper = Stepper::new(f, &tab, opts, z0, t0, t1 - t0);
    let mut z = z0.to_vec();
    let mut t = t0;
    let ok = stepper.advance(&mut z, &mut t, t1, opts.max_steps);
    SolveOutcome {
        z,
        t,
        stats: stepper.stats,
        success: ok,
    }
}

/// Adaptive solve saving the state at each time in `ts` (ts[0] = t0).
/// Returns (states, outcome-with-final-state).
pub fn solve_saveat<F: FnMut(&[f64], f64, &mut [f64])>(
    f: F,
    z0: &[f64],
    ts: &[f64],
    opts: &OdeOptions,
) -> (Vec<Vec<f64>>, SolveOutcome) {
    assert!(ts.len() >= 2, "need at least two save points");
    let tab = opts.tableau.clone();
    let mut stepper = Stepper::new(f, &tab, opts, z0, ts[0], ts[ts.len() - 1] - ts[0]);
    let mut z = z0.to_vec();
    let mut t = ts[0];
    let mut out = Vec::with_capacity(ts.len());
    out.push(z.clone());
    let mut ok = true;
    for &t_hi in &ts[1..] {
        ok &= stepper.advance(&mut z, &mut t, t_hi, opts.max_steps);
        out.push(z.clone());
    }
    (
        out,
        SolveOutcome {
            z,
            t,
            stats: stepper.stats,
            success: ok,
        },
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn exp_decay(z: &[f64], _t: f64, dz: &mut [f64]) {
        for i in 0..z.len() {
            dz[i] = -z[i];
        }
    }

    #[test]
    fn exponential_decay_accuracy() {
        let opts = OdeOptions {
            rtol: 1e-8,
            atol: 1e-8,
            ..Default::default()
        };
        let out = solve(exp_decay, &[1.0, 2.0], 0.0, 1.0, &opts);
        assert!(out.success);
        assert!((out.z[0] - (-1.0f64).exp()).abs() < 1e-7, "{}", out.z[0]);
        assert!((out.z[1] - 2.0 * (-1.0f64).exp()).abs() < 1e-7);
    }

    #[test]
    fn fifth_order_convergence() {
        // Tighter tolerance should reduce error superlinearly.
        let errs: Vec<f64> = [1e-4, 1e-6, 1e-8]
            .iter()
            .map(|&tol| {
                let opts = OdeOptions {
                    rtol: tol,
                    atol: tol,
                    ..Default::default()
                };
                let out = solve(exp_decay, &[1.0], 0.0, 1.0, &opts);
                (out.z[0] - (-1.0f64).exp()).abs()
            })
            .collect();
        assert!(errs[0] > errs[1] && errs[1] >= errs[2], "{errs:?}");
    }

    #[test]
    fn harmonic_oscillator_energy() {
        // z'' = -z  as first-order system; energy must be conserved ~1e-6.
        let f = |z: &[f64], _t: f64, dz: &mut [f64]| {
            dz[0] = z[1];
            dz[1] = -z[0];
        };
        let opts = OdeOptions {
            rtol: 1e-9,
            atol: 1e-9,
            ..Default::default()
        };
        let out = solve(f, &[1.0, 0.0], 0.0, 10.0, &opts);
        let energy = out.z[0] * out.z[0] + out.z[1] * out.z[1];
        assert!((energy - 1.0).abs() < 1e-6, "energy={energy}");
    }

    #[test]
    fn nfe_grows_with_tighter_tol() {
        let nfe: Vec<u64> = [1e-3, 1e-6, 1e-9]
            .iter()
            .map(|&tol| {
                let opts = OdeOptions {
                    rtol: tol,
                    atol: tol,
                    ..Default::default()
                };
                solve(exp_decay, &[1.0], 0.0, 1.0, &opts).stats.nfe
            })
            .collect();
        assert!(nfe[0] < nfe[1] && nfe[1] < nfe[2], "{nfe:?}");
    }

    #[test]
    fn stiffness_estimate_tracks_lambda() {
        for lambda in [10.0, 100.0] {
            let f = |z: &[f64], _t: f64, dz: &mut [f64]| {
                dz[0] = -lambda * z[0];
            };
            let opts = OdeOptions {
                rtol: 1e-7,
                atol: 1e-7,
                ..Default::default()
            };
            let out = solve(f, &[1.0], 0.0, 1.0, &opts);
            let s_per_step = out.stats.r_s / out.stats.naccept as f64;
            assert!(
                (s_per_step - lambda).abs() / lambda < 0.2,
                "lambda={lambda} est={s_per_step}"
            );
        }
    }

    #[test]
    fn saveat_grid_matches_analytic() {
        let ts: Vec<f64> = (0..11).map(|i| i as f64 * 0.1).collect();
        let opts = OdeOptions {
            rtol: 1e-8,
            atol: 1e-8,
            ..Default::default()
        };
        let (zs, out) = solve_saveat(exp_decay, &[1.0], &ts, &opts);
        assert!(out.success);
        for (i, z) in zs.iter().enumerate() {
            assert!((z[0] - (-ts[i]).exp()).abs() < 1e-6);
        }
    }

    #[test]
    fn budget_exhaustion_reports_failure() {
        let opts = OdeOptions {
            rtol: 1e-12,
            atol: 1e-12,
            max_steps: 3,
            ..Default::default()
        };
        let out = solve(exp_decay, &[1.0], 0.0, 1.0, &opts);
        assert!(!out.success);
    }

    #[test]
    fn dopri5_and_tsit5_agree() {
        let mk = |tab: Tableau| OdeOptions {
            tableau: tab,
            rtol: 1e-9,
            atol: 1e-9,
            ..Default::default()
        };
        let a = solve(exp_decay, &[1.0], 0.0, 1.0, &mk(Tableau::tsit5()));
        let b = solve(exp_decay, &[1.0], 0.0, 1.0, &mk(Tableau::dopri5()));
        assert!((a.z[0] - b.z[0]).abs() < 1e-8);
    }

    #[test]
    fn rejections_occur_on_abrupt_dynamics() {
        // A sharp transition forces step rejections.
        let f = |z: &[f64], t: f64, dz: &mut [f64]| {
            dz[0] = if t < 0.5 { -z[0] } else { -50.0 * z[0] };
        };
        let opts = OdeOptions {
            rtol: 1e-8,
            atol: 1e-8,
            ..Default::default()
        };
        let out = solve(f, &[1.0], 0.0, 1.0, &opts);
        assert!(out.success);
        assert!(out.stats.nreject > 0, "{:?}", out.stats);
    }
}
