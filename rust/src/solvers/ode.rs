//! Native adaptive explicit Runge-Kutta stack: one generic driver loop
//! ([`drive`]) behind the unified white-box API ([`super::driver`]).
//!
//! Semantics match the JAX solver: Hairer RMS error norm, paper Eq. 5
//! accept test, PI controller (Eq. 6) with the same gains, FSAL stage
//! reuse, `R_E = sum E_j |h_j|`, `R_S = sum S_j` (Eq. 9/11) and
//! DiffEqFlux-style NFE accounting.  f64 state (data generation wants the
//! extra precision; the JAX side is f32 — cross-validation tolerances
//! account for that).
//!
//! The driver integrates a [`System`] over a [`Saveat`] spec under a
//! [`SolveOptions`] budget, with optional [`OdeTape`] recording and any
//! number of [`StepObserver`]s.  The white-boxed accumulators in
//! [`Stats`] are produced by the built-in observers
//! ([`super::observer::ErrorIntegral`] / [`ErrorSquared`] /
//! [`StiffnessSum`]) the driver always installs — bit-identical to the
//! seed's hard-wired fields (pinned by `tests/solver_equivalence.rs`
//! through the unified API; the closure-based legacy shims of the
//! pre-unification release are gone).
//!
//! ## Memory layout (DESIGN.md §Perf)
//!
//! The accept/reject loop is allocation-free: all solver scratch lives in
//! one contiguous arena sized `(stages + 5) * n` at construction —
//! RK stages as a flat row-major `[stages × n]` block (row 0 doubles as
//! the FSAL stage), followed by the `zi` / `znew` / `err` / `g_x` / `g_y`
//! working vectors.  Stage combination and the embedded error estimate
//! are **fused into one pass** over the stage block
//! ([`crate::models::kernels::rk_combine`]): dims chunked into vector
//! lanes, stages as the inner loop, so stages stream through cache once
//! per attempt while each dim still accumulates in tableau stage order —
//! bit-identical to the seed's two-pass loop.  The tableau is borrowed
//! for the whole solve (never cloned), and the Shampine stiffness ratio
//! is computed with scalar accumulators instead of scratch vectors.
//! Controller constants and the error norm are shared with the SDE
//! solver via [`super::controller`].

use super::adjoint::OdeTape;
use crate::models::kernels;
use super::controller::{error_ratio, pi_factor, reject_factor, rms, stiffness_ratio, EPS};
use super::driver::{Saveat, SolveOptions};
use super::error::{SolveError, SolveErrorKind, SolveResult};
use super::observer::{ErrorIntegral, ErrorSquared, StepObserver, StepView, StiffnessSum};
use super::system::System;
use super::tableau::Tableau;

/// White-boxed solver statistics (paper Eq. 9/11 accumulators + counters).
#[derive(Clone, Copy, Debug, Default)]
pub struct Stats {
    pub r_e: f64,
    pub r_e2: f64,
    pub r_s: f64,
    pub nfe: u64,
    pub naccept: u64,
    pub nreject: u64,
}

impl Stats {
    pub fn merge(&mut self, o: &Stats) {
        self.r_e += o.r_e;
        self.r_e2 += o.r_e2;
        self.r_s += o.r_s;
        self.nfe += o.nfe;
        self.naccept += o.naccept;
        self.nreject += o.nreject;
    }

    /// Total step attempts across the whole solve (accepted + rejected).
    ///
    /// Note that under [`super::driver::StepBudget::PerSegment`] the
    /// budget applies to each save segment independently, so
    /// `attempts()` over a T-point grid may legitimately exceed the
    /// per-segment budget (up to `(T-1) ×` it); this accessor surfaces
    /// the true total so callers can account for it.
    pub fn attempts(&self) -> u64 {
        self.naccept + self.nreject
    }
}

/// Final state + statistics of one successful integration.  Failures
/// return [`SolveError`] instead (same fields plus the failure kind), so
/// "the solve succeeded" is simply the `Ok` arm of [`SolveResult`].
#[derive(Clone, Debug)]
pub struct SolveOutcome {
    pub z: Vec<f64>,
    pub t: f64,
    pub stats: Stats,
}

/// Internal stepping state threaded across segments of one [`drive`].
///
/// All scratch lives in `arena` (see the module docs for the layout); the
/// accept/reject loop performs zero heap allocation.
struct Stepper<'a, 'o, S: System> {
    sys: &'a mut S,
    tab: &'a Tableau,
    opts: &'a SolveOptions,
    h: f64,
    q_prev: f64,
    stats: Stats,
    /// Contiguous scratch: `[ks (stages × n) | zi | znew | err | g_x | g_y]`.
    /// `ks` row 0 is the FSAL stage (f at the current `(t, z)`).
    arena: Vec<f64>,
    /// Optional discrete-adjoint tape: every *accepted* step records
    /// `(t, h, z_start, stages)` before the state is committed.  `None`
    /// leaves the stepper bit-identical to the untaped solver.
    tape: Option<&'a mut OdeTape>,
    /// Built-in observers behind [`Stats::r_e`] / `r_e2` / `r_s` — same
    /// additions in the same order as the seed's hard-wired fields.
    re: ErrorIntegral,
    re2: ErrorSquared,
    rs: StiffnessSum,
    /// Caller-provided observers, offered every accepted step.
    observers: &'a mut [&'o mut dyn StepObserver],
}

impl<'a, 'o, S: System> Stepper<'a, 'o, S> {
    fn new(
        sys: &'a mut S,
        opts: &'a SolveOptions,
        z0: &[f64],
        t0: f64,
        span: f64,
        observers: &'a mut [&'o mut dyn StepObserver],
    ) -> Self {
        let n = z0.len();
        let tab = &opts.tableau;
        let s = tab.stages();
        let mut arena = vec![0.0; (s + 5) * n];
        // FSAL seed: ks row 0 = f(z0, t0).
        sys.drift(z0, t0, &mut arena[..n]);
        let h0 = opts
            .dt0
            .unwrap_or_else(|| 0.01 * span / rms(&arena[..n]).max(1.0));
        Self {
            sys,
            tab,
            opts,
            h: h0,
            q_prev: 1.0,
            stats: Stats {
                nfe: 1,
                ..Default::default()
            },
            arena,
            tape: None,
            re: ErrorIntegral::new(),
            re2: ErrorSquared::new(),
            rs: StiffnessSum::new(),
            observers,
        }
    }

    /// Integrate from (t, z) to t1 in place.
    ///
    /// A zero-length span is a successful no-op; a negative or non-finite
    /// span is a [`SolveErrorKind::BadSpan`] (explicit RK with h > 0
    /// cannot go backwards in time).  Failures are detected at
    /// step-attempt granularity: a non-finite proposed state or embedded
    /// error is [`SolveErrorKind::NonFiniteState`] (never committed), a
    /// rejection that drives the step below [`EPS`] is
    /// [`SolveErrorKind::StepSizeUnderflow`], and running out of
    /// `budget` is [`SolveErrorKind::BudgetExhausted`].  The success
    /// path is bit-identical to the seed loop — every check is a pure
    /// read inserted where the seed would have ground on futilely.
    // analyze: hot-path
    fn advance(
        &mut self,
        z: &mut [f64],
        t: &mut f64,
        t1: f64,
        budget: u64,
    ) -> Result<(), SolveErrorKind> {
        let tol = 1e-12 * t1.abs().max(1.0);
        if !t1.is_finite() || t1 < *t - tol {
            return Err(SolveErrorKind::BadSpan);
        }
        let s = self.tab.stages();
        let n = z.len();
        // One borrow split per segment — no per-attempt bookkeeping.
        let (ks, rest) = self.arena.split_at_mut(s * n);
        let (zi, rest) = rest.split_at_mut(n);
        let (znew, rest) = rest.split_at_mut(n);
        let (err, rest) = rest.split_at_mut(n);
        let (g_x, g_y) = rest.split_at_mut(n);
        let (sx, sy) = self.tab.stiff_pair;

        let mut attempts = 0;
        while *t < t1 - tol {
            if attempts >= budget {
                return Err(SolveErrorKind::BudgetExhausted);
            }
            attempts += 1;
            let h = self.h.min(t1 - *t).max(EPS);

            // Stage cascade (row 0 = k1 via FSAL, valid from init/accept).
            if sx == 0 {
                g_x.copy_from_slice(z);
            }
            for i in 1..s {
                zi.copy_from_slice(z);
                for (j, &aij) in self.tab.a[i].iter().enumerate() {
                    if aij != 0.0 {
                        let kj = &ks[j * n..(j + 1) * n];
                        for d in 0..n {
                            zi[d] += h * aij * kj[d];
                        }
                    }
                }
                if i == sx {
                    g_x.copy_from_slice(zi);
                }
                if i == sy {
                    g_y.copy_from_slice(zi);
                }
                let ti = *t + self.tab.c[i] * h;
                let (_, ki) = ks.split_at_mut(i * n);
                self.sys.drift(zi, ti, &mut ki[..n]);
            }
            self.stats.nfe += self.tab.nfe_per_attempt() as u64;

            // Combination + embedded error (paper Eq. 3), fused into one
            // pass over the stage arena (`models::kernels::rk_combine`,
            // the rk_combine.py port): dims are chunked into vector
            // lanes with stages as the inner loop, so each dim still
            // accumulates in tableau stage order — bit-identical to the
            // seed's two-pass loop (tests/solver_equivalence.rs).
            kernels::rk_combine(ks, s, n, &self.tab.b, &self.tab.btilde, z, h, znew, err);

            // A non-finite proposed state or embedded error can never be
            // accepted (q goes NaN/inf) — without this check the seed
            // ground at an unchanged step size until the budget died.
            // Pure read: the success-path FP sequence is untouched.
            if !znew.iter().all(|v| v.is_finite()) || !err.iter().all(|v| v.is_finite()) {
                return Err(SolveErrorKind::NonFiniteState);
            }

            let q = error_ratio(err, z, znew, self.opts.rtol, self.opts.atol);
            let e_norm = rms(err);

            if q <= 1.0 {
                // Shampine stiffness ratio (paper Eq. 8) via scalar
                // accumulators — same FP sequence as rms(dnum)/rms(dden),
                // epsilon convention owned by `controller::stiffness_ratio`
                // and shared with the adjoint/replay paths.
                let mut num = 0.0;
                let mut den = 0.0;
                for d in 0..n {
                    let dk = ks[sy * n + d] - ks[sx * n + d];
                    let dg = g_y[d] - g_x[d];
                    num += dk * dk;
                    den += dg * dg;
                }
                let stiff = stiffness_ratio(num, den, n);

                // White-box surface: built-in accumulators first (the
                // Stats contract), then every plugged-in observer.
                {
                    let view = StepView {
                        index: self.stats.naccept,
                        t: *t,
                        h,
                        error: e_norm,
                        stiffness: stiff,
                        nfe: self.stats.nfe,
                        nreject: self.stats.nreject,
                        z: znew,
                        err,
                    };
                    self.re.on_accept(&view);
                    self.re2.on_accept(&view);
                    self.rs.on_accept(&view);
                    for obs in self.observers.iter_mut() {
                        obs.on_accept(&view);
                    }
                }
                self.stats.naccept += 1;
                if let Some(tape) = self.tape.as_deref_mut() {
                    tape.push_step(*t, h, z, ks);
                }
                *t += h;
                z.copy_from_slice(znew);
                // FSAL: last stage is f at the accepted point.
                ks.copy_within((s - 1) * n..s * n, 0);
                self.h = h * pi_factor(q, self.q_prev, self.tab.order);
                self.q_prev = q.max(1e-4);
            } else {
                self.stats.nreject += 1;
                self.h = h * reject_factor(q, self.tab.order);
                // The controller wants a step below the EPS floor: even
                // the floor step failed tolerance, so further attempts
                // only grind (the seed clamped to EPS and re-rejected
                // until the budget died).
                if self.h < EPS {
                    return Err(SolveErrorKind::StepSizeUnderflow);
                }
            }
        }
        Ok(())
    }

    /// Final statistics: counters plus the built-in observer values.
    fn finish(&self) -> Stats {
        let mut stats = self.stats;
        stats.r_e = self.re.value();
        stats.r_e2 = self.re2.value();
        stats.r_s = self.rs.value();
        stats
    }
}

/// The single generic ODE driver loop: integrate `sys` over `saveat`
/// under `opts`, optionally recording a discrete-adjoint `tape` and
/// offering every accepted step to `observers`.
///
/// Returns the saved states (one per save point; [`Saveat::Span`] saves
/// `z0` and the endpoint) and `Result<SolveOutcome, SolveError>`.
/// Budget semantics follow [`SolveOptions::budget`]; exhaustion stops
/// the solve with [`SolveErrorKind::BudgetExhausted`].  The solve is
/// fail-fast: the first failed segment ends the integration (no later
/// segment is attempted) and the remaining save points repeat the last
/// committed state, so output shapes stay grid-sized and the tape still
/// carries one save mark per grid point.  When a tape is passed it is
/// reset and records every accepted step plus a save mark per grid
/// point (including the start), ready for
/// [`super::adjoint::ode_backward`].
pub fn drive<S: System>(
    sys: &mut S,
    z0: &[f64],
    saveat: Saveat<'_>,
    opts: &SolveOptions,
    mut tape: Option<&mut OdeTape>,
    observers: &mut [&mut dyn StepObserver],
) -> (Vec<Vec<f64>>, SolveResult) {
    crate::span!("solve", "ode");
    // Reset the tape up front: even a cleanly-failed solve must not
    // leave a previous solve's records behind (the Taping contract).
    if let Some(tape) = tape.as_deref_mut() {
        tape.reset(z0.len(), opts.tableau.stages());
    }
    let mut span_store = [0.0; 2];
    let ts: &[f64] = match super::driver::resolve_saveat(saveat, &mut span_store, z0) {
        Ok(ts) => ts,
        Err(fail) => return fail,
    };

    let mut stepper = Stepper::new(sys, opts, z0, ts[0], ts[ts.len() - 1] - ts[0], observers);
    stepper.tape = tape;

    let mut z = z0.to_vec();
    let mut t = ts[0];
    let mut out = Vec::with_capacity(ts.len());
    out.push(z.clone());
    if let Some(tp) = stepper.tape.as_deref_mut() {
        tp.mark_save();
    }
    let mut failure = None;
    for &t_hi in &ts[1..] {
        if failure.is_none() {
            let budget = opts.budget.for_segment(stepper.stats.attempts());
            if let Err(kind) = stepper.advance(&mut z, &mut t, t_hi, budget) {
                failure = Some(kind);
            }
        }
        out.push(z.clone());
        if let Some(tp) = stepper.tape.as_deref_mut() {
            tp.mark_save();
        }
    }
    let stats = stepper.finish();
    let result = match failure {
        None => Ok(SolveOutcome { z, t, stats }),
        Some(kind) => Err(SolveError { kind, t, z, stats }),
    };
    (out, result)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solvers::driver::StepBudget;
    use crate::solvers::system::OdeSystem;

    fn exp_decay(z: &[f64], _t: f64, dz: &mut [f64]) {
        for i in 0..z.len() {
            dz[i] = -z[i];
        }
    }

    /// Test shorthand: drive one span solve and return the result.
    fn solve<F: FnMut(&[f64], f64, &mut [f64])>(
        f: F,
        z0: &[f64],
        t0: f64,
        t1: f64,
        opts: &SolveOptions,
    ) -> SolveResult {
        let mut sys = OdeSystem(f);
        drive(&mut sys, z0, Saveat::Span { t0, t1 }, opts, None, &mut []).1
    }

    /// Test shorthand: drive one grid solve.
    fn solve_grid<F: FnMut(&[f64], f64, &mut [f64])>(
        f: F,
        z0: &[f64],
        ts: &[f64],
        opts: &SolveOptions,
    ) -> (Vec<Vec<f64>>, SolveResult) {
        let mut sys = OdeSystem(f);
        drive(&mut sys, z0, Saveat::Grid(ts), opts, None, &mut [])
    }

    fn tol_opts(tol: f64) -> SolveOptions {
        SolveOptions::new().with_tolerance(tol)
    }

    #[test]
    fn exponential_decay_accuracy() {
        let opts = tol_opts(1e-8);
        let out = solve(exp_decay, &[1.0, 2.0], 0.0, 1.0, &opts).unwrap();
        assert!((out.z[0] - (-1.0f64).exp()).abs() < 1e-7, "{}", out.z[0]);
        assert!((out.z[1] - 2.0 * (-1.0f64).exp()).abs() < 1e-7);
    }

    #[test]
    fn fifth_order_convergence() {
        // Tighter tolerance should reduce error superlinearly.
        let errs: Vec<f64> = [1e-4, 1e-6, 1e-8]
            .iter()
            .map(|&tol| {
                let out = solve(exp_decay, &[1.0], 0.0, 1.0, &tol_opts(tol)).unwrap();
                (out.z[0] - (-1.0f64).exp()).abs()
            })
            .collect();
        assert!(errs[0] > errs[1] && errs[1] >= errs[2], "{errs:?}");
    }

    #[test]
    fn harmonic_oscillator_energy() {
        // z'' = -z  as first-order system; energy must be conserved ~1e-6.
        let f = |z: &[f64], _t: f64, dz: &mut [f64]| {
            dz[0] = z[1];
            dz[1] = -z[0];
        };
        let opts = tol_opts(1e-9);
        let out = solve(f, &[1.0, 0.0], 0.0, 10.0, &opts).unwrap();
        let energy = out.z[0] * out.z[0] + out.z[1] * out.z[1];
        assert!((energy - 1.0).abs() < 1e-6, "energy={energy}");
    }

    #[test]
    fn nfe_grows_with_tighter_tol() {
        let nfe: Vec<u64> = [1e-3, 1e-6, 1e-9]
            .iter()
            .map(|&tol| {
                solve(exp_decay, &[1.0], 0.0, 1.0, &tol_opts(tol)).unwrap().stats.nfe
            })
            .collect();
        assert!(nfe[0] < nfe[1] && nfe[1] < nfe[2], "{nfe:?}");
    }

    #[test]
    fn stiffness_estimate_tracks_lambda() {
        for lambda in [10.0, 100.0] {
            let f = |z: &[f64], _t: f64, dz: &mut [f64]| {
                dz[0] = -lambda * z[0];
            };
            let opts = tol_opts(1e-7);
            let out = solve(f, &[1.0], 0.0, 1.0, &opts).unwrap();
            let s_per_step = out.stats.r_s / out.stats.naccept as f64;
            assert!(
                (s_per_step - lambda).abs() / lambda < 0.2,
                "lambda={lambda} est={s_per_step}"
            );
        }
    }

    #[test]
    fn saveat_grid_matches_analytic() {
        let ts: Vec<f64> = (0..11).map(|i| i as f64 * 0.1).collect();
        let opts = tol_opts(1e-8);
        let (zs, out) = solve_grid(exp_decay, &[1.0], &ts, &opts);
        assert!(out.is_ok());
        for (i, z) in zs.iter().enumerate() {
            assert!((z[0] - (-ts[i]).exp()).abs() < 1e-6);
        }
    }

    #[test]
    fn budget_exhaustion_reports_failure() {
        let opts = tol_opts(1e-12).with_budget(StepBudget::PerSegment(3));
        let err = solve(exp_decay, &[1.0], 0.0, 1.0, &opts).unwrap_err();
        assert_eq!(err.kind, SolveErrorKind::BudgetExhausted);
        assert!(err.stats.attempts() <= 3);
        assert!(err.z.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn nan_drift_is_a_typed_error_not_a_grind() {
        // A vector field that goes NaN mid-solve must surface as
        // NonFiniteState on the attempt that proposed it — not grind at
        // an unchanged step size until the budget dies.
        let f = |z: &[f64], t: f64, dz: &mut [f64]| {
            dz[0] = if t > 0.5 { f64::NAN } else { -z[0] };
        };
        let err = solve(f, &[1.0], 0.0, 1.0, &tol_opts(1e-6)).unwrap_err();
        assert_eq!(err.kind, SolveErrorKind::NonFiniteState);
        // The failure is cheap: a handful of attempts, not the 100k budget.
        assert!(err.stats.attempts() < 100, "{:?}", err.stats);
        // The last committed state is still finite.
        assert!(err.z[0].is_finite());
        assert!(err.t <= 1.0 && err.t >= 0.0);
    }

    #[test]
    fn exploding_error_is_step_size_underflow() {
        // Huge but finite dynamics whose embedded error can never meet
        // tolerance: the controller shrinks h to the EPS floor and the
        // solve dies as StepSizeUnderflow instead of rejecting forever.
        let f = |_z: &[f64], _t: f64, dz: &mut [f64]| {
            dz[0] = 1e300;
        };
        let err = solve(f, &[1.0], 0.0, 1.0, &tol_opts(1e-9)).unwrap_err();
        assert!(
            matches!(
                err.kind,
                SolveErrorKind::StepSizeUnderflow | SolveErrorKind::NonFiniteState
            ),
            "{:?}",
            err.kind
        );
        assert!(err.stats.attempts() < 1000, "typed failure must be cheap");
    }

    #[test]
    fn dopri5_and_tsit5_agree() {
        let mk = |tab: Tableau| tol_opts(1e-9).with_tableau(tab);
        let a = solve(exp_decay, &[1.0], 0.0, 1.0, &mk(Tableau::tsit5())).unwrap();
        let b = solve(exp_decay, &[1.0], 0.0, 1.0, &mk(Tableau::dopri5())).unwrap();
        assert!((a.z[0] - b.z[0]).abs() < 1e-8);
    }

    #[test]
    fn rejections_occur_on_abrupt_dynamics() {
        // A sharp transition forces step rejections.
        let f = |z: &[f64], t: f64, dz: &mut [f64]| {
            dz[0] = if t < 0.5 { -z[0] } else { -50.0 * z[0] };
        };
        let opts = tol_opts(1e-8);
        let out = solve(f, &[1.0], 0.0, 1.0, &opts).unwrap();
        assert!(out.stats.nreject > 0, "{:?}", out.stats);
    }

    #[test]
    fn zero_and_negative_spans_fail_cleanly() {
        let opts = SolveOptions::default();
        for t1 in [0.0, -1.0, f64::NAN] {
            let err = solve(exp_decay, &[1.0], 0.0, t1, &opts).unwrap_err();
            assert_eq!(err.kind, SolveErrorKind::BadSpan, "t1={t1}");
            assert_eq!(err.z, vec![1.0], "state must be untouched");
            assert_eq!(err.stats.nfe, 0, "no dynamics evaluation");
        }
    }

    #[test]
    fn saveat_rejects_decreasing_grid() {
        let (zs, out) =
            solve_grid(exp_decay, &[1.0], &[0.0, 0.5, 0.4], &SolveOptions::default());
        let err = out.unwrap_err();
        assert_eq!(err.kind, SolveErrorKind::BadSpan);
        assert_eq!(err.stats.nfe, 0, "no dynamics evaluation");
        assert_eq!(zs, vec![vec![1.0]], "only z0 saved");
    }

    #[test]
    fn taped_solve_is_bit_identical_to_untaped() {
        use crate::solvers::adjoint::OdeTape;
        let ts: Vec<f64> = (0..8).map(|i| i as f64 * 0.2).collect();
        let opts = tol_opts(1e-7);
        let (zs, out) = solve_grid(exp_decay, &[1.0, 0.5], &ts, &opts);
        let out = out.unwrap();
        let mut tape = OdeTape::new();
        let mut sys = OdeSystem(exp_decay);
        let (zs_t, out_t) = drive(
            &mut sys,
            &[1.0, 0.5],
            Saveat::Grid(&ts),
            &opts.clone().with_budget(StepBudget::Total(u64::MAX)),
            Some(&mut tape),
            &mut [],
        );
        let out_t = out_t.unwrap();
        assert_eq!(zs, zs_t, "tape recording must not perturb the solve");
        assert_eq!(out.stats.nfe, out_t.stats.nfe);
        assert_eq!(out.stats.naccept, out_t.stats.naccept);
        assert_eq!(tape.len() as u64, out.stats.naccept);
        assert_eq!(tape.save_marks().len(), ts.len());
        assert_eq!(*tape.save_marks().last().unwrap(), tape.len());
    }

    #[test]
    fn taped_solve_respects_total_budget() {
        use crate::solvers::adjoint::OdeTape;
        let ts: Vec<f64> = (0..11).map(|i| i as f64 * 0.1).collect();
        let opts = tol_opts(1e-9);
        let mut tape = OdeTape::new();
        let mut sys = OdeSystem(exp_decay);
        let (zs, out) = drive(
            &mut sys,
            &[1.0],
            Saveat::Grid(&ts),
            &opts.with_budget(StepBudget::Total(3)),
            Some(&mut tape),
            &mut [],
        );
        let err = out.unwrap_err();
        assert_eq!(
            err.kind,
            SolveErrorKind::BudgetExhausted,
            "3 attempts cannot cover 10 segments"
        );
        assert!(err.stats.attempts() <= 3);
        assert_eq!(zs.len(), ts.len(), "outputs stay grid-shaped");
        assert_eq!(tape.save_marks().len(), ts.len(), "one mark per grid point");
    }

    #[test]
    fn attempts_counts_all_step_attempts() {
        let f = |z: &[f64], t: f64, dz: &mut [f64]| {
            dz[0] = if t < 0.5 { -z[0] } else { -50.0 * z[0] };
        };
        let opts = tol_opts(1e-8);
        let out = solve(f, &[1.0], 0.0, 1.0, &opts).unwrap();
        assert_eq!(out.stats.attempts(), out.stats.naccept + out.stats.nreject);
        assert!(out.stats.attempts() > out.stats.naccept);
        // NFE bookkeeping: 1 init + nfe_per_attempt per attempt (FSAL Tsit5).
        assert_eq!(out.stats.nfe, 1 + 6 * out.stats.attempts());
    }

    #[test]
    fn drive_step_views_carry_the_tape_index() {
        // A custom observer sees exactly naccept views, indexed 0..naccept
        // in order, with positive step sizes and the accepted-step error.
        struct Probe {
            seen: Vec<(u64, f64)>,
        }
        impl StepObserver for Probe {
            fn on_accept(&mut self, v: &StepView<'_>) {
                self.seen.push((v.index, v.error * v.h.abs()));
            }
            fn value(&self) -> f64 {
                self.seen.iter().map(|&(_, e)| e).sum()
            }
            fn reset(&mut self) {
                self.seen.clear();
            }
        }
        let mut probe = Probe { seen: Vec::new() };
        let mut sys = OdeSystem(exp_decay);
        let ts = [0.0, 0.5, 1.0];
        let (_, out) = drive(
            &mut sys,
            &[1.0, 2.0],
            Saveat::Grid(&ts),
            &SolveOptions::new().with_tolerance(1e-7),
            None,
            &mut [&mut probe],
        );
        let out = out.unwrap();
        assert_eq!(probe.seen.len() as u64, out.stats.naccept);
        for (i, &(idx, _)) in probe.seen.iter().enumerate() {
            assert_eq!(idx, i as u64, "views arrive in accepted-step order");
        }
        // Summing the per-step R_E terms in order reproduces Stats::r_e.
        assert_eq!(probe.value(), out.stats.r_e);
    }
}
