//! Discrete adjoint sensitivities through the adaptive solvers.
//!
//! This is the paper's core trick made native: because the solver
//! white-boxes its internal heuristics, **both** regularizers — the local
//! error estimate `R_E = Σ E_j |h_j|` (Eq. 9) and the Shampine stiffness
//! ratio `R_S = Σ S_j` (Eq. 8/11) — are explicit functions of quantities
//! the forward solve already computes, and their gradients — like the
//! data loss's — can be obtained by a *discrete* adjoint walk back
//! through the **accepted** steps.  No continuous adjoint ODE, no
//! Kelly-et-al higher-order AD: one vector-Jacobian product per stage per
//! accepted step.
//!
//! The ODE stiffness term on step `j` is
//! `S_j = ‖k_sy − k_sx‖ / (‖g_y − g_x‖ + ε)` on the tableau's equal-`c`
//! stage pair; the stage states `g_x`/`g_y` are reconstructed from the
//! record (`g_i = z + h Σ_j a_ij k_j`), so its VJP needs no extra tape
//! storage.  Because `S_j` depends on `z` only through `g_y − g_x`, the
//! direct `∂g/∂z = I` contributions cancel and the pull-back lands
//! entirely on the recorded stage values.  The SDE surrogate
//! `S_j = ‖f_2 − f_1‖ / (‖z_em − z‖ + ε)` is differentiated through the
//! recomputed Heun internals.  The epsilon convention is owned by
//! [`super::controller::stiffness_ratio`] and shared with the forward
//! steppers so forward/backward FP sequences stay bit-identical.
//!
//! The step sequence `(t_j, h_j)` (and, for SDEs, the Brownian increments
//! `ΔW_j`) is treated as fixed — the standard discrete-adjoint convention,
//! matching how the lowered JAX artifacts differentiate the masked scan.
//! [`ode_replay`] / [`sde_replay`] re-run exactly that frozen discrete
//! program (returning the replayed `R_E` *and* `R_S`), which is what the
//! finite-difference gradient checks in `tests/adjoint_gradcheck.rs`
//! compare against.
//!
//! The unified-API entry points [`ode_backward_sys`] /
//! [`sde_backward_sys`] take the dynamics as a [`System`] (its VJP
//! hooks) and the regularizer weights as [`RegCoefs`], which besides the
//! global `coef_e`/`coef_s` sums supports the **sampled-step local**
//! error term of LRNODE/LRNSDE (`RegCoefs::local_e`): the step sampled
//! by [`super::observer::LocalReg`] during the forward solve gets an
//! extra error-cotangent weight, and nothing else changes —
//! [`ode_replay_errors`] / [`sde_replay_errors`] expose the per-step
//! terms so `tests/lrnode_gradcheck.rs` can finite-difference exactly
//! that objective.
//!
//! ## Tape memory layout (DESIGN.md §Backend)
//!
//! The ODE tape stores one record per **accepted** step (rejected attempts
//! leave no trace — they do not influence the final state):
//!
//! ```text
//! data: [accepted_steps × (stages + 1) × n]
//!        record j = [ z_start (n) | k_0 (n) | ... | k_{s-1} (n) ]
//! steps: [(t_j, h_j)]          save_marks: tape length at each save point
//! ```
//!
//! The SDE tape is `[accepted_steps × 2 × n]` (`z_start | ΔW`).  Records
//! are appended with amortized growth (or into pre-reserved capacity via
//! `with_capacity`); the accept/reject loop itself stays allocation-free
//! beyond that tape append (proven in `tests/alloc_free.rs`).

#![allow(clippy::too_many_arguments)]

use super::controller::{rms, stiffness_norm, stiffness_ratio, EPS, RMS_FLOOR};
use super::system::{OdeSystemVjp, SdeSystemVjp, System};
use super::tableau::Tableau;

/// Accumulating vector-Jacobian product of a dynamics function:
/// `vjp(z, t, w, gz, gparams)` must add `wᵀ ∂f/∂z` into `gz` and
/// `wᵀ ∂f/∂θ` into `gparams` (both `+=`, never overwrite).
pub trait VjpFn: FnMut(&[f64], f64, &[f64], &mut [f64], &mut [f64]) {}
impl<T: FnMut(&[f64], f64, &[f64], &mut [f64], &mut [f64])> VjpFn for T {}

/// Regularizer coefficients of one backward walk.
///
/// `coef_e`/`coef_s` weight the **global** sums `R_E = Σ_j E_j |h_j|`
/// and `R_S = Σ_j S_j` exactly as the legacy scalar arguments did.
/// `local_e` additionally weights the error term of **one** step — the
/// locally regularized objective (LRNODE/LRNSDE, Pal et al. 2023) whose
/// step is sampled by [`super::observer::LocalReg`] during the forward
/// solve.  The effective per-step error coefficient is
/// `coef_e + local_e.1` on the sampled step and `coef_e` elsewhere, so a
/// `None` keeps the walk bit-identical to the legacy path.
#[derive(Clone, Copy, Debug, Default)]
pub struct RegCoefs {
    /// Global `R_E` coefficient (0 disables).
    pub coef_e: f64,
    /// Global `R_S` coefficient (0 disables).
    pub coef_s: f64,
    /// Sampled-step local error regularization: `(step index, coefficient)`.
    pub local_e: Option<(usize, f64)>,
}

impl RegCoefs {
    /// The legacy global-sum objective `coef_e · R_E + coef_s · R_S`.
    pub fn global(coef_e: f64, coef_s: f64) -> RegCoefs {
        RegCoefs {
            coef_e,
            coef_s,
            local_e: None,
        }
    }

    /// Add a sampled-step local error term `coef · E_step |h_step|`.
    pub fn with_local(mut self, step: usize, coef: f64) -> RegCoefs {
        self.local_e = Some((step, coef));
        self
    }

    /// Effective error-term coefficient at recorded step `j`.
    #[inline]
    fn e_at(&self, j: usize) -> f64 {
        match self.local_e {
            Some((step, coef)) if step == j => self.coef_e + coef,
            _ => self.coef_e,
        }
    }
}

/// Recorded forward pass of an adaptive explicit-RK solve.
#[derive(Clone, Debug, Default)]
pub struct OdeTape {
    n: usize,
    stages: usize,
    data: Vec<f64>,
    steps: Vec<(f64, f64)>,
    save_marks: Vec<usize>,
}

impl OdeTape {
    pub fn new() -> OdeTape {
        OdeTape::default()
    }

    /// Pre-reserve room for `cap_steps` accepted steps of an `n`-dim solve
    /// so recording does not reallocate (see `tests/alloc_free.rs`).
    pub fn with_capacity(n: usize, stages: usize, cap_steps: usize) -> OdeTape {
        OdeTape {
            n,
            stages,
            data: Vec::with_capacity(cap_steps * (stages + 1) * n),
            steps: Vec::with_capacity(cap_steps),
            save_marks: Vec::with_capacity(64),
        }
    }

    /// Clear the tape and (re)bind its record shape, keeping allocations.
    pub fn reset(&mut self, n: usize, stages: usize) {
        self.n = n;
        self.stages = stages;
        self.data.clear();
        self.steps.clear();
        self.save_marks.clear();
    }

    /// Number of recorded (accepted) steps.
    pub fn len(&self) -> usize {
        self.steps.len()
    }

    pub fn is_empty(&self) -> bool {
        self.steps.is_empty()
    }

    pub fn n(&self) -> usize {
        self.n
    }

    pub fn stages(&self) -> usize {
        self.stages
    }

    pub fn save_marks(&self) -> &[usize] {
        &self.save_marks
    }

    /// `(t, h)` of recorded step `j`.
    pub fn step_meta(&self, j: usize) -> (f64, f64) {
        self.steps[j]
    }

    /// Record one accepted step (called by the stepper before it commits
    /// the step: `z` is the step's *start* state, `ks` the stage block).
    pub(super) fn push_step(&mut self, t: f64, h: f64, z: &[f64], ks: &[f64]) {
        debug_assert_eq!(z.len(), self.n);
        debug_assert_eq!(ks.len(), self.stages * self.n);
        self.data.extend_from_slice(z);
        self.data.extend_from_slice(ks);
        self.steps.push((t, h));
    }

    /// Mark the current tape position as a save point (called once per
    /// save time, including `t0` before any step).
    pub(super) fn mark_save(&mut self) {
        self.save_marks.push(self.steps.len());
    }

    fn record(&self, j: usize) -> (&[f64], &[f64]) {
        let w = (self.stages + 1) * self.n;
        let rec = &self.data[j * w..(j + 1) * w];
        rec.split_at(self.n)
    }
}

/// Walk the ODE tape backwards, accumulating `dL/dθ` into `grad_params`
/// and returning `dL/dz0`.
///
/// * `save_grads[i]` is the loss cotangent at save point `i` (same order
///   as the forward `ts` grid; `save_grads.len()` must equal the number
///   of recorded save marks).
/// * `coef_e` additionally differentiates `coef_e · R_E` with
///   `R_E = Σ_j E_j |h_j|` over the recorded steps (pass `0.0` to get the
///   plain data-loss adjoint).
/// * `coef_s` additionally differentiates `coef_s · R_S` with
///   `R_S = Σ_j S_j`, the Shampine stiffness ratio on the tableau's
///   equal-`c` stage pair (pass `0.0` to treat `R_S` as absent).
/// * `f_vjp` is the accumulating VJP of the dynamics (see [`VjpFn`]).
///
/// Legacy shim over [`ode_backward_sys`] with a closure-lifted
/// [`System`] and global [`RegCoefs`]; kept for one release.
pub fn ode_backward(
    tape: &OdeTape,
    tab: &Tableau,
    save_grads: &[Vec<f64>],
    coef_e: f64,
    coef_s: f64,
    grad_params: &mut [f64],
    f_vjp: impl FnMut(&[f64], f64, &[f64], &mut [f64], &mut [f64]),
) -> Vec<f64> {
    let mut sys = OdeSystemVjp {
        drift: |_z: &[f64], _t: f64, _dz: &mut [f64]| {},
        vjp: f_vjp,
    };
    ode_backward_sys(
        tape,
        tab,
        save_grads,
        &RegCoefs::global(coef_e, coef_s),
        grad_params,
        &mut sys,
    )
}

/// [`ode_backward`] over a [`System`] (its [`System::drift_vjp`] hook)
/// with full [`RegCoefs`] — the unified-API discrete adjoint, including
/// the sampled-step local error term (`RegCoefs::local_e`, the LRNODE
/// objective; gradchecked in `tests/lrnode_gradcheck.rs`).
pub fn ode_backward_sys<S: System>(
    tape: &OdeTape,
    tab: &Tableau,
    save_grads: &[Vec<f64>],
    reg: &RegCoefs,
    grad_params: &mut [f64],
    sys: &mut S,
) -> Vec<f64> {
    crate::span!("adjoint", "ode");
    let n = tape.n;
    let s = tape.stages;
    let marks = tape.save_marks();
    assert_eq!(
        save_grads.len(),
        marks.len(),
        "one loss cotangent per save point"
    );
    assert!(marks.first().is_none_or(|&m| m == 0), "tape must mark t0");

    let (sx, sy) = tab.stiff_pair;
    let mut lambda = vec![0.0; n];
    let mut w = vec![0.0; s * n];
    let mut wi = vec![0.0; n];
    let mut zi = vec![0.0; n];
    let mut gz = vec![0.0; n];
    let mut err = vec![0.0; n];
    let mut dl_err = vec![0.0; n];
    let mut g_x = vec![0.0; n];
    let mut g_y = vec![0.0; n];
    let mut dk = vec![0.0; n];
    let mut dg = vec![0.0; n];

    for si in (1..marks.len()).rev() {
        for d in 0..n {
            lambda[d] += save_grads[si][d];
        }
        for j in (marks[si - 1]..marks[si]).rev() {
            let (t, h) = tape.steps[j];
            let (z, ks) = tape.record(j);
            // Per-step error coefficient: the global coef_e plus, on the
            // sampled step, the local (LRNODE) coefficient.
            let ce = reg.e_at(j);
            let cs = reg.coef_s;

            // Recompute the embedded error of this step from the stages:
            // err = h Σ_i btilde_i k_i, E = rms(err); the R_E term
            // contributes dL/derr = ce · |h| · err / (n E).
            if ce != 0.0 {
                err.fill(0.0);
                for (i, &bt) in tab.btilde.iter().enumerate() {
                    if bt != 0.0 {
                        let ki = &ks[i * n..(i + 1) * n];
                        for d in 0..n {
                            err[d] += bt * ki[d];
                        }
                    }
                }
                for d in 0..n {
                    err[d] *= h;
                }
                let e = rms(&err);
                let scale = ce * h.abs() / (n as f64 * e);
                for d in 0..n {
                    dl_err[d] = scale * err[d];
                }
            }

            // Stage cotangents from znew = z + h Σ b_i k_i (and err).
            for i in 0..s {
                let (bi, bti) = (tab.b[i], tab.btilde[i]);
                for d in 0..n {
                    let mut acc = bi * lambda[d];
                    if ce != 0.0 {
                        acc += bti * dl_err[d];
                    }
                    w[i * n + d] = h * acc;
                }
            }

            // R_S term: S = ‖k_sy − k_sx‖ / (‖g_y − g_x‖ + EPS) with the
            // stage states reconstructed from the record exactly as the
            // forward built them (g_i = z + h Σ_j a_ij k_j).  With
            // N = stiffness_norm(Σ dk²), D₀ = stiffness_norm(Σ dg²) and
            // D = D₀ + EPS:
            //   ∂S/∂dk_d =  dk_d / (n N D)
            //   ∂S/∂dg_d = −N dg_d / (n D₀ D²)
            // The ∂g/∂z = I parts of g_y and g_x cancel (S sees only
            // their difference), so the pull-back lands on the stage
            // cotangents alone: directly on w[sx]/w[sy] through dk, and
            // on every earlier stage through dg with weight
            // h (a[sy][j] − a[sx][j]).
            if cs != 0.0 {
                for (g, stage) in [(&mut g_x, sx), (&mut g_y, sy)] {
                    g.copy_from_slice(z);
                    for (jj, &aij) in tab.a[stage].iter().enumerate() {
                        if aij != 0.0 {
                            let kj = &ks[jj * n..(jj + 1) * n];
                            for d in 0..n {
                                g[d] += h * aij * kj[d];
                            }
                        }
                    }
                }
                let mut num = 0.0;
                let mut den = 0.0;
                for d in 0..n {
                    dk[d] = ks[sy * n + d] - ks[sx * n + d];
                    dg[d] = g_y[d] - g_x[d];
                    num += dk[d] * dk[d];
                    den += dg[d] * dg[d];
                }
                let nn = stiffness_norm(num, n);
                let d0 = stiffness_norm(den, n);
                let dd = d0 + EPS;
                let c_num = cs / (n as f64 * nn * dd);
                let c_den = -cs * nn / (n as f64 * d0 * dd * dd);
                for d in 0..n {
                    let uk = c_num * dk[d];
                    w[sy * n + d] += uk;
                    w[sx * n + d] -= uk;
                }
                for (jj, &ay) in tab.a[sy].iter().enumerate() {
                    let ax = tab.a[sx].get(jj).copied().unwrap_or(0.0);
                    let coeff = h * (ay - ax);
                    if coeff != 0.0 {
                        for d in 0..n {
                            w[jj * n + d] += coeff * c_den * dg[d];
                        }
                    }
                }
            }

            // Reverse stage cascade.  `lambda` starts as the direct
            // dznew/dz = I term and accumulates each stage's pull-back.
            for i in (0..s).rev() {
                wi.copy_from_slice(&w[i * n..(i + 1) * n]);
                if wi.iter().all(|&x| x == 0.0) {
                    continue;
                }
                zi.copy_from_slice(z);
                for (jj, &aij) in tab.a[i].iter().enumerate() {
                    if aij != 0.0 {
                        let kj = &ks[jj * n..(jj + 1) * n];
                        for d in 0..n {
                            zi[d] += h * aij * kj[d];
                        }
                    }
                }
                gz.fill(0.0);
                sys.drift_vjp(&zi, t + tab.c[i] * h, &wi, &mut gz, grad_params);
                for d in 0..n {
                    lambda[d] += gz[d];
                }
                for (jj, &aij) in tab.a[i].iter().enumerate() {
                    if aij != 0.0 {
                        for d in 0..n {
                            w[jj * n + d] += h * aij * gz[d];
                        }
                    }
                }
            }
        }
    }
    for d in 0..n {
        lambda[d] += save_grads[0][d];
    }
    lambda
}

/// Re-run the exact discrete program an [`OdeTape`] recorded — same
/// `(t_j, h_j)` sequence, full stage cascade — under a (possibly
/// perturbed) dynamics `f`.  Returns the states at the save marks, the
/// replayed `R_E` and the replayed `R_S` (stiffness-pair stage states
/// captured exactly as the forward stepper captures them).  This is the
/// function the finite-difference gradient checks difference: the adjoint
/// differentiates precisely this program.
pub fn ode_replay(
    tape: &OdeTape,
    tab: &Tableau,
    z0: &[f64],
    f: impl FnMut(&[f64], f64, &mut [f64]),
) -> (Vec<Vec<f64>>, f64, f64) {
    let mut r_e = 0.0;
    let mut r_s = 0.0;
    let out = ode_replay_visit(tape, tab, z0, f, |_, e_term, s_term| {
        r_e += e_term;
        r_s += s_term;
    });
    (out, r_e, r_s)
}

/// Per-step error terms `E_j |h_j|` of the replayed frozen program —
/// the FD counterpart of the sampled-step (LRNODE) objective: entry `j`
/// is exactly the term [`RegCoefs::local_e`] weights at step `j` (and
/// summing the entries in order reproduces the replayed `R_E` bits).
pub fn ode_replay_errors(
    tape: &OdeTape,
    tab: &Tableau,
    z0: &[f64],
    f: impl FnMut(&[f64], f64, &mut [f64]),
) -> Vec<f64> {
    let mut errs = vec![0.0; tape.len()];
    ode_replay_visit(tape, tab, z0, f, |j, e_term, _| errs[j] = e_term);
    errs
}

/// Shared replay walk: re-runs the frozen program and hands each step's
/// `(j, E_j |h_j|, S_j)` to `on_step`, returning the save-mark states.
fn ode_replay_visit(
    tape: &OdeTape,
    tab: &Tableau,
    z0: &[f64],
    mut f: impl FnMut(&[f64], f64, &mut [f64]),
    mut on_step: impl FnMut(usize, f64, f64),
) -> Vec<Vec<f64>> {
    let n = tape.n;
    let s = tape.stages;
    let (sx, sy) = tab.stiff_pair;
    let mut z = z0.to_vec();
    let mut ks = vec![0.0; s * n];
    let mut zi = vec![0.0; n];
    let mut g_x = vec![0.0; n];
    let mut g_y = vec![0.0; n];
    let marks = tape.save_marks();
    let mut out = Vec::with_capacity(marks.len());
    out.push(z.clone());
    for si in 1..marks.len() {
        for j in marks[si - 1]..marks[si] {
            let (t, h) = tape.steps[j];
            for i in 0..s {
                zi.copy_from_slice(&z);
                for (jj, &aij) in tab.a[i].iter().enumerate() {
                    if aij != 0.0 {
                        for d in 0..n {
                            zi[d] += h * aij * ks[jj * n + d];
                        }
                    }
                }
                if i == sx {
                    g_x.copy_from_slice(&zi);
                }
                if i == sy {
                    g_y.copy_from_slice(&zi);
                }
                let ti = t + tab.c[i] * h;
                let (_, ki) = ks.split_at_mut(i * n);
                f(&zi, ti, &mut ki[..n]);
            }
            let mut err_sq = 0.0;
            for d in 0..n {
                let mut znew = 0.0;
                let mut e = 0.0;
                for i in 0..s {
                    znew += tab.b[i] * ks[i * n + d];
                    e += tab.btilde[i] * ks[i * n + d];
                }
                z[d] += h * znew;
                err_sq += (h * e) * (h * e);
            }
            let mut num = 0.0;
            let mut den = 0.0;
            for d in 0..n {
                let dk = ks[sy * n + d] - ks[sx * n + d];
                let dg = g_y[d] - g_x[d];
                num += dk * dk;
                den += dg * dg;
            }
            on_step(
                j,
                (err_sq / n as f64 + RMS_FLOOR).sqrt() * h.abs(),
                stiffness_ratio(num, den, n),
            );
        }
        out.push(z.clone());
    }
    out
}

/// Recorded forward pass of an adaptive stochastic-Heun SDE solve.
#[derive(Clone, Debug, Default)]
pub struct SdeTape {
    n: usize,
    /// `[accepted_steps × 2 × n]`: `z_start | ΔW` per record.
    data: Vec<f64>,
    steps: Vec<(f64, f64)>,
    save_marks: Vec<usize>,
}

impl SdeTape {
    pub fn new() -> SdeTape {
        SdeTape::default()
    }

    pub fn with_capacity(n: usize, cap_steps: usize) -> SdeTape {
        SdeTape {
            n,
            data: Vec::with_capacity(cap_steps * 2 * n),
            steps: Vec::with_capacity(cap_steps),
            save_marks: Vec::with_capacity(64),
        }
    }

    pub fn reset(&mut self, n: usize) {
        self.n = n;
        self.data.clear();
        self.steps.clear();
        self.save_marks.clear();
    }

    pub fn len(&self) -> usize {
        self.steps.len()
    }

    pub fn is_empty(&self) -> bool {
        self.steps.is_empty()
    }

    pub fn n(&self) -> usize {
        self.n
    }

    pub fn save_marks(&self) -> &[usize] {
        &self.save_marks
    }

    pub fn step_meta(&self, j: usize) -> (f64, f64) {
        self.steps[j]
    }

    pub(super) fn push_step(&mut self, t: f64, h: f64, z: &[f64], dw: &[f64]) {
        debug_assert_eq!(z.len(), self.n);
        debug_assert_eq!(dw.len(), self.n);
        self.data.extend_from_slice(z);
        self.data.extend_from_slice(dw);
        self.steps.push((t, h));
    }

    pub(super) fn mark_save(&mut self) {
        self.save_marks.push(self.steps.len());
    }

    fn record(&self, j: usize) -> (&[f64], &[f64]) {
        let rec = &self.data[j * 2 * self.n..(j + 1) * 2 * self.n];
        rec.split_at(self.n)
    }
}

/// Discrete adjoint through the accepted stochastic-Heun steps with the
/// recorded Brownian increments held fixed (pathwise sensitivities).
///
/// `drift`/`diffusion` re-evaluate the forward functions (the tape only
/// stores `z_start` and `ΔW`; stage values are cheap to recompute), while
/// `drift_vjp`/`diffusion_vjp` are their accumulating VJPs.  Both VJPs
/// accumulate into the same `grad_params` vector — the caller's closures
/// are responsible for writing to their own parameter sub-ranges.
///
/// `coef_e` differentiates `coef_e · R_E = coef_e · Σ E_j |h_j|`;
/// `coef_s` differentiates `coef_s · R_S` with the drift-based stiffness
/// surrogate `S_j = ‖f_2 − f_1‖ / (‖z_em − z‖ + EPS)` the forward stepper
/// accumulates.  Pass `0.0` to disable either term.
///
/// Legacy shim over [`sde_backward_sys`] with a closure-lifted
/// [`System`] and global [`RegCoefs`]; kept for one release.
pub fn sde_backward(
    tape: &SdeTape,
    save_grads: &[Vec<f64>],
    coef_e: f64,
    coef_s: f64,
    grad_params: &mut [f64],
    drift: impl FnMut(&[f64], f64, &mut [f64]),
    diffusion: impl FnMut(&[f64], f64, &mut [f64]),
    drift_vjp: impl FnMut(&[f64], f64, &[f64], &mut [f64], &mut [f64]),
    diffusion_vjp: impl FnMut(&[f64], f64, &[f64], &mut [f64], &mut [f64]),
) -> Vec<f64> {
    let mut sys = SdeSystemVjp {
        drift,
        diffusion,
        drift_vjp,
        diffusion_vjp,
    };
    sde_backward_sys(
        tape,
        save_grads,
        &RegCoefs::global(coef_e, coef_s),
        grad_params,
        &mut sys,
    )
}

/// [`sde_backward`] over a [`System`] (drift/diffusion re-evaluation +
/// both VJP hooks) with full [`RegCoefs`] — including the sampled-step
/// local error term (`RegCoefs::local_e`, the LRNSDE objective).
pub fn sde_backward_sys<S: System>(
    tape: &SdeTape,
    save_grads: &[Vec<f64>],
    reg: &RegCoefs,
    grad_params: &mut [f64],
    sys: &mut S,
) -> Vec<f64> {
    crate::span!("adjoint", "sde");
    let n = tape.n;
    let marks = tape.save_marks();
    assert_eq!(
        save_grads.len(),
        marks.len(),
        "one loss cotangent per save point"
    );
    assert!(marks.first().is_none_or(|&m| m == 0), "tape must mark t0");

    let mut lambda = vec![0.0; n];
    let mut f1 = vec![0.0; n];
    let mut g1 = vec![0.0; n];
    let mut f2 = vec![0.0; n];
    let mut g2 = vec![0.0; n];
    let mut zem = vec![0.0; n];
    let mut err = vec![0.0; n];
    let mut a_tot = vec![0.0; n];
    let mut lam_em = vec![0.0; n];
    let mut wbuf = vec![0.0; n];
    let mut lam_z = vec![0.0; n];
    let mut u_df = vec![0.0; n];
    let mut u_dz = vec![0.0; n];

    for si in (1..marks.len()).rev() {
        for d in 0..n {
            lambda[d] += save_grads[si][d];
        }
        for j in (marks[si - 1]..marks[si]).rev() {
            let (t, h) = tape.steps[j];
            let (z, dw) = tape.record(j);
            // Per-step error coefficient: the global coef_e plus, on the
            // sampled step, the local (LRNSDE) coefficient.
            let ce = reg.e_at(j);
            let cs = reg.coef_s;

            // Recompute the Heun pair's internals at this step.
            sys.drift(z, t, &mut f1);
            sys.diffusion(z, t, &mut g1);
            for d in 0..n {
                zem[d] = z[d] + h * f1[d] + g1[d] * dw[d];
            }
            sys.drift(&zem, t + h, &mut f2);
            sys.diffusion(&zem, t + h, &mut g2);
            // err = z_heun - z_em, with the forward stepper's expression
            // shape so the recomputed E matches the recorded one.
            for d in 0..n {
                let z_heun =
                    z[d] + 0.5 * h * (f1[d] + f2[d]) + 0.5 * dw[d] * (g1[d] + g2[d]);
                err[d] = z_heun - zem[d];
            }

            // a_tot = dL/dz_heun (data adjoint + R_E term), lam_em starts
            // from err's -dz_em dependence.
            if ce != 0.0 {
                let e = rms(&err);
                let scale = ce * h.abs() / (n as f64 * e);
                for d in 0..n {
                    let de = scale * err[d];
                    a_tot[d] = lambda[d] + de;
                    lam_em[d] = -de;
                }
            } else {
                a_tot.copy_from_slice(&lambda);
                lam_em.fill(0.0);
            }

            // R_S surrogate S = ‖f2 − f1‖ / (‖z_em − z‖ + EPS): with
            // N = stiffness_norm(Σ df²), D₀ = stiffness_norm(Σ dz²),
            // D = D₀ + EPS the cotangents are
            //   u_df_d = coef_s ·  df_d / (n N D)        (on f2 − f1)
            //   u_dz_d = coef_s · −N dz_d / (n D₀ D²)    (on z_em − z)
            // u_dz lands on z_em (+) / z (−); u_df lands on f2 (+) /
            // f1 (−).  The z_em share joins lam_em *before* the f2/g2
            // pull-backs so it flows through the whole Euler-Maruyama
            // sub-step like any other z_em cotangent.
            if cs != 0.0 {
                let mut num = 0.0;
                let mut den = 0.0;
                for d in 0..n {
                    let df = f2[d] - f1[d];
                    let dz = zem[d] - z[d];
                    num += df * df;
                    den += dz * dz;
                }
                let nn = stiffness_norm(num, n);
                let d0 = stiffness_norm(den, n);
                let dd = d0 + EPS;
                let c_num = cs / (n as f64 * nn * dd);
                let c_den = -cs * nn / (n as f64 * d0 * dd * dd);
                for d in 0..n {
                    u_df[d] = c_num * (f2[d] - f1[d]);
                    u_dz[d] = c_den * (zem[d] - z[d]);
                    lam_em[d] += u_dz[d];
                }
            } else {
                u_df.fill(0.0);
                u_dz.fill(0.0);
            }

            // z_heun = z + h/2 (f1 + f2) + dw/2 ∘ (g1 + g2): pull back
            // through f2/g2 (evaluated at z_em) into lam_em.  f2 also
            // carries the R_S numerator cotangent +u_df.
            for d in 0..n {
                wbuf[d] = 0.5 * h * a_tot[d] + u_df[d];
            }
            sys.drift_vjp(&zem, t + h, &wbuf, &mut lam_em, grad_params);
            for d in 0..n {
                wbuf[d] = 0.5 * dw[d] * a_tot[d];
            }
            sys.diffusion_vjp(&zem, t + h, &wbuf, &mut lam_em, grad_params);

            // z_em = z + h f1 + g1 ∘ dw: direct z terms plus f1/g1 (which
            // also receive the z_heun-side cotangents).  f1 carries the
            // R_S numerator cotangent −u_df; z carries −u_dz from the
            // denominator's z_em − z difference.
            for d in 0..n {
                lam_z[d] = a_tot[d] + lam_em[d] - u_dz[d];
            }
            for d in 0..n {
                wbuf[d] = 0.5 * h * a_tot[d] + h * lam_em[d] - u_df[d];
            }
            sys.drift_vjp(z, t, &wbuf, &mut lam_z, grad_params);
            for d in 0..n {
                wbuf[d] = 0.5 * dw[d] * a_tot[d] + dw[d] * lam_em[d];
            }
            sys.diffusion_vjp(z, t, &wbuf, &mut lam_z, grad_params);
            lambda.copy_from_slice(&lam_z);
        }
    }
    for d in 0..n {
        lambda[d] += save_grads[0][d];
    }
    lambda
}

/// Re-run the frozen discrete SDE program (same `(t, h, ΔW)` records)
/// under perturbed drift/diffusion.  Returns save states, replayed `R_E`
/// and replayed `R_S` — the FD counterpart of [`sde_backward`].
pub fn sde_replay(
    tape: &SdeTape,
    z0: &[f64],
    drift: impl FnMut(&[f64], f64, &mut [f64]),
    diffusion: impl FnMut(&[f64], f64, &mut [f64]),
) -> (Vec<Vec<f64>>, f64, f64) {
    let mut r_e = 0.0;
    let mut r_s = 0.0;
    let out = sde_replay_visit(tape, z0, drift, diffusion, |_, e_term, s_term| {
        r_e += e_term;
        r_s += s_term;
    });
    (out, r_e, r_s)
}

/// Per-step error terms `E_j |h_j|` of the replayed frozen SDE program —
/// the FD counterpart of the sampled-step (LRNSDE) objective (see
/// [`ode_replay_errors`]).
pub fn sde_replay_errors(
    tape: &SdeTape,
    z0: &[f64],
    drift: impl FnMut(&[f64], f64, &mut [f64]),
    diffusion: impl FnMut(&[f64], f64, &mut [f64]),
) -> Vec<f64> {
    let mut errs = vec![0.0; tape.len()];
    sde_replay_visit(tape, z0, drift, diffusion, |j, e_term, _| errs[j] = e_term);
    errs
}

/// Shared SDE replay walk: hands each step's `(j, E_j |h_j|, S_j)` to
/// `on_step`, returning the save-mark states.
fn sde_replay_visit(
    tape: &SdeTape,
    z0: &[f64],
    mut drift: impl FnMut(&[f64], f64, &mut [f64]),
    mut diffusion: impl FnMut(&[f64], f64, &mut [f64]),
    mut on_step: impl FnMut(usize, f64, f64),
) -> Vec<Vec<f64>> {
    let n = tape.n;
    let mut z = z0.to_vec();
    let mut f1 = vec![0.0; n];
    let mut g1 = vec![0.0; n];
    let mut f2 = vec![0.0; n];
    let mut g2 = vec![0.0; n];
    let mut zem = vec![0.0; n];
    let marks = tape.save_marks();
    let mut out = Vec::with_capacity(marks.len());
    out.push(z.clone());
    for si in 1..marks.len() {
        for j in marks[si - 1]..marks[si] {
            let (t, h) = tape.steps[j];
            let (_, dw) = tape.record(j);
            drift(&z, t, &mut f1);
            diffusion(&z, t, &mut g1);
            for d in 0..n {
                zem[d] = z[d] + h * f1[d] + g1[d] * dw[d];
            }
            drift(&zem, t + h, &mut f2);
            diffusion(&zem, t + h, &mut g2);
            // Stiffness surrogate before z is overwritten (same scalar
            // accumulators and FP sequence as the forward stepper).
            let mut num = 0.0;
            let mut den = 0.0;
            for d in 0..n {
                let df = f2[d] - f1[d];
                let dz = zem[d] - z[d];
                num += df * df;
                den += dz * dz;
            }
            // Same expression shapes as the forward stepper so the
            // replayed bits match the taped solve at the base point.
            let mut err_sq = 0.0;
            for d in 0..n {
                let z_heun =
                    z[d] + 0.5 * h * (f1[d] + f2[d]) + 0.5 * dw[d] * (g1[d] + g2[d]);
                let e = z_heun - zem[d];
                err_sq += e * e;
                z[d] = z_heun;
            }
            on_step(
                j,
                (err_sq / n as f64 + RMS_FLOOR).sqrt() * h.abs(),
                stiffness_ratio(num, den, n),
            );
        }
        out.push(z.clone());
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solvers::driver::{Saveat, SolveOptions, StepBudget};
    use crate::solvers::ode::{self, SolveOutcome};
    use crate::solvers::system::OdeSystem;

    /// Test shorthand: taped grid solve through the unified driver with
    /// a total attempt budget (the training contract the old taped
    /// entry point used).
    fn solve_taped<F: FnMut(&[f64], f64, &mut [f64])>(
        f: F,
        z0: &[f64],
        ts: &[f64],
        opts: &SolveOptions,
        total_budget: u64,
        tape: &mut OdeTape,
    ) -> (Vec<Vec<f64>>, SolveOutcome) {
        let mut sys = OdeSystem(f);
        let opts = opts.clone().with_budget(StepBudget::Total(total_budget));
        let (zs, out) = ode::drive(&mut sys, z0, Saveat::Grid(ts), &opts, Some(tape), &mut []);
        (zs, out.expect("taped test solve failed"))
    }

    /// Scalar linear ODE dz/dt = θ z with one parameter: the discrete
    /// adjoint must match central finite differences of the replayed
    /// program to near machine precision.
    #[test]
    fn linear_ode_param_gradient_matches_fd() {
        let theta = -0.7f64;
        let ts = [0.0, 0.4, 1.0];
        let opts = SolveOptions::new().with_tolerance(1e-8);
        let mut tape = OdeTape::new();
        let f = |th: f64| move |z: &[f64], _t: f64, dz: &mut [f64]| dz[0] = th * z[0];
        let (zs, _out) = solve_taped(f(theta), &[1.0], &ts, &opts, 100_000, &mut tape);

        // L = z(t2): cotangent 1 at the last save point.
        let save_grads = vec![vec![0.0], vec![0.0], vec![1.0]];
        let mut gp = vec![0.0; 1];
        let lam0 = ode_backward(
            &tape,
            &opts.tableau,
            &save_grads,
            0.0,
            0.0,
            &mut gp,
            |z, _t, w, gz, gth| {
                gz[0] += w[0] * theta;
                gth[0] += w[0] * z[0];
            },
        );

        let eps = 1e-6;
        let loss = |th: f64| {
            let (s, _, _) = ode_replay(&tape, &opts.tableau, &[1.0], f(th));
            s[2][0]
        };
        let fd = (loss(theta + eps) - loss(theta - eps)) / (2.0 * eps);
        assert!(
            (gp[0] - fd).abs() / fd.abs().max(1e-12) < 1e-6,
            "adjoint {} vs fd {fd}",
            gp[0]
        );
        // dz(t)/dz0 = e^{θt}
        assert!(
            (lam0[0] - (theta * 1.0f64).exp()).abs() < 1e-5,
            "lam0 {}",
            lam0[0]
        );
        // replay reproduces the taped forward trajectory (up to the
        // FSAL-stage rounding difference — see tests/adjoint_gradcheck.rs)
        let (rs, _, _) = ode_replay(&tape, &opts.tableau, &[1.0], f(theta));
        for (a, b) in rs.iter().zip(&zs) {
            assert!((a[0] - b[0]).abs() < 1e-10);
        }
    }

    /// R_E-only gradient (coef_e = 1, zero data cotangents) vs FD.
    #[test]
    fn regularizer_gradient_matches_fd() {
        let theta = 1.3f64;
        let ts = [0.0, 1.0];
        let opts = SolveOptions::new().with_tolerance(1e-6);
        // Nonlinear dynamics so R_E actually depends on θ nontrivially.
        let f = |th: f64| move |z: &[f64], _t: f64, dz: &mut [f64]| {
            dz[0] = (th * z[0]).sin();
        };
        let mut tape = OdeTape::new();
        let (_, _out) = solve_taped(f(theta), &[0.8], &ts, &opts, 100_000, &mut tape);
        assert!(!tape.is_empty());

        let save_grads = vec![vec![0.0], vec![0.0]];
        let mut gp = vec![0.0; 1];
        ode_backward(
            &tape,
            &opts.tableau,
            &save_grads,
            1.0,
            0.0,
            &mut gp,
            |z, _t, w, gz, gth| {
                let c = (theta * z[0]).cos();
                gz[0] += w[0] * theta * c;
                gth[0] += w[0] * z[0] * c;
            },
        );
        // R_E is O(rtol), so central differences need a wide stencil to
        // stay above FP noise: eps = 1e-4 puts the FD noise floor around
        // 1e-12 while truncation stays ~eps² · R ≈ 1e-14.
        let eps = 1e-4;
        let re = |th: f64| ode_replay(&tape, &opts.tableau, &[0.8], f(th)).1;
        let fd = (re(theta + eps) - re(theta - eps)) / (2.0 * eps);
        assert!(
            (gp[0] - fd).abs() / fd.abs().max(1e-12) < 1e-4,
            "adjoint {} vs fd {fd}",
            gp[0]
        );
    }

    /// R_S-only gradient (coef_s = 1, zero data cotangents, coef_e = 0)
    /// vs FD of the replayed stiffness accumulator.
    #[test]
    fn stiffness_gradient_matches_fd() {
        let theta = 1.3f64;
        let ts = [0.0, 1.0];
        let opts = SolveOptions::new().with_tolerance(1e-6);
        // Nonlinear dynamics so R_S depends on θ nontrivially.
        let f = |th: f64| move |z: &[f64], _t: f64, dz: &mut [f64]| {
            dz[0] = (th * z[0]).sin();
        };
        let mut tape = OdeTape::new();
        let (_, out) = solve_taped(f(theta), &[0.8], &ts, &opts, 100_000, &mut tape);
        assert!(!tape.is_empty());

        // Replay at the base point reproduces the forward accumulator
        // (FSAL-stage rounding only).
        let (_, _, rs0) = ode_replay(&tape, &opts.tableau, &[0.8], f(theta));
        assert!(
            (rs0 - out.stats.r_s).abs() <= 1e-9 * out.stats.r_s.max(1e-9),
            "replayed R_S {rs0} vs forward {}",
            out.stats.r_s
        );

        let save_grads = vec![vec![0.0], vec![0.0]];
        let mut gp = vec![0.0; 1];
        ode_backward(
            &tape,
            &opts.tableau,
            &save_grads,
            0.0,
            1.0,
            &mut gp,
            |z, _t, w, gz, gth| {
                let c = (theta * z[0]).cos();
                gz[0] += w[0] * theta * c;
                gth[0] += w[0] * z[0] * c;
            },
        );
        let eps = 1e-5;
        let rs = |th: f64| ode_replay(&tape, &opts.tableau, &[0.8], f(th)).2;
        let fd = (rs(theta + eps) - rs(theta - eps)) / (2.0 * eps);
        assert!(
            fd.abs() > 1e-8,
            "R_S must actually depend on θ for this check to bite (fd={fd})"
        );
        assert!(
            (gp[0] - fd).abs() / fd.abs().max(1e-12) < 1e-4,
            "adjoint {} vs fd {fd}",
            gp[0]
        );
    }

    /// Hand-built tape with a *negative* step: `R_E = Σ E_j |h_j|` must
    /// stay nonnegative in replay, and the backward R_E scale must use
    /// |h| so the adjoint still matches FD of the replayed program.
    #[test]
    fn reversed_time_step_keeps_r_e_nonnegative() {
        let tab = Tableau::tsit5();
        let s = tab.stages();
        let theta = 0.9f64;
        let f = |th: f64| move |z: &[f64], _t: f64, dz: &mut [f64]| {
            dz[0] = (th * z[0]).sin();
        };

        let mut tape = OdeTape::with_capacity(1, s, 2);
        tape.reset(1, s);
        tape.mark_save();
        // Replay/backward never read the recorded stage values of a step
        // they recompute, so zeros suffice for the ks block here.
        tape.push_step(0.0, -0.25, &[1.0], &vec![0.0; s]);
        tape.mark_save();

        let (_, r_e, _) = ode_replay(&tape, &tab, &[1.0], f(theta));
        assert!(r_e >= 0.0, "R_E must be nonnegative on reversed steps: {r_e}");
        assert!(r_e > 0.0, "nontrivial dynamics must accumulate error");

        let save_grads = vec![vec![0.0], vec![0.0]];
        let mut gp = vec![0.0; 1];
        // The adjoint reconstructs the error from the *recorded* stage
        // block, which the hand-built tape fills with zeros — rebuild the
        // record from a replayed stage cascade first so the backward sees
        // the stages the replay actually produces.
        let mut real_ks = vec![0.0; s];
        {
            let mut dyn_f = f(theta);
            let mut zi = [0.0f64; 1];
            for i in 0..s {
                zi[0] = 1.0;
                for (jj, &aij) in tab.a[i].iter().enumerate() {
                    zi[0] += -0.25 * aij * real_ks[jj];
                }
                let mut ki = [0.0f64; 1];
                dyn_f(&zi, tab.c[i] * -0.25, &mut ki);
                real_ks[i] = ki[0];
            }
        }
        let mut tape2 = OdeTape::with_capacity(1, s, 2);
        tape2.reset(1, s);
        tape2.mark_save();
        tape2.push_step(0.0, -0.25, &[1.0], &real_ks);
        tape2.mark_save();

        ode_backward(
            &tape2,
            &tab,
            &save_grads,
            1.0,
            0.0,
            &mut gp,
            |z, _t, w, gz, gth| {
                let c = (theta * z[0]).cos();
                gz[0] += w[0] * theta * c;
                gth[0] += w[0] * z[0] * c;
            },
        );
        let eps = 1e-5;
        let re = |th: f64| ode_replay(&tape2, &tab, &[1.0], f(th)).1;
        let fd = (re(theta + eps) - re(theta - eps)) / (2.0 * eps);
        assert!(
            (gp[0] - fd).abs() / fd.abs().max(1e-12) < 1e-4,
            "reversed-step adjoint {} vs fd {fd}",
            gp[0]
        );
    }

    /// SDE mirror of the reversed-time regression: replayed R_E stays
    /// nonnegative and the backward |h| scale matches FD.
    #[test]
    fn sde_reversed_time_step_keeps_r_e_nonnegative() {
        use crate::solvers::sde;
        use crate::solvers::system::SdeSystem;
        let theta = 0.8f64;
        let sigma = 0.3f64;
        let drift = |th: f64| move |z: &[f64], _t: f64, dz: &mut [f64]| {
            dz[0] = (th * z[0]).sin();
        };
        let diffusion = move |_z: &[f64], _t: f64, dg: &mut [f64]| dg[0] = sigma;

        let mut tape = SdeTape::with_capacity(1, 2);
        tape.reset(1);
        tape.mark_save();
        tape.push_step(0.0, -0.3, &[1.0], &[0.2]);
        tape.mark_save();

        let (_, r_e, _) = sde_replay(&tape, &[1.0], drift(theta), diffusion);
        assert!(r_e >= 0.0, "SDE R_E must be nonnegative on reversed steps: {r_e}");
        assert!(r_e > 0.0, "nontrivial Heun pair must accumulate error");

        let save_grads = vec![vec![0.0], vec![0.0]];
        let mut gp = vec![0.0; 1];
        sde_backward(
            &tape,
            &save_grads,
            1.0,
            0.0,
            &mut gp,
            drift(theta),
            diffusion,
            |z, _t, w, gz, gth| {
                let c = (theta * z[0]).cos();
                gz[0] += w[0] * theta * c;
                gth[0] += w[0] * z[0] * c;
            },
            |_z, _t, _w, _gz, _gp| {},
        );
        let eps = 1e-5;
        let re = |th: f64| sde_replay(&tape, &[1.0], drift(th), diffusion).1;
        let fd = (re(theta + eps) - re(theta - eps)) / (2.0 * eps);
        assert!(
            (gp[0] - fd).abs() / fd.abs().max(1e-12) < 1e-4,
            "reversed-step SDE adjoint {} vs fd {fd}",
            gp[0]
        );

        // Forward solves only march forward, so also pin the normal-time
        // accumulators against each other: taped solve vs replay bits.
        let mut rng = crate::util::rng::Rng::new(3);
        let mut fwd_tape = SdeTape::new();
        let opts = SolveOptions::new()
            .with_tolerance(1e-2)
            .with_budget(StepBudget::Total(u64::MAX));
        let mut sys = SdeSystem {
            drift: drift(theta),
            diffusion,
        };
        let (_, fwd_out) = sde::drive(
            &mut sys,
            &[1.0],
            Saveat::Grid(&[0.0, 0.5, 1.0]),
            &mut rng,
            &opts,
            Some(&mut fwd_tape),
            &mut [],
        );
        let stats = fwd_out.expect("forward SDE solve failed").stats;
        let (_, re_fwd, rs_fwd) = sde_replay(&fwd_tape, &[1.0], drift(theta), diffusion);
        assert!((re_fwd - stats.r_e).abs() <= 1e-12 * (1.0 + stats.r_e));
        assert!((rs_fwd - stats.r_s).abs() <= 1e-12 * (1.0 + stats.r_s));
    }
}
