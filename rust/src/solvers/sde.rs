//! Native adaptive SDE stack (diagonal noise): one generic driver loop
//! ([`drive`]) behind the unified white-box API ([`super::driver`]) —
//! the Rust mirror of python/compile/sde_solver.py.
//!
//! The same adaptive stochastic Heun 1.0/0.5 embedded pair with
//! Brownian-bridge rejection handling (RSwM-lite, DESIGN.md §4).  Used to
//! generate the ground-truth spiral DSDE ensembles (paper Eq. 15) that the
//! Neural SDE experiments fit, and as the reference for SDE solver tests.
//!
//! The driver integrates a diffusive [`System`] over a [`Saveat`] spec
//! under a [`SolveOptions`] budget, with optional [`SdeTape`] recording
//! and pluggable [`StepObserver`]s; the white-boxed [`Stats`]
//! accumulators come from the same built-in observers as the ODE stack.
//! (The closure-based legacy shims of the pre-unification release are
//! gone — every caller drives this loop through [`drive`] or the
//! unified [`super::driver::solve`].)
//!
//! Controller constants and the Hairer error norm are shared with the ODE
//! solver via [`super::controller`] (the embedded pair is order 1, so the
//! PI exponent is `1 - 0.75 * beta`).  All solver scratch — the four
//! drift/diffusion evaluations, the Euler-Maruyama and Heun states, the
//! embedded error, the Brownian increment and the RSwM pending increment —
//! is preallocated in `SdeStepper::new`; the accept/reject loop performs
//! zero heap allocation (DESIGN.md §Perf).

use super::adjoint::SdeTape;
use super::controller::{error_ratio, pi_factor, reject_factor, rms, stiffness_ratio, EPS};
use super::driver::{Saveat, SolveOptions};
use super::error::{SolveError, SolveErrorKind, SolveResult};
use super::observer::{ErrorIntegral, ErrorSquared, StepObserver, StepView, StiffnessSum};
use super::ode::{SolveOutcome, Stats};
use super::system::System;
use crate::util::rng::Rng;

/// Embedded-pair order of the stochastic Heun scheme (controller exponent).
const ORDER: usize = 1;

/// Allocation-free stepping state for one SDE trajectory.
///
/// Scratch layout mirrors the ODE stepper: one contiguous arena holding
/// `[f1 | g1 | f2 | g2 | z_em | z_heun | err | dw | w_pend]` (9 × n).
struct SdeStepper<'a, 'o, S: System> {
    sys: &'a mut S,
    opts: &'a SolveOptions,
    h: f64,
    q_prev: f64,
    /// RSwM-lite pending Brownian interval length.
    h_pend: f64,
    stats: Stats,
    arena: Vec<f64>,
    /// Optional discrete-adjoint tape: accepted steps record
    /// `(t, h, z_start, ΔW)`.  `None` keeps the stepper bit-identical.
    tape: Option<&'a mut SdeTape>,
    /// Built-in observers behind [`Stats::r_e`] / `r_e2` / `r_s`.
    re: ErrorIntegral,
    re2: ErrorSquared,
    rs: StiffnessSum,
    observers: &'a mut [&'o mut dyn StepObserver],
}

impl<'a, 'o, S: System> SdeStepper<'a, 'o, S> {
    fn new(
        sys: &'a mut S,
        n: usize,
        span: f64,
        opts: &'a SolveOptions,
        observers: &'a mut [&'o mut dyn StepObserver],
    ) -> Self {
        Self {
            sys,
            opts,
            h: opts.dt0.unwrap_or(0.01 * span),
            q_prev: 1.0,
            h_pend: 0.0,
            stats: Stats::default(),
            arena: vec![0.0; 9 * n],
            tape: None,
            re: ErrorIntegral::new(),
            re2: ErrorSquared::new(),
            rs: StiffnessSum::new(),
            observers,
        }
    }

    /// Integrate from (t, z) to t_hi in place.  `budget` bounds the step
    /// attempts of *this* call.  Failure detection mirrors the ODE
    /// stepper: non-finite proposed states, post-rejection step-size
    /// underflow and budget exhaustion each return their typed
    /// [`SolveErrorKind`]; the success path is bit-identical to the seed.
    // analyze: hot-path
    fn advance(
        &mut self,
        z: &mut [f64],
        t: &mut f64,
        t_hi: f64,
        rng: &mut Rng,
        budget: u64,
    ) -> Result<(), SolveErrorKind> {
        let n = z.len();
        let tol = 1e-12 * t_hi.abs().max(1.0);
        if !t_hi.is_finite() || t_hi < *t - tol {
            return Err(SolveErrorKind::BadSpan);
        }
        let (f1, rest) = self.arena.split_at_mut(n);
        let (g1, rest) = rest.split_at_mut(n);
        let (f2, rest) = rest.split_at_mut(n);
        let (g2, rest) = rest.split_at_mut(n);
        let (z_em, rest) = rest.split_at_mut(n);
        let (z_heun, rest) = rest.split_at_mut(n);
        let (err, rest) = rest.split_at_mut(n);
        let (dw, w_pend) = rest.split_at_mut(n);

        let mut attempts = 0u64;
        while *t < t_hi - tol {
            if attempts >= budget {
                return Err(SolveErrorKind::BudgetExhausted);
            }
            attempts += 1;
            let h_eff = self.h.min(t_hi - *t).max(EPS);

            // Brownian increment: bridge into or extend the pending one.
            if h_eff < self.h_pend {
                let frac = h_eff / self.h_pend;
                let var = (h_eff * (self.h_pend - h_eff) / self.h_pend).max(0.0);
                for d in 0..n {
                    dw[d] = frac * w_pend[d] + var.sqrt() * rng.normal();
                }
            } else {
                let extra = (h_eff - self.h_pend).max(0.0);
                for d in 0..n {
                    dw[d] = w_pend[d] + extra.sqrt() * rng.normal();
                }
            }

            // Heun pair (python sde_solver._heun_attempt).
            self.sys.drift(z, *t, f1);
            self.sys.diffusion(z, *t, g1);
            for d in 0..n {
                z_em[d] = z[d] + h_eff * f1[d] + g1[d] * dw[d];
            }
            self.sys.drift(z_em, *t + h_eff, f2);
            self.sys.diffusion(z_em, *t + h_eff, g2);
            for d in 0..n {
                z_heun[d] =
                    z[d] + 0.5 * h_eff * (f1[d] + f2[d]) + 0.5 * dw[d] * (g1[d] + g2[d]);
                err[d] = z_heun[d] - z_em[d];
            }
            self.stats.nfe += 4;

            // A non-finite proposed state or embedded error can never be
            // accepted (q goes NaN/inf) — typed failure instead of
            // grinding until the budget dies.  Pure read: the
            // success-path FP sequence is untouched.
            if !z_heun.iter().all(|v| v.is_finite()) || !err.iter().all(|v| v.is_finite()) {
                return Err(SolveErrorKind::NonFiniteState);
            }

            let q = error_ratio(err, z, z_heun, self.opts.rtol, self.opts.atol);
            if q <= 1.0 {
                let e_norm = rms(err);
                // Drift-based stiffness surrogate via scalar accumulators
                // (same FP sequence as rms(f2-f1)/rms(z_em-z)), epsilon
                // convention owned by `controller::stiffness_ratio` and
                // shared with the adjoint/replay paths.
                let mut num = 0.0;
                let mut den = 0.0;
                for d in 0..n {
                    let df = f2[d] - f1[d];
                    let dz = z_em[d] - z[d];
                    num += df * df;
                    den += dz * dz;
                }
                let stiff = stiffness_ratio(num, den, n);

                // White-box surface: `R_E = Σ E_j |h_j|` (Eq. 9) on |h|,
                // unified with the ODE stack (h_eff > 0 here, so the
                // abs() in ErrorIntegral is bit-free insurance).
                {
                    let view = StepView {
                        index: self.stats.naccept,
                        t: *t,
                        h: h_eff,
                        error: e_norm,
                        stiffness: stiff,
                        nfe: self.stats.nfe,
                        nreject: self.stats.nreject,
                        z: z_heun,
                        err,
                    };
                    self.re.on_accept(&view);
                    self.re2.on_accept(&view);
                    self.rs.on_accept(&view);
                    for obs in self.observers.iter_mut() {
                        obs.on_accept(&view);
                    }
                }
                self.stats.naccept += 1;
                if let Some(tape) = self.tape.as_deref_mut() {
                    tape.push_step(*t, h_eff, z, dw);
                }
                *t += h_eff;
                z.copy_from_slice(z_heun);
                self.h = h_eff * pi_factor(q, self.q_prev, ORDER);
                self.q_prev = q.max(1e-4);
                // RSwM: the unused tail of the pending increment stays
                // pending (discarding it would truncate the dW distribution
                // — acceptance is conditioned on |dW|, so dropped tails bias
                // every moment of the solution).
                if h_eff < self.h_pend {
                    self.h_pend -= h_eff;
                    for d in 0..n {
                        w_pend[d] -= dw[d];
                    }
                } else {
                    self.h_pend = 0.0;
                    w_pend.fill(0.0);
                }
            } else {
                self.stats.nreject += 1;
                // RSwM: keep the *whole* pending increment; the retry at
                // smaller h re-bridges into the same total.  If this attempt
                // extended past the pending interval, the extension becomes
                // the new pending total.
                if h_eff >= self.h_pend {
                    self.h_pend = h_eff;
                    w_pend.copy_from_slice(dw);
                }
                self.h = h_eff * reject_factor(q, ORDER);
                // The controller wants a step below the EPS floor: even
                // the floor step failed tolerance (the seed clamped to
                // EPS and re-rejected until the budget died).
                if self.h < EPS {
                    return Err(SolveErrorKind::StepSizeUnderflow);
                }
            }
        }
        Ok(())
    }

    /// Final statistics: counters plus the built-in observer values.
    fn finish(&self) -> Stats {
        let mut stats = self.stats;
        stats.r_e = self.re.value();
        stats.r_e2 = self.re2.value();
        stats.r_s = self.rs.value();
        stats
    }
}

/// The single generic SDE driver loop: integrate a diffusive `sys` over
/// `saveat` under `opts`, driven by `rng`, optionally recording a
/// discrete-adjoint `tape` and offering every accepted step to
/// `observers`.
///
/// Seed semantics: each save segment starts exactly at its grid time
/// (not at the last accepted step's floating-point sum), so stage times
/// and Brownian bridging are ulp-identical to the seed.  The tableau in
/// `opts` is ignored — the stochastic Heun pair is fixed.
pub fn drive<S: System>(
    sys: &mut S,
    z0: &[f64],
    saveat: Saveat<'_>,
    rng: &mut Rng,
    opts: &SolveOptions,
    mut tape: Option<&mut SdeTape>,
    observers: &mut [&mut dyn StepObserver],
) -> (Vec<Vec<f64>>, SolveResult) {
    crate::span!("solve", "sde");
    let n = z0.len();
    // Reset the tape up front: even a cleanly-failed solve must not
    // leave a previous solve's records behind (the Taping contract).
    if let Some(tape) = tape.as_deref_mut() {
        tape.reset(n);
    }
    let mut span_store = [0.0; 2];
    let ts: &[f64] = match super::driver::resolve_saveat(saveat, &mut span_store, z0) {
        Ok(ts) => ts,
        Err(fail) => return fail,
    };

    let span = ts[ts.len() - 1] - ts[0];
    let mut stepper = SdeStepper::new(sys, n, span, opts, observers);
    stepper.tape = tape;

    let mut z = z0.to_vec();
    let mut failure = None;
    let mut t_final = ts[0];
    let mut out = Vec::with_capacity(ts.len());
    out.push(z.clone());
    if let Some(tp) = stepper.tape.as_deref_mut() {
        tp.mark_save();
    }
    // Fail-fast: the first failed segment ends the integration; the
    // remaining save points repeat the last committed state (outputs
    // stay grid-shaped, the tape keeps one save mark per grid point).
    for seg in 1..ts.len() {
        if failure.is_none() {
            // Seed semantics: each segment starts exactly at its grid time.
            let mut t = ts[seg - 1];
            let budget = opts.budget.for_segment(stepper.stats.attempts());
            if let Err(kind) = stepper.advance(&mut z, &mut t, ts[seg], rng, budget) {
                failure = Some(kind);
            }
            t_final = t;
        }
        out.push(z.clone());
        if let Some(tp) = stepper.tape.as_deref_mut() {
            tp.mark_save();
        }
    }
    let stats = stepper.finish();
    let result = match failure {
        None => Ok(SolveOutcome {
            z,
            t: t_final,
            stats,
        }),
        Some(kind) => Err(SolveError {
            kind,
            t: t_final,
            z,
            stats,
        }),
    };
    (out, result)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solvers::driver::StepBudget;
    use crate::solvers::system::SdeSystem;

    /// Test shorthand: drive one grid solve from plain closures.
    fn solve_grid<F, G>(
        drift: F,
        diffusion: G,
        z0: &[f64],
        ts: &[f64],
        rng: &mut Rng,
        opts: &SolveOptions,
    ) -> (Vec<Vec<f64>>, Stats, bool)
    where
        F: FnMut(&[f64], f64, &mut [f64]),
        G: FnMut(&[f64], f64, &mut [f64]),
    {
        let mut sys = SdeSystem { drift, diffusion };
        let (out, result) = drive(&mut sys, z0, Saveat::Grid(ts), rng, opts, None, &mut []);
        use crate::solvers::error::SolveResultExt;
        let ok = result.is_success();
        (out, result.stats(), ok)
    }

    fn tol_opts(tol: f64) -> SolveOptions {
        SolveOptions::new().with_tolerance(tol)
    }

    /// Ornstein-Uhlenbeck: dz = -z dt + sigma dW; stationary var sigma^2/2.
    #[test]
    // Statistical / many-trajectory: minutes under the Miri
    // interpreter for no extra UB coverage (DESIGN.md §Static
    // Analysis).
    #[cfg_attr(miri, ignore)]
    fn ou_moments() {
        let sigma = 0.5;
        let mut rng = Rng::new(123);
        let ts = [0.0, 5.0, 10.0];
        let n_traj = 2000;
        // Order-1 weak scheme: solve tightly so the h-bias of the
        // stationary variance ((1+O(h)) sigma^2/2) is below the MC noise.
        let opts = tol_opts(1e-3);
        let mut finals = Vec::with_capacity(n_traj);
        for _ in 0..n_traj {
            let (zs, _, ok) = solve_grid(
                |z, _t, dz| dz[0] = -z[0],
                |_z, _t, dg| dg[0] = sigma,
                &[0.0],
                &ts,
                &mut rng,
                &opts,
            );
            assert!(ok);
            finals.push(zs[2][0]);
        }
        let mean = finals.iter().sum::<f64>() / n_traj as f64;
        let var =
            finals.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n_traj as f64;
        assert!(mean.abs() < 0.05, "mean={mean}");
        let expect = sigma * sigma / 2.0;
        assert!((var - expect).abs() / expect < 0.15, "var={var} vs {expect}");
    }

    /// With zero diffusion the SDE solver must match the analytic ODE.
    #[test]
    fn deterministic_limit() {
        let mut rng = Rng::new(7);
        let ts = [0.0, 0.5, 1.0];
        let opts = tol_opts(1e-6);
        let (zs, _, ok) = solve_grid(
            |z, _t, dz| dz[0] = -z[0],
            |_z, _t, dg| dg[0] = 0.0,
            &[1.0],
            &ts,
            &mut rng,
            &opts,
        );
        assert!(ok);
        assert!((zs[2][0] - (-1.0f64).exp()).abs() < 1e-4, "{}", zs[2][0]);
    }

    /// Multiplicative noise (GBM).  The stochastic Heun scheme converges to
    /// the **Stratonovich** solution, for which E[z_t] = z0 exp((mu +
    /// sig^2/2) t).  Solved at tight tolerance to suppress weak-order bias.
    #[test]
    // Statistical / many-trajectory: minutes under the Miri
    // interpreter for no extra UB coverage (DESIGN.md §Static
    // Analysis).
    #[cfg_attr(miri, ignore)]
    fn gbm_stratonovich_mean() {
        let mu = 0.5f64;
        let sig = 0.3;
        let mut rng = Rng::new(99);
        let ts = [0.0, 1.0];
        let n_traj = 4000;
        let opts = tol_opts(1e-4);
        let mut sum = 0.0;
        for _ in 0..n_traj {
            let (zs, _, ok) = solve_grid(
                |z, _t, dz| dz[0] = mu * z[0],
                |z, _t, dg| dg[0] = sig * z[0],
                &[1.0],
                &ts,
                &mut rng,
                &opts,
            );
            assert!(ok);
            sum += zs[1][0];
        }
        let mean = sum / n_traj as f64;
        let expect = (mu + 0.5 * sig * sig).exp();
        assert!((mean - expect).abs() / expect < 0.05, "{mean} vs {expect}");
    }

    #[test]
    fn taped_solve_is_bit_identical_to_untaped() {
        let ts = [0.0, 0.3, 0.7, 1.0];
        let opts = tol_opts(1e-3);
        let drift = |z: &[f64], _t: f64, dz: &mut [f64]| dz[0] = -z[0];
        let diffusion = |_z: &[f64], _t: f64, dg: &mut [f64]| dg[0] = 0.3;
        let mut rng_a = Rng::new(11);
        let (zs, stats, ok) = solve_grid(drift, diffusion, &[1.0], &ts, &mut rng_a, &opts);
        let mut rng_b = Rng::new(11);
        let mut tape = SdeTape::new();
        let mut sys = SdeSystem { drift, diffusion };
        let (zs_t, out_t) = drive(
            &mut sys,
            &[1.0],
            Saveat::Grid(&ts),
            &mut rng_b,
            &opts.clone().with_budget(StepBudget::Total(u64::MAX)),
            Some(&mut tape),
            &mut [],
        );
        let out_t = out_t.unwrap();
        let stats_t = out_t.stats;
        assert!(ok);
        assert_eq!(zs, zs_t, "tape recording must not perturb the solve");
        assert_eq!(stats.nfe, stats_t.nfe);
        assert_eq!(tape.len() as u64, stats.naccept);
        assert_eq!(tape.save_marks().len(), ts.len());
    }

    #[test]
    fn nfe_counts_four_per_attempt() {
        let mut rng = Rng::new(1);
        let (_, stats, _) = solve_grid(
            |z, _t, dz| dz[0] = -z[0],
            |_z, _t, dg| dg[0] = 0.1,
            &[1.0],
            &[0.0, 1.0],
            &mut rng,
            &tol_opts(1e-2),
        );
        assert_eq!(stats.nfe, 4 * (stats.naccept + stats.nreject));
        assert_eq!(stats.attempts(), stats.naccept + stats.nreject);
    }

    #[test]
    fn rejects_decreasing_grid() {
        let mut rng = Rng::new(2);
        let mut sys = SdeSystem {
            drift: |z: &[f64], _t: f64, dz: &mut [f64]| dz[0] = -z[0],
            diffusion: |_z: &[f64], _t: f64, dg: &mut [f64]| dg[0] = 0.1,
        };
        let (zs, out) = drive(
            &mut sys,
            &[1.0],
            Saveat::Grid(&[0.0, 0.6, 0.5]),
            &mut rng,
            &tol_opts(1e-2),
            None,
            &mut [],
        );
        let err = out.unwrap_err();
        assert_eq!(err.kind, SolveErrorKind::BadSpan);
        assert_eq!(err.stats.nfe, 0, "no dynamics evaluation");
        assert_eq!(zs, vec![vec![1.0]], "only z0 saved");
    }

    #[test]
    fn nan_drift_is_a_typed_error() {
        // The drift goes NaN mid-solve: typed NonFiniteState on that
        // attempt, cheap, never a grind to budget exhaustion.
        let mut rng = Rng::new(3);
        let mut sys = SdeSystem {
            drift: |z: &[f64], t: f64, dz: &mut [f64]| {
                dz[0] = if t > 0.5 { f64::NAN } else { -z[0] };
            },
            diffusion: |_z: &[f64], _t: f64, dg: &mut [f64]| dg[0] = 0.2,
        };
        let (zs, out) = drive(
            &mut sys,
            &[1.0],
            Saveat::Grid(&[0.0, 1.0]),
            &mut rng,
            &tol_opts(1e-3),
            None,
            &mut [],
        );
        let err = out.unwrap_err();
        assert_eq!(err.kind, SolveErrorKind::NonFiniteState);
        assert!(err.stats.attempts() < 1000, "{:?}", err.stats);
        assert!(err.z[0].is_finite(), "last committed state stays finite");
        assert_eq!(zs.len(), 2, "outputs stay grid-shaped");
    }

    #[test]
    fn negative_and_nan_spans_fail_cleanly() {
        for t1 in [0.0, -1.0, f64::NAN] {
            let mut rng = Rng::new(4);
            let mut sys = SdeSystem {
                drift: |z: &[f64], _t: f64, dz: &mut [f64]| dz[0] = -z[0],
                diffusion: |_z: &[f64], _t: f64, dg: &mut [f64]| dg[0] = 0.1,
            };
            let (zs, out) = drive(
                &mut sys,
                &[1.0],
                Saveat::Span { t0: 0.0, t1 },
                &mut rng,
                &tol_opts(1e-2),
                None,
                &mut [],
            );
            let err = out.unwrap_err();
            assert_eq!(err.kind, SolveErrorKind::BadSpan, "t1={t1}");
            assert_eq!(err.z, vec![1.0], "state untouched");
            assert_eq!(err.stats.nfe, 0);
            assert_eq!(zs.len(), 1, "only z0 saved");
        }
    }
}
