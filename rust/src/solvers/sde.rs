//! Native adaptive SDE integrator (diagonal noise) — the Rust mirror of
//! python/compile/sde_solver.py.
//!
//! The same adaptive stochastic Heun 1.0/0.5 embedded pair with
//! Brownian-bridge rejection handling (RSwM-lite, DESIGN.md §4).  Used to
//! generate the ground-truth spiral DSDE ensembles (paper Eq. 15) that the
//! Neural SDE experiments fit, and as the reference for SDE solver tests.

use super::ode::Stats;
use crate::util::rng::Rng;

const SAFETY: f64 = 0.9;
const MIN_FACTOR: f64 = 0.2;
const MAX_FACTOR: f64 = 10.0;
const PI_BETA: f64 = 0.04;
const EPS: f64 = 1e-12;

#[derive(Clone, Debug)]
pub struct SdeOptions {
    pub rtol: f64,
    pub atol: f64,
    pub max_steps: u64,
    pub dt0: Option<f64>,
}

impl Default for SdeOptions {
    fn default() -> Self {
        Self {
            rtol: 1e-2,
            atol: 1e-2,
            max_steps: 1_000_000,
            dt0: None,
        }
    }
}

fn rms(v: &[f64]) -> f64 {
    (v.iter().map(|x| x * x).sum::<f64>() / v.len() as f64 + 1e-300).sqrt()
}

fn error_ratio(e: &[f64], z0: &[f64], z1: &[f64], rtol: f64, atol: f64) -> f64 {
    let mut acc = 0.0;
    for i in 0..e.len() {
        let scale = atol + z0[i].abs().max(z1[i].abs()) * rtol;
        let r = e[i] / scale;
        acc += r * r;
    }
    (acc / e.len() as f64 + 1e-300).sqrt()
}

/// Adaptive diagonal-noise SDE solve saving at each time in `ts`.
///
/// `drift(z, t, out)` / `diffusion(z, t, out)` write their values; noise is
/// driven by `rng`.  Returns (saved states, final stats, success).
pub fn sde_solve_saveat<F, G>(
    mut drift: F,
    mut diffusion: G,
    z0: &[f64],
    ts: &[f64],
    rng: &mut Rng,
    opts: &SdeOptions,
) -> (Vec<Vec<f64>>, Stats, bool)
where
    F: FnMut(&[f64], f64, &mut [f64]),
    G: FnMut(&[f64], f64, &mut [f64]),
{
    assert!(ts.len() >= 2);
    let n = z0.len();
    let mut z = z0.to_vec();
    let mut stats = Stats::default();
    let mut success = true;

    let mut h = opts.dt0.unwrap_or(0.01 * (ts[ts.len() - 1] - ts[0]));
    let mut q_prev: f64 = 1.0;
    // RSwM-lite pending increment.
    let mut h_pend = 0.0f64;
    let mut w_pend = vec![0.0; n];

    let mut f1 = vec![0.0; n];
    let mut g1 = vec![0.0; n];
    let mut f2 = vec![0.0; n];
    let mut g2 = vec![0.0; n];
    let mut z_em = vec![0.0; n];
    let mut z_heun = vec![0.0; n];
    let mut err = vec![0.0; n];
    let mut dw = vec![0.0; n];

    let mut out = Vec::with_capacity(ts.len());
    out.push(z.clone());

    for seg in 1..ts.len() {
        let t_hi = ts[seg];
        let mut t = ts[seg - 1];
        let mut attempts = 0u64;
        while t < t_hi - 1e-12 * t_hi.abs().max(1.0) {
            if attempts >= opts.max_steps {
                success = false;
                break;
            }
            attempts += 1;
            let h_eff = h.min(t_hi - t).max(EPS);

            // Brownian increment: bridge into or extend the pending one.
            if h_eff < h_pend {
                let frac = h_eff / h_pend;
                let var = (h_eff * (h_pend - h_eff) / h_pend).max(0.0);
                for d in 0..n {
                    dw[d] = frac * w_pend[d] + var.sqrt() * rng.normal();
                }
            } else {
                let extra = (h_eff - h_pend).max(0.0);
                for d in 0..n {
                    dw[d] = w_pend[d] + extra.sqrt() * rng.normal();
                }
            }

            // Heun pair (python sde_solver._heun_attempt).
            drift(&z, t, &mut f1);
            diffusion(&z, t, &mut g1);
            for d in 0..n {
                z_em[d] = z[d] + h_eff * f1[d] + g1[d] * dw[d];
            }
            drift(&z_em, t + h_eff, &mut f2);
            diffusion(&z_em, t + h_eff, &mut g2);
            for d in 0..n {
                z_heun[d] =
                    z[d] + 0.5 * h_eff * (f1[d] + f2[d]) + 0.5 * dw[d] * (g1[d] + g2[d]);
                err[d] = z_heun[d] - z_em[d];
            }
            stats.nfe += 4;

            let q = error_ratio(&err, &z, &z_heun, opts.rtol, opts.atol);
            if q <= 1.0 {
                let e_norm = rms(&err);
                let mut df = vec![0.0; n];
                let mut dz = vec![0.0; n];
                for d in 0..n {
                    df[d] = f2[d] - f1[d];
                    dz[d] = z_em[d] - z[d];
                }
                stats.r_e += e_norm * h_eff;
                stats.r_e2 += e_norm * e_norm;
                stats.r_s += rms(&df) / (rms(&dz) + EPS);
                stats.naccept += 1;
                t += h_eff;
                z.copy_from_slice(&z_heun);
                let alpha = 1.0 - 0.75 * PI_BETA;
                h = h_eff
                    * (SAFETY * q.max(1e-10).powf(-alpha) * q_prev.max(1e-10f64).powf(PI_BETA))
                        .clamp(MIN_FACTOR, MAX_FACTOR);
                q_prev = q.max(1e-4);
                // RSwM: the unused tail of the pending increment stays
                // pending (discarding it would truncate the dW distribution
                // — acceptance is conditioned on |dW|, so dropped tails bias
                // every moment of the solution).
                if h_eff < h_pend {
                    h_pend -= h_eff;
                    for d in 0..n {
                        w_pend[d] -= dw[d];
                    }
                } else {
                    h_pend = 0.0;
                    w_pend.iter_mut().for_each(|w| *w = 0.0);
                }
            } else {
                stats.nreject += 1;
                // RSwM: keep the *whole* pending increment; the retry at
                // smaller h re-bridges into the same total.  If this attempt
                // extended past the pending interval, the extension becomes
                // the new pending total.
                if h_eff >= h_pend {
                    h_pend = h_eff;
                    w_pend.copy_from_slice(&dw);
                }
                h = h_eff * (SAFETY * q.max(1e-10).powf(-1.0)).clamp(MIN_FACTOR, 1.0);
            }
        }
        out.push(z.clone());
    }
    (out, stats, success)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Ornstein-Uhlenbeck: dz = -z dt + sigma dW; stationary var sigma^2/2.
    #[test]
    fn ou_moments() {
        let sigma = 0.5;
        let mut rng = Rng::new(123);
        let ts = [0.0, 5.0, 10.0];
        let n_traj = 2000;
        // Order-1 weak scheme: solve tightly so the h-bias of the
        // stationary variance ((1+O(h)) sigma^2/2) is below the MC noise.
        let opts = SdeOptions {
            rtol: 1e-3,
            atol: 1e-3,
            ..Default::default()
        };
        let mut finals = Vec::with_capacity(n_traj);
        for _ in 0..n_traj {
            let (zs, _, ok) = sde_solve_saveat(
                |z, _t, dz| dz[0] = -z[0],
                |_z, _t, dg| dg[0] = sigma,
                &[0.0],
                &ts,
                &mut rng,
                &opts,
            );
            assert!(ok);
            finals.push(zs[2][0]);
        }
        let mean = finals.iter().sum::<f64>() / n_traj as f64;
        let var =
            finals.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n_traj as f64;
        assert!(mean.abs() < 0.05, "mean={mean}");
        let expect = sigma * sigma / 2.0;
        assert!((var - expect).abs() / expect < 0.15, "var={var} vs {expect}");
    }

    /// With zero diffusion the SDE solver must match the analytic ODE.
    #[test]
    fn deterministic_limit() {
        let mut rng = Rng::new(7);
        let ts = [0.0, 0.5, 1.0];
        let opts = SdeOptions {
            rtol: 1e-6,
            atol: 1e-6,
            ..Default::default()
        };
        let (zs, _, ok) = sde_solve_saveat(
            |z, _t, dz| dz[0] = -z[0],
            |_z, _t, dg| dg[0] = 0.0,
            &[1.0],
            &ts,
            &mut rng,
            &opts,
        );
        assert!(ok);
        assert!((zs[2][0] - (-1.0f64).exp()).abs() < 1e-4, "{}", zs[2][0]);
    }

    /// Multiplicative noise (GBM).  The stochastic Heun scheme converges to
    /// the **Stratonovich** solution, for which E[z_t] = z0 exp((mu +
    /// sig^2/2) t).  Solved at tight tolerance to suppress weak-order bias.
    #[test]
    fn gbm_stratonovich_mean() {
        let mu = 0.5f64;
        let sig = 0.3;
        let mut rng = Rng::new(99);
        let ts = [0.0, 1.0];
        let n_traj = 4000;
        let opts = SdeOptions {
            rtol: 1e-4,
            atol: 1e-4,
            ..Default::default()
        };
        let mut sum = 0.0;
        for _ in 0..n_traj {
            let (zs, _, ok) = sde_solve_saveat(
                |z, _t, dz| dz[0] = mu * z[0],
                |z, _t, dg| dg[0] = sig * z[0],
                &[1.0],
                &ts,
                &mut rng,
                &opts,
            );
            assert!(ok);
            sum += zs[1][0];
        }
        let mean = sum / n_traj as f64;
        let expect = (mu + 0.5 * sig * sig).exp();
        assert!((mean - expect).abs() / expect < 0.05, "{mean} vs {expect}");
    }

    #[test]
    fn nfe_counts_four_per_attempt() {
        let mut rng = Rng::new(1);
        let (_, stats, _) = sde_solve_saveat(
            |z, _t, dz| dz[0] = -z[0],
            |_z, _t, dg| dg[0] = 0.1,
            &[1.0],
            &[0.0, 1.0],
            &mut rng,
            &SdeOptions::default(),
        );
        assert_eq!(stats.nfe, 4 * (stats.naccept + stats.nreject));
    }
}
