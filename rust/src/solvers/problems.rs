//! Canonical test problems and the paper's data-generating systems.
//!
//! * `spiral_ode` — the cubic spiral du/dt = A u^3 behind Figure 2,
//! * `spiral_dsde` — the diagonal-noise spiral SDE of paper Eq. 15,
//! * `van_der_pol` / `robertson`-style stiff systems used by the stiffness
//!   estimator tests (paper §2.5 notes these as classic stiffness examples).

/// Cubic spiral ODE (Figure 2 ground truth): du/dt = A u^3.
pub const SPIRAL_A: [[f64; 2]; 2] = [[-0.1, 2.0], [-2.0, -0.1]];

pub fn spiral_ode(z: &[f64], _t: f64, dz: &mut [f64]) {
    let u1 = z[0] * z[0] * z[0];
    let u2 = z[1] * z[1] * z[1];
    dz[0] = SPIRAL_A[0][0] * u1 + SPIRAL_A[0][1] * u2;
    dz[1] = SPIRAL_A[1][0] * u1 + SPIRAL_A[1][1] * u2;
}

/// Spiral DSDE drift (paper Eq. 15 with alpha=0.1, beta=2.0).
pub fn spiral_sde_drift(z: &[f64], _t: f64, dz: &mut [f64]) {
    const ALPHA: f64 = 0.1;
    const BETA: f64 = 2.0;
    let u1 = z[0] * z[0] * z[0];
    let u2 = z[1] * z[1] * z[1];
    dz[0] = -ALPHA * u1 + BETA * u2;
    dz[1] = -BETA * u1 - ALPHA * u2;
}

/// Spiral DSDE diagonal diffusion (paper Eq. 15 with gamma=0.2).
pub fn spiral_sde_diffusion(z: &[f64], _t: f64, dg: &mut [f64]) {
    const GAMMA: f64 = 0.2;
    dg[0] = GAMMA * z[0];
    dg[1] = GAMMA * z[1];
}

/// Van der Pol oscillator with stiffness parameter mu (stiff for large mu).
pub fn van_der_pol(mu: f64) -> impl Fn(&[f64], f64, &mut [f64]) {
    move |z, _t, dz| {
        dz[0] = z[1];
        dz[1] = mu * ((1.0 - z[0] * z[0]) * z[1]) - z[0];
    }
}

/// Linear test system with prescribed spectrum — ground truth for the
/// stiffness estimator: S should approach max |Re(lambda_i)| (paper Eq. 7).
pub fn linear_spectrum(lambdas: Vec<f64>) -> impl Fn(&[f64], f64, &mut [f64]) {
    move |z, _t, dz| {
        for (i, &l) in lambdas.iter().enumerate() {
            dz[i] = l * z[i];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solvers::driver::StepBudget;
    use crate::solvers::ode::{drive, SolveOutcome};
    use crate::solvers::system::OdeSystem;
    use crate::solvers::{Saveat, SolveOptions};

    /// Test shorthand: one span solve through the unified driver.
    fn solve<F: FnMut(&[f64], f64, &mut [f64])>(
        f: F,
        z0: &[f64],
        t0: f64,
        t1: f64,
        opts: &SolveOptions,
    ) -> SolveOutcome {
        let mut sys = OdeSystem(f);
        drive(&mut sys, z0, Saveat::Span { t0, t1 }, opts, None, &mut [])
            .1
            .expect("test solve failed")
    }

    #[test]
    fn spiral_decays_inward() {
        // The cubic spiral decays toward the origin while rotating.
        let opts = SolveOptions::new().with_tolerance(1e-8);
        let out = solve(spiral_ode, &[2.0, 0.0], 0.0, 3.0, &opts);
        let r0 = 2.0f64;
        let r1 = (out.z[0] * out.z[0] + out.z[1] * out.z[1]).sqrt();
        assert!(r1 < r0, "radius grew: {r1}");
        assert!(r1 > 0.1, "collapsed: {r1}");
    }

    #[test]
    fn spiral_drift_matches_ode_shape() {
        let mut a = [0.0; 2];
        let mut b = [0.0; 2];
        spiral_ode(&[1.0, 0.5], 0.0, &mut a);
        spiral_sde_drift(&[1.0, 0.5], 0.0, &mut b);
        // Same A matrix structure (the ODE uses A including both signs).
        assert!((a[0] - b[0]).abs() < 1e-12);
        assert!((a[1] - b[1]).abs() < 1e-12);
    }

    #[test]
    fn van_der_pol_nonstiff_vs_stiff_nfe() {
        let opts = SolveOptions::new()
            .with_tolerance(1e-6)
            .with_budget(StepBudget::PerSegment(2_000_000));
        let easy = solve(van_der_pol(1.0), &[2.0, 0.0], 0.0, 5.0, &opts);
        let hard = solve(van_der_pol(50.0), &[2.0, 0.0], 0.0, 5.0, &opts);
        assert!(
            hard.stats.nfe > 3 * easy.stats.nfe,
            "stiff NFE {} vs nonstiff {}",
            hard.stats.nfe,
            easy.stats.nfe
        );
        // and the white-boxed stiffness accumulator sees it:
        let s_easy = easy.stats.r_s / easy.stats.naccept as f64;
        let s_hard = hard.stats.r_s / hard.stats.naccept as f64;
        assert!(s_hard > 3.0 * s_easy, "S {s_hard} vs {s_easy}");
    }

    #[test]
    fn spectrum_estimator_ground_truth() {
        let opts = SolveOptions::new().with_tolerance(1e-7);
        let f = linear_spectrum(vec![-1.0, -5.0, -40.0]);
        let out = solve(f, &[1.0, 1.0, 1.0], 0.0, 1.0, &opts);
        let s = out.stats.r_s / out.stats.naccept as f64;
        // The Shampine ratio is dominated by the fastest mode.
        assert!(s > 20.0 && s < 60.0, "S={s}");
    }
}
