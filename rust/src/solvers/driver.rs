//! The unified white-box `solve()` API.
//!
//! One entry point for every integration in this suite: a [`System`]
//! (ODE or SDE), an initial state, a [`Saveat`] spec, [`SolveOptions`]
//! and — as *configuration rather than separate functions* — optional
//! [`Taping`] for the discrete adjoint and any number of
//! [`StepObserver`]s watching the solver's internal heuristics.
//!
//! ```
//! use regnde::solvers::{solve, OdeSystem, Saveat, SolveOptions, Taping};
//! use regnde::solvers::observer::{ErrorIntegral, StepObserver};
//!
//! let mut sys = OdeSystem(|z: &[f64], _t: f64, dz: &mut [f64]| dz[0] = -z[0]);
//! let mut r_e = ErrorIntegral::new();
//! let (saves, out) = solve(
//!     &mut sys,
//!     &[1.0],
//!     Saveat::Span { t0: 0.0, t1: 1.0 },
//!     &SolveOptions::new().with_tolerance(1e-8),
//!     None,            // RNG: only SDE systems need one
//!     Taping::Off,
//!     &mut [&mut r_e],
//! );
//! let out = out.expect("solve failed");    // failures are typed SolveErrors
//! assert_eq!(saves.len(), 2);              // z0 and the endpoint
//! assert_eq!(r_e.value(), out.stats.r_e);  // observers see what Stats sees
//! ```
//!
//! Dispatch is driven by [`System::has_diffusion`]: drift-only systems
//! run the adaptive RK driver ([`super::ode::drive`]) — whose per-attempt
//! stage combination + embedded error estimate are fused into one
//! lane-vectorized pass over the stage arena
//! (`crate::models::kernels::rk_combine`, DESIGN.md §Perf) — diffusive
//! systems the stochastic Heun driver ([`super::sde::drive`]) and must
//! pass an RNG.  The pre-unification closure-based entry points (`ode::solve`,
//! `ode::solve_saveat`, `ode::solve_saveat_taped` and their `sde_*`
//! mirrors) are retired — this is the only call shape.
//!
//! ## Step budgets
//!
//! The seed's `max_steps` was silently *per save segment*, which made a
//! T-point grid worth up to `(T-1) · max_steps` attempts while the taped
//! training entry points quietly used a *total* budget instead.
//! [`StepBudget`] makes that choice explicit:
//!
//! * [`StepBudget::PerSegment`] — each save interval gets the full
//!   budget (the seed's data-generation semantics),
//! * [`StepBudget::Total`] — one budget bounds the whole solve (the
//!   budget-ladder training contract; exhaustion is a typed
//!   [`SolveErrorKind::BudgetExhausted`] so the router can escalate).

use super::adjoint::{OdeTape, SdeTape};
use super::error::{SolveError, SolveErrorKind, SolveResult};
use super::ode::{self, Stats};
use super::observer::StepObserver;
use super::sde;
use super::system::System;
use super::tableau::Tableau;
use crate::util::rng::Rng;

/// Step-attempt budget semantics of one solve.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StepBudget {
    /// Every save segment independently gets this many attempts (a
    /// T-point grid may use up to `(T-1) ×` this total — see
    /// [`super::ode::Stats::attempts`]).
    PerSegment(u64),
    /// One budget for the whole solve, summed over segments (the
    /// budget-ladder training contract).
    Total(u64),
}

impl StepBudget {
    /// Attempts available for the next segment given `used` so far.
    #[inline]
    pub(super) fn for_segment(&self, used: u64) -> u64 {
        match *self {
            StepBudget::PerSegment(b) => b,
            StepBudget::Total(b) => b.saturating_sub(used),
        }
    }
}

/// Options of one unified solve — tableau, tolerances, budget, initial
/// step.  Built with chainable `with_*` methods:
///
/// ```
/// use regnde::solvers::{SolveOptions, StepBudget, Tableau};
/// let opts = SolveOptions::new()
///     .with_tableau(Tableau::dopri5())
///     .with_tolerance(1e-8)
///     .with_budget(StepBudget::Total(4096));
/// assert_eq!(opts.rtol, 1e-8);
/// ```
#[derive(Clone, Debug)]
pub struct SolveOptions {
    /// RK tableau (ignored by the stochastic Heun stack, whose scheme is
    /// fixed).
    pub tableau: Tableau,
    pub rtol: f64,
    pub atol: f64,
    pub budget: StepBudget,
    /// Initial step size; `None` uses the stack's heuristic.
    pub dt0: Option<f64>,
}

impl Default for SolveOptions {
    fn default() -> Self {
        SolveOptions {
            tableau: Tableau::tsit5(),
            rtol: 1e-6,
            atol: 1e-6,
            budget: StepBudget::PerSegment(100_000),
            dt0: None,
        }
    }
}

impl SolveOptions {
    pub fn new() -> SolveOptions {
        SolveOptions::default()
    }

    pub fn with_tableau(mut self, tableau: Tableau) -> SolveOptions {
        self.tableau = tableau;
        self
    }

    /// Set `rtol = atol = tol` (the paper's convention).
    pub fn with_tolerance(mut self, tol: f64) -> SolveOptions {
        self.rtol = tol;
        self.atol = tol;
        self
    }

    pub fn with_tolerances(mut self, rtol: f64, atol: f64) -> SolveOptions {
        self.rtol = rtol;
        self.atol = atol;
        self
    }

    pub fn with_budget(mut self, budget: StepBudget) -> SolveOptions {
        self.budget = budget;
        self
    }

    pub fn with_dt0(mut self, dt0: f64) -> SolveOptions {
        self.dt0 = Some(dt0);
        self
    }
}

/// Where to save states along the solve.
#[derive(Clone, Copy, Debug)]
pub enum Saveat<'a> {
    /// Integrate `[t0, t1]` as one segment, saving `z0` and the endpoint.
    /// Non-finite endpoints or `t1 <= t0` are a
    /// [`SolveErrorKind::BadSpan`] (state untouched, zero dynamics
    /// evaluations).
    Span { t0: f64, t1: f64 },
    /// Save at every time of a non-decreasing finite grid (`len >= 2`,
    /// `grid[0]` is the start time).  Violations are a
    /// [`SolveErrorKind::BadSpan`] — grids arrive over the wire from
    /// checkpoints and serving requests, so a malformed one must be a
    /// typed error, never a panic.
    Grid(&'a [f64]),
}

/// Discrete-adjoint taping as solve configuration.  The variant must
/// match the system's stack ([`System::has_diffusion`]); a mismatch is a
/// [`SolveErrorKind::TapeMismatch`].  The tape is always reset at the
/// start of the solve — even one that fails cleanly on an invalid
/// [`Saveat::Span`] or a taping mismatch — so a reused tape never
/// carries a previous solve's records.
pub enum Taping<'a> {
    Off,
    Ode(&'a mut OdeTape),
    Sde(&'a mut SdeTape),
}

/// The clean-failure return value shared by every pre-integration
/// check: only `z0` saved, state untouched, zero dynamics evaluations.
fn clean_failure(kind: SolveErrorKind, t0: f64, z0: &[f64]) -> (Vec<Vec<f64>>, SolveResult) {
    (
        vec![z0.to_vec()],
        Err(SolveError {
            kind,
            t: t0,
            z: z0.to_vec(),
            stats: Stats::default(),
        }),
    )
}

/// Resolve a [`Saveat`] into the save grid both stack drivers integrate
/// over: `span_store` backs the two-point grid of a [`Saveat::Span`].
/// An invalid span or malformed grid (too short, decreasing, or
/// non-finite times) yields the clean [`SolveErrorKind::BadSpan`]
/// failure return value (state untouched, zero dynamics evaluations).
pub(super) fn resolve_saveat<'a>(
    saveat: Saveat<'a>,
    span_store: &'a mut [f64; 2],
    z0: &[f64],
) -> Result<&'a [f64], (Vec<Vec<f64>>, SolveResult)> {
    match saveat {
        Saveat::Span { t0, t1 } => {
            if !t0.is_finite() || !t1.is_finite() || t1 <= t0 {
                return Err(clean_failure(SolveErrorKind::BadSpan, t0, z0));
            }
            *span_store = [t0, t1];
            Ok(&span_store[..])
        }
        Saveat::Grid(g) => {
            let bad = g.len() < 2
                || g.iter().any(|t| !t.is_finite())
                || g.windows(2).any(|w| w[1] < w[0]);
            if bad {
                let t0 = g.first().copied().unwrap_or(f64::NAN);
                return Err(clean_failure(SolveErrorKind::BadSpan, t0, z0));
            }
            Ok(g)
        }
    }
}

/// Solve a [`System`] — *the* unified entry point.
///
/// * drift-only systems run the adaptive RK driver (`rng` unused),
/// * diffusive systems run the stochastic Heun driver and require
///   `rng: Some(..)`.
///
/// Returns the saved states (per [`Saveat`]) and
/// `Result<SolveOutcome, SolveError>` whose [`super::ode::Stats`] carry
/// the white-boxed accumulators.  Every accepted step is also offered
/// to `observers`.  Misconfiguration — a diffusive system without an
/// RNG ([`SolveErrorKind::MissingRng`]) or a [`Taping`] variant for the
/// wrong stack ([`SolveErrorKind::TapeMismatch`]) — is a typed error,
/// never a panic: these arrive from user input (checkpoints, serving
/// requests), not just from first-party callers.
pub fn solve<S: System>(
    sys: &mut S,
    z0: &[f64],
    saveat: Saveat<'_>,
    opts: &SolveOptions,
    rng: Option<&mut Rng>,
    taping: Taping<'_>,
    observers: &mut [&mut dyn StepObserver],
) -> (Vec<Vec<f64>>, SolveResult) {
    let t0 = match saveat {
        Saveat::Span { t0, .. } => t0,
        Saveat::Grid(g) => g.first().copied().unwrap_or(f64::NAN),
    };
    if sys.has_diffusion() {
        let tape = match taping {
            Taping::Off => None,
            Taping::Sde(tape) => Some(tape),
            Taping::Ode(tape) => {
                // Honor the Taping contract even on failure: the reused
                // tape must not keep a previous solve's records.
                tape.reset(z0.len(), opts.tableau.stages());
                return clean_failure(SolveErrorKind::TapeMismatch, t0, z0);
            }
        };
        let Some(rng) = rng else {
            if let Some(tape) = tape {
                tape.reset(z0.len());
            }
            return clean_failure(SolveErrorKind::MissingRng, t0, z0);
        };
        sde::drive(sys, z0, saveat, rng, opts, tape, observers)
    } else {
        let tape = match taping {
            Taping::Off => None,
            Taping::Ode(tape) => Some(tape),
            Taping::Sde(tape) => {
                tape.reset(z0.len());
                return clean_failure(SolveErrorKind::TapeMismatch, t0, z0);
            }
        };
        ode::drive(sys, z0, saveat, opts, tape, observers)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solvers::observer::{ErrorIntegral, LocalReg, StiffnessSum};
    use crate::solvers::system::{OdeSystem, SdeSystem};

    fn exp_decay(z: &[f64], _t: f64, dz: &mut [f64]) {
        for i in 0..z.len() {
            dz[i] = -z[i];
        }
    }

    #[test]
    fn span_is_the_two_point_grid() {
        // A Span and its equivalent 2-point Grid are the same program:
        // same bits, same counters, same saves.
        let opts = SolveOptions::new().with_tolerance(1e-7);
        let mut sys = OdeSystem(exp_decay);
        let (saves_span, out_span) = solve(
            &mut sys,
            &[1.0, 2.0],
            Saveat::Span { t0: 0.0, t1: 1.0 },
            &opts,
            None,
            Taping::Off,
            &mut [],
        );
        let mut sys = OdeSystem(exp_decay);
        let (saves_grid, out_grid) = solve(
            &mut sys,
            &[1.0, 2.0],
            Saveat::Grid(&[0.0, 1.0]),
            &opts,
            None,
            Taping::Off,
            &mut [],
        );
        let (out_span, out_grid) = (out_span.unwrap(), out_grid.unwrap());
        assert_eq!(out_span.z, out_grid.z, "span and 2-point grid must agree bit-for-bit");
        assert_eq!(out_span.stats.nfe, out_grid.stats.nfe);
        assert_eq!(out_span.stats.r_e, out_grid.stats.r_e);
        assert_eq!(saves_span, saves_grid);
        assert_eq!(saves_span.len(), 2);
        assert_eq!(saves_span[0], vec![1.0, 2.0]);
        assert_eq!(saves_span[1], out_span.z);
    }

    #[test]
    fn observers_see_what_stats_see() {
        let mut sys = OdeSystem(exp_decay);
        let mut re = ErrorIntegral::new();
        let mut rs = StiffnessSum::new();
        let (_, out) = solve(
            &mut sys,
            &[1.0],
            Saveat::Span { t0: 0.0, t1: 1.0 },
            &SolveOptions::new().with_tolerance(1e-8),
            None,
            Taping::Off,
            &mut [&mut re, &mut rs],
        );
        let out = out.unwrap();
        assert!(out.stats.naccept > 0);
        assert_eq!(re.value(), out.stats.r_e, "R_E observer must be bit-identical");
        assert_eq!(rs.value(), out.stats.r_s, "R_S observer must be bit-identical");
    }

    #[test]
    fn unified_sde_dispatch_requires_and_uses_rng() {
        let mut sys = SdeSystem {
            drift: |z: &[f64], _t: f64, dz: &mut [f64]| dz[0] = -z[0],
            diffusion: |_z: &[f64], _t: f64, dg: &mut [f64]| dg[0] = 0.3,
        };
        let mut rng = Rng::new(11);
        let ts = [0.0, 0.5, 1.0];
        let (saves, out) = solve(
            &mut sys,
            &[1.0],
            Saveat::Grid(&ts),
            &SolveOptions::new().with_tolerance(1e-2),
            Some(&mut rng),
            Taping::Off,
            &mut [],
        );
        let out = out.unwrap();
        assert_eq!(saves.len(), 3);
        // SDE accounting: 4 dynamics evals per attempt.
        assert_eq!(out.stats.nfe, 4 * out.stats.attempts());
    }

    #[test]
    fn sde_without_rng_is_a_typed_error() {
        let mut sys = SdeSystem {
            drift: |_z: &[f64], _t: f64, dz: &mut [f64]| dz[0] = 0.0,
            diffusion: |_z: &[f64], _t: f64, dg: &mut [f64]| dg[0] = 0.0,
        };
        let (saves, out) = solve(
            &mut sys,
            &[1.0],
            Saveat::Span { t0: 0.0, t1: 1.0 },
            &SolveOptions::new(),
            None,
            Taping::Off,
            &mut [],
        );
        let err = out.unwrap_err();
        assert_eq!(err.kind, SolveErrorKind::MissingRng);
        assert_eq!(err.stats.nfe, 0, "no dynamics evaluation");
        assert_eq!(saves, vec![vec![1.0]], "only z0 saved");
    }

    #[test]
    fn mismatched_taping_is_a_typed_error() {
        // SDE tape on an ODE system and vice versa: both directions are
        // typed TapeMismatch errors, and the wrong tape is still reset
        // (the Taping contract holds even on failure).
        let mut sys = OdeSystem(exp_decay);
        let mut sde_tape = SdeTape::new();
        let (saves, out) = solve(
            &mut sys,
            &[1.0],
            Saveat::Span { t0: 0.0, t1: 1.0 },
            &SolveOptions::new(),
            None,
            Taping::Sde(&mut sde_tape),
            &mut [],
        );
        assert_eq!(out.unwrap_err().kind, SolveErrorKind::TapeMismatch);
        assert_eq!(saves, vec![vec![1.0]]);
        assert!(sde_tape.is_empty());

        let mut sys = SdeSystem {
            drift: |_z: &[f64], _t: f64, dz: &mut [f64]| dz[0] = 0.0,
            diffusion: |_z: &[f64], _t: f64, dg: &mut [f64]| dg[0] = 0.0,
        };
        let mut ode_tape = OdeTape::new();
        let mut rng = Rng::new(5);
        let (_, out) = solve(
            &mut sys,
            &[1.0],
            Saveat::Span { t0: 0.0, t1: 1.0 },
            &SolveOptions::new(),
            Some(&mut rng),
            Taping::Ode(&mut ode_tape),
            &mut [],
        );
        assert_eq!(out.unwrap_err().kind, SolveErrorKind::TapeMismatch);
        assert!(ode_tape.is_empty());
    }

    #[test]
    fn total_budget_bounds_whole_grid() {
        let ts: Vec<f64> = (0..11).map(|i| i as f64 * 0.1).collect();
        let mut sys = OdeSystem(exp_decay);
        let (saves, out) = solve(
            &mut sys,
            &[1.0],
            Saveat::Grid(&ts),
            &SolveOptions::new()
                .with_tolerance(1e-9)
                .with_budget(StepBudget::Total(3)),
            None,
            Taping::Off,
            &mut [],
        );
        let err = out.unwrap_err();
        assert_eq!(
            err.kind,
            SolveErrorKind::BudgetExhausted,
            "3 total attempts cannot cover 10 segments"
        );
        assert!(err.stats.attempts() <= 3);
        assert_eq!(saves.len(), ts.len(), "outputs stay grid-shaped");
    }

    #[test]
    fn span_failure_semantics_match_legacy() {
        let mut sys = OdeSystem(exp_decay);
        for t1 in [0.0, -1.0, f64::NAN] {
            let (saves, out) = solve(
                &mut sys,
                &[1.0],
                Saveat::Span { t0: 0.0, t1 },
                &SolveOptions::new(),
                None,
                Taping::Off,
                &mut [],
            );
            let err = out.unwrap_err();
            assert_eq!(err.kind, SolveErrorKind::BadSpan, "t1={t1} must fail");
            assert_eq!(err.z, vec![1.0], "state untouched");
            assert_eq!(err.stats.nfe, 0, "no dynamics evaluation");
            assert_eq!(saves.len(), 1, "only z0 saved on failure");
        }
    }

    #[test]
    fn failed_span_still_resets_a_reused_tape() {
        let mut sys = OdeSystem(exp_decay);
        let mut tape = OdeTape::new();
        // Populate the tape with a real solve.
        let (_, out) = solve(
            &mut sys,
            &[1.0],
            Saveat::Span { t0: 0.0, t1: 1.0 },
            &SolveOptions::new(),
            None,
            Taping::Ode(&mut tape),
            &mut [],
        );
        assert!(out.is_ok() && !tape.is_empty());
        // A cleanly-failed solve must not leave stale records behind —
        // a caller reusing the tape would otherwise walk the previous
        // solve's program.
        let (_, out) = solve(
            &mut sys,
            &[1.0],
            Saveat::Span { t0: 0.0, t1: -1.0 },
            &SolveOptions::new(),
            None,
            Taping::Ode(&mut tape),
            &mut [],
        );
        assert!(out.is_err());
        assert!(tape.is_empty(), "Taping contract: reset even on clean failure");
        assert!(tape.save_marks().is_empty());
    }

    #[test]
    fn local_reg_observer_samples_a_recorded_step() {
        let mut sys = OdeSystem(exp_decay);
        let mut tape = OdeTape::new();
        let mut lr = LocalReg::new(42);
        let ts = [0.0, 0.5, 1.0];
        let (_, out) = solve(
            &mut sys,
            &[1.0, 0.5],
            Saveat::Grid(&ts),
            &SolveOptions::new().with_tolerance(1e-7),
            None,
            Taping::Ode(&mut tape),
            &mut [&mut lr],
        );
        let out = out.unwrap();
        let j = lr.sampled_step().expect("accepted steps must be sampled");
        assert!(j < tape.len(), "sampled index {j} must name a tape record");
        assert!(lr.value() > 0.0);
        assert!(lr.value() <= out.stats.r_e, "one term cannot exceed the sum");
    }
}
