//! The [`System`] trait: one dynamics interface for both solver stacks.
//!
//! Before this trait existed every solver entry point took its dynamics as
//! ad-hoc closures — `f` for ODEs, `(drift, diffusion)` for SDEs, and four
//! separate closures for the SDE adjoint — so each new capability (taping,
//! observation, a new regularizer) multiplied the entry-point surface.  A
//! `System` packages everything the unified driver ([`super::driver`]) and
//! the discrete adjoint ([`super::adjoint`]) can ask of a model:
//!
//! * [`System::drift`] — the deterministic dynamics `dz/dt` (ODE) or the
//!   SDE drift term.  Always required.
//! * [`System::diffusion`] — the diagonal diffusion term.  Optional:
//!   [`System::has_diffusion`] reports whether it exists, and the driver
//!   routes drift-only systems through the adaptive RK stack and
//!   diffusive ones through the stochastic Heun stack.
//! * [`System::drift_vjp`] / [`System::diffusion_vjp`] — accumulating
//!   vector-Jacobian products (`gz += wᵀ ∂f/∂z`, `gp += wᵀ ∂f/∂θ`),
//!   needed only by the discrete-adjoint backward walks.  Systems that
//!   are never differentiated (data generation, benches) simply do not
//!   override them.
//!
//! Closure-based call sites do not need hand-written impls: the
//! [`OdeSystem`] / [`SdeSystem`] adapters lift plain dynamics closures,
//! and [`OdeSystemVjp`] / [`SdeSystemVjp`] additionally carry the VJP
//! closures for the legacy adjoint entry points.

/// A (possibly stochastic) dynamical system `dz = f(z, t) dt
/// [+ g(z, t) ∘ dW]` with optional VJP hooks for the discrete adjoint.
///
/// All methods take `&mut self` so implementations can own scratch
/// buffers (the allocation-free contract of DESIGN.md §Perf: the driver
/// never allocates per step, and neither should the system).
pub trait System {
    /// Write the deterministic dynamics (ODE right-hand side / SDE drift)
    /// at `(z, t)` into `dz`.
    fn drift(&mut self, z: &[f64], t: f64, dz: &mut [f64]);

    /// Whether this system has a diffusion term.  `false` (the default)
    /// routes the unified driver through the adaptive RK stack; `true`
    /// through the stochastic Heun stack (which then requires an RNG).
    fn has_diffusion(&self) -> bool {
        false
    }

    /// Write the diagonal diffusion at `(z, t)` into `dg`.  Only invoked
    /// when [`System::has_diffusion`] returns `true`.
    fn diffusion(&mut self, _z: &[f64], _t: f64, _dg: &mut [f64]) {
        // analyze: allow(panic) -- programmer-error contract: unreachable unless a caller ignores has_diffusion(); never fed by user input
        panic!("System::diffusion called on a drift-only system");
    }

    /// Accumulating VJP of the drift: add `wᵀ ∂f/∂z` into `gz` and
    /// `wᵀ ∂f/∂θ` into `gp` (both `+=`, never overwrite).  Required only
    /// by the adjoint walks ([`super::adjoint`]).
    fn drift_vjp(&mut self, _z: &[f64], _t: f64, _w: &[f64], _gz: &mut [f64], _gp: &mut [f64]) {
        // analyze: allow(panic) -- programmer-error contract: adjoint walks require a VJP-capable System; Taping::Off never reaches here
        panic!("System::drift_vjp not provided — this system is not differentiable");
    }

    /// Accumulating VJP of the diffusion (same contract as
    /// [`System::drift_vjp`]).  Required only by the SDE adjoint.
    fn diffusion_vjp(
        &mut self,
        _z: &[f64],
        _t: f64,
        _w: &[f64],
        _gz: &mut [f64],
        _gp: &mut [f64],
    ) {
        // analyze: allow(panic) -- programmer-error contract: same as drift_vjp, SDE-adjoint-only entry point
        panic!("System::diffusion_vjp not provided — this system is not differentiable");
    }
}

/// Lift a plain ODE closure `f(z, t, dz)` into a [`System`].
pub struct OdeSystem<F>(pub F);

impl<F: FnMut(&[f64], f64, &mut [f64])> System for OdeSystem<F> {
    fn drift(&mut self, z: &[f64], t: f64, dz: &mut [f64]) {
        (self.0)(z, t, dz)
    }
}

/// Lift an `(drift, diffusion)` closure pair into a diffusive [`System`].
pub struct SdeSystem<F, G> {
    pub drift: F,
    pub diffusion: G,
}

impl<F, G> System for SdeSystem<F, G>
where
    F: FnMut(&[f64], f64, &mut [f64]),
    G: FnMut(&[f64], f64, &mut [f64]),
{
    fn drift(&mut self, z: &[f64], t: f64, dz: &mut [f64]) {
        (self.drift)(z, t, dz)
    }

    fn has_diffusion(&self) -> bool {
        true
    }

    fn diffusion(&mut self, z: &[f64], t: f64, dg: &mut [f64]) {
        (self.diffusion)(z, t, dg)
    }
}

/// ODE closure pair `(drift, vjp)` — the differentiable adapter behind
/// the legacy [`super::adjoint::ode_backward`] entry point.
pub struct OdeSystemVjp<F, V> {
    pub drift: F,
    pub vjp: V,
}

impl<F, V> System for OdeSystemVjp<F, V>
where
    F: FnMut(&[f64], f64, &mut [f64]),
    V: FnMut(&[f64], f64, &[f64], &mut [f64], &mut [f64]),
{
    fn drift(&mut self, z: &[f64], t: f64, dz: &mut [f64]) {
        (self.drift)(z, t, dz)
    }

    fn drift_vjp(&mut self, z: &[f64], t: f64, w: &[f64], gz: &mut [f64], gp: &mut [f64]) {
        (self.vjp)(z, t, w, gz, gp)
    }
}

/// SDE closure quadruple — the differentiable adapter behind the legacy
/// [`super::adjoint::sde_backward`] entry point.
pub struct SdeSystemVjp<F, G, FV, GV> {
    pub drift: F,
    pub diffusion: G,
    pub drift_vjp: FV,
    pub diffusion_vjp: GV,
}

impl<F, G, FV, GV> System for SdeSystemVjp<F, G, FV, GV>
where
    F: FnMut(&[f64], f64, &mut [f64]),
    G: FnMut(&[f64], f64, &mut [f64]),
    FV: FnMut(&[f64], f64, &[f64], &mut [f64], &mut [f64]),
    GV: FnMut(&[f64], f64, &[f64], &mut [f64], &mut [f64]),
{
    fn drift(&mut self, z: &[f64], t: f64, dz: &mut [f64]) {
        (self.drift)(z, t, dz)
    }

    fn has_diffusion(&self) -> bool {
        true
    }

    fn diffusion(&mut self, z: &[f64], t: f64, dg: &mut [f64]) {
        (self.diffusion)(z, t, dg)
    }

    fn drift_vjp(&mut self, z: &[f64], t: f64, w: &[f64], gz: &mut [f64], gp: &mut [f64]) {
        (self.drift_vjp)(z, t, w, gz, gp)
    }

    fn diffusion_vjp(&mut self, z: &[f64], t: f64, w: &[f64], gz: &mut [f64], gp: &mut [f64]) {
        (self.diffusion_vjp)(z, t, w, gz, gp)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ode_adapter_is_drift_only() {
        let mut sys = OdeSystem(|z: &[f64], _t: f64, dz: &mut [f64]| dz[0] = -z[0]);
        assert!(!sys.has_diffusion());
        let mut dz = [0.0];
        sys.drift(&[2.0], 0.0, &mut dz);
        assert_eq!(dz[0], -2.0);
    }

    #[test]
    #[should_panic(expected = "drift-only")]
    fn ode_adapter_panics_on_diffusion() {
        let mut sys = OdeSystem(|_z: &[f64], _t: f64, _dz: &mut [f64]| {});
        sys.diffusion(&[1.0], 0.0, &mut [0.0]);
    }

    #[test]
    fn sde_adapter_reports_diffusion() {
        let mut sys = SdeSystem {
            drift: |z: &[f64], _t: f64, dz: &mut [f64]| dz[0] = -z[0],
            diffusion: |_z: &[f64], _t: f64, dg: &mut [f64]| dg[0] = 0.5,
        };
        assert!(sys.has_diffusion());
        let mut dg = [0.0];
        sys.diffusion(&[1.0], 0.0, &mut dg);
        assert_eq!(dg[0], 0.5);
    }

    #[test]
    fn vjp_adapters_accumulate() {
        let mut sys = OdeSystemVjp {
            drift: |z: &[f64], _t: f64, dz: &mut [f64]| dz[0] = 3.0 * z[0],
            vjp: |z: &[f64], _t: f64, w: &[f64], gz: &mut [f64], gp: &mut [f64]| {
                gz[0] += w[0] * 3.0;
                gp[0] += w[0] * z[0];
            },
        };
        let (mut gz, mut gp) = ([1.0], [2.0]);
        sys.drift_vjp(&[5.0], 0.0, &[1.0], &mut gz, &mut gp);
        assert_eq!(gz[0], 4.0, "must accumulate, not overwrite");
        assert_eq!(gp[0], 7.0);
    }
}
