//! [`StepObserver`]: the pluggable white-box surface of the solvers.
//!
//! The paper's entire method rests on observing the solver's internal
//! heuristics — the local error estimate `E_j` and the stiffness estimate
//! `S_j` of every accepted step.  The seed hard-wired exactly two
//! consumers of those quantities (the `R_E`/`R_S` accumulators inside
//! `Stats`); this module makes "open the blackbox" a first-class API:
//! the unified driver hands every accepted step to any number of
//! observers as a [`StepView`], and the built-in regularizers are just
//! observers like any other:
//!
//! * [`ErrorIntegral`] — `R_E = Σ E_j |h_j|` (paper Eq. 9),
//! * [`ErrorSquared`]  — `Σ E_j²`, the unsquared-mean variant (§4.1.2),
//! * [`StiffnessSum`]  — `R_S = Σ S_j` (paper Eq. 8/11),
//! * [`LocalReg`]      — the *locally regularized* variant (Pal et al.
//!   2023, PAPERS.md): uniformly samples **one** accepted step via
//!   reservoir sampling and exposes that step's `E_ĵ |h_ĵ|` as the
//!   regularizer — per-step work instead of a global sum.  The sampled
//!   step index feeds [`super::adjoint::RegCoefs::local_e`] so the
//!   discrete adjoint differentiates exactly the sampled term.
//!
//! Observers run inside the accept branch of the allocation-free step
//! loop (DESIGN.md §Perf): `on_accept` must not allocate.  The built-in
//! accumulators perform the same floating-point additions in the same
//! order as the seed's `Stats` fields, so the reported `R_E`/`R_E²`/`R_S`
//! stay bit-identical (pinned by `tests/solver_equivalence.rs`).
//!
//! The observability layer builds directly on this surface:
//! [`crate::obs::TraceRecorder`] is an observer that copies each
//! accepted step's `(t, h, E_j, S_j, nfe, nreject)` into a bounded
//! preallocated buffer — see [`crate::obs`] and DESIGN.md
//! §Observability for the trace schema and overhead policy.

use crate::util::rng::Rng;

/// Everything the driver knows about one **accepted** step, handed to
/// every [`StepObserver`].  Borrows point into the solver's scratch
/// arena — copy out anything that must outlive the callback.
#[derive(Debug)]
pub struct StepView<'a> {
    /// Ordinal of this accepted step within the whole solve (equals the
    /// tape index when a tape is recording).
    pub index: u64,
    /// Step start time.
    pub t: f64,
    /// Step size actually taken (positive in forward-time solves).
    pub h: f64,
    /// Local error estimate `E_j` (Hairer RMS of the embedded error).
    pub error: f64,
    /// Stiffness estimate `S_j` (Shampine ratio for RK, drift surrogate
    /// for stochastic Heun).
    pub stiffness: f64,
    /// Cumulative function evaluations of the whole solve at the moment
    /// this step was accepted (includes this step's own attempt).
    pub nfe: u64,
    /// Cumulative rejected attempts at the moment this step was
    /// accepted — the delta between consecutive views counts the
    /// rejections that preceded this acceptance.
    pub nreject: u64,
    /// The accepted state `z_{j+1}`.
    pub z: &'a [f64],
    /// The embedded error vector behind `error`.
    pub err: &'a [f64],
}

/// A per-accepted-step observer plugged into the unified driver.
pub trait StepObserver {
    /// Called once per accepted step, in step order.
    fn on_accept(&mut self, view: &StepView<'_>);

    /// The scalar this observer has accumulated so far (its regularizer
    /// value; `0.0` before any step).
    fn value(&self) -> f64;

    /// Clear accumulated state for a fresh solve.
    fn reset(&mut self);
}

/// `R_E = Σ E_j |h_j|` (paper Eq. 9) — the ERNODE/ERNSDE regularizer.
#[derive(Clone, Debug, Default)]
pub struct ErrorIntegral {
    acc: f64,
}

impl ErrorIntegral {
    pub fn new() -> ErrorIntegral {
        ErrorIntegral::default()
    }
}

impl StepObserver for ErrorIntegral {
    fn on_accept(&mut self, view: &StepView<'_>) {
        self.acc += view.error * view.h.abs();
    }

    fn value(&self) -> f64 {
        self.acc
    }

    fn reset(&mut self) {
        self.acc = 0.0;
    }
}

/// `Σ E_j²` — the unsquared-mean `R_E` variant (paper §4.1.2 note).
#[derive(Clone, Debug, Default)]
pub struct ErrorSquared {
    acc: f64,
}

impl ErrorSquared {
    pub fn new() -> ErrorSquared {
        ErrorSquared::default()
    }
}

impl StepObserver for ErrorSquared {
    fn on_accept(&mut self, view: &StepView<'_>) {
        self.acc += view.error * view.error;
    }

    fn value(&self) -> f64 {
        self.acc
    }

    fn reset(&mut self) {
        self.acc = 0.0;
    }
}

/// `R_S = Σ S_j` (paper Eq. 8/11) — the SRNODE/SRNSDE regularizer.
#[derive(Clone, Debug, Default)]
pub struct StiffnessSum {
    acc: f64,
}

impl StiffnessSum {
    pub fn new() -> StiffnessSum {
        StiffnessSum::default()
    }
}

impl StepObserver for StiffnessSum {
    fn on_accept(&mut self, view: &StepView<'_>) {
        self.acc += view.stiffness;
    }

    fn value(&self) -> f64 {
        self.acc
    }

    fn reset(&mut self) {
        self.acc = 0.0;
    }
}

/// Sampled-step local regularizer (LRNODE/LRNSDE, Pal et al. 2023):
/// reservoir-samples one accepted step ĵ uniformly over the solve and
/// exposes `R_L = E_ĵ |h_ĵ|`.
///
/// One uniform draw per accepted step, no allocation.  After the solve,
/// [`LocalReg::sampled_step`] names the step whose error term the value
/// is — hand it to [`super::adjoint::RegCoefs::local_e`] so the backward
/// walk differentiates exactly the sampled term (gradcheck:
/// `tests/lrnode_gradcheck.rs`).  Sampling is deterministic in the seed,
/// so a retried train step (budget-ladder escalation) resamples the same
/// sequence.
#[derive(Clone, Debug)]
pub struct LocalReg {
    rng: Rng,
    enabled: bool,
    seen: u64,
    sampled_step: Option<usize>,
    sampled_value: f64,
}

impl LocalReg {
    pub fn new(seed: u64) -> LocalReg {
        LocalReg {
            rng: Rng::new(seed),
            enabled: true,
            seen: 0,
            sampled_step: None,
            sampled_value: 0.0,
        }
    }

    /// An inert sampler: can be attached like any observer but ignores
    /// every step (no RNG draw), never samples, and reports `0.0`.
    /// Lets call sites keep one wiring path whether or not the local
    /// regularizer is active.
    pub fn disabled() -> LocalReg {
        LocalReg {
            enabled: false,
            ..LocalReg::new(0)
        }
    }

    /// The uniformly sampled accepted-step index (`None` before any
    /// accepted step, and always `None` when [`LocalReg::disabled`]).
    pub fn sampled_step(&self) -> Option<usize> {
        self.sampled_step
    }
}

impl StepObserver for LocalReg {
    fn on_accept(&mut self, view: &StepView<'_>) {
        if !self.enabled {
            return;
        }
        self.seen += 1;
        // Reservoir sampling: step number `seen` replaces the held sample
        // with probability 1/seen, leaving every step equally likely.
        if self.rng.uniform() * self.seen as f64 < 1.0 {
            self.sampled_step = Some(view.index as usize);
            self.sampled_value = view.error * view.h.abs();
        }
    }

    fn value(&self) -> f64 {
        self.sampled_value
    }

    fn reset(&mut self) {
        self.seen = 0;
        self.sampled_step = None;
        self.sampled_value = 0.0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn view(index: u64, h: f64, error: f64, stiffness: f64) -> StepView<'static> {
        StepView {
            index,
            t: 0.0,
            h,
            error,
            stiffness,
            nfe: 0,
            nreject: 0,
            z: &[],
            err: &[],
        }
    }

    #[test]
    fn builtin_accumulators_match_definitions() {
        let mut re = ErrorIntegral::new();
        let mut re2 = ErrorSquared::new();
        let mut rs = StiffnessSum::new();
        let steps = [(0.1, 2e-3, 5.0), (-0.2, 3e-3, 7.0), (0.4, 1e-3, 1.0)];
        for (i, &(h, e, s)) in steps.iter().enumerate() {
            let v = view(i as u64, h, e, s);
            re.on_accept(&v);
            re2.on_accept(&v);
            rs.on_accept(&v);
        }
        let want_re: f64 = steps.iter().map(|(h, e, _)| e * h.abs()).sum();
        let want_re2: f64 = steps.iter().map(|(_, e, _)| e * e).sum();
        let want_rs: f64 = steps.iter().map(|(_, _, s)| s).sum();
        assert_eq!(re.value(), want_re);
        assert_eq!(re2.value(), want_re2);
        assert_eq!(rs.value(), want_rs);
        re.reset();
        assert_eq!(re.value(), 0.0);
    }

    #[test]
    fn local_reg_always_picks_first_step_then_samples() {
        let mut lr = LocalReg::new(7);
        assert_eq!(lr.sampled_step(), None);
        lr.on_accept(&view(0, 0.5, 1e-3, 0.0));
        // The first step is held with probability 1 (u * 1 < 1 always).
        assert_eq!(lr.sampled_step(), Some(0));
        assert_eq!(lr.value(), 1e-3 * 0.5);
        for i in 1..200 {
            lr.on_accept(&view(i, 0.5, 1e-3, 0.0));
        }
        let j = lr.sampled_step().unwrap();
        assert!(j < 200);
    }

    #[test]
    fn local_reg_sampling_is_roughly_uniform() {
        // Over many independent solves of 10 steps, every index must be
        // hit a plausible number of times.
        let n_runs = 5000;
        let n_steps = 10u64;
        let mut counts = [0usize; 10];
        for run in 0..n_runs {
            let mut lr = LocalReg::new(run as u64);
            for i in 0..n_steps {
                lr.on_accept(&view(i, 0.1, 1e-3, 0.0));
            }
            counts[lr.sampled_step().unwrap()] += 1;
        }
        let expect = n_runs as f64 / n_steps as f64;
        for (i, &c) in counts.iter().enumerate() {
            assert!(
                (c as f64) > 0.7 * expect && (c as f64) < 1.3 * expect,
                "index {i} sampled {c} times, expected ~{expect}"
            );
        }
    }

    #[test]
    fn disabled_local_reg_is_inert() {
        let mut lr = LocalReg::disabled();
        for i in 0..20 {
            lr.on_accept(&view(i, 0.5, 1e-3, 0.0));
        }
        assert_eq!(lr.sampled_step(), None);
        assert_eq!(lr.value(), 0.0);
    }

    #[test]
    fn local_reg_is_deterministic_in_seed() {
        let run = |seed: u64| {
            let mut lr = LocalReg::new(seed);
            for i in 0..50 {
                lr.on_accept(&view(i, 0.1, (i as f64 + 1.0) * 1e-4, 0.0));
            }
            (lr.sampled_step(), lr.value())
        };
        assert_eq!(run(3), run(3));
    }
}
