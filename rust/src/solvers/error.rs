//! Typed solver failures: the failure-containment contract of the stack.
//!
//! Every integration driven through [`super::ode::drive`],
//! [`super::sde::drive`] or the unified [`super::driver::solve`] returns
//! `Result<SolveOutcome, SolveError>` — there is no silent truncation and
//! no panic reachable from user input.  A [`SolveError`] names *why* the
//! solve failed ([`SolveErrorKind`]) and carries the last committed state
//! and the realized [`Stats`], so callers (the budget ladder, the serving
//! batcher, the CLI) can decide whether to retry, escalate, shed or
//! surface the failure without re-deriving any of the work done.
//!
//! The kinds map one-to-one onto stable wire strings
//! ([`SolveErrorKind::as_str`] / [`SolveErrorKind::parse`]) so the
//! serving protocol can carry the failure class to remote clients
//! (DESIGN.md §Robustness).

use super::ode::{SolveOutcome, Stats};
use std::fmt;

/// Why a solve failed.  `Copy` so it can ride inside
/// [`crate::runtime::state::Metrics`] and across thread boundaries.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SolveErrorKind {
    /// A proposed state or embedded error went NaN/±inf mid-attempt (a
    /// learned vector field blew up).  Detected at step-attempt
    /// granularity — the seed ground at an unchanged step size until the
    /// budget died because `q = NaN` rejects forever.
    NonFiniteState,
    /// The controller drove the step size below [`super::controller::EPS`]
    /// after a rejection: even the floor step cannot meet tolerance (the
    /// stiff-region failure mode `R_S` exists to steer away from).
    StepSizeUnderflow,
    /// The [`super::driver::StepBudget`] was exhausted before reaching
    /// the end of the span (previously a silent `success = false`
    /// truncation).
    BudgetExhausted,
    /// The [`super::driver::Taping`] variant does not match the system's
    /// stack (ODE tape for a diffusive system or vice versa).
    TapeMismatch,
    /// A non-finite / non-increasing span or malformed save grid.
    BadSpan,
    /// A diffusive system was solved without an RNG.
    MissingRng,
}

impl SolveErrorKind {
    /// Stable wire identifier (serving protocol `kind` field).  The L3
    /// wire-stability lint (`rust/tools/analyze`) extracts these strings
    /// and diffs them against the committed `wire_registry.txt`.
    // analyze: wire(solve-error-kind)
    pub fn as_str(self) -> &'static str {
        match self {
            SolveErrorKind::NonFiniteState => "non_finite_state",
            SolveErrorKind::StepSizeUnderflow => "step_size_underflow",
            SolveErrorKind::BudgetExhausted => "budget_exhausted",
            SolveErrorKind::TapeMismatch => "tape_mismatch",
            SolveErrorKind::BadSpan => "bad_span",
            SolveErrorKind::MissingRng => "missing_rng",
        }
    }

    /// Inverse of [`as_str`](Self::as_str) for client-side decoding.
    // analyze: wire(solve-error-kind)
    pub fn parse(s: &str) -> Option<SolveErrorKind> {
        Some(match s {
            "non_finite_state" => SolveErrorKind::NonFiniteState,
            "step_size_underflow" => SolveErrorKind::StepSizeUnderflow,
            "budget_exhausted" => SolveErrorKind::BudgetExhausted,
            "tape_mismatch" => SolveErrorKind::TapeMismatch,
            "bad_span" => SolveErrorKind::BadSpan,
            "missing_rng" => SolveErrorKind::MissingRng,
            _ => return None,
        })
    }
}

impl fmt::Display for SolveErrorKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.as_str())
    }
}

/// A failed solve: the failure class plus everything the solve realized
/// before dying, so callers can inspect partial work (the saves returned
/// alongside stay grid-shaped, repeating the last committed state).
#[derive(Clone, Debug)]
pub struct SolveError {
    pub kind: SolveErrorKind,
    /// Integration time reached when the solve failed.
    pub t: f64,
    /// Last committed state (the proposed non-finite state is never
    /// committed, so this is finite whenever the initial state was).
    pub z: Vec<f64>,
    /// Solver work realized before the failure (NFE, accepts, rejects,
    /// regularizer accumulators).
    pub stats: Stats,
}

impl fmt::Display for SolveError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "solve failed: {} at t={} after {} attempts ({} nfe)",
            self.kind,
            self.t,
            self.stats.attempts(),
            self.stats.nfe
        )
    }
}

impl std::error::Error for SolveError {}

/// The return type of every drive in this suite.
pub type SolveResult = Result<SolveOutcome, SolveError>;

/// Uniform accessors over `Result<SolveOutcome, SolveError>` — both arms
/// carry a final state, a final time and realized stats, and most
/// callers (training passes, data generation, benches) want those
/// regardless of which arm they got.
pub trait SolveResultExt {
    /// Realized statistics, success or not.
    fn stats(&self) -> Stats;
    /// The failure kind, `None` on success.
    fn error_kind(&self) -> Option<SolveErrorKind>;
    /// `true` on the `Ok` arm (the seed's `success` flag).
    fn is_success(&self) -> bool;
    /// Decompose into `(z_final, t_final, stats, error_kind)`.
    fn into_parts(self) -> (Vec<f64>, f64, Stats, Option<SolveErrorKind>);
}

impl SolveResultExt for SolveResult {
    fn stats(&self) -> Stats {
        match self {
            Ok(o) => o.stats,
            Err(e) => e.stats,
        }
    }

    fn error_kind(&self) -> Option<SolveErrorKind> {
        match self {
            Ok(_) => None,
            Err(e) => Some(e.kind),
        }
    }

    fn is_success(&self) -> bool {
        self.is_ok()
    }

    fn into_parts(self) -> (Vec<f64>, f64, Stats, Option<SolveErrorKind>) {
        match self {
            Ok(o) => (o.z, o.t, o.stats, None),
            Err(e) => (e.z, e.t, e.stats, Some(e.kind)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wire_strings_round_trip() {
        for kind in [
            SolveErrorKind::NonFiniteState,
            SolveErrorKind::StepSizeUnderflow,
            SolveErrorKind::BudgetExhausted,
            SolveErrorKind::TapeMismatch,
            SolveErrorKind::BadSpan,
            SolveErrorKind::MissingRng,
        ] {
            assert_eq!(SolveErrorKind::parse(kind.as_str()), Some(kind));
        }
        assert_eq!(SolveErrorKind::parse("garbage"), None);
    }

    #[test]
    fn display_names_the_failure() {
        let e = SolveError {
            kind: SolveErrorKind::NonFiniteState,
            t: 0.5,
            z: vec![1.0],
            stats: Stats::default(),
        };
        let s = e.to_string();
        assert!(s.contains("non_finite_state") && s.contains("t=0.5"), "{s}");
    }

    #[test]
    fn result_ext_covers_both_arms() {
        let ok: SolveResult = Ok(SolveOutcome {
            z: vec![2.0],
            t: 1.0,
            stats: Stats::default(),
        });
        assert!(ok.is_success());
        assert_eq!(ok.error_kind(), None);
        let err: SolveResult = Err(SolveError {
            kind: SolveErrorKind::BudgetExhausted,
            t: 0.3,
            z: vec![1.5],
            stats: Stats::default(),
        });
        assert!(!err.is_success());
        assert_eq!(err.error_kind(), Some(SolveErrorKind::BudgetExhausted));
        let (z, t, _, kind) = err.into_parts();
        assert_eq!((z, t, kind), (vec![1.5], 0.3, Some(SolveErrorKind::BudgetExhausted)));
    }
}
