//! Shared step-size controller: the white-boxed heuristics every adaptive
//! solver in this suite steers by.
//!
//! Before this module existed, `ode.rs` and `sde.rs` each carried their own
//! copy of the SAFETY / MIN_FACTOR / MAX_FACTOR / PI_BETA constants and the
//! Hairer error norm — two copies that could silently drift apart (and drift
//! away from python/compile/norms.py, which both must mirror).  Everything
//! tolerance- and controller-related now lives here, once.
//!
//! Semantics are bit-for-bit those of the seed solvers: Hairer RMS error
//! norm over the tolerance-scaled embedded error (paper Eq. 5), PI
//! controller gains (Eq. 6) with `alpha = 1/order - 0.75 * beta`, and the
//! plain rejection backoff clamped to never grow the step.

/// Step-shrink/grow safety factor (keep in sync with python/compile/norms.py).
pub const SAFETY: f64 = 0.9;
/// Hard lower clamp on any step-size change factor.
pub const MIN_FACTOR: f64 = 0.2;
/// Hard upper clamp on any step-size change factor.
pub const MAX_FACTOR: f64 = 10.0;
/// PI controller integral gain (Eq. 6).
pub const PI_BETA: f64 = 0.04;
/// Generic tiny guard against division by zero / degenerate spans.
pub const EPS: f64 = 1e-12;
/// Denormal-safe floor added under every RMS square root in this suite
/// ([`rms`], [`error_ratio`], [`stiffness_norm`], and the replayed error
/// norms in `solvers::adjoint`): a zero vector yields ~1e-150 instead of
/// 0, so downstream ratios never divide by exactly zero.
pub const RMS_FLOOR: f64 = 1e-300;

/// Plain RMS norm with the [`RMS_FLOOR`] denormal floor (used for `E_j`
/// and the Shampine stiffness ratio numerator/denominator).
#[inline]
pub fn rms(v: &[f64]) -> f64 {
    // Explicit left-to-right fold: the accumulation order is part of the
    // bit-exactness contract (DESIGN.md §Perf), so spell it out rather
    // than lean on `Iterator::sum` being sequential.
    let mut sq = 0.0;
    for x in v {
        sq += x * x;
    }
    (sq / v.len() as f64 + RMS_FLOOR).sqrt()
}

/// Floored RMS from a squared-sum accumulator: `sqrt(sq / n + RMS_FLOOR)`.
/// Same FP sequence as [`rms`] over a materialized difference vector,
/// without needing the scratch (DESIGN.md §Perf).
#[inline]
pub fn stiffness_norm(sq: f64, n: usize) -> f64 {
    (sq / n as f64 + RMS_FLOOR).sqrt()
}

/// Shampine stiffness ratio (paper Eq. 8) from squared-sum accumulators.
///
/// **The** single epsilon convention for the stiffness estimate, shared
/// by the forward steppers (`ode.rs` / `sde.rs`), the discrete adjoint
/// and the replay paths (`adjoint.rs`) so forward and backward FP
/// sequences stay bit-identical: both norms carry the [`RMS_FLOOR`]
/// denormal floor inside their square roots (never-zero, never-NaN), and
/// the denominator norm additionally gets `+ EPS` so a fixed point
/// (`g_y == g_x`) reads as "not stiff" (~0) rather than overflowing.
#[inline]
pub fn stiffness_ratio(num_sq: f64, den_sq: f64, n: usize) -> f64 {
    stiffness_norm(num_sq, n) / (stiffness_norm(den_sq, n) + EPS)
}

/// Hairer tolerance-scaled error ratio (paper Eq. 5): RMS of
/// `e_i / (atol + max(|z0_i|, |z1_i|) * rtol)`.  `q <= 1` accepts the step.
#[inline]
pub fn error_ratio(e: &[f64], z0: &[f64], z1: &[f64], rtol: f64, atol: f64) -> f64 {
    let mut acc = 0.0;
    for i in 0..e.len() {
        let scale = atol + z0[i].abs().max(z1[i].abs()) * rtol;
        let r = e[i] / scale;
        acc += r * r;
    }
    (acc / e.len() as f64 + RMS_FLOOR).sqrt()
}

/// PI controller growth factor after an accepted step (paper Eq. 6):
/// `SAFETY * q^-(1/order - 0.75 beta) * q_prev^beta`, clamped to
/// [MIN_FACTOR, MAX_FACTOR].
#[inline]
pub fn pi_factor(q: f64, q_prev: f64, order: usize) -> f64 {
    let alpha = 1.0 / order as f64 - 0.75 * PI_BETA;
    let f = SAFETY * q.max(1e-10).powf(-alpha) * q_prev.max(1e-10).powf(PI_BETA);
    f.clamp(MIN_FACTOR, MAX_FACTOR)
}

/// Shrink factor after a rejected step: `SAFETY * q^-(1/order)`, clamped to
/// [MIN_FACTOR, 1] so a rejection can never grow the step.
#[inline]
pub fn reject_factor(q: f64, order: usize) -> f64 {
    let alpha = 1.0 / order as f64;
    (SAFETY * q.max(1e-10).powf(-alpha)).clamp(MIN_FACTOR, 1.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rms_of_zeros_is_tiny_not_nan() {
        let r = rms(&[0.0, 0.0, 0.0]);
        assert!(r > 0.0 && r < 1e-100);
    }

    #[test]
    fn error_ratio_scales_with_tolerance() {
        let e = [1e-6, -1e-6];
        let z = [1.0, 1.0];
        let loose = error_ratio(&e, &z, &z, 1e-3, 1e-3);
        let tight = error_ratio(&e, &z, &z, 1e-9, 1e-9);
        assert!(loose < 1.0, "loose={loose}");
        assert!(tight > 1.0, "tight={tight}");
    }

    #[test]
    fn pi_factor_grows_on_small_error() {
        // q far below 1 => grow, clamped at MAX_FACTOR.
        assert_eq!(pi_factor(1e-10, 1.0, 5), MAX_FACTOR);
        // q exactly at the accept boundary => shrink slightly (SAFETY).
        let f = pi_factor(1.0, 1.0, 5);
        assert!(f < 1.0 && f > 0.5, "f={f}");
    }

    #[test]
    fn reject_factor_never_grows() {
        for q in [1.0001, 2.0, 10.0, 1e6] {
            let f = reject_factor(q, 5);
            assert!((MIN_FACTOR..=1.0).contains(&f), "q={q} f={f}");
        }
    }

    #[test]
    fn factors_clamped_below() {
        assert_eq!(pi_factor(1e12, 1.0, 5), MIN_FACTOR);
        assert_eq!(reject_factor(1e12, 5), MIN_FACTOR);
    }

    #[test]
    fn stiffness_norm_matches_rms_bits() {
        // The scalar-accumulator path must reproduce rms() exactly.
        let v = [0.3, -1.7, 2.5];
        let sq: f64 = v.iter().map(|x| x * x).sum();
        assert_eq!(stiffness_norm(sq, v.len()), rms(&v));
    }

    #[test]
    fn stiffness_ratio_guards() {
        // True fixed point (both differences zero): ~0, not NaN.
        let fp = stiffness_ratio(0.0, 0.0, 2);
        assert!(fp.is_finite() && fp < 1.0, "fp={fp}");
        // Zero denominator alone: EPS-bounded, finite.
        let s = stiffness_ratio(1.0, 0.0, 2);
        assert!(s.is_finite());
        // Zero numerator: tiny but nonzero (floor over EPS-padded norm).
        let z = stiffness_ratio(0.0, 1.0, 2);
        assert!(z.is_finite() && z < 1e-100);
        // Plain case: ratio of the two RMS norms.
        let r = stiffness_ratio(4.0, 1.0, 1);
        assert!((r - 2.0 / (1.0 + EPS)).abs() < 1e-15, "r={r}");
    }
}
