//! Explicit embedded Runge-Kutta tableaus (mirror of python tableaus.py).
//!
//! Constants are kept bit-for-bit identical to the Python side so the two
//! solver stacks can be cross-validated trajectory-for-trajectory.

/// An explicit embedded RK tableau (see python/compile/tableaus.py).
#[derive(Clone, Debug)]
pub struct Tableau {
    pub name: &'static str,
    /// Strictly lower-triangular stage matrix, row-major `a[i][j]`, i < s.
    pub a: Vec<Vec<f64>>,
    /// Higher-order solution weights.
    pub b: Vec<f64>,
    /// `b - bhat` embedded difference weights (error estimate).
    pub btilde: Vec<f64>,
    /// Stage abscissae.
    pub c: Vec<f64>,
    pub order: usize,
    pub fsal: bool,
    /// Stage index pair with equal `c` for the Shampine stiffness ratio.
    pub stiff_pair: (usize, usize),
}

impl Tableau {
    pub fn stages(&self) -> usize {
        self.b.len()
    }

    pub fn nfe_per_attempt(&self) -> usize {
        if self.fsal {
            self.stages() - 1
        } else {
            self.stages()
        }
    }

    /// Tsitouras 5(4) — the paper's Neural-ODE solver.
    pub fn tsit5() -> Tableau {
        Tableau {
            name: "tsit5",
            a: vec![
                vec![],
                vec![0.161],
                vec![-0.008480655492356989, 0.335480655492357],
                vec![2.8971530571054935, -6.359448489975075, 4.3622954328695815],
                vec![
                    5.325864828439257,
                    -11.748883564062828,
                    7.4955393428898365,
                    -0.09249506636175525,
                ],
                vec![
                    5.86145544294642,
                    -12.92096931784711,
                    8.159367898576159,
                    -0.071584973281401,
                    -0.028269050394068383,
                ],
                vec![
                    0.09646076681806523,
                    0.01,
                    0.4798896504144996,
                    1.379008574103742,
                    -3.290069515436081,
                    2.324710524099774,
                ],
            ],
            b: vec![
                0.09646076681806523,
                0.01,
                0.4798896504144996,
                1.379008574103742,
                -3.290069515436081,
                2.324710524099774,
                0.0,
            ],
            btilde: vec![
                -0.00178001105222577714,
                -0.0008164344596567469,
                0.007880878010261995,
                -0.1447110071732629,
                0.5823571654525552,
                -0.45808210592918697,
                0.015151515151515152,
            ],
            c: vec![0.0, 0.161, 0.327, 0.9, 0.9800255409045097, 1.0, 1.0],
            order: 5,
            fsal: true,
            stiff_pair: (5, 6),
        }
    }

    /// Dormand-Prince 5(4).
    pub fn dopri5() -> Tableau {
        let b = vec![
            35.0 / 384.0,
            0.0,
            500.0 / 1113.0,
            125.0 / 192.0,
            -2187.0 / 6784.0,
            11.0 / 84.0,
            0.0,
        ];
        let bhat = [
            5179.0 / 57600.0,
            0.0,
            7571.0 / 16695.0,
            393.0 / 640.0,
            -92097.0 / 339200.0,
            187.0 / 2100.0,
            1.0 / 40.0,
        ];
        Tableau {
            name: "dopri5",
            a: vec![
                vec![],
                vec![1.0 / 5.0],
                vec![3.0 / 40.0, 9.0 / 40.0],
                vec![44.0 / 45.0, -56.0 / 15.0, 32.0 / 9.0],
                vec![
                    19372.0 / 6561.0,
                    -25360.0 / 2187.0,
                    64448.0 / 6561.0,
                    -212.0 / 729.0,
                ],
                vec![
                    9017.0 / 3168.0,
                    -355.0 / 33.0,
                    46732.0 / 5247.0,
                    49.0 / 176.0,
                    -5103.0 / 18656.0,
                ],
                vec![
                    35.0 / 384.0,
                    0.0,
                    500.0 / 1113.0,
                    125.0 / 192.0,
                    -2187.0 / 6784.0,
                    11.0 / 84.0,
                ],
            ],
            btilde: b.iter().zip(bhat.iter()).map(|(x, y)| x - y).collect(),
            b,
            c: vec![0.0, 0.2, 0.3, 0.8, 8.0 / 9.0, 1.0, 1.0],
            order: 5,
            fsal: true,
            stiff_pair: (5, 6),
        }
    }

    /// Bogacki-Shampine 3(2).
    pub fn bs3() -> Tableau {
        let b = vec![2.0 / 9.0, 1.0 / 3.0, 4.0 / 9.0, 0.0];
        let bhat = [7.0 / 24.0, 0.25, 1.0 / 3.0, 0.125];
        Tableau {
            name: "bs3",
            a: vec![
                vec![],
                vec![0.5],
                vec![0.0, 0.75],
                vec![2.0 / 9.0, 1.0 / 3.0, 4.0 / 9.0],
            ],
            btilde: b.iter().zip(bhat.iter()).map(|(x, y)| x - y).collect(),
            b,
            c: vec![0.0, 0.5, 0.75, 1.0],
            order: 3,
            fsal: true,
            stiff_pair: (0, 3),
        }
    }

    pub fn by_name(name: &str) -> Option<Tableau> {
        match name {
            "tsit5" => Some(Self::tsit5()),
            "dopri5" => Some(Self::dopri5()),
            "bs3" => Some(Self::bs3()),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Order conditions: sum(b) == 1 and sum(b*c) == 1/2 for every tableau.
    #[test]
    fn order_conditions() {
        for tab in [Tableau::tsit5(), Tableau::dopri5(), Tableau::bs3()] {
            let sb: f64 = tab.b.iter().sum();
            assert!((sb - 1.0).abs() < 1e-12, "{}: sum b = {sb}", tab.name);
            let sbc: f64 = tab.b.iter().zip(&tab.c).map(|(b, c)| b * c).sum();
            assert!((sbc - 0.5).abs() < 1e-12, "{}: sum b*c = {sbc}", tab.name);
        }
    }

    /// Row sums of `a` equal `c` (consistency condition).
    #[test]
    fn row_sums_match_c() {
        for tab in [Tableau::tsit5(), Tableau::dopri5(), Tableau::bs3()] {
            for (i, row) in tab.a.iter().enumerate() {
                let rs: f64 = row.iter().sum();
                assert!(
                    (rs - tab.c[i]).abs() < 1e-9,
                    "{} row {i}: {rs} vs c {}",
                    tab.name,
                    tab.c[i]
                );
            }
        }
    }

    /// The embedded difference sums to ~0 (both solutions are consistent).
    #[test]
    fn btilde_sums_to_zero() {
        for tab in [Tableau::tsit5(), Tableau::dopri5(), Tableau::bs3()] {
            let s: f64 = tab.btilde.iter().sum();
            assert!(s.abs() < 1e-12, "{}: sum btilde = {s}", tab.name);
        }
    }

    /// FSAL: the final stage row of `a` equals `b[..s-1]`.
    #[test]
    fn fsal_rows() {
        for tab in [Tableau::tsit5(), Tableau::dopri5()] {
            let last = &tab.a[tab.stages() - 1];
            for (j, a) in last.iter().enumerate() {
                assert!((a - tab.b[j]).abs() < 1e-12, "{} col {j}", tab.name);
            }
        }
    }

    #[test]
    fn stiff_pair_has_equal_c() {
        for tab in [Tableau::tsit5(), Tableau::dopri5()] {
            let (x, y) = tab.stiff_pair;
            assert_eq!(tab.c[x], tab.c[y], "{}", tab.name);
        }
    }

    #[test]
    fn lookup_by_name() {
        assert!(Tableau::by_name("tsit5").is_some());
        assert!(Tableau::by_name("rk4").is_none());
    }
}
