//! Explicit embedded Runge-Kutta tableaus (mirror of python tableaus.py).
//!
//! Constants are kept bit-for-bit identical to the Python side so the two
//! solver stacks can be cross-validated trajectory-for-trajectory.

/// An explicit embedded RK tableau (see python/compile/tableaus.py).
#[derive(Clone, Debug)]
pub struct Tableau {
    pub name: &'static str,
    /// Strictly lower-triangular stage matrix, row-major `a[i][j]`, i < s.
    pub a: Vec<Vec<f64>>,
    /// Higher-order solution weights.
    pub b: Vec<f64>,
    /// `b - bhat` embedded difference weights (error estimate).
    pub btilde: Vec<f64>,
    /// Stage abscissae.
    pub c: Vec<f64>,
    pub order: usize,
    pub fsal: bool,
    /// Stage index pair with equal `c` for the Shampine stiffness ratio.
    pub stiff_pair: (usize, usize),
}

impl Tableau {
    pub fn stages(&self) -> usize {
        self.b.len()
    }

    pub fn nfe_per_attempt(&self) -> usize {
        if self.fsal {
            self.stages() - 1
        } else {
            self.stages()
        }
    }

    /// Tsitouras 5(4) — the paper's Neural-ODE solver.
    pub fn tsit5() -> Tableau {
        Tableau {
            name: "tsit5",
            a: vec![
                vec![],
                vec![0.161],
                vec![-0.008480655492356989, 0.335480655492357],
                vec![2.8971530571054935, -6.359448489975075, 4.3622954328695815],
                vec![
                    5.325864828439257,
                    -11.748883564062828,
                    7.4955393428898365,
                    -0.09249506636175525,
                ],
                vec![
                    5.86145544294642,
                    -12.92096931784711,
                    8.159367898576159,
                    -0.071584973281401,
                    -0.028269050394068383,
                ],
                vec![
                    0.09646076681806523,
                    0.01,
                    0.4798896504144996,
                    1.379008574103742,
                    -3.290069515436081,
                    2.324710524099774,
                ],
            ],
            b: vec![
                0.09646076681806523,
                0.01,
                0.4798896504144996,
                1.379008574103742,
                -3.290069515436081,
                2.324710524099774,
                0.0,
            ],
            btilde: vec![
                -0.00178001105222577714,
                -0.0008164344596567469,
                0.007880878010261995,
                -0.1447110071732629,
                0.5823571654525552,
                -0.45808210592918697,
                0.015151515151515152,
            ],
            c: vec![0.0, 0.161, 0.327, 0.9, 0.9800255409045097, 1.0, 1.0],
            order: 5,
            fsal: true,
            stiff_pair: (5, 6),
        }
    }

    /// Dormand-Prince 5(4).
    pub fn dopri5() -> Tableau {
        let b = vec![
            35.0 / 384.0,
            0.0,
            500.0 / 1113.0,
            125.0 / 192.0,
            -2187.0 / 6784.0,
            11.0 / 84.0,
            0.0,
        ];
        let bhat = [
            5179.0 / 57600.0,
            0.0,
            7571.0 / 16695.0,
            393.0 / 640.0,
            -92097.0 / 339200.0,
            187.0 / 2100.0,
            1.0 / 40.0,
        ];
        Tableau {
            name: "dopri5",
            a: vec![
                vec![],
                vec![1.0 / 5.0],
                vec![3.0 / 40.0, 9.0 / 40.0],
                vec![44.0 / 45.0, -56.0 / 15.0, 32.0 / 9.0],
                vec![
                    19372.0 / 6561.0,
                    -25360.0 / 2187.0,
                    64448.0 / 6561.0,
                    -212.0 / 729.0,
                ],
                vec![
                    9017.0 / 3168.0,
                    -355.0 / 33.0,
                    46732.0 / 5247.0,
                    49.0 / 176.0,
                    -5103.0 / 18656.0,
                ],
                vec![
                    35.0 / 384.0,
                    0.0,
                    500.0 / 1113.0,
                    125.0 / 192.0,
                    -2187.0 / 6784.0,
                    11.0 / 84.0,
                ],
            ],
            btilde: b.iter().zip(bhat.iter()).map(|(x, y)| x - y).collect(),
            b,
            c: vec![0.0, 0.2, 0.3, 0.8, 8.0 / 9.0, 1.0, 1.0],
            order: 5,
            fsal: true,
            stiff_pair: (5, 6),
        }
    }

    /// Bogacki-Shampine 3(2).
    ///
    /// BS3 has no two distinct stages sharing an abscissa (`c = [0, 1/2,
    /// 3/4, 1]`), so there is no valid Shampine pair: `stiff_pair` is the
    /// degenerate `(3, 3)`, which makes the stiffness estimate read ~0
    /// ("not stiff") through every path — forward accumulation, adjoint
    /// and replay — instead of the seed's bogus `(0, 3)` pair that
    /// compared stages evaluated at *different* times (`c` 0 vs 1) and
    /// reported a time-difference artifact as stiffness.
    pub fn bs3() -> Tableau {
        let b = vec![2.0 / 9.0, 1.0 / 3.0, 4.0 / 9.0, 0.0];
        let bhat = [7.0 / 24.0, 0.25, 1.0 / 3.0, 0.125];
        Tableau {
            name: "bs3",
            a: vec![
                vec![],
                vec![0.5],
                vec![0.0, 0.75],
                vec![2.0 / 9.0, 1.0 / 3.0, 4.0 / 9.0],
            ],
            btilde: b.iter().zip(bhat.iter()).map(|(x, y)| x - y).collect(),
            b,
            c: vec![0.0, 0.5, 0.75, 1.0],
            order: 3,
            fsal: true,
            stiff_pair: (3, 3),
        }
    }

    /// The registry: `(name, constructor)` pairs — the **single source**
    /// behind [`Tableau::names`], [`Tableau::by_name`] and
    /// [`Tableau::parse`], so a newly registered scheme is automatically
    /// listed in the CLI usage/error text and covered by the registry
    /// invariants test.
    const REGISTRY: &'static [(&'static str, fn() -> Tableau)] = &[
        ("tsit5", Tableau::tsit5),
        ("dopri5", Tableau::dopri5),
        ("bs3", Tableau::bs3),
    ];

    /// Every registered tableau name, in lookup order.
    pub fn names() -> Vec<&'static str> {
        Self::REGISTRY.iter().map(|&(n, _)| n).collect()
    }

    /// Case-insensitive lookup (`"tsit5"`, `"DoPri5"`, ...).  Returns
    /// `None` for unknown names; prefer [`Tableau::parse`] at user-facing
    /// boundaries, where the error lists the registry.
    pub fn by_name(name: &str) -> Option<Tableau> {
        let lower = name.to_ascii_lowercase();
        Self::REGISTRY
            .iter()
            .find(|&&(n, _)| n == lower)
            .map(|&(_, make)| make())
    }

    /// [`Tableau::by_name`] with a helpful error naming the known
    /// tableaus — the CLI-boundary lookup (`regnde run --solver <name>`).
    pub fn parse(name: &str) -> Result<Tableau, String> {
        Self::by_name(name).ok_or_else(|| {
            format!(
                "unknown solver tableau {name:?}; known tableaus (case-insensitive): {}",
                Self::names().join(", ")
            )
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Every tableau in the registry, by name (so a registered name that
    /// `Tableau::by_name` cannot resolve fails loudly).
    fn registry() -> Vec<Tableau> {
        Tableau::names()
            .into_iter()
            .map(|n| Tableau::by_name(n).expect("registered name must resolve"))
            .collect()
    }

    /// Structural + order invariants, asserted for **every** registered
    /// tableau (the property the registry promises, not a per-scheme
    /// spot-check):
    ///
    /// 1. shapes: `a`/`b`/`btilde`/`c` all sized to `stages()`, `a`
    ///    strictly lower-triangular (`a[i].len() == i`, explicit scheme);
    /// 2. consistency: `Σ_j a[i][j] = c[i]` per row;
    /// 3. order conditions: `Σ b = 1`, `Σ b·c = 1/2`;
    /// 4. embedded difference: `Σ btilde = 0`;
    /// 5. a genuinely equal-`c` `stiff_pair` (the Shampine ratio compares
    ///    stage values at the *same* abscissa; a degenerate `(i, i)` pair
    ///    declares "no Shampine pair" and reads as not-stiff);
    /// 6. FSAL coherence: when `fsal`, the last row of `a` equals
    ///    `b[..s-1]` and `b[s-1] = 0`, with `c[s-1] = 1`.
    #[test]
    fn registry_invariants() {
        let tabs = registry();
        assert_eq!(tabs.len(), Tableau::names().len());
        for tab in &tabs {
            let s = tab.stages();
            let name = tab.name;
            // 1. shapes
            assert_eq!(tab.a.len(), s, "{name}: a rows");
            assert_eq!(tab.btilde.len(), s, "{name}: btilde len");
            assert_eq!(tab.c.len(), s, "{name}: c len");
            for (i, row) in tab.a.iter().enumerate() {
                assert_eq!(row.len(), i, "{name}: a[{i}] must be strictly lower-triangular");
            }
            assert!((1..=s).contains(&tab.order), "{name}: order sane");
            // 2. row-sum consistency
            for (i, row) in tab.a.iter().enumerate() {
                let rs: f64 = row.iter().sum();
                assert!(
                    (rs - tab.c[i]).abs() < 1e-9,
                    "{name} row {i}: Σa = {rs} vs c = {}",
                    tab.c[i]
                );
            }
            // 3. order conditions
            let sb: f64 = tab.b.iter().sum();
            assert!((sb - 1.0).abs() < 1e-12, "{name}: Σb = {sb}");
            let sbc: f64 = tab.b.iter().zip(&tab.c).map(|(b, c)| b * c).sum();
            assert!((sbc - 0.5).abs() < 1e-12, "{name}: Σb·c = {sbc}");
            // 4. embedded difference
            let sbt: f64 = tab.btilde.iter().sum();
            assert!(sbt.abs() < 1e-12, "{name}: Σbtilde = {sbt}");
            // 5. equal-c stiffness pair
            let (x, y) = tab.stiff_pair;
            assert!(x < s && y < s, "{name}: stiff_pair in range");
            assert_eq!(
                tab.c[x], tab.c[y],
                "{name}: stiff_pair ({x}, {y}) must share an abscissa"
            );
            // 6. FSAL coherence
            if tab.fsal {
                let last = &tab.a[s - 1];
                for (j, a) in last.iter().enumerate() {
                    assert!(
                        (a - tab.b[j]).abs() < 1e-12,
                        "{name}: FSAL row col {j}: {a} vs b {}",
                        tab.b[j]
                    );
                }
                assert_eq!(tab.b[s - 1], 0.0, "{name}: FSAL weight of the reused stage");
                assert!(
                    (tab.c[s - 1] - 1.0).abs() < 1e-12,
                    "{name}: FSAL stage sits at the step end"
                );
            }
        }
    }

    /// The proper (non-degenerate) Shampine pairs really are two distinct
    /// stages, and the only degenerate pair is BS3's documented one.
    #[test]
    fn stiff_pairs_distinct_where_a_pair_exists() {
        for tab in registry() {
            let (x, y) = tab.stiff_pair;
            if tab.name == "bs3" {
                assert_eq!((x, y), (3, 3), "bs3 has no equal-c pair (degenerate)");
            } else {
                assert_ne!(x, y, "{}: pair must be two distinct stages", tab.name);
            }
        }
    }

    #[test]
    fn lookup_by_name_is_case_insensitive() {
        assert!(Tableau::by_name("tsit5").is_some());
        assert_eq!(Tableau::by_name("DoPri5").unwrap().name, "dopri5");
        assert_eq!(Tableau::by_name("BS3").unwrap().name, "bs3");
        assert!(Tableau::by_name("rk4").is_none());
    }

    #[test]
    fn parse_error_lists_known_tableaus() {
        assert_eq!(Tableau::parse("TSIT5").unwrap().name, "tsit5");
        let err = Tableau::parse("rk4").unwrap_err();
        for name in Tableau::names() {
            assert!(err.contains(name), "error must list {name}: {err}");
        }
        assert!(err.contains("rk4"), "error must echo the bad name: {err}");
    }
}
