//! Fault injection for the solver stack: [`ChaosSystem`] wraps any
//! [`System`] and perturbs its dynamics at configured evaluation indices.
//!
//! This is the solver half of the fault-injection harness
//! (`tests/fault_injection.rs`, DESIGN.md §Robustness): instead of
//! hand-crafting a pathological vector field per failure mode, wrap the
//! real one and dial in the fault —
//!
//! * **NaN drift** ([`ChaosConfig::nan_drift_at`]) — the k-th drift
//!   evaluation returns NaN, modelling a learned vector field blowing up
//!   mid-solve.  Must surface as
//!   [`SolveErrorKind::NonFiniteState`](super::error::SolveErrorKind).
//! * **Forced rejects** ([`ChaosConfig::huge_drift_from`]) — from the
//!   k-th evaluation on, the drift is scaled by a huge factor so the
//!   embedded error can never meet tolerance, modelling a stiff region.
//!   Must surface as `StepSizeUnderflow` or `BudgetExhausted`.
//! * **Slow evaluations** ([`ChaosConfig::sleep_every`]) — every m-th
//!   evaluation sleeps, modelling an expensive model under load.  Must
//!   only slow the solve down (deadline/shed territory at the serving
//!   layer), never change its result.
//!
//! Faults trigger on the wrapper's own evaluation counter
//! ([`ChaosSystem::evals`]), counting drift and diffusion evaluations in
//! call order, so injection points are deterministic for a given solve.

use super::system::System;
use std::time::Duration;

/// Which faults to inject and where (evaluation indices are 0-based and
/// count drift + diffusion calls in order).
#[derive(Clone, Debug, Default)]
pub struct ChaosConfig {
    /// Overwrite the drift with NaN on this evaluation index.
    pub nan_drift_at: Option<u64>,
    /// Scale the drift by `1e12` from this evaluation index on, forcing
    /// step rejections until the controller underflows or the budget
    /// dies.
    pub huge_drift_from: Option<u64>,
    /// Sleep `(every m-th evaluation, duration)` — a slow model.
    pub sleep_every: Option<(u64, Duration)>,
}

impl ChaosConfig {
    pub fn nan_at(at: u64) -> ChaosConfig {
        ChaosConfig {
            nan_drift_at: Some(at),
            ..Default::default()
        }
    }

    pub fn huge_from(at: u64) -> ChaosConfig {
        ChaosConfig {
            huge_drift_from: Some(at),
            ..Default::default()
        }
    }

    pub fn slow(every: u64, dur: Duration) -> ChaosConfig {
        ChaosConfig {
            sleep_every: Some((every, dur)),
            ..Default::default()
        }
    }
}

/// A [`System`] wrapper injecting the faults of a [`ChaosConfig`] into
/// an inner system.  Forwards everything (diffusion flag, VJP hooks)
/// unchanged; with an all-`None` config the wrapped solve is
/// bit-identical to the bare one.
pub struct ChaosSystem<S: System> {
    pub inner: S,
    pub cfg: ChaosConfig,
    /// Evaluations (drift + diffusion) seen so far.
    pub evals: u64,
}

impl<S: System> ChaosSystem<S> {
    pub fn new(inner: S, cfg: ChaosConfig) -> ChaosSystem<S> {
        ChaosSystem {
            inner,
            cfg,
            evals: 0,
        }
    }

    fn tick(&mut self) -> u64 {
        let i = self.evals;
        self.evals += 1;
        if let Some((every, dur)) = self.cfg.sleep_every {
            if every > 0 && i % every == every - 1 {
                std::thread::sleep(dur);
            }
        }
        i
    }
}

impl<S: System> System for ChaosSystem<S> {
    fn drift(&mut self, z: &[f64], t: f64, dz: &mut [f64]) {
        let i = self.tick();
        self.inner.drift(z, t, dz);
        if self.cfg.nan_drift_at == Some(i) {
            dz.fill(f64::NAN);
        }
        if let Some(from) = self.cfg.huge_drift_from {
            if i >= from {
                for v in dz.iter_mut() {
                    *v *= 1e12;
                    if *v == 0.0 {
                        *v = 1e12;
                    }
                }
            }
        }
    }

    fn has_diffusion(&self) -> bool {
        self.inner.has_diffusion()
    }

    fn diffusion(&mut self, z: &[f64], t: f64, dg: &mut [f64]) {
        self.tick();
        self.inner.diffusion(z, t, dg);
    }

    fn drift_vjp(&mut self, z: &[f64], t: f64, w: &[f64], gz: &mut [f64], gp: &mut [f64]) {
        self.inner.drift_vjp(z, t, w, gz, gp);
    }

    fn diffusion_vjp(&mut self, z: &[f64], t: f64, w: &[f64], gz: &mut [f64], gp: &mut [f64]) {
        self.inner.diffusion_vjp(z, t, w, gz, gp);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solvers::driver::{Saveat, SolveOptions};
    use crate::solvers::error::SolveErrorKind;
    use crate::solvers::ode;
    use crate::solvers::system::OdeSystem;

    fn decay() -> OdeSystem<impl FnMut(&[f64], f64, &mut [f64])> {
        OdeSystem(|z: &[f64], _t: f64, dz: &mut [f64]| dz[0] = -z[0])
    }

    fn run(cfg: ChaosConfig) -> (Vec<Vec<f64>>, crate::solvers::error::SolveResult) {
        let mut sys = ChaosSystem::new(decay(), cfg);
        ode::drive(
            &mut sys,
            &[1.0],
            Saveat::Span { t0: 0.0, t1: 1.0 },
            &SolveOptions::new().with_tolerance(1e-7),
            None,
            &mut [],
        )
    }

    #[test]
    fn no_faults_is_transparent() {
        let (saves, out) = run(ChaosConfig::default());
        let mut bare = decay();
        let (saves_b, out_b) = ode::drive(
            &mut bare,
            &[1.0],
            Saveat::Span { t0: 0.0, t1: 1.0 },
            &SolveOptions::new().with_tolerance(1e-7),
            None,
            &mut [],
        );
        let (out, out_b) = (out.unwrap(), out_b.unwrap());
        assert_eq!(saves, saves_b, "empty chaos config must be bit-transparent");
        assert_eq!(out.stats.nfe, out_b.stats.nfe);
        assert_eq!(out.z, out_b.z);
    }

    #[test]
    fn nan_injection_surfaces_as_non_finite_state() {
        for at in [0, 1, 5, 20] {
            let (_, out) = run(ChaosConfig::nan_at(at));
            let err = out.unwrap_err();
            assert_eq!(err.kind, SolveErrorKind::NonFiniteState, "at={at}");
            assert!(err.z[0].is_finite(), "committed state stays finite");
        }
    }

    #[test]
    fn forced_rejects_surface_as_underflow_or_budget() {
        let (_, out) = run(ChaosConfig::huge_from(10));
        let err = out.unwrap_err();
        assert!(
            matches!(
                err.kind,
                SolveErrorKind::StepSizeUnderflow | SolveErrorKind::BudgetExhausted
            ),
            "{:?}",
            err.kind
        );
        assert!(err.stats.nreject > 0, "{:?}", err.stats);
    }

    #[test]
    fn slow_evals_change_nothing_but_time() {
        let (saves, out) = run(ChaosConfig::slow(7, Duration::from_micros(50)));
        let (saves_b, out_b) = run(ChaosConfig::default());
        assert_eq!(saves, saves_b);
        assert_eq!(out.unwrap().stats.nfe, out_b.unwrap().stats.nfe);
    }
}
