//! # regnde
//!
//! Production-shaped reproduction of **"Opening the Blackbox: Accelerating
//! Neural Differential Equations by Regularizing Internal Solver
//! Heuristics"** (Pal, Ma, Shah, Rackauckas — ICML 2021) as a three-layer
//! Rust + JAX + Pallas stack (AOT via HLO text / PJRT).
//!
//! * Layer 1 (build time): Pallas kernels for the dynamics MLP and RK stage
//!   combination (`python/compile/kernels/`).
//! * Layer 2 (build time): differentiable adaptive ODE/SDE solvers that
//!   white-box their local error and stiffness heuristics into R_E/R_S
//!   regularizers, plus models/optimizers, lowered once to
//!   `artifacts/*.hlo.txt` (`python/compile/`).
//! * Layer 3 (this crate): the training coordinator — data pipeline,
//!   method grid, coefficient schedules, STEER sampling, budget-ladder
//!   routing, metrics/NFE accounting — driving a [`runtime::Backend`].
//!   Two backends implement that seam: the **native** path (default) is a
//!   pure-Rust differentiable training stack — flat-parameter MLPs
//!   (`models`), discrete adjoints through the accepted steps of the
//!   adaptive solvers (`solvers::adjoint`), Adam — so the paper's method
//!   trains end-to-end with no Python or XLA anywhere; the **PJRT** path
//!   (cargo feature `pjrt`) executes the lowered artifacts with Python
//!   never on the hot path.
//!
//! The [`dist`] subsystem layers data-parallel training on the same
//! seam: a coordinator shards each gradient over loopback or remote
//! workers and reduces in a fixed tree, bit-identical to the
//! single-process run (DESIGN.md §Distributed).
//!
//! The [`obs`] subsystem is the unified observability layer — metrics
//! registry + Prometheus exposition, bounded solver-step tracing, and
//! Chrome-trace span profiling — wired through every layer above
//! (DESIGN.md §Observability).
//!
//! See DESIGN.md (§Backend for the trait contract and adjoint tape
//! layout) for the full system inventory and EXPERIMENTS.md for the
//! paper-vs-measured record.

pub mod bench;
pub mod coordinator;
pub mod data;
pub mod dist;
pub mod models;
pub mod obs;
pub mod runtime;
pub mod serve;
pub mod solvers;
pub mod util;

/// Default artifacts directory relative to the crate root.
pub fn default_artifacts_dir() -> std::path::PathBuf {
    std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// Default run-record directory.
pub fn default_runs_dir() -> std::path::PathBuf {
    std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("runs")
}
