//! `regnde` — CLI launcher for the regularized-NDE training framework.
//!
//! ```text
//! regnde list                                  # backend models (+artifacts)
//! regnde train --exp mnist-node --method ernode [--epochs N] [--iters N]
//!              [--seeds 0,1,2] [--backend native|pjrt] [--verbose]
//! regnde predict --exp mnist-node --method vanilla
//! regnde run spiral-node --method srnode+ernode --epochs 2 [--check-nfe]
//!                                              # method-vs-vanilla compare
//! regnde run spiral-node --method ernode --solver dopri5
//!                                              # pick the RK tableau
//! regnde validate                              # run every artifact (pjrt)
//! ```
//!
//! The default backend is the native discrete-adjoint trainer — no
//! artifacts or XLA required.  `--backend pjrt` selects the AOT engine
//! (requires `--features pjrt` and compiled artifacts).  `--solver`
//! picks the native backend's RK tableau by name (case-insensitive:
//! tsit5, dopri5, bs3).

use anyhow::{bail, Context, Result};

use regnde::coordinator::experiments::{self, TrainOpts};
use regnde::coordinator::recorder::Recorder;
use regnde::coordinator::Method;
use regnde::runtime::{make_backend, Backend};
use regnde::util::cli::Args;

const VALUED: &[&str] = &[
    "exp", "method", "epochs", "iters", "seeds", "artifacts", "runs", "backend", "solver",
];

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn usage() -> String {
    format!(
        "usage: regnde <list|validate|train|predict|run> \
         [--backend native|pjrt] [--solver {}] [--exp E] [--method M] \
         [--epochs N] [--iters N] [--seeds 0,1] [--artifacts DIR] [--runs DIR] \
         [--check-nfe] [--verbose]\n\
         experiments: mnist-node latent-ode spiral-node spiral-nsde mnist-nsde\n\
         methods: vanilla steer taynode srnode ernode lrnode (+-combined, e.g. srnode+ernode)",
        regnde::solvers::Tableau::names().join("|")
    )
}

fn run() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1), VALUED)?;
    let cmd = args
        .positional
        .first()
        .map(|s| s.as_str())
        .unwrap_or("help");
    let artifacts = args
        .get("artifacts")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(regnde::default_artifacts_dir);
    let backend_name = args.get_or("backend", "native").to_string();
    let solver = args.get("solver").map(|s| s.to_string());
    let solver = solver.as_deref();

    match cmd {
        "help" | "--help" => {
            println!("{}", usage());
            Ok(())
        }
        "list" => {
            let backend = make_backend(&backend_name, &artifacts, solver)?;
            list(backend.as_ref())?;
            #[cfg(feature = "pjrt")]
            if backend.name() == "pjrt" {
                list_artifacts(&artifacts)?;
            }
            Ok(())
        }
        "validate" => validate(&artifacts),
        "train" => {
            let backend = make_backend(&backend_name, &artifacts, solver)?;
            let exp = args.get("exp").context("--exp required")?.to_string();
            let method = Method::parse(args.get_or("method", "vanilla"))?;
            let seeds: Vec<u64> = args
                .get_or("seeds", "0")
                .split(',')
                .map(|s| s.parse::<u64>().context("bad seed"))
                .collect::<Result<_>>()?;
            let recorder = Recorder::new(
                args.get("runs")
                    .map(std::path::PathBuf::from)
                    .unwrap_or_else(regnde::default_runs_dir),
            )?;
            for seed in seeds {
                let opts = TrainOpts {
                    epochs: args.get_usize("epochs", 3)?,
                    iters_per_epoch: args.get_usize("iters", 10)?,
                    seed,
                    verbose: args.flag("verbose"),
                };
                let result = experiments::run_by_name(backend.as_ref(), &exp, method, opts)?;
                let path = recorder.save(&result)?;
                println!(
                    "[{}] seed {seed}: train {:.1}s predict {:.3}s nfe {:.1} \
                     test-metric {:.4} -> {}",
                    result.method,
                    result.train_time_s,
                    result.predict_time_s,
                    result.predict_nfe,
                    result.final_test_metric,
                    path.display()
                );
            }
            Ok(())
        }
        "predict" => {
            let backend = make_backend(&backend_name, &artifacts, solver)?;
            let exp = args.get("exp").context("--exp required")?.to_string();
            let method = Method::parse(args.get_or("method", "vanilla"))?;
            // quick one-epoch train then timed predictions
            let opts = TrainOpts {
                epochs: 1,
                iters_per_epoch: args.get_usize("iters", 5)?,
                seed: args.get_u64("seeds", 0)?,
                verbose: args.flag("verbose"),
            };
            let result = experiments::run_by_name(backend.as_ref(), &exp, method, opts)?;
            println!(
                "[{}] predict {:.4}s nfe {:.1} metric {:.4}",
                result.method,
                result.predict_time_s,
                result.predict_nfe,
                result.final_test_metric
            );
            Ok(())
        }
        "run" => {
            let backend = make_backend(&backend_name, &artifacts, solver)?;
            let exp = args
                .positional
                .get(1)
                .map(|s| s.to_string())
                .or_else(|| args.get("exp").map(|s| s.to_string()))
                .context("usage: regnde run <experiment> [--method M]")?;
            let method = Method::parse(args.get_or("method", "srnode+ernode"))?;
            let opts = TrainOpts {
                epochs: args.get_usize("epochs", 2)?,
                iters_per_epoch: args.get_usize("iters", 25)?,
                seed: args.get_u64("seeds", 0)?,
                verbose: args.flag("verbose"),
            };
            compare_run(
                backend.as_ref(),
                &exp,
                method,
                opts,
                args.flag("check-nfe"),
            )
        }
        other => bail!("unknown command {other:?}\n{}", usage()),
    }
}

fn list(backend: &dyn Backend) -> Result<()> {
    println!("backend: {}", backend.name());
    println!("\nmodels:");
    for model in backend.models() {
        let info = backend.model(&model)?;
        let ladder = backend.ladder(&model, false).unwrap_or_default();
        println!(
            "  {model:<14} params={:<8} opt={:<8} ({}) ladder={ladder:?}",
            info.params_size, info.opt_state_size, info.optimizer
        );
    }
    Ok(())
}

/// The method-vs-vanilla comparison behind CI's native smoke run: trains
/// both from the same seed and prints the paper-style summary.  With
/// `check_nfe`, exits nonzero unless the regularized run accumulates its
/// regularizers, decreases the loss, and ends with NFE no worse than
/// vanilla's — the NFE gate is waived only when the sampled-step local
/// term is the *sole* regularizer (the headline NFE claim belongs to
/// the global `er`/`sr` terms).  `sr` methods must actually *train* on
/// the stiffness gradient (zeroing coef_s must change the trajectory),
/// and `lr` methods likewise on the sampled-step local gradient
/// (R_L > 0 and zeroing coef_l must change the trajectory).
fn compare_run(
    backend: &dyn Backend,
    exp: &str,
    method: Method,
    opts: TrainOpts,
    check_nfe: bool,
) -> Result<()> {
    anyhow::ensure!(
        method != Method::VANILLA,
        "`run` compares a regularized method against vanilla; pick a method"
    );
    let reg = experiments::run_by_name(backend, exp, method, opts)?;
    let vanilla = experiments::run_by_name(backend, exp, Method::VANILLA, opts)?;

    println!("\n================ {exp}: regularized vs vanilla ================");
    for r in [&vanilla, &reg] {
        let last = r.epochs.last().context("no epochs recorded")?;
        println!(
            "{:<18} final-epoch loss {:>9.5} | train NFE {:>7.1} | predict NFE {:>7.1} \
             | escalations {}",
            r.method, last.loss, last.nfe, r.predict_nfe, r.escalations
        );
    }
    let reg_first = reg.epochs.first().context("no epochs")?;
    let reg_last = reg.epochs.last().context("no epochs")?;
    let van_last = vanilla.epochs.last().context("no epochs")?;
    println!(
        "\nregularized: loss {:.5} -> {:.5}, r_e {:.3e}, r_s {:.3e}, r_l {:.3e}, \
         NFE ratio vanilla/reg = {:.3}x",
        reg_first.loss,
        reg_last.loss,
        reg_last.r_e,
        reg_last.r_s,
        reg_last.r_l,
        van_last.nfe / reg_last.nfe.max(1e-9),
    );

    if check_nfe {
        anyhow::ensure!(
            reg_last.r_e > 0.0,
            "regularized run must accumulate R_E (got {})",
            reg_last.r_e
        );
        anyhow::ensure!(
            reg_last.loss < reg_first.loss,
            "training must decrease the loss ({} -> {})",
            reg_first.loss,
            reg_last.loss
        );
        // The NFE-vs-vanilla gate is waived only when the sampled-step
        // local term is the sole regularizer: the paper's headline NFE
        // claim belongs to the global er/sr terms (and the steer/taynode
        // baselines keep their historical gate), and a sampled-step-only
        // run is not required to beat vanilla after a smoke-length
        // budget.
        let waive_nfe = method.lr && !method.er && !method.sr;
        if !waive_nfe {
            anyhow::ensure!(
                reg_last.nfe <= van_last.nfe,
                "regularized final-epoch NFE {} exceeds vanilla {}",
                reg_last.nfe,
                van_last.nfe
            );
        }
        if method.sr {
            anyhow::ensure!(
                reg_last.r_s > 0.0,
                "sr method must accumulate R_S (got {})",
                reg_last.r_s
            );
            // Gradient-path liveness: the same run with coef_s zeroed
            // (the sr component removed) must land on different
            // parameters.  If it doesn't, R_S is riding the loss value
            // without reaching the Adam update.
            let no_sr = Method { sr: false, ..method };
            let base_run;
            let base = if no_sr == Method::VANILLA {
                &vanilla
            } else {
                base_run = experiments::run_by_name(backend, exp, no_sr, opts)?;
                &base_run
            };
            anyhow::ensure!(
                reg.final_train_loss != base.final_train_loss,
                "zeroing coef_s left training unchanged — stiffness \
                 gradient path is dead"
            );
            println!("check-sr: OK (R_S {:.3e}, coef_s path live)", reg_last.r_s);
        }
        if method.lr {
            anyhow::ensure!(
                reg_last.r_l > 0.0,
                "lr method must sample a live local regularizer (got R_L = {})",
                reg_last.r_l
            );
            // Gradient-path liveness: the same run with coef_l zeroed
            // (the lr component removed) must land on different
            // parameters — the sampled step's error cotangent has to
            // reach the Adam update, not just the loss value.
            let no_lr = Method { lr: false, ..method };
            let base_run;
            let base = if no_lr == Method::VANILLA {
                &vanilla
            } else {
                base_run = experiments::run_by_name(backend, exp, no_lr, opts)?;
                &base_run
            };
            anyhow::ensure!(
                reg.final_train_loss != base.final_train_loss,
                "zeroing coef_l left training unchanged — sampled-step \
                 gradient path is dead"
            );
            println!("check-lr: OK (R_L {:.3e}, coef_l path live)", reg_last.r_l);
        }
        if waive_nfe {
            println!(
                "check-nfe: OK (NFE gate waived for sampled-step-only method; \
                 reg {} vs vanilla {})",
                reg_last.nfe, van_last.nfe
            );
        } else {
            println!("check-nfe: OK (reg {} <= vanilla {})", reg_last.nfe, van_last.nfe);
        }
    }
    Ok(())
}

#[cfg(feature = "pjrt")]
fn list_artifacts(artifacts: &std::path::Path) -> Result<()> {
    let engine = regnde::runtime::Engine::new(artifacts)?;
    println!("platform: {}", engine.platform());
    println!("\nartifacts:");
    for (name, a) in &engine.manifest.artifacts {
        println!("  {name:<28} kind={:<10} budget={:?}", a.kind, a.budget);
    }
    Ok(())
}

/// Run every artifact once with synthetic inputs — a fast whole-manifest
/// smoke test (also exercised by rust/tests/validate_artifacts.rs).
#[cfg(feature = "pjrt")]
fn validate(artifacts: &std::path::Path) -> Result<()> {
    use regnde::runtime::{Engine, Input};

    let engine = Engine::new(artifacts)?;
    let names: Vec<String> = engine.manifest.artifacts.keys().cloned().collect();
    for name in names {
        let spec = engine.manifest.artifact(&name)?.clone();
        let mut storage: Vec<Vec<f32>> = Vec::new();
        for t in &spec.inputs {
            if t.dtype == "f32" && !t.shape.is_empty() {
                // time grids must be increasing; everything else small random
                if t.name == "ts" {
                    let n = t.numel();
                    storage.push(
                        (0..n).map(|i| i as f32 / (n - 1) as f32).collect(),
                    );
                } else {
                    storage.push(vec![0.01; t.numel()]);
                }
            } else {
                storage.push(Vec::new());
            }
        }
        let inputs: Vec<Input> = spec
            .inputs
            .iter()
            .zip(&storage)
            .map(|(t, s)| match (t.dtype.as_str(), t.shape.is_empty()) {
                ("u32", _) => Input::SeedU32(7),
                ("f32", true) => Input::Scalar(0.5),
                _ => Input::F32(s),
            })
            .collect();
        let t0 = std::time::Instant::now();
        let out = engine.run_spec(&spec, &inputs)?;
        println!(
            "  {name:<28} ok ({} outputs, {:.2}s)",
            out.len(),
            t0.elapsed().as_secs_f64()
        );
    }
    println!("all artifacts validated");
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn validate(_artifacts: &std::path::Path) -> Result<()> {
    bail!("`validate` exercises the artifact manifest — rebuild with --features pjrt")
}
