//! `regnde` — CLI launcher for the regularized-NDE training framework.
//!
//! ```text
//! regnde list                                  # backend models (+artifacts)
//! regnde train --exp mnist-node --method ernode [--epochs N] [--iters N]
//!              [--seeds 0,1,2] [--backend native|pjrt] [--verbose]
//!              [--checkpoint ckpt.json]        # persist the trained model
//! regnde predict --exp mnist-node --method vanilla
//! regnde run spiral-node --method srnode+ernode --epochs 2 [--check-nfe]
//!                                              # method-vs-vanilla compare
//! regnde run spiral-node --method ernode --solver dopri5
//!                                              # pick the RK tableau
//! regnde serve --registry <dir> --addr 127.0.0.1:7878
//!                                              # micro-batching TCP server
//! regnde predict --addr 127.0.0.1:7878 --model spiral-er \
//!                [--u0 2.0,0.0] [--requests 32] [--concurrency 8] \
//!                [--deadline-ms 250] [--retries 3] [--chaos]
//!                                              # remote serving client
//! regnde validate                              # run every artifact (pjrt)
//! ```
//!
//! The default backend is the native discrete-adjoint trainer — no
//! artifacts or XLA required.  `--backend pjrt` selects the AOT engine
//! (requires `--features pjrt` and compiled artifacts).  `--solver`
//! picks the native backend's RK tableau by name (case-insensitive:
//! tsit5, dopri5, bs3).  `--checkpoint` persists the trained model as a
//! serving checkpoint (DESIGN.md §Serving); `serve` hosts a checkpoint
//! directory and `predict --addr` talks to it.
//!
//! The serving client is drain-aware (DESIGN.md §Robustness):
//! `--deadline-ms` attaches a per-request deadline the server may shed
//! on, `--retries` retries shed/timed-out requests with exponential
//! backoff + deterministic jitter, and `--chaos` turns the client into a
//! fault injector — half-written frames, mid-request disconnects, slow
//! dribbled writes — that passes only if the server keeps serving
//! afterwards.

use std::sync::Arc;

use anyhow::{bail, ensure, Context, Result};

use regnde::coordinator::experiments::{self, ResumeState, TrainOpts};
use regnde::coordinator::metrics::RunResult;
use regnde::coordinator::recorder::Recorder;
use regnde::coordinator::Method;
use regnde::dist::{DistBackend, RemoteOpts, Worker, WorkerOpts};
use regnde::runtime::{make_backend, Backend, NativeBackend};
use regnde::serve::{
    BatchPolicy, Batcher, Checkpoint, Client, Registry, Request, Response, Server, ServerOpts,
    TrainProgress,
};
use regnde::util::cli::Args;
use regnde::util::threadpool::ThreadPool;

const VALUED: &[&str] = &[
    "exp",
    "method",
    "epochs",
    "iters",
    "seeds",
    "artifacts",
    "runs",
    "backend",
    "solver",
    "checkpoint",
    "resume",
    "registry",
    "addr",
    "model",
    "u0",
    "budget",
    "requests",
    "concurrency",
    "max-batch",
    "max-wait-us",
    "max-queue",
    "max-conns",
    "nfe-quota",
    "workers",
    "shards",
    "deadline-ms",
    "retries",
    "log-level",
    "trace",
];

/// Options (valued or boolean) each subcommand accepts — unknown ones
/// are rejected with a typed error listing the valid set, so a typo'd
/// flag can never be silently ignored.
fn known_for(cmd: &str, remote_predict: bool) -> Option<&'static [&'static str]> {
    const TRAIN: &[&str] = &[
        "backend", "solver", "artifacts", "runs", "exp", "method", "epochs", "iters", "seeds",
        "checkpoint", "resume", "verbose", "distributed", "workers", "shards", "log-level",
        "trace",
    ];
    const RUN: &[&str] = &[
        "backend", "solver", "artifacts", "runs", "exp", "method", "epochs", "iters", "seeds",
        "checkpoint", "verbose", "check-nfe", "distributed", "workers", "shards", "log-level",
        "trace",
    ];
    const PREDICT_LOCAL: &[&str] = &[
        "backend", "solver", "artifacts", "exp", "method", "iters", "seeds", "verbose",
        "log-level",
    ];
    const PREDICT_REMOTE: &[&str] = &[
        "addr", "model", "u0", "budget", "requests", "concurrency", "deadline-ms", "retries",
        "chaos", "log-level",
    ];
    const SERVE: &[&str] = &[
        "registry", "addr", "max-batch", "max-wait-us", "max-queue", "max-conns", "nfe-quota",
        "workers", "log-level",
    ];
    const LIST: &[&str] = &["backend", "solver", "artifacts", "log-level"];
    const VALIDATE: &[&str] = &["artifacts", "backend", "log-level"];
    const WORKER: &[&str] = &["addr", "solver", "backend", "max-conns", "log-level"];
    Some(match cmd {
        "train" => TRAIN,
        "run" => RUN,
        "predict" if remote_predict => PREDICT_REMOTE,
        "predict" => PREDICT_LOCAL,
        "serve" => SERVE,
        "list" => LIST,
        "validate" => VALIDATE,
        "worker" => WORKER,
        // `help` and unknown commands fail on the command itself.
        _ => return None,
    })
}

fn main() {
    if let Err(e) = run() {
        regnde::log_error!("cli", "{e:#}");
        std::process::exit(1);
    }
}

fn usage() -> String {
    format!(
        "usage: regnde <list|validate|train|predict|run|serve|worker> \
         [--backend native|pjrt] [--solver {}] [--exp E] [--method M] \
         [--epochs N] [--iters N] [--seeds 0,1] [--artifacts DIR] [--runs DIR] \
         [--checkpoint FILE] [--resume FILE] [--check-nfe] [--verbose] \
         [--log-level error|warn|info|debug] [--trace FILE]\n\
         distributed: regnde worker --addr A\n\
         \x20            regnde train --exp E --distributed --workers a,b,c \
         [--shards N]   (or --shards N alone for single-process sharding)\n\
         serving: regnde serve --registry DIR [--addr A] [--max-batch N] \
         [--max-wait-us U] [--max-queue N] [--max-conns N] [--nfe-quota Q] \
         [--workers W]\n\
         \x20        regnde predict --addr A --model ID [--u0 2.0,0.0] \
         [--budget N] [--requests N] [--concurrency C] [--deadline-ms MS] \
         [--retries N] [--chaos]\n\
         experiments: mnist-node latent-ode spiral-node spiral-nsde mnist-nsde\n\
         methods: vanilla steer taynode srnode ernode lrnode (+-combined, e.g. srnode+ernode)",
        regnde::solvers::Tableau::names().join("|")
    )
}

fn run() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1), VALUED)?;
    let cmd = args
        .positional
        .first()
        .map(|s| s.as_str())
        .unwrap_or("help");
    let artifacts = args
        .get("artifacts")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(regnde::default_artifacts_dir);
    let backend_name = args.get_or("backend", "native").to_string();
    let solver = args.get("solver").map(|s| s.to_string());
    let solver = solver.as_deref();

    // Reject unknown options up front (typos must not be silently
    // ignored); unknown subcommands fall through to the match below.
    if let Some(known) = known_for(cmd, args.get("addr").is_some()) {
        args.check_known(known)?;
    }

    if let Some(level) = args.get("log-level") {
        regnde::obs::log::set_level_str(level).map_err(anyhow::Error::msg)?;
    }

    match cmd {
        "help" | "--help" => {
            println!("{}", usage());
            Ok(())
        }
        "list" => {
            let backend = make_backend(&backend_name, &artifacts, solver)?;
            list(backend.as_ref())?;
            #[cfg(feature = "pjrt")]
            if backend.name() == "pjrt" {
                list_artifacts(&artifacts)?;
            }
            Ok(())
        }
        "validate" => validate(&artifacts),
        "train" => {
            let backend = train_backend(&args, &backend_name, &artifacts, solver)?;
            let exp = args.get("exp").context("--exp required")?.to_string();
            let method = Method::parse(args.get_or("method", "vanilla"))?;
            let seeds: Vec<u64> = args
                .get_or("seeds", "0")
                .split(',')
                .map(|s| s.parse::<u64>().context("bad seed"))
                .collect::<Result<_>>()?;
            let resume = load_resume(&args, &exp)?;
            ensure!(
                resume.is_none() || seeds.len() == 1,
                "--resume continues a single replica; pass one --seeds value"
            );
            let recorder = Recorder::new(
                args.get("runs")
                    .map(std::path::PathBuf::from)
                    .unwrap_or_else(regnde::default_runs_dir),
            )?;
            let trace_path = args.get("trace").map(|p| p.to_string());
            if trace_path.is_some() {
                regnde::obs::span::enable(1 << 16);
            }
            for seed in seeds {
                let opts = TrainOpts {
                    epochs: args.get_usize("epochs", 3)?,
                    iters_per_epoch: args.get_usize("iters", 10)?,
                    seed,
                    verbose: args.flag("verbose"),
                };
                let result = experiments::run_by_name_resumed(
                    backend.as_ref(),
                    &exp,
                    method,
                    opts,
                    resume.as_ref(),
                )?;
                let path = recorder.save(&result)?;
                println!(
                    "[{}] seed {seed}: train {:.1}s predict {:.3}s nfe {:.1} \
                     test-metric {:.4} -> {}",
                    result.method,
                    result.train_time_s,
                    result.predict_time_s,
                    result.predict_nfe,
                    result.final_test_metric,
                    path.display()
                );
                // Multiple seeds overwrite in turn: the checkpoint holds
                // the last trained replica.
                if let Some(ckpt) = args.get("checkpoint") {
                    let total = experiments::schedule_epochs(resume.as_ref(), opts.epochs);
                    save_checkpoint(backend.as_ref(), &exp, &result, total, ckpt)?;
                }
            }
            if let Some(path) = trace_path {
                write_trace(&path)?;
            }
            Ok(())
        }
        "predict" if args.get("addr").is_some() => remote_predict(&args),
        "predict" => {
            let backend = make_backend(&backend_name, &artifacts, solver)?;
            let exp = args.get("exp").context("--exp required")?.to_string();
            let method = Method::parse(args.get_or("method", "vanilla"))?;
            // quick one-epoch train then timed predictions
            let opts = TrainOpts {
                epochs: 1,
                iters_per_epoch: args.get_usize("iters", 5)?,
                seed: args.get_u64("seeds", 0)?,
                verbose: args.flag("verbose"),
            };
            let result = experiments::run_by_name(backend.as_ref(), &exp, method, opts)?;
            println!(
                "[{}] predict {:.4}s nfe {:.1} metric {:.4}",
                result.method,
                result.predict_time_s,
                result.predict_nfe,
                result.final_test_metric
            );
            Ok(())
        }
        "run" => {
            let backend = train_backend(&args, &backend_name, &artifacts, solver)?;
            let exp = args
                .positional
                .get(1)
                .map(|s| s.to_string())
                .or_else(|| args.get("exp").map(|s| s.to_string()))
                .context("usage: regnde run <experiment> [--method M]")?;
            let method = Method::parse(args.get_or("method", "srnode+ernode"))?;
            let opts = TrainOpts {
                epochs: args.get_usize("epochs", 2)?,
                iters_per_epoch: args.get_usize("iters", 25)?,
                seed: args.get_u64("seeds", 0)?,
                verbose: args.flag("verbose"),
            };
            let trace_path = args.get("trace").map(|p| p.to_string());
            if trace_path.is_some() {
                regnde::obs::span::enable(1 << 16);
            }
            compare_run(
                backend.as_ref(),
                &exp,
                method,
                opts,
                args.flag("check-nfe"),
                args.get("checkpoint"),
            )?;
            if let Some(path) = trace_path {
                write_trace(&path)?;
            }
            Ok(())
        }
        "serve" => serve(&args),
        "worker" => worker(&args, &backend_name, solver),
        other => bail!("unknown command {other:?}\n{}", usage()),
    }
}

/// Dump the collected span buffer as Chrome trace-event JSON
/// (DESIGN.md §Observability).  Load the file at `chrome://tracing` or
/// <https://ui.perfetto.dev> to inspect solve/adjoint/optimizer phases.
fn write_trace(path: &str) -> Result<()> {
    let json = regnde::obs::span::dump_chrome_trace();
    std::fs::write(path, json).with_context(|| format!("writing trace to {path}"))?;
    println!("trace -> {path}");
    Ok(())
}

/// `regnde worker --addr <a>`: host the native backend's `grad_step`
/// for a distributed coordinator (DESIGN.md §Distributed).  Blocks until
/// a coordinator sends `shutdown` (or the process is killed).
fn worker(args: &Args, backend_name: &str, solver: Option<&str>) -> Result<()> {
    ensure!(
        backend_name == "native",
        "worker serves the native backend (grad_step is native-only); \
         got --backend {backend_name}"
    );
    let addr = args.get_or("addr", "127.0.0.1:0");
    let native = match solver {
        Some(s) => NativeBackend::new().with_solver(s)?,
        None => NativeBackend::new(),
    };
    let opts = WorkerOpts {
        max_conns: args.get_usize("max-conns", 16)?.max(1),
        ..Default::default()
    };
    let handle = Worker::spawn(Arc::new(native), opts, addr)?;
    // The exact line CI greps to learn the bound port.
    println!("worker listening on {}", handle.addr);
    handle.join();
    Ok(())
}

/// Backend for `train`/`run`.  Plain `make_backend` unless sharding is
/// requested: `--shards N` alone wraps the native backend in
/// single-process sharded execution (the determinism baseline), and
/// `--distributed --workers a,b,c [--shards N]` runs the same shards on
/// remote `regnde worker` processes (DESIGN.md §Distributed).
fn train_backend(
    args: &Args,
    backend_name: &str,
    artifacts: &std::path::Path,
    solver: Option<&str>,
) -> Result<Box<dyn Backend>> {
    let distributed = args.flag("distributed");
    if !distributed && args.get("shards").is_none() {
        ensure!(
            args.get("workers").is_none(),
            "--workers requires --distributed"
        );
        return make_backend(backend_name, artifacts, solver);
    }
    ensure!(
        backend_name == "native",
        "--distributed/--shards shard the native backend (grad_step is \
         native-only); got --backend {backend_name}"
    );
    let native = match solver {
        Some(s) => NativeBackend::new().with_solver(s)?,
        None => NativeBackend::new(),
    };
    if distributed {
        let workers: Vec<String> = args
            .get("workers")
            .context("--distributed requires --workers host:port[,host:port...]")?
            .split(',')
            .map(|w| w.trim().to_string())
            .filter(|w| !w.is_empty())
            .collect();
        ensure!(!workers.is_empty(), "--workers list is empty");
        let shards = match args.get("shards") {
            Some(s) => Some(s.parse::<usize>().context("--shards expects an integer")?),
            None => None,
        };
        let backend = DistBackend::remote(native, &workers, shards, RemoteOpts::default())?;
        println!("distributed: {}", backend.describe());
        Ok(Box::new(backend))
    } else {
        let shards = args.get_usize("shards", 1)?.max(1);
        Ok(Box::new(DistBackend::local(native, shards)))
    }
}

/// Load `--resume <ckpt>` into a [`ResumeState`].  v1 checkpoints (no
/// `train` block) resume with documented defaults: fresh optimizer
/// moments, iter 0, ladder rung 0, empty descent window, zero epochs
/// done.  The caller must rerun with the same experiment, method, seed
/// and --iters for the continuation to be bit-identical (DESIGN.md
/// §Distributed).
fn load_resume(args: &Args, exp: &str) -> Result<Option<ResumeState>> {
    let Some(path) = args.get("resume") else {
        return Ok(None);
    };
    let ckpt = Checkpoint::load(std::path::Path::new(path))
        .with_context(|| format!("loading --resume checkpoint {path}"))?;
    ensure!(
        ckpt.experiment == exp,
        "--resume checkpoint {path} was trained on experiment {:?}, not {exp:?}",
        ckpt.experiment
    );
    let train = ckpt.train.unwrap_or(TrainProgress {
        opt_state: Vec::new(),
        iter: 0,
        rung: 0,
        window: Vec::new(),
        epochs_done: 0,
        total_epochs: 0,
    });
    Ok(Some(ResumeState {
        params: ckpt.state.params,
        opt_state: train.opt_state,
        iter: train.iter,
        rung: train.rung,
        window: train.window,
        epochs_done: train.epochs_done,
        total_epochs: train.total_epochs,
    }))
}

/// Persist a finished run's model as a serving checkpoint
/// (`Backend::export_state` + `serve::Checkpoint`).  `total_epochs` is
/// the epoch target the run's annealed schedules were built over
/// (`experiments::schedule_epochs`), recorded so `--resume` anneals
/// over the same horizon.
fn save_checkpoint(
    backend: &dyn Backend,
    exp: &str,
    result: &RunResult,
    total_epochs: usize,
    path: &str,
) -> Result<()> {
    let model = experiments::model_for(exp)?;
    let state = backend.export_state(model, &result.final_params)?;
    let grid = experiments::serving_grid(exp);
    let ckpt = Checkpoint::new(state, exp, result.method.clone(), grid).with_train(TrainProgress {
        opt_state: result.final_opt_state.clone(),
        iter: result.final_iter,
        rung: result.final_rung,
        window: result.final_window.clone(),
        epochs_done: result.epochs_done,
        total_epochs,
    });
    let path = std::path::Path::new(path);
    ckpt.save(path)?;
    println!("checkpoint -> {}", path.display());
    Ok(())
}

/// `regnde serve --registry <dir>`: host a checkpoint directory behind
/// the micro-batching prediction server (blocks until a `shutdown`
/// request).
fn serve(args: &Args) -> Result<()> {
    let dir = args.get("registry").context("--registry <dir> required")?;
    let addr = args.get_or("addr", "127.0.0.1:7878");
    let policy = BatchPolicy {
        max_batch: args.get_usize("max-batch", 16)?.max(1),
        max_wait: std::time::Duration::from_micros(args.get_u64("max-wait-us", 2000)?),
        max_queue: args.get_usize("max-queue", 256)?.max(1),
    };
    let opts = ServerOpts {
        nfe_quota: args.get_u64("nfe-quota", 1_000_000)?,
        max_conns: args.get_usize("max-conns", 64)?.max(1),
        ..Default::default()
    };
    let workers = args.get_usize("workers", regnde::util::threadpool::default_workers())?;

    let registry = Arc::new(Registry::open(dir)?);
    let ids = registry.ids();
    ensure!(!ids.is_empty(), "registry {dir:?} holds no checkpoints");
    let pool = Arc::new(ThreadPool::new(workers));
    let batcher = Arc::new(Batcher::new(Arc::clone(&registry), pool, policy));
    let listener = std::net::TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
    println!(
        "regnde serve: {} model(s) at {} (max-batch {}, max-wait {}us, \
         max-queue {}, max-conns {}, quota {} attempts/conn)",
        ids.len(),
        listener.local_addr()?,
        policy.max_batch,
        policy.max_wait.as_micros(),
        policy.max_queue,
        opts.max_conns,
        opts.nfe_quota,
    );
    for id in &ids {
        println!("  {id}");
    }
    let server = Arc::new(Server::new(registry, batcher, opts));
    server.serve(listener)
}

/// Exponential backoff with deterministic full jitter for retrying shed
/// or timed-out requests (DESIGN.md §Robustness).  The jitter is a hash
/// of (request, lane, attempt) rather than an RNG draw, so concurrent
/// lanes shed from the same window decorrelate their retries yet every
/// run of the client is reproducible.
fn backoff_delay(attempt: usize, lane: usize, req: usize) -> std::time::Duration {
    let base = 5u64 << attempt.min(6); // 5, 10, 20, ... 320 ms
    let jitter = (req as u64)
        .wrapping_mul(0x9E37_79B9)
        .wrapping_add((lane as u64).wrapping_mul(0x85EB_CA6B))
        .wrapping_add(attempt as u64)
        % base;
    std::time::Duration::from_millis(base + jitter)
}

/// `--chaos`: a network fault injector.  Each lane cycles through
/// half-written frames, garbage frames, slow dribbled writes, and
/// mid-request disconnects (a request sent, then the socket dropped
/// before reading the reply — the server answers a dead peer).  All
/// faults are fired before the normal request phase; the client passes
/// only if the server keeps serving afterwards.
fn chaos_storm(addr: &str, model: &str, u0: &[f32], rounds: usize, lanes: usize) {
    use std::io::{Read, Write};

    std::thread::scope(|scope| {
        for lane in 0..lanes {
            scope.spawn(move || {
                for round in 0..rounds {
                    let Ok(mut stream) = std::net::TcpStream::connect(addr) else {
                        continue; // connection cap shed — that's containment too
                    };
                    let mut line = Request::Predict {
                        model: model.to_string(),
                        u0: u0.to_vec(),
                        budget: None,
                        deadline_ms: Some(100),
                    }
                    .encode();
                    line.push('\n');
                    let bytes = line.as_bytes();
                    match (lane + round) % 4 {
                        0 => {
                            // half-written frame, then disconnect
                            let _ = stream.write_all(&bytes[..bytes.len() / 2]);
                        }
                        1 => {
                            // garbage frame; the reply must be an error,
                            // not a hangup-by-panic
                            let _ = stream.write_all(b"}{ not json at all\n");
                            let mut buf = [0u8; 512];
                            let _ = stream.read(&mut buf);
                        }
                        2 => {
                            // slow dribbled write, a few bytes at a time —
                            // exercises the server's partial-line reads
                            // across its read-timeout ticks
                            for chunk in bytes.chunks(3) {
                                if stream.write_all(chunk).is_err() {
                                    break;
                                }
                                std::thread::sleep(std::time::Duration::from_millis(2));
                            }
                            let mut buf = [0u8; 512];
                            let _ = stream.read(&mut buf);
                        }
                        _ => {
                            // full request, then vanish before the reply
                            let _ = stream.write_all(bytes);
                        }
                    }
                }
            });
        }
    });
    println!("chaos: {} fault rounds across {lanes} lane(s) injected", rounds * lanes);
}

/// `regnde predict --addr <a> --model <id>`: serving client.  Fires
/// `--requests` predictions across `--concurrency` connections (each
/// lane holds one connection; concurrent lanes are what the server
/// coalesces) and exits nonzero unless every request succeeds.
/// `--deadline-ms` attaches a per-request deadline; shed replies and
/// transport failures are retried up to `--retries` times with
/// exponential backoff + jitter.  `--chaos` runs the fault-injection
/// storm first — the normal phase then doubles as the proof that the
/// server survived it.
fn remote_predict(args: &Args) -> Result<()> {
    let addr = args.get("addr").context("--addr required")?.to_string();
    let model = args.get("model").context("--model <id> required")?.to_string();
    let u0: Vec<f32> = args
        .get_or("u0", "2.0,0.0")
        .split(',')
        .map(|s| s.trim().parse::<f32>().context("bad --u0 entry"))
        .collect::<Result<_>>()?;
    let budget = match args.get("budget") {
        Some(b) => Some(b.parse::<u64>().context("--budget expects an integer")?),
        None => None,
    };
    let deadline_ms = match args.get("deadline-ms") {
        Some(d) => Some(d.parse::<u64>().context("--deadline-ms expects milliseconds")?),
        None => None,
    };
    let retries = args.get_usize("retries", 0)?;
    let requests = args.get_usize("requests", 1)?.max(1);
    let concurrency = args.get_usize("concurrency", 1)?.clamp(1, requests);

    if args.flag("chaos") {
        chaos_storm(&addr, &model, &u0, requests.max(8), concurrency.max(4));
    }

    use std::sync::atomic::{AtomicUsize, Ordering};
    let failures = AtomicUsize::new(0);
    let sheds = AtomicUsize::new(0);
    let next = AtomicUsize::new(0);
    std::thread::scope(|scope| -> Result<()> {
        let mut lanes = Vec::new();
        for lane in 0..concurrency {
            let (addr, model, u0) = (&addr, &model, &u0);
            let (failures, sheds, next) = (&failures, &sheds, &next);
            lanes.push(scope.spawn(move || -> Result<()> {
                let mut client = Some(Client::connect(addr)?);
                'requests: loop {
                    let i = next.fetch_add(1, Ordering::SeqCst);
                    if i >= requests {
                        return Ok(());
                    }
                    let req = Request::Predict {
                        model: model.clone(),
                        u0: u0.clone(),
                        budget,
                        deadline_ms,
                    };
                    for attempt in 0..=retries {
                        let last = attempt == retries;
                        if attempt > 0 {
                            std::thread::sleep(backoff_delay(attempt - 1, lane, i));
                        }
                        let conn = match client.as_mut() {
                            Some(c) => c,
                            None => match Client::connect(addr) {
                                Ok(c) => client.insert(c),
                                Err(e) => {
                                    if last {
                                        failures.fetch_add(1, Ordering::SeqCst);
                                        regnde::log_warn!(
                                            "predict",
                                            "req {i} (lane {lane}): reconnect failed: {e:#}"
                                        );
                                        continue 'requests;
                                    }
                                    continue;
                                }
                            },
                        };
                        match conn.request(&req) {
                            Ok(Response::Predict {
                                nfe,
                                naccept,
                                nreject,
                                batch,
                                micros,
                                ref traj,
                                ..
                            }) => {
                                println!(
                                    "req {i} (lane {lane}): ok nfe={nfe} attempts={} \
                                     batch={batch} latency={micros}us traj[0..2]=[{:.4}, {:.4}]",
                                    naccept + nreject,
                                    traj.first().copied().unwrap_or(f32::NAN),
                                    traj.get(1).copied().unwrap_or(f32::NAN),
                                );
                                continue 'requests;
                            }
                            Ok(Response::Shed(reason)) => {
                                // Retryable: the server did no solver work.
                                sheds.fetch_add(1, Ordering::SeqCst);
                                if last {
                                    failures.fetch_add(1, Ordering::SeqCst);
                                    regnde::log_warn!(
                                        "predict",
                                        "req {i} (lane {lane}): SHED after {} attempt(s): {reason}",
                                        retries + 1
                                    );
                                    continue 'requests;
                                }
                            }
                            Ok(Response::Error { msg, kind }) => {
                                // Not blindly retryable: the solve ran and
                                // failed, or the request itself is bad.
                                failures.fetch_add(1, Ordering::SeqCst);
                                match kind {
                                    Some(k) => regnde::log_error!(
                                        "predict",
                                        "req {i} (lane {lane}): ERROR [{k}] {msg}"
                                    ),
                                    None => regnde::log_error!(
                                        "predict",
                                        "req {i} (lane {lane}): ERROR {msg}"
                                    ),
                                }
                                continue 'requests;
                            }
                            Ok(other) => {
                                failures.fetch_add(1, Ordering::SeqCst);
                                regnde::log_error!(
                                    "predict",
                                    "req {i} (lane {lane}): unexpected response {other:?}"
                                );
                                continue 'requests;
                            }
                            Err(e) => {
                                // Transport failure (timeout, hangup):
                                // drop the connection and retry on a
                                // fresh one.
                                client = None;
                                if last {
                                    failures.fetch_add(1, Ordering::SeqCst);
                                    regnde::log_warn!(
                                        "predict",
                                        "req {i} (lane {lane}): transport error: {e:#}"
                                    );
                                    continue 'requests;
                                }
                            }
                        }
                    }
                }
            }));
        }
        for lane in lanes {
            match lane.join() {
                Ok(res) => res?,
                Err(_) => bail!("client lane panicked"),
            }
        }
        Ok(())
    })?;

    let failed = failures.load(Ordering::SeqCst);
    let shed = sheds.load(Ordering::SeqCst);
    if shed > 0 {
        println!("{shed} shed repl(y/ies) observed (retried with backoff)");
    }
    ensure!(
        failed == 0,
        "{failed}/{requests} serving request(s) failed"
    );
    println!("{requests}/{requests} serving requests ok");
    Ok(())
}

fn list(backend: &dyn Backend) -> Result<()> {
    println!("backend: {}", backend.name());
    println!("\nmodels:");
    for model in backend.models() {
        let info = backend.model(&model)?;
        let ladder = backend.ladder(&model, false).unwrap_or_default();
        println!(
            "  {model:<14} params={:<8} opt={:<8} ({}) ladder={ladder:?}",
            info.params_size, info.opt_state_size, info.optimizer
        );
    }
    Ok(())
}

/// The method-vs-vanilla comparison behind CI's native smoke run: trains
/// both from the same seed and prints the paper-style summary.  With
/// `check_nfe`, exits nonzero unless the regularized run accumulates its
/// regularizers, decreases the loss, and ends with NFE no worse than
/// vanilla's — the NFE gate is waived only when the sampled-step local
/// term is the *sole* regularizer (the headline NFE claim belongs to
/// the global `er`/`sr` terms).  `sr` methods must actually *train* on
/// the stiffness gradient (zeroing coef_s must change the trajectory),
/// and `lr` methods likewise on the sampled-step local gradient
/// (R_L > 0 and zeroing coef_l must change the trajectory).
fn compare_run(
    backend: &dyn Backend,
    exp: &str,
    method: Method,
    opts: TrainOpts,
    check_nfe: bool,
    checkpoint: Option<&str>,
) -> Result<()> {
    anyhow::ensure!(
        method != Method::VANILLA,
        "`run` compares a regularized method against vanilla; pick a method"
    );
    let reg = experiments::run_by_name(backend, exp, method, opts)?;
    let vanilla = experiments::run_by_name(backend, exp, Method::VANILLA, opts)?;
    // --checkpoint persists the *regularized* model (the one the compare
    // is about) for the serving registry.
    if let Some(path) = checkpoint {
        save_checkpoint(backend, exp, &reg, opts.epochs, path)?;
    }

    println!("\n================ {exp}: regularized vs vanilla ================");
    for r in [&vanilla, &reg] {
        let last = r.epochs.last().context("no epochs recorded")?;
        println!(
            "{:<18} final-epoch loss {:>9.5} | train NFE {:>7.1} | predict NFE {:>7.1} \
             | escalations {}",
            r.method, last.loss, last.nfe, r.predict_nfe, r.escalations
        );
    }
    let reg_first = reg.epochs.first().context("no epochs")?;
    let reg_last = reg.epochs.last().context("no epochs")?;
    let van_last = vanilla.epochs.last().context("no epochs")?;
    println!(
        "\nregularized: loss {:.5} -> {:.5}, r_e {:.3e}, r_s {:.3e}, r_l {:.3e}, \
         NFE ratio vanilla/reg = {:.3}x",
        reg_first.loss,
        reg_last.loss,
        reg_last.r_e,
        reg_last.r_s,
        reg_last.r_l,
        van_last.nfe / reg_last.nfe.max(1e-9),
    );

    if check_nfe {
        anyhow::ensure!(
            reg_last.r_e > 0.0,
            "regularized run must accumulate R_E (got {})",
            reg_last.r_e
        );
        anyhow::ensure!(
            reg_last.loss < reg_first.loss,
            "training must decrease the loss ({} -> {})",
            reg_first.loss,
            reg_last.loss
        );
        // The NFE-vs-vanilla gate is waived only when the sampled-step
        // local term is the sole regularizer: the paper's headline NFE
        // claim belongs to the global er/sr terms (and the steer/taynode
        // baselines keep their historical gate), and a sampled-step-only
        // run is not required to beat vanilla after a smoke-length
        // budget.
        let waive_nfe = method.lr && !method.er && !method.sr;
        if !waive_nfe {
            anyhow::ensure!(
                reg_last.nfe <= van_last.nfe,
                "regularized final-epoch NFE {} exceeds vanilla {}",
                reg_last.nfe,
                van_last.nfe
            );
        }
        if method.sr {
            anyhow::ensure!(
                reg_last.r_s > 0.0,
                "sr method must accumulate R_S (got {})",
                reg_last.r_s
            );
            // Gradient-path liveness: the same run with coef_s zeroed
            // (the sr component removed) must land on different
            // parameters.  If it doesn't, R_S is riding the loss value
            // without reaching the Adam update.
            let no_sr = Method { sr: false, ..method };
            let base_run;
            let base = if no_sr == Method::VANILLA {
                &vanilla
            } else {
                base_run = experiments::run_by_name(backend, exp, no_sr, opts)?;
                &base_run
            };
            anyhow::ensure!(
                reg.final_train_loss != base.final_train_loss,
                "zeroing coef_s left training unchanged — stiffness \
                 gradient path is dead"
            );
            println!("check-sr: OK (R_S {:.3e}, coef_s path live)", reg_last.r_s);
        }
        if method.lr {
            anyhow::ensure!(
                reg_last.r_l > 0.0,
                "lr method must sample a live local regularizer (got R_L = {})",
                reg_last.r_l
            );
            // Gradient-path liveness: the same run with coef_l zeroed
            // (the lr component removed) must land on different
            // parameters — the sampled step's error cotangent has to
            // reach the Adam update, not just the loss value.
            let no_lr = Method { lr: false, ..method };
            let base_run;
            let base = if no_lr == Method::VANILLA {
                &vanilla
            } else {
                base_run = experiments::run_by_name(backend, exp, no_lr, opts)?;
                &base_run
            };
            anyhow::ensure!(
                reg.final_train_loss != base.final_train_loss,
                "zeroing coef_l left training unchanged — sampled-step \
                 gradient path is dead"
            );
            println!("check-lr: OK (R_L {:.3e}, coef_l path live)", reg_last.r_l);
        }
        if waive_nfe {
            println!(
                "check-nfe: OK (NFE gate waived for sampled-step-only method; \
                 reg {} vs vanilla {})",
                reg_last.nfe, van_last.nfe
            );
        } else {
            println!("check-nfe: OK (reg {} <= vanilla {})", reg_last.nfe, van_last.nfe);
        }
    }
    Ok(())
}

#[cfg(feature = "pjrt")]
fn list_artifacts(artifacts: &std::path::Path) -> Result<()> {
    let engine = regnde::runtime::Engine::new(artifacts)?;
    println!("platform: {}", engine.platform());
    println!("\nartifacts:");
    for (name, a) in &engine.manifest.artifacts {
        println!("  {name:<28} kind={:<10} budget={:?}", a.kind, a.budget);
    }
    Ok(())
}

/// Run every artifact once with synthetic inputs — a fast whole-manifest
/// smoke test (also exercised by rust/tests/validate_artifacts.rs).
#[cfg(feature = "pjrt")]
fn validate(artifacts: &std::path::Path) -> Result<()> {
    use regnde::runtime::{Engine, Input};

    let engine = Engine::new(artifacts)?;
    let names: Vec<String> = engine.manifest.artifacts.keys().cloned().collect();
    for name in names {
        let spec = engine.manifest.artifact(&name)?.clone();
        let mut storage: Vec<Vec<f32>> = Vec::new();
        for t in &spec.inputs {
            if t.dtype == "f32" && !t.shape.is_empty() {
                // time grids must be increasing; everything else small random
                if t.name == "ts" {
                    let n = t.numel();
                    storage.push(
                        (0..n).map(|i| i as f32 / (n - 1) as f32).collect(),
                    );
                } else {
                    storage.push(vec![0.01; t.numel()]);
                }
            } else {
                storage.push(Vec::new());
            }
        }
        let inputs: Vec<Input> = spec
            .inputs
            .iter()
            .zip(&storage)
            .map(|(t, s)| match (t.dtype.as_str(), t.shape.is_empty()) {
                ("u32", _) => Input::SeedU32(7),
                ("f32", true) => Input::Scalar(0.5),
                _ => Input::F32(s),
            })
            .collect();
        let t0 = std::time::Instant::now();
        let out = engine.run_spec(&spec, &inputs)?;
        println!(
            "  {name:<28} ok ({} outputs, {:.2}s)",
            out.len(),
            t0.elapsed().as_secs_f64()
        );
    }
    println!("all artifacts validated");
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn validate(_artifacts: &std::path::Path) -> Result<()> {
    bail!("`validate` exercises the artifact manifest — rebuild with --features pjrt")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(argv: &[&str]) -> Result<Args> {
        Args::parse(argv.iter().map(|s| s.to_string()), VALUED)
    }

    /// Mirror of `run()`'s rejection path: parse, then check the
    /// subcommand's known-option list.
    fn accept(argv: &[&str]) -> Result<()> {
        let args = parse(argv)?;
        let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
        if let Some(known) = known_for(cmd, args.get("addr").is_some()) {
            args.check_known(known)?;
        }
        Ok(())
    }

    #[test]
    fn known_commands_accept_their_own_options() {
        accept(&["train", "--exp", "spiral-node", "--epochs", "2", "--verbose"]).unwrap();
        accept(&[
            "train",
            "--exp",
            "spiral-node",
            "--distributed",
            "--workers",
            "a:1,b:2",
            "--shards",
            "2",
            "--resume",
            "ck.json",
        ])
        .unwrap();
        accept(&["run", "spiral-node", "--method", "ernode", "--check-nfe"]).unwrap();
        accept(&["worker", "--addr", "127.0.0.1:0", "--max-conns", "4"]).unwrap();
        accept(&["serve", "--registry", "d", "--max-batch", "8"]).unwrap();
        accept(&["predict", "--exp", "spiral-node"]).unwrap();
        accept(&["predict", "--addr", "a:1", "--model", "m", "--retries", "2"]).unwrap();
        accept(&["list"]).unwrap();
        accept(&["help"]).unwrap();
    }

    #[test]
    fn observability_flags_are_scoped_per_subcommand() {
        // --log-level is valid on every subcommand; --trace only where a
        // training loop runs (DESIGN.md §Observability).
        accept(&["train", "--exp", "e", "--log-level", "debug", "--trace", "t.json"]).unwrap();
        accept(&["run", "spiral-node", "--trace", "t.json"]).unwrap();
        accept(&["serve", "--registry", "d", "--log-level", "warn"]).unwrap();
        accept(&["worker", "--addr", "a:1", "--log-level", "error"]).unwrap();
        accept(&["predict", "--addr", "a:1", "--model", "m", "--log-level", "info"]).unwrap();
        accept(&["list", "--log-level", "debug"]).unwrap();
        accept(&["validate", "--log-level", "debug"]).unwrap();
        let err = accept(&["serve", "--registry", "d", "--trace", "t.json"]).unwrap_err();
        assert!(format!("{err:#}").contains("trace"));
    }

    #[test]
    fn typoed_flags_are_rejected_with_the_valid_set() {
        let err = accept(&["train", "--exp", "spiral-node", "--epoch", "2"]).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("epoch"), "names the offender: {msg}");
        assert!(msg.contains("epochs"), "lists valid options: {msg}");

        // A flag valid for one subcommand is still rejected on another.
        let err = accept(&["serve", "--registry", "d", "--resume", "x"]).unwrap_err();
        assert!(format!("{err:#}").contains("resume"));
        let err = accept(&["worker", "--distributed"]).unwrap_err();
        assert!(format!("{err:#}").contains("distributed"));
        // Local predict must not take remote-only options.
        let err = accept(&["predict", "--exp", "e", "--retries", "2"]).unwrap_err();
        assert!(format!("{err:#}").contains("retries"));
    }

    #[test]
    fn workers_without_distributed_is_rejected() {
        let args = parse(&["train", "--exp", "e", "--workers", "a:1"]).unwrap();
        let err = train_backend(
            &args,
            "native",
            std::path::Path::new("/tmp/none"),
            None,
        )
        .unwrap_err();
        assert!(format!("{err:#}").contains("--distributed"));
    }

    #[test]
    fn distributed_requires_native_backend_and_workers() {
        let args = parse(&["train", "--exp", "e", "--distributed"]).unwrap();
        let err = train_backend(&args, "native", std::path::Path::new("/tmp/none"), None)
            .unwrap_err();
        assert!(format!("{err:#}").contains("--workers"));

        let args = parse(&["train", "--distributed", "--workers", "a:1"]).unwrap();
        let err = train_backend(&args, "pjrt", std::path::Path::new("/tmp/none"), None)
            .unwrap_err();
        assert!(format!("{err:#}").contains("native"));
    }

    #[test]
    fn unknown_subcommands_fall_through_to_the_command_error() {
        // known_for returns None: the option check is skipped and the
        // `match` rejects the command itself.
        assert!(known_for("trian", false).is_none());
        assert!(known_for("worker", false).is_some());
    }

    #[test]
    fn local_sharding_builds_a_dist_backend() {
        let args = parse(&["train", "--exp", "e", "--shards", "2"]).unwrap();
        let backend =
            train_backend(&args, "native", std::path::Path::new("/tmp/none"), None).unwrap();
        assert_eq!(backend.name(), "dist");
    }
}
