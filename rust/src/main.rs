//! `regnde` — CLI launcher for the regularized-NDE training framework.
//!
//! ```text
//! regnde list                                  # artifacts + models
//! regnde validate                              # run every artifact once
//! regnde train --exp mnist-node --method ernode [--epochs N] [--iters N]
//!              [--seeds 0,1,2] [--verbose]
//! regnde predict --exp mnist-node --method vanilla
//! regnde bench --table 1                       # alias of cargo bench target
//! ```

use anyhow::{bail, Context, Result};

use regnde::coordinator::experiments::{self, TrainOpts};
use regnde::coordinator::recorder::Recorder;
use regnde::coordinator::Method;
use regnde::runtime::{Engine, Input};
use regnde::util::cli::Args;

const VALUED: &[&str] = &[
    "exp", "method", "epochs", "iters", "seeds", "artifacts", "runs",
];

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn usage() -> &'static str {
    "usage: regnde <list|validate|train|predict> \
     [--exp E] [--method M] [--epochs N] [--iters N] [--seeds 0,1] \
     [--artifacts DIR] [--runs DIR] [--verbose]\n\
     experiments: mnist-node latent-ode spiral-node spiral-nsde mnist-nsde\n\
     methods: vanilla steer taynode srnode ernode (+-combined, e.g. srnode+ernode)"
}

fn run() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1), VALUED)?;
    let cmd = args
        .positional
        .first()
        .map(|s| s.as_str())
        .unwrap_or("help");
    let artifacts = args
        .get("artifacts")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(regnde::default_artifacts_dir);

    match cmd {
        "help" | "--help" => {
            println!("{}", usage());
            Ok(())
        }
        "list" => {
            let engine = Engine::new(&artifacts)?;
            println!("platform: {}", engine.platform());
            println!("\nmodels:");
            for (name, m) in &engine.manifest.models {
                println!(
                    "  {name:<14} params={:<8} opt={} ({})",
                    m.params_size, m.opt_state_size, m.optimizer
                );
            }
            println!("\nartifacts:");
            for (name, a) in &engine.manifest.artifacts {
                println!(
                    "  {name:<28} kind={:<10} budget={:?}",
                    a.kind, a.budget
                );
            }
            Ok(())
        }
        "validate" => validate(&artifacts),
        "train" => {
            let engine = Engine::new(&artifacts)?;
            let exp = args.get("exp").context("--exp required")?.to_string();
            let method = Method::parse(args.get_or("method", "vanilla"))?;
            let seeds: Vec<u64> = args
                .get_or("seeds", "0")
                .split(',')
                .map(|s| s.parse::<u64>().context("bad seed"))
                .collect::<Result<_>>()?;
            let recorder = Recorder::new(
                args.get("runs")
                    .map(std::path::PathBuf::from)
                    .unwrap_or_else(regnde::default_runs_dir),
            )?;
            for seed in seeds {
                let opts = TrainOpts {
                    epochs: args.get_usize("epochs", 3)?,
                    iters_per_epoch: args.get_usize("iters", 10)?,
                    seed,
                    verbose: args.flag("verbose"),
                };
                let result = experiments::run_by_name(&engine, &exp, method, opts)?;
                let path = recorder.save(&result)?;
                println!(
                    "[{}] seed {seed}: train {:.1}s predict {:.3}s nfe {:.1} \
                     test-metric {:.4} -> {}",
                    result.method,
                    result.train_time_s,
                    result.predict_time_s,
                    result.predict_nfe,
                    result.final_test_metric,
                    path.display()
                );
            }
            Ok(())
        }
        "predict" => {
            let engine = Engine::new(&artifacts)?;
            let exp = args.get("exp").context("--exp required")?.to_string();
            let method = Method::parse(args.get_or("method", "vanilla"))?;
            // quick one-epoch train then timed predictions
            let opts = TrainOpts {
                epochs: 1,
                iters_per_epoch: args.get_usize("iters", 5)?,
                seed: args.get_u64("seeds", 0)?,
                verbose: args.flag("verbose"),
            };
            let result = experiments::run_by_name(&engine, &exp, method, opts)?;
            println!(
                "[{}] predict {:.4}s nfe {:.1} metric {:.4}",
                result.method,
                result.predict_time_s,
                result.predict_nfe,
                result.final_test_metric
            );
            Ok(())
        }
        other => bail!("unknown command {other:?}\n{}", usage()),
    }
}

/// Run every artifact once with synthetic inputs — a fast whole-manifest
/// smoke test (also exercised by rust/tests/validate_artifacts.rs).
fn validate(artifacts: &std::path::Path) -> Result<()> {
    let engine = Engine::new(artifacts)?;
    let names: Vec<String> = engine.manifest.artifacts.keys().cloned().collect();
    for name in names {
        let spec = engine.manifest.artifact(&name)?.clone();
        let mut storage: Vec<Vec<f32>> = Vec::new();
        for t in &spec.inputs {
            if t.dtype == "f32" && !t.shape.is_empty() {
                // time grids must be increasing; everything else small random
                if t.name == "ts" {
                    let n = t.numel();
                    storage.push(
                        (0..n).map(|i| i as f32 / (n - 1) as f32).collect(),
                    );
                } else {
                    storage.push(vec![0.01; t.numel()]);
                }
            } else {
                storage.push(Vec::new());
            }
        }
        let inputs: Vec<Input> = spec
            .inputs
            .iter()
            .zip(&storage)
            .map(|(t, s)| match (t.dtype.as_str(), t.shape.is_empty()) {
                ("u32", _) => Input::SeedU32(7),
                ("f32", true) => Input::Scalar(0.5),
                _ => Input::F32(s),
            })
            .collect();
        let t0 = std::time::Instant::now();
        let out = engine.run_spec(&spec, &inputs)?;
        println!(
            "  {name:<28} ok ({} outputs, {:.2}s)",
            out.len(),
            t0.elapsed().as_secs_f64()
        );
    }
    println!("all artifacts validated");
    Ok(())
}
