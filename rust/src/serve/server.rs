//! TCP prediction server + client: `regnde serve` / `regnde predict
//! --addr`.
//!
//! A thin `std::net` loop around the [`Registry`] + [`Batcher`] core:
//! one thread per connection, one [`protocol`] JSON line per request and
//! response.  Concurrency therefore comes from *connections* — clients
//! holding separate connections are what the batcher coalesces into
//! row-batched solves.
//!
//! ## NFE-budget admission control
//!
//! Every connection starts with an **NFE quota** measured in solver step
//! attempts ([`ServerOpts::nfe_quota`]) — the unit
//! `StepBudget::Total` bounds, and `attempts × nfe_per_attempt` away
//! from raw NFE.  A predict request declares a total attempt budget
//! (defaulting to its checkpoint's `step_budget`); the server **rejects
//! the request up front** if that declared budget exceeds the
//! connection's remaining quota — a request that *could* exhaust the
//! quota never reaches the solver.  After a served request, the quota is
//! charged the *realized* attempts of its batch solve; a solve that ran
//! and *failed* is charged the full declared budget (it may have burned
//! all of it).  Shed and rejected requests did no solver work and are
//! not charged.  Well-behaved cheap requests (the regularized-model
//! case) therefore stretch the same quota further.
//!
//! ## Failure containment (DESIGN.md §Robustness)
//!
//! * **Bounded concurrency**: at most [`ServerOpts::max_conns`]
//!   connections are served at once; an over-cap connection receives a
//!   single `shed` line and is closed — overload answers fast instead of
//!   stacking unbounded threads.
//! * **Read timeouts**: connection reads poll at
//!   [`ServerOpts::read_timeout`] so an idle or half-dead client cannot
//!   pin a thread forever once the server starts draining.
//! * **Deadlines**: a predict request may carry `deadline_ms`; expired
//!   requests are shed (by the batcher, before any solve) instead of
//!   served late.
//! * **Draining shutdown**: on `shutdown`, the accept loop stops taking
//!   connections, every in-flight request runs to completion and is
//!   answered, and [`Server::serve`] returns only after all connection
//!   threads have been joined.  Requests arriving on an existing
//!   connection *after* the drain begins are shed, not solved.
//! * **Typed failures on the wire**: a load-shed answers
//!   `{"ok":false,"shed":true,...}` (retryable — no solver work was
//!   done); a solve that ran and died answers an error carrying the
//!   [`SolveErrorKind`] string, which [`Client`]s can inspect instead of
//!   blindly retrying.
//!
//! ## Metrics (DESIGN.md §Observability)
//!
//! The server feeds the process-global [`crate::obs::metrics`] registry:
//! per-model request/served/shed/error counters, request-latency and
//! per-request-NFE histograms, a live-connection gauge
//! (`regnde_serve_connections`), and connection-level shed counters.
//! Scrape either with the `metrics` wire op (one JSON line, like every
//! other op) or with a plain `GET /metrics` HTTP/1.0 request on the
//! same port — the accept loop answers the latter with a
//! `text/plain` Prometheus exposition and closes the connection, so
//! `curl` works against a serving port without speaking the JSON
//! protocol.
//!
//! [`protocol`]: super::protocol
//! [`SolveErrorKind`]: crate::solvers::error::SolveErrorKind

use std::io::{BufRead, BufReader, ErrorKind, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use super::batcher::{BatchError, Batcher};
use super::protocol::{Request, Response};
use super::registry::Registry;
use crate::obs::metrics;

/// Per-server policy knobs.
#[derive(Clone, Copy, Debug)]
pub struct ServerOpts {
    /// Per-connection step-attempt quota (admission control unit).
    pub nfe_quota: u64,
    /// Most connections served concurrently; the rest are shed at
    /// accept with one `shed` response line.
    pub max_conns: usize,
    /// Poll tick for connection reads: how long a blocked read waits
    /// before re-checking the drain flag.  Not a request deadline —
    /// partial lines survive across ticks.
    pub read_timeout: Duration,
}

impl Default for ServerOpts {
    fn default() -> Self {
        ServerOpts {
            nfe_quota: 1_000_000,
            max_conns: 64,
            read_timeout: Duration::from_millis(250),
        }
    }
}

/// The prediction server: accept loop + per-connection protocol state.
pub struct Server {
    registry: Arc<Registry>,
    batcher: Arc<Batcher>,
    opts: ServerOpts,
    shutdown: AtomicBool,
    active_conns: AtomicUsize,
}

/// Occupancy guard: frees the connection slot even if the handler
/// thread panics, so a crashed connection can never leak capacity.
struct ConnSlot<'a>(&'a AtomicUsize);

impl Drop for ConnSlot<'_> {
    fn drop(&mut self) {
        let prev = self.0.fetch_sub(1, Ordering::SeqCst);
        metrics::registry()
            .gauge("regnde_serve_connections")
            .set(prev.saturating_sub(1) as f64);
    }
}

impl Server {
    pub fn new(registry: Arc<Registry>, batcher: Arc<Batcher>, opts: ServerOpts) -> Server {
        Server {
            registry,
            batcher,
            opts,
            shutdown: AtomicBool::new(false),
            active_conns: AtomicUsize::new(0),
        }
    }

    /// Serve until a `shutdown` request arrives, then **drain**: stop
    /// accepting, let every in-flight request finish and answer, and
    /// join all connection threads before returning.  A connection that
    /// sends another request after the drain begins gets a `shed`
    /// response and is closed.
    pub fn serve(self: &Arc<Self>, listener: TcpListener) -> Result<()> {
        let addr = listener.local_addr()?;
        let mut handles: Vec<std::thread::JoinHandle<()>> = Vec::new();
        for stream in listener.incoming() {
            if self.shutdown.load(Ordering::SeqCst) {
                break;
            }
            let Ok(stream) = stream else { continue };
            handles.retain(|h| !h.is_finished());
            // Connection-level backpressure: over the cap, answer one
            // shed line and close instead of spawning a thread.
            let occupied = self.active_conns.fetch_add(1, Ordering::SeqCst);
            if occupied >= self.opts.max_conns {
                self.active_conns.fetch_sub(1, Ordering::SeqCst);
                metrics::registry().counter("regnde_serve_conn_shed_total").inc();
                let mut stream = stream;
                let mut out =
                    Response::Shed("connection limit reached, retry with backoff".into()).encode();
                out.push('\n');
                let _ = stream.write_all(out.as_bytes());
                continue;
            }
            metrics::registry()
                .gauge("regnde_serve_connections")
                .set((occupied + 1) as f64);
            let server = Arc::clone(self);
            handles.push(std::thread::spawn(move || {
                let _slot = ConnSlot(&server.active_conns);
                server.handle_conn(stream, addr);
            }));
        }
        // Drain guarantee: every connection thread observes the flag
        // within one read-timeout tick and exits; in-flight solves
        // complete and answer first.
        for h in handles {
            let _ = h.join();
        }
        Ok(())
    }

    /// Bind `addr` and serve on a background thread; returns the bound
    /// address (use port 0 for an ephemeral one).  Joining the returned
    /// handle waits for the full drain.  The loopback path of
    /// `benches/bench_serving.rs` and the serving tests.
    pub fn spawn(
        registry: Arc<Registry>,
        batcher: Arc<Batcher>,
        opts: ServerOpts,
        addr: &str,
    ) -> Result<(SocketAddr, std::thread::JoinHandle<()>)> {
        let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
        let bound = listener.local_addr()?;
        let server = Arc::new(Server::new(registry, batcher, opts));
        let handle = std::thread::spawn(move || {
            let _ = server.serve(listener);
        });
        Ok((bound, handle))
    }

    fn handle_conn(&self, stream: TcpStream, server_addr: SocketAddr) {
        let _ = stream.set_read_timeout(Some(self.opts.read_timeout.max(Duration::from_millis(1))));
        let Ok(read_half) = stream.try_clone() else {
            return;
        };
        let mut reader = BufReader::new(read_half);
        let mut writer = stream;
        // Fresh per-connection quota (admission control state).
        let mut quota = self.opts.nfe_quota;
        let mut line = String::new();
        loop {
            // read_line appends: a partial line interrupted by a poll
            // timeout stays in `line` and completes on a later tick, so
            // slow writers get correct framing, not corrupted requests.
            match reader.read_line(&mut line) {
                Ok(0) => return, // client hung up
                Ok(_) => {}
                Err(e) if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut) => {
                    if self.shutdown.load(Ordering::SeqCst) {
                        return; // draining: nothing in flight here
                    }
                    continue;
                }
                Err(_) => return,
            }
            if line.trim().is_empty() {
                line.clear();
                continue;
            }
            // Plaintext scrape path: a `GET ` line means an HTTP client
            // (curl, the CI smoke) rather than the JSON protocol.
            // Answer `/metrics` with the Prometheus exposition and close
            // — HTTP/1.0 semantics, one request per connection.
            if line.trim_end().starts_with("GET ") {
                let target = line.split_whitespace().nth(1).unwrap_or("");
                let (status, body) = if target == "/metrics" {
                    ("200 OK", metrics::registry().render())
                } else {
                    ("404 Not Found", String::from("only /metrics is served\n"))
                };
                let head = format!(
                    "HTTP/1.0 {status}\r\nContent-Type: text/plain; version=0.0.4\r\n\
                     Content-Length: {}\r\n\r\n",
                    body.len()
                );
                let _ = writer.write_all(head.as_bytes());
                let _ = writer.write_all(body.as_bytes());
                let _ = writer.flush();
                return;
            }
            let (resp, closing) = if self.shutdown.load(Ordering::SeqCst) {
                // Request arrived after the drain began: shed (retryable
                // elsewhere), never start new solver work.
                metrics::registry().counter("regnde_serve_drain_shed_total").inc();
                (Response::Shed("server is draining".into()), true)
            } else {
                match Request::decode(line.trim()) {
                    Ok(req) => self.process(req, &mut quota),
                    Err(e) => (Response::error(format!("bad request: {e:#}")), false),
                }
            };
            line.clear();
            let mut out = resp.encode();
            out.push('\n');
            if writer.write_all(out.as_bytes()).is_err() || writer.flush().is_err() {
                return;
            }
            if closing {
                self.shutdown.store(true, Ordering::SeqCst);
                // Poke the accept loop so it observes the flag.
                let _ = TcpStream::connect(server_addr);
                return;
            }
        }
    }

    /// Execute one request against this connection's remaining `quota`.
    /// Returns the response and whether the connection (and server) is
    /// closing.  Factored off the socket so admission semantics are unit
    /// testable.
    ///
    /// Quota policy per outcome: served → charge realized attempts;
    /// solve ran and failed → charge the declared budget (it may have
    /// burned all of it); shed or rejected → no charge (the solver never
    /// ran).
    pub fn process(&self, req: Request, quota: &mut u64) -> (Response, bool) {
        match req {
            Request::List => (
                Response::List {
                    models: self.registry.ids(),
                },
                false,
            ),
            Request::Stats => (Response::stats(&self.batcher.stats()), false),
            Request::Metrics => (
                Response::Metrics {
                    text: metrics::registry().render(),
                },
                false,
            ),
            Request::Shutdown => (Response::Shutdown, true),
            Request::Predict {
                model,
                u0,
                budget,
                deadline_ms,
            } => {
                let t0 = Instant::now();
                metrics::registry()
                    .counter(&metrics::labeled("regnde_serve_requests_total", "model", &model))
                    .inc();
                let resp = self.predict_response(&model, u0, budget, deadline_ms, quota, t0);
                // Outcome accounting mirrors the quota policy above:
                // served / shed / everything-else-is-an-error.
                let outcome = match &resp {
                    Response::Predict { nfe, .. } => {
                        metrics::registry()
                            .histogram(
                                &metrics::labeled("regnde_serve_latency_seconds", "model", &model),
                                &metrics::LATENCY_BUCKETS,
                            )
                            .observe(t0.elapsed().as_secs_f64());
                        metrics::registry()
                            .histogram(
                                &metrics::labeled("regnde_serve_request_nfe", "model", &model),
                                &metrics::nfe_buckets(),
                            )
                            .observe(*nfe as f64);
                        "regnde_serve_served_total"
                    }
                    Response::Shed(_) => "regnde_serve_shed_total",
                    _ => "regnde_serve_errors_total",
                };
                metrics::registry()
                    .counter(&metrics::labeled(outcome, "model", &model))
                    .inc();
                (resp, false)
            }
        }
    }

    /// The predict path of [`Server::process`], factored out so the
    /// metric accounting wraps exactly one response-producing body.
    fn predict_response(
        &self,
        model: &str,
        u0: Vec<f32>,
        budget: Option<u64>,
        deadline_ms: Option<u64>,
        quota: &mut u64,
        t0: Instant,
    ) -> Response {
        // Admission: resolve the declared (or checkpoint-default)
        // attempt budget and reject before solving if it could
        // overrun this connection's remaining quota.
        let declared = match budget {
            Some(b) => b,
            None => match self.registry.get(model) {
                Ok(m) => m.default_budget(),
                Err(e) => return Response::error(format!("{e:#}")),
            },
        };
        if declared > *quota {
            return Response::error(format!(
                "admission rejected: request budget {declared} attempts \
                 exceeds remaining connection quota {quota}"
            ));
        }
        let deadline = deadline_ms.map(|ms| t0 + Duration::from_millis(ms));
        match self.batcher.submit(model, u0, Some(declared), deadline) {
            Ok(reply) => {
                // Charge the realized work of the batch solve.
                *quota = quota.saturating_sub(reply.naccept + reply.nreject);
                let micros = t0.elapsed().as_micros() as u64;
                Response::predict(model, &reply, micros)
            }
            Err(BatchError::Shed(msg)) => {
                // No solver work was done: retryable, not charged.
                Response::Shed(msg)
            }
            Err(BatchError::Solve { kind, msg }) => {
                // The solve ran and died — it may have burned the
                // whole declared budget, so charge it all: failing
                // requests cannot loop free solver CPU past the
                // quota.
                *quota = quota.saturating_sub(declared);
                Response::Error {
                    msg,
                    kind: Some(kind),
                }
            }
            Err(BatchError::Rejected(msg)) => {
                // Validation failure before any solve: not charged,
                // and not retryable as-is (no kind on the wire).
                Response::error(msg)
            }
        }
    }
}

/// A client connection: one request/response exchange at a time over a
/// persistent TCP stream (requests from the same `Client` are
/// sequential; open several `Client`s to exercise the batcher).
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Client> {
        let stream = TcpStream::connect(addr).with_context(|| format!("connecting {addr}"))?;
        let reader = BufReader::new(stream.try_clone()?);
        Ok(Client {
            reader,
            writer: stream,
        })
    }

    pub fn request(&mut self, req: &Request) -> Result<Response> {
        let mut line = req.encode();
        line.push('\n');
        self.writer.write_all(line.as_bytes())?;
        self.writer.flush()?;
        let mut resp = String::new();
        let n = self.reader.read_line(&mut resp)?;
        anyhow::ensure!(n > 0, "server closed the connection");
        Response::decode(resp.trim())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{Backend, NativeBackend};
    use crate::serve::batcher::BatchPolicy;
    use crate::serve::checkpoint::Checkpoint;
    use crate::util::threadpool::ThreadPool;
    use std::time::Duration;

    fn test_server(opts: ServerOpts) -> Arc<Server> {
        let be = NativeBackend::new();
        let params = be.init_params("spiral_node", 3).unwrap();
        let state = be.export_state("spiral_node", &params).unwrap();
        let ts: Vec<f32> = (0..6).map(|i| i as f32 / 5.0).collect();
        let registry = Arc::new(Registry::in_memory());
        registry
            .insert("spiral", Checkpoint::new(state, "spiral-node", "vanilla", ts))
            .unwrap();
        let pool = Arc::new(ThreadPool::new(2));
        let batcher = Arc::new(Batcher::new(
            Arc::clone(&registry),
            pool,
            BatchPolicy {
                max_batch: 4,
                max_wait: Duration::from_micros(100),
                ..Default::default()
            },
        ));
        Arc::new(Server::new(registry, batcher, opts))
    }

    fn quota_server(quota: u64) -> Arc<Server> {
        test_server(ServerOpts {
            nfe_quota: quota,
            ..Default::default()
        })
    }

    fn predict(model: &str, budget: Option<u64>) -> Request {
        Request::Predict {
            model: model.into(),
            u0: vec![2.0, 0.0],
            budget,
            deadline_ms: None,
        }
    }

    #[test]
    fn admission_rejects_over_quota_and_charges_realized_attempts() {
        let server = quota_server(10_000);
        let mut quota = server.opts.nfe_quota;

        // Declared budget above the quota: rejected up front.
        let (resp, _) = server.process(predict("spiral", Some(20_000)), &mut quota);
        assert!(matches!(&resp, Response::Error { msg, .. } if msg.contains("admission")));
        assert_eq!(quota, 10_000, "rejected requests must not be charged");

        // Within quota: served, and the realized attempts are deducted.
        let (resp, closing) = server.process(predict("spiral", Some(9_000)), &mut quota);
        assert!(!closing);
        match resp {
            Response::Predict { nfe, naccept, nreject, batch, ref traj, .. } => {
                assert!(nfe > 0 && naccept > 0);
                assert!(batch >= 1);
                assert_eq!(traj.len(), 6 * 2);
                assert_eq!(quota, 10_000 - (naccept + nreject));
            }
            other => panic!("expected predict response, got {other:?}"),
        }

        // Quota drains to the point of refusing the default budget.
        quota = 5;
        let (resp, _) = server.process(predict("spiral", None), &mut quota);
        assert!(matches!(&resp, Response::Error { msg, .. } if msg.contains("admission")));
    }

    #[test]
    fn expired_deadline_is_shed_and_never_charged() {
        let server = quota_server(10_000);
        let mut quota = server.opts.nfe_quota;
        let (resp, closing) = server.process(
            Request::Predict {
                model: "spiral".into(),
                u0: vec![2.0, 0.0],
                budget: None,
                deadline_ms: Some(0),
            },
            &mut quota,
        );
        assert!(!closing);
        assert!(matches!(resp, Response::Shed(_)), "got {resp:?}");
        assert_eq!(quota, 10_000, "shed requests must not be charged");
        // The shed shows up in the stats response.
        let (resp, _) = server.process(Request::Stats, &mut quota);
        match resp {
            Response::Stats { shed, .. } => assert!(shed >= 1, "shed count must be reported"),
            other => panic!("expected stats, got {other:?}"),
        }
    }

    #[test]
    fn list_stats_and_shutdown_ops() {
        let server = quota_server(1_000_000);
        let mut quota = u64::MAX;
        let (resp, _) = server.process(Request::List, &mut quota);
        assert_eq!(
            resp,
            Response::List {
                models: vec!["spiral".to_string()]
            }
        );
        let (resp, closing) = server.process(Request::Shutdown, &mut quota);
        assert_eq!(resp, Response::Shutdown);
        assert!(closing);
        let (resp, _) = server.process(Request::Stats, &mut quota);
        assert!(matches!(resp, Response::Stats { .. }));
    }

    #[test]
    fn loopback_end_to_end_with_draining_shutdown() {
        let server = test_server(ServerOpts::default());
        let registry_models = server.registry.ids();
        assert_eq!(registry_models, vec!["spiral".to_string()]);
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let serve_handle = {
            let server = Arc::clone(&server);
            std::thread::spawn(move || {
                let _ = server.serve(listener);
            })
        };
        let mut client = Client::connect(&addr.to_string()).unwrap();
        let resp = client.request(&Request::List).unwrap();
        assert_eq!(
            resp,
            Response::List {
                models: vec!["spiral".to_string()]
            }
        );
        let resp = client.request(&predict("spiral", None)).unwrap();
        match resp {
            Response::Predict { ref traj, nfe, .. } => {
                assert_eq!(traj.len(), 12);
                assert!(nfe > 0, "NFE must be reported per response");
                assert_eq!(traj[0], 2.0);
                assert_eq!(traj[1], 0.0);
            }
            other => panic!("expected predict, got {other:?}"),
        }
        // Unknown model: typed error, connection stays usable.
        let resp = client.request(&predict("ghost", None)).unwrap();
        assert!(matches!(resp, Response::Error { .. }));
        let resp = client.request(&Request::Shutdown).unwrap();
        assert_eq!(resp, Response::Shutdown);
        // Drain guarantee: serve() joins every connection thread and
        // returns; a hung drain fails the suite's timeout, a panic in
        // the serve thread fails the join.
        serve_handle.join().expect("serve thread must exit cleanly");
    }

    #[test]
    fn metrics_op_reports_per_model_families() {
        let server = quota_server(1_000_000);
        let mut quota = server.opts.nfe_quota;
        let (resp, _) = server.process(predict("spiral", None), &mut quota);
        assert!(matches!(resp, Response::Predict { .. }), "got {resp:?}");
        let (resp, closing) = server.process(Request::Metrics, &mut quota);
        assert!(!closing);
        let text = match resp {
            Response::Metrics { text } => text,
            other => panic!("expected metrics, got {other:?}"),
        };
        // The registry is process-global and other tests share the
        // "spiral" label, so assert presence, not exact counts.
        for family in [
            "# TYPE regnde_serve_requests_total counter",
            "regnde_serve_requests_total{model=\"spiral\"}",
            "regnde_serve_served_total{model=\"spiral\"}",
            "regnde_serve_latency_seconds_bucket{model=\"spiral\",le=\"+Inf\"}",
            "regnde_serve_request_nfe_count{model=\"spiral\"}",
        ] {
            assert!(text.contains(family), "missing {family:?} in:\n{text}");
        }
    }

    #[test]
    fn http_get_scrapes_the_prometheus_exposition() {
        let server = test_server(ServerOpts::default());
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let serve_handle = {
            let server = Arc::clone(&server);
            std::thread::spawn(move || {
                let _ = server.serve(listener);
            })
        };
        // Prime one request so per-model families exist.
        let mut client = Client::connect(&addr.to_string()).unwrap();
        let resp = client.request(&predict("spiral", None)).unwrap();
        assert!(matches!(resp, Response::Predict { .. }), "got {resp:?}");
        // Plain HTTP scrape on the same port, no JSON protocol.
        let mut http = TcpStream::connect(addr).unwrap();
        http.write_all(b"GET /metrics HTTP/1.0\r\n\r\n").unwrap();
        let mut scraped = String::new();
        std::io::Read::read_to_string(&mut http, &mut scraped).unwrap();
        assert!(scraped.starts_with("HTTP/1.0 200 OK\r\n"), "got {scraped:?}");
        assert!(scraped.contains("Content-Type: text/plain; version=0.0.4"));
        assert!(scraped.contains("regnde_serve_requests_total{model=\"spiral\"}"));
        // Unknown paths answer 404 and close.
        let mut http = TcpStream::connect(addr).unwrap();
        http.write_all(b"GET /other HTTP/1.0\r\n\r\n").unwrap();
        let mut scraped = String::new();
        std::io::Read::read_to_string(&mut http, &mut scraped).unwrap();
        assert!(scraped.starts_with("HTTP/1.0 404 Not Found\r\n"), "got {scraped:?}");
        let resp = client.request(&Request::Shutdown).unwrap();
        assert_eq!(resp, Response::Shutdown);
        serve_handle.join().expect("serve thread must exit cleanly");
    }

    #[test]
    fn over_cap_connections_are_shed_at_accept() {
        let server = test_server(ServerOpts {
            max_conns: 1,
            read_timeout: Duration::from_millis(20),
            ..Default::default()
        });
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let serve_handle = {
            let server = Arc::clone(&server);
            std::thread::spawn(move || {
                let _ = server.serve(listener);
            })
        };
        // First connection occupies the only slot...
        let mut first = Client::connect(&addr.to_string()).unwrap();
        let resp = first.request(&Request::List).unwrap();
        assert!(matches!(resp, Response::List { .. }));
        // ...so the second is shed with one response line, then closed.
        let mut second = Client::connect(&addr.to_string()).unwrap();
        let mut resp = String::new();
        second.reader.read_line(&mut resp).unwrap();
        let resp = Response::decode(resp.trim()).unwrap();
        assert!(matches!(resp, Response::Shed(_)), "got {resp:?}");
        let n = second.reader.read_line(&mut String::new()).unwrap();
        assert_eq!(n, 0, "shed connection must be closed by the server");
        // Dropping the first frees the slot within a poll tick.
        drop(first);
        std::thread::sleep(Duration::from_millis(100));
        let mut third = Client::connect(&addr.to_string()).unwrap();
        let resp = third.request(&Request::List).unwrap();
        assert!(matches!(resp, Response::List { .. }));
        let resp = third.request(&Request::Shutdown).unwrap();
        assert_eq!(resp, Response::Shutdown);
        serve_handle.join().expect("serve thread must exit cleanly");
    }
}
