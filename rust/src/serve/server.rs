//! TCP prediction server + client: `regnde serve` / `regnde predict
//! --addr`.
//!
//! A thin `std::net` loop around the [`Registry`] + [`Batcher`] core:
//! one thread per connection, one [`protocol`] JSON line per request and
//! response.  Concurrency therefore comes from *connections* — clients
//! holding separate connections are what the batcher coalesces into
//! row-batched solves.
//!
//! ## NFE-budget admission control
//!
//! Every connection starts with an **NFE quota** measured in solver step
//! attempts ([`ServerOpts::nfe_quota`]) — the unit
//! `StepBudget::Total` bounds, and `attempts × nfe_per_attempt` away
//! from raw NFE.  A predict request declares a total attempt budget
//! (defaulting to its checkpoint's `step_budget`); the server **rejects
//! the request up front** if that declared budget exceeds the
//! connection's remaining quota — a request that *could* exhaust the
//! quota never reaches the solver.  After a served request, the quota is
//! charged the *realized* attempts of its batch solve; a *failed* solve
//! is charged the full declared budget (it may have burned all of it).
//! Well-behaved cheap requests (the regularized-model case) therefore
//! stretch the same quota further.
//!
//! [`protocol`]: super::protocol

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Instant;

use anyhow::{Context, Result};

use super::batcher::Batcher;
use super::protocol::{Request, Response};
use super::registry::Registry;

/// Per-server policy knobs.
#[derive(Clone, Copy, Debug)]
pub struct ServerOpts {
    /// Per-connection step-attempt quota (admission control unit).
    pub nfe_quota: u64,
}

impl Default for ServerOpts {
    fn default() -> Self {
        ServerOpts {
            nfe_quota: 1_000_000,
        }
    }
}

/// The prediction server: accept loop + per-connection protocol state.
pub struct Server {
    registry: Arc<Registry>,
    batcher: Arc<Batcher>,
    opts: ServerOpts,
    shutdown: AtomicBool,
}

impl Server {
    pub fn new(registry: Arc<Registry>, batcher: Arc<Batcher>, opts: ServerOpts) -> Server {
        Server {
            registry,
            batcher,
            opts,
            shutdown: AtomicBool::new(false),
        }
    }

    /// Serve until a `shutdown` request arrives.  Connections are one
    /// thread each and are **not drained on shutdown**: this returns as
    /// soon as the accept loop observes the flag, and a caller that then
    /// exits the process (the CLI does) cuts any still-running
    /// connection threads mid-request.  Callers needing a graceful drain
    /// should stop sending first.
    pub fn serve(self: &Arc<Self>, listener: TcpListener) -> Result<()> {
        let addr = listener.local_addr()?;
        for stream in listener.incoming() {
            if self.shutdown.load(Ordering::SeqCst) {
                break;
            }
            let Ok(stream) = stream else { continue };
            let server = Arc::clone(self);
            std::thread::spawn(move || server.handle_conn(stream, addr));
        }
        Ok(())
    }

    /// Bind `addr` and serve on a background thread; returns the bound
    /// address (use port 0 for an ephemeral one).  The loopback path of
    /// `benches/bench_serving.rs` and the serving tests.
    pub fn spawn(
        registry: Arc<Registry>,
        batcher: Arc<Batcher>,
        opts: ServerOpts,
        addr: &str,
    ) -> Result<(SocketAddr, std::thread::JoinHandle<()>)> {
        let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
        let bound = listener.local_addr()?;
        let server = Arc::new(Server::new(registry, batcher, opts));
        let handle = std::thread::spawn(move || {
            let _ = server.serve(listener);
        });
        Ok((bound, handle))
    }

    fn handle_conn(&self, stream: TcpStream, server_addr: SocketAddr) {
        let Ok(read_half) = stream.try_clone() else {
            return;
        };
        let mut reader = BufReader::new(read_half);
        let mut writer = stream;
        // Fresh per-connection quota (admission control state).
        let mut quota = self.opts.nfe_quota;
        let mut line = String::new();
        loop {
            line.clear();
            match reader.read_line(&mut line) {
                Ok(0) | Err(_) => return, // client hung up
                Ok(_) => {}
            }
            if line.trim().is_empty() {
                continue;
            }
            let (resp, closing) = match Request::decode(line.trim()) {
                Ok(req) => self.process(req, &mut quota),
                Err(e) => (Response::Error(format!("bad request: {e:#}")), false),
            };
            let mut out = resp.encode();
            out.push('\n');
            if writer.write_all(out.as_bytes()).is_err() || writer.flush().is_err() {
                return;
            }
            if closing {
                self.shutdown.store(true, Ordering::SeqCst);
                // Poke the accept loop so it observes the flag.
                let _ = TcpStream::connect(server_addr);
                return;
            }
        }
    }

    /// Execute one request against this connection's remaining `quota`.
    /// Returns the response and whether the connection (and server) is
    /// closing.  Factored off the socket so admission semantics are unit
    /// testable.
    pub fn process(&self, req: Request, quota: &mut u64) -> (Response, bool) {
        match req {
            Request::List => (
                Response::List {
                    models: self.registry.ids(),
                },
                false,
            ),
            Request::Stats => (Response::stats(&self.batcher.stats()), false),
            Request::Shutdown => (Response::Shutdown, true),
            Request::Predict { model, u0, budget } => {
                // Admission: resolve the declared (or checkpoint-default)
                // attempt budget and reject before solving if it could
                // overrun this connection's remaining quota.
                let declared = match budget {
                    Some(b) => b,
                    None => match self.registry.get(&model) {
                        Ok(m) => m.default_budget(),
                        Err(e) => return (Response::Error(format!("{e:#}")), false),
                    },
                };
                if declared > *quota {
                    return (
                        Response::Error(format!(
                            "admission rejected: request budget {declared} attempts \
                             exceeds remaining connection quota {quota}"
                        )),
                        false,
                    );
                }
                let t0 = Instant::now();
                match self.batcher.submit(&model, u0, Some(declared)) {
                    Ok(reply) => {
                        // Charge the realized work of the batch solve.
                        *quota = quota.saturating_sub(reply.naccept + reply.nreject);
                        let micros = t0.elapsed().as_micros() as u64;
                        (Response::predict(&model, &reply, micros), false)
                    }
                    Err(e) => {
                        // A failed solve may still have burned solver
                        // work (budget exhaustion burns *all* of it), and
                        // the error path carries no Stats — charge the
                        // declared budget so failing requests cannot loop
                        // free solver CPU past the quota.
                        *quota = quota.saturating_sub(declared);
                        (Response::Error(format!("{e:#}")), false)
                    }
                }
            }
        }
    }
}

/// A client connection: one request/response exchange at a time over a
/// persistent TCP stream (requests from the same `Client` are
/// sequential; open several `Client`s to exercise the batcher).
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Client> {
        let stream = TcpStream::connect(addr).with_context(|| format!("connecting {addr}"))?;
        let reader = BufReader::new(stream.try_clone()?);
        Ok(Client {
            reader,
            writer: stream,
        })
    }

    pub fn request(&mut self, req: &Request) -> Result<Response> {
        let mut line = req.encode();
        line.push('\n');
        self.writer.write_all(line.as_bytes())?;
        self.writer.flush()?;
        let mut resp = String::new();
        let n = self.reader.read_line(&mut resp)?;
        anyhow::ensure!(n > 0, "server closed the connection");
        Response::decode(resp.trim())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{Backend, NativeBackend};
    use crate::serve::batcher::BatchPolicy;
    use crate::serve::checkpoint::Checkpoint;
    use crate::util::threadpool::ThreadPool;
    use std::time::Duration;

    fn test_server(quota: u64) -> Arc<Server> {
        let be = NativeBackend::new();
        let params = be.init_params("spiral_node", 3).unwrap();
        let state = be.export_state("spiral_node", &params).unwrap();
        let ts: Vec<f32> = (0..6).map(|i| i as f32 / 5.0).collect();
        let registry = Arc::new(Registry::in_memory());
        registry
            .insert("spiral", Checkpoint::new(state, "spiral-node", "vanilla", ts))
            .unwrap();
        let pool = Arc::new(ThreadPool::new(2));
        let batcher = Arc::new(Batcher::new(
            Arc::clone(&registry),
            pool,
            BatchPolicy {
                max_batch: 4,
                max_wait: Duration::from_micros(100),
            },
        ));
        Arc::new(Server::new(registry, batcher, ServerOpts { nfe_quota: quota }))
    }

    #[test]
    fn admission_rejects_over_quota_and_charges_realized_attempts() {
        let server = test_server(10_000);
        let mut quota = server.opts.nfe_quota;

        // Declared budget above the quota: rejected up front.
        let (resp, _) = server.process(
            Request::Predict {
                model: "spiral".into(),
                u0: vec![2.0, 0.0],
                budget: Some(20_000),
            },
            &mut quota,
        );
        assert!(matches!(&resp, Response::Error(e) if e.contains("admission")));
        assert_eq!(quota, 10_000, "rejected requests must not be charged");

        // Within quota: served, and the realized attempts are deducted.
        let (resp, closing) = server.process(
            Request::Predict {
                model: "spiral".into(),
                u0: vec![2.0, 0.0],
                budget: Some(9_000),
            },
            &mut quota,
        );
        assert!(!closing);
        match resp {
            Response::Predict { nfe, naccept, nreject, batch, ref traj, .. } => {
                assert!(nfe > 0 && naccept > 0);
                assert!(batch >= 1);
                assert_eq!(traj.len(), 6 * 2);
                assert_eq!(quota, 10_000 - (naccept + nreject));
            }
            other => panic!("expected predict response, got {other:?}"),
        }

        // Quota drains to the point of refusing the default budget.
        quota = 5;
        let (resp, _) = server.process(
            Request::Predict {
                model: "spiral".into(),
                u0: vec![2.0, 0.0],
                budget: None,
            },
            &mut quota,
        );
        assert!(matches!(&resp, Response::Error(e) if e.contains("admission")));
    }

    #[test]
    fn list_stats_and_shutdown_ops() {
        let server = test_server(1_000_000);
        let mut quota = u64::MAX;
        let (resp, _) = server.process(Request::List, &mut quota);
        assert_eq!(
            resp,
            Response::List {
                models: vec!["spiral".to_string()]
            }
        );
        let (resp, closing) = server.process(Request::Shutdown, &mut quota);
        assert_eq!(resp, Response::Shutdown);
        assert!(closing);
        let (resp, _) = server.process(Request::Stats, &mut quota);
        assert!(matches!(resp, Response::Stats { .. }));
    }

    #[test]
    fn loopback_end_to_end() {
        let server = test_server(1_000_000);
        let registry_models = server.registry.ids();
        assert_eq!(registry_models, vec!["spiral".to_string()]);
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        {
            let server = Arc::clone(&server);
            std::thread::spawn(move || {
                let _ = server.serve(listener);
            });
        }
        let mut client = Client::connect(&addr.to_string()).unwrap();
        let resp = client.request(&Request::List).unwrap();
        assert_eq!(
            resp,
            Response::List {
                models: vec!["spiral".to_string()]
            }
        );
        let resp = client
            .request(&Request::Predict {
                model: "spiral".into(),
                u0: vec![2.0, 0.0],
                budget: None,
            })
            .unwrap();
        match resp {
            Response::Predict { ref traj, nfe, .. } => {
                assert_eq!(traj.len(), 12);
                assert!(nfe > 0, "NFE must be reported per response");
                assert_eq!(traj[0], 2.0);
                assert_eq!(traj[1], 0.0);
            }
            other => panic!("expected predict, got {other:?}"),
        }
        // Unknown model: typed error, connection stays usable.
        let resp = client
            .request(&Request::Predict {
                model: "ghost".into(),
                u0: vec![1.0, 1.0],
                budget: None,
            })
            .unwrap();
        assert!(matches!(resp, Response::Error(_)));
        let resp = client.request(&Request::Shutdown).unwrap();
        assert_eq!(resp, Response::Shutdown);
    }
}
