//! Micro-batching request queue: coalesce concurrent single-trajectory
//! predict requests into one row-batched solve.
//!
//! This is where the paper's NFE savings become serving throughput: a
//! batch of `B` coalesced requests pays the solver's accepted/rejected
//! steps **once** (one `drive()` over `[B, d]` rows,
//! `NativeBackend::predict_traj_batch`), so a regularized model that
//! needs fewer steps per solve serves more requests per core — and
//! batching multiplies that by `B`.
//!
//! ## Coalescing policy (leader/follower windows)
//!
//! Requests for the same model join an open **window**; the first
//! request of a window is its *leader*.  The leader waits
//! [`BatchPolicy::max_wait`] for followers to accumulate, then closes
//! the window and hands the whole batch to the shared [`ThreadPool`] as
//! one job.  A window never exceeds [`BatchPolicy::max_batch`] requests
//! — an arrival finding the open window full opens a new window (and
//! becomes its leader), so overload turns into multiple concurrent
//! batch solves bounded by the pool width, never an unbounded batch.
//! `max_wait` is a hard latency floor for coalesced batches: the leader
//! sleeps the full window even if it fills early (keep it µs-scale).
//!
//! Every response carries the batch solve's [`Stats`] (per-request NFE
//! accounting: the steps a request's solve took, shared by its whole
//! batch) and the realized batch size.  A failing solve — budget
//! exhausted, non-finite state, model not row-batchable — fails **only
//! its own window's requests**; other windows and models are untouched.
//!
//! [`ThreadPool`]: crate::util::threadpool::ThreadPool

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::Duration;

use anyhow::{anyhow, Result};

use super::registry::{Registry, ServableModel};
use crate::solvers::ode::Stats;
use crate::util::threadpool::ThreadPool;

/// Coalescing knobs of one batcher.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    /// Hard cap on requests per batched solve.
    pub max_batch: usize,
    /// How long a window's leader waits for followers before the batch
    /// solves (the micro-batching latency budget).
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy {
            max_batch: 16,
            max_wait: Duration::from_micros(2000),
        }
    }
}

/// One served prediction: the requester's trajectory plus the batch
/// solve's accounting.
#[derive(Clone, Debug)]
pub struct BatchReply {
    /// Row-major `[T, d]` trajectory over the model's serving grid.
    pub traj: Vec<f32>,
    /// NFE of the solve that served this request (shared by the batch).
    pub nfe: u64,
    pub naccept: u64,
    pub nreject: u64,
    /// How many requests rode the same solve.
    pub batch: usize,
}

struct Job {
    u0: Vec<f32>,
    budget: u64,
    tx: mpsc::Sender<Result<BatchReply, String>>,
}

#[derive(Default)]
struct Window {
    jobs: Vec<Job>,
}

#[derive(Default)]
struct ModelQueue {
    /// Open windows by id; a window is removed when its leader closes it.
    windows: BTreeMap<u64, Window>,
    /// Id of the newest window still accepting joiners (if any).
    open: Option<u64>,
}

/// Aggregate batcher telemetry (served through the `stats` protocol op
/// and asserted by the batcher tests).
#[derive(Clone, Copy, Debug, Default)]
pub struct BatcherStats {
    pub batches: u64,
    pub requests: u64,
    pub max_batch: usize,
    /// Sum of batch-solve NFE over all batches (mean NFE per request =
    /// weighted by how many requests shared each solve).
    pub nfe_total: u64,
}

impl BatcherStats {
    pub fn mean_batch(&self) -> f64 {
        self.requests as f64 / (self.batches as f64).max(1.0)
    }
}

/// The micro-batching queue over a [`Registry`] and a shared
/// [`ThreadPool`].
pub struct Batcher {
    registry: Arc<Registry>,
    pool: Arc<ThreadPool>,
    policy: BatchPolicy,
    queues: Mutex<BTreeMap<String, ModelQueue>>,
    next_window: AtomicU64,
    stats: Arc<Mutex<BatcherStats>>,
}

impl Batcher {
    pub fn new(registry: Arc<Registry>, pool: Arc<ThreadPool>, policy: BatchPolicy) -> Batcher {
        Batcher {
            registry,
            pool,
            policy,
            queues: Mutex::new(BTreeMap::new()),
            next_window: AtomicU64::new(0),
            stats: Arc::new(Mutex::new(BatcherStats::default())),
        }
    }

    pub fn policy(&self) -> BatchPolicy {
        self.policy
    }

    pub fn stats(&self) -> BatcherStats {
        *self.stats.lock().unwrap()
    }

    /// Serve one prediction, blocking until its batch solves.  `budget`
    /// is the request's total step-attempt bound (defaults to the
    /// checkpoint's); shape and non-finite-input errors are rejected
    /// here, before the request can join (and poison) a window.  A
    /// request declaring a budget *below* the checkpoint default rides
    /// alone: the batch solves under the minimum of its riders' budgets,
    /// so an underfunded request must not drag a shared window down to a
    /// bound the other riders never asked for.
    pub fn submit(&self, model_id: &str, u0: Vec<f32>, budget: Option<u64>) -> Result<BatchReply> {
        let model = self.registry.get(model_id)?;
        let d = model.state_dim.ok_or_else(|| {
            anyhow!(
                "model {model_id:?} ({}) is not servable via the trajectory batcher",
                model.model_name()
            )
        })?;
        if u0.is_empty() || u0.len() != d {
            anyhow::bail!(
                "model {model_id:?} expects a {d}-dim initial state, got {} floats",
                u0.len()
            );
        }
        if !u0.iter().all(|v| v.is_finite()) {
            anyhow::bail!(
                "model {model_id:?}: initial state must be finite (got {u0:?})"
            );
        }
        let default_budget = model.default_budget();
        let budget = budget.unwrap_or(default_budget);
        let coalescible = budget >= default_budget;
        let (tx, rx) = mpsc::channel();

        // Join the open window, or open a new one and become its leader.
        // Underfunded requests always open (and close) their own window.
        let lead = {
            let mut queues = self.queues.lock().unwrap();
            let q = queues.entry(model_id.to_string()).or_default();
            let mut job = Some(Job { u0, budget, tx });
            if coalescible {
                if let Some(id) = q.open {
                    if let Some(w) = q.windows.get_mut(&id) {
                        if w.jobs.len() < self.policy.max_batch {
                            w.jobs.push(job.take().unwrap());
                        }
                    }
                }
            }
            match job {
                None => None,
                Some(job) => {
                    let id = self.next_window.fetch_add(1, Ordering::Relaxed);
                    q.windows.insert(id, Window { jobs: vec![job] });
                    if coalescible {
                        q.open = Some(id);
                    }
                    Some(id)
                }
            }
        };

        if let Some(window_id) = lead {
            // Leader: hold the window open for followers, then close it
            // and ship the batch to the pool (the leader's own reply
            // arrives through its channel like everyone else's).  A solo
            // (underfunded) window takes no followers, so it skips the
            // coalescing wait entirely.
            if coalescible {
                std::thread::sleep(self.policy.max_wait);
            }
            let jobs = {
                let mut queues = self.queues.lock().unwrap();
                let q = queues.get_mut(model_id).unwrap();
                if q.open == Some(window_id) {
                    q.open = None;
                }
                let window = q.windows.remove(&window_id);
                window.map(|w| w.jobs).unwrap_or_default()
            };
            if !jobs.is_empty() {
                let stats = Arc::clone(&self.stats);
                self.pool.execute(move || execute_batch(model, jobs, stats));
            }
        }

        rx.recv()
            .map_err(|_| anyhow!("batch executor dropped the request"))?
            .map_err(|e| anyhow!(e))
    }
}

/// Run one window's batch as a single row-batched solve and route each
/// trajectory back to its requester.  On failure every rider of *this*
/// batch gets the error; nothing else is affected.
fn execute_batch(model: Arc<ServableModel>, jobs: Vec<Job>, stats: Arc<Mutex<BatcherStats>>) {
    let b = jobs.len();
    let d = jobs[0].u0.len();
    let mut u0s = Vec::with_capacity(b * d);
    for job in &jobs {
        u0s.extend_from_slice(&job.u0);
    }
    // The batch solves under the tightest rider's budget: no request can
    // be made to exceed the bound it declared (admission control counts
    // the same unit).
    let budget = jobs.iter().map(|j| j.budget).min().unwrap_or(u64::MAX);

    match model.predict_batch(&u0s, budget) {
        Ok((trajs, solve_stats)) => {
            record(&stats, b, &solve_stats);
            for (job, traj) in jobs.into_iter().zip(trajs) {
                let _ = job.tx.send(Ok(BatchReply {
                    traj,
                    nfe: solve_stats.nfe,
                    naccept: solve_stats.naccept,
                    nreject: solve_stats.nreject,
                    batch: b,
                }));
            }
        }
        Err(e) => {
            let msg = format!("{e:#}");
            for job in jobs {
                let _ = job.tx.send(Err(msg.clone()));
            }
        }
    }
}

fn record(stats: &Mutex<BatcherStats>, batch: usize, solve: &Stats) {
    let mut s = stats.lock().unwrap();
    s.batches += 1;
    s.requests += batch as u64;
    s.max_batch = s.max_batch.max(batch);
    s.nfe_total += solve.nfe;
}
