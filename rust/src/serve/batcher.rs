//! Micro-batching request queue: coalesce concurrent single-trajectory
//! predict requests into one row-batched solve.
//!
//! This is where the paper's NFE savings become serving throughput: a
//! batch of `B` coalesced requests pays the solver's accepted/rejected
//! steps **once** (one `drive()` over `[B, d]` rows,
//! `NativeBackend::predict_traj_batch`), so a regularized model that
//! needs fewer steps per solve serves more requests per core — and
//! batching multiplies that by `B`.
//!
//! ## Coalescing policy (leader/follower windows)
//!
//! Requests for the same model join an open **window**; the first
//! request of a window is its *leader*.  The leader waits
//! [`BatchPolicy::max_wait`] for followers to accumulate, then closes
//! the window and hands the whole batch to the shared [`ThreadPool`] as
//! one job.  A window never exceeds [`BatchPolicy::max_batch`] requests
//! — an arrival finding the open window full opens a new window (and
//! becomes its leader), so overload turns into multiple concurrent
//! batch solves bounded by the pool width, never an unbounded batch.
//! `max_wait` is a hard latency floor for coalesced batches: the leader
//! sleeps the full window even if it fills early (keep it µs-scale).
//!
//! ## Failure containment (DESIGN.md §Robustness)
//!
//! * **Bounded admission**: at most [`BatchPolicy::max_queue`] requests
//!   may be queued per model across its open windows.  An arrival over
//!   that bound is **shed** ([`BatchError::Shed`]) without touching the
//!   solver — backpressure instead of unbounded memory growth.
//! * **Deadlines**: a request carrying a deadline that expires while it
//!   waits in a window is shed when the window closes, before the solve
//!   runs — expired work is never paid for.
//! * **Typed solve failures**: a failing batch solve fails **only its
//!   own window's requests**, each rider receiving the solver's
//!   [`SolveErrorKind`] ([`BatchError::Solve`]); other windows and
//!   models are untouched.
//! * **Poison tolerance**: all internal locks recover from a panicked
//!   holder (`into_inner`) — one crashed executor thread cannot take
//!   down every later request with poison panics.
//!
//! Every response carries the batch solve's [`Stats`] (per-request NFE
//! accounting: the steps a request's solve took, shared by its whole
//! batch) and the realized batch size.
//!
//! [`ThreadPool`]: crate::util::threadpool::ThreadPool

use std::collections::BTreeMap;
use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex, MutexGuard};
use std::time::{Duration, Instant};

use super::registry::{PredictError, Registry, ServableModel};
use crate::obs::metrics::{self, Counter, Histogram};
use crate::solvers::error::SolveErrorKind;
use crate::solvers::ode::Stats;
use crate::util::threadpool::ThreadPool;

/// Coalescing knobs of one batcher.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    /// Hard cap on requests per batched solve.
    pub max_batch: usize,
    /// How long a window's leader waits for followers before the batch
    /// solves (the micro-batching latency budget).
    pub max_wait: Duration,
    /// Bounded admission: the most requests that may be queued per model
    /// across its open windows; arrivals beyond it are shed.
    pub max_queue: usize,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy {
            max_batch: 16,
            max_wait: Duration::from_micros(2000),
            max_queue: 256,
        }
    }
}

/// One served prediction: the requester's trajectory plus the batch
/// solve's accounting.
#[derive(Clone, Debug)]
pub struct BatchReply {
    /// Row-major `[T, d]` trajectory over the model's serving grid.
    pub traj: Vec<f32>,
    /// NFE of the solve that served this request (shared by the batch).
    pub nfe: u64,
    pub naccept: u64,
    pub nreject: u64,
    /// How many requests rode the same solve.
    pub batch: usize,
}

/// Why a submitted request failed — the typed contract the server maps
/// onto wire responses (`shed` vs `error`+`kind`, DESIGN.md §Robustness).
#[derive(Clone, Debug)]
pub enum BatchError {
    /// Load-shed before any solver work (admission queue full, deadline
    /// expired).  Retryable with backoff.
    Shed(String),
    /// The batch solve ran and failed with a typed solver error; every
    /// rider of the poisoned window receives the same kind.
    Solve { kind: SolveErrorKind, msg: String },
    /// Rejected before joining a window: unknown model, wrong shape,
    /// non-finite input.  Not retryable — the same request fails again.
    Rejected(String),
}

impl fmt::Display for BatchError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            BatchError::Shed(m) => write!(f, "shed: {m}"),
            BatchError::Solve { kind, msg } => write!(f, "{msg} [{kind}]"),
            BatchError::Rejected(m) => f.write_str(m),
        }
    }
}

impl std::error::Error for BatchError {}

struct Job {
    u0: Vec<f32>,
    budget: u64,
    /// Absolute deadline; a job still queued past it is shed at window
    /// close instead of solved.
    deadline: Option<Instant>,
    tx: mpsc::Sender<Result<BatchReply, BatchError>>,
}

#[derive(Default)]
struct Window {
    jobs: Vec<Job>,
}

#[derive(Default)]
struct ModelQueue {
    /// Open windows by id; a window is removed when its leader closes it.
    windows: BTreeMap<u64, Window>,
    /// Id of the newest window still accepting joiners (if any).
    open: Option<u64>,
}

impl ModelQueue {
    /// Requests currently queued across this model's open windows (the
    /// bounded-admission unit).
    fn queued(&self) -> usize {
        self.windows.values().map(|w| w.jobs.len()).sum()
    }
}

/// Aggregate batcher telemetry (served through the `stats` protocol op
/// and asserted by the batcher tests).
#[derive(Clone, Copy, Debug, Default)]
pub struct BatcherStats {
    pub batches: u64,
    pub requests: u64,
    pub max_batch: usize,
    /// Sum of batch-solve NFE over all batches (mean NFE per request =
    /// weighted by how many requests shared each solve).
    pub nfe_total: u64,
    /// Requests shed by backpressure (queue full or deadline expired)
    /// without any solver work.
    pub shed: u64,
}

impl BatcherStats {
    pub fn mean_batch(&self) -> f64 {
        self.requests as f64 / (self.batches as f64).max(1.0)
    }
}

/// Poison-tolerant lock (see module docs).
fn plock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|p| p.into_inner())
}

/// Global-registry handles, resolved **once** at construction so the
/// submit/execute paths only touch lock-free cells, never the registry's
/// name map (DESIGN.md §Observability overhead policy).
#[derive(Clone)]
struct BatcherMetrics {
    /// Realized batch-size distribution (`regnde_serve_batch_size`).
    batch_size: Histogram,
    /// Batched solves executed (`regnde_serve_batches_total`).
    batches: Counter,
    /// Requests shed by the batcher (`regnde_serve_batch_shed_total`).
    shed: Counter,
}

impl BatcherMetrics {
    fn resolve() -> BatcherMetrics {
        let reg = metrics::registry();
        BatcherMetrics {
            batch_size: reg.histogram("regnde_serve_batch_size", &metrics::batch_buckets()),
            batches: reg.counter("regnde_serve_batches_total"),
            shed: reg.counter("regnde_serve_batch_shed_total"),
        }
    }
}

/// The micro-batching queue over a [`Registry`] and a shared
/// [`ThreadPool`].
pub struct Batcher {
    registry: Arc<Registry>,
    pool: Arc<ThreadPool>,
    policy: BatchPolicy,
    queues: Mutex<BTreeMap<String, ModelQueue>>,
    next_window: AtomicU64,
    stats: Arc<Mutex<BatcherStats>>,
    obs: BatcherMetrics,
}

impl Batcher {
    pub fn new(registry: Arc<Registry>, pool: Arc<ThreadPool>, policy: BatchPolicy) -> Batcher {
        Batcher {
            registry,
            pool,
            policy,
            queues: Mutex::new(BTreeMap::new()),
            next_window: AtomicU64::new(0),
            stats: Arc::new(Mutex::new(BatcherStats::default())),
            obs: BatcherMetrics::resolve(),
        }
    }

    pub fn policy(&self) -> BatchPolicy {
        self.policy
    }

    pub fn stats(&self) -> BatcherStats {
        *plock(&self.stats)
    }

    fn note_shed(&self) {
        plock(&self.stats).shed += 1;
        self.obs.shed.inc();
    }

    /// Serve one prediction, blocking until its batch solves.  `budget`
    /// is the request's total step-attempt bound (defaults to the
    /// checkpoint's); shape and non-finite-input errors are rejected
    /// here, before the request can join (and poison) a window.  A
    /// request declaring a budget *below* the checkpoint default rides
    /// alone: the batch solves under the minimum of its riders' budgets,
    /// so an underfunded request must not drag a shared window down to a
    /// bound the other riders never asked for.
    ///
    /// `deadline`: absolute latency bound — expired requests are shed
    /// (at admission or window close) instead of solved.
    pub fn submit(
        &self,
        model_id: &str,
        u0: Vec<f32>,
        budget: Option<u64>,
        deadline: Option<Instant>,
    ) -> Result<BatchReply, BatchError> {
        let model = self
            .registry
            .get(model_id)
            .map_err(|e| BatchError::Rejected(format!("{e:#}")))?;
        let d = model.state_dim.ok_or_else(|| {
            BatchError::Rejected(format!(
                "model {model_id:?} ({}) is not servable via the trajectory batcher",
                model.model_name()
            ))
        })?;
        if u0.is_empty() || u0.len() != d {
            return Err(BatchError::Rejected(format!(
                "model {model_id:?} expects a {d}-dim initial state, got {} floats",
                u0.len()
            )));
        }
        if !u0.iter().all(|v| v.is_finite()) {
            return Err(BatchError::Rejected(format!(
                "model {model_id:?}: initial state must be finite (got {u0:?})"
            )));
        }
        if deadline.is_some_and(|dl| Instant::now() >= dl) {
            self.note_shed();
            return Err(BatchError::Shed("deadline expired before admission".into()));
        }
        let default_budget = model.default_budget();
        let budget = budget.unwrap_or(default_budget);
        let coalescible = budget >= default_budget;
        let (tx, rx) = mpsc::channel();

        // Join the open window, or open a new one and become its leader.
        // Underfunded requests always open (and close) their own window.
        let lead = {
            let mut queues = plock(&self.queues);
            let q = queues.entry(model_id.to_string()).or_default();
            let queued = q.queued();
            if queued >= self.policy.max_queue {
                drop(queues);
                self.note_shed();
                return Err(BatchError::Shed(format!(
                    "admission queue full ({} queued >= max_queue {})",
                    queued, self.policy.max_queue
                )));
            }
            let mut job = Some(Job {
                u0,
                budget,
                deadline,
                tx,
            });
            if coalescible {
                if let Some(id) = q.open {
                    if let Some(w) = q.windows.get_mut(&id) {
                        if w.jobs.len() < self.policy.max_batch {
                            if let Some(job) = job.take() {
                                w.jobs.push(job);
                            }
                        }
                    }
                }
            }
            match job {
                None => None,
                Some(job) => {
                    let id = self.next_window.fetch_add(1, Ordering::Relaxed);
                    q.windows.insert(id, Window { jobs: vec![job] });
                    if coalescible {
                        q.open = Some(id);
                    }
                    Some(id)
                }
            }
        };

        if let Some(window_id) = lead {
            // Leader: hold the window open for followers, then close it
            // and ship the batch to the pool (the leader's own reply
            // arrives through its channel like everyone else's).  A solo
            // (underfunded) window takes no followers, so it skips the
            // coalescing wait entirely.
            if coalescible {
                std::thread::sleep(self.policy.max_wait);
            }
            let jobs = {
                let mut queues = plock(&self.queues);
                match queues.get_mut(model_id) {
                    Some(q) => {
                        if q.open == Some(window_id) {
                            q.open = None;
                        }
                        let window = q.windows.remove(&window_id);
                        window.map(|w| w.jobs).unwrap_or_default()
                    }
                    None => Vec::new(),
                }
            };
            // Deadline shed at window close: riders whose latency budget
            // expired while coalescing are answered `Shed` now, before
            // the solve — the batch never pays for work nobody is
            // waiting on.
            let now = Instant::now();
            let (live, expired): (Vec<Job>, Vec<Job>) = jobs
                .into_iter()
                .partition(|j| !j.deadline.is_some_and(|dl| now >= dl));
            for job in expired {
                self.note_shed();
                let _ = job.tx.send(Err(BatchError::Shed(
                    "deadline expired while batching".into(),
                )));
            }
            if !live.is_empty() {
                let stats = Arc::clone(&self.stats);
                let obs = self.obs.clone();
                self.pool
                    .execute(move || execute_batch(model, live, stats, obs));
            }
        }

        match rx.recv() {
            Ok(result) => result,
            Err(_) => Err(BatchError::Rejected(
                "batch executor dropped the request".into(),
            )),
        }
    }
}

/// Run one window's batch as a single row-batched solve and route each
/// trajectory back to its requester.  On failure every rider of *this*
/// batch gets the typed error; nothing else is affected.
fn execute_batch(
    model: Arc<ServableModel>,
    jobs: Vec<Job>,
    stats: Arc<Mutex<BatcherStats>>,
    obs: BatcherMetrics,
) {
    crate::span!("batch_solve", "serve");
    let b = jobs.len();
    let Some(first) = jobs.first() else { return };
    let d = first.u0.len();
    let mut u0s = Vec::with_capacity(b * d);
    for job in &jobs {
        u0s.extend_from_slice(&job.u0);
    }
    // The batch solves under the tightest rider's budget: no request can
    // be made to exceed the bound it declared (admission control counts
    // the same unit).
    let budget = jobs.iter().map(|j| j.budget).min().unwrap_or(u64::MAX);

    match model.predict_batch(&u0s, budget) {
        Ok((trajs, solve_stats)) => {
            record(&stats, &obs, b, &solve_stats);
            for (job, traj) in jobs.into_iter().zip(trajs) {
                let _ = job.tx.send(Ok(BatchReply {
                    traj,
                    nfe: solve_stats.nfe,
                    naccept: solve_stats.naccept,
                    nreject: solve_stats.nreject,
                    batch: b,
                }));
            }
        }
        Err(e) => {
            let err = match e {
                PredictError::Solve { kind, msg } => BatchError::Solve { kind, msg },
                PredictError::Invalid(msg) => BatchError::Rejected(msg),
            };
            for job in jobs {
                let _ = job.tx.send(Err(err.clone()));
            }
        }
    }
}

fn record(stats: &Mutex<BatcherStats>, obs: &BatcherMetrics, batch: usize, solve: &Stats) {
    let mut s = plock(stats);
    s.batches += 1;
    s.requests += batch as u64;
    s.max_batch = s.max_batch.max(batch);
    s.nfe_total += solve.nfe;
    drop(s);
    // Lock-free cells only past this point: the registry handles were
    // resolved at construction (BatcherMetrics::resolve).
    obs.batches.inc();
    obs.batch_size.observe(batch as f64);
}
