//! Versioned serving checkpoints: persist a trained model, bit-exactly.
//!
//! A checkpoint is the durable form of a [`runtime::ExportedState`]
//! (`runtime::Backend::export_state`) plus the coordinator-owned serving
//! metadata: the experiment id, the method label the model was trained
//! with, and the fixed serving grid the batcher coalesces requests over.
//! The on-disk format is a single JSON object (written with
//! [`util::json`], std-only — no serde):
//!
//! ```json
//! {
//!   "schema": "regnde-checkpoint",
//!   "version": 1,
//!   "model": "spiral_node",            // backend model name
//!   "experiment": "spiral-node",       // coordinator experiment id
//!   "method": "ERNODE",                // method label (informational)
//!   "solver": "tsit5",                 // Tableau name
//!   "train_tol": 1e-4,
//!   "predict_tol": 1e-6,
//!   "step_budget": 8192,               // default Total attempt budget
//!   "params_len": 354,
//!   "params_hex": "9a99...",           // f32 LE bytes, 8 hex chars each
//!   "hyper": { "lr": 0.02, ... },
//!   "ts": [0.0, 0.05, ...],            // serving grid (trajectory models)
//!   "train": {                         // v2, optional: resume block
//!     "opt_state_hex": "0000...",      // Adam moments, f32 LE hex
//!     "opt_len": 708,
//!     "iter": 50,                      // optimizer iterations done
//!     "rung": 1,                       // budget-ladder rung
//!     "window": [12.0, 9.0],           // router descent window
//!     "epochs_done": 2
//!   }
//! }
//! ```
//!
//! Parameters are stored as **hex-encoded little-endian f32 bytes**, not
//! decimal numbers, so `save → load` round-trips every bit: a loaded
//! model's `predict` is bit-identical to the in-memory model's
//! (`tests/serve_checkpoint.rs` proves it on all five experiment model
//! shapes).  Loading never panics on bad input — malformed, truncated
//! and wrong-version files all surface as a typed [`CheckpointError`].
//!
//! **Versioning:** v2 adds the optional `train` block (Adam moments +
//! budget-ladder position) that `regnde train --resume` continues from
//! bit-identically (DESIGN.md §Distributed).  v1 files still load: they
//! simply carry no train block (`train: None`), which resume treats as
//! fresh optimizer moments at iteration 0, rung 0, zero epochs done.
//!
//! [`runtime::ExportedState`]: crate::runtime::ExportedState
//! [`util::json`]: crate::util::json

use std::collections::BTreeMap;
use std::fmt;
use std::fmt::Write as _;
use std::path::Path;

use crate::runtime::ExportedState;
use crate::util::json::{obj, Json};

/// Current checkpoint format version (the `version` field): v2 adds the
/// optional `train` resume block.
// analyze: wire(checkpoint-schema)
pub const CHECKPOINT_VERSION: u64 = 2;
/// Oldest version this build still reads (no `train` block).
// analyze: wire(checkpoint-schema)
pub const CHECKPOINT_VERSION_V1: u64 = 1;
/// The `schema` tag every checkpoint carries.
// analyze: wire(checkpoint-schema)
pub const CHECKPOINT_SCHEMA: &str = "regnde-checkpoint";

/// Typed checkpoint load/decode failure — every malformed input lands on
/// one of these variants instead of a panic.
#[derive(Debug)]
pub enum CheckpointError {
    /// Filesystem failure (missing file, permissions, ...).
    Io(std::io::Error),
    /// The file is not valid JSON (including truncated files).
    Parse(String),
    /// Valid JSON, but not a checkpoint (`schema` mismatch).
    WrongSchema(String),
    /// A checkpoint from an incompatible format version.
    WrongVersion { found: u64, want: u64 },
    /// Structurally invalid checkpoint: missing/ill-typed fields, bad
    /// hex, or a parameter count that contradicts `params_len`.
    Malformed(String),
}

impl fmt::Display for CheckpointError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            CheckpointError::Io(e) => write!(f, "checkpoint io error: {e}"),
            CheckpointError::Parse(e) => write!(f, "checkpoint is not valid JSON: {e}"),
            CheckpointError::WrongSchema(s) => {
                write!(f, "not a checkpoint (schema {s:?}, want {CHECKPOINT_SCHEMA:?})")
            }
            CheckpointError::WrongVersion { found, want } => {
                write!(f, "checkpoint version {found} unsupported (this build reads {want})")
            }
            CheckpointError::Malformed(m) => write!(f, "malformed checkpoint: {m}"),
        }
    }
}

impl std::error::Error for CheckpointError {}

impl From<std::io::Error> for CheckpointError {
    fn from(e: std::io::Error) -> Self {
        CheckpointError::Io(e)
    }
}

/// Mid-run training position persisted by checkpoint v2's `train`
/// block: everything `--resume` needs to continue bit-identically.
#[derive(Clone, Debug, PartialEq)]
pub struct TrainProgress {
    /// Flat optimizer state (Adam moments), bit-exact via hex.
    pub opt_state: Vec<f32>,
    /// Completed optimizer iterations (lr-decay position).
    pub iter: u64,
    /// Budget-ladder rung.
    pub rung: usize,
    /// Budget-router descent-evidence window.
    pub window: Vec<f64>,
    /// Epochs completed before the save.
    pub epochs_done: usize,
    /// Total-epoch target the run's epoch-annealed schedules were built
    /// over — what a resumed run must anneal over to reproduce the
    /// original coefficients bit-for-bit.  Optional in the JSON
    /// (0 = unrecorded; files written before this field loads as 0 and
    /// resume falls back to `epochs_done + --epochs`).
    pub total_epochs: usize,
}

/// A persisted trained model: the backend-exported state plus the
/// coordinator-owned serving metadata.
#[derive(Clone, Debug, PartialEq)]
pub struct Checkpoint {
    /// The backend half (model name, params, solver, tolerances, budget,
    /// hyper block).
    pub state: ExportedState,
    /// Coordinator experiment id (`spiral-node`, ...).
    pub experiment: String,
    /// Method label the model was trained with (informational).
    pub method: String,
    /// Fixed serving grid for trajectory models (`serve::batcher`
    /// coalesces requests over this shared grid); empty for model kinds
    /// without a single-trajectory serving path.
    pub ts: Vec<f32>,
    /// Mid-run training position (v2; `None` for serving-only
    /// checkpoints and every v1 file).
    pub train: Option<TrainProgress>,
}

impl Checkpoint {
    pub fn new(
        state: ExportedState,
        experiment: impl Into<String>,
        method: impl Into<String>,
        ts: Vec<f32>,
    ) -> Checkpoint {
        Checkpoint {
            state,
            experiment: experiment.into(),
            method: method.into(),
            ts,
            train: None,
        }
    }

    /// Attach a training-resume block (written by `regnde train
    /// --checkpoint`; consumed by `--resume`).
    pub fn with_train(mut self, train: TrainProgress) -> Checkpoint {
        self.train = Some(train);
        self
    }

    pub fn to_json(&self) -> Json {
        let mut hyper = BTreeMap::new();
        for (k, &v) in &self.state.hyper {
            hyper.insert(k.clone(), Json::from(v));
        }
        let mut ts = Vec::with_capacity(self.ts.len());
        for &t in &self.ts {
            ts.push(Json::from(t as f64));
        }
        let mut j = obj([
            ("schema", Json::from(CHECKPOINT_SCHEMA)),
            ("version", Json::from(CHECKPOINT_VERSION as usize)),
            ("model", Json::from(self.state.model.as_str())),
            ("experiment", Json::from(self.experiment.as_str())),
            ("method", Json::from(self.method.as_str())),
            ("solver", Json::from(self.state.solver.as_str())),
            ("train_tol", Json::from(self.state.train_tol)),
            ("predict_tol", Json::from(self.state.predict_tol)),
            ("step_budget", Json::from(self.state.step_budget as usize)),
            ("params_len", Json::from(self.state.params.len())),
            ("params_hex", Json::from(encode_f32_hex(&self.state.params))),
            ("hyper", Json::Obj(hyper)),
            ("ts", Json::Arr(ts)),
        ]);
        if let (Some(t), Json::Obj(m)) = (&self.train, &mut j) {
            let window: Vec<Json> = t.window.iter().map(|&w| Json::from(w)).collect();
            m.insert(
                "train".into(),
                obj([
                    ("opt_state_hex", Json::from(encode_f32_hex(&t.opt_state))),
                    ("opt_len", Json::from(t.opt_state.len())),
                    ("iter", Json::from(t.iter as usize)),
                    ("rung", Json::from(t.rung)),
                    ("window", Json::Arr(window)),
                    ("epochs_done", Json::from(t.epochs_done)),
                    ("total_epochs", Json::from(t.total_epochs)),
                ]),
            );
        }
        j
    }

    pub fn from_json(j: &Json) -> Result<Checkpoint, CheckpointError> {
        let str_field = |key: &str| -> Result<String, CheckpointError> {
            field(j, key)?
                .as_str()
                .map(str::to_string)
                .map_err(|_| CheckpointError::Malformed(format!("field {key:?} must be a string")))
        };
        let num_field = |key: &str| -> Result<f64, CheckpointError> {
            field(j, key)?
                .as_f64()
                .map_err(|_| CheckpointError::Malformed(format!("field {key:?} must be a number")))
        };

        let schema = str_field("schema")?;
        if schema != CHECKPOINT_SCHEMA {
            return Err(CheckpointError::WrongSchema(schema));
        }
        let version = num_field("version")? as u64;
        if version != CHECKPOINT_VERSION && version != CHECKPOINT_VERSION_V1 {
            return Err(CheckpointError::WrongVersion {
                found: version,
                want: CHECKPOINT_VERSION,
            });
        }

        let params_len = num_field("params_len")? as usize;
        let params = decode_f32_hex(&str_field("params_hex")?)?;
        if params.len() != params_len {
            return Err(CheckpointError::Malformed(format!(
                "params_hex decodes to {} parameters but params_len says {params_len}",
                params.len()
            )));
        }

        let mut hyper = BTreeMap::new();
        if let Some(h) = j.opt("hyper") {
            let map = h.as_obj().map_err(|_| {
                CheckpointError::Malformed("field \"hyper\" must be an object".into())
            })?;
            for (k, v) in map {
                let v = v.as_f64().map_err(|_| {
                    CheckpointError::Malformed(format!("hyper entry {k:?} must be a number"))
                })?;
                hyper.insert(k.clone(), v);
            }
        }

        let mut ts = Vec::new();
        if let Some(t) = j.opt("ts") {
            let arr = t
                .as_arr()
                .map_err(|_| CheckpointError::Malformed("field \"ts\" must be an array".into()))?;
            for v in arr {
                let v = v.as_f64().map_err(|_| {
                    CheckpointError::Malformed("ts entries must be numbers".into())
                })?;
                ts.push(v as f32);
            }
        }

        // The resume block is a v2 feature: v1 files never carry one (a
        // stray "train" key in a v1 file is ignored, per the documented
        // "v1 loads with defaults" contract).
        let train = if version >= CHECKPOINT_VERSION {
            match j.opt("train") {
                Some(t) => Some(parse_train(t)?),
                None => None,
            }
        } else {
            None
        };

        Ok(Checkpoint {
            state: ExportedState {
                model: str_field("model")?,
                params,
                solver: str_field("solver")?,
                train_tol: num_field("train_tol")?,
                predict_tol: num_field("predict_tol")?,
                step_budget: num_field("step_budget")? as u64,
                hyper,
            },
            experiment: str_field("experiment")?,
            method: str_field("method")?,
            ts,
            train,
        })
    }

    /// Write the checkpoint to `path` (pretty JSON; parent directories
    /// are created).
    pub fn save(&self, path: &Path) -> Result<(), CheckpointError> {
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)?;
            }
        }
        std::fs::write(path, self.to_json().to_string_pretty())?;
        Ok(())
    }

    /// Read and decode a checkpoint.  Never panics: every failure mode is
    /// a typed [`CheckpointError`].
    pub fn load(path: &Path) -> Result<Checkpoint, CheckpointError> {
        let text = std::fs::read_to_string(path)?;
        let j = Json::parse(&text).map_err(|e| CheckpointError::Parse(e.to_string()))?;
        Checkpoint::from_json(&j)
    }
}

fn field<'a>(j: &'a Json, key: &str) -> Result<&'a Json, CheckpointError> {
    j.opt(key)
        .ok_or_else(|| CheckpointError::Malformed(format!("missing field {key:?}")))
}

/// Decode a v2 `train` resume block (typed errors, never panics).
fn parse_train(t: &Json) -> Result<TrainProgress, CheckpointError> {
    let num = |key: &str| -> Result<f64, CheckpointError> {
        field(t, key)?.as_f64().map_err(|_| {
            CheckpointError::Malformed(format!("train field {key:?} must be a number"))
        })
    };
    let hex = field(t, "opt_state_hex")?.as_str().map_err(|_| {
        CheckpointError::Malformed("train field \"opt_state_hex\" must be a string".into())
    })?;
    let opt_state = decode_f32_hex(hex)?;
    let opt_len = num("opt_len")? as usize;
    if opt_state.len() != opt_len {
        return Err(CheckpointError::Malformed(format!(
            "opt_state_hex decodes to {} values but opt_len says {opt_len}",
            opt_state.len()
        )));
    }
    let mut window = Vec::new();
    if let Some(w) = t.opt("window") {
        let arr = w.as_arr().map_err(|_| {
            CheckpointError::Malformed("train field \"window\" must be an array".into())
        })?;
        for v in arr {
            window.push(v.as_f64().map_err(|_| {
                CheckpointError::Malformed("train window entries must be numbers".into())
            })?);
        }
    }
    // Optional (added after the first v2 files shipped): absent = 0,
    // "schedule target unrecorded".
    let total_epochs = match t.opt("total_epochs") {
        Some(v) => v.as_f64().map_err(|_| {
            CheckpointError::Malformed("train field \"total_epochs\" must be a number".into())
        })? as usize,
        None => 0,
    };
    Ok(TrainProgress {
        opt_state,
        iter: num("iter")? as u64,
        rung: num("rung")? as usize,
        window,
        epochs_done: num("epochs_done")? as usize,
        total_epochs,
    })
}

/// Encode f32s as lowercase hex of their little-endian bytes (8 chars
/// per value) — decimal-free, so round-trips are bit-exact by
/// construction.
pub fn encode_f32_hex(values: &[f32]) -> String {
    let mut s = String::with_capacity(values.len() * 8);
    for v in values {
        for b in v.to_le_bytes() {
            let _ = write!(s, "{b:02x}");
        }
    }
    s
}

/// Decode [`encode_f32_hex`] output; rejects odd lengths, partial values
/// and non-hex characters with a typed error.
pub fn decode_f32_hex(hex: &str) -> Result<Vec<f32>, CheckpointError> {
    let bytes = hex.as_bytes();
    if bytes.len() % 8 != 0 {
        return Err(CheckpointError::Malformed(format!(
            "params_hex length {} is not a multiple of 8 (truncated?)",
            bytes.len()
        )));
    }
    let nib = |c: u8| -> Result<u8, CheckpointError> {
        match c {
            b'0'..=b'9' => Ok(c - b'0'),
            b'a'..=b'f' => Ok(c - b'a' + 10),
            b'A'..=b'F' => Ok(c - b'A' + 10),
            _ => Err(CheckpointError::Malformed(format!(
                "params_hex contains non-hex byte {:?}",
                c as char
            ))),
        }
    };
    let mut out = Vec::with_capacity(bytes.len() / 8);
    for chunk in bytes.chunks_exact(8) {
        let mut le = [0u8; 4];
        for (i, pair) in chunk.chunks_exact(2).enumerate() {
            // analyze: allow(index) -- i < 4 and pair.len() == 2 by construction: chunks_exact(2) over an 8-byte chunks_exact(8) window
            le[i] = (nib(pair[0])? << 4) | nib(pair[1])?;
        }
        out.push(f32::from_le_bytes(le));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_state() -> ExportedState {
        ExportedState {
            model: "spiral_node".into(),
            params: vec![1.5, -0.25, f32::MIN_POSITIVE, 3.14159e-7, -0.0],
            solver: "tsit5".into(),
            train_tol: 1e-4,
            predict_tol: 1e-6,
            step_budget: 8192,
            hyper: [("lr".to_string(), 0.02)].into_iter().collect(),
        }
    }

    #[test]
    fn hex_codec_is_bit_exact() {
        let vals = [
            0.0f32,
            -0.0,
            1.0,
            -1.5,
            f32::MIN_POSITIVE,
            f32::MAX,
            1.0e-45, // subnormal
            core::f32::consts::PI,
        ];
        let hex = encode_f32_hex(&vals);
        assert_eq!(hex.len(), vals.len() * 8);
        let back = decode_f32_hex(&hex).unwrap();
        for (a, b) in vals.iter().zip(&back) {
            assert_eq!(a.to_bits(), b.to_bits(), "{a} lost bits");
        }
    }

    #[test]
    fn hex_codec_rejects_garbage() {
        assert!(matches!(
            decode_f32_hex("0011223"),
            Err(CheckpointError::Malformed(_))
        ));
        assert!(matches!(
            decode_f32_hex("0011223g"),
            Err(CheckpointError::Malformed(_))
        ));
        assert!(decode_f32_hex("").unwrap().is_empty());
    }

    #[test]
    fn json_roundtrip_preserves_everything() {
        let ck = Checkpoint::new(sample_state(), "spiral-node", "ERNODE", vec![0.0, 0.5, 1.0]);
        let back = Checkpoint::from_json(&ck.to_json()).unwrap();
        assert_eq!(back, ck);
        // Through the textual form too (what save/load really exercise).
        let text = ck.to_json().to_string_pretty();
        let back = Checkpoint::from_json(&Json::parse(&text).unwrap()).unwrap();
        for (a, b) in ck.state.params.iter().zip(&back.state.params) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert_eq!(back.ts, ck.ts);
    }

    #[test]
    fn train_block_round_trips_bit_exact() {
        let progress = TrainProgress {
            opt_state: vec![0.5, -1.25e-7, f32::MIN_POSITIVE, 0.0],
            iter: 42,
            rung: 1,
            window: vec![12.0, 9.5, 3.0],
            epochs_done: 2,
            total_epochs: 5,
        };
        let ck = Checkpoint::new(sample_state(), "spiral-node", "ERNODE", vec![0.0, 1.0])
            .with_train(progress.clone());
        let back = Checkpoint::from_json(&ck.to_json()).unwrap();
        assert_eq!(back, ck);
        let t = back.train.expect("train block survives");
        for (a, b) in progress.opt_state.iter().zip(&t.opt_state) {
            assert_eq!(a.to_bits(), b.to_bits(), "Adam moments must be bit-exact");
        }
        assert_eq!(t.iter, 42);
        assert_eq!(t.rung, 1);
        assert_eq!(t.window, progress.window);
        // Through text too (what save/load exercise).
        let text = ck.to_json().to_string_pretty();
        let back = Checkpoint::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.train, ck.train);
    }

    #[test]
    fn malformed_train_blocks_are_typed() {
        let ck = Checkpoint::new(sample_state(), "spiral-node", "ERNODE", vec![]).with_train(
            TrainProgress {
                opt_state: vec![1.0, 2.0],
                iter: 1,
                rung: 0,
                window: vec![],
                epochs_done: 1,
                total_epochs: 2,
            },
        );
        // total_epochs is optional: files written before the field
        // existed load with the documented 0 ("unrecorded") default.
        let mut j = ck.to_json();
        if let Json::Obj(m) = &mut j {
            if let Some(Json::Obj(t)) = m.get_mut("train") {
                t.remove("total_epochs");
            }
        }
        let back = Checkpoint::from_json(&j).unwrap();
        assert_eq!(back.train.expect("train block").total_epochs, 0);
        // Inconsistent opt_len.
        let mut j = ck.to_json();
        if let Json::Obj(m) = &mut j {
            if let Some(Json::Obj(t)) = m.get_mut("train") {
                t.insert("opt_len".into(), Json::from(99usize));
            }
        }
        assert!(matches!(
            Checkpoint::from_json(&j),
            Err(CheckpointError::Malformed(_))
        ));
        // Missing iter.
        let mut j = ck.to_json();
        if let Json::Obj(m) = &mut j {
            if let Some(Json::Obj(t)) = m.get_mut("train") {
                t.remove("iter");
            }
        }
        assert!(matches!(
            Checkpoint::from_json(&j),
            Err(CheckpointError::Malformed(_))
        ));
    }

    #[test]
    fn v1_files_load_with_default_train() {
        let ck = Checkpoint::new(sample_state(), "spiral-node", "ERNODE", vec![0.5]);
        let mut j = ck.to_json();
        if let Json::Obj(m) = &mut j {
            m.insert("version".into(), Json::from(CHECKPOINT_VERSION_V1 as usize));
        }
        let back = Checkpoint::from_json(&j).unwrap();
        assert_eq!(back.train, None);
        assert_eq!(back.state, ck.state);
    }

    #[test]
    fn wrong_schema_and_version_are_typed() {
        let ck = Checkpoint::new(sample_state(), "spiral-node", "ERNODE", vec![]);
        let mut j = ck.to_json();
        if let Json::Obj(m) = &mut j {
            m.insert("schema".into(), Json::from("not-a-checkpoint"));
        }
        assert!(matches!(
            Checkpoint::from_json(&j),
            Err(CheckpointError::WrongSchema(_))
        ));
        let mut j = ck.to_json();
        if let Json::Obj(m) = &mut j {
            m.insert("version".into(), Json::from(99usize));
        }
        assert!(matches!(
            Checkpoint::from_json(&j),
            Err(CheckpointError::WrongVersion { found: 99, .. })
        ));
    }

    #[test]
    fn missing_and_inconsistent_fields_are_malformed() {
        let ck = Checkpoint::new(sample_state(), "spiral-node", "ERNODE", vec![]);
        let mut j = ck.to_json();
        if let Json::Obj(m) = &mut j {
            m.remove("params_hex");
        }
        assert!(matches!(
            Checkpoint::from_json(&j),
            Err(CheckpointError::Malformed(_))
        ));
        let mut j = ck.to_json();
        if let Json::Obj(m) = &mut j {
            m.insert("params_len".into(), Json::from(77usize));
        }
        assert!(matches!(
            Checkpoint::from_json(&j),
            Err(CheckpointError::Malformed(_))
        ));
    }
}
