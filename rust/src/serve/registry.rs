//! Thread-safe in-memory model registry with lazy checkpoint loading.
//!
//! The registry maps **model ids** (the checkpoint file stem, e.g.
//! `spiral-er` for `spiral-er.json`) to loaded [`ServableModel`]s: the
//! decoded checkpoint, a [`NativeBackend`] reconstructed with the
//! checkpoint's solver, and the validated parameter vector — everything
//! a predict request needs, resolved once.  Loading is lazy: opening a
//! registry directory only indexes the ids; a checkpoint is parsed,
//! validated (`Backend::import_state`) and cached on the first request
//! that names it, and every later request shares the same
//! `Arc<ServableModel>`.
//!
//! The native backend has no JIT, so "warming" a model is cheap: the
//! load step parses the solver name, decodes the hex parameter block and
//! resolves the serving state width up front — a served request performs
//! no per-request validation beyond its own input shape.

use std::collections::BTreeMap;
use std::fmt;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex, MutexGuard};

use anyhow::{anyhow, bail, Context, Result};

use super::checkpoint::Checkpoint;
use crate::runtime::state::Metrics;
use crate::runtime::{Backend, NativeBackend, TrainData};
use crate::solvers::error::SolveErrorKind;
use crate::solvers::ode::Stats;

/// Typed failure of the serving hot path ([`ServableModel::predict_batch`]).
///
/// Distinguishes requests the solver never saw from solves that ran and
/// died — the batcher and the wire protocol preserve the distinction so
/// clients can tell a mis-shaped request from a model that diverged.
#[derive(Clone, Debug)]
pub enum PredictError {
    /// The request never reached the solver: model kind not
    /// row-batchable, bad shape, rejected parameters.
    Invalid(String),
    /// The batch solve ran and failed; `kind` is the typed solver
    /// failure every rider of the batch receives over the wire.
    Solve { kind: SolveErrorKind, msg: String },
}

impl fmt::Display for PredictError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            PredictError::Invalid(m) => f.write_str(m),
            PredictError::Solve { kind, msg } => write!(f, "{msg} [{kind}]"),
        }
    }
}

impl std::error::Error for PredictError {}

/// Poison-tolerant lock: a thread that panicked while holding the map
/// only ever leaves it in a consistent state (inserts are atomic), so
/// serving continues instead of propagating the poison panic to every
/// later request.
fn plock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|p| p.into_inner())
}

/// One loaded checkpoint, ready to serve.
pub struct ServableModel {
    /// Registry id (checkpoint file stem).
    pub id: String,
    /// The decoded checkpoint (metadata + serving grid).
    pub checkpoint: Checkpoint,
    /// State width of the single-trajectory serving path; `None` for
    /// model kinds the batcher cannot row-batch.
    pub state_dim: Option<usize>,
    backend: NativeBackend,
    params: Vec<f32>,
}

impl ServableModel {
    /// Validate a checkpoint into a servable model: reconstruct the
    /// backend with the checkpoint's solver, import the parameters, and
    /// resolve the serving width.
    pub fn from_checkpoint(id: impl Into<String>, checkpoint: Checkpoint) -> Result<ServableModel> {
        let id = id.into();
        let backend = NativeBackend::new()
            .with_solver(&checkpoint.state.solver)
            .with_context(|| format!("model {id:?}: bad solver in checkpoint"))?;
        let params = backend
            .import_state(&checkpoint.state)
            .with_context(|| format!("model {id:?}: checkpoint rejected"))?;
        let state_dim = backend.traj_state_dim(&checkpoint.state.model).ok();
        if state_dim.is_some() && checkpoint.ts.len() < 2 {
            bail!(
                "model {id:?}: trajectory checkpoint needs a serving grid \
                 of >= 2 points (got {})",
                checkpoint.ts.len()
            );
        }
        Ok(ServableModel {
            id,
            checkpoint,
            state_dim,
            backend,
            params,
        })
    }

    /// Backend model name this checkpoint reconstructs.
    pub fn model_name(&self) -> &str {
        &self.checkpoint.state.model
    }

    /// The validated flat parameter vector (bit-exact from the
    /// checkpoint).
    pub fn params(&self) -> &[f32] {
        &self.params
    }

    /// Default total step-attempt budget of a served solve.
    pub fn default_budget(&self) -> u64 {
        self.checkpoint.state.step_budget
    }

    /// Full-fidelity single-request inference (any model kind).
    pub fn predict(&self, data: &TrainData, seed: u32) -> Result<(Vec<f32>, Metrics)> {
        self.backend
            .predict(self.model_name(), &self.params, data, seed)
    }

    /// The serving hot path: one row-batched `drive()` over the
    /// checkpoint's grid for `B` coalesced requests
    /// (`NativeBackend::predict_traj_batch`).  Fails typed
    /// ([`PredictError`]) if this model kind is not row-batchable or the
    /// solve dies — the batcher maps the failure onto exactly the
    /// requests that rode this batch, carrying the [`SolveErrorKind`]
    /// to every rider.
    pub fn predict_batch(
        &self,
        u0s: &[f32],
        budget: u64,
    ) -> Result<(Vec<Vec<f32>>, Stats), PredictError> {
        if self.state_dim.is_none() {
            return Err(PredictError::Invalid(format!(
                "model {:?} ({}) is not servable via the trajectory batcher",
                self.id,
                self.model_name()
            )));
        }
        let (trajs, stats, kind) = match self.backend.predict_traj_batch(
            self.model_name(),
            &self.params,
            u0s,
            &self.checkpoint.ts,
            Some(budget),
        ) {
            Ok(out) => out,
            Err(e) => return Err(PredictError::Invalid(format!("{e:#}"))),
        };
        if let Some(kind) = kind {
            return Err(PredictError::Solve {
                kind,
                msg: format!(
                    "solve failed for model {:?} under step budget {budget}: {kind}",
                    self.id
                ),
            });
        }
        Ok((trajs, stats))
    }
}

/// Thread-safe id → model map with lazy loading from a checkpoint
/// directory.
pub struct Registry {
    dir: Option<PathBuf>,
    models: Mutex<BTreeMap<String, Arc<ServableModel>>>,
}

impl Registry {
    /// Open a checkpoint directory (`<id>.json` files).  The directory
    /// must exist; checkpoints are indexed now but parsed lazily.
    pub fn open(dir: impl AsRef<Path>) -> Result<Registry> {
        let dir = dir.as_ref().to_path_buf();
        if !dir.is_dir() {
            bail!("registry directory {dir:?} does not exist");
        }
        Ok(Registry {
            dir: Some(dir),
            models: Mutex::new(BTreeMap::new()),
        })
    }

    /// A registry with no backing directory (models arrive via
    /// [`Registry::insert`] — tests and in-process serving).
    pub fn in_memory() -> Registry {
        Registry {
            dir: None,
            models: Mutex::new(BTreeMap::new()),
        }
    }

    /// Validate and register a checkpoint under `id`, replacing any
    /// previous model with that id.
    pub fn insert(&self, id: &str, checkpoint: Checkpoint) -> Result<Arc<ServableModel>> {
        let model = Arc::new(ServableModel::from_checkpoint(id, checkpoint)?);
        plock(&self.models).insert(id.to_string(), Arc::clone(&model));
        Ok(model)
    }

    /// Fetch a model, lazily loading `<dir>/<id>.json` on first use.
    pub fn get(&self, id: &str) -> Result<Arc<ServableModel>> {
        if let Some(m) = plock(&self.models).get(id) {
            return Ok(Arc::clone(m));
        }
        // Load outside the lock (checkpoint decode can be slow); a
        // concurrent first-load of the same id is harmless — last insert
        // wins and both Arcs serve identical bits.
        let dir = self.dir.as_ref().ok_or_else(|| {
            anyhow!("unknown model {id:?} (in-memory registry has: {:?})", self.ids())
        })?;
        let path = dir.join(format!("{id}.json"));
        if !path.is_file() {
            bail!("unknown model {id:?} (no {path:?}; registry has: {:?})", self.ids());
        }
        let ckpt = Checkpoint::load(&path)
            .map_err(|e| anyhow!("loading model {id:?} from {path:?}: {e}"))?;
        let model = Arc::new(ServableModel::from_checkpoint(id, ckpt)?);
        plock(&self.models).insert(id.to_string(), Arc::clone(&model));
        Ok(model)
    }

    /// Every servable id: loaded models plus on-disk checkpoints not yet
    /// touched.
    pub fn ids(&self) -> Vec<String> {
        let mut ids: Vec<String> = plock(&self.models).keys().cloned().collect();
        if let Some(dir) = &self.dir {
            if let Ok(entries) = std::fs::read_dir(dir) {
                for entry in entries.flatten() {
                    let path = entry.path();
                    if path.extension().and_then(|e| e.to_str()) == Some("json") {
                        if let Some(stem) = path.file_stem().and_then(|s| s.to_str()) {
                            if !ids.iter().any(|i| i == stem) {
                                ids.push(stem.to_string());
                            }
                        }
                    }
                }
            }
        }
        ids.sort();
        ids
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spiral_checkpoint() -> Checkpoint {
        let be = NativeBackend::new();
        let params = be.init_params("spiral_node", 7).unwrap();
        let state = be.export_state("spiral_node", &params).unwrap();
        let ts: Vec<f32> = (0..8).map(|i| i as f32 / 7.0).collect();
        Checkpoint::new(state, "spiral-node", "vanilla", ts)
    }

    #[test]
    fn insert_get_and_ids() {
        let reg = Registry::in_memory();
        assert!(reg.get("nope").is_err());
        reg.insert("spiral", spiral_checkpoint()).unwrap();
        let m = reg.get("spiral").unwrap();
        assert_eq!(m.model_name(), "spiral_node");
        assert_eq!(m.state_dim, Some(2));
        assert_eq!(reg.ids(), vec!["spiral".to_string()]);
    }

    #[test]
    fn lazy_load_from_directory() {
        let dir = std::env::temp_dir().join(format!("regnde-reg-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        spiral_checkpoint().save(&dir.join("lazy.json")).unwrap();
        let reg = Registry::open(&dir).unwrap();
        assert_eq!(reg.ids(), vec!["lazy".to_string()]);
        let a = reg.get("lazy").unwrap();
        let b = reg.get("lazy").unwrap();
        assert!(Arc::ptr_eq(&a, &b), "second get must hit the cache");
        assert!(reg.get("missing").is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn trajectory_checkpoint_requires_a_grid() {
        let be = NativeBackend::new();
        let params = be.init_params("spiral_node", 7).unwrap();
        let state = be.export_state("spiral_node", &params).unwrap();
        let ck = Checkpoint::new(state, "spiral-node", "vanilla", vec![]);
        assert!(ServableModel::from_checkpoint("bad", ck).is_err());
    }
}
