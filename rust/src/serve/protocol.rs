//! Wire protocol of the prediction server: line-delimited JSON over TCP.
//!
//! One request per line, one response per line, both UTF-8 JSON objects
//! (`std::net` + [`util::json`] — no new dependencies).  Grammar
//! (documented normatively in DESIGN.md §Serving):
//!
//! ```text
//! request  := predict | list | stats | metrics | shutdown
//! predict  := {"op":"predict","model":<id>,"u0":[f32...]
//!              [,"budget":<attempts>][,"deadline_ms":<ms>]}
//! list     := {"op":"list"}
//! stats    := {"op":"stats"}
//! metrics  := {"op":"metrics"}
//! shutdown := {"op":"shutdown"}
//!
//! response := ok | shed | error
//! shed     := {"ok":false,"shed":true,"error":<string>}
//! error    := {"ok":false,"error":<string>[,"kind":<solve-error-kind>]}
//! ok       := {"ok":true, ...op-specific fields...}
//!   predict: "model","traj":[f32...],"nfe","naccept","nreject","batch","micros"
//!   list:    "models":[<id>...]
//!   stats:   "batches","requests","mean_batch","max_batch","nfe_total","shed"
//!   metrics: "text":<Prometheus exposition, JSON-escaped>
//!   shutdown:"closing":true
//! ```
//!
//! `metrics` returns the process-global [`crate::obs::metrics`] registry
//! rendered as Prometheus text (DESIGN.md §Observability).  The same
//! exposition is also served on a plain-HTTP path: a connection whose
//! first line starts with `GET ` receives an `HTTP/1.0 200` plaintext
//! response and is closed, so `curl http://host:port/metrics` works
//! against the JSON-lines port.
//!
//! `budget` is the request's **total step-attempt bound**
//! (`StepBudget::Total`) and doubles as the admission-control unit: the
//! server rejects a predict whose declared budget exceeds the
//! connection's remaining NFE quota (DESIGN.md §Serving).  Responses
//! report realized solver work (`nfe`, `naccept`, `nreject`) of the
//! batch solve that served the request, plus the coalesced batch size.
//!
//! ## Failure containment on the wire (DESIGN.md §Robustness)
//!
//! * `deadline_ms` is the client's per-request latency budget: a request
//!   still queued when its deadline expires is **shed**, not solved.
//! * A `shed` response means the server did no solver work — the request
//!   was turned away by backpressure (admission queue full, connection
//!   cap, deadline expired, draining shutdown).  Shed is the *retryable*
//!   class: clients back off exponentially and resend.
//! * An `error` response with a `kind` field carries the typed
//!   [`SolveErrorKind`] wire string of the batch solve that failed
//!   (`budget_exhausted`, `non_finite_state`, ...); `kind` is absent for
//!   request-level rejections (bad shape, unknown model, admission).
//!   Errors are **not** blindly retryable — the same request fails again.
//!
//! [`util::json`]: crate::util::json

use anyhow::{bail, Context, Result};

use super::batcher::{BatcherStats, BatchReply};
use crate::solvers::error::SolveErrorKind;
use crate::util::json::{obj, Json};

/// Every field name and `op` value on the wire, as named constants — the
/// single source of truth for the protocol vocabulary.  The L3
/// wire-stability lint (`rust/tools/analyze`, DESIGN.md §Static
/// Analysis) extracts this module and diffs it against the committed
/// `wire_registry.txt`, so renaming a tag is an explicit two-file
/// change that shows up in review as a registry edit.
// analyze: wire(protocol-tags)
pub mod tags {
    /// Request discriminator field.
    pub const OP: &str = "op";
    pub const OP_PREDICT: &str = "predict";
    pub const OP_LIST: &str = "list";
    pub const OP_STATS: &str = "stats";
    pub const OP_METRICS: &str = "metrics";
    pub const OP_SHUTDOWN: &str = "shutdown";
    /// Model id (predict request and response).
    pub const MODEL: &str = "model";
    pub const U0: &str = "u0";
    pub const BUDGET: &str = "budget";
    pub const DEADLINE_MS: &str = "deadline_ms";
    /// Response success flag — present on every response.
    pub const OK: &str = "ok";
    pub const ERROR: &str = "error";
    /// Doubles as the shed marker (`"shed":true`) and the shed counter
    /// in stats responses.
    pub const SHED: &str = "shed";
    pub const KIND: &str = "kind";
    pub const TRAJ: &str = "traj";
    pub const NFE: &str = "nfe";
    pub const NACCEPT: &str = "naccept";
    pub const NREJECT: &str = "nreject";
    pub const BATCH: &str = "batch";
    pub const MICROS: &str = "micros";
    pub const MODELS: &str = "models";
    pub const CLOSING: &str = "closing";
    pub const BATCHES: &str = "batches";
    pub const REQUESTS: &str = "requests";
    pub const MEAN_BATCH: &str = "mean_batch";
    pub const MAX_BATCH: &str = "max_batch";
    pub const NFE_TOTAL: &str = "nfe_total";
    /// Prometheus exposition payload of a metrics response.
    pub const TEXT: &str = "text";

    /// Every tag above — the registry round-trip test walks this.
    pub const ALL: &[&str] = &[
        OP,
        OP_PREDICT,
        OP_LIST,
        OP_STATS,
        OP_METRICS,
        OP_SHUTDOWN,
        MODEL,
        U0,
        BUDGET,
        DEADLINE_MS,
        OK,
        ERROR,
        SHED,
        KIND,
        TRAJ,
        NFE,
        NACCEPT,
        NREJECT,
        BATCH,
        MICROS,
        MODELS,
        CLOSING,
        BATCHES,
        REQUESTS,
        MEAN_BATCH,
        MAX_BATCH,
        NFE_TOTAL,
        TEXT,
    ];
}

/// A client request (one JSON line).
#[derive(Clone, Debug, PartialEq)]
pub enum Request {
    Predict {
        model: String,
        u0: Vec<f32>,
        /// Total step-attempt budget; `None` uses the checkpoint default.
        budget: Option<u64>,
        /// Per-request latency budget: a request still queued when this
        /// many milliseconds have passed is shed instead of solved.
        deadline_ms: Option<u64>,
    },
    List,
    Stats,
    /// Scrape the process-global metrics registry (Prometheus text).
    Metrics,
    Shutdown,
}

impl Request {
    pub fn to_json(&self) -> Json {
        match self {
            Request::Predict {
                model,
                u0,
                budget,
                deadline_ms,
            } => {
                let mut fields = vec![
                    (tags::OP, Json::from(tags::OP_PREDICT)),
                    (tags::MODEL, Json::from(model.as_str())),
                    (tags::U0, f32_arr(u0)),
                ];
                if let Some(b) = budget {
                    fields.push((tags::BUDGET, Json::from(*b as usize)));
                }
                if let Some(d) = deadline_ms {
                    fields.push((tags::DEADLINE_MS, Json::from(*d as usize)));
                }
                obj(fields)
            }
            Request::List => obj([(tags::OP, Json::from(tags::OP_LIST))]),
            Request::Stats => obj([(tags::OP, Json::from(tags::OP_STATS))]),
            Request::Metrics => obj([(tags::OP, Json::from(tags::OP_METRICS))]),
            Request::Shutdown => obj([(tags::OP, Json::from(tags::OP_SHUTDOWN))]),
        }
    }

    pub fn from_json(j: &Json) -> Result<Request> {
        match j.get(tags::OP)?.as_str()? {
            tags::OP_PREDICT => {
                let model = j.get(tags::MODEL).context("predict needs a model id")?;
                Ok(Request::Predict {
                    model: model.as_str()?.to_string(),
                    u0: parse_f32_arr(j.get(tags::U0).context("predict needs u0")?)?,
                    budget: match j.opt(tags::BUDGET) {
                        Some(b) => Some(b.as_f64()? as u64),
                        None => None,
                    },
                    deadline_ms: match j.opt(tags::DEADLINE_MS) {
                        Some(d) => Some(d.as_f64()? as u64),
                        None => None,
                    },
                })
            }
            tags::OP_LIST => Ok(Request::List),
            tags::OP_STATS => Ok(Request::Stats),
            tags::OP_METRICS => Ok(Request::Metrics),
            tags::OP_SHUTDOWN => Ok(Request::Shutdown),
            other => bail!("unknown op {other:?} (predict|list|stats|metrics|shutdown)"),
        }
    }

    /// One wire line (no trailing newline).
    pub fn encode(&self) -> String {
        self.to_json().to_string_compact()
    }

    pub fn decode(line: &str) -> Result<Request> {
        Request::from_json(&Json::parse(line)?)
    }
}

/// A server response (one JSON line).
#[derive(Clone, Debug, PartialEq)]
pub enum Response {
    Predict {
        model: String,
        traj: Vec<f32>,
        nfe: u64,
        naccept: u64,
        nreject: u64,
        batch: usize,
        /// Server-side latency of this request, microseconds.
        micros: u64,
    },
    List {
        models: Vec<String>,
    },
    Stats {
        batches: u64,
        requests: u64,
        mean_batch: f64,
        max_batch: usize,
        nfe_total: u64,
        /// Requests turned away by backpressure (queue full, deadline
        /// expired, connection cap, draining shutdown).
        shed: u64,
    },
    /// Prometheus text exposition of the metrics registry.
    Metrics {
        text: String,
    },
    Shutdown,
    /// Load-shed: the server did no solver work for this request.
    /// Retryable — clients back off and resend.
    Shed(String),
    /// Request failed.  `kind` carries the typed [`SolveErrorKind`] when
    /// the batch solve itself failed; `None` for request-level
    /// rejections (bad shape, unknown model, admission control).
    Error {
        msg: String,
        kind: Option<SolveErrorKind>,
    },
}

impl Response {
    pub fn predict(model: &str, reply: &BatchReply, micros: u64) -> Response {
        Response::Predict {
            model: model.to_string(),
            traj: reply.traj.clone(),
            nfe: reply.nfe,
            naccept: reply.naccept,
            nreject: reply.nreject,
            batch: reply.batch,
            micros,
        }
    }

    /// A request-level error (no solver failure class).
    pub fn error(msg: impl Into<String>) -> Response {
        Response::Error {
            msg: msg.into(),
            kind: None,
        }
    }

    pub fn stats(s: &BatcherStats) -> Response {
        Response::Stats {
            batches: s.batches,
            requests: s.requests,
            mean_batch: s.mean_batch(),
            max_batch: s.max_batch,
            nfe_total: s.nfe_total,
            shed: s.shed,
        }
    }

    pub fn to_json(&self) -> Json {
        match self {
            Response::Predict {
                model,
                traj,
                nfe,
                naccept,
                nreject,
                batch,
                micros,
            } => obj([
                (tags::OK, Json::from(true)),
                (tags::MODEL, Json::from(model.as_str())),
                (tags::TRAJ, f32_arr(traj)),
                (tags::NFE, Json::from(*nfe as usize)),
                (tags::NACCEPT, Json::from(*naccept as usize)),
                (tags::NREJECT, Json::from(*nreject as usize)),
                (tags::BATCH, Json::from(*batch)),
                (tags::MICROS, Json::from(*micros as usize)),
            ]),
            Response::List { models } => {
                let mut ids = Vec::with_capacity(models.len());
                for m in models {
                    ids.push(Json::from(m.as_str()));
                }
                obj([(tags::OK, Json::from(true)), (tags::MODELS, Json::Arr(ids))])
            }
            Response::Stats {
                batches,
                requests,
                mean_batch,
                max_batch,
                nfe_total,
                shed,
            } => obj([
                (tags::OK, Json::from(true)),
                (tags::BATCHES, Json::from(*batches as usize)),
                (tags::REQUESTS, Json::from(*requests as usize)),
                (tags::MEAN_BATCH, Json::from(*mean_batch)),
                (tags::MAX_BATCH, Json::from(*max_batch)),
                (tags::NFE_TOTAL, Json::from(*nfe_total as usize)),
                (tags::SHED, Json::from(*shed as usize)),
            ]),
            Response::Metrics { text } => obj([
                (tags::OK, Json::from(true)),
                (tags::TEXT, Json::Str(text.clone())),
            ]),
            Response::Shutdown => {
                obj([(tags::OK, Json::from(true)), (tags::CLOSING, Json::from(true))])
            }
            Response::Shed(reason) => obj([
                (tags::OK, Json::from(false)),
                (tags::SHED, Json::from(true)),
                (tags::ERROR, Json::Str(reason.clone())),
            ]),
            Response::Error { msg, kind } => {
                let mut fields = vec![
                    (tags::OK, Json::from(false)),
                    (tags::ERROR, Json::Str(msg.clone())),
                ];
                if let Some(k) = kind {
                    fields.push((tags::KIND, Json::from(k.as_str())));
                }
                obj(fields)
            }
        }
    }

    pub fn from_json(j: &Json) -> Result<Response> {
        if !j.get(tags::OK)?.as_bool()? {
            let msg = j.get(tags::ERROR)?.as_str()?.to_string();
            if j.opt(tags::SHED).is_some_and(|s| s.as_bool().unwrap_or(false)) {
                return Ok(Response::Shed(msg));
            }
            let kind = match j.opt(tags::KIND) {
                Some(k) => SolveErrorKind::parse(k.as_str()?),
                None => None,
            };
            return Ok(Response::Error { msg, kind });
        }
        if let Some(arr) = j.opt(tags::MODELS) {
            let mut models = Vec::new();
            for m in arr.as_arr()? {
                models.push(m.as_str()?.to_string());
            }
            return Ok(Response::List { models });
        }
        if j.opt(tags::CLOSING).is_some() {
            return Ok(Response::Shutdown);
        }
        if let Some(text) = j.opt(tags::TEXT) {
            return Ok(Response::Metrics {
                text: text.as_str()?.to_string(),
            });
        }
        if let Some(traj) = j.opt(tags::TRAJ) {
            return Ok(Response::Predict {
                model: j.get(tags::MODEL)?.as_str()?.to_string(),
                traj: parse_f32_arr(traj)?,
                nfe: j.get(tags::NFE)?.as_f64()? as u64,
                naccept: j.get(tags::NACCEPT)?.as_f64()? as u64,
                nreject: j.get(tags::NREJECT)?.as_f64()? as u64,
                batch: j.get(tags::BATCH)?.as_usize()?,
                micros: j.get(tags::MICROS)?.as_f64()? as u64,
            });
        }
        Ok(Response::Stats {
            batches: j.get(tags::BATCHES)?.as_f64()? as u64,
            requests: j.get(tags::REQUESTS)?.as_f64()? as u64,
            mean_batch: j.get(tags::MEAN_BATCH)?.as_f64()?,
            max_batch: j.get(tags::MAX_BATCH)?.as_usize()?,
            nfe_total: j.get(tags::NFE_TOTAL)?.as_f64()? as u64,
            shed: match j.opt(tags::SHED) {
                Some(s) => s.as_f64()? as u64,
                None => 0,
            },
        })
    }

    /// One wire line (no trailing newline).
    pub fn encode(&self) -> String {
        self.to_json().to_string_compact()
    }

    pub fn decode(line: &str) -> Result<Response> {
        Response::from_json(&Json::parse(line)?)
    }
}

/// f32 values as a JSON array.  `f64` formatting in [`util::json`] uses
/// the shortest round-trippable decimal form, and every f32 widens to an
/// exactly-representable f64, so `f32 -> wire -> f32` is bit-exact.
///
/// [`util::json`]: crate::util::json
fn f32_arr(v: &[f32]) -> Json {
    Json::Arr(v.iter().map(|&x| Json::from(x as f64)).collect())
}

fn parse_f32_arr(j: &Json) -> Result<Vec<f32>> {
    let mut out = Vec::new();
    for v in j.as_arr()? {
        out.push(v.as_f64()? as f32);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_roundtrip() {
        let reqs = [
            Request::Predict {
                model: "spiral-er".into(),
                u0: vec![2.0, -0.5],
                budget: Some(4096),
                deadline_ms: Some(250),
            },
            Request::Predict {
                model: "m".into(),
                u0: vec![1.0],
                budget: None,
                deadline_ms: None,
            },
            Request::List,
            Request::Stats,
            Request::Metrics,
            Request::Shutdown,
        ];
        for r in reqs {
            assert_eq!(Request::decode(&r.encode()).unwrap(), r, "{r:?}");
        }
        assert!(Request::decode("{\"op\":\"frobnicate\"}").is_err());
        assert!(Request::decode("not json").is_err());
    }

    #[test]
    fn response_roundtrip_is_f32_exact() {
        let resp = Response::Predict {
            model: "spiral-er".into(),
            traj: vec![2.0, -0.0, 1.9375, -0.123456789, f32::MIN_POSITIVE],
            nfe: 433,
            naccept: 72,
            nreject: 0,
            batch: 7,
            micros: 1234,
        };
        let back = Response::decode(&resp.encode()).unwrap();
        match (&resp, &back) {
            (Response::Predict { traj: a, .. }, Response::Predict { traj: b, .. }) => {
                for (x, y) in a.iter().zip(b) {
                    assert_eq!(x.to_bits(), y.to_bits(), "wire must not perturb f32 bits");
                }
            }
            _ => panic!("wrong variant"),
        }
        assert_eq!(back, resp);
    }

    #[test]
    fn other_responses_roundtrip() {
        for r in [
            Response::List {
                models: vec!["a".into(), "b".into()],
            },
            Response::Stats {
                batches: 3,
                requests: 17,
                mean_batch: 17.0 / 3.0,
                max_batch: 9,
                nfe_total: 999,
                shed: 4,
            },
            Response::Shutdown,
            // Multi-line Prometheus text must survive JSON escaping.
            Response::Metrics {
                text: "# TYPE a counter\na 1\nb{model=\"x\",le=\"+Inf\"} 2\n".into(),
            },
            Response::error("nope"),
            Response::Error {
                msg: "solve failed".into(),
                kind: Some(SolveErrorKind::NonFiniteState),
            },
            Response::Shed("queue full".into()),
        ] {
            assert_eq!(Response::decode(&r.encode()).unwrap(), r, "{r:?}");
        }
    }

    #[test]
    fn every_solve_error_kind_survives_the_wire() {
        for kind in [
            SolveErrorKind::NonFiniteState,
            SolveErrorKind::StepSizeUnderflow,
            SolveErrorKind::BudgetExhausted,
            SolveErrorKind::TapeMismatch,
            SolveErrorKind::BadSpan,
            SolveErrorKind::MissingRng,
        ] {
            let r = Response::Error {
                msg: format!("solve failed: {kind}"),
                kind: Some(kind),
            };
            assert_eq!(Response::decode(&r.encode()).unwrap(), r);
        }
        // An unknown kind string degrades to a kind-less error, never a
        // decode failure (forward compatibility with newer servers).
        let back =
            Response::decode("{\"ok\":false,\"error\":\"x\",\"kind\":\"not_a_kind\"}").unwrap();
        assert_eq!(back, Response::error("x"));
    }

    #[test]
    fn wire_lines_are_single_line() {
        let r = Request::Predict {
            model: "m".into(),
            u0: vec![1.0, 2.0],
            budget: None,
            deadline_ms: None,
        };
        assert!(!r.encode().contains('\n'));
        assert!(!Response::error("x\ny").encode().contains('\n'));
    }
}
