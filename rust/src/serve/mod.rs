//! Inference serving: checkpoints, a model registry, and a
//! micro-batching NFE-aware prediction server.
//!
//! The paper's pitch is cheap *prediction* — regularizing the solver's
//! internal cost heuristics so the trained NDE needs fewer function
//! evaluations at inference time.  This subsystem is where that saving
//! is cashed out as serving capacity: a trained `NativeBackend` model is
//! persisted, reloaded bit-exactly, and served over TCP with concurrent
//! requests coalesced into row-batched solves, so fewer accepted steps
//! per solve directly means more requests per core.  Four layers
//! (DESIGN.md §Serving):
//!
//! * [`checkpoint`] — the durable model format: a versioned, std-only
//!   JSON file wrapping [`runtime::Backend::export_state`]'s
//!   [`ExportedState`] (experiment id, method label, tableau name,
//!   tolerances, step budget, hyper block) with the flat f32 parameters
//!   **hex-encoded for bit-exactness** — `save → load → predict` is
//!   bit-identical to the in-memory model (`tests/serve_checkpoint.rs`
//!   pins all five experiment model shapes).  Malformed, truncated and
//!   wrong-version files decode to a typed [`CheckpointError`], never a
//!   panic.  Produced by `regnde run/train … --checkpoint <path>`.
//! * [`registry`] — a thread-safe id → model map with lazy loading from
//!   a checkpoint directory: each [`ServableModel`] holds the decoded
//!   checkpoint, a backend reconstructed with the checkpoint's solver,
//!   and the validated parameter vector, shared via `Arc` across every
//!   connection.
//! * [`batcher`] — the micro-batching queue: concurrent predict requests
//!   for the same model join a leader/follower *window*
//!   ([`BatchPolicy`]: `max_batch`, `max_wait`), and each closed window
//!   becomes **one** row-batched `drive()` solve
//!   (`NativeBackend::predict_traj_batch`) on the shared
//!   [`util::threadpool::ThreadPool`].  Replies carry the batch solve's
//!   `Stats` — per-request NFE accounting — and a failing solve fails
//!   only its own window's requests.
//! * [`protocol`] / [`server`] — line-delimited JSON over TCP
//!   (`std::net`, no new deps): `regnde serve --registry <dir> --addr
//!   <a>` hosts it, `regnde predict --addr <a> --model <id>` consumes
//!   it, and per-connection **NFE-budget admission control** rejects
//!   requests whose declared `StepBudget::Total` would exceed the
//!   connection's remaining quota ([`ServerOpts::nfe_quota`]).
//!
//! Latency/throughput/NFE-per-request numbers are tracked by
//! `benches/bench_serving.rs` (`BENCH_serving.json`, schema in DESIGN.md
//! §Serving), which serves a vanilla and an `ernode` checkpoint over
//! loopback and reports the regularized model's requests-per-second
//! advantage.
//!
//! [`ExportedState`]: crate::runtime::ExportedState
//! [`runtime::Backend::export_state`]: crate::runtime::Backend::export_state
//! [`util::threadpool::ThreadPool`]: crate::util::threadpool::ThreadPool

pub mod batcher;
pub mod checkpoint;
pub mod protocol;
pub mod registry;
pub mod server;

pub use batcher::{BatchPolicy, BatchReply, Batcher, BatcherStats};
pub use checkpoint::{Checkpoint, CheckpointError};
pub use protocol::{Request, Response};
pub use registry::{Registry, ServableModel};
pub use server::{Client, Server, ServerOpts};
