//! Inference serving: checkpoints, a model registry, and a
//! micro-batching NFE-aware prediction server.
//!
//! The paper's pitch is cheap *prediction* — regularizing the solver's
//! internal cost heuristics so the trained NDE needs fewer function
//! evaluations at inference time.  This subsystem is where that saving
//! is cashed out as serving capacity: a trained `NativeBackend` model is
//! persisted, reloaded bit-exactly, and served over TCP with concurrent
//! requests coalesced into row-batched solves, so fewer accepted steps
//! per solve directly means more requests per core.  Four layers
//! (DESIGN.md §Serving):
//!
//! * [`checkpoint`] — the durable model format: a versioned, std-only
//!   JSON file wrapping [`runtime::Backend::export_state`]'s
//!   [`ExportedState`] (experiment id, method label, tableau name,
//!   tolerances, step budget, hyper block) with the flat f32 parameters
//!   **hex-encoded for bit-exactness** — `save → load → predict` is
//!   bit-identical to the in-memory model (`tests/serve_checkpoint.rs`
//!   pins all five experiment model shapes).  Malformed, truncated and
//!   wrong-version files decode to a typed [`CheckpointError`], never a
//!   panic.  Produced by `regnde run/train … --checkpoint <path>`.
//! * [`registry`] — a thread-safe id → model map with lazy loading from
//!   a checkpoint directory: each [`ServableModel`] holds the decoded
//!   checkpoint, a backend reconstructed with the checkpoint's solver,
//!   and the validated parameter vector, shared via `Arc` across every
//!   connection.
//! * [`batcher`] — the micro-batching queue: concurrent predict requests
//!   for the same model join a leader/follower *window*
//!   ([`BatchPolicy`]: `max_batch`, `max_wait`), and each closed window
//!   becomes **one** row-batched `drive()` solve
//!   (`NativeBackend::predict_traj_batch`) on the shared
//!   [`util::threadpool::ThreadPool`].  Replies carry the batch solve's
//!   `Stats` — per-request NFE accounting — and a failing solve fails
//!   only its own window's requests.
//! * [`protocol`] / [`server`] — line-delimited JSON over TCP
//!   (`std::net`, no new deps): `regnde serve --registry <dir> --addr
//!   <a>` hosts it, `regnde predict --addr <a> --model <id>` consumes
//!   it, and per-connection **NFE-budget admission control** rejects
//!   requests whose declared `StepBudget::Total` would exceed the
//!   connection's remaining quota ([`ServerOpts::nfe_quota`]).
//!
//! Latency/throughput/NFE-per-request numbers are tracked by
//! `benches/bench_serving.rs` (`BENCH_serving.json`, schema in DESIGN.md
//! §Serving), which serves a vanilla and an `ernode` checkpoint over
//! loopback and reports the regularized model's requests-per-second
//! advantage.
//!
//! ## Failure containment (DESIGN.md §Robustness)
//!
//! No input reachable from the wire may panic a serving thread; every
//! failure is **typed** and **scoped**:
//!
//! * A solve that runs and dies surfaces the solver's
//!   [`SolveErrorKind`] end-to-end — [`PredictError::Solve`] out of the
//!   registry, [`BatchError::Solve`] out of the batcher, and an error
//!   response carrying the machine-readable `kind` string on the wire —
//!   and poisons **only its own batch window**; other windows, models
//!   and connections are untouched.
//! * **Load shedding** is distinct from failure: a request refused
//!   before any solver work (bounded admission queue
//!   [`BatchPolicy::max_queue`], expired `deadline_ms`, connection cap
//!   [`ServerOpts::max_conns`], draining shutdown) answers
//!   `{"ok":false,"shed":true,...}` and is safely retryable with
//!   backoff (`regnde predict --retries` does exactly that).
//! * **Shutdown drains**: the accept loop stops, in-flight windows
//!   flush and answer, connection threads are joined — then
//!   [`Server::serve`] returns.
//! * Corrupt checkpoints decode to typed [`CheckpointError`]s and
//!   internal locks recover from panicked holders, so one bad artifact
//!   or crashed thread cannot take the server down.
//!
//! `tests/fault_injection.rs` drives all of this adversarially —
//! non-finite parameters, hostile wire bytes, mid-request disconnects —
//! and asserts the server keeps answering.
//!
//! ## Observability (DESIGN.md §Observability)
//!
//! Serving feeds the process-global [`crate::obs::metrics`] registry:
//! per-model request/served/shed/error counters, request-latency and
//! per-request-NFE histograms (server), batch-size histogram and
//! batch/shed counters (batcher), plus a live-connection gauge.  Scrape
//! with the `metrics` wire op or `GET /metrics` on the serving port —
//! the full metric catalog, bucket layouts, and exposition grammar live
//! in DESIGN.md §Observability, the spans ([`crate::obs::span`])
//! bracket each `batch_solve`, and the batcher resolves its registry
//! handles once at construction so the hot path only touches lock-free
//! cells.
//!
//! ## Enforced invariants (DESIGN.md §Static Analysis)
//!
//! Serving code is the strictest `regnde-analyze` lint scope: no
//! panic-family calls *and* no bare slice indexing outside tests
//! (`L2`), lock acquisition follows the committed
//! `rust/tools/analyze/lock_order.txt` ranks with no guard held across
//! I/O or a batch drive (`L4`), and every protocol tag, error kind and
//! checkpoint schema string on the wire is pinned by
//! `rust/tools/analyze/wire_registry.txt` (`L3`) — renaming one is an
//! explicit two-file change.  The nightly TSan job hammers the batcher
//! window-close / drain-shutdown races dynamically
//! (`tests/serve_stress.rs`).
//!
//! [`ExportedState`]: crate::runtime::ExportedState
//! [`runtime::Backend::export_state`]: crate::runtime::Backend::export_state
//! [`util::threadpool::ThreadPool`]: crate::util::threadpool::ThreadPool
//! [`SolveErrorKind`]: crate::solvers::error::SolveErrorKind

pub mod batcher;
pub mod checkpoint;
pub mod protocol;
pub mod registry;
pub mod server;

pub use batcher::{BatchError, BatchPolicy, BatchReply, Batcher, BatcherStats};
pub use checkpoint::{Checkpoint, CheckpointError, TrainProgress};
pub use protocol::{Request, Response};
pub use registry::{PredictError, Registry, ServableModel};
pub use server::{Client, Server, ServerOpts};
