//! Phase-level span profiler with Chrome trace-event output.
//!
//! [`Span::enter`] returns a guard; its `Drop` records a complete
//! (`"ph":"X"`) trace event into a buffer preallocated by [`enable`].
//! When profiling is off (the default) a span is a single relaxed
//! atomic load — no clock read, no lock, no allocation — so
//! instrumented code paths cost nothing in production and the
//! alloc-free/bit-equality suites run with the instrumentation compiled
//! in.
//!
//! **Overhead policy** (DESIGN.md §Observability): spans wrap *phases*
//! — a whole solve, an adjoint walk, an optimizer step, an all-reduce —
//! never per-step or per-GEMM work.  Recording one event takes the
//! profiler mutex, which is fine at phase granularity and ruinous
//! inside a hot loop (`regnde-analyze` L1.obs enforces this for
//! `hot-path` annotated fns).
//!
//! [`dump_chrome_trace`] renders the buffer as a Chrome trace-event
//! JSON array loadable in `chrome://tracing` / Perfetto; the CLI's
//! `--trace <path>` flag wires it to disk.

use std::fmt::Write as _;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, MutexGuard, OnceLock};
use std::time::Instant;

static ENABLED: AtomicBool = AtomicBool::new(false);
static NEXT_TID: AtomicU64 = AtomicU64::new(1);

thread_local! {
    /// Small sequential thread id for the `tid` trace field.
    static TID: u64 = NEXT_TID.fetch_add(1, Ordering::Relaxed);
}

struct SpanEvent {
    name: &'static str,
    cat: &'static str,
    tid: u64,
    ts_us: u64,
    dur_us: u64,
}

struct Prof {
    epoch: Instant,
    events: Vec<SpanEvent>,
    capacity: usize,
    dropped: u64,
}

fn state() -> &'static Mutex<Prof> {
    static STATE: OnceLock<Mutex<Prof>> = OnceLock::new();
    STATE.get_or_init(|| {
        Mutex::new(Prof {
            epoch: Instant::now(),
            events: Vec::new(),
            capacity: 0,
            dropped: 0,
        })
    })
}

fn plock(m: &Mutex<Prof>) -> MutexGuard<'_, Prof> {
    m.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

/// Start profiling: preallocate room for `capacity` events (at least
/// one), clear anything previously recorded, and reset the trace epoch.
/// Events past the capacity are counted in [`dropped`], never grown
/// into.
pub fn enable(capacity: usize) {
    let cap = capacity.max(1);
    let mut p = plock(state());
    p.epoch = Instant::now();
    p.events.clear();
    p.events.reserve(cap);
    p.capacity = cap;
    p.dropped = 0;
    drop(p);
    ENABLED.store(true, Ordering::Relaxed);
}

/// Stop recording.  The buffer is kept for [`dump_chrome_trace`].
pub fn disable() {
    ENABLED.store(false, Ordering::Relaxed);
}

/// Is the profiler currently recording?
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Events recorded so far.
pub fn event_count() -> usize {
    plock(state()).events.len()
}

/// Events discarded because the buffer was full.
pub fn dropped() -> u64 {
    plock(state()).dropped
}

/// RAII span guard: created by [`Span::enter`] (or the `span!` macro),
/// records one complete event on drop.
pub struct Span {
    start: Option<(Instant, &'static str, &'static str)>,
}

impl Span {
    /// Open a span named `name` in category `cat`.  A no-op (one
    /// relaxed load) while profiling is disabled.
    pub fn enter(name: &'static str, cat: &'static str) -> Span {
        if !ENABLED.load(Ordering::Relaxed) {
            return Span { start: None };
        }
        Span {
            start: Some((Instant::now(), name, cat)),
        }
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        let Some((t0, name, cat)) = self.start.take() else {
            return;
        };
        let dur_us = t0.elapsed().as_micros() as u64;
        let tid = TID.with(|t| *t);
        let mut p = plock(state());
        let ts_us = t0.saturating_duration_since(p.epoch).as_micros() as u64;
        if p.events.len() < p.capacity {
            p.events.push(SpanEvent {
                name,
                cat,
                tid,
                ts_us,
                dur_us,
            });
        } else {
            p.dropped += 1;
        }
    }
}

/// Render everything recorded since [`enable`] as a Chrome trace-event
/// JSON array (`[{"name":…,"ph":"X","ts":…,"dur":…,"pid":1,"tid":…}]`).
/// Span names and categories are `&'static str` identifiers chosen in
/// code, so no JSON escaping is needed.
pub fn dump_chrome_trace() -> String {
    let p = plock(state());
    let mut out = String::from("[");
    for (i, e) in p.events.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        let _ = write!(
            out,
            "\n {{\"name\":\"{}\",\"cat\":\"{}\",\"ph\":\"X\",\"ts\":{},\"dur\":{},\"pid\":1,\"tid\":{}}}",
            e.name, e.cat, e.ts_us, e.dur_us, e.tid
        );
    }
    out.push_str("\n]\n");
    out
}

/// Scope-guard span macro: `span!("solve")` or `span!("solve", "ode")`.
/// Expands to a `let` binding, so the span closes at end of scope.
#[macro_export]
macro_rules! span {
    ($name:expr) => {
        let _obs_span = $crate::obs::span::Span::enter($name, "phase");
    };
    ($name:expr, $cat:expr) => {
        let _obs_span = $crate::obs::span::Span::enter($name, $cat);
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    // The profiler is process-global, so these tests serialize on a
    // local mutex to keep enable/disable from interleaving.
    static LOCK: Mutex<()> = Mutex::new(());

    #[test]
    fn disabled_spans_record_nothing_and_events_round_trip() {
        let _g = LOCK.lock().unwrap_or_else(|p| p.into_inner());
        disable();
        {
            let _s = Span::enter("ghost", "test");
        }
        enable(8);
        let before = event_count();
        {
            let _s = Span::enter("solve", "test");
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        assert_eq!(event_count(), before + 1);
        let json = dump_chrome_trace();
        assert!(json.contains("\"name\":\"solve\""), "{json}");
        assert!(json.contains("\"ph\":\"X\""), "{json}");
        assert!(json.contains("\"cat\":\"test\""), "{json}");
        assert!(!json.contains("ghost"), "{json}");
        disable();
    }

    #[test]
    fn capacity_bounds_the_buffer() {
        let _g = LOCK.lock().unwrap_or_else(|p| p.into_inner());
        enable(2);
        for _ in 0..5 {
            let _s = Span::enter("tick", "test");
        }
        assert_eq!(event_count(), 2);
        assert_eq!(dropped(), 3);
        disable();
    }

    #[test]
    fn macro_expands_to_a_scope_guard() {
        let _g = LOCK.lock().unwrap_or_else(|p| p.into_inner());
        enable(4);
        let before = event_count();
        {
            crate::span!("macro_span", "test");
        }
        {
            crate::span!("macro_default");
        }
        assert_eq!(event_count(), before + 2);
        let json = dump_chrome_trace();
        assert!(json.contains("\"name\":\"macro_default\",\"cat\":\"phase\""), "{json}");
        disable();
    }
}
