//! Process-global metrics registry: counters, gauges, fixed-bucket
//! histograms, and a Prometheus-style text exposition.
//!
//! Design constraints (DESIGN.md §Observability):
//!
//! * **Record paths are alloc-free and lock-free.**  Every handle
//!   ([`Counter`], [`Gauge`], [`Histogram`]) is an `Arc` around
//!   preallocated atomics; [`Counter::inc`], [`Gauge::set`] and
//!   [`Histogram::observe`] touch only relaxed atomics plus (for
//!   histograms) a linear scan over a fixed bound array.  The registry
//!   mutex is taken only at *registration* (name lookup) and at
//!   *exposition* time — wiring sites that sit anywhere near a hot loop
//!   must resolve their handles once, up front.
//! * **Deterministic exposition.**  Metrics live in a `BTreeMap` keyed
//!   by full name (including the `{label="value"}` suffix), so
//!   [`Registry::render`] is byte-stable across runs for the same
//!   recorded values — no `HashMap` iteration anywhere
//!   (`regnde-analyze` L5 scope covers `obs/`).
//! * **Infallible API.**  Registration cannot fail: re-registering a
//!   name under a different kind hands back a detached cell instead of
//!   panicking, leaving the registered metric untouched (panic-freedom,
//!   L2 scope).
//!
//! The metric name catalog and the bucket layouts are documented in
//! DESIGN.md §Observability.

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, OnceLock};

/// Monotone event counter.
#[derive(Clone)]
pub struct Counter(Arc<AtomicU64>);

impl Counter {
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Last-writer-wins instantaneous value (stored as `f64` bits).
#[derive(Clone)]
pub struct Gauge(Arc<AtomicU64>);

impl Gauge {
    pub fn set(&self, v: f64) {
        self.0.store(v.to_bits(), Ordering::Relaxed);
    }

    pub fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }
}

struct HistogramCore {
    /// Upper bounds of the finite buckets, strictly increasing; a final
    /// implicit `+Inf` bucket catches everything above the last bound.
    bounds: Vec<f64>,
    /// One slot per finite bound plus the overflow slot.
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    /// Σ of observations, stored as `f64` bits and updated by CAS.
    sum_bits: AtomicU64,
}

/// Fixed-bucket histogram.  Bounds are frozen at registration; recording
/// never allocates.
#[derive(Clone)]
pub struct Histogram(Arc<HistogramCore>);

impl Histogram {
    /// Record one observation: three relaxed atomic updates plus a
    /// linear scan over the preallocated bounds.
    pub fn observe(&self, v: f64) {
        let c = &self.0;
        c.count.fetch_add(1, Ordering::Relaxed);
        let mut cur = c.sum_bits.load(Ordering::Relaxed);
        loop {
            let next = (f64::from_bits(cur) + v).to_bits();
            match c
                .sum_bits
                .compare_exchange_weak(cur, next, Ordering::Relaxed, Ordering::Relaxed)
            {
                Ok(_) => break,
                Err(seen) => cur = seen,
            }
        }
        for (bound, slot) in c.bounds.iter().zip(c.buckets.iter()) {
            if v <= *bound {
                slot.fetch_add(1, Ordering::Relaxed);
                return;
            }
        }
        if let Some(overflow) = c.buckets.last() {
            overflow.fetch_add(1, Ordering::Relaxed);
        }
    }

    pub fn count(&self) -> u64 {
        self.0.count.load(Ordering::Relaxed)
    }

    pub fn sum(&self) -> f64 {
        f64::from_bits(self.0.sum_bits.load(Ordering::Relaxed))
    }

    /// Histogram-derived quantile estimate for `q ∈ [0, 1]`: walk to the
    /// bucket holding the ⌈q·count⌉-th observation and interpolate
    /// linearly inside it.  Observations in the overflow bucket clamp to
    /// the largest finite bound; an empty histogram reports `0.0`.
    pub fn quantile(&self, q: f64) -> f64 {
        let c = &self.0;
        let mut total = 0u64;
        for slot in c.buckets.iter() {
            total += slot.load(Ordering::Relaxed);
        }
        if total == 0 {
            return 0.0;
        }
        let target = ((q.clamp(0.0, 1.0) * total as f64).ceil() as u64).max(1);
        let last_finite = c.bounds.last().copied().unwrap_or(0.0);
        let mut seen = 0u64;
        let mut lo = 0.0f64;
        let his = c.bounds.iter().copied().chain(std::iter::once(last_finite));
        for (slot, hi) in c.buckets.iter().zip(his) {
            let n = slot.load(Ordering::Relaxed);
            if n > 0 && seen + n >= target {
                let into = (target - seen) as f64 / n as f64;
                return lo + (hi - lo) * into;
            }
            seen += n;
            lo = hi;
        }
        last_finite
    }
}

enum Slot {
    Counter(Arc<AtomicU64>),
    Gauge(Arc<AtomicU64>),
    Histogram(Arc<HistogramCore>),
}

/// Named metric registry.  Use the process-global [`registry`] in
/// product code; construct fresh instances in tests.
#[derive(Default)]
pub struct Registry {
    slots: Mutex<BTreeMap<String, Slot>>,
}

fn plock(m: &Mutex<BTreeMap<String, Slot>>) -> MutexGuard<'_, BTreeMap<String, Slot>> {
    m.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    /// Get-or-register a counter under `name` (full name, including any
    /// `{label="value"}` suffix — see [`labeled`]).
    pub fn counter(&self, name: &str) -> Counter {
        let mut slots = plock(&self.slots);
        let slot = slots
            .entry(name.to_string())
            .or_insert_with(|| Slot::Counter(Arc::new(AtomicU64::new(0))));
        match slot {
            Slot::Counter(c) => Counter(Arc::clone(c)),
            // Kind clash: hand back a detached cell, leave the
            // registered metric untouched (infallible by design).
            _ => Counter(Arc::new(AtomicU64::new(0))),
        }
    }

    /// Get-or-register a gauge under `name`.
    pub fn gauge(&self, name: &str) -> Gauge {
        let mut slots = plock(&self.slots);
        let slot = slots
            .entry(name.to_string())
            .or_insert_with(|| Slot::Gauge(Arc::new(AtomicU64::new(0))));
        match slot {
            Slot::Gauge(g) => Gauge(Arc::clone(g)),
            _ => Gauge(Arc::new(AtomicU64::new(0))),
        }
    }

    /// Get-or-register a histogram under `name` with the given finite
    /// bucket bounds (an `+Inf` overflow bucket is added implicitly).
    /// Bounds are frozen by whoever registers first.
    pub fn histogram(&self, name: &str, bounds: &[f64]) -> Histogram {
        let mut slots = plock(&self.slots);
        let slot = slots.entry(name.to_string()).or_insert_with(|| {
            let mut buckets = Vec::with_capacity(bounds.len() + 1);
            for _ in 0..bounds.len() + 1 {
                buckets.push(AtomicU64::new(0));
            }
            Slot::Histogram(Arc::new(HistogramCore {
                bounds: bounds.to_vec(),
                buckets,
                count: AtomicU64::new(0),
                sum_bits: AtomicU64::new(0.0f64.to_bits()),
            }))
        });
        match slot {
            Slot::Histogram(h) => Histogram(Arc::clone(h)),
            _ => Histogram(Arc::new(HistogramCore {
                bounds: bounds.to_vec(),
                buckets: std::iter::repeat_with(|| AtomicU64::new(0))
                    .take(bounds.len() + 1)
                    .collect(),
                count: AtomicU64::new(0),
                sum_bits: AtomicU64::new(0.0f64.to_bits()),
            })),
        }
    }

    /// Render the whole registry as Prometheus-style text exposition
    /// (`# TYPE` per family, cumulative `le` buckets, `_sum`/`_count`).
    /// Output is byte-deterministic for fixed recorded values: names
    /// iterate in `BTreeMap` order.
    pub fn render(&self) -> String {
        let slots = plock(&self.slots);
        let mut out = String::new();
        let mut last_family = String::new();
        for (name, slot) in slots.iter() {
            let (family, labels) = split_name(name);
            if family != last_family {
                let kind = match slot {
                    Slot::Counter(_) => "counter",
                    Slot::Gauge(_) => "gauge",
                    Slot::Histogram(_) => "histogram",
                };
                let _ = writeln!(out, "# TYPE {family} {kind}");
                last_family = family.to_string();
            }
            match slot {
                Slot::Counter(c) => {
                    let _ = writeln!(out, "{name} {}", c.load(Ordering::Relaxed));
                }
                Slot::Gauge(g) => {
                    let _ = writeln!(out, "{name} {}", f64::from_bits(g.load(Ordering::Relaxed)));
                }
                Slot::Histogram(h) => {
                    let mut cum = 0u64;
                    let les = h
                        .bounds
                        .iter()
                        .map(|b| LeBound::Finite(*b))
                        .chain(std::iter::once(LeBound::Inf));
                    for (slot_, le) in h.buckets.iter().zip(les) {
                        cum += slot_.load(Ordering::Relaxed);
                        match labels {
                            Some(l) => {
                                let _ =
                                    writeln!(out, "{family}_bucket{{{l},le=\"{le}\"}} {cum}");
                            }
                            None => {
                                let _ = writeln!(out, "{family}_bucket{{le=\"{le}\"}} {cum}");
                            }
                        }
                    }
                    let sum = f64::from_bits(h.sum_bits.load(Ordering::Relaxed));
                    let count = h.count.load(Ordering::Relaxed);
                    match labels {
                        Some(l) => {
                            let _ = writeln!(out, "{family}_sum{{{l}}} {sum}");
                            let _ = writeln!(out, "{family}_count{{{l}}} {count}");
                        }
                        None => {
                            let _ = writeln!(out, "{family}_sum {sum}");
                            let _ = writeln!(out, "{family}_count {count}");
                        }
                    }
                }
            }
        }
        out
    }
}

enum LeBound {
    Finite(f64),
    Inf,
}

impl std::fmt::Display for LeBound {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            LeBound::Finite(b) => write!(f, "{b}"),
            LeBound::Inf => write!(f, "+Inf"),
        }
    }
}

/// `family{label="v"}` → `("family", Some("label=\"v\""))`.
fn split_name(name: &str) -> (&str, Option<&str>) {
    match name.split_once('{') {
        Some((family, rest)) => (family, Some(rest.trim_end_matches('}'))),
        None => (name, None),
    }
}

/// The process-global registry every wiring site records into and the
/// `metrics` wire op / `GET /metrics` path render from.
pub fn registry() -> &'static Registry {
    static GLOBAL: OnceLock<Registry> = OnceLock::new();
    GLOBAL.get_or_init(Registry::new)
}

/// Build a full metric name with one label: `labeled("f", "model", "x")`
/// → `f{model="x"}`.
pub fn labeled(family: &str, key: &str, value: &str) -> String {
    format!("{family}{{{key}=\"{value}\"}}")
}

/// Log-spaced latency bounds (seconds): 100 µs … 10 s in a 1–2.5–5
/// ladder (DESIGN.md §Observability).
pub const LATENCY_BUCKETS: [f64; 16] = [
    0.0001, 0.00025, 0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25, 0.5, 1.0, 2.5,
    5.0, 10.0,
];

/// Linear per-request NFE bounds: 32-wide bins up to 1024 function
/// evaluations.
pub fn nfe_buckets() -> [f64; 32] {
    std::array::from_fn(|i| ((i + 1) * 32) as f64)
}

/// Linear batch-size bounds: 1 … 32 requests per solver batch.
pub fn batch_buckets() -> [f64; 32] {
    std::array::from_fn(|i| (i + 1) as f64)
}

/// One-call training telemetry: per-step gauges under `model`, plus the
/// step counter.  Pure reads of values the trainer already computed —
/// never perturbs training state (bit-transparency contract).
pub fn note_train_step(model: &str, loss: f64, r_e: f64, r_s: f64, grad_norm: f64, secs: f64) {
    let r = registry();
    r.gauge(&labeled("regnde_train_loss", "model", model)).set(loss);
    r.gauge(&labeled("regnde_train_r_e", "model", model)).set(r_e);
    r.gauge(&labeled("regnde_train_r_s", "model", model)).set(r_s);
    r.gauge(&labeled("regnde_train_grad_norm", "model", model))
        .set(grad_norm);
    r.gauge(&labeled("regnde_train_step_seconds", "model", model))
        .set(secs);
    r.counter(&labeled("regnde_train_steps_total", "model", model))
        .inc();
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_and_gauge_roundtrip() {
        let r = Registry::new();
        let c = r.counter("c_total");
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
        // Re-registration hands back the same cell.
        assert_eq!(r.counter("c_total").get(), 5);
        let g = r.gauge("g");
        g.set(-2.5);
        assert_eq!(r.gauge("g").get(), -2.5);
    }

    #[test]
    fn kind_clash_yields_detached_cell() {
        let r = Registry::new();
        let c = r.counter("name");
        c.inc();
        let g = r.gauge("name");
        g.set(9.0);
        // The registered counter is untouched; the gauge was detached.
        assert_eq!(r.counter("name").get(), 1);
    }

    #[test]
    fn histogram_buckets_count_and_sum() {
        let r = Registry::new();
        let h = r.histogram("lat", &[1.0, 2.0, 4.0]);
        for v in [0.5, 1.5, 3.0, 100.0] {
            h.observe(v);
        }
        assert_eq!(h.count(), 4);
        assert!((h.sum() - 105.0).abs() < 1e-12);
        let text = r.render();
        assert!(text.contains("# TYPE lat histogram"), "{text}");
        assert!(text.contains("lat_bucket{le=\"1\"} 1"), "{text}");
        assert!(text.contains("lat_bucket{le=\"2\"} 2"), "{text}");
        assert!(text.contains("lat_bucket{le=\"4\"} 3"), "{text}");
        assert!(text.contains("lat_bucket{le=\"+Inf\"} 4"), "{text}");
        assert!(text.contains("lat_sum 105"), "{text}");
        assert!(text.contains("lat_count 4"), "{text}");
    }

    #[test]
    fn histogram_quantiles_interpolate() {
        let r = Registry::new();
        let h = r.histogram("q", &[10.0, 20.0, 30.0]);
        for i in 0..100 {
            // Uniform over (0, 30]: ~33 per bucket.
            h.observe((i % 30 + 1) as f64);
        }
        let p50 = h.quantile(0.5);
        assert!((10.0..=20.0).contains(&p50), "p50={p50}");
        let p99 = h.quantile(0.99);
        assert!((20.0..=30.0).contains(&p99), "p99={p99}");
        // Overflow observations clamp to the largest finite bound.
        h.observe(1e9);
        assert!(h.quantile(1.0) <= 30.0);
        // Empty histogram.
        assert_eq!(r.histogram("empty", &[1.0]).quantile(0.5), 0.0);
    }

    #[test]
    fn labeled_families_render_sorted_with_one_type_line() {
        let r = Registry::new();
        r.counter(&labeled("req_total", "model", "b")).inc();
        r.counter(&labeled("req_total", "model", "a")).add(2);
        let text = r.render();
        let type_lines = text.matches("# TYPE req_total counter").count();
        assert_eq!(type_lines, 1, "{text}");
        let a = text.find("model=\"a\"").expect("a line");
        let b = text.find("model=\"b\"").expect("b line");
        assert!(a < b, "BTreeMap order: {text}");
        assert!(text.contains("req_total{model=\"a\"} 2"), "{text}");
    }

    #[test]
    fn labeled_histogram_merges_le_into_label_set() {
        let r = Registry::new();
        let h = r.histogram(&labeled("lat", "model", "m"), &[1.0]);
        h.observe(0.5);
        let text = r.render();
        assert!(text.contains("lat_bucket{model=\"m\",le=\"1\"} 1"), "{text}");
        assert!(text.contains("lat_sum{model=\"m\"} 0.5"), "{text}");
        assert!(text.contains("lat_count{model=\"m\"} 1"), "{text}");
    }

    #[test]
    fn global_registry_is_a_singleton() {
        let name = "obs_metrics_singleton_test_total";
        registry().counter(name).inc();
        assert!(registry().counter(name).get() >= 1);
    }

    #[test]
    fn bucket_layouts_are_increasing() {
        for w in LATENCY_BUCKETS.windows(2) {
            assert!(w[0] < w[1]);
        }
        for w in nfe_buckets().windows(2) {
            assert!(w[0] < w[1]);
        }
        for w in batch_buckets().windows(2) {
            assert!(w[0] < w[1]);
        }
    }
}
