//! Leveled stderr logger behind the `log_error!`/`log_warn!`/
//! `log_info!`/`log_debug!` macros.
//!
//! One process-global level (an `AtomicU8`, default [`Level::Info`]),
//! set once at startup from the CLI's `--log-level` flag.  Each macro
//! checks the level *before* building its format arguments, so disabled
//! targets cost one relaxed load and no formatting.  Lines render as
//! `[LEVEL] target: message` on stderr — stdout stays reserved for the
//! CLI's machine-greppable result lines (checkpoint paths, bench JSON,
//! smoke-test markers), which is why this logger never writes there.

use std::fmt;
use std::sync::atomic::{AtomicU8, Ordering};

/// Log severity, most severe first.  A configured level admits itself
/// and everything more severe.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

impl Level {
    pub fn as_str(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN",
            Level::Info => "INFO",
            Level::Debug => "DEBUG",
        }
    }

    /// Parse a `--log-level` value (case-insensitive).
    pub fn parse(s: &str) -> Option<Level> {
        match s.trim().to_ascii_lowercase().as_str() {
            "error" => Some(Level::Error),
            "warn" => Some(Level::Warn),
            "info" => Some(Level::Info),
            "debug" => Some(Level::Debug),
            _ => None,
        }
    }
}

static LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);

pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

pub fn level() -> Level {
    match LEVEL.load(Ordering::Relaxed) {
        0 => Level::Error,
        1 => Level::Warn,
        3 => Level::Debug,
        _ => Level::Info,
    }
}

/// Set the level from a CLI string; `Err` names the accepted values.
pub fn set_level_str(s: &str) -> Result<(), String> {
    match Level::parse(s) {
        Some(l) => {
            set_level(l);
            Ok(())
        }
        None => Err(format!(
            "unknown log level `{s}` (expected error|warn|info|debug)"
        )),
    }
}

/// Would a message at `l` currently be emitted?
pub fn enabled(l: Level) -> bool {
    (l as u8) <= LEVEL.load(Ordering::Relaxed)
}

/// Emit one line.  Called by the macros after their level check; prefer
/// the macros so arguments are not formatted when filtered out.
pub fn write(l: Level, target: &str, args: fmt::Arguments<'_>) {
    eprintln!("[{}] {}: {}", l.as_str(), target, args);
}

/// `log_error!("target", "fmt", args…)` — always emitted (ERROR is the
/// floor of every level).
#[macro_export]
macro_rules! log_error {
    ($target:expr, $($arg:tt)*) => {
        if $crate::obs::log::enabled($crate::obs::log::Level::Error) {
            $crate::obs::log::write($crate::obs::log::Level::Error, $target, format_args!($($arg)*));
        }
    };
}

/// `log_warn!("target", "fmt", args…)`.
#[macro_export]
macro_rules! log_warn {
    ($target:expr, $($arg:tt)*) => {
        if $crate::obs::log::enabled($crate::obs::log::Level::Warn) {
            $crate::obs::log::write($crate::obs::log::Level::Warn, $target, format_args!($($arg)*));
        }
    };
}

/// `log_info!("target", "fmt", args…)` — startup/lifecycle lines.
#[macro_export]
macro_rules! log_info {
    ($target:expr, $($arg:tt)*) => {
        if $crate::obs::log::enabled($crate::obs::log::Level::Info) {
            $crate::obs::log::write($crate::obs::log::Level::Info, $target, format_args!($($arg)*));
        }
    };
}

/// `log_debug!("target", "fmt", args…)` — chaos/shed noise, off by
/// default.
#[macro_export]
macro_rules! log_debug {
    ($target:expr, $($arg:tt)*) => {
        if $crate::obs::log::enabled($crate::obs::log::Level::Debug) {
            $crate::obs::log::write($crate::obs::log::Level::Debug, $target, format_args!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_accepts_the_documented_levels() {
        assert_eq!(Level::parse("error"), Some(Level::Error));
        assert_eq!(Level::parse("WARN"), Some(Level::Warn));
        assert_eq!(Level::parse(" info "), Some(Level::Info));
        assert_eq!(Level::parse("Debug"), Some(Level::Debug));
        assert_eq!(Level::parse("verbose"), None);
        assert!(set_level_str("trace").is_err());
    }

    #[test]
    fn level_gating_is_monotone() {
        // Global state: restore the default before returning.
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        assert!(!enabled(Level::Debug));
        assert_eq!(level(), Level::Warn);
        set_level(Level::Info);
        assert!(enabled(Level::Info));
    }
}
