//! [`TraceRecorder`]: bounded per-accepted-step solver trace.
//!
//! A [`crate::solvers::observer::StepObserver`] that copies each
//! accepted step's white-box signals — `(t, h, E_j, S_j, nfe, nreject)`
//! — into a buffer preallocated at construction.  Once full, further
//! steps are counted in [`TraceRecorder::dropped`] instead of grown
//! into, so `on_accept` never allocates inside the solver's alloc-free
//! step loop (proved by `tests/alloc_free.rs`).  Like every observer it
//! only *reads* the [`StepView`], so attaching one is bit-transparent
//! (pinned by `tests/solver_equivalence.rs`).

use crate::solvers::observer::{StepObserver, StepView};

/// One accepted step's signals, copied out of the solver arena.
///
/// `nfe` / `nreject` are the solve's *cumulative* totals at the moment
/// this step was accepted, so consecutive entries encode both the
/// per-step evaluation cost (`nfe` delta) and how many rejected
/// attempts preceded each acceptance (`nreject` delta).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TraceStep {
    /// Ordinal of the accepted step (== [`StepView::index`]).
    pub index: u64,
    /// Step start time.
    pub t: f64,
    /// Step size taken.
    pub h: f64,
    /// Local error estimate `E_j`.
    pub error: f64,
    /// Stiffness estimate `S_j`.
    pub stiffness: f64,
    /// Cumulative function evaluations at accept time.
    pub nfe: u64,
    /// Cumulative rejected attempts at accept time.
    pub nreject: u64,
}

/// Bounded, preallocated step trace (see module docs).
#[derive(Clone, Debug)]
pub struct TraceRecorder {
    steps: Vec<TraceStep>,
    dropped: u64,
}

impl TraceRecorder {
    /// Preallocate room for `capacity` accepted steps (at least one).
    pub fn with_capacity(capacity: usize) -> TraceRecorder {
        TraceRecorder {
            steps: Vec::with_capacity(capacity.max(1)),
            dropped: 0,
        }
    }

    /// The recorded steps, in acceptance order.
    pub fn steps(&self) -> &[TraceStep] {
        &self.steps
    }

    /// Accepted steps that arrived after the buffer filled.
    pub fn dropped(&self) -> u64 {
        self.dropped
    }
}

impl StepObserver for TraceRecorder {
    fn on_accept(&mut self, view: &StepView<'_>) {
        // `push` below `capacity` never reallocates; the bound turns a
        // long solve into dropped tail entries, not into allocation.
        if self.steps.len() < self.steps.capacity() {
            self.steps.push(TraceStep {
                index: view.index,
                t: view.t,
                h: view.h,
                error: view.error,
                stiffness: view.stiffness,
                nfe: view.nfe,
                nreject: view.nreject,
            });
        } else {
            self.dropped += 1;
        }
    }

    fn value(&self) -> f64 {
        self.steps.len() as f64
    }

    fn reset(&mut self) {
        self.steps.clear();
        self.dropped = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn view(index: u64) -> StepView<'static> {
        StepView {
            index,
            t: index as f64 * 0.1,
            h: 0.1,
            error: 1e-3,
            stiffness: 2.0,
            nfe: (index + 1) * 6,
            nreject: index / 2,
            z: &[],
            err: &[],
        }
    }

    #[test]
    fn records_in_order_and_saturates_at_capacity() {
        let mut rec = TraceRecorder::with_capacity(3);
        for i in 0..5 {
            rec.on_accept(&view(i));
        }
        assert_eq!(rec.steps().len(), 3);
        assert_eq!(rec.dropped(), 2);
        assert_eq!(rec.value(), 3.0);
        assert_eq!(rec.steps()[0].index, 0);
        assert_eq!(rec.steps()[2].nfe, 18);
        assert_eq!(rec.steps()[2].nreject, 1);
        rec.reset();
        assert!(rec.steps().is_empty());
        assert_eq!(rec.dropped(), 0);
        // Capacity survives reset: recording resumes without growth.
        rec.on_accept(&view(9));
        assert_eq!(rec.steps().len(), 1);
    }

    #[test]
    fn zero_capacity_still_holds_one_step() {
        let mut rec = TraceRecorder::with_capacity(0);
        rec.on_accept(&view(0));
        rec.on_accept(&view(1));
        assert_eq!(rec.steps().len(), 1);
        assert_eq!(rec.dropped(), 1);
    }
}
