//! `obs` — the unified observability layer (ISSUE 10).
//!
//! The paper's thesis is that the solver's internal heuristics (local
//! error `E_j`, stiffness `S_j`, NFE) are cheap, accurate signals; this
//! module is where those signals — and the serving/training/distributed
//! layers built on top of them — become observable at runtime instead
//! of being discarded after each solve.  Three pillars:
//!
//! * [`metrics`] — a process-global registry of named counters, gauges
//!   and fixed-bucket histograms with Prometheus-style text exposition,
//!   served by the `metrics` wire op and the `GET /metrics` path of
//!   [`crate::serve`], and fed by the trainer
//!   (`runtime/native.rs`) and the distributed coordinator/worker.
//! * [`trace`] — [`trace::TraceRecorder`], a bounded, preallocated
//!   [`crate::solvers::observer::StepObserver`] capturing per-accepted-
//!   step `(t, h, E_j, S_j, nfe, nreject)` without allocating on the
//!   solver hot path.
//! * [`span`] — phase-level span timers (`span!` guard macro) around
//!   solve/adjoint/optimizer/all-reduce phases, dumpable as Chrome
//!   trace-event JSON via the CLI's `--trace <path>` flag.
//!
//! Plus [`log`], the leveled stderr logger behind `log_error!` ..
//! `log_debug!` and the CLI's `--log-level` flag.
//!
//! Metric name catalog, bucket layouts, exposition grammar, trace-event
//! schema and the overhead policy are specified in `rust/DESIGN.md`
//! §Observability.  Everything here is std-only, and all record paths
//! honor the repo's headline invariants: alloc-free on hot paths
//! (`tests/alloc_free.rs`), bit-transparent to solver numerics
//! (`tests/solver_equivalence.rs`, `tests/dist_equivalence.rs`), and
//! panic-free with deterministic exposition ordering (`regnde-analyze`
//! L2/L5 over `obs/`).

pub mod log;
pub mod metrics;
pub mod span;
pub mod trace;

pub use log::Level;
pub use metrics::{registry, Counter, Gauge, Histogram, Registry};
pub use trace::{TraceRecorder, TraceStep};
