//! Vectorized batched MLP kernels + the fused RK stage-combine — the
//! native port of the seed's Pallas prototypes
//! (`python/compile/kernels/fused_dense.py`, `rk_combine.py`).
//!
//! Every NFE the paper's regularizers fight to eliminate is a row-batched
//! MLP forward (and VJP during adjoint training), so these kernels own
//! the FLOP-dominant inner loops of all five experiments:
//!
//! * [`dense_act`] — batched GEMM `[rows × in] · Wᵀ` with fused bias and
//!   activation.  Cache-blocked over [`ROW_BLOCK`] batch rows (one weight
//!   row stays register/L1-resident across the block) and explicitly
//!   vectorized with [`LANES`]-wide independent `f64` accumulators, which
//!   break the serial dependency chain of a naive dot product so the
//!   compiler can keep multiple FMAs in flight (and auto-vectorize).
//! * [`dense_backward_params`] / [`dense_backward_input`] — the matching
//!   batched VJP: `gW += Δᵀ·X`, `gb += Σ_r Δ`, `dX = Δ·W`.  Both are
//!   element-wise `axpy` sweeps whose per-element accumulation order is
//!   **identical** to the retained per-row scalar path, so the backward
//!   kernels are bit-for-bit the scalar reference, just vectorized.
//! * [`rk_combine`] — the fused RK stage combination + embedded error
//!   (`z_new = z + h·Σ bᵢkᵢ`, `err = h·Σ b̃ᵢkᵢ`) in **one** pass over the
//!   solver's stage arena: dims are chunked [`LANES`] wide and stages run
//!   as the inner loop, so each dim's sum still accumulates in tableau
//!   stage order and the result is bit-identical to the seed's two-pass
//!   loop (pinned by `tests/solver_equivalence.rs`).
//!
//! ## Accumulation-order policy (decide, don't drift)
//!
//! * Forward GEMM ([`dense_act`]): the [`LANES`]-chunked reduction
//!   **reassociates** the dot product relative to the seed's left-to-right
//!   sum.  The order is *fixed* (chunk lanes, then a fixed-shape tree
//!   reduction, then the remainder tail) and contains no FMA contraction,
//!   so results are deterministic and platform-independent — but they
//!   differ from the scalar reference by bounded rounding, pinned to an
//!   explicit tolerance in `tests/kernel_equivalence.rs`.  Each output
//!   element depends only on its own row, never on `rows` or the block
//!   decomposition, so a batch of one is bit-identical to the same row
//!   inside a batch of 128 (the serving-consistency contract).
//! * Backward kernels and [`rk_combine`]: per-element accumulation order
//!   matches the scalar path exactly — bit-identical, no tolerance
//!   needed.
//!
//! ## Scalar-fallback ablation knob
//!
//! [`set_scalar_fallback`] routes `Mlp::forward_batch`/`vjp_batch` back
//! to the retained per-row scalar path and [`rk_combine`] to its
//! reference loop, so the benches can measure scalar-vs-kernel on
//! otherwise identical code paths (`benches/bench_solver_core.rs` batch
//! sweep, `benches/bench_native_train.rs` epoch wall-clock).  It is a
//! process-global flag for ablation only — not a per-call mode.

// Kernel signatures mirror the BLAS convention (buffers + explicit
// dimensions) rather than bundling shape structs — every argument is a
// hot-loop slice or extent.
#![allow(clippy::too_many_arguments)]

use std::sync::atomic::{AtomicBool, Ordering};

/// Independent accumulator lanes of the chunked reductions (8 × f64 =
/// one cache line; enough ILP to hide FP-add latency on current cores).
pub const LANES: usize = 8;

/// Batch rows per cache block of [`dense_act`]: one weight row is reused
/// across the whole block while the block's input rows stay hot
/// (`ROW_BLOCK × in_dim × 8` bytes — L1-resident for every dynamics net;
/// the 784-wide MNIST encoder streams from L2 but still reuses each
/// weight row `ROW_BLOCK` times).
pub const ROW_BLOCK: usize = 8;

/// Activation fused into the [`dense_act`] output write.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Act {
    Linear,
    Tanh,
}

static SCALAR_FALLBACK: AtomicBool = AtomicBool::new(false);

/// Route the batched entry points back to the retained scalar paths
/// (ablation benches only; see the module docs).
pub fn set_scalar_fallback(on: bool) {
    SCALAR_FALLBACK.store(on, Ordering::Relaxed);
}

/// Whether the scalar-fallback ablation knob is set.
pub fn scalar_fallback() -> bool {
    SCALAR_FALLBACK.load(Ordering::Relaxed)
}

/// Chunked dot product: [`LANES`] independent accumulators over the
/// body, a fixed-shape tree reduction, then the remainder tail.  The
/// reduction order is fixed and FMA-free, so the result is deterministic
/// and platform-independent (but reassociated relative to a serial sum —
/// see the module-level accumulation-order policy).
#[inline]
// analyze: hot-path
fn dot_lanes(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / LANES;
    let mut lanes = [0.0f64; LANES];
    for k in 0..chunks {
        let ab = &a[k * LANES..(k + 1) * LANES];
        let bb = &b[k * LANES..(k + 1) * LANES];
        for ((acc, &av), &bv) in lanes.iter_mut().zip(ab).zip(bb) {
            *acc += av * bv;
        }
    }
    let mut s = ((lanes[0] + lanes[1]) + (lanes[2] + lanes[3]))
        + ((lanes[4] + lanes[5]) + (lanes[6] + lanes[7]));
    for k in chunks * LANES..n {
        s += a[k] * b[k];
    }
    s
}

/// Batched dense layer with fused bias + activation:
/// `out[r, o] = act(b[o] + Σ_c w[o, c]·x[r, c])` for `r < rows`.
///
/// `w` is row-major `[out_dim × in_dim]`, `x`/`out` row-major
/// `[rows × in_dim]` / `[rows × out_dim]`.  Cache-blocked over
/// [`ROW_BLOCK`] rows with the [`dot_lanes`] vectorized reduction; each
/// output element is independent of `rows`, so any batch decomposition
/// produces identical bits per element.
// analyze: hot-path
pub fn dense_act(
    w: &[f64],
    bias: &[f64],
    x: &[f64],
    rows: usize,
    in_dim: usize,
    out_dim: usize,
    act: Act,
    out: &mut [f64],
) {
    debug_assert_eq!(w.len(), out_dim * in_dim);
    debug_assert_eq!(bias.len(), out_dim);
    debug_assert_eq!(x.len(), rows * in_dim);
    debug_assert_eq!(out.len(), rows * out_dim);
    let mut r0 = 0;
    while r0 < rows {
        let r1 = (r0 + ROW_BLOCK).min(rows);
        for o in 0..out_dim {
            let wrow = &w[o * in_dim..(o + 1) * in_dim];
            let bo = bias[o];
            for r in r0..r1 {
                let v = bo + dot_lanes(wrow, &x[r * in_dim..(r + 1) * in_dim]);
                out[r * out_dim + o] = match act {
                    Act::Tanh => v.tanh(),
                    Act::Linear => v,
                };
            }
        }
        r0 = r1;
    }
}

/// Scalar reference of [`dense_act`] with the seed's accumulation order
/// (bias first, then a serial left-to-right sum over `in_dim`) — the
/// equivalence anchor of `tests/kernel_equivalence.rs` and the forward
/// body of the per-row scalar fallback.
pub fn dense_act_ref(
    w: &[f64],
    bias: &[f64],
    x: &[f64],
    rows: usize,
    in_dim: usize,
    out_dim: usize,
    act: Act,
    out: &mut [f64],
) {
    debug_assert_eq!(w.len(), out_dim * in_dim);
    debug_assert_eq!(bias.len(), out_dim);
    debug_assert_eq!(x.len(), rows * in_dim);
    debug_assert_eq!(out.len(), rows * out_dim);
    for r in 0..rows {
        let xrow = &x[r * in_dim..(r + 1) * in_dim];
        for o in 0..out_dim {
            let wrow = &w[o * in_dim..(o + 1) * in_dim];
            let mut acc = bias[o];
            for (&wv, &xv) in wrow.iter().zip(xrow) {
                acc += wv * xv;
            }
            out[r * out_dim + o] = match act {
                Act::Tanh => acc.tanh(),
                Act::Linear => acc,
            };
        }
    }
}

/// Batched parameter VJP of a dense layer: `gw[o, c] += Σ_r Δ[r, o]·x[r, c]`
/// and `gb[o] += Σ_r Δ[r, o]` (both **accumulate**, matching the `+=`
/// contract of `Mlp::vjp`).  Rows accumulate in batch order and each
/// `gw` element is a serial `axpy` sweep, so the result is bit-identical
/// to the retained per-row scalar path (zero-`Δ` rows are skipped there
/// too).
// analyze: hot-path
pub fn dense_backward_params(
    delta: &[f64],
    x: &[f64],
    rows: usize,
    in_dim: usize,
    out_dim: usize,
    gw: &mut [f64],
    gb: &mut [f64],
) {
    debug_assert_eq!(delta.len(), rows * out_dim);
    debug_assert_eq!(x.len(), rows * in_dim);
    debug_assert_eq!(gw.len(), out_dim * in_dim);
    debug_assert_eq!(gb.len(), out_dim);
    for r in 0..rows {
        let drow = &delta[r * out_dim..(r + 1) * out_dim];
        let xrow = &x[r * in_dim..(r + 1) * in_dim];
        for (o, &d) in drow.iter().enumerate() {
            if d == 0.0 {
                continue;
            }
            let grow = &mut gw[o * in_dim..(o + 1) * in_dim];
            for (g, &xv) in grow.iter_mut().zip(xrow) {
                *g += d * xv;
            }
            gb[o] += d;
        }
    }
}

/// Batched input VJP of a dense layer: `dx[r, c] = Σ_o w[o, c]·Δ[r, o]`
/// (**overwrites** `dx`; callers apply the previous layer's activation
/// derivative afterwards).  Formulated as per-row `axpy` sweeps over the
/// weight rows, so each `dx` element accumulates over `o` in the same
/// order as the scalar path's per-column sum — bit-identical.
// analyze: hot-path
pub fn dense_backward_input(
    w: &[f64],
    delta: &[f64],
    rows: usize,
    in_dim: usize,
    out_dim: usize,
    dx: &mut [f64],
) {
    debug_assert_eq!(w.len(), out_dim * in_dim);
    debug_assert_eq!(delta.len(), rows * out_dim);
    debug_assert_eq!(dx.len(), rows * in_dim);
    for r in 0..rows {
        let drow = &delta[r * out_dim..(r + 1) * out_dim];
        let dxrow = &mut dx[r * in_dim..(r + 1) * in_dim];
        dxrow.fill(0.0);
        for (o, &d) in drow.iter().enumerate() {
            let wrow = &w[o * in_dim..(o + 1) * in_dim];
            for (dst, &wv) in dxrow.iter_mut().zip(wrow) {
                *dst += d * wv;
            }
        }
    }
}

/// Fused RK stage combination + embedded error estimate (the
/// `rk_combine.py` port): `znew[d] = z[d] + h·Σᵢ b[i]·ks[i, d]` and
/// `err[d] = h·Σᵢ b̃[i]·ks[i, d]` in **one** pass over the row-major
/// `[stages × n]` stage arena.
///
/// Dims are chunked [`LANES`] wide with stages as the inner loop, so each
/// dim's accumulator still adds stage terms in tableau order `i = 0..s` —
/// the exact FP sequence of the seed's two-pass loop, hence bit-identical
/// output (the `tests/solver_equivalence.rs` pin holds by construction,
/// not by tolerance).  Allocation-free.
// analyze: hot-path
pub fn rk_combine(
    ks: &[f64],
    stages: usize,
    n: usize,
    b: &[f64],
    btilde: &[f64],
    z: &[f64],
    h: f64,
    znew: &mut [f64],
    err: &mut [f64],
) {
    debug_assert!(ks.len() >= stages * n);
    debug_assert!(b.len() >= stages && btilde.len() >= stages);
    debug_assert_eq!(z.len(), n);
    debug_assert_eq!(znew.len(), n);
    debug_assert_eq!(err.len(), n);
    if scalar_fallback() {
        rk_combine_ref(ks, stages, n, b, btilde, z, h, znew, err);
        return;
    }
    let chunks = n / LANES;
    for blk in 0..chunks {
        let base = blk * LANES;
        let mut az = [0.0f64; LANES];
        let mut ae = [0.0f64; LANES];
        for i in 0..stages {
            let (bi, bti) = (b[i], btilde[i]);
            let kb = &ks[i * n + base..i * n + base + LANES];
            for l in 0..LANES {
                az[l] += bi * kb[l];
                ae[l] += bti * kb[l];
            }
        }
        for l in 0..LANES {
            znew[base + l] = z[base + l] + h * az[l];
            err[base + l] = h * ae[l];
        }
    }
    for d in chunks * LANES..n {
        let mut az = 0.0;
        let mut ae = 0.0;
        for i in 0..stages {
            az += b[i] * ks[i * n + d];
            ae += btilde[i] * ks[i * n + d];
        }
        znew[d] = z[d] + h * az;
        err[d] = h * ae;
    }
}

/// Reference (seed-transcription) stage combination: two accumulation
/// sweeps over the stage block plus a finalize pass — the loop the fused
/// [`rk_combine`] replaces, kept for the ablation benches and the
/// bit-equality check in `tests/kernel_equivalence.rs`.
pub fn rk_combine_ref(
    ks: &[f64],
    stages: usize,
    n: usize,
    b: &[f64],
    btilde: &[f64],
    z: &[f64],
    h: f64,
    znew: &mut [f64],
    err: &mut [f64],
) {
    znew.fill(0.0);
    err.fill(0.0);
    for i in 0..stages {
        let (bi, bti) = (b[i], btilde[i]);
        let ki = &ks[i * n..(i + 1) * n];
        for d in 0..n {
            znew[d] += bi * ki[d];
            err[d] += bti * ki[d];
        }
    }
    for d in 0..n {
        znew[d] = z[d] + h * znew[d];
        err[d] *= h;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn randv(rng: &mut Rng, n: usize) -> Vec<f64> {
        (0..n).map(|_| rng.range(-2.0, 2.0)).collect()
    }

    #[test]
    fn dense_act_close_to_reference_on_odd_shapes() {
        let mut rng = Rng::new(17);
        for &(rows, i, o) in &[(1usize, 1usize, 1usize), (3, 7, 5), (13, 70, 9), (8, 16, 64)] {
            let w = randv(&mut rng, o * i);
            let b = randv(&mut rng, o);
            let x = randv(&mut rng, rows * i);
            let mut fast = vec![0.0; rows * o];
            let mut slow = vec![0.0; rows * o];
            for act in [Act::Linear, Act::Tanh] {
                dense_act(&w, &b, &x, rows, i, o, act, &mut fast);
                dense_act_ref(&w, &b, &x, rows, i, o, act, &mut slow);
                for (a, s) in fast.iter().zip(&slow) {
                    assert!(
                        (a - s).abs() <= 1e-12 * (1.0 + s.abs()),
                        "{rows}x{i}x{o}: {a} vs {s}"
                    );
                }
            }
        }
    }

    #[test]
    fn dense_act_is_batch_decomposition_invariant() {
        // Row 5 of a 13-row batch must be bit-identical to the same row
        // run as a batch of one (the serving-consistency contract).
        let mut rng = Rng::new(23);
        let (rows, i, o) = (13, 21, 6);
        let w = randv(&mut rng, o * i);
        let b = randv(&mut rng, o);
        let x = randv(&mut rng, rows * i);
        let mut full = vec![0.0; rows * o];
        dense_act(&w, &b, &x, rows, i, o, Act::Tanh, &mut full);
        for r in 0..rows {
            let mut one = vec![0.0; o];
            dense_act(&w, &b, &x[r * i..(r + 1) * i], 1, i, o, Act::Tanh, &mut one);
            assert_eq!(
                one.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                full[r * o..(r + 1) * o].iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "row {r} must not depend on the batch around it"
            );
        }
    }

    #[test]
    fn rk_combine_bit_identical_to_reference() {
        let mut rng = Rng::new(31);
        for &(stages, n) in &[(7usize, 2usize), (7, 16), (4, 70), (9, 1), (7, 8)] {
            let ks = randv(&mut rng, stages * n);
            let b = randv(&mut rng, stages);
            let bt = randv(&mut rng, stages);
            let z = randv(&mut rng, n);
            let h = rng.range(1e-4, 0.3);
            let (mut z1, mut e1) = (vec![0.0; n], vec![0.0; n]);
            let (mut z2, mut e2) = (vec![0.0; n], vec![0.0; n]);
            rk_combine(&ks, stages, n, &b, &bt, &z, h, &mut z1, &mut e1);
            rk_combine_ref(&ks, stages, n, &b, &bt, &z, h, &mut z2, &mut e2);
            for d in 0..n {
                assert_eq!(z1[d].to_bits(), z2[d].to_bits(), "znew[{d}] ({stages}x{n})");
                assert_eq!(e1[d].to_bits(), e2[d].to_bits(), "err[{d}] ({stages}x{n})");
            }
        }
    }
}
