//! Flat-parameter multi-layer perceptrons with hand-rolled VJPs.
//!
//! The native backend keeps every model as one flat `f32` parameter
//! vector (the same contract the PJRT artifacts use), so an [`Mlp`] is a
//! *view* over a parameter slice: `[W_0 | b_0 | W_1 | b_1 | ...]` with
//! `W_l` row-major `[out × in]`.  Hidden layers are `tanh`; the output
//! layer is linear unless `final_tanh` is set.  `cube_input` prepends the
//! paper's spiral idiom `x ↦ x³` (DiffEqFlux's `Chain(x -> x.^3, ...)`).
//!
//! [`Mlp::vjp`] is the accumulating vector-Jacobian product the discrete
//! adjoint walks through: it recomputes the forward activations (cheap —
//! no tape) and adds `wᵀ∂f/∂x` / `wᵀ∂f/∂θ` into caller buffers.
//!
//! The solver hot path goes through the **row-batched** entry points
//! [`Mlp::forward_batch`] / [`Mlp::vjp_batch`]: one
//! [`super::kernels::dense_act`] / backward-kernel pass per layer over a
//! flat `[rows × dim]` activation scratch ([`MlpBatchScratch`]), instead
//! of a per-row scalar loop.  The per-row [`Mlp::forward`] / [`Mlp::vjp`]
//! pair is retained as the scalar reference (equivalence-tested in
//! `tests/kernel_equivalence.rs` and reachable at runtime through the
//! `kernels::set_scalar_fallback` ablation knob).

use super::kernels::{self, Act};
use crate::util::rng::Rng;

/// MLP shape: `dims = [in, hidden..., out]`.
#[derive(Clone, Debug)]
pub struct Mlp {
    pub dims: Vec<usize>,
    /// Feature map `x ↦ x³` before the first layer.
    pub cube_input: bool,
    /// Apply `tanh` to the output layer too (used for encoders).
    pub final_tanh: bool,
    /// Precomputed per-layer `(w_offset, b_offset, in, out)` within the
    /// flat parameter slice.  [`Mlp::layer`] used to rebuild these with
    /// an O(L) scan per call — O(L²) per forward/VJP pass.
    layers: Vec<(usize, usize, usize, usize)>,
}

/// Reusable forward/backward scratch for one [`Mlp`] (no per-call heap
/// allocation on the solver hot path).
#[derive(Clone, Debug)]
pub struct MlpScratch {
    /// Input feature + post-activation of every layer, concatenated.
    acts: Vec<f64>,
    delta: Vec<f64>,
    delta2: Vec<f64>,
}

/// Reusable row-batched forward/backward scratch for one [`Mlp`]: a flat
/// `[rows × dim]` activation block per layer boundary plus two delta
/// blocks, all sized at construction so the batched kernels stay
/// allocation-free on the solver hot path.
#[derive(Clone, Debug)]
pub struct MlpBatchScratch {
    rows: usize,
    /// Layer-boundary activations, boundary-major: block `b` holds the
    /// row-major `[rows × dims[b]]` activations at boundary `b`.
    acts: Vec<f64>,
    delta: Vec<f64>,
    delta2: Vec<f64>,
    /// Per-row scalar scratch backing the `kernels::scalar_fallback`
    /// ablation leg (same allocation-free contract).
    row: MlpScratch,
}

impl MlpBatchScratch {
    /// Batch width this scratch was sized for.
    pub fn rows(&self) -> usize {
        self.rows
    }
}

impl Mlp {
    pub fn new(dims: &[usize]) -> Mlp {
        assert!(dims.len() >= 2, "MLP needs at least [in, out]");
        let mut layers = Vec::with_capacity(dims.len() - 1);
        let mut off = 0;
        for w in dims.windows(2) {
            layers.push((off, off + w[0] * w[1], w[0], w[1]));
            off += (w[0] + 1) * w[1];
        }
        Mlp {
            dims: dims.to_vec(),
            cube_input: false,
            final_tanh: false,
            layers,
        }
    }

    /// With the cubic input feature (spiral dynamics idiom).
    pub fn cubed(dims: &[usize]) -> Mlp {
        Mlp {
            cube_input: true,
            ..Mlp::new(dims)
        }
    }

    /// With `tanh` on the output layer (encoder idiom).
    pub fn tanh_out(dims: &[usize]) -> Mlp {
        Mlp {
            final_tanh: true,
            ..Mlp::new(dims)
        }
    }

    pub fn n_layers(&self) -> usize {
        self.dims.len() - 1
    }

    pub fn in_dim(&self) -> usize {
        self.dims[0]
    }

    pub fn out_dim(&self) -> usize {
        *self.dims.last().unwrap()
    }

    /// Flat parameter count: `Σ_l (in_l + 1) · out_l`.
    pub fn n_params(&self) -> usize {
        self.dims
            .windows(2)
            .map(|w| (w[0] + 1) * w[1])
            .sum::<usize>()
    }

    /// (w_offset, b_offset, in, out) of layer `l` within the flat slice
    /// — an O(1) lookup into the table built at construction.
    fn layer(&self, l: usize) -> (usize, usize, usize, usize) {
        self.layers[l]
    }

    pub fn scratch(&self) -> MlpScratch {
        let max = *self.dims.iter().max().unwrap();
        MlpScratch {
            acts: vec![0.0; self.dims.iter().sum::<usize>()],
            delta: vec![0.0; max],
            delta2: vec![0.0; max],
        }
    }

    /// Scratch for the row-batched entry points, sized for `rows` states
    /// per call ([`Mlp::forward_batch`] / [`Mlp::vjp_batch`]).
    pub fn batch_scratch(&self, rows: usize) -> MlpBatchScratch {
        assert!(rows > 0, "batch scratch needs at least one row");
        let total: usize = self.dims.iter().sum::<usize>();
        let max = *self.dims.iter().max().unwrap();
        MlpBatchScratch {
            rows,
            acts: vec![0.0; rows * total],
            delta: vec![0.0; rows * max],
            delta2: vec![0.0; rows * max],
            row: self.scratch(),
        }
    }

    /// Xavier-uniform init into `out[..self.n_params()]`, biases zero.
    pub fn init(&self, rng: &mut Rng, out: &mut [f32]) {
        assert_eq!(out.len(), self.n_params());
        for l in 0..self.n_layers() {
            let (woff, boff, i, o) = self.layer(l);
            let limit = (6.0 / (i + o) as f64).sqrt();
            for w in &mut out[woff..woff + i * o] {
                *w = rng.range(-limit, limit) as f32;
            }
            for b in &mut out[boff..boff + o] {
                *b = 0.0;
            }
        }
    }

    /// Forward pass; fills `scratch.acts` with the input feature and each
    /// layer's post-activation, and copies the output layer into `out`.
    // analyze: hot-path
    pub fn forward(&self, theta: &[f64], x: &[f64], out: &mut [f64], scratch: &mut MlpScratch) {
        debug_assert_eq!(out.len(), self.out_dim());
        self.forward_acts(theta, x, scratch);
        let last_off: usize = self.dims[..self.n_layers()].iter().sum::<usize>();
        out.copy_from_slice(&scratch.acts[last_off..last_off + self.out_dim()]);
    }

    /// Forward pass into the scratch activations only (no output copy) —
    /// what [`Mlp::vjp`] uses, allocation-free.
    // analyze: hot-path
    fn forward_acts(&self, theta: &[f64], x: &[f64], scratch: &mut MlpScratch) {
        debug_assert_eq!(x.len(), self.in_dim());
        let acts = &mut scratch.acts;
        // Input feature.
        for d in 0..self.dims[0] {
            acts[d] = if self.cube_input { x[d] * x[d] * x[d] } else { x[d] };
        }
        let mut in_off = 0;
        let mut out_off = self.dims[0];
        for l in 0..self.n_layers() {
            let (woff, boff, i, o) = self.layer(l);
            let last = l == self.n_layers() - 1;
            for r in 0..o {
                let wrow = &theta[woff + r * i..woff + (r + 1) * i];
                let mut acc = theta[boff + r];
                for c in 0..i {
                    acc += wrow[c] * acts[in_off + c];
                }
                acts[out_off + r] = if !last || self.final_tanh { acc.tanh() } else { acc };
            }
            in_off = out_off;
            out_off += o;
        }
        let _ = in_off;
    }

    /// Accumulating VJP: adds `wᵀ ∂f/∂x` into `gx` and `wᵀ ∂f/∂θ` into
    /// `gtheta` (both `+=`).  Recomputes the forward internally.
    // analyze: hot-path
    pub fn vjp(
        &self,
        theta: &[f64],
        x: &[f64],
        w: &[f64],
        gx: &mut [f64],
        gtheta: &mut [f64],
        scratch: &mut MlpScratch,
    ) {
        debug_assert_eq!(w.len(), self.out_dim());
        debug_assert_eq!(gx.len(), self.in_dim());
        debug_assert_eq!(gtheta.len(), self.n_params());
        // Forward to refresh activations (no tape — recompute is cheaper
        // than storing per-stage activations on the adjoint tape).
        self.forward_acts(theta, x, scratch);

        // delta = w (∘ tanh' if the output layer is activated).
        let n_l = self.n_layers();
        let last_off: usize = self.dims[..n_l].iter().sum::<usize>();
        for r in 0..self.out_dim() {
            let mut d = w[r];
            if self.final_tanh {
                let a = scratch.acts[last_off + r];
                d *= 1.0 - a * a;
            }
            scratch.delta[r] = d;
        }

        for l in (0..n_l).rev() {
            let (woff, boff, i, o) = self.layer(l);
            let in_off: usize = self.dims[..l].iter().sum::<usize>();
            // gW += delta ⊗ in_act ; gb += delta
            for r in 0..o {
                let d = scratch.delta[r];
                if d == 0.0 {
                    continue;
                }
                let grow = &mut gtheta[woff + r * i..woff + (r + 1) * i];
                for c in 0..i {
                    grow[c] += d * scratch.acts[in_off + c];
                }
                gtheta[boff + r] += d;
            }
            // delta_prev = Wᵀ delta (∘ activation' of the previous layer).
            for c in 0..i {
                let mut acc = 0.0;
                for r in 0..o {
                    acc += theta[woff + r * i + c] * scratch.delta[r];
                }
                scratch.delta2[c] = acc;
            }
            if l > 0 {
                // Previous layer is tanh-activated: multiply by 1 - a².
                for c in 0..i {
                    let a = scratch.acts[in_off + c];
                    scratch.delta2[c] *= 1.0 - a * a;
                }
            }
            std::mem::swap(&mut scratch.delta, &mut scratch.delta2);
        }
        // Through the input feature map.
        for d in 0..self.in_dim() {
            let g = scratch.delta[d];
            gx[d] += if self.cube_input { g * 3.0 * x[d] * x[d] } else { g };
        }
    }

    /// Row-batched forward pass: `x` / `out` are row-major
    /// `[rows × in_dim]` / `[rows × out_dim]` with `rows` fixed by the
    /// scratch.  One [`kernels::dense_act`] pass per layer over the flat
    /// activation scratch; every output element is independent of the
    /// batch around it (a batch of one is bit-identical to the same row
    /// of a batch of 128 — the serving-consistency contract).
    /// Allocation-free.
    // analyze: hot-path
    pub fn forward_batch(
        &self,
        theta: &[f64],
        x: &[f64],
        out: &mut [f64],
        scratch: &mut MlpBatchScratch,
    ) {
        let rows = scratch.rows;
        debug_assert_eq!(x.len(), rows * self.in_dim());
        debug_assert_eq!(out.len(), rows * self.out_dim());
        if kernels::scalar_fallback() {
            // Retained per-row scalar path (the ablation leg).
            let (i, o) = (self.in_dim(), self.out_dim());
            for r in 0..rows {
                self.forward(
                    theta,
                    &x[r * i..(r + 1) * i],
                    &mut out[r * o..(r + 1) * o],
                    &mut scratch.row,
                );
            }
            return;
        }
        self.forward_batch_acts(theta, x, scratch);
        let last_off = scratch.rows * self.dims[..self.n_layers()].iter().sum::<usize>();
        out.copy_from_slice(&scratch.acts[last_off..last_off + rows * self.out_dim()]);
    }

    /// Batched forward into the scratch activation blocks only — shared
    /// by [`Mlp::forward_batch`] and [`Mlp::vjp_batch`].
    // analyze: hot-path
    fn forward_batch_acts(&self, theta: &[f64], x: &[f64], scratch: &mut MlpBatchScratch) {
        let rows = scratch.rows;
        let d0 = self.dims[0];
        // Input feature block.
        for (dst, &src) in scratch.acts[..rows * d0].iter_mut().zip(x) {
            *dst = if self.cube_input { src * src * src } else { src };
        }
        let mut in_off = 0usize;
        let mut out_off = rows * d0;
        for l in 0..self.n_layers() {
            let (woff, boff, i, o) = self.layers[l];
            let last = l == self.n_layers() - 1;
            let act = if !last || self.final_tanh { Act::Tanh } else { Act::Linear };
            let (inb, outb) = scratch.acts.split_at_mut(out_off);
            kernels::dense_act(
                &theta[woff..woff + i * o],
                &theta[boff..boff + o],
                &inb[in_off..in_off + rows * i],
                rows,
                i,
                o,
                act,
                &mut outb[..rows * o],
            );
            in_off = out_off;
            out_off += rows * o;
        }
    }

    /// Row-batched accumulating VJP: adds each row's `wᵀ∂f/∂x` into the
    /// matching row of `gx` (row-major `[rows × in_dim]`) and the
    /// batch-summed `wᵀ∂f/∂θ` into `gtheta` (both `+=`, the same
    /// contract as [`Mlp::vjp`]; rows accumulate in batch order, exactly
    /// like the per-row scalar loop).  Recomputes the forward internally
    /// — one backward-kernel pass per layer.  Allocation-free.
    // analyze: hot-path
    pub fn vjp_batch(
        &self,
        theta: &[f64],
        x: &[f64],
        w: &[f64],
        gx: &mut [f64],
        gtheta: &mut [f64],
        scratch: &mut MlpBatchScratch,
    ) {
        let rows = scratch.rows;
        debug_assert_eq!(x.len(), rows * self.in_dim());
        debug_assert_eq!(w.len(), rows * self.out_dim());
        debug_assert_eq!(gx.len(), rows * self.in_dim());
        debug_assert_eq!(gtheta.len(), self.n_params());
        if kernels::scalar_fallback() {
            // Retained per-row scalar path (the ablation leg).
            let (i, o) = (self.in_dim(), self.out_dim());
            for r in 0..rows {
                self.vjp(
                    theta,
                    &x[r * i..(r + 1) * i],
                    &w[r * o..(r + 1) * o],
                    &mut gx[r * i..(r + 1) * i],
                    gtheta,
                    &mut scratch.row,
                );
            }
            return;
        }
        self.forward_batch_acts(theta, x, scratch);

        // delta = w (∘ tanh' if the output layer is activated).
        let n_l = self.n_layers();
        let od = self.out_dim();
        let last_off = rows * self.dims[..n_l].iter().sum::<usize>();
        for (k, dst) in scratch.delta[..rows * od].iter_mut().enumerate() {
            let mut d = w[k];
            if self.final_tanh {
                let a = scratch.acts[last_off + k];
                d *= 1.0 - a * a;
            }
            *dst = d;
        }

        for l in (0..n_l).rev() {
            let (woff, boff, i, o) = self.layers[l];
            let in_off = rows * self.dims[..l].iter().sum::<usize>();
            let inb = &scratch.acts[in_off..in_off + rows * i];
            // gW += Δᵀ ⊗ in_acts ; gb += Σ_r Δ  (w and b are adjacent in
            // the flat slice: woff..boff is W, boff..boff+o is b).
            {
                let (gw, gb) = gtheta[woff..boff + o].split_at_mut(i * o);
                kernels::dense_backward_params(&scratch.delta[..rows * o], inb, rows, i, o, gw, gb);
            }
            // Δ_prev = Δ · W (∘ activation' of the previous layer).
            kernels::dense_backward_input(
                &theta[woff..woff + i * o],
                &scratch.delta[..rows * o],
                rows,
                i,
                o,
                &mut scratch.delta2[..rows * i],
            );
            if l > 0 {
                for (dv, &a) in scratch.delta2[..rows * i].iter_mut().zip(inb) {
                    *dv *= 1.0 - a * a;
                }
            }
            std::mem::swap(&mut scratch.delta, &mut scratch.delta2);
        }
        // Through the input feature map.
        let d0 = self.dims[0];
        for (k, g) in gx[..rows * d0].iter_mut().enumerate() {
            let d = scratch.delta[k];
            *g += if self.cube_input {
                d * 3.0 * x[k] * x[k]
            } else {
                d
            };
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fd_check(mlp: &Mlp, seed: u64) {
        let mut rng = Rng::new(seed);
        let np = mlp.n_params();
        let mut p32 = vec![0.0f32; np];
        mlp.init(&mut rng, &mut p32);
        let theta: Vec<f64> = p32.iter().map(|&v| v as f64).collect();
        let x: Vec<f64> = (0..mlp.in_dim()).map(|_| rng.range(-1.0, 1.0)).collect();
        let w: Vec<f64> = (0..mlp.out_dim()).map(|_| rng.range(-1.0, 1.0)).collect();

        let mut scratch = mlp.scratch();
        let mut gx = vec![0.0; mlp.in_dim()];
        let mut gt = vec![0.0; np];
        mlp.vjp(&theta, &x, &w, &mut gx, &mut gt, &mut scratch);

        let loss = |theta: &[f64], x: &[f64]| -> f64 {
            let mut out = vec![0.0; mlp.out_dim()];
            let mut s = mlp.scratch();
            mlp.forward(theta, x, &mut out, &mut s);
            out.iter().zip(&w).map(|(o, w)| o * w).sum()
        };
        let eps = 1e-6;
        for k in 0..np {
            let mut tp = theta.clone();
            tp[k] += eps;
            let mut tm = theta.clone();
            tm[k] -= eps;
            let fd = (loss(&tp, &x) - loss(&tm, &x)) / (2.0 * eps);
            assert!(
                (gt[k] - fd).abs() < 1e-6 * fd.abs().max(1.0),
                "param {k}: vjp {} vs fd {fd}",
                gt[k]
            );
        }
        for k in 0..mlp.in_dim() {
            let mut xp = x.clone();
            xp[k] += eps;
            let mut xm = x.clone();
            xm[k] -= eps;
            let fd = (loss(&theta, &xp) - loss(&theta, &xm)) / (2.0 * eps);
            assert!(
                (gx[k] - fd).abs() < 1e-6 * fd.abs().max(1.0),
                "input {k}: vjp {} vs fd {fd}",
                gx[k]
            );
        }
    }

    #[test]
    fn vjp_matches_finite_differences() {
        fd_check(&Mlp::new(&[3, 5, 2]), 1);
        fd_check(&Mlp::cubed(&[2, 8, 2]), 2);
        fd_check(&Mlp::tanh_out(&[4, 3]), 3);
        fd_check(&Mlp::new(&[2, 4]), 4);
    }

    #[test]
    fn param_count_and_layout() {
        let m = Mlp::new(&[2, 16, 2]);
        assert_eq!(m.n_params(), 3 * 16 + 17 * 2);
        let (w0, b0, i0, o0) = m.layer(0);
        assert_eq!((w0, b0, i0, o0), (0, 32, 2, 16));
        let (w1, _, i1, o1) = m.layer(1);
        assert_eq!((w1, i1, o1), (48, 16, 2));
    }

    #[test]
    fn init_is_seeded_and_finite() {
        let m = Mlp::new(&[4, 8, 4]);
        let mut a = vec![0.0f32; m.n_params()];
        let mut b = vec![0.0f32; m.n_params()];
        m.init(&mut Rng::new(7), &mut a);
        m.init(&mut Rng::new(7), &mut b);
        assert_eq!(a, b);
        m.init(&mut Rng::new(8), &mut b);
        assert_ne!(a, b);
        assert!(a.iter().all(|v| v.is_finite()));
        assert!(a.iter().any(|&v| v != 0.0));
    }

    #[test]
    fn vjp_accumulates() {
        let m = Mlp::new(&[2, 3]);
        let mut rng = Rng::new(5);
        let mut p32 = vec![0.0f32; m.n_params()];
        m.init(&mut rng, &mut p32);
        let theta: Vec<f64> = p32.iter().map(|&v| v as f64).collect();
        let mut s = m.scratch();
        let (x, w) = ([0.3, -0.2], [1.0, 0.5, -0.5]);
        let mut gx1 = vec![0.0; 2];
        let mut gt1 = vec![0.0; m.n_params()];
        m.vjp(&theta, &x, &w, &mut gx1, &mut gt1, &mut s);
        let mut gx2 = gx1.clone();
        let mut gt2 = gt1.clone();
        m.vjp(&theta, &x, &w, &mut gx2, &mut gt2, &mut s);
        for (a, b) in gt1.iter().zip(&gt2) {
            assert!((2.0 * a - b).abs() < 1e-12, "gtheta must accumulate");
        }
        for (a, b) in gx1.iter().zip(&gx2) {
            assert!((2.0 * a - b).abs() < 1e-12, "gx must accumulate");
        }
    }
}
