//! Adam on flat parameter vectors (Kingma & Ba 2015).
//!
//! Optimizer state is the standard `[m | v]` pair stored as one flat
//! `f32` vector of size `2 · n_params` — the same opaque-flat-vector
//! contract the PJRT train artifacts use for their optimizer state, so
//! [`crate::runtime::TrainState`] carries either backend's state
//! unchanged.

/// Adam hyper-parameters (`lr` is passed per step — the coordinator owns
/// the learning-rate schedule).
#[derive(Clone, Copy, Debug)]
pub struct Adam {
    pub beta1: f64,
    pub beta2: f64,
    pub eps: f64,
}

impl Default for Adam {
    fn default() -> Self {
        Adam {
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
        }
    }
}

impl Adam {
    /// Size of the flat optimizer state for `n` parameters.
    pub fn opt_state_size(n_params: usize) -> usize {
        2 * n_params
    }

    /// One update in place.  `iter` is the number of *completed* steps
    /// before this one (bias correction uses `t = iter + 1`).
    pub fn step(
        &self,
        params: &mut [f32],
        opt_state: &mut [f32],
        grad: &[f64],
        lr: f64,
        iter: u64,
    ) {
        let n = params.len();
        assert_eq!(grad.len(), n, "gradient/parameter size mismatch");
        assert_eq!(opt_state.len(), 2 * n, "opt state must be [m | v]");
        let t = (iter + 1) as i32;
        let bc1 = 1.0 - self.beta1.powi(t);
        let bc2 = 1.0 - self.beta2.powi(t);
        let (ms, vs) = opt_state.split_at_mut(n);
        for k in 0..n {
            let g = grad[k];
            let m = self.beta1 * ms[k] as f64 + (1.0 - self.beta1) * g;
            let v = self.beta2 * vs[k] as f64 + (1.0 - self.beta2) * g * g;
            ms[k] = m as f32;
            vs[k] = v as f32;
            let update = lr * (m / bc1) / ((v / bc2).sqrt() + self.eps);
            params[k] = (params[k] as f64 - update) as f32;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn descends_a_quadratic() {
        // minimize f(p) = Σ (p - 3)²
        let adam = Adam::default();
        let mut p = vec![0.0f32; 4];
        let mut s = vec![0.0f32; 8];
        for it in 0..500 {
            let g: Vec<f64> = p.iter().map(|&x| 2.0 * (x as f64 - 3.0)).collect();
            adam.step(&mut p, &mut s, &g, 0.05, it);
        }
        for &x in &p {
            assert!((x - 3.0).abs() < 0.05, "{x}");
        }
    }

    #[test]
    fn first_step_is_lr_sized() {
        // With bias correction, |Δp| ≈ lr on step one regardless of |g|.
        let adam = Adam::default();
        for g0 in [1e-4, 1.0, 1e4] {
            let mut p = vec![0.0f32];
            let mut s = vec![0.0f32; 2];
            adam.step(&mut p, &mut s, &[g0], 0.01, 0);
            assert!(
                (p[0].abs() as f64 - 0.01).abs() < 1e-3,
                "g0={g0} -> Δp {}",
                p[0]
            );
        }
    }

    #[test]
    fn rejects_mismatched_sizes() {
        let adam = Adam::default();
        let mut p = vec![0.0f32; 2];
        let mut s = vec![0.0f32; 4];
        let result = std::panic::catch_unwind(move || {
            adam.step(&mut p, &mut s, &[1.0], 0.01, 0);
        });
        assert!(result.is_err());
    }
}
