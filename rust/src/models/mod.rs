//! Native model substrate: flat-parameter networks + optimizers.
//!
//! The PJRT path lowers models/optimizers to HLO at build time; this
//! module is their pure-Rust counterpart so the native backend
//! (`runtime::native`) can train without artifacts.  Everything operates
//! on flat vectors — parameters are `[W_0 | b_0 | ...]` slices viewed
//! through [`Mlp`], optimizer state is `[m | v]` through [`Adam`] — so
//! `runtime::TrainState` is backend-agnostic.
//!
//! The FLOP-dominant inner loops live in [`kernels`] (DESIGN.md §Perf):
//! cache-blocked, lane-vectorized batched GEMM + VJP kernels behind
//! [`Mlp::forward_batch`] / [`Mlp::vjp_batch`] (one pass per layer over a
//! flat `[rows × dim]` scratch, [`MlpBatchScratch`]), and the fused RK
//! stage-combine the ODE stepper calls once per attempt.  The per-row
//! scalar [`Mlp::forward`] / [`Mlp::vjp`] pair is the retained reference,
//! reachable through the `kernels::set_scalar_fallback` ablation knob.

pub mod adam;
pub mod kernels;
pub mod mlp;

pub use adam::Adam;
pub use mlp::{Mlp, MlpBatchScratch, MlpScratch};
