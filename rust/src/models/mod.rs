//! Native model substrate: flat-parameter networks + optimizers.
//!
//! The PJRT path lowers models/optimizers to HLO at build time; this
//! module is their pure-Rust counterpart so the native backend
//! (`runtime::native`) can train without artifacts.  Everything operates
//! on flat vectors — parameters are `[W_0 | b_0 | ...]` slices viewed
//! through [`Mlp`], optimizer state is `[m | v]` through [`Adam`] — so
//! `runtime::TrainState` is backend-agnostic.

pub mod adam;
pub mod mlp;

pub use adam::Adam;
pub use mlp::{Mlp, MlpScratch};
