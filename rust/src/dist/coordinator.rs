//! The data-parallel training coordinator: shard → evaluate → reduce →
//! update, behind the ordinary [`Backend`] seam.
//!
//! [`DistBackend`] wraps the in-process [`NativeBackend`] and implements
//! [`Backend`], so every experiment driver (`coordinator::experiments`)
//! and the budget-ladder router run **unchanged** on top of it.  Its
//! `train_step`:
//!
//!  1. splits the batch into `shards` deterministic contiguous item
//!     ranges ([`ShardPlan::by_count`]),
//!  2. evaluates each occupied shard's gradient through a
//!     [`GradExecutor`] — in-process ([`LocalExecutor`]) or on remote
//!     workers over the dist protocol ([`RemoteExecutor`]),
//!  3. reduces the shard gradients in a **fixed binary tree over shard
//!     indices** (widened to f64, weighted by item fraction), and
//!  4. applies one Adam update to the coordinator-owned optimizer
//!     state.
//!
//! ## Bit-determinism guarantee (DESIGN.md §Distributed)
//!
//! At equal shard count, remote and local execution produce
//! **bit-identical** parameters and metrics: shard assignment is a pure
//! function of the shard index (`shard % workers`), the per-shard RNG
//! seed derives only from `(step seed, shard index)`
//! ([`shard_seed`]), f32 tensors cross the wire bit-exactly, and the
//! reduction tree's shape and evaluation order depend only on the shard
//! count — never on scheduling, worker count, or retry history.  With
//! one shard, `DistBackend` reproduces the plain
//! [`NativeBackend::train_step`] bit-for-bit (the leaf weight is
//! exactly 1.0).
//!
//! ## Failure handling
//!
//! Transport failures (connect/read/write/timeout, frame corruption)
//! mark the worker dead **for the rest of the current optimizer step**
//! and the shard is **reassigned** to the next live worker in fixed
//! ring order — a deterministic recompute, so the bits are unaffected.
//! Dead-marks reset at the next step ([`GradExecutor::begin_step`]), so
//! a worker that was restarted or merely blew one
//! [`RemoteOpts::request_timeout`] rejoins the fleet instead of one
//! transient slowdown cascading into [`DistError::WorkersExhausted`]
//! against a healthy fleet.  When every worker has failed a shard
//! within a step, the step fails with a typed [`DistError`], which the
//! experiment driver surfaces as a typed epoch failure.  Every read is
//! bounded by a timeout, so the coordinator never hangs on a dead
//! worker.  *Solver* failures (budget exhausted, non-finite state) are
//! not transport failures: they ride back inside [`Metrics`] for the
//! budget router to escalate or skip, exactly as in single-process
//! training.

use std::fmt;
use std::io::{BufRead, BufReader, ErrorKind, Write};
use std::net::{TcpStream, ToSocketAddrs};
use std::ops::Range;
use std::sync::Mutex;
use std::time::{Duration, Instant};

use anyhow::{bail, ensure, Context, Result};

use super::protocol::{
    data_frames, frame, frames_for_kind, read_frame_patient, DistRequest, DistResponse, Frame,
    FrameError,
};
use super::sharder::ShardPlan;
use crate::models::Adam;
use crate::obs::metrics;
use crate::runtime::{
    Backend, ExportedState, GradOutput, Metrics, ModelInfo, NativeBackend, StepCoefs, StepOutput,
    TrainData, TrainState,
};
use crate::solvers::error::SolveErrorKind;
use crate::util::threadpool::map_bounded;

/// Typed failure of the distributed step — what an epoch fails with
/// when the fleet cannot produce a gradient.
#[derive(Clone, Debug, PartialEq)]
pub enum DistError {
    /// Shard `shard` was offered to every configured worker and all of
    /// them failed it (`last` is the final failure).
    WorkersExhausted {
        shard: usize,
        workers: usize,
        last: String,
    },
}

impl fmt::Display for DistError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            DistError::WorkersExhausted {
                shard,
                workers,
                last,
            } => write!(
                f,
                "shard {shard} failed on all {workers} workers (last: {last})"
            ),
        }
    }
}

impl std::error::Error for DistError {}

/// Per-shard RNG seed: a pure function of the step seed and the shard
/// index.  Shard 0 keeps the step seed unchanged, so a 1-shard plan
/// draws exactly the single-process stream.
pub fn shard_seed(step_seed: u32, shard: usize) -> u32 {
    step_seed.wrapping_add((shard as u32).wrapping_mul(0x9E37_79B9))
}

/// Where shard gradients are evaluated.  Implementations must be
/// deterministic in `(shard, params, data, coefs)` — the coordinator
/// relies on replays (after worker reassignment) reproducing the same
/// bits.
pub trait GradExecutor: Send + Sync {
    /// Called once at the start of every optimizer step, before the
    /// shard fan-out.  Remote executors use it to clear per-step
    /// dead-marks so a transiently slow or restarted worker rejoins
    /// the fleet at the next step instead of staying lost for the run.
    fn begin_step(&self) {}

    /// Evaluate one shard's gradient at `params`.  Transport-level
    /// failures are `Err`; solver failures ride inside the returned
    /// metric block.
    #[allow(clippy::too_many_arguments)]
    fn shard_grad(
        &self,
        local: &NativeBackend,
        shard: usize,
        model: &str,
        tay: bool,
        rung: usize,
        params: &[f32],
        data: &TrainData,
        coefs: &StepCoefs,
    ) -> Result<GradOutput>;

    /// Human-readable placement (for logs/benches).
    fn describe(&self) -> String;
}

/// In-process execution: the single-process baseline the equivalence
/// tests compare against, and the `--shards N` CLI path.
pub struct LocalExecutor;

impl GradExecutor for LocalExecutor {
    fn shard_grad(
        &self,
        local: &NativeBackend,
        _shard: usize,
        model: &str,
        tay: bool,
        rung: usize,
        params: &[f32],
        data: &TrainData,
        coefs: &StepCoefs,
    ) -> Result<GradOutput> {
        let state = TrainState {
            params: params.to_vec(),
            opt_state: vec![],
            iter: 0,
        };
        local.grad_step(model, tay, rung, &state, data, coefs)
    }

    fn describe(&self) -> String {
        "local".to_string()
    }
}

/// Remote execution policy knobs.
#[derive(Clone, Copy, Debug)]
pub struct RemoteOpts {
    /// Per-worker TCP connect bound.
    pub connect_timeout: Duration,
    /// End-to-end bound on one shard request (solve time included).
    /// Must comfortably exceed the worst-case shard solve time: a
    /// request that blows this deadline counts as a transport failure,
    /// skipping the worker for the rest of the step (it is retried at
    /// the next one) while the shard recomputes on a ring sibling.
    pub request_timeout: Duration,
    /// Poll tick for response reads within the request timeout.
    pub read_tick: Duration,
}

impl Default for RemoteOpts {
    fn default() -> Self {
        RemoteOpts {
            connect_timeout: Duration::from_secs(2),
            request_timeout: Duration::from_secs(120),
            read_tick: Duration::from_millis(50),
        }
    }
}

/// One persistent worker connection (lazily established).
struct WorkerConn {
    addr: String,
    client: Option<FrameClient>,
    dead: bool,
}

/// What a worker answered: a gradient, or a request-level error (the
/// worker is healthy — the *request* was refused deterministically).
enum WorkerReply {
    Grad(GradOutput),
    AppError(String),
}

/// A line + frame client over one TCP stream.
struct FrameClient {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl FrameClient {
    fn connect(addr: &str, opts: &RemoteOpts) -> Result<FrameClient> {
        let mut last: Option<std::io::Error> = None;
        for sa in addr
            .to_socket_addrs()
            .with_context(|| format!("resolving worker address {addr:?}"))?
        {
            match TcpStream::connect_timeout(&sa, opts.connect_timeout) {
                Ok(stream) => {
                    stream
                        .set_read_timeout(Some(opts.read_tick.max(Duration::from_millis(1))))?;
                    let reader = BufReader::new(stream.try_clone()?);
                    return Ok(FrameClient {
                        reader,
                        writer: stream,
                    });
                }
                Err(e) => last = Some(e),
            }
        }
        bail!("connecting worker {addr:?} failed: {last:?}")
    }

    /// Read one response line, tolerating poll ticks until `deadline`.
    fn read_line_deadline(&mut self, deadline: Instant) -> Result<String> {
        let mut line = String::new();
        loop {
            match self.reader.read_line(&mut line) {
                Ok(0) => bail!("worker closed the connection"),
                Ok(_) => return Ok(line),
                Err(e) if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut) => {
                    ensure!(
                        Instant::now() < deadline,
                        "timed out waiting for worker response"
                    );
                }
                Err(e) if e.kind() == ErrorKind::Interrupted => {}
                Err(e) => return Err(e.into()),
            }
        }
    }

    /// One grad_step exchange.  `Err` means the connection can no
    /// longer be trusted (transport/protocol failure).
    fn grad_step(
        &mut self,
        req: &DistRequest,
        params: &[f32],
        data: &TrainData,
        deadline: Instant,
    ) -> Result<WorkerReply> {
        let mut line = req.encode();
        line.push('\n');
        let mut sent = line.len() as u64;
        self.writer.write_all(line.as_bytes())?;
        let pframe = Frame::f32(frame::PARAMS, params.to_vec());
        sent += pframe.wire_len() as u64;
        pframe.write_to(&mut self.writer)?;
        for f in data_frames(data) {
            sent += f.wire_len() as u64;
            f.write_to(&mut self.writer)?;
        }
        self.writer.flush()?;
        metrics::registry()
            .counter("regnde_dist_bytes_sent_total")
            .add(sent);
        let resp = self.read_line_deadline(deadline)?;
        match DistResponse::decode(resp.trim())? {
            DistResponse::Grad { success, kind } => {
                let keep = || Instant::now() < deadline;
                let g = read_frame_patient(&mut self.reader, keep)?;
                let m = read_frame_patient(&mut self.reader, keep)?;
                metrics::registry()
                    .counter("regnde_dist_bytes_received_total")
                    .add((resp.len() + g.wire_len() + m.wire_len()) as u64);
                Ok(WorkerReply::Grad(GradOutput {
                    grad: g.expect_f32(frame::GRAD)?.to_vec(),
                    metrics: m.to_metrics(success, kind)?,
                }))
            }
            DistResponse::Error { msg, kind } => Ok(WorkerReply::AppError(match kind {
                Some(k) => format!("{msg} [{}]", k.as_str()),
                None => msg,
            })),
            DistResponse::Closing => bail!("worker is shutting down"),
        }
    }
}

/// Remote execution over the dist protocol: fixed shard→worker
/// assignment (`shard % workers`), ring-order reassignment on worker
/// failure, every read bounded by [`RemoteOpts`].
pub struct RemoteExecutor {
    conns: Vec<Mutex<WorkerConn>>,
    opts: RemoteOpts,
}

impl RemoteExecutor {
    pub fn new(workers: &[String], opts: RemoteOpts) -> Result<RemoteExecutor> {
        ensure!(!workers.is_empty(), "need at least one worker address");
        Ok(RemoteExecutor {
            conns: workers
                .iter()
                .map(|a| {
                    Mutex::new(WorkerConn {
                        addr: a.clone(),
                        client: None,
                        dead: false,
                    })
                })
                .collect(),
            opts,
        })
    }

    /// Workers not marked dead within the current optimizer step
    /// (marks reset at the next [`GradExecutor::begin_step`]).
    pub fn live_workers(&self) -> usize {
        self.conns
            .iter()
            .filter(|c| !c.lock().unwrap_or_else(|p| p.into_inner()).dead)
            .count()
    }
}

impl GradExecutor for RemoteExecutor {
    fn begin_step(&self) {
        // Dead-marks are scoped to one optimizer step: within a step a
        // failed worker is skipped by every later shard (no repeated
        // timeouts), but the next step offers it one fresh connection
        // attempt.  Reassignment stays deterministic either way, so
        // revival cannot change any bits — only availability.
        for slot in &self.conns {
            slot.lock().unwrap_or_else(|p| p.into_inner()).dead = false;
        }
    }

    fn shard_grad(
        &self,
        _local: &NativeBackend,
        shard: usize,
        model: &str,
        tay: bool,
        rung: usize,
        params: &[f32],
        data: &TrainData,
        coefs: &StepCoefs,
    ) -> Result<GradOutput> {
        let n = self.conns.len();
        let start = shard % n.max(1);
        let mut last = "no live workers".to_string();
        // Fixed ring order: home worker first, then each successor once.
        // A reassigned shard recomputes the identical request, so the
        // result bits do not depend on which worker answered.
        for k in 0..n {
            let Some(slot) = self.conns.get((start + k) % n) else {
                continue;
            };
            let mut conn = slot.lock().unwrap_or_else(|p| p.into_inner());
            if conn.dead {
                continue;
            }
            if conn.client.is_none() {
                match FrameClient::connect(&conn.addr, &self.opts) {
                    Ok(c) => conn.client = Some(c),
                    Err(e) => {
                        conn.dead = true;
                        metrics::registry()
                            .counter(&metrics::labeled(
                                "regnde_dist_dead_marks_total",
                                "worker",
                                &conn.addr,
                            ))
                            .inc();
                        last = format!("{e:#}");
                        continue;
                    }
                }
            }
            if k > 0 {
                // The shard's home worker did not answer: this attempt
                // is a ring reassignment (deterministic recompute).
                metrics::registry()
                    .counter("regnde_dist_reassignments_total")
                    .inc();
            }
            let req = DistRequest::GradStep {
                model: model.to_string(),
                tay,
                rung,
                coefs: *coefs,
                kind: data.kind().to_string(),
                frames: frames_for_kind(data.kind())?,
            };
            let deadline = Instant::now() + self.opts.request_timeout;
            let Some(client) = conn.client.as_mut() else {
                continue;
            };
            let t0 = Instant::now();
            let reply = client.grad_step(&req, params, data, deadline);
            metrics::registry()
                .histogram(
                    &metrics::labeled("regnde_dist_rtt_seconds", "worker", &conn.addr),
                    &metrics::LATENCY_BUCKETS,
                )
                .observe(t0.elapsed().as_secs_f64());
            match reply {
                Ok(WorkerReply::Grad(out)) => return Ok(out),
                Ok(WorkerReply::AppError(msg)) => {
                    // The worker is healthy; the request failed
                    // deterministically.  Trying siblings gives a
                    // different fleet the chance to disagree, then the
                    // step fails typed.
                    last = msg;
                }
                Err(e) => {
                    // Transport failure: skip this worker for the rest
                    // of the *step* (begin_step revives it) and
                    // reassign to the next in the ring.
                    if matches!(e.downcast_ref::<FrameError>(), Some(FrameError::Checksum)) {
                        metrics::registry()
                            .counter("regnde_dist_checksum_failures_total")
                            .inc();
                    }
                    conn.dead = true;
                    conn.client = None;
                    metrics::registry()
                        .counter(&metrics::labeled(
                            "regnde_dist_dead_marks_total",
                            "worker",
                            &conn.addr,
                        ))
                        .inc();
                    last = format!("{e:#}");
                }
            }
        }
        Err(DistError::WorkersExhausted {
            shard,
            workers: n,
            last,
        }
        .into())
    }

    fn describe(&self) -> String {
        format!("remote({} workers)", self.conns.len())
    }
}

/// Owned per-shard slice of a [`TrainData`] batch.
enum ShardData {
    Trajectory { data: Vec<f32>, ts: Vec<f32> },
    Moments { u0: Vec<f32>, mu: Vec<f32>, var: Vec<f32>, ts: Vec<f32> },
    Classify { x: Vec<f32>, y: Vec<f32> },
    Series { x: Vec<f32>, mask: Vec<f32>, ts: Vec<f32> },
}

/// Rows `range` of a `[items, width]` row-major tensor.
fn slice_rows(v: &[f32], items: usize, range: &Range<usize>) -> Result<Vec<f32>> {
    ensure!(
        items > 0 && v.len() % items == 0,
        "tensor length {} is not divisible into {items} items",
        v.len()
    );
    let w = v.len() / items;
    match v.get(range.start * w..range.end * w) {
        Some(s) => Ok(s.to_vec()),
        None => bail!("shard range {range:?} out of bounds for {items} items"),
    }
}

impl ShardData {
    fn slice(data: &TrainData, items: usize, range: &Range<usize>) -> Result<ShardData> {
        Ok(match data {
            // Whole-batch payloads are one item: the only occupied shard
            // carries the full tensors.
            TrainData::Trajectory { data, ts } => {
                ensure!(*range == (0..items), "trajectory data is unsplittable");
                ShardData::Trajectory {
                    data: data.to_vec(),
                    ts: ts.to_vec(),
                }
            }
            TrainData::Moments { u0, mu, var, ts } => {
                ensure!(*range == (0..items), "moments data is unsplittable");
                ShardData::Moments {
                    u0: u0.to_vec(),
                    mu: mu.to_vec(),
                    var: var.to_vec(),
                    ts: ts.to_vec(),
                }
            }
            TrainData::Classify { x, y } => ShardData::Classify {
                x: slice_rows(x, items, range)?,
                y: slice_rows(y, items, range)?,
            },
            TrainData::Series { x, mask, ts } => ShardData::Series {
                x: slice_rows(x, items, range)?,
                mask: slice_rows(mask, items, range)?,
                ts: ts.to_vec(),
            },
        })
    }

    fn view(&self) -> TrainData<'_> {
        match self {
            ShardData::Trajectory { data, ts } => TrainData::Trajectory { data, ts },
            ShardData::Moments { u0, mu, var, ts } => TrainData::Moments { u0, mu, var, ts },
            ShardData::Classify { x, y } => TrainData::Classify { x, y },
            ShardData::Series { x, mask, ts } => TrainData::Series { x, mask, ts },
        }
    }
}

/// One reduction-tree node: the weighted f64 partial gradient plus the
/// combined metric block.
struct Reduced {
    grad: Vec<f64>,
    loss: f64,
    metric: f64,
    nfe: f64,
    naccept: f64,
    nreject: f64,
    r_e: f64,
    r_e2: f64,
    r_s: f64,
    r_l: f64,
    r_aux: f64,
    success: bool,
    error: Option<SolveErrorKind>,
}

/// Leaf of the reduction tree: widen the shard's f32 gradient to f64
/// and scale by its item fraction.  Loss/metric/regularizers combine as
/// weighted means (weights sum to 1); solver-work counters sum
/// unweighted; `success` ANDs; `error` keeps the lowest shard index.
fn leaf(w: f64, out: &GradOutput) -> Reduced {
    let m = &out.metrics;
    Reduced {
        grad: out.grad.iter().map(|&g| g as f64 * w).collect(),
        loss: w * m.loss,
        metric: w * m.metric,
        nfe: m.nfe,
        naccept: m.naccept,
        nreject: m.nreject,
        r_e: w * m.r_e,
        r_e2: w * m.r_e2,
        r_s: w * m.r_s,
        r_l: w * m.r_l,
        r_aux: w * m.r_aux,
        success: m.success,
        error: m.error,
    }
}

fn combine(mut a: Reduced, b: Reduced) -> Reduced {
    for (x, y) in a.grad.iter_mut().zip(&b.grad) {
        *x += *y;
    }
    a.loss += b.loss;
    a.metric += b.metric;
    a.nfe += b.nfe;
    a.naccept += b.naccept;
    a.nreject += b.nreject;
    a.r_e += b.r_e;
    a.r_e2 += b.r_e2;
    a.r_s += b.r_s;
    a.r_l += b.r_l;
    a.r_aux += b.r_aux;
    a.success &= b.success;
    // `or` keeps the earlier (lower shard index) error: deterministic
    // because the tree combines strictly in shard-index order.
    a.error = a.error.or(b.error);
    a
}

/// Fixed binary-tree reduction over shard-index-ordered leaves:
/// `((0,1),(2,3)) → (01,23) → ...`.  The tree shape is a pure function
/// of the leaf count, so the floating-point combination order — and
/// therefore every output bit — is identical on every run and every
/// placement.
fn reduce_tree(mut level: Vec<Reduced>, n_params: usize) -> Reduced {
    while level.len() > 1 {
        let mut next = Vec::with_capacity(level.len().div_ceil(2));
        let mut it = level.into_iter();
        while let Some(a) = it.next() {
            match it.next() {
                Some(b) => next.push(combine(a, b)),
                None => next.push(a),
            }
        }
        level = next;
    }
    match level.pop() {
        Some(r) => r,
        // Unreachable (callers ensure ≥ 1 leaf); keep it total.
        None => Reduced {
            grad: vec![0.0; n_params],
            loss: 0.0,
            metric: 0.0,
            nfe: 0.0,
            naccept: 0.0,
            nreject: 0.0,
            r_e: 0.0,
            r_e2: 0.0,
            r_s: 0.0,
            r_l: 0.0,
            r_aux: 0.0,
            success: false,
            error: None,
        },
    }
}

/// The distributed training backend (see module docs).
pub struct DistBackend {
    inner: NativeBackend,
    exec: Box<dyn GradExecutor>,
    shards: usize,
}

impl DistBackend {
    /// Single-process sharded execution — the equivalence baseline and
    /// the `--shards N` CLI path.
    pub fn local(inner: NativeBackend, shards: usize) -> DistBackend {
        DistBackend {
            inner,
            exec: Box::new(LocalExecutor),
            shards: shards.max(1),
        }
    }

    /// Remote execution over `workers`.  `shards` defaults to the
    /// worker count (one shard per worker).
    pub fn remote(
        inner: NativeBackend,
        workers: &[String],
        shards: Option<usize>,
        opts: RemoteOpts,
    ) -> Result<DistBackend> {
        let exec = RemoteExecutor::new(workers, opts)?;
        Ok(DistBackend {
            inner,
            exec: Box::new(exec),
            shards: shards.unwrap_or(workers.len()).max(1),
        })
    }

    /// Wrap a custom executor (test seam).
    pub fn with_executor(
        inner: NativeBackend,
        exec: Box<dyn GradExecutor>,
        shards: usize,
    ) -> DistBackend {
        DistBackend {
            inner,
            exec,
            shards: shards.max(1),
        }
    }

    pub fn shards(&self) -> usize {
        self.shards
    }

    /// Placement description for logs/benches.
    pub fn describe(&self) -> String {
        format!("{} × {} shards", self.exec.describe(), self.shards)
    }

    /// Shard, evaluate, and tree-reduce one gradient; the f64 result
    /// feeds Adam directly (no re-rounding between reduce and update).
    fn sharded_grad(
        &self,
        model: &str,
        tay: bool,
        rung: usize,
        state: &TrainState,
        data: &TrainData,
        coefs: &StepCoefs,
    ) -> Result<(Vec<f64>, Metrics)> {
        self.exec.begin_step();
        let items = self.inner.shard_items(model, data)?;
        let plan = ShardPlan::by_count(items, self.shards);
        let jobs: Vec<(usize, Range<usize>)> = plan.occupied().collect();
        ensure!(!jobs.is_empty(), "no occupied shards over {items} items");
        // Slice up front (cheap, serial, deterministic) so the parallel
        // section only runs solver work.
        let mut sliced = Vec::with_capacity(jobs.len());
        for (idx, range) in &jobs {
            sliced.push((*idx, range.len(), ShardData::slice(data, items, range)?));
        }
        let results: Vec<Result<Reduced>> = map_bounded(
            self.shards.max(1),
            sliced,
            |(idx, len, sd): (usize, usize, ShardData)| {
                let shard_coefs = StepCoefs {
                    seed: shard_seed(coefs.seed, idx),
                    ..*coefs
                };
                let out = self.exec.shard_grad(
                    &self.inner,
                    idx,
                    model,
                    tay,
                    rung,
                    &state.params,
                    &sd.view(),
                    &shard_coefs,
                )?;
                ensure!(
                    out.grad.len() == state.params.len(),
                    "shard {idx} returned a gradient of {} values, expected {}",
                    out.grad.len(),
                    state.params.len()
                );
                Ok(leaf(len as f64 / items as f64, &out))
            },
        );
        let mut leaves = Vec::with_capacity(results.len());
        for r in results {
            // First failure in shard-index order wins (deterministic).
            leaves.push(r?);
        }
        let red = {
            crate::span!("all_reduce", "dist");
            reduce_tree(leaves, state.params.len())
        };
        let metrics = Metrics {
            loss: red.loss,
            metric: red.metric,
            nfe: red.nfe,
            naccept: red.naccept,
            nreject: red.nreject,
            success: red.success,
            error: red.error,
            r_e: red.r_e,
            r_e2: red.r_e2,
            r_s: red.r_s,
            r_l: red.r_l,
            r_aux: red.r_aux,
        };
        Ok((red.grad, metrics))
    }
}

impl Backend for DistBackend {
    fn name(&self) -> &'static str {
        "dist"
    }

    fn models(&self) -> Vec<String> {
        self.inner.models()
    }

    fn model(&self, model: &str) -> Result<ModelInfo> {
        self.inner.model(model)
    }

    fn ladder(&self, model: &str, tay: bool) -> Result<Vec<usize>> {
        self.inner.ladder(model, tay)
    }

    fn init_params(&self, model: &str, seed: u32) -> Result<Vec<f32>> {
        self.inner.init_params(model, seed)
    }

    fn warm(&self, model: &str, tay: bool) -> Result<()> {
        self.inner.warm(model, tay)
    }

    fn train_step(
        &self,
        model: &str,
        tay: bool,
        rung: usize,
        state: &TrainState,
        data: &TrainData,
        coefs: &StepCoefs,
    ) -> Result<StepOutput> {
        let t0 = Instant::now();
        let (grad, step_metrics) = self.sharded_grad(model, tay, rung, state, data, coefs)?;
        let mut params = state.params.clone();
        let mut opt_state = state.opt_state.clone();
        {
            crate::span!("optimizer", "dist");
            Adam::default().step(
                &mut params,
                &mut opt_state,
                &grad,
                coefs.lr as f64,
                state.iter,
            );
        }
        // Pure reads — the gauges never feed back into the update, so
        // the dist/native bit-equivalence suites pass untouched.
        let mut grad_sq = 0.0f64;
        for g in &grad {
            grad_sq += g * g;
        }
        metrics::note_train_step(
            model,
            step_metrics.loss,
            step_metrics.r_e,
            step_metrics.r_s,
            grad_sq.sqrt(),
            t0.elapsed().as_secs_f64(),
        );
        Ok(StepOutput {
            params,
            opt_state,
            metrics: step_metrics,
        })
    }

    /// The sharded gradient, rounded to the f32 seam dtype.  (The
    /// internal `train_step` path keeps the reduced gradient in f64 all
    /// the way into Adam — with one shard both views coincide.)
    fn grad_step(
        &self,
        model: &str,
        tay: bool,
        rung: usize,
        state: &TrainState,
        data: &TrainData,
        coefs: &StepCoefs,
    ) -> Result<GradOutput> {
        let (grad, metrics) = self.sharded_grad(model, tay, rung, state, data, coefs)?;
        Ok(GradOutput {
            grad: grad.iter().map(|&g| g as f32).collect(),
            metrics,
        })
    }

    fn shard_items(&self, model: &str, data: &TrainData) -> Result<usize> {
        self.inner.shard_items(model, data)
    }

    fn predict(
        &self,
        model: &str,
        params: &[f32],
        data: &TrainData,
        seed: u32,
    ) -> Result<(Vec<f32>, Metrics)> {
        self.inner.predict(model, params, data, seed)
    }

    fn export_state(&self, model: &str, params: &[f32]) -> Result<ExportedState> {
        self.inner.export_state(model, params)
    }

    fn import_state(&self, state: &ExportedState) -> Result<Vec<f32>> {
        self.inner.import_state(state)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::experiments::spiral_node;

    fn spiral_setup() -> (NativeBackend, Vec<f32>, Vec<f32>, Vec<f32>) {
        let be = NativeBackend::new();
        let params = be.init_params("spiral_node", 7).unwrap();
        let (truth, ts) = spiral_node::ground_truth();
        (be, params, truth, ts)
    }

    #[test]
    fn one_shard_matches_plain_train_step_bitwise() {
        let (be, params, truth, ts) = spiral_setup();
        let info = be.model("spiral_node").unwrap();
        let data = TrainData::Trajectory {
            data: &truth,
            ts: &ts,
        };
        let coefs = StepCoefs {
            coef_e: 0.1,
            seed: 99,
            ..Default::default()
        };
        let state = TrainState::new(params.clone(), info.opt_state_size);
        let plain = be.train_step("spiral_node", false, 0, &state, &data, &coefs).unwrap();
        let dist = DistBackend::local(NativeBackend::new(), 1);
        let sharded = dist
            .train_step("spiral_node", false, 0, &state, &data, &coefs)
            .unwrap();
        assert_eq!(plain.params, sharded.params, "1-shard params must be bit-identical");
        assert_eq!(plain.opt_state, sharded.opt_state);
        assert_eq!(plain.metrics.loss.to_bits(), sharded.metrics.loss.to_bits());
        assert_eq!(plain.metrics.nfe, sharded.metrics.nfe);
    }

    #[test]
    fn unsplittable_data_tolerates_extra_shards_bitwise() {
        // Trajectory fits are 1 item: with 4 shards only shard 0 is
        // occupied, so the result must equal the 1-shard plan exactly.
        let (be, params, truth, ts) = spiral_setup();
        let info = be.model("spiral_node").unwrap();
        let data = TrainData::Trajectory {
            data: &truth,
            ts: &ts,
        };
        let coefs = StepCoefs {
            seed: 5,
            ..Default::default()
        };
        let state = TrainState::new(params, info.opt_state_size);
        let one = DistBackend::local(NativeBackend::new(), 1)
            .train_step("spiral_node", false, 0, &state, &data, &coefs)
            .unwrap();
        let four = DistBackend::local(NativeBackend::new(), 4)
            .train_step("spiral_node", false, 0, &state, &data, &coefs)
            .unwrap();
        assert_eq!(one.params, four.params);
        assert_eq!(one.metrics.nfe, four.metrics.nfe);
    }

    #[test]
    fn sharded_step_is_deterministic_across_runs() {
        let be = NativeBackend::new();
        let info = be.model("mnist_node").unwrap();
        let params = be.init_params("mnist_node", 1).unwrap();
        // 4 rows of fake image data, one-hot labels.
        let b = 4;
        let x: Vec<f32> = (0..b * 784).map(|i| ((i % 17) as f32) / 17.0).collect();
        let mut y = vec![0.0f32; b * 10];
        for (r, row) in y.chunks_mut(10).enumerate() {
            row[r % 10] = 1.0;
        }
        let data = TrainData::Classify { x: &x, y: &y };
        let coefs = StepCoefs {
            t1: 1.0,
            seed: 1234,
            ..Default::default()
        };
        let state = TrainState::new(params, info.opt_state_size);
        let run = || {
            DistBackend::local(NativeBackend::new(), 2)
                .train_step("mnist_node", false, 0, &state, &data, &coefs)
                .unwrap()
        };
        let a = run();
        let b2 = run();
        assert_eq!(a.params, b2.params, "sharded step must be reproducible");
        assert_eq!(a.metrics.loss.to_bits(), b2.metrics.loss.to_bits());
        // Two occupied shards contribute solver work.
        assert!(a.metrics.nfe > 0.0);
    }

    #[test]
    fn shard_seed_is_identity_on_shard_zero() {
        assert_eq!(shard_seed(0xABCD, 0), 0xABCD);
        assert_ne!(shard_seed(0xABCD, 1), 0xABCD);
        assert_ne!(shard_seed(0xABCD, 1), shard_seed(0xABCD, 2));
    }

    #[test]
    fn failing_executor_surfaces_typed_dist_error() {
        struct AlwaysFails;
        impl GradExecutor for AlwaysFails {
            fn shard_grad(
                &self,
                _local: &NativeBackend,
                shard: usize,
                _model: &str,
                _tay: bool,
                _rung: usize,
                _params: &[f32],
                _data: &TrainData,
                _coefs: &StepCoefs,
            ) -> Result<GradOutput> {
                Err(DistError::WorkersExhausted {
                    shard,
                    workers: 0,
                    last: "synthetic".into(),
                }
                .into())
            }
            fn describe(&self) -> String {
                "always-fails".into()
            }
        }
        let (be, params, truth, ts) = spiral_setup();
        let info = be.model("spiral_node").unwrap();
        let state = TrainState::new(params, info.opt_state_size);
        let dist = DistBackend::with_executor(NativeBackend::new(), Box::new(AlwaysFails), 2);
        let err = dist
            .train_step(
                "spiral_node",
                false,
                0,
                &state,
                &TrainData::Trajectory {
                    data: &truth,
                    ts: &ts,
                },
                &StepCoefs::default(),
            )
            .expect_err("must fail typed");
        assert!(
            err.downcast_ref::<DistError>().is_some(),
            "epoch failure must carry a typed DistError: {err:#}"
        );
    }
}
