//! The gradient worker: `regnde worker --addr` — a TCP server answering
//! [`DistRequest::GradStep`] requests with shard gradients.
//!
//! Workers are **stateless** between requests: every request carries the
//! full parameter vector and its shard's data tensors, the worker runs
//! one [`Backend::grad_step`] (no optimizer update — the coordinator
//! owns the Adam state) and streams back the gradient + metric block.
//! Statelessness is what makes the coordinator's failure handling
//! simple: any shard can be replayed on any live worker and produce the
//! same bits (DESIGN.md §Distributed).
//!
//! Structure mirrors `serve::Server` (PR 5/6): one thread per
//! connection, poll-style read timeouts so an idle or half-dead
//! coordinator can never pin a thread past shutdown, draining `shutdown`
//! op, bounded connection count.  The one new wrinkle is the binary
//! frame stream after each control line: a read that dies *mid-frame*
//! desynchronizes the connection, so frame-level failures answer one
//! typed error line (when possible) and close — they never try to
//! resync.

use std::io::{self, BufRead, BufReader, ErrorKind, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::{Context, Result};

use super::protocol::{
    data_from_frames, frame, frames_for_kind, read_frame_patient, DistRequest, DistResponse,
    Frame,
};
use crate::obs::metrics;
use crate::runtime::{Backend, StepCoefs, TrainState};

/// Per-worker policy knobs.
#[derive(Clone, Copy, Debug)]
pub struct WorkerOpts {
    /// Poll tick for connection reads (drain-flag latency bound).
    pub read_timeout: Duration,
    /// Most connections served concurrently; excess connections are
    /// answered with one error line and closed.
    pub max_conns: usize,
}

impl Default for WorkerOpts {
    fn default() -> Self {
        WorkerOpts {
            read_timeout: Duration::from_millis(250),
            max_conns: 16,
        }
    }
}

/// The gradient worker server.
pub struct Worker {
    backend: Arc<dyn Backend + Send + Sync>,
    opts: WorkerOpts,
    shutdown: AtomicBool,
    active_conns: AtomicUsize,
}

/// Occupancy guard: frees the connection slot even if the handler
/// thread panics.
struct ConnSlot<'a>(&'a AtomicUsize);

impl Drop for ConnSlot<'_> {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::SeqCst);
    }
}

impl Worker {
    pub fn new(backend: Arc<dyn Backend + Send + Sync>, opts: WorkerOpts) -> Worker {
        Worker {
            backend,
            opts,
            shutdown: AtomicBool::new(false),
            active_conns: AtomicUsize::new(0),
        }
    }

    /// Serve until a `shutdown` request arrives (or [`WorkerHandle`]
    /// aborts), then join every connection thread before returning.
    pub fn serve(self: &Arc<Self>, listener: TcpListener) -> Result<()> {
        let addr = listener.local_addr()?;
        let mut handles: Vec<std::thread::JoinHandle<()>> = Vec::new();
        for stream in listener.incoming() {
            if self.shutdown.load(Ordering::SeqCst) {
                break;
            }
            let Ok(stream) = stream else { continue };
            handles.retain(|h| !h.is_finished());
            if self.active_conns.fetch_add(1, Ordering::SeqCst) >= self.opts.max_conns {
                self.active_conns.fetch_sub(1, Ordering::SeqCst);
                let mut stream = stream;
                let mut out = DistResponse::error("worker connection limit reached").encode();
                out.push('\n');
                let _ = stream.write_all(out.as_bytes());
                continue;
            }
            let worker = Arc::clone(self);
            handles.push(std::thread::spawn(move || {
                let _slot = ConnSlot(&worker.active_conns);
                worker.handle_conn(stream, addr);
            }));
        }
        for h in handles {
            let _ = h.join();
        }
        Ok(())
    }

    /// Bind `addr` and serve on a background thread; returns a handle
    /// carrying the bound address (use port 0 for an ephemeral one).
    pub fn spawn(
        backend: Arc<dyn Backend + Send + Sync>,
        opts: WorkerOpts,
        addr: &str,
    ) -> Result<WorkerHandle> {
        let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
        let bound = listener.local_addr()?;
        let worker = Arc::new(Worker::new(backend, opts));
        let thread = {
            let worker = Arc::clone(&worker);
            std::thread::spawn(move || {
                let _ = worker.serve(listener);
            })
        };
        Ok(WorkerHandle {
            addr: bound,
            worker,
            thread,
        })
    }

    fn handle_conn(&self, stream: TcpStream, server_addr: SocketAddr) {
        let _ = stream.set_read_timeout(Some(self.opts.read_timeout.max(Duration::from_millis(1))));
        let Ok(read_half) = stream.try_clone() else {
            return;
        };
        let mut reader = BufReader::new(read_half);
        let mut writer = stream;
        let mut line = String::new();
        loop {
            // read_line appends: a partial line interrupted by a poll
            // timeout stays in `line` and completes on a later tick.
            match reader.read_line(&mut line) {
                Ok(0) => return, // coordinator hung up
                Ok(_) => {}
                Err(e) if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut) => {
                    if self.shutdown.load(Ordering::SeqCst) {
                        return; // aborting / draining
                    }
                    continue;
                }
                Err(_) => return,
            }
            if line.trim().is_empty() {
                line.clear();
                continue;
            }
            let req = match DistRequest::decode(line.trim()) {
                Ok(r) => r,
                Err(e) => {
                    // A garbled grad_step line may have frames behind it
                    // that we cannot size: answer once and drop the
                    // connection rather than guess at resync.
                    let _ = respond(
                        &mut writer,
                        &DistResponse::error(format!("bad request: {e:#}")),
                        &[],
                    );
                    return;
                }
            };
            line.clear();
            match req {
                DistRequest::Shutdown => {
                    let _ = respond(&mut writer, &DistResponse::Closing, &[]);
                    self.shutdown.store(true, Ordering::SeqCst);
                    // Poke the accept loop so it observes the flag.
                    let _ = TcpStream::connect(server_addr);
                    return;
                }
                DistRequest::GradStep {
                    model,
                    tay,
                    rung,
                    coefs,
                    kind,
                    frames,
                } => {
                    // Validate the declared frame count against the kind
                    // BEFORE reading any frame: a mismatch would leave
                    // the stream desynchronized.
                    let expected = match frames_for_kind(&kind) {
                        Ok(n) if n == frames => n,
                        Ok(n) => {
                            let _ = respond(
                                &mut writer,
                                &DistResponse::error(format!(
                                    "kind {kind:?} carries {n} data frames, request declared \
                                     {frames}"
                                )),
                                &[],
                            );
                            return;
                        }
                        Err(e) => {
                            let _ = respond(
                                &mut writer,
                                &DistResponse::error(format!("{e:#}")),
                                &[],
                            );
                            return;
                        }
                    };
                    let mut keep = || !self.shutdown.load(Ordering::SeqCst);
                    let mut read_f32 = |r: &mut BufReader<TcpStream>, ty: u8| -> Result<Vec<f32>> {
                        let f = read_frame_patient(r, &mut keep)?;
                        Ok(f.expect_f32(ty)?.to_vec())
                    };
                    let payload = (|| -> Result<(Vec<f32>, Vec<Vec<f32>>)> {
                        let params = read_f32(&mut reader, frame::PARAMS)?;
                        let mut tensors = Vec::with_capacity(expected);
                        for _ in 0..expected {
                            tensors.push(read_f32(&mut reader, frame::DATA)?);
                        }
                        Ok((params, tensors))
                    })();
                    let (params, tensors) = match payload {
                        Ok(p) => p,
                        Err(e) => {
                            // Mid-frame failure: the stream is dead.
                            let _ = respond(
                                &mut writer,
                                &DistResponse::error(format!("frame error: {e:#}")),
                                &[],
                            );
                            return;
                        }
                    };
                    let (resp, out_frames) =
                        self.evaluate(&model, tay, rung, &coefs, &kind, params, &tensors);
                    if respond(&mut writer, &resp, &out_frames).is_err() {
                        return;
                    }
                }
            }
        }
    }

    /// Run one shard gradient evaluation.  Solver failures (budget
    /// exhausted, non-finite state, ...) are *data*, not errors: they
    /// ride back inside the metric block for the coordinator's router,
    /// exactly as in single-process training.
    #[allow(clippy::too_many_arguments)]
    fn evaluate(
        &self,
        model: &str,
        tay: bool,
        rung: usize,
        coefs: &StepCoefs,
        kind: &str,
        params: Vec<f32>,
        tensors: &[Vec<f32>],
    ) -> (DistResponse, Vec<Frame>) {
        let data = match data_from_frames(kind, tensors) {
            Ok(d) => d,
            Err(e) => return (DistResponse::error(format!("{e:#}")), vec![]),
        };
        // grad_step never touches the optimizer state, so the worker's
        // replica carries an empty one (the coordinator owns Adam).
        let state = TrainState {
            params,
            opt_state: vec![],
            iter: 0,
        };
        let t0 = std::time::Instant::now();
        let result = self.backend.grad_step(model, tay, rung, &state, &data, coefs);
        metrics::registry()
            .counter("regnde_dist_worker_steps_total")
            .inc();
        metrics::registry()
            .histogram("regnde_dist_worker_step_seconds", &metrics::LATENCY_BUCKETS)
            .observe(t0.elapsed().as_secs_f64());
        match result {
            Ok(out) => (
                DistResponse::Grad {
                    success: out.metrics.success,
                    kind: out.metrics.error,
                },
                vec![Frame::f32(frame::GRAD, out.grad), Frame::metrics(&out.metrics)],
            ),
            Err(e) => (DistResponse::error(format!("grad_step failed: {e:#}")), vec![]),
        }
    }
}

/// One response: the JSON line, then any frames, then a flush.
fn respond(w: &mut TcpStream, resp: &DistResponse, frames: &[Frame]) -> io::Result<()> {
    let mut out = resp.encode();
    out.push('\n');
    let mut sent = out.len() as u64;
    w.write_all(out.as_bytes())?;
    for f in frames {
        sent += f.wire_len() as u64;
        f.write_to(w)?;
    }
    metrics::registry()
        .counter("regnde_dist_worker_bytes_sent_total")
        .add(sent);
    w.flush()
}

/// Handle to a spawned worker: its bound address plus abort/join
/// control.  Used by the CLI, the loopback tests, and the fault
/// harness.
pub struct WorkerHandle {
    pub addr: SocketAddr,
    worker: Arc<Worker>,
    thread: std::thread::JoinHandle<()>,
}

impl WorkerHandle {
    /// Abort the worker without draining: connection threads exit at
    /// their next poll tick *without answering* — from the
    /// coordinator's side this is indistinguishable from a crashed
    /// worker, which is exactly what the fault tests want.
    pub fn kill(self) {
        self.worker.shutdown.store(true, Ordering::SeqCst);
        // Poke the accept loop so it observes the flag.
        let _ = TcpStream::connect(self.addr);
        let _ = self.thread.join();
    }

    /// Wait for the worker to exit on its own (a `shutdown` request).
    pub fn join(self) {
        let _ = self.thread.join();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{GradOutput, NativeBackend};
    use crate::solvers::error::SolveErrorKind;

    fn spawn_worker() -> WorkerHandle {
        Worker::spawn(
            Arc::new(NativeBackend::new()),
            WorkerOpts {
                read_timeout: Duration::from_millis(20),
                ..Default::default()
            },
            "127.0.0.1:0",
        )
        .expect("spawn worker")
    }

    fn grad_request(model: &str, seed: u32) -> (DistRequest, Vec<Frame>) {
        let be = NativeBackend::new();
        let params = be.init_params(model, 3).unwrap();
        let (truth, ts) = crate::coordinator::experiments::spiral_node::ground_truth();
        let req = DistRequest::GradStep {
            model: model.into(),
            tay: false,
            rung: 0,
            coefs: StepCoefs {
                seed,
                ..Default::default()
            },
            kind: "trajectory".into(),
            frames: 2,
        };
        let frames = vec![
            Frame::f32(frame::PARAMS, params),
            Frame::f32(frame::DATA, truth),
            Frame::f32(frame::DATA, ts),
        ];
        (req, frames)
    }

    fn exchange(addr: &SocketAddr, req: &DistRequest, frames: &[Frame]) -> Result<GradOutput> {
        let stream = TcpStream::connect(addr)?;
        let mut reader = BufReader::new(stream.try_clone()?);
        let mut writer = stream;
        let mut line = req.encode();
        line.push('\n');
        writer.write_all(line.as_bytes())?;
        for f in frames {
            f.write_to(&mut writer)?;
        }
        writer.flush()?;
        let mut resp = String::new();
        reader.read_line(&mut resp)?;
        match DistResponse::decode(resp.trim())? {
            DistResponse::Grad { success, kind } => {
                let g = read_frame_patient(&mut reader, || true)?;
                let m = read_frame_patient(&mut reader, || true)?;
                Ok(GradOutput {
                    grad: g.expect_f32(frame::GRAD)?.to_vec(),
                    metrics: m.to_metrics(success, kind)?,
                })
            }
            other => anyhow::bail!("worker answered {other:?}"),
        }
    }

    #[test]
    fn loopback_grad_step_matches_in_process() {
        let handle = spawn_worker();
        let (req, frames) = grad_request("spiral_node", 42);
        let remote = exchange(&handle.addr, &req, &frames).expect("loopback grad");

        // The same evaluation in-process must be bit-identical.
        let be = NativeBackend::new();
        let params = frames[0].expect_f32(frame::PARAMS).unwrap().to_vec();
        let (truth, ts) = crate::coordinator::experiments::spiral_node::ground_truth();
        let state = TrainState {
            params,
            opt_state: vec![],
            iter: 0,
        };
        let local = be
            .grad_step(
                "spiral_node",
                false,
                0,
                &state,
                &crate::runtime::TrainData::Trajectory {
                    data: &truth,
                    ts: &ts,
                },
                &StepCoefs {
                    seed: 42,
                    ..Default::default()
                },
            )
            .unwrap();
        assert_eq!(remote.grad.len(), local.grad.len());
        for (a, b) in remote.grad.iter().zip(&local.grad) {
            assert_eq!(a.to_bits(), b.to_bits(), "wire must not perturb gradient bits");
        }
        assert_eq!(remote.metrics.loss.to_bits(), local.metrics.loss.to_bits());
        assert_eq!(remote.metrics.nfe, local.metrics.nfe);
        assert_eq!(remote.metrics.success, local.metrics.success);

        // Draining shutdown via the protocol.
        let stream = TcpStream::connect(handle.addr).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut writer = stream;
        writer
            .write_all(format!("{}\n", DistRequest::Shutdown.encode()).as_bytes())
            .unwrap();
        writer.flush().unwrap();
        let mut resp = String::new();
        reader.read_line(&mut resp).unwrap();
        assert_eq!(
            DistResponse::decode(resp.trim()).unwrap(),
            DistResponse::Closing
        );
        handle.join();
    }

    #[test]
    fn bad_requests_get_typed_errors() {
        let handle = spawn_worker();

        // Unknown op: one error line, connection closed.
        let stream = TcpStream::connect(handle.addr).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut writer = stream;
        writer.write_all(b"{\"op\":\"frobnicate\"}\n").unwrap();
        writer.flush().unwrap();
        let mut resp = String::new();
        reader.read_line(&mut resp).unwrap();
        assert!(matches!(
            DistResponse::decode(resp.trim()).unwrap(),
            DistResponse::Error { .. }
        ));

        // Frame-count mismatch is rejected before any frame is read.
        let (req, _) = grad_request("spiral_node", 1);
        let DistRequest::GradStep { model, coefs, .. } = req else {
            unreachable!()
        };
        let bad = DistRequest::GradStep {
            model,
            tay: false,
            rung: 0,
            coefs,
            kind: "trajectory".into(),
            frames: 7,
        };
        let err = exchange(&handle.addr, &bad, &[]).expect_err("must be rejected");
        assert!(err.to_string().contains("worker answered"), "{err:#}");

        // Unknown model inside a well-formed request: typed error, and
        // the error carries no stale frames.
        let (good_req, frames) = grad_request("spiral_node", 1);
        let DistRequest::GradStep { coefs, .. } = good_req else {
            unreachable!()
        };
        let ghost = DistRequest::GradStep {
            model: "ghost".into(),
            tay: false,
            rung: 0,
            coefs,
            kind: "trajectory".into(),
            frames: 2,
        };
        let err = exchange(&handle.addr, &ghost, &frames).expect_err("unknown model");
        assert!(err.to_string().contains("worker answered"), "{err:#}");
        handle.kill();
    }

    #[test]
    fn solver_failure_rides_the_metric_block_not_the_error_path() {
        let handle = spawn_worker();
        let be = NativeBackend::new();
        let params = be.init_params("spiral_node", 3).unwrap();
        let (truth, ts) = crate::coordinator::experiments::spiral_node::ground_truth();
        // Rung 0 budget is far too small for tol=spec when we shrink it:
        // instead force failure via an absurd trajectory: NaN data makes
        // the loss non-finite -> typed solver error in metrics.
        let poisoned: Vec<f32> = truth.iter().map(|_| f32::NAN).collect();
        let req = DistRequest::GradStep {
            model: "spiral_node".into(),
            tay: false,
            rung: 0,
            coefs: StepCoefs::default(),
            kind: "trajectory".into(),
            frames: 2,
        };
        let frames = vec![
            Frame::f32(frame::PARAMS, params),
            Frame::f32(frame::DATA, poisoned),
            Frame::f32(frame::DATA, ts),
        ];
        match exchange(&handle.addr, &req, &frames) {
            Ok(out) => {
                // Either the solve reports a typed failure or the loss
                // itself is non-finite — both must survive the wire.
                assert!(
                    !out.metrics.success
                        || !out.metrics.loss.is_finite()
                        || out.metrics.error == Some(SolveErrorKind::NonFiniteState),
                    "poisoned data must surface: {:?}",
                    out.metrics
                );
            }
            // A request-level error is also acceptable containment.
            Err(e) => assert!(e.to_string().contains("worker answered"), "{e:#}"),
        }
        handle.kill();
    }
}
