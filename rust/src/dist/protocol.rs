//! Distributed-training wire protocol: line-delimited JSON control
//! messages with length-prefixed binary tensor frames riding the same
//! TCP stream (`std::net` + [`util::json`] — no new dependencies).
//!
//! Control grammar (documented normatively in DESIGN.md §Distributed):
//!
//! ```text
//! request   := grad_step | shutdown
//! grad_step := {"op":"grad_step","model":<id>,"tay":<bool>,
//!               "rung":<n>,"data":<kind>,"frames":<n>,
//!               "coefs":{"lr","coef_e","coef_s","coef_l","coef_aux",
//!                        "kl","t1","seed"}}
//!              <PARAMS frame> <DATA frame> * frames
//! shutdown  := {"op":"shutdown"}
//!
//! response  := grad | closing | error
//! grad      := {"ok":true,"success":<bool>[,"kind":<solve-error-kind>]}
//!              <GRAD frame> <METRICS frame>
//! closing   := {"ok":true,"closing":true}
//! error     := {"ok":false,"error":<string>[,"kind":<solve-error-kind>]}
//! ```
//!
//! `<kind>` is the [`TrainData::kind`] string; it fixes the number and
//! order of the DATA frames that follow (`trajectory`: data, ts ·
//! `moments`: u0, mu, var, ts · `classify`: x, y · `series`: x, mask,
//! ts).
//!
//! Binary frame grammar (all integers little-endian):
//!
//! ```text
//! frame    := magic:u32 type:u8 count:u32 payload checksum:u64
//! payload  := count × f32le   (PARAMS | DATA | GRAD)
//!           | count × f64le   (METRICS)
//! checksum := FNV-1a-64 over type ∥ count ∥ payload
//! ```
//!
//! Floats ride as raw IEEE-754 bits, so `f32 → wire → f32` is exact by
//! construction — and unlike the JSON number path, NaN/±inf survive
//! (a failed shard's `loss` is NaN; that is *why* the metric block is a
//! binary frame and not JSON numbers).  Decoding is total: truncated,
//! corrupted, mistyped, or oversized frames return a typed
//! [`FrameError`]; the decoder never panics and never reads past the
//! declared length.  `count` is capped at [`MAX_FRAME_ELEMS`] *before*
//! any allocation, so a hostile header cannot balloon memory.
//!
//! The `success` flag and typed [`SolveErrorKind`] ride the JSON line
//! (as in the serving protocol, PR 6); the ten numeric [`Metrics`]
//! fields ride the METRICS frame.
//!
//! [`util::json`]: crate::util::json
//! [`TrainData::kind`]: crate::runtime::TrainData::kind

use std::fmt;
use std::io::{self, Read, Write};

use anyhow::{bail, ensure, Result};

use crate::runtime::{Metrics, StepCoefs, TrainData};
use crate::solvers::error::SolveErrorKind;
use crate::util::json::{obj, Json};

/// Hard cap on elements in one frame, checked before any allocation.
/// Far above any real payload (the largest shard tensor is a few tens of
/// thousands of floats) but small enough that a corrupt or hostile
/// header cannot balloon memory.
pub const MAX_FRAME_ELEMS: usize = 1 << 24;

/// Every field name and value vocabulary of the dist control channel,
/// as named constants — the single source of truth, extracted by the L3
/// wire-stability lint (`rust/tools/analyze`, group `dist`) and diffed
/// against the committed `wire_registry.txt`.
// analyze: wire(dist)
pub mod tags {
    /// Request discriminator field.
    pub const OP: &str = "op";
    pub const OP_GRAD_STEP: &str = "grad_step";
    pub const OP_SHUTDOWN: &str = "shutdown";
    pub const MODEL: &str = "model";
    pub const TAY: &str = "tay";
    pub const RUNG: &str = "rung";
    /// Shard payload kind (`TrainData::kind` vocabulary below); fixes
    /// the DATA frame count and order.
    pub const DATA: &str = "data";
    pub const DATA_TRAJECTORY: &str = "trajectory";
    pub const DATA_MOMENTS: &str = "moments";
    pub const DATA_CLASSIFY: &str = "classify";
    pub const DATA_SERIES: &str = "series";
    /// Number of DATA frames following the request line.
    pub const FRAMES: &str = "frames";
    /// Nested scalar-coefficient object of a grad_step request.
    pub const COEFS: &str = "coefs";
    pub const LR: &str = "lr";
    pub const COEF_E: &str = "coef_e";
    pub const COEF_S: &str = "coef_s";
    pub const COEF_L: &str = "coef_l";
    pub const COEF_AUX: &str = "coef_aux";
    pub const KL: &str = "kl";
    pub const T1: &str = "t1";
    pub const SEED: &str = "seed";
    /// Response success flag — present on every response line.
    pub const OK: &str = "ok";
    /// Solver-level success of the shard evaluation (`Metrics::success`).
    pub const SUCCESS: &str = "success";
    pub const ERROR: &str = "error";
    /// Typed `SolveErrorKind` wire string.
    pub const KIND: &str = "kind";
    pub const CLOSING: &str = "closing";

    /// Every tag above — the registry round-trip test walks this.
    pub const ALL: &[&str] = &[
        OP,
        OP_GRAD_STEP,
        OP_SHUTDOWN,
        MODEL,
        TAY,
        RUNG,
        DATA,
        DATA_TRAJECTORY,
        DATA_MOMENTS,
        DATA_CLASSIFY,
        DATA_SERIES,
        FRAMES,
        COEFS,
        LR,
        COEF_E,
        COEF_S,
        COEF_L,
        COEF_AUX,
        KL,
        T1,
        SEED,
        OK,
        SUCCESS,
        ERROR,
        KIND,
        CLOSING,
    ];
}

/// Binary-frame framing constants — wire-stable, so registered with the
/// L3 lint alongside the JSON tags.
// analyze: wire(dist)
pub mod frame {
    /// Leading magic word of every frame (`"FNGR"` in LE byte order —
    /// reversed "RGNF", regnde frame).
    pub const MAGIC: u32 = 0x52474E46;
    /// Flat f32 parameter vector (coordinator → worker).
    pub const PARAMS: u8 = 1;
    /// One f32 shard-data tensor (coordinator → worker).
    pub const DATA: u8 = 2;
    /// Flat f32 gradient (worker → coordinator).
    pub const GRAD: u8 = 3;
    /// f64 metric block of exactly `METRICS_LEN` values (worker →
    /// coordinator).
    pub const METRICS: u8 = 4;
    /// Element count of a METRICS frame: loss, metric, nfe, naccept,
    /// nreject, r_e, r_e2, r_s, r_l, r_aux — in that order.
    pub const METRICS_LEN: usize = 10;

    /// Every frame-type constant — the registry round-trip test walks
    /// this.
    pub const ALL_TYPES: &[u8] = &[PARAMS, DATA, GRAD, METRICS];
}

/// Fixed header size: magic (4) + type (1) + count (4).
const HEADER_LEN: usize = 9;
/// Trailing FNV-1a-64 checksum size.
const CHECKSUM_LEN: usize = 8;

/// Typed failure of the binary frame codec.  `Truncated` means the
/// buffer/stream ended before the frame did (`need` counts from the
/// frame start); every other variant means the bytes are present but
/// wrong.
#[derive(Debug)]
pub enum FrameError {
    Truncated { need: usize, got: usize },
    BadMagic(u32),
    BadType(u8),
    Oversized { count: u32, max: usize },
    /// FNV-1a checksum mismatch: the frame was bit-corrupted in transit.
    Checksum,
    Io(io::Error),
}

impl fmt::Display for FrameError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            FrameError::Truncated { need, got } => {
                write!(f, "truncated frame: need {need} bytes, got {got}")
            }
            FrameError::BadMagic(m) => write!(f, "bad frame magic {m:#010x}"),
            FrameError::BadType(t) => write!(f, "unknown frame type {t}"),
            FrameError::Oversized { count, max } => {
                write!(f, "frame declares {count} elements, cap is {max}")
            }
            FrameError::Checksum => write!(f, "frame checksum mismatch"),
            FrameError::Io(e) => write!(f, "frame io: {e}"),
        }
    }
}

impl std::error::Error for FrameError {}

impl From<io::Error> for FrameError {
    fn from(e: io::Error) -> FrameError {
        FrameError::Io(e)
    }
}

/// Frame payload: f32 tensors (params/data/grad) or the f64 metric
/// block.  The dtype is determined by the frame type, not negotiated.
#[derive(Clone, Debug, PartialEq)]
pub enum FrameBody {
    F32(Vec<f32>),
    F64(Vec<f64>),
}

/// One decoded binary frame.
#[derive(Clone, Debug, PartialEq)]
pub struct Frame {
    pub ty: u8,
    pub body: FrameBody,
}

const FNV_BASIS: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

fn fnv_update(mut h: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

/// Little-endian integer reads without slice indexing (total on short
/// input: missing high bytes read as zero — callers size-check first).
fn le_u32(b: &[u8]) -> u32 {
    b.iter().take(4).rev().fold(0u32, |acc, &x| (acc << 8) | x as u32)
}

fn le_u64(b: &[u8]) -> u64 {
    b.iter().take(8).rev().fold(0u64, |acc, &x| (acc << 8) | x as u64)
}

fn arr4(c: &[u8]) -> [u8; 4] {
    let mut a = [0u8; 4];
    for (d, s) in a.iter_mut().zip(c) {
        *d = *s;
    }
    a
}

fn arr8(c: &[u8]) -> [u8; 8] {
    let mut a = [0u8; 8];
    for (d, s) in a.iter_mut().zip(c) {
        *d = *s;
    }
    a
}

/// Payload element width for a frame type; `BadType` for anything else.
fn width_of(ty: u8) -> Result<usize, FrameError> {
    match ty {
        frame::PARAMS | frame::DATA | frame::GRAD => Ok(4),
        frame::METRICS => Ok(8),
        other => Err(FrameError::BadType(other)),
    }
}

/// Validate a 9-byte header; returns `(ty, count, payload element
/// width)`.  The `Oversized` cap fires here — before any allocation.
fn header_info(h: &[u8]) -> Result<(u8, usize, usize), FrameError> {
    if h.len() < HEADER_LEN {
        return Err(FrameError::Truncated {
            need: HEADER_LEN,
            got: h.len(),
        });
    }
    let magic = le_u32(h);
    if magic != frame::MAGIC {
        return Err(FrameError::BadMagic(magic));
    }
    let ty = h.get(4).copied().unwrap_or(0);
    let width = width_of(ty)?;
    let count = le_u32(h.get(5..9).unwrap_or_default());
    if count as usize > MAX_FRAME_ELEMS {
        return Err(FrameError::Oversized {
            count,
            max: MAX_FRAME_ELEMS,
        });
    }
    Ok((ty, count as usize, width))
}

impl Frame {
    /// An f32 tensor frame (`PARAMS` / `DATA` / `GRAD`).
    pub fn f32(ty: u8, vals: Vec<f32>) -> Frame {
        Frame {
            ty,
            body: FrameBody::F32(vals),
        }
    }

    /// The METRICS frame of a metric block (numeric fields only; the
    /// `success`/`error` pair rides the JSON response line).
    pub fn metrics(m: &Metrics) -> Frame {
        Frame {
            ty: frame::METRICS,
            body: FrameBody::F64(vec![
                m.loss, m.metric, m.nfe, m.naccept, m.nreject, m.r_e, m.r_e2, m.r_s, m.r_l,
                m.r_aux,
            ]),
        }
    }

    /// Reassemble a [`Metrics`] from a METRICS frame plus the JSON-borne
    /// `success`/`error` pair.
    pub fn to_metrics(&self, success: bool, error: Option<SolveErrorKind>) -> Result<Metrics> {
        ensure!(
            self.ty == frame::METRICS,
            "frame type {} is not a metrics frame",
            self.ty
        );
        let FrameBody::F64(v) = &self.body else {
            bail!("metrics frame carries the wrong dtype");
        };
        let [loss, metric, nfe, naccept, nreject, r_e, r_e2, r_s, r_l, r_aux] = v.as_slice()
        else {
            bail!(
                "metrics frame has {} values, expected {}",
                v.len(),
                frame::METRICS_LEN
            );
        };
        Ok(Metrics {
            loss: *loss,
            metric: *metric,
            nfe: *nfe,
            naccept: *naccept,
            nreject: *nreject,
            success,
            error,
            r_e: *r_e,
            r_e2: *r_e2,
            r_s: *r_s,
            r_l: *r_l,
            r_aux: *r_aux,
        })
    }

    /// Borrow the f32 payload, checking the frame type.
    pub fn expect_f32(&self, ty: u8) -> Result<&[f32]> {
        ensure!(self.ty == ty, "expected frame type {ty}, got {}", self.ty);
        match &self.body {
            FrameBody::F32(v) => Ok(v),
            FrameBody::F64(_) => bail!("frame type {ty} carries the wrong dtype"),
        }
    }

    /// Encoded size in bytes (header + payload + checksum) without
    /// serializing — the bytes-on-wire counter hook of the dist metrics
    /// (DESIGN.md §Observability), kept equal to `encode().len()` by
    /// the codec tests.
    pub fn wire_len(&self) -> usize {
        let payload = match &self.body {
            FrameBody::F32(v) => v.len() * 4,
            FrameBody::F64(v) => v.len() * 8,
        };
        HEADER_LEN + payload + CHECKSUM_LEN
    }

    /// Serialize to the wire byte layout (see module grammar).
    pub fn encode(&self) -> Vec<u8> {
        let payload: Vec<u8> = match &self.body {
            FrameBody::F32(v) => v.iter().flat_map(|x| x.to_le_bytes()).collect(),
            FrameBody::F64(v) => v.iter().flat_map(|x| x.to_le_bytes()).collect(),
        };
        let count = match &self.body {
            FrameBody::F32(v) => v.len() as u32,
            FrameBody::F64(v) => v.len() as u32,
        };
        let mut out = Vec::with_capacity(HEADER_LEN + payload.len() + CHECKSUM_LEN);
        out.extend_from_slice(&frame::MAGIC.to_le_bytes());
        out.push(self.ty);
        out.extend_from_slice(&count.to_le_bytes());
        out.extend_from_slice(&payload);
        let mut sum = fnv_update(FNV_BASIS, &[self.ty]);
        sum = fnv_update(sum, &count.to_le_bytes());
        sum = fnv_update(sum, &payload);
        out.extend_from_slice(&sum.to_le_bytes());
        out
    }

    /// Decode one frame from the front of `buf`; returns the frame and
    /// the number of bytes consumed.  Total: every malformed input maps
    /// to a typed [`FrameError`], and no byte past the declared frame
    /// end is ever inspected.
    pub fn decode(buf: &[u8]) -> Result<(Frame, usize), FrameError> {
        let (ty, count, width) = header_info(buf)?;
        let payload_len = count * width;
        let total = HEADER_LEN + payload_len + CHECKSUM_LEN;
        let trunc = FrameError::Truncated {
            need: total,
            got: buf.len(),
        };
        let Some(payload) = buf.get(HEADER_LEN..HEADER_LEN + payload_len) else {
            return Err(trunc);
        };
        let Some(sum_bytes) = buf.get(HEADER_LEN + payload_len..total) else {
            return Err(trunc);
        };
        let mut sum = fnv_update(FNV_BASIS, &[ty]);
        sum = fnv_update(sum, &(count as u32).to_le_bytes());
        sum = fnv_update(sum, payload);
        if sum != le_u64(sum_bytes) {
            return Err(FrameError::Checksum);
        }
        let body = if width == 4 {
            FrameBody::F32(
                payload
                    .chunks_exact(4)
                    .map(|c| f32::from_le_bytes(arr4(c)))
                    .collect(),
            )
        } else {
            FrameBody::F64(
                payload
                    .chunks_exact(8)
                    .map(|c| f64::from_le_bytes(arr8(c)))
                    .collect(),
            )
        };
        Ok((Frame { ty, body }, total))
    }

    /// Read exactly one frame from a stream.  A read that times out or
    /// hits EOF mid-frame surfaces as [`FrameError::Io`] — the stream is
    /// desynchronized at that point and the caller must drop the
    /// connection.
    pub fn read_from(r: &mut impl Read) -> Result<Frame, FrameError> {
        let mut header = [0u8; HEADER_LEN];
        r.read_exact(&mut header)?;
        let (_, count, width) = header_info(&header)?;
        let mut rest = vec![0u8; count * width + CHECKSUM_LEN];
        r.read_exact(&mut rest)?;
        let mut buf = header.to_vec();
        buf.append(&mut rest);
        let (f, _) = Frame::decode(&buf)?;
        Ok(f)
    }

    /// Write this frame to a stream (no flush).
    pub fn write_to(&self, w: &mut impl Write) -> io::Result<()> {
        w.write_all(&self.encode())
    }
}

/// DATA frame count fixed by a data kind (the request's `frames` field
/// must agree — validated before any frame is read, so a malformed
/// request can never desynchronize the stream by under/over-reading).
pub fn frames_for_kind(kind: &str) -> Result<usize> {
    Ok(match kind {
        tags::DATA_TRAJECTORY | tags::DATA_CLASSIFY => 2,
        tags::DATA_SERIES => 3,
        tags::DATA_MOMENTS => 4,
        other => bail!("unknown data kind {other:?}"),
    })
}

/// `read_exact` over a socket with a poll-style read timeout:
/// `WouldBlock`/`TimedOut` ticks re-check `keep_waiting` and resume
/// without losing the partial fill.  `keep_waiting() == false` turns the
/// tick into a typed [`FrameError::Io`] — the caller's deadline or
/// shutdown signal.
fn read_exact_patient(
    r: &mut impl Read,
    buf: &mut [u8],
    keep_waiting: &mut impl FnMut() -> bool,
) -> Result<(), FrameError> {
    let mut filled = 0;
    while filled < buf.len() {
        let dst = buf.get_mut(filled..).unwrap_or_default();
        match r.read(dst) {
            Ok(0) => {
                return Err(FrameError::Io(io::Error::new(
                    io::ErrorKind::UnexpectedEof,
                    "stream closed mid-frame",
                )))
            }
            Ok(n) => filled += n,
            Err(e) if matches!(e.kind(), io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut) => {
                if !keep_waiting() {
                    return Err(FrameError::Io(e));
                }
            }
            Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
            Err(e) => return Err(FrameError::Io(e)),
        }
    }
    Ok(())
}

/// Read exactly one frame from a timeout-polled stream, tolerating
/// `WouldBlock` ticks while `keep_waiting` stays true.  Any other
/// failure — EOF mid-frame, a malformed header, a checksum mismatch —
/// is typed and final: the stream is desynchronized and must be
/// dropped.
pub fn read_frame_patient(
    r: &mut impl Read,
    mut keep_waiting: impl FnMut() -> bool,
) -> Result<Frame, FrameError> {
    let mut header = [0u8; HEADER_LEN];
    read_exact_patient(r, &mut header, &mut keep_waiting)?;
    let (_, count, width) = header_info(&header)?;
    let mut buf = vec![0u8; HEADER_LEN + count * width + CHECKSUM_LEN];
    for (d, s) in buf.iter_mut().zip(header.iter()) {
        *d = *s;
    }
    read_exact_patient(
        r,
        buf.get_mut(HEADER_LEN..).unwrap_or_default(),
        &mut keep_waiting,
    )?;
    let (f, _) = Frame::decode(&buf)?;
    Ok(f)
}

/// The shard-data tensors of a [`TrainData`], as DATA frames in the
/// fixed per-kind order the worker reassembles with
/// [`data_from_frames`].
pub fn data_frames(data: &TrainData) -> Vec<Frame> {
    let tensors: Vec<&[f32]> = match data {
        TrainData::Trajectory { data, ts } => vec![data, ts],
        TrainData::Moments { u0, mu, var, ts } => vec![u0, mu, var, ts],
        TrainData::Classify { x, y } => vec![x, y],
        TrainData::Series { x, mask, ts } => vec![x, mask, ts],
    };
    tensors
        .into_iter()
        .map(|t| Frame::f32(frame::DATA, t.to_vec()))
        .collect()
}

/// Reassemble a [`TrainData`] view over received tensors (`kind` is the
/// request's `data` tag).  Tensor *count* is validated here; shapes are
/// validated by the backend pass it feeds.
pub fn data_from_frames<'a>(kind: &str, tensors: &'a [Vec<f32>]) -> Result<TrainData<'a>> {
    match (kind, tensors) {
        (tags::DATA_TRAJECTORY, [data, ts]) => Ok(TrainData::Trajectory { data, ts }),
        (tags::DATA_MOMENTS, [u0, mu, var, ts]) => Ok(TrainData::Moments { u0, mu, var, ts }),
        (tags::DATA_CLASSIFY, [x, y]) => Ok(TrainData::Classify { x, y }),
        (tags::DATA_SERIES, [x, mask, ts]) => Ok(TrainData::Series { x, mask, ts }),
        (k, t) => bail!(
            "data kind {k:?} with {} tensors is not a valid shard payload",
            t.len()
        ),
    }
}

/// A coordinator→worker request (one JSON line, then frames).
#[derive(Clone, Debug, PartialEq)]
pub enum DistRequest {
    /// One shard gradient evaluation.  Followed on the wire by one
    /// PARAMS frame and `frames` DATA frames.
    GradStep {
        model: String,
        tay: bool,
        rung: usize,
        coefs: StepCoefs,
        /// [`TrainData::kind`] of the shard payload.
        kind: String,
        /// DATA frame count (fixed by `kind`; carried explicitly so the
        /// worker can validate before reading).
        frames: usize,
    },
    Shutdown,
}

fn coefs_json(c: &StepCoefs) -> Json {
    obj([
        (tags::LR, Json::from(c.lr as f64)),
        (tags::COEF_E, Json::from(c.coef_e as f64)),
        (tags::COEF_S, Json::from(c.coef_s as f64)),
        (tags::COEF_L, Json::from(c.coef_l as f64)),
        (tags::COEF_AUX, Json::from(c.coef_aux as f64)),
        (tags::KL, Json::from(c.kl as f64)),
        (tags::T1, Json::from(c.t1 as f64)),
        (tags::SEED, Json::from(c.seed as usize)),
    ])
}

fn coefs_from(j: &Json) -> Result<StepCoefs> {
    Ok(StepCoefs {
        lr: j.get(tags::LR)?.as_f64()? as f32,
        coef_e: j.get(tags::COEF_E)?.as_f64()? as f32,
        coef_s: j.get(tags::COEF_S)?.as_f64()? as f32,
        coef_l: j.get(tags::COEF_L)?.as_f64()? as f32,
        coef_aux: j.get(tags::COEF_AUX)?.as_f64()? as f32,
        kl: j.get(tags::KL)?.as_f64()? as f32,
        t1: j.get(tags::T1)?.as_f64()? as f32,
        seed: j.get(tags::SEED)?.as_f64()? as u32,
    })
}

impl DistRequest {
    pub fn to_json(&self) -> Json {
        match self {
            DistRequest::GradStep {
                model,
                tay,
                rung,
                coefs,
                kind,
                frames,
            } => obj([
                (tags::OP, Json::from(tags::OP_GRAD_STEP)),
                (tags::MODEL, Json::from(model.as_str())),
                (tags::TAY, Json::from(*tay)),
                (tags::RUNG, Json::from(*rung)),
                (tags::DATA, Json::from(kind.as_str())),
                (tags::FRAMES, Json::from(*frames)),
                (tags::COEFS, coefs_json(coefs)),
            ]),
            DistRequest::Shutdown => obj([(tags::OP, Json::from(tags::OP_SHUTDOWN))]),
        }
    }

    pub fn from_json(j: &Json) -> Result<DistRequest> {
        match j.get(tags::OP)?.as_str()? {
            tags::OP_GRAD_STEP => Ok(DistRequest::GradStep {
                model: j.get(tags::MODEL)?.as_str()?.to_string(),
                tay: j.get(tags::TAY)?.as_bool()?,
                rung: j.get(tags::RUNG)?.as_usize()?,
                coefs: coefs_from(j.get(tags::COEFS)?)?,
                kind: j.get(tags::DATA)?.as_str()?.to_string(),
                frames: j.get(tags::FRAMES)?.as_usize()?,
            }),
            tags::OP_SHUTDOWN => Ok(DistRequest::Shutdown),
            other => bail!("unknown dist op {other:?} (grad_step|shutdown)"),
        }
    }

    /// One wire line (no trailing newline).
    pub fn encode(&self) -> String {
        self.to_json().to_string_compact()
    }

    pub fn decode(line: &str) -> Result<DistRequest> {
        DistRequest::from_json(&Json::parse(line)?)
    }
}

/// A worker→coordinator response (one JSON line, then frames for
/// `Grad`).
#[derive(Clone, Debug, PartialEq)]
pub enum DistResponse {
    /// Gradient evaluated.  Followed on the wire by one GRAD frame and
    /// one METRICS frame.  `success`/`kind` are the metric block's
    /// solver outcome (a *solver* failure — e.g. `budget_exhausted` —
    /// still returns `Grad`: the coordinator's router decides what to do
    /// with it, exactly as in single-process training).
    Grad {
        success: bool,
        kind: Option<SolveErrorKind>,
    },
    /// Request-level failure: nothing was evaluated, no frames follow.
    Error {
        msg: String,
        kind: Option<SolveErrorKind>,
    },
    /// Acknowledges a shutdown request.
    Closing,
}

impl DistResponse {
    pub fn error(msg: impl Into<String>) -> DistResponse {
        DistResponse::Error {
            msg: msg.into(),
            kind: None,
        }
    }

    pub fn to_json(&self) -> Json {
        match self {
            DistResponse::Grad { success, kind } => {
                let mut fields = vec![
                    (tags::OK, Json::from(true)),
                    (tags::SUCCESS, Json::from(*success)),
                ];
                if let Some(k) = kind {
                    fields.push((tags::KIND, Json::from(k.as_str())));
                }
                obj(fields)
            }
            DistResponse::Closing => {
                obj([(tags::OK, Json::from(true)), (tags::CLOSING, Json::from(true))])
            }
            DistResponse::Error { msg, kind } => {
                let mut fields = vec![
                    (tags::OK, Json::from(false)),
                    (tags::ERROR, Json::Str(msg.clone())),
                ];
                if let Some(k) = kind {
                    fields.push((tags::KIND, Json::from(k.as_str())));
                }
                obj(fields)
            }
        }
    }

    pub fn from_json(j: &Json) -> Result<DistResponse> {
        let kind = match j.opt(tags::KIND) {
            Some(k) => SolveErrorKind::parse(k.as_str()?),
            None => None,
        };
        if !j.get(tags::OK)?.as_bool()? {
            let msg = j.get(tags::ERROR)?.as_str()?.to_string();
            return Ok(DistResponse::Error { msg, kind });
        }
        if j.opt(tags::CLOSING).is_some() {
            return Ok(DistResponse::Closing);
        }
        Ok(DistResponse::Grad {
            success: j.get(tags::SUCCESS)?.as_bool()?,
            kind,
        })
    }

    /// One wire line (no trailing newline).
    pub fn encode(&self) -> String {
        self.to_json().to_string_compact()
    }

    pub fn decode(line: &str) -> Result<DistResponse> {
        DistResponse::from_json(&Json::parse(line)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(f: &Frame) -> Frame {
        let bytes = f.encode();
        let (back, used) = Frame::decode(&bytes).expect("frame must decode");
        assert_eq!(used, bytes.len(), "decode must consume the whole frame");
        back
    }

    #[test]
    fn frames_round_trip_bit_exact() {
        let f = Frame::f32(
            frame::PARAMS,
            vec![1.0, -0.0, f32::MIN_POSITIVE, -1.9375e-7, f32::NAN, f32::INFINITY],
        );
        let back = roundtrip(&f);
        assert_eq!(back.ty, frame::PARAMS);
        let (FrameBody::F32(a), FrameBody::F32(b)) = (&f.body, &back.body) else {
            panic!("dtype changed");
        };
        for (x, y) in a.iter().zip(b) {
            assert_eq!(x.to_bits(), y.to_bits(), "wire must not perturb f32 bits");
        }
        // Empty frames are legal (an empty shard range ships no data).
        assert_eq!(roundtrip(&Frame::f32(frame::GRAD, vec![])), Frame::f32(frame::GRAD, vec![]));
    }

    #[test]
    fn wire_len_matches_encoded_length() {
        for f in [
            Frame::f32(frame::PARAMS, vec![1.0; 7]),
            Frame::f32(frame::GRAD, vec![]),
            Frame::metrics(&Metrics::default()),
        ] {
            assert_eq!(f.wire_len(), f.encode().len(), "{f:?}");
        }
    }

    #[test]
    fn metrics_frame_round_trips_including_nan_loss() {
        let m = Metrics {
            loss: f64::NAN,
            metric: 0.25,
            nfe: 120.0,
            naccept: 17.0,
            nreject: 3.0,
            success: false,
            error: Some(SolveErrorKind::NonFiniteState),
            r_e: 0.5,
            r_e2: 0.125,
            r_s: 2.0,
            r_l: 0.0625,
            r_aux: 0.0,
        };
        let back = roundtrip(&Frame::metrics(&m))
            .to_metrics(m.success, m.error)
            .expect("metrics reassembly");
        assert!(back.loss.is_nan(), "NaN loss must survive the wire");
        assert_eq!(back.metric, m.metric);
        assert_eq!(back.nfe, m.nfe);
        assert_eq!(back.r_e2, m.r_e2);
        assert_eq!(back.r_l, m.r_l);
        assert_eq!(back.error, Some(SolveErrorKind::NonFiniteState));
    }

    #[test]
    fn typed_codec_failures() {
        let good = Frame::f32(frame::DATA, vec![1.0, 2.0, 3.0]).encode();
        // Truncation at every prefix length: typed error, never panic.
        for cut in 0..good.len() {
            let e = Frame::decode(&good[..cut]).expect_err("prefix must not decode");
            assert!(
                matches!(e, FrameError::Truncated { .. } | FrameError::BadMagic(_)),
                "cut {cut}: {e}"
            );
        }
        // A flipped payload bit is caught by the checksum.
        let mut bad = good.clone();
        bad[12] ^= 0x40;
        assert!(matches!(Frame::decode(&bad), Err(FrameError::Checksum)));
        // A wrong magic word is typed.
        let mut bad = good.clone();
        bad[0] ^= 0xFF;
        assert!(matches!(Frame::decode(&bad), Err(FrameError::BadMagic(_))));
        // An unknown frame type is typed (checked before the count).
        let mut bad = good.clone();
        bad[4] = 99;
        assert!(matches!(Frame::decode(&bad), Err(FrameError::BadType(99))));
        // An oversized count is refused before allocation.
        let mut bad = good;
        bad[5..9].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(matches!(Frame::decode(&bad), Err(FrameError::Oversized { .. })));
    }

    #[test]
    fn decode_reports_consumed_length_for_streams() {
        let a = Frame::f32(frame::PARAMS, vec![5.0; 7]);
        let b = Frame::metrics(&Metrics::default());
        let mut buf = a.encode();
        buf.extend_from_slice(&b.encode());
        let (fa, used) = Frame::decode(&buf).unwrap();
        assert_eq!(fa, a);
        let (fb, _) = Frame::decode(&buf[used..]).unwrap();
        assert_eq!(fb, b);
    }

    #[test]
    fn read_from_matches_decode() {
        let f = Frame::f32(frame::GRAD, vec![0.5, -2.5]);
        let mut cursor = io::Cursor::new(f.encode());
        assert_eq!(Frame::read_from(&mut cursor).unwrap(), f);
        // EOF mid-frame is a typed Io error.
        let mut short = io::Cursor::new(f.encode()[..10].to_vec());
        assert!(matches!(Frame::read_from(&mut short), Err(FrameError::Io(_))));
    }

    #[test]
    fn requests_round_trip() {
        let reqs = [
            DistRequest::GradStep {
                model: "spiral_node".into(),
                tay: false,
                rung: 1,
                coefs: StepCoefs {
                    lr: 0.01,
                    coef_e: 0.125,
                    seed: 0xDEAD_BEEF,
                    ..Default::default()
                },
                kind: tags::DATA_TRAJECTORY.into(),
                frames: 2,
            },
            DistRequest::Shutdown,
        ];
        for r in reqs {
            assert_eq!(DistRequest::decode(&r.encode()).unwrap(), r, "{r:?}");
            assert!(!r.encode().contains('\n'));
        }
        assert!(DistRequest::decode("{\"op\":\"frobnicate\"}").is_err());
        assert!(DistRequest::decode("not json").is_err());
    }

    #[test]
    fn responses_round_trip_with_typed_kinds() {
        for r in [
            DistResponse::Grad {
                success: true,
                kind: None,
            },
            DistResponse::Grad {
                success: false,
                kind: Some(SolveErrorKind::BudgetExhausted),
            },
            DistResponse::Error {
                msg: "shard failed".into(),
                kind: Some(SolveErrorKind::StepSizeUnderflow),
            },
            DistResponse::error("bad request"),
            DistResponse::Closing,
        ] {
            assert_eq!(DistResponse::decode(&r.encode()).unwrap(), r, "{r:?}");
        }
    }

    #[test]
    fn data_frames_round_trip_every_kind() {
        let x = [1.0f32, 2.0, 3.0, 4.0];
        let ts = [0.0f32, 0.5];
        let cases: Vec<TrainData> = vec![
            TrainData::Trajectory { data: &x, ts: &ts },
            TrainData::Moments {
                u0: &x,
                mu: &x,
                var: &x,
                ts: &ts,
            },
            TrainData::Classify { x: &x, y: &ts },
            TrainData::Series {
                x: &x,
                mask: &x,
                ts: &ts,
            },
        ];
        for data in cases {
            let frames = data_frames(&data);
            let tensors: Vec<Vec<f32>> = frames
                .iter()
                .map(|f| f.expect_f32(frame::DATA).unwrap().to_vec())
                .collect();
            let back = data_from_frames(data.kind(), &tensors).unwrap();
            assert_eq!(back.kind(), data.kind());
            assert_eq!(frames.len(), tensors.len());
        }
        assert!(data_from_frames("classify", &[vec![1.0]]).is_err());
        assert!(data_from_frames("nonsense", &[]).is_err());
    }
}
