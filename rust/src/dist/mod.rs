//! Distributed data-parallel training: coordinator/worker gradient
//! sharding with a bit-deterministic all-reduce.
//!
//! The subsystem splits one [`Backend::train_step`] into the
//! `grad_step` seam (evaluate the gradient, do **not** touch the
//! optimizer) plus a coordinator-owned reduce-and-update, so a fleet of
//! workers can share the batch while training stays **bit-identical**
//! to the single-process run at equal shard count.  The full contract —
//! grad_step semantics, the determinism guarantee, failure/retry
//! semantics, and the wire-frame grammar — is specified in
//! **DESIGN.md §Distributed**; the protocol literals are enforced
//! against `rust/tools/analyze/wire_registry.txt` by the `wire(dist)`
//! static-analysis group.
//!
//! Layout (a peer of `solvers/`, `runtime/`, and `serve/`):
//!
//!  * [`sharder`] — deterministic contiguous shard plans, shared with
//!    the in-process ensemble/moment paths.
//!  * [`protocol`] — length-prefixed checksummed binary tensor frames
//!    riding a line-delimited JSON control channel.
//!  * [`worker`] — the `regnde worker` loop: serve `grad_step` requests
//!    over TCP.
//!  * [`coordinator`] — [`DistBackend`]: shard → evaluate (local or
//!    remote) → fixed-tree f64 reduce → one Adam update, behind the
//!    ordinary [`Backend`] trait so every experiment driver runs
//!    unchanged.
//!
//! [`Backend`]: crate::runtime::Backend
//! [`Backend::train_step`]: crate::runtime::Backend::train_step

pub mod coordinator;
pub mod protocol;
pub mod sharder;
pub mod worker;

pub use coordinator::{
    shard_seed, DistBackend, DistError, GradExecutor, LocalExecutor, RemoteExecutor, RemoteOpts,
};
pub use protocol::{Frame, FrameBody, FrameError, MAX_FRAME_ELEMS};
pub use sharder::ShardPlan;
pub use worker::{Worker, WorkerHandle, WorkerOpts};
