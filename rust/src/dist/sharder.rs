//! Deterministic work sharding — the single source of truth for how the
//! distributed coordinator, the local sharded trainer, and the ensemble
//! paths (`solvers::ensemble`, spiral SDE moments, physionet synthesis)
//! split `n` items over `s` slots.
//!
//! Determinism contract (DESIGN.md §Distributed): a [`ShardPlan`] is a
//! pure function of `(n, s)` (or `(n, chunk)` for [`ShardPlan::by_chunk`])
//! — same inputs, same ranges, on every machine, every run.  Shard `i`
//! always owns a contiguous range, ranges are ascending and disjoint,
//! and their union is exactly `0..n`.  Combined with the fixed
//! tree-reduction order in `dist::coordinator`, this is what makes
//! distributed training bit-identical to single-process at equal shard
//! count.

use std::ops::Range;

/// A deterministic partition of `0..n` into contiguous shards.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ShardPlan {
    n: usize,
    ranges: Vec<Range<usize>>,
}

impl ShardPlan {
    /// Balanced split of `n` items over exactly `shards` slots (slot
    /// count preserved even when `n < shards`: trailing shards get empty
    /// ranges).  The first `n % shards` shards get one extra item, so
    /// sizes differ by at most one and earlier shards are never smaller.
    pub fn by_count(n: usize, shards: usize) -> ShardPlan {
        let s = shards.max(1);
        let base = n / s;
        let extra = n % s;
        let mut ranges = Vec::with_capacity(s);
        let mut start = 0;
        for i in 0..s {
            let len = base + usize::from(i < extra);
            ranges.push(start..start + len);
            start += len;
        }
        ShardPlan { n, ranges }
    }

    /// Fixed-size chunking: ceil(n / chunk) shards of `chunk` items with
    /// a possibly-short tail (the `util::threadpool::chunk_ranges`
    /// contract, now owned here so ensemble sweeps and the distributed
    /// sharder agree).  `n == 0` yields an empty plan.
    pub fn by_chunk(n: usize, chunk: usize) -> ShardPlan {
        let c = chunk.max(1);
        let ranges = (0..n.div_ceil(c)).map(|k| k * c..((k + 1) * c).min(n)).collect();
        ShardPlan { n, ranges }
    }

    /// Total item count being partitioned.
    pub fn items(&self) -> usize {
        self.n
    }

    /// Number of shard slots (including empty tails from `by_count`).
    pub fn len(&self) -> usize {
        self.ranges.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ranges.is_empty()
    }

    /// The contiguous item range owned by shard `i` (None past the end).
    pub fn range(&self, i: usize) -> Option<Range<usize>> {
        self.ranges.get(i).cloned()
    }

    /// Iterate `(shard_index, range)` over non-empty shards only — the
    /// shards that actually carry work.
    pub fn occupied(&self) -> impl Iterator<Item = (usize, Range<usize>)> + '_ {
        self.ranges
            .iter()
            .enumerate()
            .filter(|(_, r)| !r.is_empty())
            .map(|(i, r)| (i, r.clone()))
    }

    /// All ranges in shard order (empty ones included).
    pub fn ranges(&self) -> &[Range<usize>] {
        &self.ranges
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::{check, ensure, PropResult};

    #[test]
    fn by_count_is_balanced_and_exhaustive() {
        check("sharder::by_count", 300, |g| -> PropResult {
            let n = g.usize_in(0, 300);
            let s = g.usize_in(1, 9);
            let plan = ShardPlan::by_count(n, s);
            ensure(plan.len() == s, "slot count preserved")?;
            ensure(plan.items() == n, "items recorded")?;
            let mut covered = 0;
            let mut prev_end = 0;
            let mut prev_len = usize::MAX;
            for r in plan.ranges() {
                ensure(r.start == prev_end, "contiguous ascending")?;
                ensure(r.len() <= prev_len, "earlier shards never smaller")?;
                prev_len = r.len();
                prev_end = r.end;
                covered += r.len();
            }
            ensure(covered == n && prev_end == n, "union is exactly 0..n")?;
            // Balance: sizes differ by at most one.
            let min = plan.ranges().iter().map(|r| r.len()).min().unwrap_or(0);
            let max = plan.ranges().iter().map(|r| r.len()).max().unwrap_or(0);
            ensure(max - min <= 1, "balanced within one item")
        });
    }

    #[test]
    fn by_chunk_matches_the_threadpool_contract() {
        check("sharder::by_chunk", 300, |g| -> PropResult {
            let n = g.usize_in(0, 300);
            let c = g.usize_in(0, 50);
            let plan = ShardPlan::by_chunk(n, c);
            let cc = c.max(1);
            ensure(plan.len() == n.div_ceil(cc), "ceil(n/chunk) shards")?;
            let mut prev_end = 0;
            for (i, r) in plan.ranges().iter().enumerate() {
                ensure(r.start == prev_end, "contiguous")?;
                let want = if i + 1 == plan.len() { n - r.start } else { cc };
                ensure(r.len() == want, "full chunks then tail")?;
                prev_end = r.end;
            }
            ensure(prev_end == n, "covers 0..n")
        });
    }

    #[test]
    fn plans_are_deterministic() {
        assert_eq!(ShardPlan::by_count(10, 4), ShardPlan::by_count(10, 4));
        assert_eq!(
            ShardPlan::by_count(10, 4).ranges(),
            &[0..3, 3..6, 6..8, 8..10]
        );
        // n < shards: trailing empties, slot count preserved.
        let small = ShardPlan::by_count(1, 3);
        assert_eq!(small.ranges(), &[0..1, 1..1, 1..1]);
        assert_eq!(small.occupied().count(), 1);
        assert_eq!(ShardPlan::by_chunk(7, 3).ranges(), &[0..3, 3..6, 6..7]);
    }
}
