//! Budget-ladder routing — the coordinator's scheduling contribution.
//!
//! Train-time solves are lowered as bounded masked scans (reverse-mode AD
//! cannot cross `while_loop`), so a single train artifact costs its full
//! step budget in wall-clock regardless of the NFE actually used.  To make
//! the paper's NFE reductions show up as *training time* reductions, each
//! model is lowered at several budgets (a ladder of artifacts) and this
//! router picks the rung per step:
//!
//!  * a step that exhausts its budget (`success == false`) escalates to the
//!    next rung and the batch is retried there (its result is discarded —
//!    gradients from truncated solves are biased);
//!  * the router tracks a sliding window of attempt usage
//!    (naccept + nreject); when the window's max fits comfortably (with
//!    `headroom`) inside the next rung down, it descends.
//!
//! The same mechanism doubles as a failure-injection point in tests.

use anyhow::{bail, Result};

/// Routing policy over an ascending ladder of step budgets.
#[derive(Debug)]
pub struct BudgetRouter {
    budgets: Vec<usize>,
    rung: usize,
    window: Vec<f64>,
    window_len: usize,
    headroom: f64,
    pub escalations: u64,
    pub descents: u64,
    pub retries: u64,
    /// Batches dropped on non-budget failures (divergent solves: a bigger
    /// rung cannot fix a NaN vector field, so the step is skipped).
    pub skips: u64,
}

impl BudgetRouter {
    pub fn new(budgets: Vec<usize>) -> Result<Self> {
        if budgets.is_empty() {
            bail!("budget ladder is empty");
        }
        if budgets.windows(2).any(|w| w[0] >= w[1]) {
            bail!("budget ladder must be strictly ascending: {budgets:?}");
        }
        Ok(Self {
            budgets,
            rung: 0,
            window: Vec::new(),
            window_len: 16,
            headroom: 0.75,
            escalations: 0,
            descents: 0,
            retries: 0,
            skips: 0,
        })
    }

    /// Index of the current rung.
    pub fn rung(&self) -> usize {
        self.rung
    }

    /// The sliding attempt-usage window (descent evidence) — persisted
    /// by checkpoint v2 so a resumed run replays descent decisions
    /// bit-identically to the uninterrupted run.
    pub fn window(&self) -> &[f64] {
        &self.window
    }

    /// Restore a persisted ladder position (checkpoint resume): the
    /// rung plus the descent-evidence window.  Errors on a rung outside
    /// this ladder (e.g. a checkpoint from a different model).
    pub fn restore(&mut self, rung: usize, window: &[f64]) -> Result<()> {
        if rung >= self.budgets.len() {
            bail!(
                "checkpoint rung {rung} out of range for a {}-rung ladder",
                self.budgets.len()
            );
        }
        self.rung = rung;
        self.window = window.to_vec();
        if self.window.len() > self.window_len {
            self.window.drain(..self.window.len() - self.window_len);
        }
        Ok(())
    }

    /// Step budget of the current rung.
    pub fn budget(&self) -> usize {
        self.budgets[self.rung]
    }

    /// Record a batch skipped on a non-budget failure (NaN drift,
    /// step-size underflow): the rung stays put — escalation only answers
    /// undersized budgets — but the descent window is cleared so a
    /// divergence episode cannot contribute "low usage" evidence.
    pub fn note_skip(&mut self) {
        self.window.clear();
        self.skips += 1;
    }

    /// Record a completed train step.  `attempts` = naccept + nreject,
    /// `success` = the artifact's success flag.  Returns `true` if the
    /// caller should *retry the same batch* (the step was truncated and has
    /// been escalated).
    pub fn observe(&mut self, attempts: f64, success: bool) -> bool {
        if !success {
            self.window.clear();
            if self.rung + 1 < self.budgets.len() {
                self.rung += 1;
                self.escalations += 1;
                self.retries += 1;
                return true;
            }
            // Top rung still failing: accept the truncated step (logged by
            // the trainer); nothing better is available.
            return false;
        }
        self.window.push(attempts);
        if self.window.len() > self.window_len {
            self.window.remove(0);
        }
        if self.rung > 0 && self.window.len() == self.window_len {
            let max_used = self.window.iter().cloned().fold(0.0, f64::max);
            let lower = self.budgets[self.rung - 1] as f64;
            if max_used <= self.headroom * lower {
                self.rung -= 1;
                self.descents += 1;
                self.window.clear();
            }
        }
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::{check, ensure};

    #[test]
    fn restore_validates_and_round_trips() {
        let mut r = BudgetRouter::new(vec![16, 32, 64]).unwrap();
        assert!(r.restore(3, &[]).is_err(), "rung past the ladder must fail");
        r.restore(1, &[4.0, 5.0]).unwrap();
        assert_eq!(r.rung(), 1);
        assert_eq!(r.window(), &[4.0, 5.0]);
        // A resumed router behaves exactly like one that lived through
        // the same observations: filling the window to 16 low-usage
        // steps descends.
        for _ in 0..14 {
            assert!(!r.observe(5.0, true));
        }
        assert_eq!(r.rung(), 0, "restored window must count toward descent");
    }

    #[test]
    fn rejects_bad_ladders() {
        assert!(BudgetRouter::new(vec![]).is_err());
        assert!(BudgetRouter::new(vec![16, 16]).is_err());
        assert!(BudgetRouter::new(vec![32, 16]).is_err());
    }

    #[test]
    fn escalates_on_failure_and_requests_retry() {
        let mut r = BudgetRouter::new(vec![16, 32, 64]).unwrap();
        assert_eq!(r.budget(), 16);
        assert!(r.observe(16.0, false));
        assert_eq!(r.budget(), 32);
        assert!(r.observe(32.0, false));
        assert_eq!(r.budget(), 64);
        // top rung: no retry possible
        assert!(!r.observe(64.0, false));
        assert_eq!(r.budget(), 64);
        assert_eq!(r.escalations, 2);
    }

    #[test]
    fn note_skip_keeps_rung_but_clears_descent_evidence() {
        let mut r = BudgetRouter::new(vec![16, 32]).unwrap();
        assert!(r.observe(20.0, false)); // escalate to 32
        for _ in 0..15 {
            assert!(!r.observe(8.0, true));
        }
        // One divergent batch resets the window: no descent on the next
        // low-usage step even though 16 successes would have triggered it.
        r.note_skip();
        assert_eq!(r.budget(), 32, "skip must not move the rung");
        assert!(!r.observe(8.0, true));
        assert_eq!(r.budget(), 32);
        assert_eq!(r.skips, 1);
        assert_eq!(r.descents, 0);
    }

    #[test]
    fn descends_after_consistent_low_usage() {
        let mut r = BudgetRouter::new(vec![16, 32]).unwrap();
        assert!(r.observe(20.0, false)); // escalate to 32
        for _ in 0..16 {
            assert!(!r.observe(8.0, true)); // well under 0.75 * 16
        }
        assert_eq!(r.budget(), 16);
        assert_eq!(r.descents, 1);
    }

    #[test]
    fn does_not_descend_on_high_usage() {
        let mut r = BudgetRouter::new(vec![16, 32]).unwrap();
        assert!(r.observe(20.0, false));
        for _ in 0..64 {
            r.observe(14.0, true); // 14 > 0.75*16 = 12
        }
        assert_eq!(r.budget(), 32);
    }

    #[test]
    fn invariant_rung_always_covers_observed_usage() {
        check("router never descends below usage", 100, |g| {
            let mut r = BudgetRouter::new(vec![8, 16, 32, 64]).unwrap();
            let mut worst_violation = None;
            for _ in 0..200 {
                let attempts = g.f64_in(1.0, 70.0);
                let success = attempts <= r.budget() as f64;
                r.observe(attempts.min(r.budget() as f64), success);
                // After descending, the last window max must have fit.
                if r.rung() > 0 && attempts > r.budget() as f64 {
                    worst_violation = Some(attempts);
                }
                let _ = worst_violation;
            }
            ensure(r.budget() >= 8, "rung out of range")
        });
    }
}
