//! STEER baseline (Behl et al. 2020) — temporal regularization by
//! stochastically sampling the integration end time during training.
//!
//! The train artifacts expose the end time / save grid as runtime inputs,
//! so STEER lives entirely at L3:
//!  * supervised models: `t1 ~ U(T - b, T + b)` per iteration (paper
//!    §4.1.1 uses T = 1, b = 0.5),
//!  * time-series models: each interior save point `t_i` is perturbed
//!    uniformly within half the neighbouring gaps (paper §4.1.2).

use crate::util::rng::Rng;

/// End-time sampler for supervised (single-span) models.
#[derive(Clone, Copy, Debug)]
pub struct EndTimeSampler {
    pub t_nominal: f64,
    pub b: f64,
}

impl EndTimeSampler {
    pub fn sample(&self, rng: &mut Rng) -> f32 {
        rng.range(self.t_nominal - self.b, self.t_nominal + self.b) as f32
    }
}

/// Perturb interior grid points within half the adjacent gaps, preserving
/// strict monotonicity (time-series STEER, paper §4.1.2).
pub fn perturb_grid(ts: &[f32], rng: &mut Rng) -> Vec<f32> {
    let n = ts.len();
    let mut out = ts.to_vec();
    for i in 1..n - 1 {
        let lo = 0.5 * (ts[i - 1] + ts[i]);
        let hi = 0.5 * (ts[i] + ts[i + 1]);
        out[i] = rng.range(lo as f64, hi as f64) as f32;
    }
    // Monotonicity is preserved by construction (disjoint half-gap windows),
    // but guard against f32 rounding making neighbours equal.
    for i in 1..n {
        if out[i] <= out[i - 1] {
            out[i] = out[i - 1] + f32::EPSILON;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::{check, ensure};

    #[test]
    fn end_time_in_window() {
        let s = EndTimeSampler {
            t_nominal: 1.0,
            b: 0.5,
        };
        let mut rng = Rng::new(1);
        for _ in 0..1000 {
            let t = s.sample(&mut rng) as f64;
            assert!((0.5..1.5).contains(&t));
        }
    }

    #[test]
    fn end_time_covers_window() {
        let s = EndTimeSampler {
            t_nominal: 1.0,
            b: 0.5,
        };
        let mut rng = Rng::new(2);
        let xs: Vec<f64> = (0..2000).map(|_| s.sample(&mut rng) as f64).collect();
        let lo = xs.iter().cloned().fold(f64::INFINITY, f64::min);
        let hi = xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        assert!(lo < 0.6 && hi > 1.4, "lo={lo} hi={hi}");
    }

    #[test]
    fn grid_perturbation_stays_monotone() {
        check("steer grid monotone", 200, |g| {
            let n = g.usize_in(3, 20);
            let mut ts: Vec<f32> = (0..n).map(|i| i as f32 / (n - 1) as f32).collect();
            // irregular grid
            for i in 1..n - 1 {
                ts[i] += g.f32_in(-0.2, 0.2) / n as f32;
            }
            ts.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let mut rng = Rng::new(g.rng.next_u64());
            let p = perturb_grid(&ts, &mut rng);
            ensure(
                p.windows(2).all(|w| w[0] < w[1]),
                format!("not monotone: {p:?}"),
            )?;
            ensure(p[0] == ts[0] && p[n - 1] == ts[n - 1], "endpoints moved")
        });
    }
}
