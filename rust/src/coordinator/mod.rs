//! Layer-3 coordinator: the training-systems half of the paper.
//!
//! The paper's experiments are grids of (method x seed) training runs with
//! per-epoch coefficient schedules and careful NFE/wall-clock accounting.
//! This module owns all of that policy:
//!
//!  * `method`   — the regularization methods compared in Tables 1-4
//!                 (Vanilla / STEER / TayNODE / SRNODE / ERNODE / combos)
//!                 mapped to artifact coefficients,
//!  * `schedule` — exponential coefficient annealing, lr inverse decay and
//!                 KL annealing (paper §4.1.1/§4.1.2),
//!  * `steer`    — the STEER baseline's stochastic end-time sampling,
//!  * `budget`   — **budget-ladder routing**: train artifacts are compiled
//!                 at several masked-scan step budgets; the router watches
//!                 each step's attempt usage and success flag, escalating on
//!                 failure and descending when regularization has pushed the
//!                 NFE down.  This is what converts the paper's "fewer NFE"
//!                 into real training wall-clock reduction under AOT,
//!  * `metrics`  — per-epoch aggregation and run summaries,
//!  * `recorder` — JSON/CSV run records under runs/,
//!  * `experiments` — one driver per paper experiment (Tables 1-4, Figs 2-6).

pub mod budget;
pub mod experiments;
pub mod method;
pub mod metrics;
pub mod recorder;
pub mod schedule;
pub mod steer;

pub use budget::BudgetRouter;
pub use method::Method;
pub use metrics::{EpochRecord, RunResult};
