//! The regularization methods compared in the paper's Tables 1-4, plus
//! the locally regularized follow-up (Pal et al. 2023).

use anyhow::{bail, Result};

/// A training method = a combination of the paper's regularizers/baselines.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct Method {
    /// ERNODE/ERNSDE: error-estimate regularization (paper Eq. 9).
    pub er: bool,
    /// SRNODE/SRNSDE: stiffness regularization (paper Eq. 11).
    pub sr: bool,
    /// LRNODE/LRNSDE: sampled-step *local* error regularization (Pal et
    /// al. 2023) — one uniformly sampled accepted step's `E_ĵ |h_ĵ|`
    /// instead of the global sum.
    pub lr: bool,
    /// STEER baseline: stochastic end time (Behl et al. 2020).
    pub steer: bool,
    /// TayNODE baseline: K-th derivative regularization (Kelly et al. 2020).
    pub taynode: bool,
}

impl Method {
    pub const VANILLA: Method = Method {
        er: false,
        sr: false,
        lr: false,
        steer: false,
        taynode: false,
    };

    pub fn parse(s: &str) -> Result<Method> {
        let mut m = Method::VANILLA;
        if s == "vanilla" {
            return Ok(m);
        }
        for part in s.split('+') {
            match part {
                "ernode" | "ernsde" | "er" => m.er = true,
                "srnode" | "srnsde" | "sr" => m.sr = true,
                "lrnode" | "lrnsde" | "lr" => m.lr = true,
                "steer" => m.steer = true,
                "taynode" | "tay" => m.taynode = true,
                other => bail!(
                    "unknown method component {other:?} \
                     (vanilla|ernode|srnode|lrnode|steer|taynode, '+'-combined)"
                ),
            }
        }
        if m.taynode && (m.er || m.sr || m.lr) {
            bail!("taynode is a standalone baseline in the paper");
        }
        Ok(m)
    }

    /// Paper-style display name ("SRNODE + ERNODE", "Vanilla", ...).
    pub fn label(&self, sde: bool) -> String {
        let suffix = if sde { "NSDE" } else { "NODE" };
        let mut parts = Vec::new();
        if self.steer {
            parts.push("STEER".to_string());
        }
        if self.taynode {
            parts.push("TayNODE".to_string());
        }
        if self.sr {
            parts.push(format!("SR{suffix}"));
        }
        if self.er {
            parts.push(format!("ER{suffix}"));
        }
        if self.lr {
            parts.push(format!("LR{suffix}"));
        }
        if parts.is_empty() {
            format!("Vanilla {suffix}")
        } else {
            parts.join(" + ")
        }
    }

    /// The method grid of Table 1/2 (ODE experiments), extended with the
    /// local-regularization variant.
    pub fn table_grid_ode() -> Vec<Method> {
        [
            "vanilla",
            "steer",
            "taynode",
            "srnode",
            "ernode",
            "lrnode",
            "steer+srnode",
            "steer+ernode",
            "srnode+ernode",
        ]
        .iter()
        .map(|s| Method::parse(s).unwrap())
        .collect()
    }

    /// The method grid of Table 3/4 (SDE experiments), extended with the
    /// local-regularization variant.
    pub fn table_grid_sde() -> Vec<Method> {
        ["vanilla", "srnsde", "ernsde", "lrnsde"]
            .iter()
            .map(|s| Method::parse(s).unwrap())
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_combos() {
        let m = Method::parse("steer+ernode").unwrap();
        assert!(m.steer && m.er && !m.sr && !m.lr && !m.taynode);
        assert_eq!(m.label(false), "STEER + ERNODE");
        assert_eq!(Method::parse("vanilla").unwrap(), Method::VANILLA);
    }

    #[test]
    fn parse_lrnode() {
        let m = Method::parse("lrnode").unwrap();
        assert!(m.lr && !m.er && !m.sr);
        assert_eq!(m.label(false), "LRNODE");
        assert_eq!(Method::parse("lrnsde").unwrap().label(true), "LRNSDE");
        let combo = Method::parse("srnode+lrnode").unwrap();
        assert!(combo.sr && combo.lr);
        assert_eq!(combo.label(false), "SRNODE + LRNODE");
    }

    #[test]
    fn parse_rejects_bad() {
        assert!(Method::parse("magic").is_err());
        assert!(Method::parse("taynode+ernode").is_err());
        assert!(Method::parse("taynode+lrnode").is_err());
    }

    #[test]
    fn sde_labels() {
        assert_eq!(Method::parse("er").unwrap().label(true), "ERNSDE");
        assert_eq!(
            Method::parse("sr+er").unwrap().label(true),
            "SRNSDE + ERNSDE"
        );
    }

    #[test]
    fn grids_match_paper_plus_local() {
        assert_eq!(Method::table_grid_ode().len(), 9);
        assert_eq!(Method::table_grid_sde().len(), 4);
        assert!(Method::table_grid_ode().iter().any(|m| m.lr));
        assert!(Method::table_grid_sde().iter().any(|m| m.lr));
    }
}
