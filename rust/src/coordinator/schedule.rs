//! Coefficient schedules (paper §4.1.1, §4.1.2).
//!
//! * Exponential annealing of the error-regularization coefficient
//!   (MNIST: 100 -> 10 over 75 epochs; Physionet: 1000 -> 100 over 300),
//! * Flux.jl-style inverse learning-rate decay `lr0 / (1 + gamma * iter)`,
//! * KL annealing `1 - rho^epoch` for the Latent ODE ELBO.

/// Exponential interpolation from `start` to `end` over `total` epochs.
#[derive(Clone, Copy, Debug)]
pub struct ExpAnneal {
    pub start: f64,
    pub end: f64,
    pub total_epochs: usize,
}

impl ExpAnneal {
    pub fn at(&self, epoch: usize) -> f64 {
        if self.total_epochs <= 1 {
            return self.end;
        }
        let frac = (epoch as f64 / (self.total_epochs - 1) as f64).clamp(0.0, 1.0);
        self.start * (self.end / self.start).powf(frac)
    }
}

/// Flux.jl `InvDecay`: lr_t = lr0 / (1 + gamma * t).
#[derive(Clone, Copy, Debug)]
pub struct InvDecay {
    pub lr0: f64,
    pub gamma: f64,
}

impl InvDecay {
    pub fn at(&self, iter: u64) -> f64 {
        self.lr0 / (1.0 + self.gamma * iter as f64)
    }
}

/// KL annealing: coefficient 1 - rho^(epoch+1) ramping toward 1.
#[derive(Clone, Copy, Debug)]
pub struct KlAnneal {
    pub rho: f64,
}

impl KlAnneal {
    pub fn at(&self, epoch: usize) -> f64 {
        1.0 - self.rho.powi(epoch as i32 + 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exp_anneal_endpoints() {
        let a = ExpAnneal {
            start: 100.0,
            end: 10.0,
            total_epochs: 75,
        };
        assert!((a.at(0) - 100.0).abs() < 1e-9);
        assert!((a.at(74) - 10.0).abs() < 1e-9);
        // geometric midpoint at the middle epoch
        let mid = a.at(37);
        assert!(mid < 100.0 && mid > 10.0);
        assert!((a.at(37) / a.at(38) - a.at(10) / a.at(11)).abs() < 1e-6);
    }

    #[test]
    fn exp_anneal_monotone_decreasing() {
        let a = ExpAnneal {
            start: 1000.0,
            end: 100.0,
            total_epochs: 300,
        };
        for e in 1..300 {
            assert!(a.at(e) < a.at(e - 1));
        }
    }

    #[test]
    fn exp_anneal_clamps_past_end() {
        let a = ExpAnneal {
            start: 100.0,
            end: 10.0,
            total_epochs: 10,
        };
        assert!((a.at(50) - 10.0).abs() < 1e-9);
    }

    #[test]
    fn inv_decay() {
        let d = InvDecay {
            lr0: 0.1,
            gamma: 1e-5,
        };
        assert_eq!(d.at(0), 0.1);
        assert!((d.at(100_000) - 0.05).abs() < 1e-9);
    }

    #[test]
    fn kl_anneal_ramps_to_one() {
        let k = KlAnneal { rho: 0.99 };
        assert!(k.at(0) < 0.02);
        assert!(k.at(500) > 0.99);
        for e in 1..100 {
            assert!(k.at(e) > k.at(e - 1));
        }
    }
}
