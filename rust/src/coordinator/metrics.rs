//! Run-level metric aggregation: per-epoch records and run summaries.

use crate::runtime::state::Metrics;
use crate::util::json::{obj, Json};

/// Aggregated metrics for one training epoch.
#[derive(Clone, Copy, Debug, Default)]
pub struct EpochRecord {
    pub epoch: usize,
    pub loss: f64,
    pub metric: f64,
    pub nfe: f64,
    pub naccept: f64,
    pub nreject: f64,
    pub r_e: f64,
    /// `Σ E_j²` variant accumulator (native backend; 0 on PJRT).
    pub r_e2: f64,
    pub r_s: f64,
    /// Sampled-step local regularizer `R_L` (LRNODE/LRNSDE; native
    /// backend, 0 elsewhere or when the method is off).
    pub r_l: f64,
    pub wall_s: f64,
    pub rung: usize,
}

impl EpochRecord {
    pub fn to_json(&self) -> Json {
        obj([
            ("epoch", self.epoch.into()),
            ("loss", self.loss.into()),
            ("metric", self.metric.into()),
            ("nfe", self.nfe.into()),
            ("naccept", self.naccept.into()),
            ("nreject", self.nreject.into()),
            ("r_e", self.r_e.into()),
            ("r_e2", self.r_e2.into()),
            ("r_s", self.r_s.into()),
            ("r_l", self.r_l.into()),
            ("wall_s", self.wall_s.into()),
            ("rung", self.rung.into()),
        ])
    }
}

/// Accumulates step metrics into an epoch average.
#[derive(Debug, Default)]
pub struct EpochAccumulator {
    n: usize,
    sums: EpochRecord,
}

impl EpochAccumulator {
    pub fn push(&mut self, m: &Metrics) {
        self.n += 1;
        self.sums.loss += m.loss;
        self.sums.metric += m.metric;
        self.sums.nfe += m.nfe;
        self.sums.naccept += m.naccept;
        self.sums.nreject += m.nreject;
        self.sums.r_e += m.r_e;
        self.sums.r_e2 += m.r_e2;
        self.sums.r_s += m.r_s;
        self.sums.r_l += m.r_l;
    }

    pub fn finish(self, epoch: usize, wall_s: f64, rung: usize) -> EpochRecord {
        let n = self.n.max(1) as f64;
        EpochRecord {
            epoch,
            loss: self.sums.loss / n,
            metric: self.sums.metric / n,
            nfe: self.sums.nfe / n,
            naccept: self.sums.naccept / n,
            nreject: self.sums.nreject / n,
            r_e: self.sums.r_e / n,
            r_e2: self.sums.r_e2 / n,
            r_s: self.sums.r_s / n,
            r_l: self.sums.r_l / n,
            wall_s,
            rung,
        }
    }

    pub fn count(&self) -> usize {
        self.n
    }
}

/// Full result of one (method, seed) training run.
#[derive(Clone, Debug)]
pub struct RunResult {
    pub experiment: String,
    pub method: String,
    pub seed: u64,
    pub epochs: Vec<EpochRecord>,
    /// Total training wall-clock (seconds).
    pub train_time_s: f64,
    /// One-batch prediction wall-clock (seconds).
    pub predict_time_s: f64,
    /// NFE of the prediction solve.
    pub predict_nfe: f64,
    /// Final train-set metric (accuracy or MSE).
    pub final_train_metric: f64,
    /// Held-out metric.
    pub final_test_metric: f64,
    pub final_train_loss: f64,
    pub final_test_loss: f64,
    /// Router telemetry.
    pub escalations: u64,
    pub descents: u64,
    /// Final committed flat parameters — what `--checkpoint` persists
    /// via `Backend::export_state` (kept out of the JSON run record,
    /// which stays a lean metrics trace; the serving checkpoint is the
    /// parameter artifact).
    pub final_params: Vec<f32>,
    /// Final optimizer state (Adam moments) — persisted by checkpoint
    /// v2's train block so `--resume` continues bit-identically; kept
    /// out of the JSON run record like `final_params`.
    pub final_opt_state: Vec<f32>,
    /// Completed optimizer iterations (lr-decay position).
    pub final_iter: u64,
    /// Budget-ladder rung at the end of the run.
    pub final_rung: usize,
    /// Budget-router descent window at the end of the run (checkpoint
    /// v2; lets a resumed router replay descent decisions exactly).
    pub final_window: Vec<f64>,
    /// Total epochs completed across the whole run, resumed segments
    /// included (`epoch0 + opts.epochs`).
    pub epochs_done: usize,
}

impl RunResult {
    pub fn to_json(&self) -> Json {
        obj([
            ("experiment", self.experiment.as_str().into()),
            ("method", self.method.as_str().into()),
            ("seed", (self.seed as usize).into()),
            (
                "epochs",
                Json::Arr(self.epochs.iter().map(|e| e.to_json()).collect()),
            ),
            ("train_time_s", self.train_time_s.into()),
            ("predict_time_s", self.predict_time_s.into()),
            ("predict_nfe", self.predict_nfe.into()),
            ("final_train_metric", self.final_train_metric.into()),
            ("final_test_metric", self.final_test_metric.into()),
            ("final_train_loss", self.final_train_loss.into()),
            ("final_test_loss", self.final_test_loss.into()),
            ("escalations", (self.escalations as usize).into()),
            ("descents", (self.descents as usize).into()),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulator_averages() {
        let mut acc = EpochAccumulator::default();
        for i in 0..4 {
            acc.push(&Metrics {
                loss: i as f64,
                nfe: 10.0 * i as f64,
                r_e2: 2.0 * i as f64,
                ..Default::default()
            });
        }
        let rec = acc.finish(3, 1.5, 1);
        assert_eq!(rec.loss, 1.5);
        assert_eq!(rec.nfe, 15.0);
        assert_eq!(rec.r_e2, 3.0, "r_e2 must ride the epoch average");
        assert_eq!(rec.epoch, 3);
        assert_eq!(rec.rung, 1);
        let j = rec.to_json();
        assert!(j.get("r_e2").is_some(), "r_e2 must be recorded");
        assert!(j.get("r_l").is_some(), "r_l must be recorded");
    }

    #[test]
    fn empty_accumulator_is_safe() {
        let rec = EpochAccumulator::default().finish(0, 0.0, 0);
        assert_eq!(rec.loss, 0.0);
    }

    #[test]
    fn run_result_serializes() {
        let r = RunResult {
            experiment: "t1".into(),
            method: "ERNODE".into(),
            seed: 3,
            epochs: vec![EpochRecord::default()],
            train_time_s: 10.0,
            predict_time_s: 0.1,
            predict_nfe: 177.0,
            final_train_metric: 0.99,
            final_test_metric: 0.97,
            final_train_loss: 0.05,
            final_test_loss: 0.08,
            escalations: 1,
            descents: 2,
            final_params: vec![0.5; 3],
            final_opt_state: vec![0.0; 6],
            final_iter: 10,
            final_rung: 1,
            final_window: vec![3.0],
            epochs_done: 1,
        };
        let j = r.to_json();
        assert_eq!(j.get("method").unwrap().as_str().unwrap(), "ERNODE");
        assert_eq!(j.get("epochs").unwrap().as_arr().unwrap().len(), 1);
    }
}
