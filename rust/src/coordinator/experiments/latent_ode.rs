//! Physionet Latent ODE experiment driver — paper §4.1.2 (Table 2, Fig 4).
//!
//! Paper setting: B=512, Adamax(0.01) + InvDecay(1e-5), 300 epochs,
//! coef_e annealed 1000 -> 100, coef_s = 0.285, KL annealing rho = 0.99,
//! TayNODE K=2 with coefficient 0.01, STEER = interior-grid perturbation.
//! Testbed scale: synthetic vitals (physionet_synth), B=32, T=16.

use anyhow::Result;

use crate::coordinator::budget::BudgetRouter;
use crate::coordinator::method::Method;
use crate::coordinator::metrics::{EpochAccumulator, RunResult};
use crate::coordinator::schedule::{ExpAnneal, InvDecay, KlAnneal};
use crate::coordinator::steer;
use crate::data::{batcher::Batcher, physionet_synth};
use crate::runtime::state::{Metrics, TrainState};
use crate::runtime::{Backend, StepCoefs, TrainData};
use crate::util::rng::Rng;
use crate::util::timer::Stopwatch;

pub const MODEL: &str = "latent_ode";
const BATCH: usize = 32;
const T: usize = 16;
const D: usize = physionet_synth::CHANNELS;

pub fn run(backend: &dyn Backend, method: Method, opts: super::TrainOpts) -> Result<RunResult> {
    run_with(backend, method, opts, None)
}

/// [`run`] continuing from a checkpointed training position
/// (`opts.epochs` = additional epochs; see `super::ResumeState`).
pub fn run_with(
    backend: &dyn Backend,
    method: Method,
    opts: super::TrainOpts,
    resume: Option<&super::ResumeState>,
) -> Result<RunResult> {
    let info = backend.model(MODEL)?;
    let get = |k: &str| -> f64 { info.hyper.get(k).copied().unwrap_or(0.0) };
    let epoch0 = resume.map_or(0, |r| r.epochs_done);

    let lr = InvDecay {
        lr0: get("lr"),
        gamma: get("inv_decay"),
    };
    // Anneals over the whole run's epoch target — completed epochs
    // included, the checkpointed target preferred — so resume sees the
    // same coefficient at epoch e as the original run.
    let coef_e = method.er.then(|| ExpAnneal {
        start: get("coef_e_start"),
        end: get("coef_e_end"),
        total_epochs: super::schedule_epochs(resume, opts.epochs),
    });
    let coef_s = if method.sr { get("coef_s") } else { 0.0 };
    let coef_l = if method.lr { get("coef_l") } else { 0.0 };
    let coef_aux = if method.taynode { get("taylor_coef") } else { 0.0 };
    let kl = KlAnneal {
        rho: get("kl_anneal"),
    };

    let n_train = (opts.iters_per_epoch * BATCH).max(BATCH * 4);
    let train = physionet_synth::generate(n_train, T, opts.seed);
    let test = physionet_synth::generate(BATCH * 2, T, opts.seed ^ 0xDEAD);

    let mut router = BudgetRouter::new(backend.ladder(MODEL, method.taynode)?)?;
    let mut state = TrainState::new(
        backend.init_params(MODEL, opts.seed as u32)?,
        info.opt_state_size,
    );
    let mut rng = Rng::new(opts.seed ^ 0x7EED);
    let mut batcher = Batcher::new(train.n, BATCH, opts.seed);

    if let Some(r) = resume {
        super::apply_resume(&mut state, &mut router, r)?;
    }
    // Fast-forward the batch order and RNG streams past the completed
    // epochs, replaying the exact per-iteration call order (batch draw,
    // optional STEER grid perturbation, seed draw).
    for _ in 0..epoch0 * opts.iters_per_epoch {
        let _ = batcher.next_batch();
        if method.steer {
            let _ = steer::perturb_grid(&train.ts, &mut rng);
        }
        let _ = rng.next_u32();
    }

    let sz = T * D;
    backend.warm(MODEL, method.taynode)?;

    let mut sw = Stopwatch::new();
    let mut epochs_out = Vec::with_capacity(opts.epochs);
    let (mut bx, mut bm) = (Vec::new(), Vec::new());

    for epoch in epoch0..epoch0 + opts.epochs {
        let mut acc = EpochAccumulator::default();
        let t0 = std::time::Instant::now();
        sw.start();
        for _ in 0..opts.iters_per_epoch {
            let idx = batcher.next_batch().to_vec();
            Batcher::gather(&train.values, sz, &idx, &mut bx);
            Batcher::gather(&train.masks, sz, &idx, &mut bm);
            let ts = if method.steer {
                steer::perturb_grid(&train.ts, &mut rng)
            } else {
                train.ts.clone()
            };
            let step = StepCoefs {
                lr: lr.at(state.iter) as f32,
                coef_e: coef_e.map_or(0.0, |a| a.at(epoch)) as f32,
                coef_s: coef_s as f32,
                coef_l: coef_l as f32,
                coef_aux: coef_aux as f32,
                kl: kl.at(epoch) as f32,
                seed: rng.next_u32(),
                ..Default::default()
            };
            let m = super::routed_step(
                backend,
                MODEL,
                method.taynode,
                &mut router,
                &mut state,
                &TrainData::Series {
                    x: &bx,
                    mask: &bm,
                    ts: &ts,
                },
                &step,
            )?;
            acc.push(&m);
        }
        sw.stop();
        anyhow::ensure!(state.is_finite(), "parameters diverged at epoch {epoch}");
        let rec = acc.finish(epoch, t0.elapsed().as_secs_f64(), router.rung());
        if opts.verbose {
            println!(
                "[{}] epoch {epoch}: loss {:.4} mse {:.4} nfe {:.1} rung {} ({:.1}s)",
                method.label(false),
                rec.loss,
                rec.metric,
                rec.nfe,
                rec.rung,
                rec.wall_s
            );
        }
        epochs_out.push(rec);
    }

    // Evaluation through the early-exiting predict path.
    let eval = |data: &physionet_synth::Dataset, batches: usize| -> Result<(Metrics, f64)> {
        let mut ms = Vec::new();
        let mut secs = Vec::new();
        for b in 0..batches {
            let xs = &data.values[b * BATCH * sz..(b + 1) * BATCH * sz];
            let mk = &data.masks[b * BATCH * sz..(b + 1) * BATCH * sz];
            let t0 = std::time::Instant::now();
            let (_, m) = backend.predict(
                MODEL,
                &state.params,
                &TrainData::Series {
                    x: xs,
                    mask: mk,
                    ts: &data.ts,
                },
                12345,
            )?;
            secs.push(t0.elapsed().as_secs_f64());
            ms.push(m);
        }
        let n = ms.len().max(1) as f64;
        Ok((
            Metrics {
                loss: ms.iter().map(|m| m.loss).sum::<f64>() / n,
                metric: ms.iter().map(|m| m.metric).sum::<f64>() / n,
                nfe: ms.iter().map(|m| m.nfe).sum::<f64>() / n,
                ..Default::default()
            },
            secs.iter().sum::<f64>() / n,
        ))
    };
    let (train_eval, _) = eval(&train, 2)?;
    let (test_eval, pred_s) = eval(&test, 2)?;

    Ok(RunResult {
        experiment: "table2_physionet".into(),
        method: method.label(false),
        seed: opts.seed,
        epochs: epochs_out,
        train_time_s: sw.total_secs(),
        predict_time_s: pred_s,
        predict_nfe: test_eval.nfe,
        final_train_metric: train_eval.metric,
        final_test_metric: test_eval.metric,
        final_train_loss: train_eval.loss,
        final_test_loss: test_eval.loss,
        escalations: router.escalations,
        descents: router.descents,
        final_opt_state: state.opt_state,
        final_iter: state.iter,
        final_rung: router.rung(),
        final_window: router.window().to_vec(),
        epochs_done: epoch0 + opts.epochs,
        final_params: state.params,
    })
}
