//! Physionet Latent ODE experiment driver — paper §4.1.2 (Table 2, Fig 4).
//!
//! Paper setting: B=512, Adamax(0.01) + InvDecay(1e-5), 300 epochs,
//! coef_e annealed 1000 -> 100, coef_s = 0.285, KL annealing rho = 0.99,
//! TayNODE K=2 with coefficient 0.01, STEER = interior-grid perturbation.
//! Testbed scale: synthetic vitals (physionet_synth), B=32, T=16.

use anyhow::{Context, Result};

use crate::coordinator::budget::BudgetRouter;
use crate::coordinator::method::Method;
use crate::coordinator::metrics::{EpochAccumulator, RunResult};
use crate::coordinator::schedule::{ExpAnneal, InvDecay, KlAnneal};
use crate::coordinator::steer;
use crate::data::{batcher::Batcher, physionet_synth};
use crate::runtime::state::{Metrics, TrainState};
use crate::runtime::{Engine, Input};
use crate::util::rng::Rng;
use crate::util::timer::Stopwatch;

pub const MODEL: &str = "latent_ode";
const BATCH: usize = 32;
const T: usize = 16;
const D: usize = physionet_synth::CHANNELS;

pub fn run(engine: &Engine, method: Method, opts: super::TrainOpts) -> Result<RunResult> {
    let spec = engine.manifest.model(MODEL)?.clone();
    let h = &spec.hyper;
    let get = |k: &str| -> f64 { *h.get(k).unwrap_or(&0.0) };

    let lr = InvDecay {
        lr0: get("lr"),
        gamma: get("inv_decay"),
    };
    let coef_e = method.er.then(|| ExpAnneal {
        start: get("coef_e_start"),
        end: get("coef_e_end"),
        total_epochs: opts.epochs,
    });
    let coef_s = if method.sr { get("coef_s") } else { 0.0 };
    let coef_aux = if method.taynode { get("taylor_coef") } else { 0.0 };
    let kl = KlAnneal {
        rho: get("kl_anneal"),
    };

    let n_train = (opts.iters_per_epoch * BATCH).max(BATCH * 4);
    let train = physionet_synth::generate(n_train, T, opts.seed);
    let test = physionet_synth::generate(BATCH * 2, T, opts.seed ^ 0xDEAD);

    let ladder: Vec<_> = engine
        .manifest
        .train_ladder(MODEL, method.taynode)
        .into_iter()
        .cloned()
        .collect();
    anyhow::ensure!(!ladder.is_empty(), "no train artifacts for {MODEL}");
    let mut router = BudgetRouter::new(
        ladder.iter().map(|a| a.budget.unwrap_or(usize::MAX)).collect(),
    )?;

    let mut state = TrainState::new(
        engine.init_params(MODEL, opts.seed as u32)?,
        spec.opt_state_size,
    );
    let mut rng = Rng::new(opts.seed ^ 0x7EED);
    let mut batcher = Batcher::new(train.n, BATCH, opts.seed);

    let sz = T * D;
    // Pre-compile every rung + the predict artifact so the stopwatch
    // measures steady-state training, not PJRT JIT.
    for art in &ladder {
        engine.load(&art.name)?;
    }
    engine.load(&format!("{MODEL}_predict"))?;

    let mut sw = Stopwatch::new();
    let mut epochs_out = Vec::with_capacity(opts.epochs);
    let (mut bx, mut bm) = (Vec::new(), Vec::new());

    for epoch in 0..opts.epochs {
        let mut acc = EpochAccumulator::default();
        let t0 = std::time::Instant::now();
        sw.start();
        for _ in 0..opts.iters_per_epoch {
            let idx = batcher.next_batch().to_vec();
            Batcher::gather(&train.values, sz, &idx, &mut bx);
            Batcher::gather(&train.masks, sz, &idx, &mut bm);
            let ts = if method.steer {
                steer::perturb_grid(&train.ts, &mut rng)
            } else {
                train.ts.clone()
            };
            let lr_t = lr.at(state.iter) as f32;
            let ce = coef_e.map_or(0.0, |a| a.at(epoch)) as f32;
            let kl_t = kl.at(epoch) as f32;
            let seed = rng.next_u32();
            loop {
                let art = &ladder[router.rung()];
                let out = engine
                    .run_spec(
                        art,
                        &[
                            Input::F32(&state.params),
                            Input::F32(&state.opt_state),
                            Input::F32(&bx),
                            Input::F32(&bm),
                            Input::F32(&ts),
                            Input::Scalar(lr_t),
                            Input::Scalar(ce),
                            Input::Scalar(coef_s as f32),
                            Input::Scalar(coef_aux as f32),
                            Input::Scalar(kl_t),
                            Input::SeedU32(seed),
                        ],
                    )
                    .with_context(|| format!("train step on {}", art.name))?;
                let [params, opt_state, metrics]: [Vec<f32>; 3] =
                    out.try_into().ok().context("train step arity")?;
                let m = Metrics::decode(&metrics)?;
                if router.observe(m.naccept + m.nreject, m.success) {
                    continue;
                }
                state.update(params, opt_state)?;
                acc.push(&m);
                break;
            }
        }
        sw.stop();
        anyhow::ensure!(state.is_finite(), "parameters diverged at epoch {epoch}");
        let rec = acc.finish(epoch, t0.elapsed().as_secs_f64(), router.rung());
        if opts.verbose {
            println!(
                "[{}] epoch {epoch}: loss {:.4} mse {:.4} nfe {:.1} rung {} ({:.1}s)",
                method.label(false),
                rec.loss,
                rec.metric,
                rec.nfe,
                rec.rung,
                rec.wall_s
            );
        }
        epochs_out.push(rec);
    }

    // Evaluation through the early-exiting predict artifact.
    let eval = |data: &physionet_synth::Dataset, batches: usize| -> Result<(Metrics, f64)> {
        let mut ms = Vec::new();
        let mut secs = Vec::new();
        for b in 0..batches {
            let xs = &data.values[b * BATCH * sz..(b + 1) * BATCH * sz];
            let mk = &data.masks[b * BATCH * sz..(b + 1) * BATCH * sz];
            let t0 = std::time::Instant::now();
            let out = engine.run(
                &format!("{MODEL}_predict"),
                &[
                    Input::F32(&state.params),
                    Input::F32(xs),
                    Input::F32(mk),
                    Input::F32(&data.ts),
                    Input::SeedU32(12345),
                ],
            )?;
            secs.push(t0.elapsed().as_secs_f64());
            ms.push(Metrics::decode(&out[1])?);
        }
        let n = ms.len().max(1) as f64;
        Ok((
            Metrics {
                loss: ms.iter().map(|m| m.loss).sum::<f64>() / n,
                metric: ms.iter().map(|m| m.metric).sum::<f64>() / n,
                nfe: ms.iter().map(|m| m.nfe).sum::<f64>() / n,
                ..Default::default()
            },
            secs.iter().sum::<f64>() / n,
        ))
    };
    engine.load(&format!("{MODEL}_predict"))?;
    let (train_eval, _) = eval(&train, 2)?;
    let (test_eval, pred_s) = eval(&test, 2)?;

    Ok(RunResult {
        experiment: "table2_physionet".into(),
        method: method.label(false),
        seed: opts.seed,
        epochs: epochs_out,
        train_time_s: sw.total_secs(),
        predict_time_s: pred_s,
        predict_nfe: test_eval.nfe,
        final_train_metric: train_eval.metric,
        final_test_metric: test_eval.metric,
        final_train_loss: train_eval.loss,
        final_test_loss: test_eval.loss,
        escalations: router.escalations,
        descents: router.descents,
    })
}
