//! Spiral Neural SDE driver — paper §4.2.1 (Table 3, Figure 5).
//!
//! Paper setting: AdaBelief(0.01), 250 iterations, GMM moment loss over 30
//! save points, data = 10k trajectories of the spiral DSDE (Eq. 15).  The
//! ground-truth moments come from the native Rust SDE solver ensemble
//! (data::spiral::spiral_sde_moments); the model predicts a fresh ensemble
//! each iteration with a coordinator-supplied seed.

use anyhow::Result;

use crate::coordinator::budget::BudgetRouter;
use crate::coordinator::method::Method;
use crate::coordinator::metrics::{EpochAccumulator, RunResult};
use crate::data::spiral;
use crate::runtime::state::TrainState;
use crate::runtime::{Backend, StepCoefs, TrainData};
use crate::util::rng::Rng;
use crate::util::timer::Stopwatch;

pub const MODEL: &str = "spiral_nsde";
const N_TRAJ: usize = 64;
const T: usize = 30;
const SPAN: f64 = 1.0;
/// Ensemble size behind the ground-truth moments (paper: 10_000; scaled
/// to keep data generation snappy while moments stay tight).
const DATA_ENSEMBLE: usize = 2000;

/// Ground-truth inputs: (u0 tiled, data_mu, data_var, ts).
pub fn ground_truth(seed: u64) -> (Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>) {
    let ts = spiral::uniform_grid(T, SPAN);
    let (mu, var) = spiral::spiral_sde_moments([1.0, 1.0], &ts, DATA_ENSEMBLE, seed);
    let mut u0 = Vec::with_capacity(N_TRAJ * 2);
    for _ in 0..N_TRAJ {
        u0.extend_from_slice(&[1.0, 1.0]);
    }
    (u0, mu, var, ts.iter().map(|&t| t as f32).collect())
}

pub fn run(backend: &dyn Backend, method: Method, opts: super::TrainOpts) -> Result<RunResult> {
    run_with(backend, method, opts, None)
}

/// [`run`] continuing from a checkpointed training position
/// (`opts.epochs` = additional epochs; see `super::ResumeState`).
pub fn run_with(
    backend: &dyn Backend,
    method: Method,
    opts: super::TrainOpts,
    resume: Option<&super::ResumeState>,
) -> Result<RunResult> {
    let info = backend.model(MODEL)?;
    let get = |k: &str| -> f64 { info.hyper.get(k).copied().unwrap_or(0.0) };
    let base_coefs = StepCoefs {
        lr: get("lr") as f32,
        coef_e: if method.er { get("coef_e") as f32 } else { 0.0 },
        coef_s: if method.sr { get("coef_s") as f32 } else { 0.0 },
        coef_l: if method.lr { get("coef_l") as f32 } else { 0.0 },
        ..Default::default()
    };

    let (u0, data_mu, data_var, ts) = ground_truth(opts.seed);
    let train_data = TrainData::Moments {
        u0: &u0,
        mu: &data_mu,
        var: &data_var,
        ts: &ts,
    };

    let mut router = BudgetRouter::new(backend.ladder(MODEL, false)?)?;
    let mut state = TrainState::new(
        backend.init_params(MODEL, opts.seed as u32)?,
        info.opt_state_size,
    );
    let mut rng = Rng::new(opts.seed ^ 0x51DE);

    let epoch0 = resume.map_or(0, |r| r.epochs_done);
    if let Some(r) = resume {
        super::apply_resume(&mut state, &mut router, r)?;
    }
    // Fast-forward the per-iteration seed stream past completed epochs
    // (one draw per iteration), matching the uninterrupted run.
    for _ in 0..epoch0 * opts.iters_per_epoch {
        let _ = rng.next_u32();
    }

    backend.warm(MODEL, false)?;

    let mut sw = Stopwatch::new();
    let mut epochs_out = Vec::with_capacity(opts.epochs);
    for epoch in epoch0..epoch0 + opts.epochs {
        let mut acc = EpochAccumulator::default();
        let t0 = std::time::Instant::now();
        sw.start();
        for _ in 0..opts.iters_per_epoch {
            let coefs = StepCoefs {
                seed: rng.next_u32(),
                ..base_coefs
            };
            let m = super::routed_step(
                backend,
                MODEL,
                false,
                &mut router,
                &mut state,
                &train_data,
                &coefs,
            )?;
            acc.push(&m);
        }
        sw.stop();
        anyhow::ensure!(state.is_finite(), "parameters diverged at epoch {epoch}");
        let rec = acc.finish(epoch, t0.elapsed().as_secs_f64(), router.rung());
        if opts.verbose {
            println!(
                "[{}] epoch {epoch}: gmm {:.4} nfe {:.1} rung {} ({:.2}s)",
                method.label(true),
                rec.loss,
                rec.nfe,
                rec.rung,
                rec.wall_s
            );
        }
        epochs_out.push(rec);
    }

    let t0 = std::time::Instant::now();
    let (_, m) = backend.predict(MODEL, &state.params, &train_data, 999)?;
    let pred_s = t0.elapsed().as_secs_f64();

    Ok(RunResult {
        experiment: "table3_spiral_sde".into(),
        method: method.label(true),
        seed: opts.seed,
        epochs: epochs_out,
        train_time_s: sw.total_secs(),
        predict_time_s: pred_s,
        predict_nfe: m.nfe,
        final_train_metric: m.metric,
        final_test_metric: m.metric,
        final_train_loss: m.loss,
        final_test_loss: m.loss,
        escalations: router.escalations,
        descents: router.descents,
        final_opt_state: state.opt_state,
        final_iter: state.iter,
        final_rung: router.rung(),
        final_window: router.window().to_vec(),
        epochs_done: epoch0 + opts.epochs,
        final_params: state.params,
    })
}

/// Predicted ensemble at the save grid (Figure 5 series: [T, N_TRAJ, 2]).
pub fn predict_ensemble(backend: &dyn Backend, params: &[f32], seed: u32) -> Result<Vec<f32>> {
    let (u0, data_mu, data_var, ts) = ground_truth(0);
    let (ens, _) = backend.predict(
        MODEL,
        params,
        &TrainData::Moments {
            u0: &u0,
            mu: &data_mu,
            var: &data_var,
            ts: &ts,
        },
        seed,
    )?;
    Ok(ens)
}
