//! Spiral Neural SDE driver — paper §4.2.1 (Table 3, Figure 5).
//!
//! Paper setting: AdaBelief(0.01), 250 iterations, GMM moment loss over 30
//! save points, data = 10k trajectories of the spiral DSDE (Eq. 15).  The
//! ground-truth moments come from the native Rust SDE solver ensemble
//! (data::spiral::spiral_sde_moments); the model predicts a fresh ensemble
//! each iteration with a coordinator-supplied seed.

use anyhow::{Context, Result};

use crate::coordinator::budget::BudgetRouter;
use crate::coordinator::method::Method;
use crate::coordinator::metrics::{EpochAccumulator, RunResult};
use crate::data::spiral;
use crate::runtime::state::{Metrics, TrainState};
use crate::runtime::{Engine, Input};
use crate::util::rng::Rng;
use crate::util::timer::Stopwatch;

pub const MODEL: &str = "spiral_nsde";
const N_TRAJ: usize = 64;
const T: usize = 30;
const SPAN: f64 = 1.0;
/// Ensemble size behind the ground-truth moments (paper: 10_000; scaled
/// to keep data generation snappy while moments stay tight).
const DATA_ENSEMBLE: usize = 2000;

/// Ground-truth inputs: (u0 tiled, data_mu, data_var, ts).
pub fn ground_truth(seed: u64) -> (Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>) {
    let ts = spiral::uniform_grid(T, SPAN);
    let (mu, var) = spiral::spiral_sde_moments([1.0, 1.0], &ts, DATA_ENSEMBLE, seed);
    let mut u0 = Vec::with_capacity(N_TRAJ * 2);
    for _ in 0..N_TRAJ {
        u0.extend_from_slice(&[1.0, 1.0]);
    }
    (u0, mu, var, ts.iter().map(|&t| t as f32).collect())
}

pub fn run(engine: &Engine, method: Method, opts: super::TrainOpts) -> Result<RunResult> {
    let spec = engine.manifest.model(MODEL)?.clone();
    let h = &spec.hyper;
    let get = |k: &str| -> f64 { *h.get(k).unwrap_or(&0.0) };
    let lr = get("lr");
    let ce = if method.er { get("coef_e") } else { 0.0 };
    let cs = if method.sr { get("coef_s") } else { 0.0 };

    let (u0, data_mu, data_var, ts) = ground_truth(opts.seed);

    let ladder: Vec<_> = engine
        .manifest
        .train_ladder(MODEL, false)
        .into_iter()
        .cloned()
        .collect();
    let mut router = BudgetRouter::new(
        ladder.iter().map(|a| a.budget.unwrap_or(usize::MAX)).collect(),
    )?;

    let mut state = TrainState::new(
        engine.init_params(MODEL, opts.seed as u32)?,
        spec.opt_state_size,
    );
    let mut rng = Rng::new(opts.seed ^ 0x51DE);

    // Pre-compile every rung + the predict artifact so the stopwatch
    // measures steady-state training, not PJRT JIT.
    for art in &ladder {
        engine.load(&art.name)?;
    }
    engine.load(&format!("{MODEL}_predict"))?;

    let mut sw = Stopwatch::new();
    let mut epochs_out = Vec::with_capacity(opts.epochs);
    for epoch in 0..opts.epochs {
        let mut acc = EpochAccumulator::default();
        let t0 = std::time::Instant::now();
        sw.start();
        for _ in 0..opts.iters_per_epoch {
            let seed = rng.next_u32();
            loop {
                let art = &ladder[router.rung()];
                let out = engine
                    .run_spec(
                        art,
                        &[
                            Input::F32(&state.params),
                            Input::F32(&state.opt_state),
                            Input::F32(&u0),
                            Input::F32(&data_mu),
                            Input::F32(&data_var),
                            Input::F32(&ts),
                            Input::Scalar(lr as f32),
                            Input::Scalar(ce as f32),
                            Input::Scalar(cs as f32),
                            Input::SeedU32(seed),
                        ],
                    )
                    .with_context(|| format!("train step on {}", art.name))?;
                let [params, opt_state, metrics]: [Vec<f32>; 3] =
                    out.try_into().ok().context("train step arity")?;
                let m = Metrics::decode(&metrics)?;
                if router.observe(m.naccept + m.nreject, m.success) {
                    continue;
                }
                state.update(params, opt_state)?;
                acc.push(&m);
                break;
            }
        }
        sw.stop();
        anyhow::ensure!(state.is_finite(), "parameters diverged at epoch {epoch}");
        let rec = acc.finish(epoch, t0.elapsed().as_secs_f64(), router.rung());
        if opts.verbose {
            println!(
                "[{}] epoch {epoch}: gmm {:.4} nfe {:.1} rung {} ({:.2}s)",
                method.label(true),
                rec.loss,
                rec.nfe,
                rec.rung,
                rec.wall_s
            );
        }
        epochs_out.push(rec);
    }

    engine.load(&format!("{MODEL}_predict"))?;
    let t0 = std::time::Instant::now();
    let out = engine.run(
        &format!("{MODEL}_predict"),
        &[
            Input::F32(&state.params),
            Input::F32(&u0),
            Input::F32(&data_mu),
            Input::F32(&data_var),
            Input::F32(&ts),
            Input::SeedU32(999),
        ],
    )?;
    let pred_s = t0.elapsed().as_secs_f64();
    let m = Metrics::decode(&out[1])?;

    Ok(RunResult {
        experiment: "table3_spiral_sde".into(),
        method: method.label(true),
        seed: opts.seed,
        epochs: epochs_out,
        train_time_s: sw.total_secs(),
        predict_time_s: pred_s,
        predict_nfe: m.nfe,
        final_train_metric: m.metric,
        final_test_metric: m.metric,
        final_train_loss: m.loss,
        final_test_loss: m.loss,
        escalations: router.escalations,
        descents: router.descents,
    })
}

/// Predicted ensemble at the save grid (Figure 5 series: [T, N_TRAJ, 2]).
pub fn predict_ensemble(engine: &Engine, params: &[f32], seed: u32) -> Result<Vec<f32>> {
    let (u0, data_mu, data_var, ts) = ground_truth(0);
    let out = engine.run(
        &format!("{MODEL}_predict"),
        &[
            Input::F32(params),
            Input::F32(&u0),
            Input::F32(&data_mu),
            Input::F32(&data_var),
            Input::F32(&ts),
            Input::SeedU32(seed),
        ],
    )?;
    Ok(out.into_iter().next().unwrap())
}
